"""Benchmark entry point — one section per paper table/figure plus the
roofline summary.  Prints ``name,us_per_call,derived`` CSV lines per section.

    PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    t_start = time.time()

    print("# === Table 1: execution time vs graph size (paper §4.4) ===")
    from benchmarks import table1_speed
    for r in table1_speed.run():
        if "linearity_ratio" in r:
            print(f"{r['algo']},0,m={r['m']};ratio={r['linearity_ratio']:.3f}")
            continue
        derived = f"m={r['m']};{r['edges_per_s']:.0f} edges/s"
        if "peak_buffer_bytes" in r:
            # the paper's memory claim, measured: resident edge buffer
            # (O(batch)) alongside the 3n-int state
            derived += (f";edge_buf={r['peak_buffer_bytes']/1e6:.1f}MB"
                        f";state={r['state_bytes']/1e6:.1f}MB")
        print(f"{r['algo']},{r['seconds']*1e6:.0f},{derived}")

    print("\n# === Table 2: detection quality F1/NMI (paper §4.4) ===")
    from benchmarks import table2_quality
    for r in table2_quality.run():
        print(f"{r['regime']}/{r['algo']},{r['seconds']*1e6:.0f},"
              f"F1={r['f1']:.3f};NMI={r['nmi']:.3f};Q={r['modularity']:.3f}")

    print("\n# === Memory footprint: 3n ints vs edge list (paper §4.4) ===")
    from benchmarks import memory_footprint
    for r in memory_footprint.run():
        print(f"memory/{r['dataset']},0,"
              f"state={r['state_int64_MB']:.1f}MB;"
              f"edges={r['edge_list_int64_MB']:.1f}MB;ratio={r['ratio']:.1f}x")

    print("\n# === Multi-v_max one-pass sweep (paper §2.5) ===")
    from benchmarks import multiparam_bench
    for r in multiparam_bench.run():
        print(f"multiparam/A={r['A']},{r['sweep_s']*1e6:.0f},"
              f"separate={r['separate_s']*1e6:.0f}us;speedup={r['speedup']:.2f}x")

    print("\n# === Kernel micro-benchmarks ===")
    from benchmarks import kernel_bench
    for r in kernel_bench.run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")

    print("\n# === Roofline summary (from dry-run artifacts) ===")
    try:
        from benchmarks import roofline
        cells = roofline.load_cells("single")
        for c in cells:
            if c["status"] != "ok":
                print(f"roofline/{c['arch']}/{c['shape']},0,skipped")
                continue
            r = c["roofline"]
            print(
                f"roofline/{c['arch']}/{c['shape']},"
                f"{r['roofline_s']*1e6:.0f},"
                f"dominant={r['dominant']};fraction={r['roofline_fraction']:.4f}"
            )
    except Exception as e:  # dry-run artifacts absent
        print(f"roofline,0,unavailable({e})", file=sys.stderr)

    print(f"\n# total benchmark wall time: {time.time()-t_start:.0f}s")


if __name__ == "__main__":
    main()
