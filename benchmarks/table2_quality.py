"""Paper Table 2 — detection quality (avg F1 / NMI) vs baselines.

SNAP ground-truth graphs are not available offline; we use SBM streams with
planted communities in two regimes mirroring the paper's datasets: many small
communities (SNAP-like: Amazon/DBLP ground truth averages ~10-30 nodes) and
fewer large ones.  STR runs the one-pass multi-v_max sweep (paper §2.5) with
density-based selection; the best-in-sweep entry is also reported (upper
bound of the selector).  Distributed STR (8 shards) quantifies the 2-level
merge quality cost.  All STR tiers run through ``repro.cluster``.  The
stream is produced by a segment generator (``sbm_segments``) and
materialized exactly once for the F1/NMI/Q *evaluation*, which reads the
whole graph by definition; the clustering tiers themselves all stream
(every backend is resumable/out-of-core since PR 3), and that ingestion
path is measured in ``table1_speed`` and the ``streaming_tiers`` smoke
rows instead.
"""

from __future__ import annotations

import time

import numpy as np

from repro.cluster import (
    ClusterConfig,
    GeneratorSource,
    avg_f1,
    canonical_labels,
    cluster,
    modularity,
    nmi,
)
from repro.core.labelprop import label_propagation
from repro.core.louvain import louvain
from repro.graph.generators import sbm_segments

REGIMES = {
    "sbm-small-comm": dict(n=20_000, k=1000, avg_degree=10, p_intra=0.7),
    "sbm-large-comm": dict(n=10_000, k=100, avg_degree=16, p_intra=0.8),
}

V_MAXES = (8, 16, 32, 64, 128, 256, 512, 1024)


def run(regimes=None):
    rows = []
    for regime, kw in (REGIMES if regimes is None else regimes).items():
        n, k = kw["n"], kw["k"]
        m = int(n * kw["avg_degree"] / 2)
        segment, truth = sbm_segments(n, k, p_intra=kw["p_intra"], seed=11)
        source = GeneratorSource(segment, m, segment_edges=1 << 15)
        edges = source.materialize()  # one copy: clusterers + evaluation

        def add(name, labels, seconds, **extra):
            labels = canonical_labels(labels)
            rows.append({
                "regime": regime, "algo": name,
                "f1": avg_f1(labels, truth), "nmi": nmi(labels, truth),
                "modularity": modularity(edges, labels), "seconds": seconds,
                **extra,
            })

        def refine_fields(info):
            # the refinement memory/fidelity claim, visible per row
            return dict(
                refine_sketch_peak_bytes=info["refine_sketch_peak_bytes"],
                refine_dropped_weight=info["refine_dropped_weight"],
                refine_supernodes=info["refine_supernodes"],
                refine_communities=info["refine_communities"],
                refine_replay_rows=info["refine_replay_rows"],
            )

        t0 = time.perf_counter()
        sweep = cluster(edges, ClusterConfig(
            n=n, backend="multiparam", v_maxes=V_MAXES, criterion="density"))
        t1 = time.perf_counter()
        add("STR(sweep,density-pick)", sweep.labels, t1 - t0)

        sweep_labels = sweep.info["sweep_labels"]
        f1s = [
            avg_f1(canonical_labels(np.asarray(sweep_labels[a])), truth)
            for a in range(len(V_MAXES))
        ]
        best = int(np.argmax(f1s))
        add(f"STR(best v_max={V_MAXES[best]})", np.asarray(sweep_labels[best]),
            t1 - t0)

        # the refinement tiers (DESIGN.md §11): same one-pass sweep, plus a
        # contracted-supergraph refinement at finalize — sketch-only
        # (louvain) and sketch+buffered-replay, the quality acceptance row
        t0 = time.perf_counter()
        ref_lv = cluster(edges, ClusterConfig(
            n=n, backend="multiparam", v_maxes=V_MAXES, criterion="density",
            refine="louvain"))
        add("STR(sweep)+refine(louvain)", ref_lv.labels,
            time.perf_counter() - t0, refine=ref_lv.config.refine,
            **refine_fields(ref_lv.info))

        t0 = time.perf_counter()
        ref_rp = cluster(edges, ClusterConfig(
            n=n, backend="multiparam", v_maxes=V_MAXES, criterion="density",
            refine="labelprop+replay"))
        add("STR(sweep)+refine", ref_rp.labels, time.perf_counter() - t0,
            refine=ref_rp.config.refine, **refine_fields(ref_rp.info))

        t0 = time.perf_counter()
        dist = cluster(edges, ClusterConfig(
            n=n, v_max=V_MAXES[best], backend="distributed", n_shards=8,
            chunk=2048))
        add("STR-distributed(8 shards)", dist.labels, time.perf_counter() - t0)

        t0 = time.perf_counter()
        add("Louvain", louvain(edges, n, seed=0), time.perf_counter() - t0)
        t0 = time.perf_counter()
        add("LabelProp", label_propagation(edges, n, sweeps=3),
            time.perf_counter() - t0)
    return rows


def main():
    cur = None
    for r in run():
        if r["regime"] != cur:
            cur = r["regime"]
            print(f"\n--- {cur} ---")
        print(f"{r['algo']:28s} F1={r['f1']:.3f} NMI={r['nmi']:.3f} "
              f"Q={r['modularity']:.3f} ({r['seconds']:.2f}s)")


if __name__ == "__main__":
    main()
