"""Kernel-tier micro-benchmarks (CPU; interpret-mode Pallas is a correctness
vehicle, not a perf proxy — TPU perf is covered by the §Roofline analysis).

Clustering tiers are exercised through the unified ``repro.cluster`` API so
the benchmark measures exactly what callers get.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.cluster import ClusterConfig, cluster
from repro.graph.generators import chung_lu_stream
from repro.kernels.seg_volume.ops import seg_volume
from repro.kernels.seg_volume.ref import seg_volume_ref


def _t(fn, *args):
    out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    t0 = time.perf_counter()
    out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return time.perf_counter() - t0


def run():
    rows = []
    n, m = 20_000, 200_000
    edges = chung_lu_stream(n, m, seed=1)
    scan_cfg = ClusterConfig(n=n, v_max=64, backend="scan")
    t_scan = _t(lambda e: cluster(e, scan_cfg), edges)
    rows.append({"name": "cluster_scan(1edge/step)", "us_per_call": t_scan * 1e6,
                 "derived": f"{m/t_scan:,.0f} edges/s"})
    for chunk in (512, 4096):
        cfg = ClusterConfig(n=n, v_max=64, backend="chunked", chunk=chunk)
        t_c = _t(lambda e: cluster(e, cfg), edges)
        rows.append({"name": f"cluster_chunked(B={chunk})",
                     "us_per_call": t_c * 1e6,
                     "derived": f"{m/t_c:,.0f} edges/s"})
    # Pallas-tier fused paths (interpret mode on CPU, hence the smaller
    # stream): the megabatch DMA kernel and the wavefront variant — visible
    # here so kernel-level regressions surface outside the smoke suite.
    m_pal = 50_000
    edges_pal = chung_lu_stream(n, m_pal, seed=2)
    mega_cfg = ClusterConfig(n=n, v_max=64, backend="pallas", chunk=1024,
                             batch_edges=1024, megabatch_k=8)
    t_mb = _t(lambda e: cluster(e, mega_cfg), edges_pal)
    rows.append({"name": "cluster_pallas_megabatch(K=8,B=1024)",
                 "us_per_call": t_mb * 1e6,
                 "derived": f"{m_pal/t_mb:,.0f} edges/s"})
    wave_cfg = mega_cfg.replace(wavefront=16)
    t_wf = _t(lambda e: cluster(e, wave_cfg), edges_pal)
    rows.append({"name": "cluster_pallas_wavefront(K=8,B=1024,W=16)",
                 "us_per_call": t_wf * 1e6,
                 "derived": f"{m_pal/t_wf:,.0f} edges/s"})
    lab = jnp.asarray(np.random.default_rng(0).integers(0, 1024, 65536))
    w = jnp.ones(65536, jnp.float32)
    t_ref = _t(lambda l: seg_volume_ref(l, w, 1024), lab)
    rows.append({"name": "seg_volume_scatter_ref", "us_per_call": t_ref * 1e6,
                 "derived": ""})
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.0f},{r['derived']}")


if __name__ == "__main__":
    main()
