"""Roofline summary: reads results/dryrun/*.json into the EXPERIMENTS.md
table (per-cell three terms, dominant bottleneck, useful-FLOPs ratio)."""

from __future__ import annotations

import glob
import json
import os

_BASE = os.path.dirname(__file__)
# authoritative sweep = final optimized code; fall back to the first sweep
RESULTS = os.path.join(_BASE, "../results/dryrun_opt")
if not os.path.isdir(RESULTS):
    RESULTS = os.path.join(_BASE, "../results/dryrun")


def load_cells(mesh="single"):
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def fmt_s(x):
    if x >= 1.0:
        return f"{x:8.2f}s "
    return f"{x*1e3:8.2f}ms"


def table(mesh="single", file=None):
    cells = load_cells(mesh)
    hdr = (f"{'arch':22s} {'shape':12s} {'GB/dev':>7s} {'fit':>4s} "
           f"{'compute':>10s} {'memory':>10s} {'collective':>10s} "
           f"{'dominant':>11s} {'useful':>7s} {'roofline%':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        if c["status"] == "skipped":
            lines.append(
                f"{c['arch']:22s} {c['shape']:12s} {'—':>7s} {'—':>4s} "
                f"{'skipped: ' + c['reason']:>44s}"
            )
            continue
        r = c["roofline"]
        m = c["memory"]
        lines.append(
            f"{c['arch']:22s} {c['shape']:12s} "
            f"{m['bytes_per_device']/1e9:7.2f} "
            f"{'y' if m['fits_16GB'] else 'N':>4s} "
            f"{fmt_s(r['compute_s'])} {fmt_s(r['memory_s'])} "
            f"{fmt_s(r['collective_s'])} "
            f"{r['dominant'].replace('_s',''):>11s} "
            f"{r['useful_flops_ratio']:7.3f} "
            f"{100*r['roofline_fraction']:8.2f}%"
        )
    out = "\n".join(lines)
    if file:
        print(out, file=file)
    else:
        print(out)
    return cells


def main():
    for mesh in ("single", "multi"):
        print(f"\n=== mesh: {mesh} ===")
        table(mesh)


if __name__ == "__main__":
    main()
