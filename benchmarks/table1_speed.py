"""Paper Table 1 — execution time vs graph size (+ the `cat` lower bound).

The paper streams SNAP graphs of 1e6..1.8e9 edges; offline we run synthetic
Chung–Lu streams at 1e5..1e7 edges, assert linear scaling in m (the paper's
complexity claim), and report per-edge throughput so the Friendster-scale
runtime is a direct extrapolation.  The `stream_read` row reproduces the
paper's `cat` comparison: a pass over the edge stream that does no clustering
work (memory-bandwidth lower bound).

Each stream is produced by a segment generator (``chung_lu_segments``, O(segment)
memory), spooled once to a binary edge file, and both the `cat` pass and the
clusterer then stream that *same file* through ``BinaryFileSource`` +
``BatchPipeline`` — so `stream_read` stays a genuine pass over stored bytes
(page-cache/memory-bandwidth bound, as in the paper) and the STR rows measure
clustering an on-disk stream, not RNG throughput.  The edge list never
materializes on the heap; each STR row reports the measured peak edge-buffer
bytes next to the ``3n``-int state, which is the paper's memory claim made
visible.  Baselines (Louvain/LabelProp) are inherently non-streaming and
materialize a small stream once.
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.cluster import (
    BatchPipeline,
    BinaryFileSource,
    ClusterConfig,
    GeneratorSource,
    cluster,
)
from repro.core.labelprop import label_propagation
from repro.core.louvain import louvain
from repro.graph.generators import chung_lu_segments
from repro.graph.stream import state_bytes


def _time(fn, *args, repeat=1, warm=True):
    if warm:
        fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / repeat


def _spool(n, m, seed, segment, path):
    """Generate the stream segment-by-segment and write it to ``path`` —
    O(segment) memory end to end."""
    gen = GeneratorSource(
        chung_lu_segments(n, seed=seed), m, segment_edges=segment
    )
    return BinaryFileSource.write(path, gen)


def run(sizes=(100_000, 1_000_000, 5_000_000), v_max=64, baselines_at=300_000,
        batch_edges=1 << 18):
    rows = []
    with tempfile.TemporaryDirectory(prefix="table1_streams_") as tmpdir:
        rows = _run_sizes(tmpdir, sizes, v_max, baselines_at, batch_edges)
    # linearity check + Friendster extrapolation for the streaming tier
    str_rows = [r for r in rows if r["algo"] == "STR-chunked"]
    if len(str_rows) >= 2:
        a, b = str_rows[0], str_rows[-1]
        scale = (b["seconds"] / a["seconds"]) / (b["m"] / a["m"])
        # a dimensionless ratio, not a throughput — kept out of edges_per_s
        # so baseline diffs never treat it as a measured-throughput row
        rows.append({"algo": "STR-linearity(t ratio / m ratio)", "m": b["m"],
                     "linearity_ratio": scale})
        rows.append({
            "algo": "STR-friendster-extrapolation(1.8e9 edges)",
            "m": 1_806_067_135,
            "seconds": 1_806_067_135 / b["edges_per_s"],
            "edges_per_s": b["edges_per_s"],
            # projected from the measured per-edge rate, not a run — the
            # baseline diff skips it when comparing measured values
            "extrapolated": True,
        })
    return rows


def _run_sizes(tmpdir, sizes, v_max, baselines_at, batch_edges):
    rows = []
    for m in sizes:
        n = max(m // 10, 1000)
        path = os.path.join(tmpdir, f"chung_lu_{m}.bin")
        src = _spool(n, m, seed=m % 97, segment=min(batch_edges, m), path=path)
        chunked_cfg = ClusterConfig(n=n, v_max=v_max, backend="chunked",
                                    chunk=4096, batch_edges=batch_edges)

        def stream_read(source):
            # the paper's `cat`: touch every stored edge, cluster nothing
            acc = np.int32(0)
            for batch in BatchPipeline(source, batch_edges):
                acc ^= np.bitwise_xor.reduce(batch.edges, axis=None)
            return acc

        t_read = _time(stream_read, src)
        res = cluster(src, chunked_cfg)  # warmup/compile + buffer measurement
        t_str = _time(lambda s: cluster(s, chunked_cfg), src, warm=False)
        rows.append(
            {"algo": "stream_read(cat)", "m": m, "seconds": t_read,
             "edges_per_s": m / t_read}
        )
        rows.append(
            {"algo": "STR-chunked", "m": m, "seconds": t_str,
             "edges_per_s": m / t_str,
             "peak_buffer_bytes": res.info["peak_buffer_bytes"],
             "state_bytes": state_bytes(n)}
        )
        if m <= baselines_at:
            edges = src.materialize()  # baselines are not streaming
            dense_cfg = ClusterConfig(n=n, v_max=v_max, backend="dense")
            t_oracle = _time(lambda e: cluster(e, dense_cfg), edges)
            t_lv = _time(lambda e: louvain(e, n, seed=0), edges)
            t_lp = _time(lambda e: label_propagation(e, n, sweeps=3), edges)
            rows.append({"algo": "STR-sequential(paper)", "m": m,
                         "seconds": t_oracle, "edges_per_s": m / t_oracle})
            rows.append({"algo": "Louvain", "m": m, "seconds": t_lv,
                         "edges_per_s": m / t_lv})
            rows.append({"algo": "LabelProp", "m": m, "seconds": t_lp,
                         "edges_per_s": m / t_lp})
        os.remove(path)  # spooled stream no longer needed; bounds disk use
    return rows


def main():
    for r in run():
        if "linearity_ratio" in r:
            print(f"{r['algo']:42s} m={r['m']:>12,d} "
                  f"ratio={r['linearity_ratio']:.3f}")
            continue
        extra = ""
        if "peak_buffer_bytes" in r:
            extra = (f"  buf={r['peak_buffer_bytes']/1e6:.1f}MB "
                     f"state={r['state_bytes']/1e6:.1f}MB")
        print(f"{r['algo']:42s} m={r['m']:>12,d} {r['seconds']:10.3f}s "
              f"{r['edges_per_s']:>14,.0f} edges/s{extra}")


if __name__ == "__main__":
    main()
