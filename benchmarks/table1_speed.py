"""Paper Table 1 — execution time vs graph size (+ the `cat` lower bound).

The paper streams SNAP graphs of 1e6..1.8e9 edges; offline we run synthetic
Chung–Lu streams at 1e5..1e7 edges, assert linear scaling in m (the paper's
complexity claim), and report per-edge throughput so the Friendster-scale
runtime is a direct extrapolation.  The `stream_read` row reproduces the
paper's `cat` comparison: a pass over the edge stream that does no clustering
work (memory-bandwidth lower bound).

All streaming tiers run through the unified ``repro.cluster`` API.
"""

from __future__ import annotations

import time

import numpy as np

from repro.cluster import ClusterConfig, cluster
from repro.core.labelprop import label_propagation
from repro.core.louvain import louvain
from repro.graph.generators import chung_lu_stream


def _time(fn, *args, repeat=1):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / repeat


def run(sizes=(100_000, 1_000_000, 5_000_000), v_max=64, baselines_at=300_000):
    rows = []
    for m in sizes:
        n = max(m // 10, 1000)
        edges = chung_lu_stream(n, m, seed=m % 97)
        chunked_cfg = ClusterConfig(n=n, v_max=v_max, backend="chunked",
                                    chunk=4096)

        t_read = _time(lambda e: np.bitwise_xor.reduce(e, axis=None), edges)
        t_str = _time(lambda e: cluster(e, chunked_cfg), edges)
        rows.append(
            {"algo": "stream_read(cat)", "m": m, "seconds": t_read,
             "edges_per_s": m / t_read}
        )
        rows.append(
            {"algo": "STR-chunked", "m": m, "seconds": t_str,
             "edges_per_s": m / t_str}
        )
        if m <= baselines_at:
            dense_cfg = ClusterConfig(n=n, v_max=v_max, backend="dense")
            t_oracle = _time(lambda e: cluster(e, dense_cfg), edges)
            t_lv = _time(lambda e: louvain(e, n, seed=0), edges)
            t_lp = _time(lambda e: label_propagation(e, n, sweeps=3), edges)
            rows.append({"algo": "STR-sequential(paper)", "m": m,
                         "seconds": t_oracle, "edges_per_s": m / t_oracle})
            rows.append({"algo": "Louvain", "m": m, "seconds": t_lv,
                         "edges_per_s": m / t_lv})
            rows.append({"algo": "LabelProp", "m": m, "seconds": t_lp,
                         "edges_per_s": m / t_lp})
    # linearity check + Friendster extrapolation for the streaming tier
    str_rows = [r for r in rows if r["algo"] == "STR-chunked"]
    if len(str_rows) >= 2:
        a, b = str_rows[0], str_rows[-1]
        scale = (b["seconds"] / a["seconds"]) / (b["m"] / a["m"])
        rows.append({"algo": "STR-linearity(t ratio / m ratio)", "m": b["m"],
                     "seconds": scale, "edges_per_s": 0.0})
        rows.append({
            "algo": "STR-friendster-extrapolation(1.8e9 edges)",
            "m": 1_806_067_135,
            "seconds": 1_806_067_135 / b["edges_per_s"],
            "edges_per_s": b["edges_per_s"],
        })
    return rows


def main():
    for r in run():
        print(f"{r['algo']:42s} m={r['m']:>12,d} {r['seconds']:10.3f}s "
              f"{r['edges_per_s']:>14,.0f} edges/s")


if __name__ == "__main__":
    main()
