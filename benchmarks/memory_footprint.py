"""Paper §4.4 memory comparison: streaming state (3 ints/node) vs storing the
edge list (lower bound of non-streaming algorithms).

The paper's own numbers use 64-bit ints (Amazon 8.1 MB state vs 14.8 MB edge
list; Friendster 1.6 GB vs 28.9 GB) — reproduced analytically below alongside
our int32 implementation's footprint on the benchmark graphs.
"""

from __future__ import annotations

from repro.graph.stream import edge_list_bytes, state_bytes

PAPER_DATASETS = {
    "Amazon": (334_863, 925_872),
    "DBLP": (317_080, 1_049_866),
    "YouTube": (1_134_890, 2_987_624),
    "LiveJournal": (3_997_962, 34_681_189),
    "Orkut": (3_072_441, 117_185_083),
    "Friendster": (65_608_366, 1_806_067_135),
}


def run():
    rows = []
    for name, (n, m) in PAPER_DATASETS.items():
        rows.append({
            "dataset": name, "n": n, "m": m,
            "state_int64_MB": state_bytes(n, 8) / 1e6,  # paper's convention
            "state_int32_MB": state_bytes(n, 4) / 1e6,  # ours
            "edge_list_int64_MB": edge_list_bytes(m, 8) / 1e6,
            "ratio": edge_list_bytes(m, 8) / state_bytes(n, 8),
        })
    return rows


def main():
    print(f"{'dataset':12s} {'state(int64)':>13s} {'state(int32)':>13s} "
          f"{'edges(int64)':>13s} {'ratio':>7s}")
    for r in run():
        print(f"{r['dataset']:12s} {r['state_int64_MB']:>11.1f}MB "
              f"{r['state_int32_MB']:>11.1f}MB {r['edge_list_int64_MB']:>11.1f}MB "
              f"{r['ratio']:>6.1f}x")


if __name__ == "__main__":
    main()
