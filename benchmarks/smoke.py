"""CI benchmark smoke: tiny-size runs of the paper tables, written as a
``BENCH_*.json`` artifact so the perf trajectory is recorded per commit.

Sizes are deliberately small (seconds, not minutes, on a CI CPU runner) —
the artifact's value is the *trend* of edges/s, peak edge-buffer bytes, and
quality across commits, not absolute numbers.

    PYTHONPATH=src python -m benchmarks.smoke [--out BENCH_smoke.json]
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def run():
    from benchmarks import memory_footprint, table1_speed, table2_quality

    t0 = time.time()
    speed = table1_speed.run(
        sizes=(20_000, 80_000), baselines_at=20_000, batch_edges=1 << 14
    )

    # one tiny quality regime (module-level REGIMES is benchmark-scale)
    quality = table2_quality.run(regimes={
        "sbm-smoke": dict(n=2_000, k=100, avg_degree=10, p_intra=0.8),
    })

    return {
        "suite": "smoke",
        "python": platform.python_version(),
        "machine": platform.machine(),
        "wall_s": round(time.time() - t0, 2),
        "table1_speed": speed,
        "table2_quality": quality,
        "memory": memory_footprint.run(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_smoke.json")
    args = ap.parse_args(argv)
    report = run()
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, default=float)
    print(f"wrote {args.out} ({report['wall_s']}s)", file=sys.stderr)
    for r in report["table1_speed"]:
        print(f"smoke/{r['algo']},{r['seconds']*1e6:.0f},"
              f"{r['edges_per_s']:.0f} edges/s")


if __name__ == "__main__":
    main()
