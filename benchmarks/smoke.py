"""CI benchmark smoke: tiny-size runs of the paper tables, written as a
``BENCH_*.json`` artifact so the perf trajectory is recorded per commit.

Sizes are deliberately small (seconds, not minutes, on a CI CPU runner) —
the artifact's value is the *trend* of edges/s, peak edge-buffer bytes, and
quality across commits, not absolute numbers.  ``streaming_tiers`` rows
record the memory frontier of the two wide-state tiers (multiparam sweep,
sharded distributed): measured peak edge-buffer bytes vs the bytes the
stream would occupy materialized, next to each tier's state bytes.
``compressed_stream`` rows record the ingest-bandwidth frontier: on-disk
bytes/edge and decode throughput for the raw vs delta+varint codecs (the
dvc ratio staying under 0.5x raw is checked structurally — it is a format
property, not a runner-speed number).

    PYTHONPATH=src python -m benchmarks.smoke [--out BENCH_smoke.json]
                                              [--baseline BENCH_smoke.json]

``--baseline`` diffs the fresh report against a committed baseline
*structurally* (suites, row identities, memory-claim fields) and exits
non-zero on drift — numbers vary per runner, shape must not.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def streaming_tiers():
    """Out-of-core rows for the wide-state tiers: peak buffer vs state."""
    from repro.cluster import ClusterConfig, GeneratorSource, cluster
    from repro.graph.generators import chung_lu_segments
    from repro.graph.stream import edge_list_bytes, state_bytes

    rows = []
    n, batch = 20_000, 1 << 13
    # m must dominate the pipeline's (prefetch + 1) batch buffers for the
    # out-of-core claim to be visible at smoke scale
    src = GeneratorSource(chung_lu_segments(n, seed=13), 120_000,
                          segment_edges=batch)
    A = 4
    cfg = ClusterConfig(n=n, backend="multiparam",
                        v_maxes=(16, 64, 256, 1024), batch_edges=batch)
    cluster(src, cfg).block_until_ready()  # warmup/compile
    t0 = time.time()
    res = cluster(src, cfg).block_until_ready()
    dt = time.time() - t0
    rows.append({
        "tier": "multiparam", "m": src.n_edges, "A": A, "seconds": dt,
        "edges_per_s": src.n_edges / dt,
        "peak_buffer_bytes": res.info["peak_buffer_bytes"],
        "state_bytes": (2 * A + 1) * n * 4,
        "edge_list_bytes": edge_list_bytes(src.n_edges, 4),
    })

    src = GeneratorSource(chung_lu_segments(n, seed=17), 400_000,
                          segment_edges=batch)
    dcfg = ClusterConfig(n=n, v_max=64, backend="distributed", n_shards=4,
                         chunk=4096, batch_edges=batch)
    cluster(src, dcfg).block_until_ready()
    t0 = time.time()
    res = cluster(src, dcfg).block_until_ready()
    dt = time.time() - t0
    rows.append({
        "tier": "distributed", "m": src.n_edges, "n_shards": 4, "seconds": dt,
        "edges_per_s": src.n_edges / dt,
        "peak_buffer_bytes": res.info["peak_buffer_bytes"],
        "state_bytes": 3 * 4 * n * 4,  # 3Pn ints, P = 4
        "edge_list_bytes": edge_list_bytes(src.n_edges, 4),
    })
    return rows


def compressed_stream():
    """Codec rows: on-disk bytes/edge and decode throughput, raw vs dvc.

    The stream is the delta codec's target regime — sorted-by-source with
    community locality (the SNAP/CSR-ish on-disk layout) — so the row
    records the bandwidth trade the codec exists for: fewer stream bytes
    for vectorized decode compute.
    """
    import os
    import tempfile

    import numpy as np

    from repro.graph.codecs import DeltaVarintCodec, RawCodec
    from repro.graph.sources import CodecFileSource

    n, m = 20_000, 400_000
    rng = np.random.default_rng(23)
    i = np.sort(rng.integers(0, n, m).astype(np.int64))
    j = (i + rng.integers(-64, 65, m)) % n
    edges = np.stack([i, np.where(j == i, (j + 1) % n, j)], 1).astype(np.int32)

    rows = []
    with tempfile.TemporaryDirectory() as d:
        for name, codec in (("raw", RawCodec()), ("dvc", DeltaVarintCodec())):
            path = os.path.join(d, f"s.{name}")
            t0 = time.time()
            src = CodecFileSource.write(path, edges, codec)
            enc_s = time.time() - t0
            t0 = time.time()
            sink = 0
            for sl in src.iter_slices(0):
                # reduce every row: raw slices are lazy memmap views, so the
                # timed loop must fault the pages or it measures nothing
                sink += int(np.asarray(sl, np.int64).sum())
            dec_s = time.time() - t0
            assert sink == int(edges.astype(np.int64).sum())
            nbytes = os.path.getsize(path)
            rows.append({
                "codec": name, "m": m,
                "bytes_per_edge": nbytes / m,
                "ratio_vs_raw": nbytes / (8 * m),
                "encode_s": enc_s, "decode_s": dec_s,
                # raw-equivalent stream bandwidth the decode sustains
                "decode_mb_per_s": 8 * m / dec_s / 1e6,
            })
    return rows


def run():
    from benchmarks import memory_footprint, table1_speed, table2_quality

    t0 = time.time()
    speed = table1_speed.run(
        sizes=(20_000, 80_000), baselines_at=20_000, batch_edges=1 << 14
    )

    # one tiny quality regime (module-level REGIMES is benchmark-scale)
    quality = table2_quality.run(regimes={
        "sbm-smoke": dict(n=2_000, k=100, avg_degree=10, p_intra=0.8),
    })

    return {
        "suite": "smoke",
        "python": platform.python_version(),
        "machine": platform.machine(),
        "wall_s": round(time.time() - t0, 2),
        "table1_speed": speed,
        "table2_quality": quality,
        "streaming_tiers": streaming_tiers(),
        "compressed_stream": compressed_stream(),
        "memory": memory_footprint.run(),
    }


def check_against_baseline(report: dict, baseline: dict) -> list:
    """Structural diff: same suites, same row identities, memory-claim
    fields present.  Values are runner-dependent and not compared."""
    problems = []
    for key in ("table1_speed", "table2_quality", "streaming_tiers",
                "compressed_stream", "memory"):
        if (key in baseline) != (key in report):
            problems.append(f"suite {key!r} appeared/disappeared")

    def ids(rows, field):
        return sorted({r[field] for r in rows if field in r})

    if "table1_speed" in baseline and "table1_speed" in report:
        got, want = ids(report["table1_speed"], "algo"), ids(
            baseline["table1_speed"], "algo")
        if got != want:
            problems.append(f"table1 algos changed: {want} -> {got}")
    if "table2_quality" in baseline and "table2_quality" in report:
        got, want = ids(report["table2_quality"], "algo"), ids(
            baseline["table2_quality"], "algo")
        if got != want:
            problems.append(f"table2 algos changed: {want} -> {got}")
    if "streaming_tiers" in baseline and "streaming_tiers" in report:
        got, want = ids(report["streaming_tiers"], "tier"), ids(
            baseline["streaming_tiers"], "tier")
        if got != want:
            problems.append(f"streaming tiers changed: {want} -> {got}")
        for row in report.get("streaming_tiers", []):
            for field in ("peak_buffer_bytes", "state_bytes",
                          "edge_list_bytes"):
                if field not in row:
                    problems.append(
                        f"streaming tier {row.get('tier')!r} lost {field!r}")
            if row.get("peak_buffer_bytes", 0) >= row.get(
                    "edge_list_bytes", float("inf")):
                problems.append(
                    f"tier {row.get('tier')!r} buffered the whole stream "
                    f"({row.get('peak_buffer_bytes')} B)")
    if "compressed_stream" in baseline and "compressed_stream" in report:
        got, want = ids(report["compressed_stream"], "codec"), ids(
            baseline["compressed_stream"], "codec")
        if got != want:
            problems.append(f"codecs changed: {want} -> {got}")
        for row in report.get("compressed_stream", []):
            for field in ("bytes_per_edge", "ratio_vs_raw",
                          "decode_mb_per_s"):
                if field not in row:
                    problems.append(
                        f"codec {row.get('codec')!r} lost {field!r}")
            # the bandwidth claim itself: the compressed stream must stay
            # under half the raw bytes/edge (hardware-independent; a row
            # missing the field entirely is reported by the loop above)
            ratio = row.get("ratio_vs_raw")
            if row.get("codec") == "dvc" and ratio is not None and ratio >= 0.5:
                problems.append(
                    f"dvc ratio_vs_raw {ratio:.3f} >= 0.5 — compression "
                    "claim regressed")
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_smoke.json")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_smoke.json to diff against")
    args = ap.parse_args(argv)
    report = run()
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, default=float)
    print(f"wrote {args.out} ({report['wall_s']}s)", file=sys.stderr)
    for r in report["table1_speed"]:
        print(f"smoke/{r['algo']},{r['seconds']*1e6:.0f},"
              f"{r['edges_per_s']:.0f} edges/s")
    for r in report["streaming_tiers"]:
        print(f"smoke/{r['tier']},buf={r['peak_buffer_bytes']},"
              f"state={r['state_bytes']},edges={r['edge_list_bytes']}")
    for r in report["compressed_stream"]:
        print(f"smoke/codec-{r['codec']},{r['bytes_per_edge']:.2f} B/edge,"
              f"{r['decode_mb_per_s']:.0f} MB/s decode")
    if args.baseline:
        try:
            with open(args.baseline) as f:
                baseline = json.load(f)
        except FileNotFoundError:
            print(f"baseline {args.baseline!r} not found — commit a "
                  "BENCH_smoke.json baseline (see --out)", file=sys.stderr)
            return 1
        problems = check_against_baseline(report, baseline)
        for p in problems:
            print(f"baseline drift: {p}", file=sys.stderr)
        if problems:
            return 1
        print("baseline diff: structure unchanged", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
