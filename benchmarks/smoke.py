"""CI benchmark smoke: tiny-size runs of the paper tables, written as a
``BENCH_*.json`` artifact so the perf trajectory is recorded per commit.

Sizes are deliberately small (seconds, not minutes, on a CI CPU runner) —
the artifact's value is the *trend* of edges/s, peak edge-buffer bytes, and
quality across commits, not absolute numbers.  ``streaming_tiers`` rows
record the memory frontier of the two wide-state tiers (multiparam sweep,
sharded distributed): measured peak edge-buffer bytes vs the bytes the
stream would occupy materialized, next to each tier's state bytes.
``compressed_stream`` rows record the ingest-bandwidth frontier: on-disk
bytes/edge and decode throughput for the raw vs delta+varint codecs (the
dvc ratio staying under 0.5x raw is checked structurally — it is a format
property, not a runner-speed number).  ``device_pipeline`` rows record the
dispatch-amortisation frontier: edges/s and exact dispatches-per-million-
edges for per-batch vs fused megabatch ingestion (``lax.scan``-over-chunks
and double-buffered-DMA Pallas), with the ~K-fold dispatch reduction and
the no-new-buffers counters asserted in-suite.

    PYTHONPATH=src python -m benchmarks.smoke [--out BENCH_smoke.json]
                                              [--baseline BENCH_smoke.json]

``--baseline`` diffs the fresh report against a committed baseline
*structurally* (suites, row identities, memory-claim fields) and exits
non-zero on drift — numbers vary per runner, shape must not.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def streaming_tiers():
    """Out-of-core rows for the wide-state tiers: peak buffer vs state."""
    from repro.cluster import ClusterConfig, GeneratorSource, cluster
    from repro.graph.generators import chung_lu_segments
    from repro.graph.stream import edge_list_bytes, state_bytes

    rows = []
    n, batch = 20_000, 1 << 13
    # m must dominate the pipeline's (prefetch + 1) batch buffers for the
    # out-of-core claim to be visible at smoke scale
    src = GeneratorSource(chung_lu_segments(n, seed=13), 120_000,
                          segment_edges=batch)
    A = 4
    cfg = ClusterConfig(n=n, backend="multiparam",
                        v_maxes=(16, 64, 256, 1024), batch_edges=batch)
    cluster(src, cfg).block_until_ready()  # warmup/compile
    t0 = time.time()
    res = cluster(src, cfg).block_until_ready()
    dt = time.time() - t0
    rows.append({
        "tier": "multiparam", "m": src.n_edges, "A": A, "seconds": dt,
        "edges_per_s": src.n_edges / dt,
        "peak_buffer_bytes": res.info["peak_buffer_bytes"],
        "state_bytes": (2 * A + 1) * n * 4,
        "edge_list_bytes": edge_list_bytes(src.n_edges, 4),
    })

    src = GeneratorSource(chung_lu_segments(n, seed=17), 400_000,
                          segment_edges=batch)
    dcfg = ClusterConfig(n=n, v_max=64, backend="distributed", n_shards=4,
                         chunk=4096, batch_edges=batch)
    cluster(src, dcfg).block_until_ready()
    t0 = time.time()
    res = cluster(src, dcfg).block_until_ready()
    dt = time.time() - t0
    rows.append({
        "tier": "distributed", "m": src.n_edges, "n_shards": 4, "seconds": dt,
        "edges_per_s": src.n_edges / dt,
        "peak_buffer_bytes": res.info["peak_buffer_bytes"],
        "state_bytes": 3 * 4 * n * 4,  # 3Pn ints, P = 4
        "edge_list_bytes": edge_list_bytes(src.n_edges, 4),
    })
    return rows


def device_pipeline():
    """Fused megabatch dispatch rows (DESIGN.md §10 device pipelining).

    The per-batch loop pays one jitted dispatch + one host→device transfer
    + one Python round-trip per ``BatchPipeline`` batch; megabatch mode
    stages K batches into one ``(K, B, 2)`` buffer on the prefetch thread
    and dispatches once (``chunked``: one ``lax.scan`` over all chunks;
    ``pallas``: one double-buffered-DMA kernel launch).  Rows record
    edges/s and *dispatches per million edges* — the dispatch counts are
    exact integers (hardware-independent), so the ~K-fold amortisation is
    asserted here and structurally checked against the baseline; the
    speedup ratio is recorded for the trajectory.

    Deliberately dispatch-bound shapes (small batches): the point is to
    measure the overhead the fused path removes, not the Jacobi compute.
    """
    import jax

    from repro.cluster import ClusterConfig, GeneratorSource, cluster
    from repro.graph.generators import chung_lu_segments
    from repro.graph.pipeline import pad_template_allocs

    # (mode, backend, m, batch_edges=chunk, megabatch_k)
    cases = [
        ("chunked-per-batch", "chunked", 400_000, 512, None),
        ("chunked-fused-scan", "chunked", 400_000, 512, 64),
        ("pallas-per-batch", "pallas", 100_000, 1024, None),
        ("pallas-megabatch-dma", "pallas", 100_000, 1024, 16),
    ]
    n = 10_000
    rows = []
    base_eps = {}
    for mode, backend, m, B, k in cases:
        src = GeneratorSource(chung_lu_segments(n, seed=29), m,
                              segment_edges=1 << 13)
        cfg = ClusterConfig(n=n, v_max=64, backend=backend, chunk=B,
                            batch_edges=B, megabatch_k=k)
        cluster(src, cfg).block_until_ready()  # warmup/compile
        live_before = len(jax.live_arrays())
        allocs_before = pad_template_allocs()
        t0 = time.time()
        res = cluster(src, cfg).block_until_ready()
        dt = time.time() - t0
        # Allocation counters: the PAD template must not regrow per batch,
        # and (with donated state buffers) a steady-state run must not
        # accumulate device arrays — both deterministic, both asserted.
        if pad_template_allocs() != allocs_before:
            raise RuntimeError(
                f"{mode}: PAD template reallocated during steady-state run")
        live_after = len(jax.live_arrays())
        if live_after - live_before > 16:
            raise RuntimeError(
                f"{mode}: device buffers grew {live_before} -> {live_after} "
                "across one run — donation/lifetime regression")
        batches = res.info["stream_batches"]
        dispatches = res.info["stream_dispatches"]
        want = batches if k is None else -(-batches // k)
        if dispatches != want:
            raise RuntimeError(
                f"{mode}: {dispatches} dispatches for {batches} batches "
                f"(megabatch_k={k}) — expected {want}")
        row = {
            "mode": mode, "backend": backend, "m": m, "batch_edges": B,
            "megabatch_k": k, "seconds": dt, "edges_per_s": m / dt,
            "dispatches": dispatches,
            "dispatches_per_m_edges": dispatches / (m / 1e6),
            "peak_buffer_bytes": res.info["peak_buffer_bytes"],
        }
        if k is None:
            base_eps[backend] = m / dt
        else:
            row["speedup_vs_per_batch"] = (m / dt) / base_eps[backend]
        rows.append(row)
    return rows


def kernel_wavefront():
    """Wavefront kernel-path rows (DESIGN.md §12 conflict-free batching).

    Measures the bit-exact tier's wave-vectorised apply against the
    sequential per-edge scan over the *same* staged megabatches — the exact
    work the wavefront subsystem replaces.  On CPU the Pallas kernel only
    runs in interpret mode (an emulator, not a perf vehicle), so the
    wavefront side is measured via the pure-JAX wave-apply reference path
    (``repro.core.wavefront`` — the math the kernel shares) and the
    sequential side via the same ``lax.scan`` step the kernel's fallback
    uses; labels are asserted bit-identical in-suite.  The planner runs
    host-side up front (its cost is its own column — in production it rides
    the pipeline's prefetch thread, overlapped with device work).

    The ``speedup_vs_sequential`` ratio is same-runner and is checked
    against the >= 2x floor in the baseline diff; the planner counters
    (mean wave width, fallback rate, leftover rows) are structural.
    """
    import functools

    import jax
    import numpy as np

    from repro.core.state import ClusterState
    from repro.core.streaming import _edge_update
    from repro.core.wavefront import wavefront_update_megabatch
    from repro.graph.generators import chung_lu_stream
    from repro.graph.wavefront import plan_waves

    import jax.numpy as jnp

    n, m, v_max = 10_000, 100_000, 64
    K, B, W = 16, 1024, 16
    M = K * B
    edges = chung_lu_stream(n, m, seed=29)
    megas = [edges[t * M : (t + 1) * M] for t in range(m // M)]
    m_run = len(megas) * M

    @functools.partial(jax.jit, donate_argnums=(0,))
    def seq_mega(state, flat, vm):
        (d, c, v), _ = jax.lax.scan(
            functools.partial(_edge_update, v_max=vm),
            (state.d, state.c, state.v),
            flat,
        )
        return ClusterState(d=d, c=c, v=v, edges_seen=state.edges_seen)

    def run_seq():
        s = ClusterState.init(n).to_device()
        t0 = time.time()
        for flat in megas:
            s = seq_mega(s, jnp.asarray(flat), jnp.int32(v_max))
        s.block_until_ready()
        return time.time() - t0, s

    plans = [plan_waves(flat, W) for flat in megas]

    def run_wave():
        s = ClusterState.init(n).to_device()
        stats = None
        t0 = time.time()
        for p in plans:
            s, st = wavefront_update_megabatch(
                s, jnp.asarray(p.waves), jnp.asarray(p.leftover),
                jnp.asarray(p.meta), jnp.int32(v_max),
            )
            stats = st if stats is None else stats + st
        s.block_until_ready()
        return time.time() - t0, s, np.asarray(stats)

    run_seq()  # warmup/compile
    run_wave()
    t_seq, s_seq = min(run_seq(), run_seq(), key=lambda r: r[0])
    t_wave, s_wave, stats = min(run_wave(), run_wave(), key=lambda r: r[0])
    if not (
        np.array_equal(np.asarray(s_seq.c), np.asarray(s_wave.c))
        and np.array_equal(np.asarray(s_seq.v), np.asarray(s_wave.v))
    ):
        raise RuntimeError(
            "wavefront labels diverged from the sequential kernel path")
    live, fall = int(stats[0]), int(stats[1])
    waves = sum(p.n_waves for p in plans)
    rows_in_waves = sum(p.rows_in_waves for p in plans)
    return [
        {
            "mode": "sequential-scan", "m": m_run, "megabatch_k": K,
            "batch_edges": B, "seconds": t_seq, "edges_per_s": m_run / t_seq,
        },
        {
            "mode": "wavefront", "m": m_run, "megabatch_k": K,
            "batch_edges": B, "width": W, "seconds": t_wave,
            "edges_per_s": m_run / t_wave,
            "speedup_vs_sequential": t_seq / t_wave,
            "waves": waves,
            "mean_wave_width": rows_in_waves / waves if waves else 0.0,
            "fallback_rate": fall / live if live else 0.0,
            "leftover_rows": sum(p.leftover_rows for p in plans),
            "plan_seconds": sum(p.plan_seconds for p in plans),
        },
    ]


def fleet():
    """Multi-tenant fleet rows (DESIGN.md §13 vmapped fleet engine).

    T independent tenant streams advanced by ONE donated dispatch per fleet
    step (stacked ``(T, n)`` FleetState, vmapped chunked update) vs the
    obvious alternative — a Python loop of T single-stream ``partial_fit``
    calls per step, paying T dispatches.  Deliberately dispatch-bound
    shapes, like ``device_pipeline``: small per-tenant graphs and batches
    are exactly the serving regime the fleet engine exists for (thousands
    of small per-user graphs), and per-tenant compute is identical on both
    sides, so the ratio isolates the T-fold dispatch amortisation.

    The headline metric is **tenants/s**: fleet size over the wall time to
    drain every tenant's whole stream.  Per-tenant labels are asserted
    bit-identical between the two paths in-suite (the fleet contract);
    ``dispatches_per_fleet_step == 1`` and the >= 5x speedup floor are
    checked against the baseline — dispatch counts are exact integers, and
    the ratio is same-runner so it travels across machines.
    """
    import numpy as np

    from repro.cluster import ClusterConfig, FleetClusterer, StreamClusterer
    from repro.graph.generators import sbm_segments

    T, n, B, steps = 256, 512, 64, 16
    # T independent SBM tenants from one base seed via per-tenant offsets
    streams = []
    for t in range(T):
        seg, _ = sbm_segments(n, 32, seed=31, seed_offset=t)
        streams.append(seg(0, B * steps))
    cfg = ClusterConfig(n=n, v_max=32, backend="chunked", chunk=B,
                        batch_edges=B, tenants=T)
    m_total = T * B * steps

    FleetClusterer(cfg).fit(streams)  # warmup/compile
    fc = FleetClusterer(cfg)
    t0 = time.time()
    fc.fit(streams)
    fc.state.block_until_ready()
    t_fleet = time.time() - t0
    res = fc.finalize()
    if res.info["dispatches_per_fleet_step"] != 1.0:
        raise RuntimeError(
            f"fleet step took {res.info['dispatches_per_fleet_step']} "
            "dispatches — the one-dispatch-per-step claim regressed")

    scfg = cfg.replace(tenants=None)
    StreamClusterer(scfg).partial_fit(streams[0][:B])  # warmup/compile
    scs = [StreamClusterer(scfg) for _ in range(T)]
    t0 = time.time()
    for s in range(steps):
        for t in range(T):
            scs[t].partial_fit(streams[t][s * B : (s + 1) * B])
    scs[-1].state.block_until_ready()
    t_loop = time.time() - t0

    # the fleet contract: per-tenant rows bit-identical to the looped runs
    for t in range(0, T, 17):
        if not np.array_equal(
            res.raw_labels[t], np.asarray(scs[t].state.to_numpy().c)
        ):
            raise RuntimeError(
                f"fleet tenant {t} labels diverged from its single-stream "
                "run")
    return [
        {
            "mode": "looped-partial-fit", "tenants": T, "n": n,
            "batch_edges": B, "fleet_steps": steps, "m": m_total,
            "seconds": t_loop, "tenants_per_s": T / t_loop,
            "edges_per_s": m_total / t_loop,
            "dispatches": T * steps,
        },
        {
            "mode": "fleet-vmap", "tenants": T, "n": n,
            "batch_edges": B, "fleet_steps": res.info["fleet_steps"],
            "m": m_total, "seconds": t_fleet,
            "tenants_per_s": T / t_fleet,
            "edges_per_s": m_total / t_fleet,
            "dispatches": res.info["stream_dispatches"],
            "dispatches_per_fleet_step":
                res.info["dispatches_per_fleet_step"],
            "peak_staging_bytes": res.info["peak_staging_bytes"],
            "speedup_vs_looped": t_loop / t_fleet,
        },
    ]


def compressed_stream():
    """Codec rows: on-disk bytes/edge and decode throughput, raw vs dvc.

    The stream is the delta codec's target regime — sorted-by-source with
    community locality (the SNAP/CSR-ish on-disk layout) — so the row
    records the bandwidth trade the codec exists for: fewer stream bytes
    for vectorized decode compute.
    """
    import os
    import tempfile

    import numpy as np

    from repro.graph.codecs import DeltaVarintCodec, RawCodec
    from repro.graph.sources import CodecFileSource

    n, m = 20_000, 400_000
    rng = np.random.default_rng(23)
    i = np.sort(rng.integers(0, n, m).astype(np.int64))
    j = (i + rng.integers(-64, 65, m)) % n
    edges = np.stack([i, np.where(j == i, (j + 1) % n, j)], 1).astype(np.int32)

    rows = []
    with tempfile.TemporaryDirectory() as d:
        # dvc-v1 rides along so the decode-fast-path win (DVE2 fixed-width
        # columns vs the per-byte varint loop) stays visible per commit;
        # dvc-v3 is the device-decodable lane layout (DESIGN.md §14)
        for name, codec in (
            ("raw", RawCodec()),
            ("dvc", DeltaVarintCodec()),
            ("dvc-v1", DeltaVarintCodec(version=1)),
            ("dvc-v3", DeltaVarintCodec(version=3)),
        ):
            path = os.path.join(d, f"s.{name}")
            t0 = time.time()
            src = CodecFileSource.write(path, edges, codec)
            enc_s = time.time() - t0
            # Corrected decode measurement: stage every slice into a
            # preallocated int32 buffer — the copy-out cost pipeline
            # staging actually pays.  The legacy sum-reduction fields below
            # let raw memmap slices ride lazy page faults + a cheap
            # reduction instead of a real materialization, flattering the
            # raw row; both field sets are kept for one release so baseline
            # trajectories can cross over.
            stage = np.empty((m, 2), np.int32)
            t0 = time.time()
            pos = 0
            for sl in src.iter_slices(0):
                k = len(sl)
                stage[pos : pos + k] = sl
                pos += k
            copy_s = time.time() - t0
            assert pos == m and np.array_equal(stage, edges)
            t0 = time.time()
            sink = 0
            for sl in src.iter_slices(0):
                # legacy loop (deprecated, one-release overlap): reduces
                # every row but never materializes the staging buffer
                sink += int(np.asarray(sl, np.int64).sum())
            dec_s = time.time() - t0
            assert sink == int(edges.astype(np.int64).sum())
            nbytes = os.path.getsize(path)
            rows.append({
                "codec": name, "m": m,
                "bytes_per_edge": nbytes / m,
                "ratio_vs_raw": nbytes / (8 * m),
                "encode_s": enc_s,
                # raw-equivalent stream bandwidth the encoder sustains
                "encode_mb_per_s": 8 * m / enc_s / 1e6,
                "decode_s": dec_s,  # deprecated: sum-reduction loop
                "decode_mb_per_s": 8 * m / dec_s / 1e6,  # deprecated
                "decode_copyout_s": copy_s,
                # raw-equivalent bandwidth of a real copy-out decode
                "decode_copyout_mb_per_s": 8 * m / copy_s / 1e6,
            })
    return rows


def resilience():
    """Fault-tolerance rows (DESIGN.md §15): what the robustness machinery
    costs when nothing fails, and what it accounts for when something does.

    ``plain`` vs ``hardened`` is a same-runner ratio with identical
    clustering compute on both sides — unchecksummed framing with retries
    disabled vs checksummed DVC blocks + RetryPolicy + stall watchdog — so
    ``overhead_ratio`` isolates the per-block crc32 and the retry/heartbeat
    bracketing.  The <5% ceiling is gated against the baseline (best-of-N
    wall times keep the ratio stable across runners).  The ``quarantine``
    and ``autosave`` rows pin the accounting counters structurally:
    ``edges_lost`` must equal the planted corruption exactly
    (``loss_exact``), and a 400k-row fit at ``autosave_every=64k`` must
    actually autosave — a silently disabled counter shows up as baseline
    drift, not a green run.
    """
    import os
    import tempfile

    import numpy as np

    from repro.cluster import ClusterConfig, cluster
    from repro.cluster.api import StreamClusterer
    from repro.graph.codecs import DeltaVarintCodec
    from repro.graph.faults import corrupt_blocks
    from repro.graph.sources import CodecFileSource

    n, m = 20_000, 800_000
    rng = np.random.default_rng(31)
    edges = rng.integers(0, n, size=(m, 2)).astype(np.int32)
    base = dict(n=n, v_max=64, backend="chunked", chunk=4096,
                batch_edges=1 << 14)

    def timed_fit(path, cfg):
        sc = StreamClusterer(cfg)
        t0 = time.time()
        sc.fit(CodecFileSource(path))
        dt = time.time() - t0
        return dt, sc.finalize()

    rows = []
    with tempfile.TemporaryDirectory() as d:
        plain_path = os.path.join(d, "p.dvc")
        CodecFileSource.write(plain_path, edges,
                              DeltaVarintCodec(checksum=False))
        hard_path = os.path.join(d, "h.dvc")
        CodecFileSource.write(hard_path, edges, DeltaVarintCodec())

        plain_cfg = ClusterConfig(**base, retries=0)
        hard_cfg = ClusterConfig(**base, retries=3, stall_timeout=60.0)
        timed_fit(plain_path, plain_cfg)  # warmup: jit compile + page cache
        timed_fit(hard_path, hard_cfg)
        # The gated e2e ratio is the median of back-to-back pairwise
        # ratios: each pair sees the same machine load, and the median
        # discards load-spike outliers that would flake a 5% gate.  In
        # steady state the prefetch thread fully overlaps decode with the
        # jitted update, so the machinery's cost vanishes from e2e
        # throughput — which is exactly the claim.
        ratios, plain_s, hard_s = [], [], []
        for _ in range(7):
            p_dt, plain_out = timed_fit(plain_path, plain_cfg)
            h_dt, hard_out = timed_fit(hard_path, hard_cfg)
            ratios.append(h_dt / p_dt)
            plain_s.append(p_dt)
            hard_s.append(h_dt)
        mid = sorted(ratios)[2:-2]  # trimmed mean of the middle 3 of 7
        overhead = sum(mid) / len(mid)
        plain_s, hard_s = min(plain_s), min(hard_s)
        assert np.array_equal(plain_out.labels, hard_out.labels)

        # Un-gated trajectory field: the raw ingest drain (no clustering
        # dispatch) shows what the per-block crc32 + retry wrapper cost
        # before pipeline overlap hides them — worth watching per commit
        # even though only the e2e ratio is a claim.
        from repro.graph.errors import RetryPolicy
        from repro.graph.pipeline import BatchPipeline

        def drain_s(path, retry, stall):
            pipe = BatchPipeline(CodecFileSource(path),
                                 base["batch_edges"], retry=retry,
                                 stall_timeout=stall)
            t0 = time.time()
            rows_seen = sum(b.n_rows for b in pipe.batches())
            dt = time.time() - t0
            assert rows_seen == m
            return dt

        drain_s(plain_path, None, None)  # warmup (page cache)
        drain_s(hard_path, RetryPolicy(), 60.0)
        plain_drain, hard_drain = [], []
        for _ in range(3):
            plain_drain.append(drain_s(plain_path, None, None))
            hard_drain.append(drain_s(hard_path, RetryPolicy(), 60.0))
        plain_drain, hard_drain = min(plain_drain), min(hard_drain)
        rows.append({
            "mode": "plain", "m": m, "fit_s": plain_s,
            "edges_per_s": m / plain_s,
        })
        rows.append({
            "mode": "hardened", "m": m, "fit_s": hard_s,
            "edges_per_s": m / hard_s,
            # fault-free e2e cost of checksums + retry/stall machinery
            "overhead_ratio": overhead,
            # raw ingest-drain cost before pipeline overlap (not gated)
            "drain_overhead_ratio": hard_drain / plain_drain,
            "drain_s": hard_drain,
            "plain_drain_s": plain_drain,
            "ingest_retries": hard_out.info.get("ingest_retries", 0),
            "ingest_stalls": hard_out.info.get("ingest_stalls", 0),
        })

        # exact-loss accounting under planted block corruption
        qpath = os.path.join(d, "q.dvc")
        CodecFileSource.write(qpath, edges,
                              DeltaVarintCodec(block_edges=1 << 13))
        planted = corrupt_blocks(qpath, seed=0, n_blocks=4)
        t0 = time.time()
        qout = cluster(qpath, ClusterConfig(**base, on_corrupt="quarantine"))
        q_s = time.time() - t0
        rows.append({
            "mode": "quarantine", "m": m, "fit_s": q_s,
            "edges_per_s": m / q_s,
            "blocks_quarantined": qout.info["blocks_quarantined"],
            "edges_lost": qout.info["edges_lost"],
            "planted_rows_lost": planted["rows_lost"],
            "loss_exact": qout.info["edges_lost"] == planted["rows_lost"],
        })

        # autosave cadence: checkpoints from inside fit, counted in info
        adir = os.path.join(d, "autosave")
        sc = StreamClusterer(ClusterConfig(
            **base, autosave_every=1 << 16, autosave_dir=adir))
        t0 = time.time()
        sc.fit(CodecFileSource(plain_path))
        a_s = time.time() - t0
        aout = sc.finalize()
        assert np.array_equal(aout.labels, plain_out.labels)
        rows.append({
            "mode": "autosave", "m": m, "fit_s": a_s,
            "edges_per_s": m / a_s,
            "autosaves": aout.info.get("autosaves", 0),
        })
    return rows


def device_ingest():
    """Device-resident compressed ingest rows (DESIGN.md §14).

    Two row families.  *Staging* rows time the host-side cost of the
    ingest leg — what the producer thread pays per edge to hand the device
    a ready buffer (``prefetch=0`` so both paths pay their producer on the
    timed thread).  The host-decode path pays codec block decode plus the
    stacking memcpy into the ``(K * B, 2)`` slab; the compressed path pays
    only the block memcpy into the payload slab plus descriptor assembly.
    That host-cost ratio is ``speedup_vs_host`` and carries the >= 3x
    floor in the baseline diff: in steady state device decode overlaps
    staging of the next megabatch (DESIGN.md §14), so the host-side cost
    *is* the sustained ingest rate wherever the accelerator decodes at
    device bandwidth.  On this CPU-only runner the decode kernel runs as
    the jitted pure-JAX reference; its wall time is reported separately as
    ``emulated_decode_rows_per_s`` (an emulation artifact, not a device
    number, and not part of the gated ratio).

    *End-to-end* rows run the same ``.dvc`` stream through
    ``StreamClusterer.fit`` with ``device_decode`` off/on; labels are
    asserted bit-identical and the dispatch counts equal in-suite (the §14
    contract).  The fallback-segment rate (varint/u8 blocks the device
    cannot decode) is structural in the baseline diff.
    """
    import os
    import tempfile

    import jax.numpy as jnp
    import numpy as np

    from repro.cluster import ClusterConfig, StreamClusterer
    from repro.core.decode import decode_megabatch
    from repro.graph.codecs import DeltaVarintCodec
    from repro.graph.pipeline import BatchPipeline
    from repro.graph.sources import CodecFileSource

    # adjacency-ordered local stream (small positive j deltas — the shape
    # DVE3 fixed blocks are built for) with one far-edge burst so exactly
    # one codec block exercises the raw-fallback staging path
    n, m = 20_000, 400_000
    rng = np.random.default_rng(23)
    i = np.sort(rng.integers(0, n - 65, m).astype(np.int64))
    edges = np.stack([i, i + rng.integers(1, 65, m)], 1).astype(np.int32)
    edges[m // 2 : m // 2 + 128, 1] = rng.integers(0, n, 128)
    B, K = 1 << 13, 16

    rows = []
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.dvc3")
        # unchecksummed framing on purpose: this suite's ratio isolates
        # the §14 variable (host decode vs block memcpy), and the crc32
        # pass of the checksummed default is the same cost on both sides
        # of the §15 claim — its drain cost is tracked separately as
        # resilience.drain_overhead_ratio, not folded into this one
        CodecFileSource.write(
            path, edges,
            DeltaVarintCodec(block_edges=B, version=3, checksum=False))

        def drain_host():
            pipe = BatchPipeline(CodecFileSource(path), B, prefetch=0)
            t0 = time.time()
            staged = 0
            for mb in pipe.megabatches(K):
                staged += mb.n_rows
            return staged / (time.time() - t0)

        def drain_device():
            # host-side cost only: compressed staging to a device-ready
            # payload + descriptor table (the decode itself rides the
            # device, overlapped with staging the next megabatch)
            pipe = BatchPipeline(CodecFileSource(path), B, prefetch=0)
            t0 = time.time()
            staged = fb_segs = segs = 0
            cmegas = []
            for cm in pipe.compressed_megabatches(K):
                staged += cm.n_rows
                segs += cm.n_desc
                fb_segs += int(np.count_nonzero(
                    cm.desc[: cm.n_desc, 0] == 2))  # D_KIND == DESC_RAW
                cmegas.append(cm)
            return staged / (time.time() - t0), fb_segs, segs, cmegas

        def emulate_decode(cmegas):
            # CPU-only stand-in for the device kernel: jitted reference
            # decode over the staged slabs (reported, never gated)
            staged = [(jnp.asarray(cm.payload), jnp.asarray(cm.desc),
                       cm.window, cm.out_rows, cm.n_rows) for cm in cmegas]
            for pay, de, w, o, _ in staged:  # warmup/compile
                decode_megabatch(pay, de, w, o).block_until_ready()
            t0 = time.time()
            out, rows_done = None, 0
            for pay, de, w, o, nr in staged:
                out = decode_megabatch(pay, de, w, o)
                rows_done += nr
            out.block_until_ready()
            return rows_done / (time.time() - t0)

        drain_host()  # warmup (page cache)
        drain_device()  # warmup
        host_eps = max(drain_host(), drain_host())
        (dev_eps, fb_segs, segs, cmegas) = max(
            drain_device(), drain_device(), key=lambda r: r[0])
        emu_rps = emulate_decode(cmegas)
        rows.append({
            "mode": "staging-host-decode", "m": m, "batch_edges": B,
            "megabatch_k": K, "edges_per_s": host_eps,
            "decode_mb_per_s": 8 * host_eps / 1e6,
        })
        rows.append({
            "mode": "staging-device-decode", "m": m, "batch_edges": B,
            "megabatch_k": K, "edges_per_s": dev_eps,
            "decode_mb_per_s": 8 * dev_eps / 1e6,
            "speedup_vs_host": dev_eps / host_eps,
            "emulated_decode_rows_per_s": emu_rps,
            "fallback_segments": fb_segs,
            "fallback_segment_rate": fb_segs / segs if segs else 0.0,
        })

        # end-to-end fit(): same stream, device_decode off vs on
        base = ClusterConfig(n=n, v_max=64, backend="chunked", chunk=B,
                             batch_edges=B, megabatch_k=K)
        dd = base.replace(device_decode=True)
        for cfg in (base, dd):  # warmup/compile
            StreamClusterer(cfg).fit(CodecFileSource(path))
        results = {}
        for mode, cfg in (("host", base), ("device", dd)):
            sc = StreamClusterer(cfg)
            t0 = time.time()
            sc.fit(CodecFileSource(path))
            sc.state.block_until_ready()
            dt = time.time() - t0
            results[mode] = (sc.finalize(), dt)
        res_h, t_h = results["host"]
        res_d, t_d = results["device"]
        if not np.array_equal(res_h.labels, res_d.labels):
            raise RuntimeError(
                "device_decode labels diverged from the host-decode path")
        if res_h.info["stream_dispatches"] != res_d.info["stream_dispatches"]:
            raise RuntimeError(
                f"device_decode changed the dispatch count: "
                f"{res_h.info['stream_dispatches']} -> "
                f"{res_d.info['stream_dispatches']}")
        rows.append({
            "mode": "fit-host-decode", "m": m, "batch_edges": B,
            "megabatch_k": K, "seconds": t_h, "edges_per_s": m / t_h,
            "dispatches": res_h.info["stream_dispatches"],
        })
        rows.append({
            "mode": "fit-device-decode", "m": m, "batch_edges": B,
            "megabatch_k": K, "seconds": t_d, "edges_per_s": m / t_d,
            "dispatches": res_d.info["stream_dispatches"],
            "speedup_vs_host": t_h / t_d,
            "decoded_megabatches":
                res_d.info["device_decoded_megabatches"],
            "fallback_rows": res_d.info["device_fallback_rows"],
            "fallback_segment_rate":
                res_d.info["device_fallback_segment_rate"],
        })
    return rows


def run():
    from benchmarks import memory_footprint, table1_speed, table2_quality

    t0 = time.time()
    speed = table1_speed.run(
        sizes=(20_000, 80_000), baselines_at=20_000, batch_edges=1 << 14
    )

    # one tiny quality regime (module-level REGIMES is benchmark-scale)
    quality = table2_quality.run(regimes={
        "sbm-smoke": dict(n=2_000, k=100, avg_degree=10, p_intra=0.8),
    })

    return {
        "suite": "smoke",
        "python": platform.python_version(),
        "machine": platform.machine(),
        "wall_s": round(time.time() - t0, 2),
        "table1_speed": speed,
        "table2_quality": quality,
        "streaming_tiers": streaming_tiers(),
        "device_pipeline": device_pipeline(),
        "kernel_wavefront": kernel_wavefront(),
        "compressed_stream": compressed_stream(),
        "device_ingest": device_ingest(),
        "fleet": fleet(),
        "resilience": resilience(),
        "memory": memory_footprint.run(),
    }


def check_against_baseline(report: dict, baseline: dict) -> list:
    """Structural diff: same suites, same row identities, memory-claim
    fields present.  Values are runner-dependent and not compared."""
    problems = []
    for key in ("table1_speed", "table2_quality", "streaming_tiers",
                "device_pipeline", "kernel_wavefront", "compressed_stream",
                "device_ingest", "fleet", "resilience", "memory"):
        if (key in baseline) != (key in report):
            problems.append(f"suite {key!r} appeared/disappeared")

    def ids(rows, field):
        return sorted({r[field] for r in rows if field in r})

    if "table1_speed" in baseline and "table1_speed" in report:
        got, want = ids(report["table1_speed"], "algo"), ids(
            baseline["table1_speed"], "algo")
        if got != want:
            problems.append(f"table1 algos changed: {want} -> {got}")
    if "table2_quality" in baseline and "table2_quality" in report:
        got, want = ids(report["table2_quality"], "algo"), ids(
            baseline["table2_quality"], "algo")
        if got != want:
            problems.append(f"table2 algos changed: {want} -> {got}")
        # quality deltas: the regimes are seeded and every tier is
        # deterministic, so F1/NMI/Q are comparable across runners — a drop
        # beyond tolerance fails CI exactly like a perf-claim regression.
        # Rows tagged "extrapolated" are projections, not measurements, and
        # are skipped from all value comparisons.
        tol = 0.05
        base_rows = {(r.get("regime"), r.get("algo")): r
                     for r in baseline["table2_quality"]
                     if not r.get("extrapolated")}
        for row in report["table2_quality"]:
            if row.get("extrapolated"):
                continue
            base = base_rows.get((row.get("regime"), row.get("algo")))
            if base is None:
                continue
            for field in ("f1", "nmi", "modularity"):
                if field not in row:
                    problems.append(
                        f"table2 {row.get('algo')!r} lost {field!r}")
                elif field in base and row[field] < base[field] - tol:
                    problems.append(
                        f"table2 {row.get('regime')}/{row.get('algo')}: "
                        f"{field} {base[field]:.3f} -> {row[field]:.3f} — "
                        "quality regressed")
            if "refine_sketch_peak_bytes" in base and \
                    "refine_sketch_peak_bytes" not in row:
                problems.append(
                    f"table2 {row.get('algo')!r} lost the refinement "
                    "memory claim (refine_sketch_peak_bytes)")
    if "streaming_tiers" in baseline and "streaming_tiers" in report:
        got, want = ids(report["streaming_tiers"], "tier"), ids(
            baseline["streaming_tiers"], "tier")
        if got != want:
            problems.append(f"streaming tiers changed: {want} -> {got}")
        for row in report.get("streaming_tiers", []):
            for field in ("peak_buffer_bytes", "state_bytes",
                          "edge_list_bytes"):
                if field not in row:
                    problems.append(
                        f"streaming tier {row.get('tier')!r} lost {field!r}")
            if row.get("peak_buffer_bytes", 0) >= row.get(
                    "edge_list_bytes", float("inf")):
                problems.append(
                    f"tier {row.get('tier')!r} buffered the whole stream "
                    f"({row.get('peak_buffer_bytes')} B)")
    if "device_pipeline" in baseline and "device_pipeline" in report:
        got, want = ids(report["device_pipeline"], "mode"), ids(
            baseline["device_pipeline"], "mode")
        if got != want:
            problems.append(f"device_pipeline modes changed: {want} -> {got}")
        by_backend = {}
        for row in report.get("device_pipeline", []):
            for field in ("edges_per_s", "dispatches",
                          "dispatches_per_m_edges", "peak_buffer_bytes"):
                if field not in row:
                    problems.append(
                        f"device_pipeline {row.get('mode')!r} lost {field!r}")
            by_backend.setdefault(row.get("backend"), {})[
                "mega" if row.get("megabatch_k") else "per_batch"] = row
        for backend, pair in by_backend.items():
            # the dispatch-amortisation claim itself: exact integer counts,
            # hardware-independent — the fused path must dispatch at most
            # half as often per edge as the per-batch baseline
            if "mega" in pair and "per_batch" in pair:
                mega = pair["mega"].get("dispatches_per_m_edges")
                per = pair["per_batch"].get("dispatches_per_m_edges")
                if mega is not None and per is not None and mega * 2 > per:
                    problems.append(
                        f"device_pipeline {backend!r}: fused path dispatches "
                        f"{mega:.1f}/Medge vs per-batch {per:.1f}/Medge — "
                        "amortisation claim regressed")
    if "kernel_wavefront" in baseline and "kernel_wavefront" in report:
        got, want = ids(report["kernel_wavefront"], "mode"), ids(
            baseline["kernel_wavefront"], "mode")
        if got != want:
            problems.append(f"kernel_wavefront modes changed: {want} -> {got}")
        for row in report.get("kernel_wavefront", []):
            if row.get("mode") != "wavefront":
                continue
            for field in ("edges_per_s", "speedup_vs_sequential",
                          "mean_wave_width", "fallback_rate",
                          "leftover_rows", "plan_seconds"):
                if field not in row:
                    problems.append(f"kernel_wavefront lost {field!r}")
            # the perf claim itself: a same-runner ratio over identical
            # staged megabatches, so it travels across machines — the
            # wavefront path must hold at least 2x over the sequential scan
            speedup = row.get("speedup_vs_sequential")
            if speedup is not None and speedup < 2.0:
                problems.append(
                    f"kernel_wavefront speedup_vs_sequential {speedup:.2f} "
                    "< 2.0 — wavefront throughput claim regressed")
            mw = row.get("mean_wave_width")
            if mw is not None and not 1.0 <= mw <= row.get("width", 1e9):
                problems.append(
                    f"kernel_wavefront mean_wave_width {mw} out of range")
            fr = row.get("fallback_rate")
            if fr is not None and not 0.0 <= fr <= 1.0:
                problems.append(
                    f"kernel_wavefront fallback_rate {fr} out of range")
    if "device_ingest" in baseline and "device_ingest" in report:
        got, want = ids(report["device_ingest"], "mode"), ids(
            baseline["device_ingest"], "mode")
        if got != want:
            problems.append(f"device_ingest modes changed: {want} -> {got}")
        for row in report.get("device_ingest", []):
            if row.get("mode") == "staging-device-decode":
                for field in ("edges_per_s", "decode_mb_per_s",
                              "speedup_vs_host", "fallback_segment_rate",
                              "emulated_decode_rows_per_s"):
                    if field not in row:
                        problems.append(f"device_ingest lost {field!r}")
                # the §14 perf claim itself: a same-runner host-side cost
                # ratio over the identical compressed stream, so it travels
                # across machines — compressed staging must keep the host
                # at least 3x cheaper per edge than host-decode staging
                speedup = row.get("speedup_vs_host")
                if speedup is not None and speedup < 3.0:
                    problems.append(
                        f"device_ingest speedup_vs_host {speedup:.2f} < 3.0 "
                        "— compressed-ingest throughput claim regressed")
                fr = row.get("fallback_segment_rate")
                if fr is not None and not 0.0 <= fr <= 1.0:
                    problems.append(
                        f"device_ingest fallback_segment_rate {fr} out of "
                        "range")
            if row.get("mode") == "fit-device-decode":
                for field in ("edges_per_s", "dispatches",
                              "decoded_megabatches", "fallback_rows",
                              "fallback_segment_rate"):
                    if field not in row:
                        problems.append(f"device_ingest lost {field!r}")
    if "fleet" in baseline and "fleet" in report:
        got, want = ids(report["fleet"], "mode"), ids(baseline["fleet"],
                                                      "mode")
        if got != want:
            problems.append(f"fleet modes changed: {want} -> {got}")
        for row in report.get("fleet", []):
            if row.get("mode") != "fleet-vmap":
                continue
            for field in ("tenants", "tenants_per_s", "edges_per_s",
                          "dispatches", "dispatches_per_fleet_step",
                          "peak_staging_bytes", "speedup_vs_looped"):
                if field not in row:
                    problems.append(f"fleet lost {field!r}")
            # one donated dispatch per fleet step — exact integer counts,
            # hardware-independent; the fleet engine's structural claim
            dpfs = row.get("dispatches_per_fleet_step")
            if dpfs is not None and dpfs != 1.0:
                problems.append(
                    f"fleet dispatches_per_fleet_step {dpfs} != 1.0 — "
                    "single-dispatch claim regressed")
            # the perf claim itself: a same-runner ratio (identical per-
            # tenant compute on both sides) so it travels across machines —
            # one fleet dispatch must beat T looped partial_fit calls >= 5x
            speedup = row.get("speedup_vs_looped")
            if speedup is not None and speedup < 5.0:
                problems.append(
                    f"fleet speedup_vs_looped {speedup:.2f} < 5.0 — "
                    "tenants/s claim regressed")
    if "resilience" in baseline and "resilience" in report:
        got, want = ids(report["resilience"], "mode"), ids(
            baseline["resilience"], "mode")
        if got != want:
            problems.append(f"resilience modes changed: {want} -> {got}")
        for row in report.get("resilience", []):
            if row.get("mode") == "hardened":
                for field in ("overhead_ratio", "ingest_retries",
                              "ingest_stalls"):
                    if field not in row:
                        problems.append(f"resilience lost {field!r}")
                # the fault-free cost claim: checksummed framing + retry/
                # stall machinery must stay under 5% of hardware-off
                # edges/s (same-runner ratio, best-of-N on both sides)
                ratio = row.get("overhead_ratio")
                if ratio is not None and ratio >= 1.05:
                    problems.append(
                        f"resilience overhead_ratio {ratio:.3f} >= 1.05 — "
                        "fault-free robustness cost regressed")
            if row.get("mode") == "quarantine":
                for field in ("blocks_quarantined", "edges_lost",
                              "planted_rows_lost", "loss_exact"):
                    if field not in row:
                        problems.append(f"resilience lost {field!r}")
                # accounting exactness is deterministic, so it gates:
                # edges_lost must equal the planted corruption, bit-exact
                if row.get("loss_exact") is not True:
                    problems.append(
                        f"resilience edges_lost {row.get('edges_lost')} != "
                        f"planted {row.get('planted_rows_lost')} — "
                        "quarantine accounting regressed")
            if row.get("mode") == "autosave":
                if "autosaves" not in row:
                    problems.append("resilience lost 'autosaves'")
                elif row["autosaves"] < 1:
                    problems.append(
                        "resilience autosaves == 0 — autosave cadence "
                        "silently disabled")
    if "compressed_stream" in baseline and "compressed_stream" in report:
        got, want = ids(report["compressed_stream"], "codec"), ids(
            baseline["compressed_stream"], "codec")
        if got != want:
            problems.append(f"codecs changed: {want} -> {got}")
        for row in report.get("compressed_stream", []):
            for field in ("bytes_per_edge", "ratio_vs_raw",
                          "decode_mb_per_s", "decode_copyout_mb_per_s",
                          "encode_mb_per_s"):
                if field not in row:
                    problems.append(
                        f"codec {row.get('codec')!r} lost {field!r}")
            # the bandwidth claim itself: the compressed stream must stay
            # under half the raw bytes/edge (hardware-independent; a row
            # missing the field entirely is reported by the loop above)
            ratio = row.get("ratio_vs_raw")
            if (
                str(row.get("codec", "")).startswith("dvc")
                and ratio is not None
                and ratio >= 0.5
            ):
                problems.append(
                    f"{row.get('codec')} ratio_vs_raw {ratio:.3f} >= 0.5 — "
                    "compression claim regressed")
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_smoke.json")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_smoke.json to diff against")
    args = ap.parse_args(argv)
    report = run()
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, default=float)
    print(f"wrote {args.out} ({report['wall_s']}s)", file=sys.stderr)
    for r in report["table1_speed"]:
        if "linearity_ratio" in r:
            print(f"smoke/{r['algo']},0,ratio={r['linearity_ratio']:.3f}")
            continue
        print(f"smoke/{r['algo']},{r['seconds']*1e6:.0f},"
              f"{r['edges_per_s']:.0f} edges/s")
    for r in report["streaming_tiers"]:
        print(f"smoke/{r['tier']},buf={r['peak_buffer_bytes']},"
              f"state={r['state_bytes']},edges={r['edge_list_bytes']}")
    for r in report["device_pipeline"]:
        extra = (f",x{r['speedup_vs_per_batch']:.2f}"
                 if "speedup_vs_per_batch" in r else "")
        print(f"smoke/pipeline-{r['mode']},{r['edges_per_s']:.0f} edges/s,"
              f"{r['dispatches_per_m_edges']:.1f} disp/Medge{extra}")
    for r in report["kernel_wavefront"]:
        extra = (f",x{r['speedup_vs_sequential']:.2f}"
                 f",width={r['mean_wave_width']:.1f}"
                 f",fallback={r['fallback_rate']:.3f}"
                 if r["mode"] == "wavefront" else "")
        print(f"smoke/wavefront-{r['mode']},{r['edges_per_s']:.0f} "
              f"edges/s{extra}")
    for r in report["compressed_stream"]:
        print(f"smoke/codec-{r['codec']},{r['bytes_per_edge']:.2f} B/edge,"
              f"{r['decode_copyout_mb_per_s']:.0f} MB/s decode,"
              f"{r['encode_mb_per_s']:.0f} MB/s encode")
    for r in report["device_ingest"]:
        extra = (f",x{r['speedup_vs_host']:.2f}"
                 f",fallback={r['fallback_segment_rate']:.3f}"
                 if "speedup_vs_host" in r else "")
        print(f"smoke/ingest-{r['mode']},{r['edges_per_s']:.0f} edges/s"
              f"{extra}")
    for r in report["fleet"]:
        extra = (f",x{r['speedup_vs_looped']:.2f}"
                 f",staging={r['peak_staging_bytes']}"
                 if "speedup_vs_looped" in r else "")
        print(f"smoke/fleet-{r['mode']},{r['tenants_per_s']:.0f} tenants/s,"
              f"{r['dispatches']} disp{extra}")
    for r in report["resilience"]:
        extra = ""
        if "overhead_ratio" in r:
            extra = f",overhead=x{r['overhead_ratio']:.3f}"
        elif "edges_lost" in r:
            extra = (f",quarantined={r['blocks_quarantined']}"
                     f",lost={r['edges_lost']}"
                     f"/{r['planted_rows_lost']}")
        elif "autosaves" in r:
            extra = f",autosaves={r['autosaves']}"
        print(f"smoke/resilience-{r['mode']},{r['edges_per_s']:.0f} "
              f"edges/s{extra}")
    if args.baseline:
        try:
            with open(args.baseline) as f:
                baseline = json.load(f)
        except FileNotFoundError:
            print(f"baseline {args.baseline!r} not found — commit a "
                  "BENCH_smoke.json baseline (see --out)", file=sys.stderr)
            return 1
        problems = check_against_baseline(report, baseline)
        for p in problems:
            print(f"baseline drift: {p}", file=sys.stderr)
        if problems:
            return 1
        print("baseline diff: structure unchanged", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
