"""Paper §2.5 — one-pass multi-v_max sweep vs A independent passes.

Both sides run through ``repro.cluster`` and both *stream*: the sweep is one
``multiparam`` call over a ``GeneratorSource`` (edge residency O(batch),
sweep state ``(2A+1) n`` ints), the baseline is A separate streamed ``scan``
calls over the same source.  Each row reports the measured peak edge-buffer
bytes next to the full edge-list bytes the old materializing sweep paid.
"""

from __future__ import annotations

import time

from repro.cluster import ClusterConfig, GeneratorSource, cluster
from repro.graph.generators import sbm_segments
from repro.graph.stream import edge_list_bytes


def run(n=5000, a_values=(4, 8), batch_edges=1 << 12):
    segment, _ = sbm_segments(n, 100, seed=3)
    m = int(n * 12 / 2)
    source = GeneratorSource(segment, m, segment_edges=batch_edges)
    rows = []
    for A in a_values:
        vms = tuple(2 ** (i + 3) for i in range(A))
        sweep_cfg = ClusterConfig(
            n=n, backend="multiparam", v_maxes=vms, batch_edges=batch_edges
        )
        # one streamed pass, A parameters
        res = cluster(source, sweep_cfg).block_until_ready()
        t0 = time.perf_counter()
        res = cluster(source, sweep_cfg).block_until_ready()
        t_sweep = time.perf_counter() - t0
        # A independent streamed passes
        scan_cfg = ClusterConfig(
            n=n, v_max=int(vms[0]), backend="scan", batch_edges=batch_edges
        )
        cluster(source, scan_cfg).block_until_ready()
        t0 = time.perf_counter()
        for v in vms:
            cluster(
                source, scan_cfg.replace(v_max=int(v))
            ).block_until_ready()
        t_sep = time.perf_counter() - t0
        rows.append({
            "A": A, "sweep_s": t_sweep, "separate_s": t_sep,
            "speedup": t_sep / t_sweep,
            "peak_buffer_bytes": res.info["peak_buffer_bytes"],
            "edge_list_bytes": edge_list_bytes(m, 4),
            "sweep_state_bytes": (2 * A + 1) * n * 4,
        })
    return rows


def main():
    for r in run():
        print(f"A={r['A']:2d}  one-pass {r['sweep_s']:.2f}s  "
              f"separate {r['separate_s']:.2f}s  speedup {r['speedup']:.2f}x  "
              f"buf={r['peak_buffer_bytes']/1e3:.0f}kB "
              f"(edge list {r['edge_list_bytes']/1e3:.0f}kB, "
              f"state {r['sweep_state_bytes']/1e3:.0f}kB)")


if __name__ == "__main__":
    main()
