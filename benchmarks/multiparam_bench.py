"""Paper §2.5 — one-pass multi-v_max sweep vs A independent passes.

Both sides run through ``repro.cluster``: the sweep is one ``multiparam``
call, the baseline is A separate ``scan`` calls.
"""

from __future__ import annotations

import time

from repro.cluster import ClusterConfig, cluster
from repro.graph.generators import sbm_stream


def run(n=5000, a_values=(4, 8)):
    edges, _ = sbm_stream(n, 100, avg_degree=12, seed=3)
    rows = []
    for A in a_values:
        vms = tuple(2 ** (i + 3) for i in range(A))
        sweep_cfg = ClusterConfig(n=n, backend="multiparam", v_maxes=vms)
        # one pass, A parameters
        cluster(edges, sweep_cfg).block_until_ready()
        t0 = time.perf_counter()
        cluster(edges, sweep_cfg).block_until_ready()
        t_sweep = time.perf_counter() - t0
        # A independent passes
        cluster(edges, ClusterConfig(n=n, v_max=vms[0], backend="scan"))\
            .block_until_ready()
        t0 = time.perf_counter()
        for v in vms:
            cluster(
                edges, ClusterConfig(n=n, v_max=int(v), backend="scan")
            ).block_until_ready()
        t_sep = time.perf_counter() - t0
        rows.append({"A": A, "sweep_s": t_sweep, "separate_s": t_sep,
                     "speedup": t_sep / t_sweep})
    return rows


def main():
    for r in run():
        print(f"A={r['A']:2d}  one-pass {r['sweep_s']:.2f}s  "
              f"separate {r['separate_s']:.2f}s  speedup {r['speedup']:.2f}x")


if __name__ == "__main__":
    main()
