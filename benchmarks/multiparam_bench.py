"""Paper §2.5 — one-pass multi-v_max sweep vs A independent passes."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.multiparam import cluster_stream_multiparam, select_result
from repro.core.streaming import cluster_stream_scan
from repro.graph.generators import sbm_stream


def run(n=5000, a_values=(4, 8)):
    edges, _ = sbm_stream(n, 100, avg_degree=12, seed=3)
    ej = jnp.asarray(edges)
    rows = []
    for A in a_values:
        vms = jnp.asarray([2 ** (i + 3) for i in range(A)])
        # one pass, A parameters
        cluster_stream_multiparam(ej, vms, n).c.block_until_ready()
        t0 = time.perf_counter()
        res = cluster_stream_multiparam(ej, vms, n)
        res.c.block_until_ready()
        t_sweep = time.perf_counter() - t0
        # A independent passes
        cluster_stream_scan(ej, int(vms[0]), n)[0].block_until_ready()
        t0 = time.perf_counter()
        for v in vms:
            cluster_stream_scan(ej, int(v), n)[0].block_until_ready()
        t_sep = time.perf_counter() - t0
        rows.append({"A": A, "sweep_s": t_sweep, "separate_s": t_sep,
                     "speedup": t_sep / t_sweep})
    return rows


def main():
    for r in run():
        print(f"A={r['A']:2d}  one-pass {r['sweep_s']:.2f}s  "
              f"separate {r['separate_s']:.2f}s  speedup {r['speedup']:.2f}x")


if __name__ == "__main__":
    main()
