"""Tests for the roofline HLO analyzer — the §Roofline methodology itself.

Validates trip-count multiplication (scan, nested scan), dot-FLOP counting,
and collective-byte detection on SPMD programs (subprocess with fake
devices, keeping this process single-device)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    res = analyze(jax.jit(f).lower(x, w).compile().as_text())
    expected = 10 * 2 * 128 * 256 * 256
    assert abs(res["flops"] - expected) / expected < 1e-6


def test_nested_scan_multiplies():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    res = analyze(jax.jit(g).lower(x, w).compile().as_text())
    expected = 20 * 2 * 64 * 128 * 128
    assert abs(res["flops"] - expected) / expected < 1e-6


def test_xla_cost_analysis_undercounts_loops():
    """Documents WHY the custom analyzer exists: XLA counts loop bodies once."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ca = jax.jit(f).lower(x, w).compile().cost_analysis()
    if isinstance(ca, list):  # older jax returns one dict per device program
        ca = ca[0]
    one_iter = 2 * 128 * 256 * 256
    assert ca["flops"] == one_iter  # NOT 10x


def test_spmd_collectives_and_per_device_flops():
    script = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        def f(x, w):
            return jnp.sum(x @ w)
        xs = NamedSharding(mesh, P("data", None))
        ws = NamedSharding(mesh, P(None, "model"))
        x = jax.ShapeDtypeStruct((128, 256), jnp.float32, sharding=xs)
        w = jax.ShapeDtypeStruct((256, 512), jnp.float32, sharding=ws)
        comp = jax.jit(f, in_shardings=(xs, ws)).lower(x, w).compile()
        res = analyze(comp.as_text())
        assert abs(res["flops"] - 2*128*256*512/8) < 1, res["flops"]
        assert res["collective_bytes_total"] > 0
        assert "all-reduce" in res["collective_bytes"]
        print("OK")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=600,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
    )
    assert proc.returncode == 0 and "OK" in proc.stdout, (
        proc.stdout + proc.stderr
    )


def test_slice_traffic_not_full_buffer():
    """dynamic-slice from a big stacked array counts the slice, not the
    whole array, per loop iteration."""
    def f(stack):
        def body(c, i):
            blk = jax.lax.dynamic_index_in_dim(stack, i, 0, keepdims=False)
            return c + blk.sum(), None
        out, _ = jax.lax.scan(body, jnp.float32(0), jnp.arange(64))
        return out

    stack = jax.ShapeDtypeStruct((64, 1024, 32), jnp.float32)
    res = analyze(jax.jit(f).lower(stack).compile().as_text())
    full = 64 * 1024 * 32 * 4
    # 64 iterations x whole buffer would be 64*full = 537 MB; slice-aware
    # accounting should stay within a few x of one full pass.
    assert res["traffic_bytes"] < 6 * full, res["traffic_bytes"]
