"""Fault-tolerance suite (DESIGN.md §15): deterministic chaos injection,
retry/quarantine accounting, kill-and-resume bit-identity, torn-artifact
restores, and fleet tenant isolation.

The chaos seed comes from ``CHAOS_SEED`` (default 0) so the CI chaos matrix
re-runs the same tests under different planted fault schedules — each seed
is fully deterministic, so failures reproduce locally with the same env.
"""

import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointCorruptError, CheckpointManager
from repro.cluster import ClusterConfig, cluster
from repro.cluster.api import StreamClusterer
from repro.cluster.fleet import FleetClusterer
from repro.dist.fault_tolerance import HeartbeatMonitor, PreemptionHandler
from repro.graph.codecs import DeltaVarintCodec
from repro.graph.errors import (
    CorruptStreamError,
    RetryPolicy,
    SourceDeadError,
    StallError,
    TransientReadError,
    retrying_slices,
)
from repro.graph.faults import (
    ChaosSource,
    FaultInjector,
    corrupt_blocks,
    list_blocks,
    truncate_blocks,
)
from repro.graph.pipeline import BatchPipeline
from repro.graph.sources import ArraySource, CodecFileSource

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _write_dvc(path, edges, block_edges=1024):
    with open(path, "wb") as f:
        DeltaVarintCodec(block_edges=block_edges).encode(iter([edges]), f)
    return str(path)


def _edges(m, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n, size=(m, 2), dtype=np.int32)


# ---------------------------------------------------------------------------
# RetryPolicy / retrying_slices / stall watchdog
# ---------------------------------------------------------------------------


def test_retry_policy_backoff_and_classes():
    p = RetryPolicy(max_retries=4, backoff_base=0.01, backoff_cap=0.03)
    assert [p.delay(k) for k in (1, 2, 3, 4)] == [0.01, 0.02, 0.03, 0.03]
    assert p.is_retryable(TransientReadError("x"))
    assert p.is_retryable(OSError("x"))
    assert not p.is_retryable(SourceDeadError("gone"))  # never retried
    assert not p.is_retryable(ValueError("corrupt"))
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)


def test_retrying_slices_resets_attempts_between_faults():
    # 5 independent transients with budget 1 each: consecutive counting
    # would die at the second fault, per-fault counting survives all 5
    edges = _edges(5000, 100)
    src = ChaosSource(
        ArraySource(edges),
        FaultInjector(CHAOS_SEED, transients=5, stall_seconds=0.0).plan(5000),
    )
    policy = RetryPolicy(max_retries=1, backoff_base=0.0)
    got = np.concatenate(
        list(
            retrying_slices(
                src.resume, src.cursor_at, src.cursor_at(0), policy
            )
        )
    )
    assert np.array_equal(got, edges)


def test_pipeline_retry_bit_identical_and_counted():
    edges = _edges(20_000, 200)
    plan = FaultInjector(CHAOS_SEED, transients=3, stall_seconds=0.0).plan(
        20_000
    )
    chaos = ChaosSource(ArraySource(edges), plan)
    pipe = BatchPipeline(chaos, 1024, retry=RetryPolicy(backoff_base=0.0))
    got = np.concatenate([b.edges[: b.n_rows] for b in pipe.batches()])
    assert np.array_equal(got, edges)
    assert pipe.retries == 3


def test_pipeline_retry_disabled_propagates():
    edges = _edges(4000, 100)
    plan = FaultInjector(CHAOS_SEED, transients=1, stall_seconds=0.0).plan(4000)
    pipe = BatchPipeline(
        ChaosSource(ArraySource(edges), plan), 512, retry=None
    )
    with pytest.raises(TransientReadError):
        list(pipe.batches())


def test_pipeline_stall_watchdog():
    class Wedged(ArraySource):
        def iter_slices(self, start=0):
            yield self.edges[start : start + 256]
            time.sleep(5.0)
            yield self.edges[start + 256 :]

    pipe = BatchPipeline(
        Wedged(_edges(4000, 50)), 256, retry=None, stall_timeout=0.2
    )
    t0 = time.monotonic()
    with pytest.raises(StallError):
        list(pipe.batches())
    assert time.monotonic() - t0 < 3.0  # raised promptly, no 5 s hang


# ---------------------------------------------------------------------------
# Deterministic fault plans / ChaosSource
# ---------------------------------------------------------------------------


def test_fault_plans_reproducible_by_seed():
    mk = lambda: FaultInjector(
        CHAOS_SEED, transients=4, stalls=2, die=True
    ).plan(123_456)
    assert mk() == mk()
    other = FaultInjector(
        CHAOS_SEED + 1, transients=4, stalls=2, die=True
    ).plan(123_456)
    assert mk() != other


def test_chaos_source_death_is_permanent():
    edges = _edges(6000, 100)
    plan = FaultInjector(CHAOS_SEED, die=True).plan(6000)
    src = ChaosSource(ArraySource(edges), plan)
    with pytest.raises(SourceDeadError):
        np.concatenate(list(src.iter_slices(0)))
    with pytest.raises(SourceDeadError):
        list(src.iter_slices(0))  # still dead; retries are useless
    assert not RetryPolicy().is_retryable(SourceDeadError("gone"))


# ---------------------------------------------------------------------------
# Quarantine accounting: exact planted loss, e2e through cluster()
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["chunked", "pallas"])
def test_corrupt_blocks_exact_loss_e2e(tmp_path, backend):
    n = 500
    edges = _edges(30_000, n, seed=3)
    path = _write_dvc(tmp_path / "e.dvc", edges, block_edges=512)
    planted = corrupt_blocks(path, seed=CHAOS_SEED, n_blocks=3)
    cfg = ClusterConfig(
        n=n,
        v_max=40,
        backend=backend,
        chunk=256,
        batch_edges=2048,
        on_corrupt="quarantine",
    )
    out = cluster(path, cfg)
    assert out.info["edges_lost"] == planted["rows_lost"]
    # adjacent corrupt blocks can merge into one resync gap event
    assert 1 <= out.info["blocks_quarantined"] <= 3
    # the surviving rows are exactly the non-quarantined ones, in order
    lost = set()
    for _, first, k in planted["blocks"]:
        lost.update(range(first, first + k))
    keep = np.array([r for r in range(len(edges)) if r not in lost])
    ref = cluster(edges[keep], cfg.replace(on_corrupt="raise"))
    assert np.array_equal(out.labels, ref.labels)


def test_truncated_tail_exact_loss_e2e(tmp_path):
    n = 400
    edges = _edges(20_000, n, seed=4)
    path = _write_dvc(tmp_path / "t.dvc", edges, block_edges=512)
    planted = truncate_blocks(path, n_blocks=5)
    cfg = ClusterConfig(
        n=n, v_max=40, backend="chunked", chunk=256, batch_edges=2048,
        on_corrupt="quarantine",
    )
    out = cluster(path, cfg)
    assert out.info["edges_lost"] == planted["rows_lost"]
    ref = cluster(
        edges[: planted["first_lost_row"]], cfg.replace(on_corrupt="raise")
    )
    assert np.array_equal(out.labels, ref.labels)


def test_corrupt_block_raises_typed_without_quarantine(tmp_path):
    edges = _edges(10_000, 300, seed=5)
    path = _write_dvc(tmp_path / "r.dvc", edges, block_edges=512)
    corrupt_blocks(path, seed=CHAOS_SEED, n_blocks=1)
    cfg = ClusterConfig(
        n=300, v_max=40, backend="chunked", chunk=256, batch_edges=2048
    )
    with pytest.raises(CorruptStreamError):
        cluster(path, cfg)


def test_quarantine_counts_idempotent_across_passes(tmp_path):
    edges = _edges(12_000, 300, seed=6)
    path = _write_dvc(tmp_path / "i.dvc", edges, block_edges=512)
    planted = corrupt_blocks(path, seed=CHAOS_SEED, n_blocks=2)
    src = CodecFileSource(path, on_corrupt="quarantine")
    a = np.concatenate(list(src.iter_slices(0)))
    b = np.concatenate(list(src.iter_slices(0)))  # second pass, same source
    assert np.array_equal(a, b)
    assert src.edges_lost == planted["rows_lost"]  # not double-counted
    # adjacent corrupt blocks merge into one resync gap, so the event
    # count is bounded by the planted count, never inflated by re-walks
    assert 1 <= src.blocks_quarantined <= 2


# ---------------------------------------------------------------------------
# Autosave + crash recovery (SIGTERM drain and hard SIGKILL)
# ---------------------------------------------------------------------------

_CHILD = textwrap.dedent(
    """
    import os, sys
    import numpy as np
    from repro.cluster import ClusterConfig
    from repro.cluster.api import StreamClusterer
    from repro.dist.fault_tolerance import PreemptionHandler
    from repro.graph.faults import ChaosSource, FaultInjector
    from repro.graph.sources import CodecFileSource

    path, ckpt, backend, seed = sys.argv[1:5]
    src = CodecFileSource(path)
    plan = FaultInjector(
        int(seed), transients=2, stalls=60, stall_seconds=0.05
    ).plan(src.n_edges)
    cfg = ClusterConfig(
        n=500, v_max=40, backend=backend, chunk=256, batch_edges=1024,
        autosave_every=2048, autosave_dir=ckpt, interpret=True,
    )
    pre = PreemptionHandler()
    pre.install()
    sc = StreamClusterer(cfg)
    print("READY", flush=True)
    sc.fit(ChaosSource(src, plan), preemption=pre)
    if pre.preempted:
        print("PREEMPTED", sc.stream_offset, flush=True)
        sys.exit(0)
    sc.save(ckpt)
    print("DONE", sc.stream_offset, flush=True)
    """
)


def _spawn_child(path, ckpt, backend):
    return subprocess.Popen(
        [sys.executable, "-c", _CHILD, path, ckpt, backend, str(CHAOS_SEED)],
        stdout=subprocess.PIPE,
        text=True,
        env={
            **os.environ,
            "PYTHONPATH": os.path.join(ROOT, "src"),
            "JAX_PLATFORMS": "cpu",
        },
    )


def _resume_and_compare(ckpt, path, edges, backend):
    """Restore from the newest valid autosave generation, drain the rest of
    the (fault-free) file, and demand bit-identity with an uninterrupted
    fault-free run."""
    sc = StreamClusterer.restore(ckpt)
    assert sc.stream_offset % 1024 == 0  # exact batch-boundary cursor
    sc.fit(CodecFileSource(path))
    out = sc.finalize()
    cfg = ClusterConfig(
        n=500, v_max=40, backend=backend, chunk=256, batch_edges=1024,
        interpret=True,
    )
    ref = cluster(edges, cfg)
    assert np.array_equal(out.labels, ref.labels)
    return out


@pytest.mark.parametrize("backend", ["chunked", "pallas"])
def test_sigterm_drain_then_resume_bit_identical(tmp_path, backend):
    edges = _edges(24_000, 500, seed=7)
    path = _write_dvc(tmp_path / "s.dvc", edges)
    ckpt = str(tmp_path / "ck")
    proc = _spawn_child(path, ckpt, backend)
    try:
        assert proc.stdout.readline().strip() == "READY"
        time.sleep(1.0)  # land mid-stream (pacing stalls keep it there)
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=240)
    finally:
        if proc.poll() is None:
            proc.kill()
    assert proc.returncode == 0, out
    # drained cleanly: either preempted mid-stream or already finished
    assert out.splitlines()[-1].split()[0] in ("PREEMPTED", "DONE"), out
    _resume_and_compare(ckpt, path, edges, backend)


@pytest.mark.parametrize("backend", ["chunked", "pallas"])
def test_sigkill_then_resume_bit_identical(tmp_path, backend):
    edges = _edges(24_000, 500, seed=8)
    path = _write_dvc(tmp_path / "k.dvc", edges)
    ckpt = str(tmp_path / "ck")
    proc = _spawn_child(path, ckpt, backend)
    try:
        assert proc.stdout.readline().strip() == "READY"
        deadline = time.monotonic() + 180
        while time.monotonic() < deadline:
            if proc.poll() is not None:
                break  # finished before we could kill it — still resumable
            if os.path.isdir(ckpt) and any(
                e.startswith("step_") for e in os.listdir(ckpt)
            ):
                proc.kill()  # SIGKILL: no drain, no atexit, nothing
                break
            time.sleep(0.02)
        proc.wait(timeout=240)
    finally:
        if proc.poll() is None:
            proc.kill()
    _resume_and_compare(ckpt, path, edges, backend)


# ---------------------------------------------------------------------------
# Torn checkpoint artifacts: typed errors + generation fallback
# ---------------------------------------------------------------------------


def _save_generations(tmp_path, steps=(10, 20)):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=5)
    for s in steps:
        mgr.save(s, {"x": np.arange(s, dtype=np.int64)})
    return mgr


def test_truncated_manifest_falls_back_a_generation(tmp_path):
    mgr = _save_generations(tmp_path)
    man = tmp_path / "ck" / "step_20" / "manifest.json"
    man.write_text(man.read_text()[: 17])  # torn mid-JSON
    with pytest.raises(CheckpointCorruptError):
        mgr.restore({"x": np.zeros(1, np.int64)}, step=20)
    restored = mgr.restore({"x": np.zeros(1, np.int64)})  # newest valid
    assert np.array_equal(restored["x"], np.arange(10))


def test_missing_leaf_is_typed_and_falls_back(tmp_path):
    mgr = _save_generations(tmp_path)
    os.remove(tmp_path / "ck" / "step_20" / "x.npy")
    with pytest.raises(CheckpointCorruptError, match="missing"):
        mgr.restore({"x": np.zeros(1, np.int64)}, step=20)
    restored = mgr.restore({"x": np.zeros(1, np.int64)})
    assert np.array_equal(restored["x"], np.arange(10))


def test_bitflipped_leaf_fails_checksum_and_falls_back(tmp_path):
    mgr = _save_generations(tmp_path)
    leaf = tmp_path / "ck" / "step_20" / "x.npy"
    raw = bytearray(leaf.read_bytes())
    raw[-3] ^= 0xFF  # flip a payload byte; shape/header stay plausible
    leaf.write_bytes(bytes(raw))
    with pytest.raises(CheckpointCorruptError, match="checksum"):
        mgr.restore({"x": np.zeros(1, np.int64)}, step=20)
    restored = mgr.restore({"x": np.zeros(1, np.int64)})
    assert np.array_equal(restored["x"], np.arange(10))


def test_every_generation_corrupt_raises_aggregate(tmp_path):
    mgr = _save_generations(tmp_path)
    for s in (10, 20):
        os.remove(tmp_path / "ck" / f"step_{s}" / "x.npy")
    with pytest.raises(CheckpointCorruptError, match="every checkpoint"):
        mgr.restore({"x": np.zeros(1, np.int64)})


def test_dvc_truncated_midblock_is_typed(tmp_path):
    # plain (unchecksummed) framing: mid-block truncation must surface as a
    # typed CorruptStreamError, never a bare ValueError with no class
    edges = _edges(8000, 200, seed=9)
    path = str(tmp_path / "p.dvc")
    with open(path, "wb") as f:
        DeltaVarintCodec(block_edges=512, checksum=False).encode(
            iter([edges]), f
        )
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 37)
    src = CodecFileSource(path)
    with pytest.raises(CorruptStreamError):
        np.concatenate(list(src.iter_slices(0)))


# ---------------------------------------------------------------------------
# CheckpointManager swap-window crash regression
# ---------------------------------------------------------------------------


def test_crash_between_aside_and_rename_leaves_old_generation(
    tmp_path, monkeypatch
):
    """The historical bug: rmtree(final) before rename(tmp, final) had a
    window with *zero* complete generations on disk.  The aside-rename swap
    must leave the previous generation recoverable at every instant."""
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d)
    mgr.save(7, {"x": np.arange(5, dtype=np.int64)})

    import shutil as _shutil

    real_rename = os.rename
    calls = {"n": 0}

    def crashing_rename(a, b):
        real_rename(a, b)
        if b.endswith(".old"):
            calls["n"] += 1
            raise RuntimeError("simulated crash after renaming aside")

    monkeypatch.setattr(os, "rename", crashing_rename)
    with pytest.raises(RuntimeError, match="simulated crash"):
        mgr.save(7, {"x": np.arange(99, dtype=np.int64)})
    monkeypatch.setattr(os, "rename", real_rename)
    assert calls["n"] == 1

    # a fresh manager heals the orphaned .old back into place and the
    # previous generation restores intact
    mgr2 = CheckpointManager(d)
    restored = mgr2.restore({"x": np.zeros(1, np.int64)}, step=7)
    assert np.array_equal(restored["x"], np.arange(5))
    assert not any(e.endswith(".old") for e in os.listdir(d))
    # and the manager is fully functional afterwards
    mgr2.save(7, {"x": np.arange(9, dtype=np.int64)})
    assert np.array_equal(
        mgr2.restore({"x": np.zeros(1, np.int64)}, step=7)["x"], np.arange(9)
    )


def test_crash_after_swap_drops_stale_aside(tmp_path, monkeypatch):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d)
    mgr.save(3, {"x": np.arange(4, dtype=np.int64)})

    import shutil

    real_rmtree = shutil.rmtree

    def crashing_rmtree(p, *a, **k):
        if p.endswith(".old"):
            raise RuntimeError("simulated crash before dropping aside")
        return real_rmtree(p, *a, **k)

    monkeypatch.setattr(shutil, "rmtree", crashing_rmtree)
    with pytest.raises(RuntimeError, match="simulated crash"):
        mgr.save(3, {"x": np.arange(8, dtype=np.int64)})
    monkeypatch.setattr(shutil, "rmtree", real_rmtree)

    # both generations exist; the NEW one won the swap, so recovery keeps
    # it and drops the stale aside
    mgr2 = CheckpointManager(d)
    restored = mgr2.restore({"x": np.zeros(1, np.int64)}, step=3)
    assert np.array_equal(restored["x"], np.arange(8))
    assert not any(e.endswith(".old") for e in os.listdir(d))


# ---------------------------------------------------------------------------
# PreemptionHandler / HeartbeatMonitor satellites
# ---------------------------------------------------------------------------


def test_preemption_install_returns_and_uninstall_restores():
    sentinel_calls = []

    def sentinel(signum, frame):
        sentinel_calls.append(signum)

    prev0 = signal.signal(signal.SIGUSR1, sentinel)
    try:
        h = PreemptionHandler()
        displaced = h.install(signals=(signal.SIGUSR1,))
        assert displaced[signal.SIGUSR1] is sentinel
        os.kill(os.getpid(), signal.SIGUSR1)
        assert h.preempted and not sentinel_calls
        h.uninstall()
        assert signal.getsignal(signal.SIGUSR1) is sentinel
        os.kill(os.getpid(), signal.SIGUSR1)
        assert sentinel_calls == [signal.SIGUSR1]
    finally:
        signal.signal(signal.SIGUSR1, prev0)


def test_heartbeat_median_is_true_median():
    mon = HeartbeatMonitor(window=10)
    for d in (0.1, 0.2, 0.9):  # mean 0.4 — a mean would misreport
        mon._durations.append(d)
    assert mon.median == pytest.approx(0.2)
    mon._durations.append(0.3)
    assert mon.median == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# Fleet tenant isolation
# ---------------------------------------------------------------------------


def test_fleet_one_dead_tenant_survivors_bit_identical():
    T, n = 16, 300
    rng = np.random.default_rng(CHAOS_SEED)
    streams = [
        rng.integers(0, n, size=(int(rng.integers(2000, 5000)), 2), dtype=np.int32)
        for _ in range(T)
    ]
    dead = int(rng.integers(T))
    plan = FaultInjector(CHAOS_SEED, die=True).plan(len(streams[dead]))
    sources = [
        ChaosSource(ArraySource(s), plan) if t == dead else ArraySource(s)
        for t, s in enumerate(streams)
    ]
    cfg = ClusterConfig(
        n=n, v_max=30, backend="chunked", chunk=128, batch_edges=512,
        tenants=T, on_tenant_fault="quarantine",
    )
    out = FleetClusterer(cfg).fit(sources).finalize()
    assert out.info["tenants_quarantined"] == [dead]
    assert "SourceDeadError" in out.info["tenant_faults"][dead]
    solo_cfg = cfg.replace(tenants=None, on_tenant_fault="raise")
    for t in range(T):
        if t == dead:
            continue
        solo = StreamClusterer(solo_cfg).fit(ArraySource(streams[t])).finalize()
        assert np.array_equal(out.tenant(t).labels, solo.labels), t
    # the dead tenant dispatched at most its pre-death prefix
    assert out.info["tenant_rows"][dead] <= plan.die_row


def test_fleet_default_policy_raises_on_dead_tenant():
    T, n = 4, 100
    rng = np.random.default_rng(CHAOS_SEED + 1)
    streams = [
        rng.integers(0, n, size=(3000, 2), dtype=np.int32) for _ in range(T)
    ]
    plan = FaultInjector(CHAOS_SEED, die=True).plan(3000)
    sources = [
        ChaosSource(ArraySource(s), plan) if t == 1 else ArraySource(s)
        for t, s in enumerate(streams)
    ]
    cfg = ClusterConfig(
        n=n, v_max=30, backend="chunked", chunk=128, batch_edges=512, tenants=T
    )
    with pytest.raises(SourceDeadError):
        FleetClusterer(cfg).fit(sources)


# ---------------------------------------------------------------------------
# Chaos + retry through the one-call API (transients are invisible)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["chunked", "pallas"])
def test_transient_chaos_invisible_to_labels(tmp_path, backend):
    n = 400
    edges = _edges(16_000, n, seed=11)
    path = _write_dvc(tmp_path / "c.dvc", edges)
    plan = FaultInjector(CHAOS_SEED, transients=4, stall_seconds=0.0).plan(
        16_000
    )
    cfg = ClusterConfig(
        n=n, v_max=40, backend=backend, chunk=256, batch_edges=2048,
        interpret=True,
    )
    out = (
        StreamClusterer(cfg)
        .fit(ChaosSource(CodecFileSource(path), plan))
        .finalize()
    )
    ref = cluster(edges, cfg)
    assert np.array_equal(out.labels, ref.labels)
    assert out.info["ingest_retries"] == 4
    assert out.info["edges_lost"] == 0
