"""Out-of-core ingestion tests: ``repro.graph.sources`` + ``BatchPipeline``
threaded through the cluster API.

The invariants under test are the PR's contract:

* **source invariance** — file-backed, generator-backed, and in-memory runs
  of the same stream produce identical labels for every resumable backend,
  at several batch sizes;
* **mid-stream resumability** — suspend/restore at a mid-file offset
  continues the stream exactly (checkpoint records the raw offset);
* **bounded residency** — a 10M-edge generator-backed stream clusters with
  host edge-buffer residency O(batch_edges), not O(m).
"""

import os

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.cluster import ClusterConfig, StreamClusterer, cluster
from repro.graph.codecs import Cursor, DeltaVarintCodec
from repro.graph.generators import chung_lu_segments, sbm_segments
from repro.graph.pipeline import PAD, Batch, BatchPipeline, rechunk
from repro.graph.sources import (
    ArraySource,
    BinaryFileSource,
    CodecFileSource,
    EdgeListFileSource,
    GeneratorSource,
    ShardedSource,
    as_source,
)
from repro.graph.stream import edge_list_bytes, shard_stream

RESUMABLE = ("oracle", "dense", "scan", "pallas", "chunked")


def _random_stream(n, m, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2)).astype(np.int32)
    e[:, 1] = np.where(e[:, 0] == e[:, 1], (e[:, 1] + 1) % n, e[:, 1])
    return e


def _write_txt(path, edges, header=True):
    with open(path, "w") as f:
        if header:
            f.write("# SNAP-style header\n% another comment style\n\n")
        for i, j in edges:
            f.write(f"{i}\t{j}\n")
    return str(path)


def _all_sources(edges, tmp_path):
    """The same stream behind every concrete source type."""
    txt = _write_txt(tmp_path / "g.txt", edges)
    binp = BinaryFileSource.write(tmp_path / "g.bin", edges)
    dvc = CodecFileSource.write(
        tmp_path / "g.dvc", edges, DeltaVarintCodec(block_edges=173)
    )
    gen = GeneratorSource(
        lambda s, length: edges[s : s + length], len(edges), segment_edges=97
    )
    return {
        "array": ArraySource(edges),
        "text": EdgeListFileSource(txt),
        "binary": binp,
        "dvc": dvc,
        "generator": gen,
    }


# ---------------------------------------------------------------------------
# Pipeline mechanics
# ---------------------------------------------------------------------------

def test_rechunk_exact_batches_any_slicing():
    edges = _random_stream(40, 230, 0)
    ragged = [edges[0:3], edges[3:3], edges[3:150], edges[150:230]]
    got = list(rechunk(ragged, 64))
    assert [len(b) for b in got] == [64, 64, 64, 38]
    assert np.array_equal(np.concatenate(got), edges)


def test_pipeline_fixed_shapes_offsets_and_padding():
    edges = _random_stream(50, 137, 1)
    pipe = BatchPipeline(ArraySource(edges), 30, pad_multiple=8)
    batches = list(pipe)
    assert pipe.batch_edges == 32  # rounded up to the pad multiple
    assert all(isinstance(b, Batch) for b in batches)
    assert all(b.edges.shape == (32, 2) for b in batches)  # one jit compile
    assert [b.offset for b in batches] == [0, 32, 64, 96, 128]
    assert sum(b.n_rows for b in batches) == 137
    last = batches[-1]
    assert (last.edges[last.n_rows :] == PAD).all()
    recon = np.concatenate([b.edges[: b.n_rows] for b in batches])
    assert np.array_equal(recon, edges)


def test_pipeline_residency_is_O_batch():
    """Peak host edge buffer is (prefetch + 1) batches + one source slice,
    not the stream (slices bounded by the source's segment granularity)."""
    m, batch = 50_000, 256
    src = GeneratorSource(
        lambda s, length: np.zeros((length, 2), np.int32), m,
        segment_edges=batch,
    )
    pipe = BatchPipeline(src, batch, prefetch=2)
    for _ in pipe:
        pass
    batch_bytes = batch * 2 * 4
    assert 0 < pipe.peak_buffer_bytes <= 5 * batch_bytes
    assert pipe.peak_buffer_bytes < m * 2 * 4  # never the whole stream


def test_pipeline_residency_honest_for_in_memory_arrays():
    """An ArraySource's one slice is the resident array itself — the metric
    must report it, not pretend an in-memory stream was out-of-core."""
    edges = _random_stream(100, 5000, 2)
    pipe = BatchPipeline(ArraySource(edges), 256, prefetch=2)
    for _ in pipe:
        pass
    assert pipe.peak_buffer_bytes >= edges.nbytes


def test_pipeline_early_close_shuts_down_prefetch():
    edges = _random_stream(30, 2000, 3)
    pipe = BatchPipeline(ArraySource(edges), 64, prefetch=2)
    for i, _ in enumerate(pipe):
        if i == 1:
            break
    # residency accounting drains despite the abandoned iterator
    assert pipe._inflight_bytes == 0


def test_pad_shims_deleted_canonical_home_is_pipeline():
    """Satellite: the historical ``core.streaming`` / ``graph.stream`` pad
    shims are gone — ``repro.graph.pipeline`` is the single home of the
    padding primitives (PAD stays importable where it is genuinely used)."""
    import jax.numpy as jnp

    import repro.core.streaming as core_streaming
    import repro.graph.stream as graph_stream
    from repro.graph.pipeline import pad_edges_to_chunks, pad_to_chunks

    assert not hasattr(core_streaming, "pad_edges_to_chunks")
    assert not hasattr(graph_stream, "pad_to_chunks")
    chunks = pad_to_chunks(_random_stream(20, 130, 4), 64)
    assert chunks.shape == (3, 64, 2)
    padded, n_chunks = pad_edges_to_chunks(jnp.zeros((5, 2), jnp.int32), 8)
    assert padded.shape == (8, 2) and n_chunks == 1


# ---------------------------------------------------------------------------
# Source equivalence
# ---------------------------------------------------------------------------

def test_all_sources_yield_the_same_stream(tmp_path):
    edges = _random_stream(60, 411, 5)
    for name, src in _all_sources(edges, tmp_path).items():
        assert np.array_equal(src.materialize(), edges), name
        assert src.count_edges() == 411, name
        for bs in (64, 411, 1000):
            got = np.concatenate(list(src.batches(bs)))
            assert np.array_equal(got, edges), (name, bs)
        # resume from an arbitrary raw offset
        got = np.concatenate(list(src.batches(100, start=123)))
        assert np.array_equal(got, edges[123:]), name


def test_text_source_skips_comments_headers_blank_lines_extra_columns(tmp_path):
    p = tmp_path / "weird.txt"
    with open(p, "w") as f:
        f.write("# comment\nFromNodeId\tToNodeId\n\n1 2 0.5\n% other\n"
                "3\t4\t17 99\n5 6\n")
    src = EdgeListFileSource(p)
    assert np.array_equal(src.materialize(), [[1, 2], [3, 4], [5, 6]])
    assert src.count_edges() == 3


def test_text_source_resume_uses_seekable_offsets(tmp_path):
    """Re-reading from a mid-file offset seeks to a recorded byte position
    instead of re-parsing the prefix (O(remaining) preemption loops)."""
    edges = _random_stream(50, 1000, 20)
    src = EdgeListFileSource(
        _write_txt(tmp_path / "big.txt", edges), block_lines=128
    )
    list(src.batches(128))  # first drain records slice-boundary offsets
    assert len(src._resume) > 3
    row, pos, _ = src._best_resume(640)
    assert 0 < row <= 640 and pos > 0
    got = np.concatenate(list(src.batches(100, start=640)))
    assert np.array_equal(got, edges[640:])
    assert src.count_edges() == 1000


def test_text_source_names_file_and_line_on_malformed_edge(tmp_path):
    p = tmp_path / "torn.txt"
    p.write_text("1 2\n7\n3 4\n")
    with pytest.raises(ValueError, match=r"torn\.txt:2"):
        EdgeListFileSource(p).materialize()


def test_binary_source_rejects_torn_file(tmp_path):
    p = tmp_path / "bad.bin"
    p.write_bytes(b"\x00" * 12)  # not a whole number of int32 pairs
    with pytest.raises(ValueError, match="int32"):
        BinaryFileSource(p)


@pytest.mark.parametrize("backend", RESUMABLE)
@pytest.mark.parametrize("batch_edges", [64, 193])
def test_labels_invariant_across_sources_and_batch_sizes(
    tmp_path, backend, batch_edges
):
    """The acceptance invariant: every source backing the same stream gives
    the *same* labels as the in-memory one-shot run, for every resumable
    backend, at several batch sizes.  (chunked included: the pipeline aligns
    batches to Jacobi chunk boundaries, so batching never moves one.)"""
    n, m = 80, 500
    edges = _random_stream(n, m, 6)
    cfg = ClusterConfig(n=n, v_max=8, backend=backend, chunk=32)
    ref = cluster(edges, cfg).labels
    for name, src in _all_sources(edges, tmp_path).items():
        got = cluster(src, cfg.replace(batch_edges=batch_edges))
        assert np.array_equal(got.labels, ref), (backend, name, batch_edges)
        assert got.info["peak_buffer_bytes"] > 0
        assert int(got.state.edges_seen) == m


def test_cluster_accepts_paths_directly(tmp_path):
    edges = _random_stream(40, 200, 7)
    txt = _write_txt(tmp_path / "p.txt", edges)
    binp = str(tmp_path / "p.bin")
    BinaryFileSource.write(binp, edges)
    cfg = ClusterConfig(n=40, v_max=6, backend="dense")
    ref = cluster(edges, cfg).labels
    assert np.array_equal(cluster(txt, cfg).labels, ref)
    assert np.array_equal(cluster(binp, cfg).labels, ref)
    assert isinstance(as_source(txt), EdgeListFileSource)
    assert isinstance(as_source(binp), BinaryFileSource)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    batch_edges=st.integers(1, 300),
    v_max=st.integers(1, 100),
)
def test_property_file_backed_equals_in_memory(tmp_path_factory, seed, batch_edges, v_max):
    """Property: for any stream, batch size, and v_max, a file-backed dense
    run is bit-identical to the in-memory one-shot run."""
    n, m = 40, 250
    edges = _random_stream(n, m, seed)
    d = tmp_path_factory.mktemp("prop")
    txt = _write_txt(d / "s.txt", edges)
    cfg = ClusterConfig(n=n, v_max=v_max, backend="dense")
    ref = cluster(edges, cfg).labels
    got = cluster(txt, cfg.replace(batch_edges=batch_edges)).labels
    assert np.array_equal(got, ref)


# ---------------------------------------------------------------------------
# Mid-stream suspend / resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["dense", "scan", "chunked"])
def test_suspend_restore_at_mid_file_offset(tmp_path, backend):
    """fit two batches, checkpoint, restore in a fresh clusterer, fit the
    rest — labels identical to the uninterrupted in-memory run."""
    n, m = 70, 600
    edges = _random_stream(n, m, 8)
    txt = _write_txt(tmp_path / "stream.txt", edges)
    cfg = ClusterConfig(n=n, v_max=8, backend=backend, chunk=32, batch_edges=128)

    sc = StreamClusterer(cfg)
    sc.fit(txt, max_batches=2)
    assert sc.stream_offset == 2 * 128
    ck = str(tmp_path / "ckpt")
    sc.save(ck)

    sc2 = StreamClusterer.restore(ck)  # fresh "session"
    assert sc2.stream_offset == 2 * 128
    assert sc2.edges_seen == sc.edges_seen
    sc2.fit(txt)
    assert sc2.stream_offset == m

    ref = cluster(edges, cfg)
    res = sc2.finalize()
    assert np.array_equal(res.labels, ref.labels)
    assert int(sc2.state.edges_seen) == m
    # fit()-driven runs surface the stream metrics like cluster() does
    assert res.info["peak_buffer_bytes"] > 0
    assert res.info["stream_batches"] > 0


def test_int64_counters_survive_restore_past_2_31(tmp_path):
    """edges_seen / stream_offset are int64 on disk and must not be demoted
    to int32 at restore — past 2^31 a demoted counter goes negative and the
    next save() writes a step dir that latest_step() never finds."""
    sc = StreamClusterer(ClusterConfig(n=10, v_max=4, backend="dense"))
    sc.partial_fit(np.array([[0, 1]], np.int32))
    sc._state.edges_seen = np.int64(2**31 + 5)
    sc._cursor = Cursor(2**31 + 9)
    sc.save(str(tmp_path))
    sc2 = StreamClusterer.restore(str(tmp_path))
    assert sc2.edges_seen == 2**31 + 5
    assert sc2.stream_offset == 2**31 + 9
    assert "step_2147483653" in sc2.save(str(tmp_path))


def test_generator_source_resumes_from_exact_offset():
    """GeneratorSource regenerates any row range from its absolute offset —
    a resumed read never replays and never skips."""
    seg = chung_lu_segments(200, seed=11)
    src = GeneratorSource(seg, 5000, segment_edges=256)
    full = src.materialize()
    for start in (0, 1, 255, 256, 257, 4999):
        got = np.concatenate(list(src.batches(190, start=start)))
        assert np.array_equal(got, full[start:]), start


def test_sbm_segments_ground_truth_and_determinism():
    seg, labels = sbm_segments(300, 10, p_intra=0.9, seed=12)
    assert labels.shape == (300,) and labels.max() < 10
    a, b = seg(512, 128), seg(512, 128)
    assert np.array_equal(a, b) and a.shape == (128, 2)
    assert (a[:, 0] != a[:, 1]).all()  # no self-loops


# ---------------------------------------------------------------------------
# Sharded source (distributed tier)
# ---------------------------------------------------------------------------

def test_sharded_source_matches_vectorized_shard_stream(tmp_path):
    edges = _random_stream(100, 777, 9)
    txt = _write_txt(tmp_path / "s.txt", edges)
    stacked = ShardedSource(EdgeListFileSource(txt), 8).stacked()
    assert np.array_equal(stacked, shard_stream(edges, 8))
    # windows partition the stream contiguously
    shards = ShardedSource(ArraySource(edges), 8).shards()
    flat = np.concatenate([w.materialize() for w in shards])
    assert np.array_equal(flat, edges)


def test_distributed_backend_from_file_source(tmp_path):
    n = 200
    edges = _random_stream(n, 1200, 10)
    txt = _write_txt(tmp_path / "d.txt", edges)
    cfg = ClusterConfig(
        n=n, v_max=8, backend="distributed", n_shards=4, chunk=128
    )
    assert np.array_equal(cluster(txt, cfg).labels, cluster(edges, cfg).labels)


# ---------------------------------------------------------------------------
# Out-of-core at scale (acceptance criterion)
# ---------------------------------------------------------------------------

def test_10m_edge_generator_stream_is_out_of_core():
    """A 10M-edge generator-backed stream clusters with edge-buffer residency
    bounded by O(batch_edges) — the paper's memory model, measured: edges
    never materialize, state stays 3n ints."""
    n, m = 1 << 17, 10_000_000
    batch_edges = 1 << 18
    src = GeneratorSource(
        chung_lu_segments(n, seed=7), m, segment_edges=1 << 17
    )
    cfg = ClusterConfig(
        n=n, v_max=64, backend="chunked", chunk=16384, batch_edges=batch_edges
    )
    res = cluster(src, cfg).block_until_ready()

    assert int(res.state.edges_seen) == m
    batch_bytes = batch_edges * 2 * 4
    # double-buffered pipeline: at most (prefetch + 1) = 3 batches plus the
    # generator segments still pinnable by rechunk views
    assert 0 < res.info["peak_buffer_bytes"] <= 5 * batch_bytes
    # far under materializing the stream (80 MB at int32)
    assert res.info["peak_buffer_bytes"] * 8 <= edge_list_bytes(m, 4)
    assert res.info["stream_batches"] == -(-m // batch_edges)
    # the clustering did real work: some merges happened
    assert res.n_communities < n


def test_10m_stream_small_prefix_matches_in_memory():
    """Bit-identity spot check for the scale test's stream: a prefix of the
    same generator source, streamed vs materialized, on a sequential tier."""
    n, m = 1 << 17, 20_000
    src = GeneratorSource(chung_lu_segments(n, seed=7), m, segment_edges=4096)
    cfg = ClusterConfig(n=n, v_max=64, backend="scan")
    ref = cluster(src.materialize(), cfg)
    got = cluster(src, cfg.replace(batch_edges=4096))
    assert np.array_equal(got.labels, ref.labels)
