"""End-to-end system tests: the paper's pipeline from stream to scores, the
LM training loop driver, serving path, and dry-run artifact integrity."""

import glob
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_paper_pipeline_end_to_end():
    """Stream -> cluster (3 tiers) -> metrics -> multiparam selection."""
    from repro.core.chunked import cluster_stream_chunked
    from repro.core.metrics import avg_f1, modularity
    from repro.core.multiparam import cluster_stream_multiparam, select_result
    from repro.core.streaming import canonical_labels, cluster_stream_dense
    from repro.graph.generators import sbm_stream

    n = 3000
    edges, truth = sbm_stream(n, 150, avg_degree=14, p_intra=0.8, seed=0)
    c_seq, d, v = cluster_stream_dense(edges, 64, n)
    assert d.sum() == 2 * len(edges)
    q_seq = modularity(edges, c_seq)
    assert q_seq > 0.2

    c_chk, _, _ = cluster_stream_chunked(jnp.asarray(edges), 64, n, chunk=1024)
    assert abs(modularity(edges, np.asarray(c_chk)) - q_seq) < 0.05

    sweep = cluster_stream_multiparam(
        jnp.asarray(edges), jnp.asarray([16, 64, 256]), n
    )
    sel = select_result(sweep)
    assert sel["best_v_max"] in (16, 64, 256)
    f1 = avg_f1(canonical_labels(sel["labels"]), truth)
    assert f1 > 0.05


def test_training_loop_loss_decreases():
    from repro.launch.train import main as train_main

    losses = train_main([
        "--arch", "qwen1.5-0.5b", "--smoke", "--steps", "30",
        "--batch", "8", "--seq", "128", "--lr", "3e-3",
    ])
    assert len(losses) == 30
    assert losses[-1] < losses[0]


def test_serve_path_produces_tokens():
    from repro.launch.serve import main as serve_main

    out = serve_main([
        "--arch", "gemma3-1b", "--smoke", "--batch", "2",
        "--prompt-len", "16", "--gen", "4",
    ])
    assert out.shape == (2, 4)
    assert bool((np.asarray(out) >= 0).all())


@pytest.mark.skipif(
    not glob.glob(os.path.join(ROOT, "results/dryrun_opt/*.json")),
    reason="dry-run artifacts not generated",
)
def test_dryrun_artifacts_complete_and_fit():
    """All 40 cells x 2 meshes accounted for; every live cell compiled and
    fits the 16 GB/chip budget; skips are only long_500k full-attention."""
    cells = glob.glob(os.path.join(ROOT, "results/dryrun_opt/*__*.json"))
    assert len(cells) == 80
    n_ok = n_skip = 0
    for f in cells:
        with open(f) as fh:
            c = json.load(fh)
        if c["status"] == "skipped":
            n_skip += 1
            assert c["shape"] == "long_500k"
        else:
            n_ok += 1
            assert c["memory"]["fits_16GB"], f
            r = c["roofline"]
            assert r["compute_s"] >= 0 and r["memory_s"] > 0
            assert r["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert n_ok == 66 and n_skip == 14
