"""The streaming state engine across every tier (ISSUE 3 acceptance).

* **sweep columns are Algorithm 1** — hypothesis property: column ``a`` of a
  multiparam sweep is bit-identical to a single-parameter dense run at
  ``v_maxes[a]``, for any stream and any parameter set;
* **batching invariance** — a batched sweep equals the one-shot sweep at
  every batch size (the SweepState threads exactly);
* **mid-file suspend/resume** for the sweep backend, mirroring
  ``test_sources.py``;
* **out-of-core at scale** — a 10M-edge generator-backed sweep (A=4) and a
  4-shard distributed run both complete with peak edge-buffer residency
  under a quarter of the edge-list bytes, while sweep labels stay
  bit-identical to the one-shot scan.
"""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.cluster import ClusterConfig, StreamClusterer, cluster
from repro.graph.generators import chung_lu_segments
from repro.graph.sources import GeneratorSource
from repro.graph.stream import edge_list_bytes, state_bytes


def _random_stream(n, m, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2)).astype(np.int32)
    e[:, 1] = np.where(e[:, 0] == e[:, 1], (e[:, 1] + 1) % n, e[:, 1])
    return e


def _write_txt(path, edges):
    with open(path, "w") as f:
        for i, j in edges:
            f.write(f"{i}\t{j}\n")
    return str(path)


# ---------------------------------------------------------------------------
# Sweep columns ≡ Algorithm 1 per parameter (hypothesis property)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    v_maxes=st.lists(st.integers(1, 120), min_size=1, max_size=5),
)
def test_property_sweep_column_equals_dense_run(seed, v_maxes):
    """Property: for any stream and any parameter set, sweep column ``a`` is
    bit-identical to a single-param dense run at ``v_maxes[a]`` (the sweep
    is A copies of Algorithm 1 sharing the degree dictionary)."""
    n, m = 40, 250
    edges = _random_stream(n, m, seed)
    res = cluster(
        edges, ClusterConfig(n=n, backend="multiparam", v_maxes=tuple(v_maxes))
    )
    sweep_c = np.asarray(res.info["sweep_labels"])
    for a, v_max in enumerate(v_maxes):
        direct = cluster(edges, ClusterConfig(n=n, v_max=v_max, backend="dense"))
        assert np.array_equal(sweep_c[a], np.asarray(direct.raw_labels)), (
            a, v_max,
        )


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    batch_edges=st.integers(1, 300),
)
def test_property_batched_sweep_equals_one_shot(seed, batch_edges):
    """Property: the sweep threaded through partial_fit at any batch size is
    bit-identical to the one-shot sweep — whole SweepState, not just the
    selected column."""
    n, m = 40, 250
    edges = _random_stream(n, m, seed)
    cfg = ClusterConfig(n=n, backend="multiparam", v_maxes=(3, 17, 80))
    ref = cluster(edges, cfg)
    got = cluster(edges, cfg.replace(batch_edges=batch_edges))
    assert np.array_equal(
        np.asarray(got.info["sweep_labels"]), np.asarray(ref.info["sweep_labels"])
    )
    assert np.array_equal(got.labels, ref.labels)
    assert got.info["best_v_max"] == ref.info["best_v_max"]


# Deterministic counterparts so the invariants are exercised even where
# hypothesis is unavailable (the property tests above then skip).

@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("v_maxes", [(1, 7), (5, 33, 110)])
def test_sweep_column_equals_dense_run(seed, v_maxes):
    n, m = 50, 300
    edges = _random_stream(n, m, seed)
    res = cluster(edges, ClusterConfig(n=n, backend="multiparam", v_maxes=v_maxes))
    sweep_c = np.asarray(res.info["sweep_labels"])
    for a, v_max in enumerate(v_maxes):
        direct = cluster(edges, ClusterConfig(n=n, v_max=v_max, backend="dense"))
        assert np.array_equal(sweep_c[a], np.asarray(direct.raw_labels)), v_max


@pytest.mark.parametrize("batch_edges", [1, 64, 193, 1000])
def test_batched_sweep_equals_one_shot(batch_edges):
    n, m = 50, 300
    edges = _random_stream(n, m, 3)
    cfg = ClusterConfig(n=n, backend="multiparam", v_maxes=(3, 17, 80))
    ref = cluster(edges, cfg)
    got = cluster(edges, cfg.replace(batch_edges=batch_edges))
    assert np.array_equal(
        np.asarray(got.info["sweep_labels"]), np.asarray(ref.info["sweep_labels"])
    )
    assert got.info["best_v_max"] == ref.info["best_v_max"]


# ---------------------------------------------------------------------------
# Mid-file suspend / resume for the sweep backend (mirrors test_sources)
# ---------------------------------------------------------------------------

def test_sweep_suspend_restore_at_mid_file_offset(tmp_path):
    """fit two batches of a file-backed sweep, checkpoint, restore in a
    fresh clusterer, fit the rest — whole sweep identical to the
    uninterrupted in-memory run."""
    n, m = 70, 600
    edges = _random_stream(n, m, 8)
    txt = _write_txt(tmp_path / "stream.txt", edges)
    cfg = ClusterConfig(
        n=n, backend="multiparam", v_maxes=(4, 16, 64), batch_edges=128
    )

    sc = StreamClusterer(cfg)
    sc.fit(txt, max_batches=2)
    assert sc.stream_offset == 2 * 128
    ck = str(tmp_path / "ckpt")
    sc.save(ck)

    sc2 = StreamClusterer.restore(ck)  # fresh "session"
    assert sc2.stream_offset == 2 * 128
    assert sc2.edges_seen == sc.edges_seen
    sc2.fit(txt)
    assert sc2.stream_offset == m

    ref = cluster(edges, cfg.replace(batch_edges=None))
    res = sc2.finalize()
    assert np.array_equal(res.labels, ref.labels)
    assert np.array_equal(
        np.asarray(res.info["sweep_labels"]),
        np.asarray(ref.info["sweep_labels"]),
    )
    assert int(sc2.state.edges_seen) == m
    assert res.info["peak_buffer_bytes"] > 0
    assert res.info["stream_batches"] > 0


# ---------------------------------------------------------------------------
# Out-of-core at scale (acceptance criteria)
# ---------------------------------------------------------------------------

def test_10m_edge_generator_sweep_is_out_of_core():
    """A 10M-edge generator-backed multiparam sweep (A=4) streams with edge
    residency O(batch_edges) — under a quarter of the edge-list bytes — and
    its labels are bit-identical to the one-shot scan at the selected
    v_max (spot-checked on a prefix below; the full-scale run asserts the
    memory claim).  ``n`` is kept small: the sweep is one edge per scan step
    and XLA CPU pays O(n) per step, so node count — not stream length — is
    what this tier's wall clock scales with."""
    n, m, A = 1 << 12, 10_000_000, 4
    batch_edges = 1 << 18
    src = GeneratorSource(chung_lu_segments(n, seed=7), m, segment_edges=1 << 17)
    cfg = ClusterConfig(
        n=n,
        backend="multiparam",
        v_maxes=(16, 64, 256, 1024),
        batch_edges=batch_edges,
    )
    res = cluster(src, cfg).block_until_ready()

    assert len(res.info["rows"]) == A
    assert int(res.state.edges_seen) == m
    batch_bytes = batch_edges * 2 * 4
    assert 0 < res.info["peak_buffer_bytes"] <= 5 * batch_bytes
    # the acceptance bound: < 1/4 of materializing the int32 edge list
    assert res.info["peak_buffer_bytes"] * 4 < edge_list_bytes(m, 4)
    assert res.info["stream_batches"] == -(-m // batch_edges)
    # sweep state is (2A+1) n ints — far under the edge list too
    assert (2 * A + 1) * n * 4 < edge_list_bytes(m, 4) // 4
    assert res.n_communities < n


def test_10m_sweep_prefix_bit_identical_to_one_shot_scan():
    """Bit-identity spot check for the scale test's stream: a prefix of the
    same generator, streamed through the sweep, equals the one-shot scan at
    each swept v_max."""
    n, m = 1 << 12, 20_000
    src = GeneratorSource(chung_lu_segments(n, seed=7), m, segment_edges=4096)
    edges = src.materialize()
    cfg = ClusterConfig(n=n, backend="multiparam", v_maxes=(16, 64))
    got = cluster(src, cfg.replace(batch_edges=4096))
    sweep_c = np.asarray(got.info["sweep_labels"])
    for a, v_max in enumerate((16, 64)):
        ref = cluster(edges, ClusterConfig(n=n, v_max=v_max, backend="scan"))
        assert np.array_equal(sweep_c[a], np.asarray(ref.raw_labels)), v_max


def test_4_shard_distributed_run_is_out_of_core():
    """A 4-shard distributed run over a generator source streams shard by
    shard: peak edge residency under a quarter of the edge-list bytes, no
    stacked O(m) array, and the merged state carries the edge-free
    metrics."""
    n, m = 1 << 15, 2_000_000
    batch_edges = 1 << 16
    src = GeneratorSource(chung_lu_segments(n, seed=9), m, segment_edges=1 << 16)
    cfg = ClusterConfig(
        n=n,
        v_max=64,
        backend="distributed",
        n_shards=4,
        chunk=8192,
        batch_edges=batch_edges,
    )
    res = cluster(src, cfg).block_until_ready()

    assert res.info["n_shards"] == 4
    assert int(res.state.edges_seen) == m
    assert res.info["peak_buffer_bytes"] * 4 < edge_list_bytes(m, 4)
    assert res.entropy is not None and res.entropy > 0
    assert res.avg_density is not None
    # sharded state is 3Pn ints; merged view is the paper's 3n
    assert state_bytes(n) * 4 < edge_list_bytes(m, 4)
    assert res.n_communities < n


def test_distributed_defaults_to_one_window_per_shard(tmp_path):
    """With batch_edges unset the sharded tier counts the stream once and
    deals one contiguous window per shard — the classic ShardedSource split
    at batch granularity.  Holds for cluster() and for a direct
    StreamClusterer.fit alike: every shard must ingest."""
    n, m = 60, 400
    edges = _random_stream(n, m, 10)
    txt = _write_txt(tmp_path / "w.txt", edges)
    cfg = ClusterConfig(n=n, v_max=8, backend="distributed", n_shards=4, chunk=32)
    res = cluster(txt, cfg)
    assert res.info["stream_batches"] == 4
    assert np.array_equal(res.labels, cluster(edges, cfg).labels)

    sc = StreamClusterer(cfg)
    sc.fit(txt)
    assert int(sc.state.cursor) == 4
    assert (np.asarray(sc.state.d).sum(axis=1) > 0).all()  # no starved shard
    assert np.array_equal(sc.finalize().labels, res.labels)
