"""Device-resident compressed ingest tests (DESIGN.md §14).

The contract under test:

* **slab identity** — the pure-JAX reference decode reconstructs, bit for
  bit, the ``(K * B, 2)`` PAD-carved slab the host-decode staging path
  stages for the same rows, on streams mixing every DVE3 width class,
  raw-fallback blocks, and a ragged tail;
* **kernel pinning** — the Pallas decode kernel and the fused
  decode→update kernel (run through the emulator) are pinned against that
  reference: identical slabs, identical post-update state (the CI
  interpret leg runs this file on the tier-1 matrix);
* **round-trip** — a cursor taken at *any* batch boundary — including one
  that lands inside a compressed megabatch's framing — restores
  bit-identical labels whether the run suspends/resumes under
  ``device_decode=True`` or ``False``, and whether the resumed session
  flips the knob (property test);
* **rejection** — a torn descriptor table (spliced rows, bad widths,
  truncated payload, non-tiling segments) raises instead of decoding
  garbage;
* **plumbing** — ``device_decode`` config guards, backend capability
  errors, and the §14 info counters.
"""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from hypothesis_compat import given, settings, st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.cluster import (  # noqa: E402
    ClusterConfig,
    CodecFileSource,
    DeltaVarintCodec,
    StreamClusterer,
    cluster,
)
from repro.core.decode import (  # noqa: E402
    chunked_decode_update_megabatch,
    decode_megabatch,
)
from repro.core.state import ClusterState  # noqa: E402
from repro.graph.pipeline import (  # noqa: E402
    BatchPipeline,
    D_KIND,
    D_NROWS,
    D_ROW,
    D_W_I,
    DESC_FIXED,
)
from repro.kernels.edge_stream.kernel import (  # noqa: E402
    DESC_COLS,
    build_decode_call,
    build_decode_update_call,
)
from repro.kernels.edge_stream.ops import (  # noqa: E402
    pallas_update_megabatch,
)


def _mixed_stream(n, m, seed):
    """Adjacency-ordered stream with both DVE3 segment kinds live: small
    positive deltas (u1/u2 fixed blocks) plus two contiguous far-endpoint
    bursts, each confined to a stretch of the stream so the blocks they
    land in take the raw/varint fallback while the rest stay fixed."""
    rng = np.random.default_rng(seed)
    i = np.sort(rng.integers(0, n - 2, m))
    j = np.minimum(i + rng.integers(1, 9, m), n - 1)
    for at in (m // 3, (2 * m) // 3):
        burst = min(max(m // 16, 1), m - at)
        j[at : at + burst] = rng.integers(0, n, burst)
    j = np.where(j == i, np.minimum(i + 1, n - 1), j)
    return np.stack([i, j], 1).astype(np.int32)


def _write(tmp_path, edges, block_edges):
    path = str(tmp_path / "stream.dvc3")
    CodecFileSource.write(
        path, edges, DeltaVarintCodec(block_edges=block_edges, version=3)
    )
    return path


def _assert_states_equal(a, b):
    for field in ("d", "c", "v", "edges_seen"):
        assert np.array_equal(
            np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
        ), field


def _slab_pairs(path, B, K):
    """(host-staged slab, compressed megabatch) pairs over the stream."""
    host = BatchPipeline(CodecFileSource(path), B, prefetch=0)
    comp = BatchPipeline(CodecFileSource(path), B, prefetch=0)
    return list(
        zip(
            (np.asarray(mb.edges).reshape(-1, 2) for mb in host.megabatches(K)),
            comp.compressed_megabatches(K),
        )
    )


# ---------------------------------------------------------------------------
# Reference decode == host-staged slab
# ---------------------------------------------------------------------------

def test_decode_reference_matches_host_slab(tmp_path):
    edges = _mixed_stream(900, 20_000, 3)
    path = _write(tmp_path, edges, block_edges=1024)
    pairs = _slab_pairs(path, B=512, K=4)
    assert len(pairs) > 1  # exercises a ragged tail megabatch
    saw_raw = saw_fixed = False
    for ref, cm in pairs:
        kinds = np.asarray(cm.desc[: cm.n_desc, D_KIND])
        saw_fixed |= bool((kinds == DESC_FIXED).any())
        saw_raw |= bool((kinds != DESC_FIXED).any())
        dec = np.asarray(
            decode_megabatch(
                jnp.asarray(cm.payload), jnp.asarray(cm.desc),
                cm.window, cm.out_rows,
            )
        )
        assert dec.shape == ref.shape
        assert np.array_equal(dec, ref)
    assert saw_fixed and saw_raw  # the stream covered both segment kinds


# ---------------------------------------------------------------------------
# Pallas kernels pinned against the reference (emulator)
# ---------------------------------------------------------------------------

def test_pallas_decode_kernel_pins_reference(tmp_path):
    edges = _mixed_stream(400, 4096, 11)
    path = _write(tmp_path, edges, block_edges=512)
    for ref, cm in _slab_pairs(path, B=512, K=2):
        d_max = cm.desc.shape[0]
        n_out_windows = -(-(cm.out_rows + cm.window) // cm.window)
        call = build_decode_call(cm.window, d_max, n_out_windows, True)
        out = np.asarray(
            call(jnp.asarray(cm.desc), jnp.asarray(cm.payload))
        )[: cm.out_rows]
        assert np.array_equal(out, ref)


def test_fused_decode_update_kernel_pins_reference(tmp_path):
    n, v_max = 400, 24
    edges = _mixed_stream(n, 4096, 13)
    path = _write(tmp_path, edges, block_edges=512)
    seq = ClusterState.init(n)
    fused = ClusterState.init(n)
    for ref, cm in _slab_pairs(path, B=512, K=2):
        seq = pallas_update_megabatch(
            seq, jnp.asarray(ref).reshape(1, cm.out_rows, 2), v_max,
            chunk=512,
        )
        d_max = cm.desc.shape[0]
        call = build_decode_update_call(n, cm.window, d_max, v_max, True)
        d, c, v, stats = call(
            jnp.asarray(cm.desc), jnp.asarray(cm.payload),
            fused.d.astype(jnp.int32), fused.c.astype(jnp.int32),
            fused.v.astype(jnp.int32),
        )
        fused = ClusterState(
            d=d, c=c, v=v, edges_seen=fused.edges_seen + stats[0]
        )
    _assert_states_equal(seq, fused)


def test_chunked_fused_jit_matches_reference_composition(tmp_path):
    from repro.core.chunked import chunked_update_megabatch

    n, v_max = 300, 16
    edges = _mixed_stream(n, 3000, 17)
    path = _write(tmp_path, edges, block_edges=512)
    a = ClusterState.init(n)
    b = ClusterState.init(n)
    for ref, cm in _slab_pairs(path, B=256, K=3):
        a = chunked_update_megabatch(
            a, jnp.asarray(ref).reshape(1, cm.out_rows, 2),
            jnp.int32(v_max), chunk=256,
        )
        b = chunked_decode_update_megabatch(
            b, jnp.asarray(cm.payload), jnp.asarray(cm.desc), v_max,
            cm.window, cm.out_rows, chunk=256,
        )
    _assert_states_equal(a, b)


# ---------------------------------------------------------------------------
# End-to-end: device_decode on == off == in-memory, counters, dispatches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["chunked", "pallas"])
def test_fit_device_decode_bit_identical(tmp_path, backend):
    n = 500
    edges = _mixed_stream(n, 12_000, 29)
    path = _write(tmp_path, edges, block_edges=1024)
    base = ClusterConfig(
        n=n, v_max=32, backend=backend, batch_edges=1024, megabatch_k=4,
        chunk=1024,
    )
    oracle = cluster(edges, base.replace(megabatch_k=None))
    off = StreamClusterer(base).fit(CodecFileSource(path))
    on = StreamClusterer(base.replace(device_decode=True)).fit(
        CodecFileSource(path)
    )
    r_off, r_on = off.finalize(), on.finalize()
    assert np.array_equal(r_off.labels, r_on.labels)
    assert np.array_equal(oracle.labels, r_on.labels)
    assert (
        r_off.info["stream_dispatches"] == r_on.info["stream_dispatches"]
    )
    assert r_on.info["device_decoded_megabatches"] > 0
    assert 0.0 <= r_on.info["device_fallback_segment_rate"] <= 1.0
    assert r_on.info["device_fallback_rows"] >= 0


# ---------------------------------------------------------------------------
# Round-trip property: suspend at any batch boundary, resume either mode
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    cut=st.integers(1, 11),
    suspend_on=st.booleans(),
    resume_on=st.booleans(),
)
def test_cursor_round_trip_any_boundary(
    tmp_path_factory, seed, cut, suspend_on, resume_on
):
    """A checkpoint at batch boundary ``cut`` — usually *inside* a
    compressed megabatch's K-frame — resumes to labels bit-identical to
    the uninterrupted run, for every on/off combination of
    ``device_decode`` across the suspend/resume sessions."""
    tmp_path = tmp_path_factory.mktemp("roundtrip")
    n, B, K = 300, 256, 4
    edges = _mixed_stream(n, 12 * B, seed)
    path = _write(tmp_path, edges, block_edges=B)
    base = ClusterConfig(
        n=n, v_max=24, backend="chunked", batch_edges=B, megabatch_k=K,
        chunk=B,
    )
    cfg = lambda on: base.replace(device_decode=on)  # noqa: E731
    straight = (
        StreamClusterer(cfg(suspend_on)).fit(CodecFileSource(path)).finalize()
    )

    sc = StreamClusterer(cfg(suspend_on))
    sc.fit(CodecFileSource(path), max_batches=cut)
    ckpt = str(tmp_path / f"ckpt-{seed}-{cut}")
    sc.save(ckpt)
    sc2 = StreamClusterer.restore(ckpt, config=cfg(resume_on))
    assert sc2.stream_offset == sc.stream_offset
    sc2.fit(CodecFileSource(path))
    assert np.array_equal(sc2.finalize().labels, straight.labels)


# ---------------------------------------------------------------------------
# Torn descriptor tables are rejected
# ---------------------------------------------------------------------------

def _one_cmega(tmp_path, seed=5):
    edges = _mixed_stream(400, 4096, seed)
    path = _write(tmp_path, edges, block_edges=512)
    pipe = BatchPipeline(CodecFileSource(path), 512, prefetch=0)
    return next(iter(pipe.compressed_megabatches(4)))


def test_torn_descriptor_tables_rejected(tmp_path):
    cm = _one_cmega(tmp_path)
    cm.validate()  # the clean slab passes

    def tamper(**cols):
        d = cm.desc.copy()
        for col, val in cols.items():
            d[0, globals()[col]] = val
        return cm._replace(desc=d)

    torn = [
        cm._replace(n_desc=cm.desc.shape[0] + 1),  # n_desc past the table
        cm._replace(n_desc=cm.n_desc - 1),  # live row past n_desc
        tamper(D_KIND=9),  # unknown kind
        tamper(D_NROWS=0),  # empty live segment
        tamper(D_NROWS=cm.window + 1),  # wider than the decode window
        tamper(D_ROW=3),  # segments no longer tile [0, n_rows)
        tamper(D_W_I=3),  # width the device cannot decode
        cm._replace(payload=cm.payload[:8]),  # truncated payload
    ]
    for bad in torn:
        with pytest.raises(ValueError, match="torn"):
            bad.validate()


def test_partial_fit_cmegabatch_rejects_torn_table(tmp_path):
    cm = _one_cmega(tmp_path, seed=7)
    sc = StreamClusterer(
        ClusterConfig(
            n=400, v_max=16, backend="chunked", batch_edges=512,
            megabatch_k=4, chunk=512, device_decode=True,
        )
    )
    d = cm.desc.copy()
    d[0, D_ROW] += 1
    with pytest.raises(ValueError, match="torn"):
        sc.partial_fit_cmegabatch(cm._replace(desc=d))
    # the clean slab still ingests after the rejection
    sc.partial_fit_cmegabatch(cm)
    assert sc.stream_offset == cm.n_rows


# ---------------------------------------------------------------------------
# Config guards + capability errors
# ---------------------------------------------------------------------------

def test_device_decode_config_guards():
    with pytest.raises(ValueError, match="megabatch_k"):
        ClusterConfig(n=10, v_max=4, device_decode=True)
    with pytest.raises(ValueError, match="wavefront"):
        ClusterConfig(
            n=10, v_max=4, device_decode=True, megabatch_k=2,
            batch_edges=64, wavefront=8,
        )
    with pytest.raises(ValueError, match="refine"):
        ClusterConfig(
            n=10, v_max=4, device_decode=True, megabatch_k=2,
            batch_edges=64, refine="louvain",
        )


def test_backend_without_decode_fn_raises(tmp_path):
    cm = _one_cmega(tmp_path, seed=9)
    sc = StreamClusterer(
        ClusterConfig(
            n=400, v_max=16, backend="dense", batch_edges=512, megabatch_k=4
        )
    )
    with pytest.raises(ValueError, match="device decode"):
        sc.partial_fit_cmegabatch(cm)


def test_desc_cols_layout_shared_with_kernel():
    # the kernel and the pipeline must agree on the table layout
    from repro.graph.pipeline import DESC_COLS as PIPE_COLS

    assert DESC_COLS == PIPE_COLS
