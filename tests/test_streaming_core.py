"""Tier-equivalence and behaviour tests for the paper's Algorithm 1."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.chunked import cluster_stream_chunked
from repro.core.metrics import avg_f1, modularity, nmi
from repro.core.streaming import (
    PAD,
    canonical_labels,
    cluster_stream_dense,
    cluster_stream_oracle,
    cluster_stream_scan,
)
from repro.graph.generators import chung_lu_stream, ring_of_cliques, sbm_stream
from repro.graph.pipeline import pad_to_chunks
from repro.graph.stream import shard_stream


def _random_stream(n, m, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2)).astype(np.int32)
    e[:, 1] = np.where(e[:, 0] == e[:, 1], (e[:, 1] + 1) % n, e[:, 1])
    return e


@pytest.mark.parametrize("v_max", [1, 3, 10, 100])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dense_matches_dict_oracle(v_max, seed):
    n, m = 60, 400
    edges = _random_stream(n, m, seed)
    c_dict = cluster_stream_oracle(edges, v_max)
    c_arr = np.array([c_dict.get(i, 0) for i in range(n)])
    c_dense, _, _ = cluster_stream_dense(edges, v_max, n)
    assert np.array_equal(canonical_labels(c_arr), canonical_labels(c_dense))


@pytest.mark.parametrize("v_max", [2, 8, 64])
def test_scan_bitexact_vs_dense(v_max):
    n, m = 80, 600
    edges = _random_stream(n, m, 3)
    c_d, d_d, v_d = cluster_stream_dense(edges, v_max, n)
    c_s, d_s, v_s = cluster_stream_scan(jnp.asarray(edges), v_max, n)
    assert np.array_equal(np.asarray(c_s), c_d.astype(np.int32))
    assert np.array_equal(np.asarray(d_s), d_d.astype(np.int32))
    assert np.array_equal(np.asarray(v_s), v_d.astype(np.int32))


def test_chunk1_bitexact_vs_scan():
    """chunk=1 chunked clustering degenerates to the sequential algorithm."""
    n, m = 50, 300
    edges = _random_stream(n, m, 4)
    c_d, d_d, v_d = cluster_stream_dense(edges, 8, n)
    c_c, d_c, v_c = cluster_stream_chunked(jnp.asarray(edges), 8, n, chunk=1)
    assert np.array_equal(np.asarray(c_c), c_d.astype(np.int32))
    assert np.array_equal(np.asarray(v_c), v_d.astype(np.int32))


def test_pad_edges_are_noops():
    n = 30
    edges = _random_stream(n, 100, 5)
    padded = np.concatenate(
        [edges, np.full((37, 2), PAD, dtype=np.int32)], axis=0
    )
    c1, d1, v1 = cluster_stream_dense(edges, 6, n)
    c2, d2, v2 = cluster_stream_dense(padded, 6, n)
    assert np.array_equal(c1, c2) and np.array_equal(v1, v2)
    c3, _, _ = cluster_stream_scan(jnp.asarray(padded), 6, n)
    assert np.array_equal(np.asarray(c3), c1.astype(np.int32))


def test_ring_of_cliques_recovered():
    edges, truth = ring_of_cliques(10, 6, seed=0)
    n = 60
    # v_max ~ half the final clique volume is the sweet spot (joins must
    # happen while communities are still below threshold).
    c, _, _ = cluster_stream_dense(edges, 16, n)
    f1 = avg_f1(canonical_labels(c), truth)
    assert f1 > 0.8
    assert modularity(edges, c) > 0.5


def test_chunked_quality_parity_on_sbm():
    n = 2000
    edges, truth = sbm_stream(n, 100, avg_degree=12, p_intra=0.8, seed=1)
    v_max = 48
    c_seq, _, _ = cluster_stream_dense(edges, v_max, n)
    c_chk, _, _ = cluster_stream_chunked(jnp.asarray(edges), v_max, n, chunk=512)
    q_seq = modularity(edges, c_seq)
    q_chk = modularity(edges, np.asarray(c_chk))
    assert abs(q_seq - q_chk) < 0.05
    f_seq = avg_f1(canonical_labels(c_seq), truth)
    f_chk = avg_f1(canonical_labels(np.asarray(c_chk)), truth)
    assert f_chk > 0.8 * f_seq


# ---------------------------------------------------------------------------
# Property-based invariants (hypothesis)
# ---------------------------------------------------------------------------

stream_strategy = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=25, deadline=None)
@given(seed=stream_strategy, v_max=st.integers(min_value=1, max_value=200))
def test_invariants_hold_on_random_streams(seed, v_max):
    """Invariants of Algorithm 1 state, for any stream and any v_max:

    * sum of community volumes == sum of degrees == 2 * (#live edges)
    * volume of community k == sum of degrees of its members
    * every node's community label is a node id that belongs to the community
      chain (labels form a valid partition)
    """
    n, m = 40, 250
    edges = _random_stream(n, m, seed)
    c, d, v = cluster_stream_dense(edges, v_max, n)
    assert d.sum() == 2 * m
    assert v.sum() == d.sum()
    vol_check = np.zeros(n, dtype=np.int64)
    np.add.at(vol_check, c, d)
    assert np.array_equal(vol_check, v)
    # partition validity: labels are in range and every non-empty community id
    # has positive volume
    assert (c >= 0).all() and (c < n).all()
    used = np.unique(c[d > 0])
    assert (v[used] > 0).all()


@settings(max_examples=15, deadline=None)
@given(seed=stream_strategy)
def test_vmax1_keeps_volume_bounded_growth(seed):
    """With v_max=1 no join can fire after a community reaches volume 2:
    community sizes stay tiny (pairs at most)."""
    n, m = 30, 200
    edges = _random_stream(n, m, seed)
    c, d, v = cluster_stream_dense(edges, 1, n)
    sizes = np.bincount(c, minlength=n)
    assert sizes.max() <= 2


@settings(max_examples=10, deadline=None)
@given(seed=stream_strategy)
def test_monotone_vmax_reduces_fragmentation(seed):
    """Larger v_max can only produce <= as many communities (on average).

    Not a strict theorem — checked as a trend over one stream with a wide
    spread of v_max; guards against sign errors in the threshold logic."""
    n, m = 60, 500
    edges = _random_stream(n, m, seed)
    counts = []
    for vm in (1, 10, 10_000):
        c, d, _ = cluster_stream_dense(edges, vm, n)
        counts.append(len(np.unique(c[d > 0])))
    assert counts[0] >= counts[1] >= counts[2] - 2


def _canonical_labels_loop(c):
    """Reference implementation of canonical_labels (per-element loop)."""
    c = np.asarray(c)
    _, inv = np.unique(c, return_inverse=True)
    first = {}
    out = np.empty_like(inv)
    nxt = 0
    for idx, lab in enumerate(inv):
        if lab not in first:
            first[lab] = nxt
            nxt += 1
        out[idx] = first[lab]
    return out


@settings(max_examples=30, deadline=None)
@given(seed=stream_strategy, lo=st.integers(-10, 0), hi=st.integers(1, 500))
def test_canonical_labels_matches_loop_reference(seed, lo, hi):
    rng = np.random.default_rng(seed)
    x = rng.integers(lo, hi, size=rng.integers(1, 400))
    got = canonical_labels(x)
    want = _canonical_labels_loop(x)
    assert np.array_equal(got, want)
    # canonical form: labels are 0..K-1, first appearances are increasing
    assert got.min() == 0 and got.max() == len(np.unique(x)) - 1
    first_pos = [np.argmax(got == k) for k in range(got.max() + 1)]
    assert first_pos == sorted(first_pos)


def test_canonical_labels_examples():
    assert np.array_equal(canonical_labels([7, 7, 3, 7, 3, 9]), [0, 0, 1, 0, 1, 2])
    assert np.array_equal(canonical_labels([2]), [0])


def test_shard_stream_partitions_preserve_edges():
    edges = _random_stream(100, 777, 9)
    shards = shard_stream(edges, 8)
    flat = shards.reshape(-1, 2)
    live = flat[:, 0] != PAD
    assert live.sum() == 777
    assert np.array_equal(flat[live][: len(edges)], edges)


def test_pad_to_chunks_shapes():
    edges = _random_stream(50, 130, 2)
    chunks = pad_to_chunks(edges, 64)
    assert chunks.shape == (3, 64, 2)
    assert (chunks.reshape(-1, 2)[130:] == PAD).all()
