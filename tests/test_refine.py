"""The multi-stage refinement subsystem (ISSUE 6 acceptance).

* **weighted engines** — hypothesis properties: Louvain and label
  propagation on a weighted graph are bit-identical to the same run on the
  graph with every edge duplicated ``w`` times (integer weights);
* **contraction equivalence** — hypothesis property: the weighted
  modularity of projected labels on the original graph equals the weighted
  modularity of the supergraph partition on the contracted graph (the
  invariant that makes supergraph moves optimise the real objective);
* **accumulator** — dense→hash spill preserves content, eviction is
  deterministic and counted in ``dropped_weight``, leaves round-trip
  bit-identically mid-accumulation;
* **checkpoint/resume** (acceptance) — a streamed-then-refined run with a
  mid-stream suspend/resume produces labels bit-identical to the
  uninterrupted run, sketch and replay window included;
* **quality** — refinement lifts modularity and F1 on a planted SBM above
  the raw streamed labels.
"""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.cluster import (
    ClusterConfig,
    StreamClusterer,
    avg_f1,
    canonical_labels,
    cluster,
    modularity,
    weighted_modularity,
)
from repro.cluster.refine import (
    ReplayBuffer,
    SupergraphAccumulator,
    parse_refine,
)
from repro.core.labelprop import label_propagation
from repro.core.louvain import louvain
from repro.core.refine import (
    contract_graph,
    contract_pairs,
    project_labels,
    refine_partition,
)
from repro.graph.generators import sbm_segments
from repro.graph.sources import GeneratorSource


def _random_graph(n, m, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2)).astype(np.int64)
    e[:, 1] = np.where(e[:, 0] == e[:, 1], (e[:, 1] + 1) % n, e[:, 1])
    return e


def _sbm(n, k, avg_degree, p_intra, seed=11):
    m = int(n * avg_degree / 2)
    segment, truth = sbm_segments(n, k, p_intra=p_intra, seed=seed)
    edges = GeneratorSource(segment, m, segment_edges=1 << 14).materialize()
    return edges, truth


# ---------------------------------------------------------------------------
# Weighted engines ≡ duplicated-edge runs (hypothesis properties)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_weighted_louvain_equals_duplicated_edges(seed):
    """Property: Louvain with integer weights is bit-identical to Louvain on
    the multigraph with each edge repeated ``w`` times."""
    n, m = 30, 80
    rng = np.random.default_rng(seed)
    edges = _random_graph(n, m, seed)
    w = rng.integers(1, 5, size=m)
    dup = np.repeat(edges, w, axis=0)
    a = louvain(edges, n, seed=7, weights=w.astype(np.float64))
    b = louvain(dup, n, seed=7)
    np.testing.assert_array_equal(a, b)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_property_weighted_labelprop_equals_duplicated_edges(seed):
    """Property: weighted label propagation ≡ duplicated-edge propagation
    (same votes, same smallest-label tie-breaks, same sweeps)."""
    n, m = 30, 80
    rng = np.random.default_rng(seed)
    edges = _random_graph(n, m, seed)
    w = rng.integers(1, 5, size=m)
    dup = np.repeat(edges, w, axis=0)
    a = label_propagation(edges, n, sweeps=4, seed=3, weights=w.astype(np.float64))
    b = label_propagation(dup, n, sweeps=4, seed=3)
    np.testing.assert_array_equal(a, b)


def test_weighted_modularity_matches_unweighted():
    edges = _random_graph(50, 200, 0)
    labels = np.arange(50) % 7
    assert weighted_modularity(edges, labels) == pytest.approx(
        modularity(edges, labels), abs=1e-12
    )


# ---------------------------------------------------------------------------
# Contraction equivalence (the refinement invariant)
# ---------------------------------------------------------------------------

def _supergraph_modularity(sg, sg_labels):
    """Weighted modularity of a supergraph partition, self-loops included."""
    k = sg.k
    loops = np.stack([np.arange(k), np.arange(k)], axis=1)
    edges = np.concatenate([sg.edges, loops], axis=0)
    weights = np.concatenate([sg.weights, sg.self_weight])
    return weighted_modularity(edges, np.asarray(sg_labels), weights)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_comm=st.integers(1, 12),
)
def test_property_projected_modularity_equals_supergraph_modularity(
    seed, n_comm
):
    """Property: for any graph, any streamed labelling, and any supergraph
    partition, Q(projected labels, original graph) == Q(partition,
    contracted graph).  Supergraph moves optimise the real objective."""
    n, m = 40, 150
    rng = np.random.default_rng(seed)
    edges = _random_graph(n, m, seed)
    streamed = rng.integers(0, n, size=n)  # arbitrary node-id-space labels
    streamed = np.arange(n)[streamed]  # label = some node id
    sg = contract_graph(edges, streamed)
    sg_labels = rng.integers(0, n_comm, size=sg.k)
    proj = project_labels(streamed, sg, sg_labels)
    assert _supergraph_modularity(sg, sg_labels) == pytest.approx(
        weighted_modularity(edges, proj), abs=1e-9
    )


def test_refine_partition_never_lowers_supergraph_modularity():
    edges, _ = _sbm(400, 20, 8, 0.8)
    streamed = np.asarray(
        cluster(edges, ClusterConfig(n=400, v_max=16, backend="dense")).labels
    )
    sg = contract_graph(edges, streamed)
    q0 = _supergraph_modularity(sg, np.arange(sg.k))
    for engine in ("louvain", "labelprop"):
        q1 = _supergraph_modularity(
            sg, refine_partition(sg, engine=engine, rounds=10)
        )
        assert q1 >= q0 - 1e-9


def test_accumulator_matches_exact_contraction_under_final_labels():
    """A sketch fed under the *final* labels reproduces the exact
    contraction (the streaming approximation is only label staleness)."""
    n = 60
    edges = _random_graph(n, 300, 5)
    # idempotent node-id labelling (founders keep their own label), the
    # structure a finalized dense-space state has: remapping final-label
    # entries through ``labels[founder]`` is then the identity
    rng = np.random.default_rng(5)
    founders = rng.choice(n, size=10, replace=False)
    labels = founders[rng.integers(0, 10, size=n)]
    labels[founders] = founders
    acc = SupergraphAccumulator(n)
    for lo in range(0, 300, 64):
        acc.observe(edges[lo:lo + 64], labels)
    a, b, w = acc.entries()
    sg_sketch = contract_pairs(a, b, w, labels)
    sg_exact = contract_graph(edges, labels)
    np.testing.assert_array_equal(sg_sketch.edges, sg_exact.edges)
    np.testing.assert_allclose(sg_sketch.weights, sg_exact.weights)
    np.testing.assert_allclose(sg_sketch.self_weight, sg_exact.self_weight)
    np.testing.assert_array_equal(sg_sketch.node_of, sg_exact.node_of)


# ---------------------------------------------------------------------------
# Accumulator: spill, eviction, leaves
# ---------------------------------------------------------------------------

def test_accumulator_spills_dense_to_hash_preserving_content():
    n = 1000
    acc_small = SupergraphAccumulator(n, dense_k=8)  # forced spill
    acc_big = SupergraphAccumulator(n, dense_k=1024)  # stays dense
    rng = np.random.default_rng(0)
    labels = np.arange(n)
    for _ in range(5):
        e = rng.integers(0, n, size=(200, 2))
        acc_small.observe(e, labels)
        acc_big.observe(e, labels)
    assert acc_small.spilled and not acc_big.spilled
    for x, y in zip(acc_small.entries(), acc_big.entries()):
        np.testing.assert_array_equal(x, y)
    assert acc_small.dropped_weight == 0


def test_accumulator_eviction_is_counted_and_bounded():
    n = 10_000
    acc = SupergraphAccumulator(n, dense_k=4, max_pairs=64)
    rng = np.random.default_rng(1)
    labels = np.arange(n)
    total = 0
    for _ in range(20):
        e = rng.integers(0, n, size=(500, 2))
        live = e[:, 0] != e[:, 1]
        total += int(np.count_nonzero(live))
        acc.observe(e, labels)
    _, _, w = acc.entries()
    assert len(w) <= 64
    assert acc.dropped_weight > 0
    # conservation: surviving weight + dropped weight == observed weight
    assert int(w.sum()) + acc.dropped_weight == total
    assert acc.peak_bytes <= 16 * (64 + 500)  # cap + one batch of slack


def test_accumulator_leaves_roundtrip_mid_accumulation():
    """Restoring from leaves and continuing is bit-identical to never
    having stopped — for both storage modes."""
    n = 500
    rng = np.random.default_rng(2)
    labels = np.arange(n)
    batches = [rng.integers(0, n, size=(100, 2)) for _ in range(8)]
    for dense_k in (4, 256):  # spilled vs dense at the suspend point
        a = SupergraphAccumulator(n, dense_k=dense_k, max_pairs=128)
        for e in batches[:4]:
            a.observe(e, labels)
        b = SupergraphAccumulator.from_leaves(
            a.to_leaves(), dense_k=dense_k, max_pairs=128
        )
        assert b.spilled == a.spilled
        assert b.dropped_weight == a.dropped_weight
        for e in batches[4:]:
            a.observe(e, labels)
            b.observe(e, labels)
        for x, y in zip(a.entries(), b.entries()):
            np.testing.assert_array_equal(x, y)
        assert b.dropped_weight == a.dropped_weight


def test_replay_buffer_is_row_exact():
    """Window contents are a pure function of the stream position — the
    same rows arrive, regardless of how they were batched."""
    edges = _random_graph(100, 1000, 3).astype(np.int32)
    a = ReplayBuffer(cap_rows=333)
    b = ReplayBuffer(cap_rows=333)
    a.append(edges)
    for lo in range(0, 1000, 17):
        b.append(edges[lo:lo + 17])
    np.testing.assert_array_equal(a.rows(), b.rows())
    assert a.n_rows == 333
    np.testing.assert_array_equal(a.rows(), edges[-333:])


# ---------------------------------------------------------------------------
# Config / dispatch surface
# ---------------------------------------------------------------------------

def test_refine_config_validation():
    assert parse_refine(None) is None
    assert parse_refine("louvain") == ("louvain", False)
    assert parse_refine("labelprop+replay") == ("labelprop", True)
    for bad in ("leiden", "louvain+buffered", "replay", "louvain+"):
        with pytest.raises(ValueError):
            ClusterConfig(n=10, v_max=4, refine=bad)
    with pytest.raises(ValueError):
        ClusterConfig(n=10, v_max=4, refine_rounds=0)
    with pytest.raises(ValueError):
        ClusterConfig(n=10, v_max=4, refine_max_pairs=0)


def test_refine_rejects_oracle_label_space():
    edges = _random_graph(50, 100, 0).astype(np.int32)
    with pytest.raises(ValueError, match="dense-label-space"):
        cluster(
            edges,
            ClusterConfig(n=50, v_max=8, backend="oracle", refine="louvain"),
        )


# ---------------------------------------------------------------------------
# End-to-end: every state kind refines at finalize
# ---------------------------------------------------------------------------

def _quality_regime():
    return _sbm(600, 30, 10, 0.8)


@pytest.mark.parametrize("backend,kw", [
    ("chunked", dict(v_max=16)),
    ("multiparam", dict(v_maxes=(8, 32, 128))),
    ("distributed", dict(v_max=16, n_shards=2, chunk=512)),
])
def test_refine_dispatches_across_state_kinds(backend, kw):
    edges, _ = _quality_regime()
    base = cluster(edges, ClusterConfig(n=600, backend=backend, **kw))
    res = cluster(
        edges,
        ClusterConfig(n=600, backend=backend, refine="louvain", **kw),
    )
    labels = np.asarray(res.labels)
    assert labels.shape == (600,)
    assert res.info["refine_engine"] == "louvain"
    assert res.info["refine_supernodes"] >= res.info["refine_communities"]
    assert res.info["refine_sketch_peak_bytes"] > 0
    # refinement must not lose modularity vs the raw streamed labels
    assert modularity(edges, labels) >= modularity(
        edges, np.asarray(base.labels)
    ) - 1e-9


def test_refine_improves_quality_on_planted_sbm():
    edges, truth = _quality_regime()
    cfg = dict(n=600, backend="multiparam", v_maxes=(8, 16, 32, 64, 128),
               criterion="density")
    raw = cluster(edges, ClusterConfig(**cfg))
    ref = cluster(edges, ClusterConfig(**cfg, refine="labelprop+replay"))
    q_raw = modularity(edges, np.asarray(raw.labels))
    q_ref = modularity(edges, np.asarray(ref.labels))
    f_raw = avg_f1(canonical_labels(np.asarray(raw.labels)), truth)
    f_ref = avg_f1(canonical_labels(np.asarray(ref.labels)), truth)
    assert q_ref > q_raw + 0.1
    assert f_ref > f_raw + 0.1
    assert ref.info["refine_replay_rows"] > 0


def test_refine_memory_is_cluster_bounded():
    """Peak sketch bytes stay O(#clusters^2 | max_pairs), reported in info."""
    edges, _ = _quality_regime()
    res = cluster(
        edges,
        ClusterConfig(n=600, v_max=16, backend="chunked", refine="louvain",
                      refine_max_pairs=4096),
    )
    assert res.info["refine_sketch_peak_bytes"] <= max(
        16 * (4096 + 600), 8 * 512 * 512
    )
    assert res.info["refine_dropped_weight"] >= 0


# ---------------------------------------------------------------------------
# Acceptance: mid-stream suspend/resume is bit-identical, sketch included
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", ["louvain", "labelprop+replay"])
def test_refined_checkpoint_resume_bit_identical(tmp_path, spec):
    edges, _ = _sbm(500, 25, 10, 0.8)
    cfg = ClusterConfig(n=500, backend="multiparam", v_maxes=(16, 64),
                        criterion="density", refine=spec, batch_edges=256)

    sc = StreamClusterer(cfg)
    sc.fit(edges)
    ref = np.asarray(sc.finalize().labels)

    sc1 = StreamClusterer(cfg)
    b = 256
    for lo in range(0, 4 * b, b):
        sc1.partial_fit(edges[lo:lo + b])
    d = str(tmp_path / "ckpt")
    sc1.save(d)
    sc2 = StreamClusterer.restore(d)

    # the sketch (and replay window) restores bit-identically
    for a1, a2 in zip(sc1._refine.accumulators, sc2._refine.accumulators):
        for x, y in zip(a1.entries(), a2.entries()):
            np.testing.assert_array_equal(x, y)
        assert a1.dropped_weight == a2.dropped_weight
    if sc1._refine.replay_buffer is not None:
        np.testing.assert_array_equal(
            sc1._refine.replay_buffer.rows(), sc2._refine.replay_buffer.rows()
        )

    for lo in range(sc2.stream_offset, edges.shape[0], b):
        sc2.partial_fit(edges[lo:lo + b])
    got = np.asarray(sc2.finalize().labels)
    np.testing.assert_array_equal(ref, got)


def test_restore_without_refine_leaves_starts_fresh(tmp_path):
    """A checkpoint written without refine restores under a refine config
    with an empty sketch (only post-resume edges are observed)."""
    edges = _random_graph(200, 800, 9).astype(np.int32)
    cfg = ClusterConfig(n=200, v_max=16, backend="chunked", batch_edges=256)
    sc = StreamClusterer(cfg)
    sc.partial_fit(edges[:256])
    d = str(tmp_path / "ckpt")
    sc.save(d)
    sc2 = StreamClusterer.restore(d, cfg.replace(refine="louvain"))
    assert sc2._refine is not None
    a, b, w = sc2._refine.accumulators[0].entries()
    assert len(w) == 0
    for lo in range(sc2.stream_offset, 800, 256):
        sc2.partial_fit(edges[lo:lo + 256])
    res = sc2.finalize()
    assert res.info["refine_engine"] == "louvain"
