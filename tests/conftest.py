"""Shared test fixtures.  NOTE: no XLA_FLAGS device-count override here —
smoke tests and benches must see the single real CPU device; multi-device
tests spawn subprocesses with their own XLA_FLAGS (see test_distributed.py).
"""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
