"""Codec/transport split tests: ``repro.graph.codecs``, ``CodecFileSource``,
``MergedSource``, and the cursor-threaded suspend/resume path.

The invariants under test are this PR's contract:

* **codec transparency** — a delta+varint compressed stream is
  byte-for-byte the same *stream* as its raw encoding: identical rows,
  identical labels, resumable from any cursor;
* **cursor semantics** — a checkpointed cursor (row + opaque token) minted
  by one process resumes the stream exactly in a fresh process, for raw,
  compressed, text, and merged sources alike, and legacy integer-offset
  checkpoints still restore;
* **multi-stream merge** — ``MergedSource`` is one well-defined,
  deterministic, resumable stream;
* **bandwidth** — the compressed stream spends < 0.5x the raw bytes/edge
  at the 10M-edge scale.
"""

import os
import threading

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.cluster import ClusterConfig, StreamClusterer, cluster
from repro.graph import convert
from repro.graph.codecs import (
    DVC_TOKEN_TAG,
    TEXT_TOKEN_TAG,
    Cursor,
    DeltaVarintCodec,
    RawCodec,
    as_cursor,
    decode_varints,
    encode_varints,
    sniff_codec,
    zigzag_decode,
    zigzag_encode,
)
from repro.graph.pipeline import BatchPipeline
from repro.graph.sources import (
    ArraySource,
    BinaryFileSource,
    CodecFileSource,
    EdgeListFileSource,
    GeneratorSource,
    MergedSource,
    as_source,
)


def _random_stream(n, m, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2)).astype(np.int32)
    e[:, 1] = np.where(e[:, 0] == e[:, 1], (e[:, 1] + 1) % n, e[:, 1])
    return e


def _sorted_local_stream(n, m, seed, spread=64):
    """Sorted-by-source stream with community locality — the on-disk layout
    (SNAP dumps, CSR-ish edge lists) the delta codec is built for."""
    rng = np.random.default_rng(seed)
    i = np.sort(rng.integers(0, n, m).astype(np.int64))
    j = (i + rng.integers(-spread, spread + 1, m)) % n
    j = np.where(j == i, (j + 1) % n, j)
    return np.stack([i, j], axis=1).astype(np.int32)


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def test_zigzag_varint_extremes():
    x = np.array([0, -1, 1, -(2**63), 2**63 - 1, 12345, -99999], np.int64)
    assert np.array_equal(zigzag_decode(zigzag_encode(x)), x)
    v = np.array([0, 1, 127, 128, 2**32, 2**63, 2**64 - 1], np.uint64)
    enc = encode_varints(v)
    dec, used = decode_varints(enc, v.size)
    assert used == enc.size and np.array_equal(dec, v)
    # empty stream
    assert encode_varints(np.zeros(0, np.uint64)).size == 0
    assert decode_varints(np.zeros(0, np.uint8), 0)[0].size == 0


@settings(max_examples=30, deadline=None)
@given(vals=st.lists(st.integers(-(2**63), 2**63 - 1), max_size=200))
def test_property_zigzag_varint_roundtrip(vals):
    x = np.array(vals, np.int64)
    enc = encode_varints(zigzag_encode(x))
    dec, used = decode_varints(enc, x.size)
    assert used == enc.size
    assert np.array_equal(zigzag_decode(dec), x)


def test_varint_truncation_detected():
    enc = encode_varints(np.array([2**40], np.uint64))
    with pytest.raises(ValueError, match="truncated"):
        decode_varints(enc[:-1], 1)


# ---------------------------------------------------------------------------
# DeltaVarintCodec round trip + cursors
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(0, 700),
    block_edges=st.integers(1, 200),
    start=st.integers(0, 800),
)
def test_property_dvc_roundtrip_and_row_resume(
    tmp_path_factory, seed, m, block_edges, start
):
    """Property: encode→decode identity and resume from any raw row, for
    any stream / block size / start (including past-the-end)."""
    edges = _random_stream(50, m, seed) if m else np.zeros((0, 2), np.int32)
    d = tmp_path_factory.mktemp("dvc")
    path = str(d / "s.dvc")
    src = CodecFileSource.write(
        path, edges, DeltaVarintCodec(block_edges=block_edges)
    )
    assert src.n_edges == m
    got = list(src.iter_slices(start))
    tail = np.concatenate(got) if got else np.zeros((0, 2), np.int32)
    assert np.array_equal(tail, edges[start:])


def test_dvc_preserves_arbitrary_int32_values(tmp_path):
    """The codec is order- and value-exact for the full int32 range (PAD=-1
    rows, negative ids, extreme deltas) — it may never canonicalize."""
    edges = np.array(
        [[-1, -1], [2**31 - 1, -(2**31)], [0, 2**31 - 1], [5, 5], [-7, 3]],
        np.int32,
    )
    path = str(tmp_path / "x.dvc")
    src = CodecFileSource.write(path, edges, DeltaVarintCodec(block_edges=2))
    assert np.array_equal(src.materialize(), edges)


def test_dvc_block_cursor_token_resumes_in_fresh_process(tmp_path):
    """A cursor minted while streaming (token = block sync point) must
    resume exactly in a *fresh* source — the checkpointed-restart path."""
    edges = _random_stream(300, 5000, 7)
    path = str(tmp_path / "s.dvc")
    src = CodecFileSource.write(path, edges, DeltaVarintCodec(block_edges=256))
    list(src.iter_slices(0))  # records block sync points
    for row in (0, 1, 255, 256, 4000, 4999):
        cur = src.cursor_at(row)
        fresh = CodecFileSource(path)  # fresh "process": no sync map
        got = list(fresh.resume(cur))
        tail = np.concatenate(got) if got else np.zeros((0, 2), np.int32)
        assert np.array_equal(tail, edges[row:]), row
        # serialization round trip (how checkpoints carry it)
        assert Cursor.from_array(cur.to_array()) == cur
    assert src.cursor_at(4000).token != ()  # tokens actually minted


def test_dvc_rejects_corruption(tmp_path):
    edges = _random_stream(40, 500, 8)
    path = str(tmp_path / "s.dvc")
    CodecFileSource.write(path, edges, DeltaVarintCodec(block_edges=64))
    data = open(path, "rb").read()
    # truncated inside a block
    with open(path, "wb") as f:
        f.write(data[:-11])
    with pytest.raises(ValueError, match="truncated"):
        CodecFileSource(path).materialize()
    # bad magic
    with open(path, "wb") as f:
        f.write(b"NOPE" + data[4:])
    with pytest.raises(ValueError, match="magic"):
        CodecFileSource(path, DeltaVarintCodec())


def test_dvc_sentinel_header_truncated_payload_detected_at_open(tmp_path):
    """A .dvc with the unknown-length sentinel header (unseekable encode)
    that was truncated mid-payload must fail at open, not overcount."""
    import struct

    edges = _random_stream(40, 500, 21)
    path = str(tmp_path / "s.dvc")
    codec = DeltaVarintCodec(block_edges=64)
    CodecFileSource.write(path, edges, codec)
    data = bytearray(open(path, "rb").read())
    # restore the "length unknown" sentinel, then cut inside a payload
    data[4:16] = struct.pack("<IQ", 64, (1 << 64) - 1)
    with open(path, "wb") as f:
        f.write(data[:-9])
    with pytest.raises(ValueError, match="truncated"):
        CodecFileSource(path)


def test_text_cursor_at_survives_unlinked_path(tmp_path):
    """cursor_at is called per batch from the fit loop; if the file was
    unlinked while an open handle still streams it, it must mint a bare-row
    cursor, not abort the fit."""
    p = str(tmp_path / "g.txt")
    with open(p, "w") as f:
        f.write("1 2\n3 4\n")
    src = EdgeListFileSource(p)
    list(src.iter_slices(0))
    os.unlink(p)
    assert src.cursor_at(1) == Cursor(1)


def test_raw_codec_validates_record_size_at_open(tmp_path):
    """Satellite: a torn raw file fails loudly at open instead of silently
    dropping the tail edge."""
    p = str(tmp_path / "torn.bin")
    with open(p, "wb") as f:
        f.write(b"\x00" * 20)  # 2.5 int32 pairs
    with pytest.raises(ValueError, match="truncated|whole number"):
        BinaryFileSource(p)
    with pytest.raises(ValueError, match="truncated|whole number"):
        CodecFileSource(p, RawCodec())


def test_sniffing_magic_beats_suffix(tmp_path):
    edges = _random_stream(30, 100, 9)
    # dvc payload under a .bin suffix: magic wins
    disguised = str(tmp_path / "disguised.bin")
    CodecFileSource.write(disguised, edges, DeltaVarintCodec())
    src = as_source(disguised)
    assert isinstance(src, CodecFileSource) and src.codec.name == "dvc"
    assert np.array_equal(src.materialize(), edges)
    # plain .dvc suffix and .bin raw still dispatch
    assert as_source(
        str(CodecFileSource.write(tmp_path / "a.dvc", edges).path)
    ).codec.name == "dvc"
    assert isinstance(
        as_source(str(BinaryFileSource.write(tmp_path / "a.bin", edges).path)),
        BinaryFileSource,
    )
    assert sniff_codec(str(tmp_path / "missing.txt")) is None


def test_dvc_v1_v2_cross_version_read(tmp_path):
    """One decoder, both on-disk formats: a v2-default codec reads v1 files
    (and vice versa), values and cursor-resume identical."""
    edges = _random_stream(300, 4000, 13)
    p1 = str(tmp_path / "old.dvc")
    p2 = str(tmp_path / "new.dvc")
    CodecFileSource.write(p1, edges, DeltaVarintCodec(version=1))
    # checksum=False keeps the legacy plain framing (the v2 default now
    # writes the checksummed DVX2 magic)
    CodecFileSource.write(p2, edges, DeltaVarintCodec(version=2, checksum=False))
    with open(p1, "rb") as f:
        assert f.read(4) == b"DVE1"
    with open(p2, "rb") as f:
        assert f.read(4) == b"DVE2"
    for p in (p1, p2):
        src = CodecFileSource(p, DeltaVarintCodec())  # default (v2) reader
        assert src.n_edges == len(edges)
        assert np.array_equal(src.materialize(), edges)
        got = list(src.iter_slices(2500))
        assert np.array_equal(np.concatenate(got), edges[2500:])
    # sniffing dispatches on either magic
    for p in (p1, p2):
        assert sniff_codec(p).name == "dvc"


def test_dvc_v2_fixed_width_and_varint_fallback_columns(tmp_path):
    """v2 picks the narrowest winning fixed width per column and falls back
    to varint (mode 0) when extreme deltas make fixed encoding wider —
    both modes must round-trip exactly."""
    # deltas with zigzag in [128, 256): u1 fixed (1 B/value) strictly beats
    # varint (2 B/value), so the column flips to mode 1
    small = np.stack(
        [np.arange(500) * 100, np.arange(500) * 100 + 90], 1
    ).astype(np.int32)
    # alternating int32 extremes → zigzag deltas ≥ 2^32: no fixed width fits
    wild = np.empty((500, 2), np.int32)
    wild[0::2] = [2**31 - 1, -(2**31)]
    wild[1::2] = [-(2**31), 2**31 - 1]
    for name, edges in (("small", small), ("wild", wild)):
        path = str(tmp_path / f"{name}.dvc")
        src = CodecFileSource.write(
            path, edges, DeltaVarintCodec(block_edges=64)
        )
        assert np.array_equal(src.materialize(), edges), name
    # the u1-column file beats its v1 (pure-varint) encoding in bytes
    v1_path = str(tmp_path / "small_v1.dvc")
    CodecFileSource.write(
        v1_path, small, DeltaVarintCodec(block_edges=64, version=1)
    )
    assert os.path.getsize(str(tmp_path / "small.dvc")) <= os.path.getsize(
        v1_path
    )


def test_dvc_version_validation():
    DeltaVarintCodec(version=3)  # DVE3 is a valid version
    with pytest.raises(ValueError, match="version"):
        DeltaVarintCodec(version=4)


def test_convert_cli_roundtrip(tmp_path, capsys):
    edges = _sorted_local_stream(500, 20_000, 10)
    txt = str(tmp_path / "g.txt")
    with open(txt, "w") as f:
        for i, j in edges:
            f.write(f"{i} {j}\n")
    dvc = str(tmp_path / "g.dvc")
    raw = str(tmp_path / "g.bin")
    assert convert.main([txt, dvc, "--block-edges", "2048"]) == 0
    assert convert.main([dvc, raw, "--codec", "raw", "--quiet"]) == 0
    # --block-edges never silently changes the output format
    with pytest.raises(SystemExit):
        convert.main([txt, str(tmp_path / "x.bin"), "--block-edges", "64"])
    assert np.array_equal(as_source(dvc).materialize(), edges)
    assert np.array_equal(as_source(raw).materialize(), edges)
    # the sorted+local regime actually compresses
    assert os.path.getsize(dvc) < 0.5 * os.path.getsize(raw)


# ---------------------------------------------------------------------------
# Decode overlaps device compute (prefetch thread)
# ---------------------------------------------------------------------------

class _ThreadRecordingSource(ArraySource):
    def __init__(self, edges):
        super().__init__(edges)
        self.threads = set()

    def iter_slices(self, start: int = 0):
        for sl in super().iter_slices(start):
            self.threads.add(threading.get_ident())
            yield sl


def test_source_decode_runs_on_prefetch_thread():
    """The pipeline pulls the source's generator (where codec block decode
    happens) on its background worker, so decompression overlaps the
    consumer's device compute."""
    src = _ThreadRecordingSource(_random_stream(40, 5000, 11))
    for _ in BatchPipeline(src, 256, prefetch=2):
        pass
    assert src.threads and threading.get_ident() not in src.threads


# ---------------------------------------------------------------------------
# MergedSource: deterministic arrival-time interleave
# ---------------------------------------------------------------------------

def test_merged_round_robin_at_equal_rates():
    a = np.stack([np.zeros(40, np.int32), np.arange(40, dtype=np.int32)], 1)
    b = np.stack([np.ones(40, np.int32), np.arange(40, dtype=np.int32)], 1)
    ms = MergedSource([ArraySource(a), ArraySource(b)], granule=10)
    got = ms.materialize()
    # equal rates, tie -> lower index: strict a/b alternation in 10-row turns
    expect = np.concatenate(
        [x for k in range(4) for x in (a[k * 10 : k * 10 + 10], b[k * 10 : k * 10 + 10])]
    )
    assert np.array_equal(got, expect)


def test_merged_rates_shape_the_interleave():
    a = np.full((30, 2), 0, np.int32)
    b = np.full((90, 2), 1, np.int32)
    ms = MergedSource([ArraySource(a), ArraySource(b)], rates=[1, 3], granule=10)
    got = ms.materialize()[:, 0]
    # per 40-row window of the merge, source b (3x rate) supplies 30 rows
    assert got.shape[0] == 120
    for w in range(3):
        window = got[w * 40 : (w + 1) * 40]
        assert int((window == 1).sum()) == 30, w


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    sizes=st.lists(st.integers(0, 300), min_size=1, max_size=4),
    rates=st.lists(st.integers(1, 5), min_size=4, max_size=4),
    granule=st.integers(1, 97),
    start=st.integers(0, 900),
)
def test_property_merged_resume_any_offset(seed, sizes, rates, granule, start):
    """Property: for any source sizes, rates, granule, and start row, the
    merged stream resumed at ``start`` is exactly the tail of the full
    stream — the schedule is a pure function of consumed-row state."""
    srcs = [
        ArraySource(_random_stream(20, m, seed + k)) for k, m in enumerate(sizes)
    ]
    ms = MergedSource(srcs, rates=rates[: len(srcs)], granule=granule)
    full = ms.materialize()
    got = list(ms.iter_slices(start))
    tail = np.concatenate(got) if got else np.zeros((0, 2), np.int32)
    assert np.array_equal(tail, full[start:])
    # cursor token = per-source offsets; resumes a fresh instance identically
    row = min(start, ms.n_edges)
    cur = ms.cursor_at(row)
    assert sum(cur.token) == row
    fresh = MergedSource(srcs, rates=rates[: len(srcs)], granule=granule)
    got2 = list(fresh.resume(cur))
    tail2 = np.concatenate(got2) if got2 else np.zeros((0, 2), np.int32)
    assert np.array_equal(tail2, full[row:])


def test_merged_stream_clusters_and_resumes_mid_file(tmp_path):
    """Acceptance: a MergedSource of 2+ sources (one compressed, one raw)
    clusters identically to its materialized stream on a resumable backend,
    including a mid-stream checkpoint suspend/restore in a fresh clusterer."""
    n = 120
    a = _sorted_local_stream(n, 3000, 12, spread=9)
    b = _random_stream(n, 2000, 13)
    dvc = str(tmp_path / "a.dvc")
    raw = str(tmp_path / "b.bin")
    CodecFileSource.write(dvc, a, DeltaVarintCodec(block_edges=512))
    BinaryFileSource.write(raw, b)

    def make_source():  # fresh transports each time, like a fresh process
        return MergedSource([dvc, raw], rates=[2, 1], granule=300)

    ms = make_source()
    merged = ms.materialize()
    cfg = ClusterConfig(n=n, v_max=8, backend="chunked", chunk=64,
                        batch_edges=448)
    ref = cluster(merged, cfg)
    assert np.array_equal(cluster(make_source(), cfg).labels, ref.labels)

    sc = StreamClusterer(cfg)
    sc.fit(make_source(), max_batches=4)
    assert sc.stream_offset == 4 * 448
    assert sum(sc.stream_cursor.token) == sc.stream_offset
    ck = str(tmp_path / "ck")
    sc.save(ck)
    sc2 = StreamClusterer.restore(ck)
    assert sc2.stream_cursor == sc.stream_cursor
    sc2.fit(make_source())
    assert sc2.stream_offset == merged.shape[0]
    assert np.array_equal(sc2.finalize().labels, ref.labels)


def test_merged_resume_ignores_schedule_inconsistent_tokens():
    """A token whose per-source offsets disagree with the schedule replay
    (e.g. a checkpoint restored against different rates/granule) must not
    reorder the resumed stream: the arithmetic replay is canonical."""
    a = _random_stream(10, 100, 17)
    b = _random_stream(10, 100, 18)
    ms = MergedSource([ArraySource(a), ArraySource(b)], granule=10)
    full = ms.materialize()
    # true replay at row 20 is (10, 10); this token claims (20, 0)
    got = np.concatenate(list(ms.resume(Cursor(20, (20, 0)))))
    assert np.array_equal(got, full[20:])
    # a token minted under other rates resumes THIS schedule, not that one
    other = MergedSource([ArraySource(a), ArraySource(b)], rates=[1, 3],
                         granule=10)
    stale = other.cursor_at(40)
    got = np.concatenate(list(ms.resume(stale)))
    assert np.array_equal(got, full[40:])


def test_dvc_block_boundary_truncation_detected(tmp_path):
    """A .dvc file cut exactly at a block boundary decodes cleanly but
    short — the source must raise instead of silently dropping the tail
    (the same torn-file failure RawCodec rejects at open)."""
    edges = _random_stream(40, 1000, 19)
    path = str(tmp_path / "s.dvc")
    src = CodecFileSource.write(path, edges, DeltaVarintCodec(block_edges=100))
    # find the byte offset of the sync point after the 5th block
    syncs = [nxt for _, nxt in src.codec.decode_from(path, Cursor(0))]
    cut = syncs[4].token[2]  # (tag, file_size, byte, row)
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[:cut])
    torn = CodecFileSource(path)  # header still declares 1000 edges
    with pytest.raises(ValueError, match="truncated"):
        torn.materialize()


def test_merged_requires_consistent_rates():
    with pytest.raises(ValueError, match="rates"):
        MergedSource([ArraySource(np.zeros((4, 2), np.int32))], rates=[1, 2])
    with pytest.raises(ValueError, match="at least one"):
        MergedSource([])


# ---------------------------------------------------------------------------
# Cursor-threaded checkpoints (incl. legacy layout)
# ---------------------------------------------------------------------------

def test_compressed_stream_suspends_and_restores_mid_file(tmp_path):
    """The PR 2 invariant extended to compressed streams: fit a prefix of a
    .dvc file, checkpoint, restore in a fresh session, fit the rest —
    labels identical to the uninterrupted in-memory run, and the restored
    cursor carries a block sync token (no prefix re-decode)."""
    n, m = 70, 600
    edges = _random_stream(n, m, 8)
    path = str(tmp_path / "stream.dvc")
    CodecFileSource.write(path, edges, DeltaVarintCodec(block_edges=128))
    cfg = ClusterConfig(n=n, v_max=8, backend="dense", batch_edges=128)

    sc = StreamClusterer(cfg)
    sc.fit(path, max_batches=2)
    assert sc.stream_offset == 256
    assert sc.stream_cursor.token != ()
    ck = str(tmp_path / "ckpt")
    sc.save(ck)

    sc2 = StreamClusterer.restore(ck)
    assert sc2.stream_cursor == sc.stream_cursor
    sc2.fit(path)
    assert sc2.stream_offset == m
    ref = cluster(edges, cfg)
    assert np.array_equal(sc2.finalize().labels, ref.labels)


def test_legacy_integer_offset_checkpoint_restores(tmp_path):
    """Back-compat: checkpoints written by the pre-cursor layout (scalar
    ``stream_offset`` leaf) restore as a token-less cursor and continue."""
    n, m = 50, 400
    edges = _random_stream(n, m, 14)
    cfg = ClusterConfig(n=n, v_max=6, backend="dense", batch_edges=100)
    sc = StreamClusterer(cfg)
    sc.fit(ArraySource(edges), max_batches=2)

    ck = str(tmp_path / "legacy")
    mgr = CheckpointManager(ck)
    with open(os.path.join(ck, "cluster_config.json"), "w") as f:
        f.write(cfg.to_json())
    mgr.save(
        sc.edges_seen,
        {
            "cluster_state": sc.state,
            "stream_offset": np.int64(sc.stream_offset),
        },
    )

    sc2 = StreamClusterer.restore(ck)
    assert sc2.stream_offset == 200 and sc2.stream_cursor.token == ()
    sc2.fit(ArraySource(edges))
    ref = cluster(edges, cfg)
    assert np.array_equal(sc2.finalize().labels, ref.labels)


def test_text_source_cursor_token_seeks_in_fresh_process(tmp_path):
    """EdgeListFileSource tokens (byte offset + line number) make a fresh
    process's resume seek instead of re-parsing the prefix."""
    edges = _random_stream(50, 2000, 15)
    p = str(tmp_path / "g.txt")
    with open(p, "w") as f:
        for i, j in edges:
            f.write(f"{i} {j}\n")
    src = EdgeListFileSource(p, block_lines=128)
    list(src.iter_slices(0))  # record seek points
    cur = src.cursor_at(1000)
    # (tag, file_size, sync_row, byte_pos, lineno)
    assert cur.token[0] == TEXT_TOKEN_TAG and cur.token[3] > 0
    fresh = EdgeListFileSource(p, block_lines=128)
    got = np.concatenate(list(fresh.resume(cur)))
    assert np.array_equal(got, edges[1000:])
    # the token seeded a non-zero seek point (no full prefix re-parse)
    assert any(r > 0 for r in fresh._resume)


def test_foreign_and_stale_tokens_fall_back_to_row(tmp_path):
    """The cursor contract: a foreign or stale token is *recognized* and
    dropped — `row` alone must always resume correctly.  (Regression: an
    unvalidated token once restarted a text parse mid-line, and a stale dvc
    byte offset past EOF silently truncated the stream to zero rows.)"""
    edges = _random_stream(50, 500, 16)
    txt = str(tmp_path / "g.txt")
    with open(txt, "w") as f:
        for i, j in edges:
            f.write(f"{i} {j}\n")
    dvc = str(tmp_path / "g.dvc")
    CodecFileSource.write(dvc, edges, DeltaVarintCodec(block_edges=64))

    txt_size = os.path.getsize(txt)
    dvc_size = os.path.getsize(dvc)
    foreign = [
        Cursor(300, (100, 100, 100)),  # merge-style offsets (sum == row)
        Cursor(300, (100, 200)),  # 2-source merge offsets
        Cursor(400, (DVC_TOKEN_TAG, 10**9, 400)),  # old-layout token
        Cursor(400, (TEXT_TOKEN_TAG, 100, 10**9, 100)),  # old-layout token
        # right tag, wrong file size (checkpoint against a replaced file)
        Cursor(400, (DVC_TOKEN_TAG, dvc_size + 7, 64, 0)),
        Cursor(400, (TEXT_TOKEN_TAG, txt_size + 7, 0, 0, 0)),
        # right tag and size, but byte offset at/past EOF (stale sync):
        # must fall back to row, not yield zero rows or raise
        Cursor(400, (DVC_TOKEN_TAG, dvc_size, dvc_size, 50)),
        Cursor(400, (TEXT_TOKEN_TAG, txt_size, 50, txt_size, 10)),
        # right tag and size, mid-line byte position (forged/corrupt)
        Cursor(400, (TEXT_TOKEN_TAG, txt_size, 100, 3, 100)),
    ]
    for src_factory in (
        lambda: EdgeListFileSource(txt),
        lambda: CodecFileSource(dvc),
    ):
        for cur in foreign:
            got = list(src_factory().resume(cur))
            tail = np.concatenate(got) if got else np.zeros((0, 2), np.int32)
            assert np.array_equal(tail, edges[cur.row :]), (cur, src_factory())


def test_as_cursor_coercion():
    assert as_cursor(7) == Cursor(7)
    assert as_cursor(Cursor(3, (1, 2))) == Cursor(3, (1, 2))
    assert Cursor.from_array(np.zeros(0, np.int64)) == Cursor(0)


# ---------------------------------------------------------------------------
# Scale acceptance: 10M edges, < 0.5x bytes/edge, bit-identical, resumable
# ---------------------------------------------------------------------------

def test_10m_edge_dvc_stream_bit_identical_and_under_half_raw_bytes(tmp_path):
    """Acceptance: a 10M-edge DeltaVarintCodec stream clusters bit-identical
    to the raw-binary and in-memory runs on the chunked tier (the scale
    backend; small-scale cross-backend identity is covered source-by-source
    in test_sources.py), at < 0.5x the raw on-disk bytes/edge, including a
    suspend/restore mid-file via the cursor."""
    n, m = 1 << 14, 10_000_000
    edges = _sorted_local_stream(n, m, 5)
    raw = str(tmp_path / "s.bin")
    dvc = str(tmp_path / "s.dvc")
    BinaryFileSource.write(raw, edges)
    CodecFileSource.write(dvc, edges, DeltaVarintCodec())
    assert os.path.getsize(dvc) < 0.5 * os.path.getsize(raw)

    cfg = ClusterConfig(
        n=n, v_max=64, backend="chunked", chunk=16384, batch_edges=1 << 18
    )
    ref = cluster(edges, cfg).block_until_ready()
    for path in (raw, dvc):
        res = cluster(path, cfg).block_until_ready()
        assert np.array_equal(res.labels, ref.labels), path
        assert int(res.state.edges_seen) == int(ref.state.edges_seen)
        # out-of-core: buffer stays O(batch), far under the 80 MB stream
        assert res.info["peak_buffer_bytes"] < edges.nbytes / 4

    sc = StreamClusterer(cfg)
    sc.fit(dvc, max_batches=13)
    assert sc.stream_cursor.token != ()
    ck = str(tmp_path / "ck")
    sc.save(ck)
    sc2 = StreamClusterer.restore(ck)
    assert sc2.stream_cursor == sc.stream_cursor
    sc2.fit(dvc)
    assert sc2.stream_offset == m
    assert np.array_equal(sc2.finalize().labels, ref.labels)
