"""Wavefront scheduling tests (DESIGN.md §12).

The contract under test:

* **planner soundness** — ``plan_waves`` only *segments* the stream (never
  reorders): waves ∪ leftover reconstruct the flattened megabatch exactly,
  every wave is node-disjoint over its live rows, and the layout shapes
  depend only on ``(M, width, slack)`` so the kernel compiles once;
* **bit-exactness** — both wavefront apply paths (the pure-JAX reference
  and the Pallas kernel in interpret mode) produce labels/degrees/volumes
  bit-identical to the sequential ``dense_update`` oracle on adversarial
  streams (hubs, repeated endpoints, self-loops, PAD tails), including a
  forced-fallback stream where every wave after the first collides in
  community space;
* **plumbing** — ``ClusterConfig(wavefront=W)`` routes ``fit`` through the
  backend's ``wavefront_fn`` with identical labels to megabatch and
  per-batch modes, surfaces the §12 info counters, survives checkpoint
  suspend/resume, is ignored by backends without a wavefront path, and the
  pipeline's residency accounting charges (and fully releases) the staged
  plan bytes.
"""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from hypothesis_compat import given, settings, st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.cluster import (  # noqa: E402
    ClusterConfig,
    GeneratorSource,
    StreamClusterer,
    cluster,
    plan_waves,
)
from repro.core.state import ClusterState  # noqa: E402
from repro.core.streaming import dense_update  # noqa: E402
from repro.core.wavefront import wavefront_update_megabatch  # noqa: E402
from repro.graph.generators import chung_lu_segments  # noqa: E402
from repro.graph.pipeline import PAD, BatchPipeline  # noqa: E402
from repro.graph.sources import ArraySource  # noqa: E402
from repro.kernels.edge_stream.ops import pallas_wavefront_update  # noqa: E402


def _adversarial_stream(n, m, seed, m_pad):
    """Stream with hub bias, repeated endpoints, self-loops, and interior
    PAD rows, padded with a trailing PAD tail to ``m_pad`` rows."""
    rng = np.random.default_rng(seed)
    out = np.full((m_pad, 2), PAD, np.int32)
    if m:
        # hub bias: half the endpoints drawn from the first few node ids
        a = np.where(rng.random(m) < 0.5, rng.integers(0, max(2, n // 8), m),
                     rng.integers(0, n, m))
        b = rng.integers(0, n, m)
        e = np.stack([a, b], axis=1).astype(np.int32)
        loops = rng.random(m) < 0.05
        e[loops, 1] = e[loops, 0]  # self-loops
        e[rng.random(m) < 0.03] = PAD  # interior dead rows
        out[:m] = e
    return out


def _wave_rows(plan):
    """Stream-order reconstruction: used waves' live prefixes + leftover."""
    parts = [plan.waves[t, : plan.counts[t]] for t in range(plan.n_waves)]
    parts.append(plan.leftover[: plan.leftover_rows])
    return (np.concatenate(parts) if parts else
            np.zeros((0, 2), np.int32))


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("width", [1, 4, 16])
@pytest.mark.parametrize("m,m_pad", [(0, 64), (50, 64), (256, 256)])
def test_plan_reconstructs_stream_in_order(width, m, m_pad):
    """waves ∪ leftover == the flattened stream up to its last live row —
    the planner segments, it never reorders or drops."""
    edges = _adversarial_stream(37, m, seed=width + m, m_pad=m_pad)
    plan = plan_waves(edges, width)
    flat = edges.reshape(-1, 2)
    m_eff = plan.rows_in_waves + plan.leftover_rows
    np.testing.assert_array_equal(_wave_rows(plan), flat[:m_eff])
    # everything past m_eff is dead (PAD or self-loop): it constrains nothing
    tail = flat[m_eff:]
    dead = (tail[:, 0] == PAD) | (tail[:, 1] == PAD) | (tail[:, 0] == tail[:, 1])
    assert dead.all()


@pytest.mark.parametrize("seed", range(4))
def test_plan_waves_node_disjoint(seed):
    edges = _adversarial_stream(23, 300, seed=seed, m_pad=320)
    plan = plan_waves(edges, 16)
    assert plan.n_waves == plan.meta[0]
    for t in range(plan.n_waves):
        assert plan.counts[t] >= 1  # forward progress per used wave
        rows = plan.waves[t, : plan.counts[t]]
        live = (rows[:, 0] != PAD) & (rows[:, 1] != PAD) & (rows[:, 0] != rows[:, 1])
        ends = rows[live].ravel()
        assert len(np.unique(ends)) == ends.size, t


def test_plan_shapes_depend_only_on_geometry():
    """Fixed compile shapes: (M, width, slack) fully determine the layout,
    regardless of stream content — one kernel compile per run."""
    W, M, slack = 8, 96, 4
    dense = _adversarial_stream(11, 96, seed=1, m_pad=M)  # heavy reuse
    sparse = np.stack([np.arange(M), np.arange(M) + M], 1).astype(np.int32)
    for edges in (dense, sparse):
        plan = plan_waves(edges, W, slack=slack)
        assert plan.waves.shape == (slack * -(-M // W), W, 2)
        assert plan.counts.shape == (slack * -(-M // W),)
        assert plan.leftover.shape == (M, 2)
        assert plan.meta.shape == (2,)
    # the all-disjoint stream packs perfectly: full waves, no leftover
    full = plan_waves(sparse, W, slack=slack)
    assert full.leftover_rows == 0 and full.mean_wave_width == W


def test_plan_validation_and_dead_stream():
    edges = np.zeros((8, 2), np.int32)
    with pytest.raises(ValueError, match="width"):
        plan_waves(edges, 0)
    with pytest.raises(ValueError, match="slack"):
        plan_waves(edges, 4, slack=0)
    dead = np.full((32, 2), PAD, np.int32)
    plan = plan_waves(dead, 4)
    assert plan.n_waves == 0 == plan.rows_in_waves == plan.leftover_rows


# ---------------------------------------------------------------------------
# Bit-exactness vs the sequential oracle (hypothesis, fixed shapes)
# ---------------------------------------------------------------------------

_M, _W = 128, 8  # fixed layout → a handful of compiles for the whole sweep


def _assert_matches_oracle(apply_fn, edges, n, v_max):
    plan = plan_waves(edges, _W)
    ref = dense_update(ClusterState.init(n, numpy=True), edges, v_max)
    state, stats = apply_fn(
        ClusterState.init(n).to_device(),
        jnp.asarray(plan.waves),
        jnp.asarray(plan.leftover),
        jnp.asarray(plan.meta),
        v_max,
    )
    got = state.to_numpy()
    np.testing.assert_array_equal(got.c, ref.c)
    np.testing.assert_array_equal(got.d, ref.d)
    np.testing.assert_array_equal(got.v, ref.v)
    assert int(got.edges_seen) == int(ref.edges_seen)
    live, fall = (int(x) for x in np.asarray(stats))
    assert 0 <= fall <= live <= plan.n_waves


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n=st.integers(4, 60),
    m=st.integers(0, _M),
    v_max=st.sampled_from([1, 2, 8, 64]),
)
def test_property_wavefront_reference_bit_identical(seed, n, m, v_max):
    """Reference path vs dense oracle on adversarial streams."""
    edges = _adversarial_stream(n, m, seed=seed, m_pad=_M)
    _assert_matches_oracle(wavefront_update_megabatch, edges, n, v_max)


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n=st.integers(4, 60),
    m=st.integers(0, _M),
    v_max=st.sampled_from([2, 8, 64]),
)
def test_property_wavefront_kernel_bit_identical(seed, n, m, v_max):
    """Pallas wavefront kernel (interpret mode) vs dense oracle."""
    edges = _adversarial_stream(n, m, seed=seed, m_pad=_M)

    def kernel(state, waves, leftover, meta, vm):
        return pallas_wavefront_update(
            state, waves, leftover, meta, vm, chunk=64, interpret=True
        )

    _assert_matches_oracle(kernel, edges, n, v_max)


@pytest.mark.parametrize("seed,n,m,v_max", [
    (0, 6, _M, 2),     # tiny graph: heavy endpoint reuse, short waves
    (1, 40, 100, 8),   # PAD tail after row 100
    (2, 12, _M, 1),    # v_max=1: everything saturates immediately
    (3, 60, 64, 64),   # sparse reuse: wide waves, no saturation
    (4, 4, _M, 8),     # 4 nodes, 128 rows: maximal collision pressure
])
def test_wavefront_paths_bit_identical_grid(seed, n, m, v_max):
    """Deterministic analogue of the hypothesis sweeps (runs even without
    hypothesis installed): both apply paths vs the dense oracle."""
    edges = _adversarial_stream(n, m, seed=seed, m_pad=_M)
    _assert_matches_oracle(wavefront_update_megabatch, edges, n, v_max)
    _assert_matches_oracle(
        lambda *a: pallas_wavefront_update(*a, chunk=64, interpret=True),
        edges, n, v_max,
    )


def test_forced_fallback_is_exact_and_counted():
    """After the first wave merges {0,2} and {1,3}, every later wave's two
    node-disjoint edges share both (unsaturated) communities — the runtime
    check must fire and the sequential fallback must keep bit-exactness."""
    n, v_max = 8, 1 << 20  # never saturates: every collision is live
    edges = np.tile(
        np.array([[0, 2], [1, 3], [0, 3], [1, 2]], np.int32), (10, 1)
    )
    plan = plan_waves(edges, 2)
    assert plan.leftover_rows == 0  # width-2 waves always pack here
    ref = dense_update(ClusterState.init(n, numpy=True), edges, v_max)
    for apply_fn in (
        wavefront_update_megabatch,
        lambda *a: pallas_wavefront_update(*a, chunk=16, interpret=True),
    ):
        state, stats = apply_fn(
            ClusterState.init(n).to_device(),
            jnp.asarray(plan.waves),
            jnp.asarray(plan.leftover),
            jnp.asarray(plan.meta),
            v_max,
        )
        got = state.to_numpy()
        np.testing.assert_array_equal(got.c, ref.c)
        np.testing.assert_array_equal(got.v, ref.v)
        live, fall = (int(x) for x in np.asarray(stats))
        assert fall >= 1  # the collision pattern actually exercised fallback
        assert live == plan.n_waves


# ---------------------------------------------------------------------------
# API plumbing: fit / info counters / checkpoints / pipeline residency
# ---------------------------------------------------------------------------

def _source(n, m, seed, segment=700):
    return GeneratorSource(
        chung_lu_segments(n, seed=seed), m, segment_edges=segment
    )


@pytest.mark.parametrize("m", [200, 2048, 5000])
def test_wavefront_fit_bit_identical_with_counters(m):
    n, B, K, W = 900, 256, 4, 8
    src = _source(n, m, seed=m)
    cfg = ClusterConfig(
        n=n, v_max=24, backend="pallas", chunk=128, batch_edges=B,
        megabatch_k=K,
    )
    r_wave = cluster(src, cfg.replace(wavefront=W))
    r_mega = cluster(src, cfg)
    r_per = cluster(src, cfg.replace(megabatch_k=None))
    np.testing.assert_array_equal(r_wave.labels, r_mega.labels)
    np.testing.assert_array_equal(r_wave.labels, r_per.labels)
    info = r_wave.info
    assert info["wavefront_megabatches"] == info["stream_megabatches"]
    assert info["wavefront_waves"] >= 1
    assert 1.0 <= info["wavefront_mean_wave_width"] <= W
    assert 0.0 <= info["wavefront_fallback_rate"] <= 1.0
    assert info["wavefront_fallback_waves"] <= info["wavefront_live_waves"]
    assert info["wavefront_plan_seconds"] >= 0.0
    # every live row went through a wave or the leftover tail
    assert "wavefront_megabatches" not in r_mega.info


def test_wavefront_checkpoint_resume_bit_identical(tmp_path):
    """Suspend per-batch mid-megabatch, restore, finish in wavefront mode —
    plans are stateless per megabatch, so checkpoints are untouched."""
    n, m, B, K = 700, 5000, 256, 4
    src = _source(n, m, seed=5)
    cfg = ClusterConfig(
        n=n, v_max=24, backend="pallas", chunk=128, batch_edges=B,
        megabatch_k=K, wavefront=8,
    )
    sc = StreamClusterer(cfg)
    sc.fit(src, max_batches=3)
    ckpt = str(tmp_path / "ck-wave")
    sc.save(ckpt)
    res = StreamClusterer.restore(ckpt).fit(src).finalize()
    ref = cluster(src, cfg.replace(wavefront=None, megabatch_k=None))
    np.testing.assert_array_equal(res.labels, ref.labels)
    assert res.info["wavefront_megabatches"] >= 1


def test_wavefront_requires_megabatch_k():
    with pytest.raises(ValueError, match="megabatch_k"):
        ClusterConfig(n=10, v_max=4, backend="pallas", wavefront=8)
    with pytest.raises(ValueError, match="wavefront"):
        ClusterConfig(
            n=10, v_max=4, backend="pallas", megabatch_k=2, wavefront=0
        )


def test_wavefront_knob_ignored_without_wavefront_fn():
    """Backends with a megabatch path but no wavefront path silently use
    sequential megabatch dispatch (mirrors the megabatch_k fallback rule)."""
    n, m = 400, 1500
    src = _source(n, m, seed=3)
    cfg = ClusterConfig(
        n=n, v_max=16, backend="chunked", chunk=128, batch_edges=256,
        megabatch_k=4, wavefront=8,
    )
    r = cluster(src, cfg)
    ref = cluster(src, cfg.replace(wavefront=None, megabatch_k=None))
    np.testing.assert_array_equal(r.labels, ref.labels)
    assert "wavefront_megabatches" not in r.info


def test_pipeline_stages_plans_and_releases_residency():
    """megabatches(wavefront=W) attaches a plan to every staged buffer and
    charges its bytes; after consumption the in-flight account drains to
    zero (no leaked plan residency)."""
    n, m, B, K, W = 200, 4000, 256, 4, 8
    rng = np.random.default_rng(0)
    edges = rng.integers(0, n, (m, 2)).astype(np.int32)
    pipe = BatchPipeline(ArraySource(edges), B)
    seen = 0
    for mb in pipe.megabatches(K, wavefront=W):
        assert mb.plan is not None
        assert mb.plan.waves.shape[1] == W
        seen += mb.n_rows
        # plan bytes are part of the residency account while staged
        assert pipe.peak_buffer_bytes >= mb.edges.nbytes + mb.plan.nbytes
    assert seen == m
    assert pipe._inflight_bytes == 0
    with pytest.raises(ValueError, match="wavefront"):
        next(iter(BatchPipeline(ArraySource(edges), B).megabatches(
            K, wavefront=0)))


# ---------------------------------------------------------------------------
# Dead-gap run merging (plan_waves(gap=...), ClusterConfig.wavefront_gap)
# ---------------------------------------------------------------------------

def test_gap_mode_waves_hold_only_live_rows_in_order():
    edges = _adversarial_stream(23, 300, seed=11, m_pad=320)
    flat = edges.reshape(-1, 2)
    live = ((flat[:, 0] != PAD) & (flat[:, 1] != PAD)
            & (flat[:, 0] != flat[:, 1]))
    for gap in (0, 2, 7):
        plan = plan_waves(edges, 16, gap=gap)
        staged = [plan.waves[t, : plan.counts[t]]
                  for t in range(plan.n_waves)]
        staged = (np.concatenate(staged) if staged
                  else np.zeros((0, 2), np.int32))
        # waves stage exactly the live prefix, in stream order, no dead rows
        np.testing.assert_array_equal(
            staged, flat[live][: plan.rows_in_waves]
        )
        # covered stream prefix = live staged + interior dead skipped
        start = plan.rows_in_waves + plan.dead_rows_skipped
        np.testing.assert_array_equal(
            plan.leftover[: plan.leftover_rows],
            flat[start : start + plan.leftover_rows],
        )
        for t in range(plan.n_waves):
            rows = plan.waves[t, : plan.counts[t]]
            assert np.all((rows[:, 0] != PAD) & (rows[:, 1] != PAD)
                          & (rows[:, 0] != rows[:, 1])), (gap, t)
            ends = rows.ravel()
            assert len(np.unique(ends)) == ends.size, (gap, t)


@pytest.mark.parametrize("gap", [0, 1, 4])
@pytest.mark.parametrize("seed", range(3))
def test_gap_mode_bit_identical_to_oracle(seed, gap):
    n, v_max = 29, 5
    edges = _adversarial_stream(n, 120, seed=seed, m_pad=_M)
    plan = plan_waves(edges, _W, gap=gap)
    ref = dense_update(ClusterState.init(n, numpy=True), edges, v_max)
    state, _ = wavefront_update_megabatch(
        ClusterState.init(n).to_device(),
        jnp.asarray(plan.waves),
        jnp.asarray(plan.leftover),
        jnp.asarray(plan.meta),
        v_max,
    )
    got = state.to_numpy()
    np.testing.assert_array_equal(got.c, ref.c)
    np.testing.assert_array_equal(got.d, ref.d)
    np.testing.assert_array_equal(got.v, ref.v)


def test_gap_mode_improves_occupancy_on_dead_interleaved_stream():
    # node-disjoint live edges with 2/3 interior dead rows: historical
    # waves are width-bound by dead filler, gap mode packs live rows
    m, n = 2048, 8192
    edges = np.stack(
        [2 * np.arange(m) % n, (2 * np.arange(m) + 1) % n], 1
    ).astype(np.int32)
    edges[np.arange(m) % 3 != 0] = PAD
    legacy = plan_waves(edges, 64)
    gp = plan_waves(edges, 64, gap=4)
    assert legacy.dead_rows_skipped == 0
    assert gp.dead_rows_skipped > 0
    assert gp.n_waves < legacy.n_waves / 2
    assert gp.leftover_rows == 0 == legacy.leftover_rows
    # a gap shorter than the dead runs closes waves instead of merging
    tight = plan_waves(edges, 64, gap=1)
    assert tight.n_waves > gp.n_waves


def test_gap_default_preserves_historical_plans():
    edges = _adversarial_stream(31, 200, seed=13, m_pad=256)
    a = plan_waves(edges, 8)
    b = plan_waves(edges, 8, gap=None)
    np.testing.assert_array_equal(a.waves, b.waves)
    np.testing.assert_array_equal(a.counts, b.counts)
    np.testing.assert_array_equal(a.leftover, b.leftover)
    assert a.dead_rows_skipped == 0 == b.dead_rows_skipped


def test_wavefront_gap_fit_bit_identical_with_counter():
    n, m = 150, 1200
    src = _source(n, m, seed=17)
    base_cfg = ClusterConfig(
        n=n, v_max=12, backend="pallas", chunk=64, batch_edges=128,
        megabatch_k=4, wavefront=16,
    )
    ref = cluster(src, base_cfg)
    gapped = cluster(src, base_cfg.replace(wavefront_gap=8))
    np.testing.assert_array_equal(gapped.labels, ref.labels)
    assert "wavefront_dead_rows_skipped" in gapped.info
    # the m->KB-padded ragged tail guarantees interior dead rows to skip
    assert gapped.info["wavefront_dead_rows_skipped"] >= 0
    with pytest.raises(ValueError, match="wavefront_gap"):
        ClusterConfig(n=n, v_max=4, backend="pallas", megabatch_k=2,
                      wavefront=8, wavefront_gap=-1)
    with pytest.raises(ValueError, match="wavefront"):
        ClusterConfig(n=n, v_max=4, backend="pallas", wavefront_gap=4)


# ---------------------------------------------------------------------------
# Adaptive width (wavefront="auto")
# ---------------------------------------------------------------------------

def test_auto_width_plans_are_sound_and_pow2():
    from repro.graph.wavefront import _AUTO_WIDTH_MAX, _AUTO_WIDTH_MIN

    rng = np.random.default_rng(7)
    edges = rng.integers(0, 200, (2048, 2)).astype(np.int32)
    plan = plan_waves(edges, "auto")
    assert _AUTO_WIDTH_MIN <= plan.width <= _AUTO_WIDTH_MAX
    assert plan.width & (plan.width - 1) == 0  # power of two
    # an auto plan is just a fixed-W plan at the chosen width
    fixed = plan_waves(edges, int(plan.width))
    np.testing.assert_array_equal(plan.waves, fixed.waves)
    np.testing.assert_array_equal(plan.counts, fixed.counts)
    np.testing.assert_array_equal(plan.leftover, fixed.leftover)
    with pytest.raises(ValueError, match="auto"):
        plan_waves(edges, "adaptive")


def test_auto_width_fit_bit_identical_with_widths_counter():
    n, m, B, K = 600, 4000, 256, 4
    src = _source(n, m, seed=23)
    cfg = ClusterConfig(
        n=n, v_max=24, backend="pallas", chunk=128, batch_edges=B,
        megabatch_k=K, wavefront="auto",
    )
    r_auto = cluster(src, cfg)
    ref = cluster(src, cfg.replace(wavefront=None, megabatch_k=None))
    np.testing.assert_array_equal(r_auto.labels, ref.labels)
    widths = r_auto.info["wavefront_widths"]
    assert len(widths) == r_auto.info["wavefront_megabatches"]
    assert all(w & (w - 1) == 0 and w >= 8 for w in widths)
    # the JSON config round-trip keeps the sentinel
    assert ClusterConfig.from_json(cfg.to_json()).wavefront == "auto"


def test_fixed_width_plans_unchanged_by_auto_support():
    """The historical fixed-W entry point must produce byte-identical plans
    (auto support only adds a string-typed branch before width is known)."""
    rng = np.random.default_rng(11)
    edges = rng.integers(0, 50, (512, 2)).astype(np.int32)
    plan = plan_waves(edges, 8)
    assert plan.width == 8
    assert plan.waves.shape[1] == 8
    recon = [plan.waves[t, : plan.counts[t]] for t in range(plan.meta[0])]
    recon.append(plan.leftover[: plan.meta[1]])
    np.testing.assert_array_equal(np.concatenate(recon), edges)
