"""Substrate tests: optimizer (+ int8 state), quantisation, gradient
compression with error feedback, schedules, data pipeline, checkpointing,
fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import TokenPipeline, make_pipeline
from repro.dist.compress import compress_grads, init_error_feedback
from repro.dist.fault_tolerance import HeartbeatMonitor, PreemptionHandler
from repro.optim.adamw import AdamW
from repro.optim.quant import dequantize_to, quantize
from repro.optim.schedule import cosine_schedule, wsd_schedule


# ---------------------------------------------------------------------------
# Quantisation
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       d=st.integers(min_value=1, max_value=300))
def test_quantize_roundtrip_error_bound(seed, d):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((4, d)) * rng.uniform(0.01, 100))
    deq = dequantize_to(quantize(x), d)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    # per-block symmetric int8: error <= scale/2 = max|block|/254
    blocks = np.asarray(x).reshape(4, -1)
    assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 + 1e-9


def test_quantized_adamw_tracks_fp32():
    """int8-moment AdamW stays close to fp32 AdamW on a quadratic."""
    def loss(p):
        return jnp.sum(jnp.square(p - 3.0))

    p32 = jnp.zeros((4, 256))
    p8 = jnp.zeros((4, 256))
    o32 = AdamW(weight_decay=0.0, clip_norm=0)
    o8 = AdamW(weight_decay=0.0, clip_norm=0, m_dtype="int8", v_dtype="int8")
    s32, s8 = o32.init(p32), o8.init(p8)
    for _ in range(60):
        g = jax.grad(loss)(p32)
        p32, s32, _ = o32.update(g, s32, p32, jnp.float32(0.05))
        g8 = jax.grad(loss)(p8)
        p8, s8, _ = o8.update(g8, s8, p8, jnp.float32(0.05))
    assert float(loss(p8)) < 0.1 * float(loss(jnp.zeros((4, 256))))
    assert float(jnp.abs(p8 - p32).max()) < 0.3


def test_grad_compression_error_feedback_unbiased():
    """With EF, the *accumulated* applied update converges to the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((8, 256)).astype(np.float32))
    params = {"w": jnp.zeros((8, 256))}
    ef = init_error_feedback(params)
    applied = jnp.zeros((8, 256))
    for _ in range(50):
        grads, ef = compress_grads({"w": g_true}, ef)
        applied = applied + grads["w"]
    # mean applied gradient ~= true gradient (residual is bounded)
    np.testing.assert_allclose(
        np.asarray(applied) / 50.0, np.asarray(g_true), atol=0.02
    )


def test_schedules():
    lr = wsd_schedule(1.0, warmup_steps=10, total_steps=100, decay_frac=0.2)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(50)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.0, abs=1e-6)
    lr2 = cosine_schedule(1.0, 5, 100)
    assert float(lr2(5)) == pytest.approx(1.0, rel=1e-2)
    assert float(lr2(100)) == pytest.approx(0.1, rel=1e-2)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_checkpointable():
    p1 = TokenPipeline(vocab_size=100, batch=4, seq_len=32, seed=7)
    batches = [next(p1) for _ in range(5)]
    # restore at step 3 and replay
    p2 = TokenPipeline(vocab_size=100, batch=4, seq_len=32, seed=7)
    p2.load_state_dict({"step": 3})
    replay = next(p2)
    np.testing.assert_array_equal(replay["tokens"], batches[3]["tokens"])


def test_pipeline_host_sharding_disjoint():
    a = TokenPipeline(vocab_size=100, batch=4, seq_len=16, seed=1, host_id=0)
    b = TokenPipeline(vocab_size=100, batch=4, seq_len=16, seed=1, host_id=1)
    assert not np.array_equal(next(a)["tokens"], next(b)["tokens"])


def test_pipeline_labels_shift():
    p = TokenPipeline(vocab_size=50, batch=2, seq_len=16, seed=0, noise=0.0)
    b = next(p)
    # labels are next-token: stride-affine chains must continue
    diffs = (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    assert diffs


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {
        "params": {"w": jnp.arange(12.0).reshape(3, 4)},
        "opt": {"m": jnp.ones((3, 4)), "count": jnp.int32(5)},
        "step": jnp.int32(7),
    }
    mgr.save(7, state)
    template = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored = mgr.restore(template)
    assert int(restored["step"]) == 7
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_atomicity_no_partial(tmp_path):
    """tmp dirs never count as checkpoints."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "tmp_step_9"))
    assert mgr.latest_step() is None


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_preemption_handler_flag():
    h = PreemptionHandler()
    assert not h.preempted
    h.request()
    assert h.preempted


def test_heartbeat_straggler_detection():
    import time

    mon = HeartbeatMonitor(window=10, straggler_factor=3.0)
    for i in range(6):
        mon.step_start()
        time.sleep(0.01)
        assert not mon.step_end(i)
    mon.step_start()
    time.sleep(0.12)
    assert mon.step_end(6)  # 12x median -> straggler
    assert mon.stragglers[0]["step"] == 6


def test_train_resume_bitexact(tmp_path):
    """Checkpoint/restore resumes the exact training trajectory."""
    from repro.configs.registry import get_smoke_config
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_smoke_config("qwen1.5-0.5b")
    opt = AdamW()
    step = jax.jit(make_train_step(cfg, opt, lambda s: jnp.float32(1e-3),
                                   ce_chunk=32))
    pipe = make_pipeline(cfg, 2, 32, seed=0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)

    mgr = CheckpointManager(str(tmp_path))
    # run 4 steps, checkpoint at 2
    states = []
    for i in range(4):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, _ = step(state, batch)
        if i == 1:
            mgr.save(2, {"state": state, "data": pipe.state_dict()})
        states.append(state)

    # restore and replay steps 2..3
    template = {"state": jax.tree.map(jnp.zeros_like, states[-1]),
                "data": pipe.state_dict()}
    restored = mgr.restore(template)
    pipe2 = make_pipeline(cfg, 2, 32, seed=0)
    pipe2.load_state_dict(restored["data"])
    st2 = restored["state"]
    for i in range(2):
        batch = {k: jnp.asarray(v) for k, v in next(pipe2).items()}
        st2, _ = step(st2, batch)
    a = jax.tree.leaves(states[-1]["params"])[0]
    b = jax.tree.leaves(st2["params"])[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
