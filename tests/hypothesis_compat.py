"""Optional-``hypothesis`` shim: property tests skip (instead of erroring at
collection) when the dependency is missing.

    from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

When hypothesis is installed this re-exports the real decorators; otherwise
``@given(...)`` marks the test skipped and ``st.*`` return inert placeholders,
so module import (and every non-property test in the module) still works.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: property tests skip, the rest of the module runs
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _AnyStrategy:
        def __call__(self, *args, **kwargs):
            return None

        def __getattr__(self, name):
            return _AnyStrategy()

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        def __getattr__(self, name):
            return _AnyStrategy()

    st = st()
