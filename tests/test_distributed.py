"""Multi-device integration tests.  Each test runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test process
keeps its single-device view (smoke tests must see 1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str, n_devices: int = 8, timeout: int = 600):
    script = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n_devices}'\n"
        + textwrap.dedent(body)
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=timeout,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        cwd=ROOT,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    return proc.stdout


def test_distributed_clustering_quality_multi_device():
    out = run_in_subprocess("""
        import jax, numpy as np
        from repro.core.distributed import distributed_cluster
        from repro.core.streaming import cluster_stream_dense, canonical_labels
        from repro.graph.generators import sbm_stream
        from repro.core.metrics import avg_f1, modularity

        mesh = jax.make_mesh((8,), ("data",))
        n = 2000
        edges, truth = sbm_stream(n, 100, avg_degree=12, p_intra=0.8, seed=5)
        c_seq, _, _ = cluster_stream_dense(edges, 48, n)
        f_seq = avg_f1(canonical_labels(c_seq), truth)
        c_dist, info = distributed_cluster(edges, 48, n, mesh=mesh, chunk=256)
        f_dist = avg_f1(canonical_labels(c_dist), truth)
        assert info["n_shards"] == 8
        assert f_dist > 0.6 * f_seq, (f_dist, f_seq)
        q = modularity(edges, c_dist)
        assert q > 0.15, q
        print("OK", f_seq, f_dist, q)
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """Loss on a (4, 2) mesh == loss on 1 device (same params/batch)."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import get_smoke_config
        from repro.dist.sharding import param_shardings, batch_sharding, sharding_context
        from repro.models.transformer import init_params
        from repro.optim.adamw import AdamW
        from repro.train.train_step import init_train_state, make_train_step

        cfg = get_smoke_config("qwen1.5-0.5b").replace(dtype="float32")
        opt = AdamW()
        lr = lambda s: jnp.float32(1e-3)
        step = make_train_step(cfg, opt, lr, ce_chunk=32)
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        key = jax.random.PRNGKey(1)
        batch = {
            "tokens": jax.random.randint(key, (8, 64), 0, cfg.vocab_size),
            "labels": jax.random.randint(key, (8, 64), 0, cfg.vocab_size),
        }
        # single device
        s1, m1 = jax.jit(step)(state, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh, sharding_context(mesh):
            pshard = param_shardings(
                jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg)), mesh
            )
            state2 = init_train_state(jax.random.PRNGKey(0), cfg, opt)
            sharded = jax.jit(step)
            s2, m2 = sharded(state2, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
        a = np.asarray(jax.tree.leaves(s1["params"])[0])
        b = np.asarray(jax.tree.leaves(s2["params"])[0])
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)
        print("OK", float(m1["loss"]), float(m2["loss"]))
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Save on a (4,2) mesh, restore onto (2,4) — values identical."""
    out = run_in_subprocess("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager

        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        x = jnp.arange(64.0 * 32).reshape(64, 32)
        xs = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save(1, {"x": xs})
        restored = mgr.restore(
            {"x": jnp.zeros((64, 32))},
            shardings={"x": NamedSharding(mesh_b, P("data", "model"))},
        )
        np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
        assert restored["x"].sharding.mesh.shape["model"] == 4
        print("OK")
    """)
    assert "OK" in out


def test_decode_step_sharded_cache():
    """Sharded decode (cache over dp/model) matches unsharded decode."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_smoke_config
        from repro.dist.sharding import cache_shardings, param_shardings, sharding_context
        from repro.models.transformer import init_params, make_cache, prefill, decode_step

        cfg = get_smoke_config("gemma3-1b").replace(dtype="float32", kv_dtype="float32")
        params = init_params(jax.random.PRNGKey(0), cfg)
        B, S = 8, 32
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
        _, cache = prefill(params, cfg, tokens[:, :S], cache_size=S + 4)
        want, _ = decode_step(params, cfg, cache, tokens[:, S:S+1], jnp.int32(S))

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with mesh, sharding_context(mesh):
            cshard = cache_shardings(jax.eval_shape(lambda: cache), mesh)
            cache_s = jax.device_put(cache, cshard)
            got, _ = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t, jnp.int32(S)))(
                params, cache_s, tokens[:, S:S+1]
            )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
        )
        print("OK")
    """)
    assert "OK" in out
