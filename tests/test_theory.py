"""Numerical verification of the paper's §3 analysis (Lemmas 1–2, Theorem 1).

All lemmas are identities over a finite prefix + partition, so they are
asserted to ~machine precision against brute-force recomputation of Q_t.
"""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import theory


def _instance(seed, n=24, t=120):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(t, 2))
    e[:, 1] = np.where(e[:, 0] == e[:, 1], (e[:, 1] + 1) % n, e[:, 1])
    labels = rng.integers(0, 5, size=n)
    w = 2.0 * (t + 60)  # full-stream weight (> prefix weight, as in the paper)
    return e, labels, w


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lemma1_is_exact(seed):
    """Q_{t+1} = Q_t + 2[δ - (Vol(C(i)) + Vol(C(j)) + 1 + δ)/w]."""
    e, labels, w = _instance(seed)
    rng = np.random.default_rng(seed + 1)
    i, j = rng.integers(0, len(labels), size=2)
    if i == j:
        j = (j + 1) % len(labels)
    q_t = theory.streaming_q(e, labels, w)
    e_t1 = np.concatenate([e, [[i, j]]], axis=0)
    q_t1 = theory.streaming_q(e_t1, labels, w)
    same = labels[i] == labels[j]
    vci = theory.vol_t(e, labels, int(labels[i]))
    vcj = theory.vol_t(e, labels, int(labels[j]))
    pred = q_t + theory.lemma1_increment(vci, vcj, bool(same), w)
    assert q_t1 == pytest.approx(pred, abs=1e-9)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_lemma2_is_exact(seed):
    """ΔQ_t for moving node i from C(i) to C(j) matches the L_t form."""
    e, labels, w = _instance(seed)
    rng = np.random.default_rng(seed + 2)
    i = int(rng.integers(0, len(labels)))
    dst_options = np.unique(labels[labels != labels[i]])
    if len(dst_options) == 0:
        return
    dst = int(rng.choice(dst_options))
    q_before = theory.streaming_q(e, labels, w)
    moved = labels.copy()
    moved[i] = dst
    q_after = theory.streaming_q(e, moved, w)
    pred = theory.lemma2_delta(e, labels, i, dst, w)
    assert q_after - q_before == pytest.approx(pred, abs=1e-9)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_appendix_c_closed_form(seed):
    """ΔQ_{t+1} closed form == brute force Q^(a) - Q^(c)."""
    e, labels, w = _instance(seed)
    rng = np.random.default_rng(seed + 3)
    i, j = rng.integers(0, len(labels), size=2)
    if i == j or labels[i] == labels[j]:
        return
    q_a, q_c = theory.brute_force_delta_q_t1(e, labels, int(i), int(j), w)
    pred = theory.delta_q_t1(e, labels, int(i), int(j), w)
    assert q_a - q_c == pytest.approx(pred, abs=1e-9)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_theorem1_sufficient_condition(seed):
    """Vol(C(i)) <= Vol(C(j)) <= v_t(i,j)  ⇒  ΔQ_{t+1} >= 0."""
    e, labels, w = _instance(seed)
    rng = np.random.default_rng(seed + 4)
    i, j = rng.integers(0, len(labels), size=2)
    if i == j or labels[i] == labels[j]:
        return
    vci = theory.vol_t(e, labels, int(labels[i]))
    vcj = theory.vol_t(e, labels, int(labels[j]))
    if vci > vcj:
        return  # theorem's precondition
    thr = theory.theorem1_threshold(e, labels, int(i), int(j), w)
    if vcj <= thr:
        q_a, q_c = theory.brute_force_delta_q_t1(e, labels, int(i), int(j), w)
        assert q_a - q_c >= -1e-9
