"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values (required deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import REGISTRY, SMOKE_REGISTRY, shapes_for
from repro.models.transformer import (
    count_params_analytic,
    decode_step,
    forward,
    init_params,
    make_cache,
    prefill,
    unembed,
)
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_schedule
from repro.train.train_step import init_train_state, make_train_step

ARCHS = list(SMOKE_REGISTRY)


def _enc_inputs(cfg, B, key):
    if cfg.encoder_layers:
        return jax.random.normal(key, (B, cfg.n_frames, cfg.d_model)).astype(
            cfg.dtype
        )
    if cfg.n_image_tokens:
        return jax.random.normal(
            key, (B, cfg.n_image_tokens, cfg.d_model)
        ).astype(cfg.dtype)
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = SMOKE_REGISTRY[arch]
    key = jax.random.PRNGKey(0)
    B, S = 2, 64
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    x, aux = forward(params, cfg, tokens, enc_inputs=_enc_inputs(cfg, B, key),
                     remat=False)
    assert x.shape == (B, S, cfg.d_model)
    logits = unembed(params, cfg, x)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = SMOKE_REGISTRY[arch]
    opt = AdamW()
    lr_fn = lambda s: jnp.float32(1e-3)  # constant: step 0 must move params
    step = jax.jit(make_train_step(cfg, opt, lr_fn, ce_chunk=32))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    key = jax.random.PRNGKey(1)
    B, S = 2, 64
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    enc = _enc_inputs(cfg, B, key)
    if enc is not None:
        batch["enc"] = enc
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    before = jax.tree.leaves(state["params"])[1]
    after = jax.tree.leaves(new_state["params"])[1]
    assert not np.array_equal(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Decode after prefill matches the train-mode forward at high precision
    (fp32 smoke config; MoE capacity relaxed to avoid drop differences)."""
    cfg = SMOKE_REGISTRY[arch].replace(
        dtype="float32", kv_dtype="float32", capacity_factor=16.0
    )
    key = jax.random.PRNGKey(0)
    B, S = 2, 32
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    enc = _enc_inputs(cfg, B, key)
    if enc is not None:
        enc = enc.astype(jnp.float32)
    x, _ = forward(params, cfg, tokens, enc_inputs=enc, remat=False)
    want = unembed(params, cfg, x)[:, -1]
    _, cache = prefill(params, cfg, tokens[:, :S], cache_size=S + 4,
                       enc_inputs=enc)
    got, _ = decode_step(params, cfg, cache, tokens[:, S:S + 1], jnp.int32(S))
    np.testing.assert_allclose(
        np.asarray(got[:, 0]), np.asarray(want), rtol=2e-3, atol=2e-4
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = REGISTRY[arch]
    expected = {
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expected
    # layer stack covers exactly n_layers blocks
    prefix, n_cycles, suffix = cfg.layer_stack
    assert len(prefix) + n_cycles * len(cfg.block_pattern) + len(suffix) == \
        cfg.n_layers


def test_param_counts_in_range():
    """Analytic totals land near the advertised model sizes."""
    expect = {
        "gemma3-1b": (0.9e9, 1.1e9),
        "llama3-405b": (395e9, 415e9),
        "qwen1.5-0.5b": (0.4e9, 0.52e9),
        "phi3-mini-3.8b": (3.6e9, 4.0e9),
        "recurrentgemma-2b": (2.5e9, 3.1e9),
        "mamba2-1.3b": (1.2e9, 1.45e9),
        "llama-3.2-vision-90b": (82e9, 92e9),
        "whisper-medium": (0.7e9, 1.05e9),
        "deepseek-v2-236b": (228e9, 244e9),
        "phi3.5-moe-42b-a6.6b": (40e9, 44e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params_analytic(REGISTRY[arch])
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    n_act = count_params_analytic(REGISTRY["deepseek-v2-236b"],
                                  active_only=True)
    assert 18e9 <= n_act <= 24e9  # ~21B active
    n_act2 = count_params_analytic(REGISTRY["phi3.5-moe-42b-a6.6b"],
                                   active_only=True)
    assert 5.5e9 <= n_act2 <= 7.5e9  # ~6.6B active


def test_long_context_shape_assignment():
    long_archs = {n for n, c in REGISTRY.items() if c.supports_long_context}
    assert long_archs == {"gemma3-1b", "recurrentgemma-2b", "mamba2-1.3b"}
    for name, cfg in REGISTRY.items():
        names = [s.name for s in shapes_for(cfg)]
        assert ("long_500k" in names) == (name in long_archs)
