"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.edge_decide.ops import edge_decide
from repro.kernels.edge_decide.ref import edge_decide_ref
from repro.kernels.edge_stream.ops import edge_stream_cluster
from repro.kernels.edge_stream.ref import edge_stream_ref
from repro.kernels.seg_volume.ops import seg_volume
from repro.kernels.seg_volume.ref import seg_volume_ref


def _stream(n, m, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2)).astype(np.int32)
    e[:, 1] = np.where(e[:, 0] == e[:, 1], (e[:, 1] + 1) % n, e[:, 1])
    return e


# ---------------------------------------------------------------------------
# edge_stream: bit-exact sequential clustering, shape sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(16, 40), (100, 700), (513, 3000)])
@pytest.mark.parametrize("chunk", [1, 64, 500])
@pytest.mark.parametrize("v_max", [1, 16, 512])
def test_edge_stream_kernel_bitexact(n, m, chunk, v_max):
    e = jnp.asarray(_stream(n, m, n + m))
    c_k, d_k, v_k = edge_stream_cluster(e, v_max, n, chunk=chunk)
    c_r, d_r, v_r = edge_stream_ref(e, v_max, n)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))


def test_edge_stream_kernel_handles_pad_rows():
    n = 32
    e = _stream(n, 50, 0)
    padded = np.concatenate([e, np.full((30, 2), -1, np.int32)])
    c_k, _, _ = edge_stream_cluster(jnp.asarray(padded), 8, n, chunk=16)
    c_r, _, _ = edge_stream_ref(jnp.asarray(e), 8, n)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


# ---------------------------------------------------------------------------
# edge_decide: decision stage, shape/dtype sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", [5, 128, 1000, 4096])
@pytest.mark.parametrize("v_max", [1, 100])
@pytest.mark.parametrize("block_rows", [8, 16])
def test_edge_decide_matches_ref(b, v_max, block_rows):
    rng = np.random.default_rng(b + v_max)
    vci = jnp.asarray(rng.integers(0, 200, b), jnp.int32)
    vcj = jnp.asarray(rng.integers(0, 200, b), jnp.int32)
    di = jnp.asarray(rng.integers(1, 50, b), jnp.int32)
    dj = jnp.asarray(rng.integers(1, 50, b), jnp.int32)
    live = jnp.asarray(rng.integers(0, 2, b), jnp.int32)
    a_k, m_k = edge_decide(vci, vcj, di, dj, live, v_max, block_rows=block_rows)
    a_r, m_r = edge_decide_ref(vci, vcj, di, dj, live, v_max)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))


# ---------------------------------------------------------------------------
# seg_volume: histogram-as-matmul, shape/dtype sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k", [(100, 17), (2048, 256), (5000, 1000)])
@pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_seg_volume_matches_ref(b, k, wdtype):
    rng = np.random.default_rng(b * k)
    labels = jnp.asarray(rng.integers(0, k, b), jnp.int32)
    if wdtype == jnp.int32:
        w = jnp.asarray(rng.integers(0, 10, b), wdtype)
    else:
        w = jnp.asarray(rng.random(b), wdtype)
    out_k = seg_volume(labels, w, k)
    out_r = seg_volume_ref(labels, w, k)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("block_b,block_k", [(128, 128), (512, 512)])
def test_seg_volume_block_shape_sweep(block_b, block_k):
    rng = np.random.default_rng(7)
    b, k = 3000, 700
    labels = jnp.asarray(rng.integers(0, k, b), jnp.int32)
    w = jnp.asarray(rng.random(b), jnp.float32)
    out_k = seg_volume(labels, w, k, block_b=block_b, block_k=block_k)
    out_r = seg_volume_ref(labels, w, k)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5
    )
