"""Tests for the unified ``repro.cluster`` API: backend registry,
cross-backend equivalence, partial_fit resumability, checkpoint suspend /
resume, and config validation."""

import numpy as np
import pytest

from repro.cluster import (
    ClusterConfig,
    ClusterState,
    StreamClusterer,
    available_backends,
    avg_f1,
    canonical_labels,
    cluster,
    get_backend,
    modularity,
)
from repro.graph.generators import sbm_stream

ALL_BACKENDS = (
    "chunked", "dense", "distributed", "multiparam", "oracle", "pallas", "scan",
)
SEQUENTIAL = ("oracle", "dense", "scan", "pallas")  # bit-exact, resumable
RESUMABLE = SEQUENTIAL + ("chunked",)


def _random_stream(n, m, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(m, 2)).astype(np.int32)
    e[:, 1] = np.where(e[:, 0] == e[:, 1], (e[:, 1] + 1) % n, e[:, 1])
    return e


def _cfg(backend, n=80, v_max=8, **kw):
    kw.setdefault("chunk", 64)
    return ClusterConfig(n=n, v_max=v_max, backend=backend, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_lists_all_seven_backends():
    assert available_backends() == ALL_BACKENDS


def test_backend_capabilities():
    """Resumable + out-of-core is the invariant: every registered tier
    threads a state pytree through partial_fit."""
    for name in ALL_BACKENDS:
        assert get_backend(name).resumable, name
    for name in SEQUENTIAL:
        assert get_backend(name).bit_exact, name
    assert not get_backend("chunked").bit_exact
    assert not get_backend("distributed").bit_exact
    assert get_backend("multiparam").bit_exact  # per sweep column
    # state-kind dispatch: the API layer no longer assumes ClusterState
    kinds = {name: get_backend(name).state_kind for name in ALL_BACKENDS}
    assert kinds["multiparam"] == "sweep"
    assert kinds["distributed"] == "sharded"
    assert all(kinds[b] == "cluster" for b in RESUMABLE)
    # labels of the wide-state tiers are derived at finalize time
    assert get_backend("multiparam").finalize_fn is not None
    assert get_backend("distributed").finalize_fn is not None
    with pytest.raises(KeyError):
        get_backend("nope")


# ---------------------------------------------------------------------------
# Config validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(n=0, v_max=4),
    dict(n=100, v_max=0),
    dict(n=100, v_max=None),
    dict(n=100, v_max=4, backend="does-not-exist"),
    dict(n=100, v_max=4, chunk=0),
    dict(n=100, backend="multiparam"),  # missing v_maxes
    dict(n=100, backend="multiparam", v_maxes=(4, 0)),
    dict(n=100, v_max=4, criterion="modularity"),  # not edge-free (paper §2.5)
    dict(n=100, v_max=4, n_shards=0),
])
def test_config_validation_rejects(bad):
    with pytest.raises(ValueError):
        ClusterConfig(**bad)


def test_config_json_roundtrip():
    cfg = ClusterConfig(n=50, backend="multiparam", v_maxes=(4, 8), chunk=32)
    assert ClusterConfig.from_json(cfg.to_json()) == cfg


# ---------------------------------------------------------------------------
# Cross-backend equivalence (acceptance: oracle == dense == scan bit-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v_max", [1, 3, 10, 100])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_sequential_backends_bitexact(v_max, seed):
    n, m = 60, 400
    edges = _random_stream(n, m, seed)
    results = {
        b: cluster(edges, _cfg(b, n=n, v_max=v_max)) for b in SEQUENTIAL
    }
    ref = results["dense"].labels
    for b in SEQUENTIAL:
        assert np.array_equal(results[b].labels, ref), b
        # edge-free metrics agree across label spaces
        assert results[b].entropy == pytest.approx(results["dense"].entropy)
        assert results[b].avg_density == pytest.approx(
            results["dense"].avg_density
        )


@pytest.mark.parametrize("backend", ["chunked", "distributed"])
def test_parallel_backends_quality_parity_on_sbm(backend):
    n = 2000
    edges, truth = sbm_stream(n, 100, avg_degree=12, p_intra=0.8, seed=1)
    v_max = 48
    seq = cluster(edges, ClusterConfig(n=n, v_max=v_max, backend="dense"))
    kw = dict(n_shards=4) if backend == "distributed" else {}
    par = cluster(
        edges, ClusterConfig(n=n, v_max=v_max, backend=backend, chunk=512, **kw)
    )
    q_seq = modularity(edges, seq.labels)
    q_par = modularity(edges, par.labels)
    assert abs(q_seq - q_par) < 0.08, (q_seq, q_par)
    f_seq = avg_f1(seq.labels, truth)
    f_par = avg_f1(par.labels, truth)
    assert f_par > 0.6 * f_seq, (f_seq, f_par)


def test_multiparam_backend_selected_state_matches_scan():
    n, m = 100, 600
    edges = _random_stream(n, m, 7)
    res = cluster(
        edges,
        ClusterConfig(n=n, backend="multiparam", v_maxes=(4, 16, 64)),
    )
    best_v = res.info["best_v_max"]
    direct = cluster(edges, ClusterConfig(n=n, v_max=best_v, backend="scan"))
    assert np.array_equal(res.labels, direct.labels)
    assert len(res.info["rows"]) == 3


# ---------------------------------------------------------------------------
# Incremental ingestion (acceptance: partial_fit == one-shot, sequential)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", SEQUENTIAL)
@pytest.mark.parametrize("n_batches", [3])
def test_partial_fit_matches_one_shot(backend, n_batches):
    n, m = 80, 500
    edges = _random_stream(n, m, 11)
    one_shot = cluster(edges, _cfg(backend, n=n))
    sc = StreamClusterer(_cfg(backend, n=n))
    for batch in np.array_split(edges, n_batches):
        assert sc.partial_fit(batch) is sc
    res = sc.finalize()
    assert np.array_equal(res.labels, one_shot.labels)
    assert sc.edges_seen == m
    assert int(np.asarray(res.state.d).sum()) == 2 * m
    assert int(np.asarray(res.state.v).sum()) == 2 * m


def test_partial_fit_chunked_deterministic_and_valid():
    """Chunked partial_fit: batch boundaries are chunk boundaries, so labels
    are batching-dependent — but deterministic and a valid partition."""
    n, m = 100, 700
    edges = _random_stream(n, m, 13)

    def run():
        sc = StreamClusterer(_cfg("chunked", n=n))
        for batch in np.array_split(edges, 4):
            sc.partial_fit(batch)
        return sc.finalize()

    a, b = run(), run()
    assert np.array_equal(a.labels, b.labels)
    assert int(np.asarray(a.state.d).sum()) == 2 * m
    assert int(np.asarray(a.state.v).sum()) == 2 * m


def test_multiparam_partial_fit_matches_one_shot():
    """The sweep is a partial_fit backend now: k batches through the wider
    SweepState produce labels bit-identical to the one-shot call."""
    n, m = 80, 500
    edges = _random_stream(n, m, 11)
    cfg = ClusterConfig(n=n, backend="multiparam", v_maxes=(4, 16, 64))
    one_shot = cluster(edges, cfg)
    sc = StreamClusterer(cfg)
    for batch in np.array_split(edges, 5):
        sc.partial_fit(batch)
    res = sc.finalize()
    assert np.array_equal(res.labels, one_shot.labels)
    assert res.info["best_v_max"] == one_shot.info["best_v_max"]
    assert sc.edges_seen == m
    # finalize does not consume the sweep: the clusterer still threads the
    # wide state while the result carries the selected ClusterState view
    assert sc.state.c.ndim == 2 and res.state.c.ndim == 1


def test_distributed_partial_fit_deals_batches_onto_shards():
    n, m = 100, 800
    edges = _random_stream(n, m, 41)
    cfg = ClusterConfig(
        n=n, v_max=8, backend="distributed", n_shards=4, chunk=32
    )
    sc = StreamClusterer(cfg)
    for batch in np.array_split(edges, 4):
        sc.partial_fit(batch)
    res = sc.finalize()
    assert int(sc.state.cursor) == 4
    # every shard ingested one batch
    assert (np.asarray(sc.state.d).sum(axis=1) > 0).all()
    assert sc.edges_seen == m
    # the merged state makes edge-free metrics available for this tier
    assert res.state is not None and res.entropy is not None
    assert int(np.asarray(res.state.d).sum()) == 2 * m


def test_sweep_state_rejects_mismatched_v_maxes():
    """A carried/restored sweep state must match config.v_maxes — resuming
    under different parameters would silently corrupt the sweep."""
    cfg = ClusterConfig(n=20, backend="multiparam", v_maxes=(2, 4))
    sc = StreamClusterer(cfg)
    sc.partial_fit(_random_stream(20, 50, 43))
    with pytest.raises(ValueError, match="v_maxes"):
        cluster(
            _random_stream(20, 10, 44),
            ClusterConfig(n=20, backend="multiparam", v_maxes=(2, 8)),
            state=sc.state,
        )


def test_sharded_state_rejects_mismatched_shard_count():
    cfg = ClusterConfig(n=20, v_max=4, backend="distributed", n_shards=2)
    sc = StreamClusterer(cfg)
    sc.partial_fit(_random_stream(20, 50, 45))
    with pytest.raises(ValueError, match="n_shards"):
        cluster(
            _random_stream(20, 10, 46),
            cfg.replace(n_shards=3),
            state=sc.state,
        )


def test_finalize_before_any_batch_is_all_singletons():
    sc = StreamClusterer(_cfg("dense", n=25))
    res = sc.finalize()
    assert res.community_stats["n_communities"] == 25
    assert sc.edges_seen == 0


# ---------------------------------------------------------------------------
# Suspend / resume across "sessions" (checkpoint.manager integration)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["dense", "scan", "oracle"])
def test_save_restore_resumes_exactly(tmp_path, backend):
    n, m = 60, 400
    edges = _random_stream(n, m, 17)
    one_shot = cluster(edges, _cfg(backend, n=n))

    batches = np.array_split(edges, 3)
    sc = StreamClusterer(_cfg(backend, n=n))
    sc.partial_fit(batches[0])
    sc.save(str(tmp_path))

    sc2 = StreamClusterer.restore(str(tmp_path))  # fresh "session"
    assert sc2.config == sc.config
    assert sc2.edges_seen == sc.edges_seen
    for batch in batches[1:]:
        sc2.partial_fit(batch)
    assert np.array_equal(sc2.finalize().labels, one_shot.labels)


def test_restore_with_config_override(tmp_path):
    edges = _random_stream(40, 200, 19)
    sc = StreamClusterer(_cfg("dense", n=40))
    sc.partial_fit(edges)
    sc.save(str(tmp_path))
    # dense state is the layout every dense-space backend shares — resume the
    # same run on the scan tier
    sc2 = StreamClusterer.restore(
        str(tmp_path), config=_cfg("scan", n=40)
    )
    assert sc2.edges_seen == 200
    assert np.array_equal(
        np.asarray(sc2.state.c), np.asarray(sc.state.c)
    )


# ---------------------------------------------------------------------------
# Clustering result object
# ---------------------------------------------------------------------------

def test_clustering_bundles_edge_free_metrics():
    n = 60
    edges, _ = sbm_stream(n, 6, avg_degree=8, p_intra=0.9, seed=3)
    res = cluster(edges, ClusterConfig(n=n, v_max=16, backend="dense"))
    assert res.entropy is not None and res.entropy >= 0.0
    assert res.avg_density is not None and res.avg_density >= 0.0
    stats = res.community_stats
    assert stats["n_communities"] == res.n_communities >= 1
    assert isinstance(res.labels, np.ndarray)
    assert res.labels.min() == 0
    # canonical: labels are comparable across backends without relabeling
    assert np.array_equal(res.labels, canonical_labels(res.labels))


def test_cluster_state_counts_edges_and_ignores_pad():
    edges = _random_stream(30, 100, 23)
    padded = np.concatenate([edges, np.full((37, 2), -1, np.int32)])
    res = cluster(padded, ClusterConfig(n=30, v_max=6, backend="scan"))
    assert int(res.state.edges_seen) == 100
    ref = cluster(edges, ClusterConfig(n=30, v_max=6, backend="scan"))
    assert np.array_equal(res.labels, ref.labels)


def test_restore_rejects_cross_label_space_override(tmp_path):
    """An oracle checkpoint read as dense state would silently mislabel."""
    sc = StreamClusterer(_cfg("oracle", n=40))
    sc.partial_fit(_random_stream(40, 100, 37))
    sc.save(str(tmp_path))
    with pytest.raises(ValueError, match="label space"):
        StreamClusterer.restore(str(tmp_path), config=_cfg("scan", n=40))
    # same-space override (dense family) is fine
    sc2 = StreamClusterer(_cfg("dense", n=40))
    sc2.partial_fit(_random_stream(40, 100, 37))
    sc2.save(str(tmp_path))
    assert StreamClusterer.restore(
        str(tmp_path), config=_cfg("pallas", n=40)
    ).edges_seen == 100


def test_restore_rejects_cross_state_kind_override(tmp_path):
    """A sweep checkpoint is a wider pytree — restoring it as a 3n-int
    backend (or vice versa) is rejected by the state-kind check."""
    sc = StreamClusterer(ClusterConfig(n=30, backend="multiparam", v_maxes=(4, 8)))
    sc.partial_fit(_random_stream(30, 100, 53))
    sc.save(str(tmp_path))
    with pytest.raises(ValueError, match="state kind"):
        StreamClusterer.restore(str(tmp_path), config=_cfg("scan", n=30))
    # same-kind restore round-trips the full sweep
    sc2 = StreamClusterer.restore(str(tmp_path))
    assert sc2.edges_seen == sc.edges_seen
    assert np.array_equal(np.asarray(sc2.state.c), np.asarray(sc.state.c))
    assert np.array_equal(np.asarray(sc2.state.v_maxes), [4, 8])


def test_carried_state_must_match_config_n(tmp_path):
    """A state restored/carried into a different node-id space is rejected
    (out-of-range ids would be silently dropped by device scatters)."""
    sc = StreamClusterer(_cfg("scan", n=40))
    sc.partial_fit(_random_stream(40, 100, 29))
    sc.save(str(tmp_path))
    with pytest.raises(ValueError, match="n="):
        StreamClusterer.restore(str(tmp_path), config=_cfg("scan", n=99))
    with pytest.raises(ValueError, match="n="):
        cluster(
            _random_stream(40, 10, 31), _cfg("dense", n=99), state=sc.state
        )


def test_state_init_shapes():
    s = ClusterState.init(17)
    assert s.n == 17
    assert s.d.shape == s.c.shape == s.v.shape == (17,)
    assert int(s.edges_seen) == 0
