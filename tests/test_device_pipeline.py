"""Device-resident streaming engine: fused megabatch dispatch (DESIGN.md §10).

Covers the device-pipelining contract end to end:

* megabatch staging (`BatchPipeline.megabatches`) reproduces per-batch
  boundaries exactly — a megabatch is the concatenation of the next K
  batches, ragged tails padded with all-PAD no-op batches;
* the fused device paths — `chunked_update_megabatch` (one `lax.scan` over
  all chunks) and `pallas_update_megabatch` (double-buffered-DMA kernel) —
  are bit-identical to K sequential per-batch updates, across K, batch
  size, and stream length (hypothesis property + deterministic grid);
* `cluster`/`fit` in megabatch mode produce bit-identical labels with
  ~K-fold fewer device dispatches, and checkpoint suspend/resume at a
  megabatch-interior batch cursor restores to identical labels;
* the prefetch worker propagates producer exceptions (and is joined) and
  `pad_batch` fills from the shared PAD template without per-batch
  template reallocation.
"""

import sys
import threading
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from hypothesis_compat import given, settings, st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.cluster import (  # noqa: E402
    ClusterConfig,
    GeneratorSource,
    StreamClusterer,
    cluster,
)
from repro.core.chunked import chunked_update, chunked_update_megabatch  # noqa: E402
from repro.core.state import ClusterState  # noqa: E402
from repro.core.streaming import dense_update  # noqa: E402
from repro.graph.generators import chung_lu_segments  # noqa: E402
from repro.graph.pipeline import (  # noqa: E402
    PAD,
    BatchPipeline,
    pad_batch,
    pad_template_allocs,
)
from repro.graph.sources import ArraySource  # noqa: E402
from repro.kernels.edge_stream.ops import (  # noqa: E402
    pallas_update,
    pallas_update_megabatch,
)


def _edges(n, m, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, (m, 2)).astype(np.int32)
    return e


def _stack_megabatch(edges, k, batch_edges):
    """Reference staging: K PAD-padded batches stacked (ragged tail ok)."""
    mb = np.full((k, batch_edges, 2), PAD, np.int32)
    rows = 0
    for b in range(k):
        raw = edges[b * batch_edges : (b + 1) * batch_edges]
        mb[b, : raw.shape[0]] = raw
        rows += raw.shape[0]
    return mb, rows


# ---------------------------------------------------------------------------
# Pipeline staging
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 3, 4])
@pytest.mark.parametrize("m", [0, 40, 256, 1000, 1023])
def test_megabatches_are_stacked_batches(k, m):
    """A megabatch is exactly the next K per-batch results, PAD-padded."""
    edges = _edges(97, m, seed=m + k)
    B = 64
    per = list(BatchPipeline(ArraySource(edges), B).batches())
    megas = list(BatchPipeline(ArraySource(edges), B).megabatches(k))
    assert len(megas) == -(-len(per) // k)
    idx = 0
    for mega in megas:
        assert mega.edges.shape == (k, B, 2)
        assert mega.offset == (per[idx].offset if per else 0)
        for b in range(mega.n_batches):
            np.testing.assert_array_equal(mega.edges[b], per[idx].edges)
            idx += 1
        # padding batches of a ragged tail are all-PAD no-ops
        assert (mega.edges[mega.n_batches :] == PAD).all()
    assert idx == len(per)
    assert sum(mb.n_rows for mb in megas) == m


def test_megabatch_residency_counts_staging_buffer():
    """peak_buffer_bytes sees the (K, B, 2) staging buffers."""
    edges = _edges(97, 4096, seed=0)
    B, K = 256, 4
    pipe = BatchPipeline(ArraySource(edges), B, prefetch=1)
    for _ in pipe.megabatches(K):
        pass
    assert pipe.peak_buffer_bytes >= K * B * 2 * 4
    assert pipe.megabatches_produced == 4
    assert pipe.batches_produced == 16


def test_megabatch_k_validation():
    pipe = BatchPipeline(ArraySource(_edges(7, 8, 1)), 4)
    with pytest.raises(ValueError, match="megabatch k"):
        next(pipe.megabatches(0))


# ---------------------------------------------------------------------------
# Fused device paths ≡ per-batch (direct tier calls)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 100, 192, 250])
@pytest.mark.parametrize("k", [1, 2, 5])
def test_chunked_megabatch_matches_sequential(k, m):
    n, chunk, B = 150, 16, 64
    edges = _edges(n, m, seed=m * 7 + k)
    seq = ClusterState.init(n)
    for b in range(-(-m // B) if m else 1):
        raw = edges[b * B : (b + 1) * B]
        seq = chunked_update(
            seq, jnp.asarray(pad_batch(raw, B)), jnp.int32(9), chunk=chunk
        )
    n_batches = max(1, -(-m // B))
    # stack everything into ceil(n_batches / k) megabatches of k batches
    fused = ClusterState.init(n)
    done = 0
    while done < n_batches:
        mb, _ = _stack_megabatch(edges[done * B :], k, B)
        fused = chunked_update_megabatch(
            fused, jnp.asarray(mb), jnp.int32(9), chunk=chunk
        )
        done += k
    for leaf in ("d", "c", "v"):
        np.testing.assert_array_equal(
            np.asarray(getattr(seq, leaf)), np.asarray(getattr(fused, leaf))
        )
    assert int(seq.edges_seen) == int(fused.edges_seen)


@pytest.mark.parametrize("m", [1, 100, 192])
@pytest.mark.parametrize("k", [1, 3])
def test_pallas_megabatch_bit_exact_with_dense(k, m):
    """The double-buffered-DMA kernel preserves strict stream order: its
    result equals the numpy-sequential dense oracle (and the per-batch
    grid kernel) for any K / batch size / ragged tail."""
    n, chunk, B = 120, 8, 32
    edges = _edges(n, m, seed=m * 3 + k)
    ref = dense_update(ClusterState.init(n, numpy=True), edges, 7)

    per = ClusterState.init(n)
    for b in range(max(1, -(-m // B))):
        raw = edges[b * B : (b + 1) * B]
        per = pallas_update(
            per, jnp.asarray(pad_batch(raw, B)), 7, chunk=chunk, interpret=True
        )

    fused = ClusterState.init(n)
    n_batches = max(1, -(-m // B))
    done = 0
    while done < n_batches:
        mb, _ = _stack_megabatch(edges[done * B :], k, B)
        fused = pallas_update_megabatch(
            fused, jnp.asarray(mb), 7, chunk=chunk, interpret=True
        )
        done += k
    for leaf in ("d", "c", "v"):
        np.testing.assert_array_equal(
            getattr(ref, leaf), np.asarray(getattr(fused, leaf))
        )
        np.testing.assert_array_equal(
            np.asarray(getattr(per, leaf)), np.asarray(getattr(fused, leaf))
        )


# ---------------------------------------------------------------------------
# API: megabatch fit ≡ per-batch fit (labels bit-identical, fewer dispatches)
# ---------------------------------------------------------------------------

def _source(n, m, seed, segment=700):
    return GeneratorSource(
        chung_lu_segments(n, seed=seed), m, segment_edges=segment
    )


@pytest.mark.parametrize("backend", ["chunked", "pallas"])
@pytest.mark.parametrize("k,batch_edges,m", [
    (2, 256, 5000),    # many full megabatches + ragged tail
    (4, 512, 2048),    # exactly one megabatch
    (3, 256, 200),     # stream shorter than one batch
    (5, 256, 4 * 256), # ragged megabatch tail, full batches
])
def test_megabatch_fit_labels_bit_identical(backend, k, batch_edges, m):
    n = 1200
    src = _source(n, m, seed=k + m)
    cfg = ClusterConfig(
        n=n, v_max=24, backend=backend, chunk=128, batch_edges=batch_edges
    )
    r_per = cluster(src, cfg)
    r_mega = cluster(src, cfg.replace(megabatch_k=k))
    np.testing.assert_array_equal(r_per.labels, r_mega.labels)
    # ~K-fold dispatch amortisation, exactly: ceil(batches / K) dispatches
    batches = r_mega.info["stream_batches"]
    assert r_mega.info["stream_dispatches"] == -(-batches // k)
    assert r_mega.info["stream_megabatches"] == -(-batches // k)
    assert r_per.info["stream_dispatches"] == r_per.info["stream_batches"]


@pytest.mark.parametrize("k,batch_edges,m", [
    (2, 256, 5000),    # many full megabatches + ragged tail
    (4, 512, 2048),    # exactly one megabatch
    (3, 256, 200),     # stream shorter than one batch
    (2, 256, 0),       # empty stream
])
def test_wavefront_megabatch_fit_labels_bit_identical(k, batch_edges, m):
    """Wavefront mode (DESIGN.md §12) on the same acceptance grid: planned
    node-disjoint waves + runtime fallback never change labels."""
    n = 1200
    src = _source(n, m, seed=k + m)
    cfg = ClusterConfig(
        n=n, v_max=24, backend="pallas", chunk=128, batch_edges=batch_edges,
        megabatch_k=k,
    )
    r_per = cluster(src, cfg.replace(megabatch_k=None))
    r_wave = cluster(src, cfg.replace(wavefront=8))
    np.testing.assert_array_equal(r_per.labels, r_wave.labels)
    if m:
        assert r_wave.info["wavefront_megabatches"] >= 1


@settings(max_examples=10, deadline=None)
@given(
    k=st.integers(min_value=1, max_value=5),
    b_chunks=st.integers(min_value=1, max_value=3),
    m=st.integers(min_value=1, max_value=3000),
    backend=st.sampled_from(["chunked", "pallas"]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_megabatch_fit_labels_bit_identical_property(
    k, b_chunks, m, backend, seed
):
    """Hypothesis sweep over K, batch size, and stream length (ragged tails
    included): megabatch mode never changes labels on either fused tier."""
    n = 500
    chunk = 64
    src = _source(n, m, seed=seed, segment=311)
    cfg = ClusterConfig(
        n=n, v_max=16, backend=backend, chunk=chunk,
        batch_edges=b_chunks * chunk,
    )
    r_per = cluster(src, cfg)
    r_mega = cluster(src, cfg.replace(megabatch_k=k))
    np.testing.assert_array_equal(r_per.labels, r_mega.labels)


def test_megabatch_config_ignored_without_fused_path():
    """Backends without a megabatch_fn silently use per-batch dispatch."""
    n, m = 400, 1500
    src = _source(n, m, seed=3)
    cfg = ClusterConfig(
        n=n, v_max=16, backend="scan", batch_edges=256, megabatch_k=4
    )
    r = cluster(src, cfg)
    ref = cluster(src, cfg.replace(megabatch_k=None))
    np.testing.assert_array_equal(r.labels, ref.labels)
    assert "stream_megabatches" not in r.info


def test_partial_fit_megabatch_requires_fused_backend():
    sc = StreamClusterer(ClusterConfig(n=10, v_max=4, backend="scan"))
    with pytest.raises(ValueError, match="no fused megabatch path"):
        sc.partial_fit_megabatch(np.zeros((2, 4, 2), np.int32))


def test_config_validation():
    with pytest.raises(ValueError, match="megabatch_k"):
        ClusterConfig(n=10, v_max=4, megabatch_k=0)
    with pytest.raises(ValueError, match="prefetch"):
        ClusterConfig(n=10, v_max=4, prefetch=-1)


# ---------------------------------------------------------------------------
# Checkpoint: suspend/resume at megabatch-interior batch cursors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["chunked", "pallas"])
@pytest.mark.parametrize("stop_after", [1, 3, 5])
def test_checkpoint_resume_at_megabatch_interior_cursor(
    tmp_path, backend, stop_after
):
    """Suspend at a batch boundary that is *interior* to a megabatch (per-
    batch ingest for j batches, j not a multiple of K), restore in a new
    clusterer, finish in megabatch mode — labels identical to both the
    uninterrupted megabatch run and the per-batch run."""
    n, m, B, K = 900, 6000, 256, 4
    src = _source(n, m, seed=11)
    cfg = ClusterConfig(
        n=n, v_max=24, backend=backend, chunk=128, batch_edges=B,
        megabatch_k=K,
    )

    sc = StreamClusterer(cfg)
    sc.fit(src, max_batches=stop_after)  # < K: per-batch suspend point
    assert sc.stream_offset == stop_after * B
    ckpt = str(tmp_path / f"ck-{backend}-{stop_after}")
    sc.save(ckpt)

    sc2 = StreamClusterer.restore(ckpt)
    assert sc2.stream_offset == stop_after * B
    res = sc2.fit(src).finalize()

    ref_mega = cluster(src, cfg)
    ref_per = cluster(src, cfg.replace(megabatch_k=None))
    np.testing.assert_array_equal(res.labels, ref_mega.labels)
    np.testing.assert_array_equal(res.labels, ref_per.labels)


def test_megabatch_fit_max_batches_budget_exact(tmp_path):
    """A max_batches budget that is not a megabatch multiple drains the
    remainder per-batch and the cursor lands on the exact batch row."""
    n, m, B, K = 600, 4000, 256, 3
    src = _source(n, m, seed=19)
    cfg = ClusterConfig(
        n=n, v_max=16, backend="chunked", chunk=128, batch_edges=B,
        megabatch_k=K,
    )
    sc = StreamClusterer(cfg)
    sc.fit(src, max_batches=7)  # 2 megabatches + 1 per-batch remainder
    assert sc.stream_batches == 7
    assert sc.stream_offset == 7 * B
    assert sc.stream_megabatches == 2
    assert sc.stream_dispatches == 3
    ckpt = str(tmp_path / "ck-budget")
    sc.save(ckpt)
    res = StreamClusterer.restore(ckpt).fit(src).finalize()
    ref = cluster(src, cfg.replace(megabatch_k=None))
    np.testing.assert_array_equal(res.labels, ref.labels)


# ---------------------------------------------------------------------------
# Prefetch worker failure path + PAD template
# ---------------------------------------------------------------------------

class _Boom(RuntimeError):
    pass


def _exploding_segments(fail_at_row):
    def segment(start, length):
        if start + length > fail_at_row:
            raise _Boom(f"decode failed at row {start}")
        return np.full((length, 2), 1, np.int32)

    return segment


@pytest.mark.parametrize("mega", [False, True])
# 900: fails while stacking the *first* batch of a megabatch; 1100: fails
# interior to a megabatch, after its staging buffer is already acquired
@pytest.mark.parametrize("fail_at", [900, 1100])
def test_prefetch_propagates_producer_exception_and_joins(mega, fail_at):
    """A decode error mid-stream surfaces as-is on the consumer and the
    prefetch worker thread is joined — no dangling producer."""
    src = GeneratorSource(
        _exploding_segments(fail_at), 10_000, segment_edges=128
    )
    pipe = BatchPipeline(src, 256, prefetch=2)
    threads_before = threading.active_count()
    it = pipe.megabatches(3) if mega else pipe.batches()
    consumed = 0
    with pytest.raises(_Boom, match="decode failed"):
        for _ in it:
            consumed += 1
    assert consumed >= 1  # rows before the failure were delivered
    # the worker is joined before the exception reaches the consumer
    assert threading.active_count() <= threads_before
    # residency accounting unwound (nothing left acquired)
    assert pipe._inflight_bytes == 0


def test_fit_surfaces_producer_exception():
    src = GeneratorSource(_exploding_segments(600), 5_000, segment_edges=128)
    cfg = ClusterConfig(
        n=50, v_max=8, backend="chunked", chunk=64, batch_edges=128,
        megabatch_k=2,
    )
    with pytest.raises(_Boom):
        StreamClusterer(cfg).fit(src)


@pytest.mark.parametrize("backend", ["chunked", "pallas", "multiparam"])
def test_finalize_result_survives_further_partial_fits(backend):
    """finalize() does not consume the run: with donated state buffers the
    next partial_fit deletes the live device state, so a finalized
    Clustering must hold its own host snapshot."""
    n = 200
    kw = (
        dict(v_maxes=(4, 16)) if backend == "multiparam" else dict(v_max=8)
    )
    cfg = ClusterConfig(n=n, backend=backend, chunk=64, **kw)
    sc = StreamClusterer(cfg)
    sc.partial_fit(_edges(n, 500, seed=1))
    mid = sc.finalize()  # untouched until after the next ingest
    sc.partial_fit(_edges(n, 500, seed=2))
    end = sc.finalize()
    # the earlier result is still fully readable after more ingestion (with
    # donation and no snapshot this raised "Array has been deleted")
    ref = StreamClusterer(cfg).partial_fit(_edges(n, 500, seed=1)).finalize()
    np.testing.assert_array_equal(mid.labels, ref.labels)
    assert mid.entropy is not None
    assert int(mid.state.edges_seen) <= int(end.state.edges_seen)


def test_pad_batch_uses_template_without_reallocating():
    B = 512
    pad_batch(_edges(9, 100, 0), B)  # warm the template past B rows
    allocs = pad_template_allocs()
    for i in range(50):
        out = pad_batch(_edges(9, 100 + i, i), B)
        assert out.shape == (B, 2)
        assert (out[100 + i :] == PAD).all()
    assert pad_template_allocs() == allocs  # steady state: zero growths


def test_pad_batch_result_is_fresh_and_writable():
    src_rows = _edges(9, 10, 0)
    out = pad_batch(src_rows, 32)
    out[:] = 0  # must not alias the shared PAD template
    again = pad_batch(_edges(9, 10, 1), 32)
    assert (again[10:] == PAD).all()
