"""Fleet engine tests (DESIGN.md §13).

The contract under test:

* **bit-identity** — a ``T``-tenant fleet run produces, for every tenant,
  labels/degrees/volumes bit-identical to ``T`` independent single-stream
  runs of the same backend and batch geometry, for every fleet-capable
  backend (``chunked`` / ``scan`` / ``pallas``) and over adversarial
  tenant-size mixes (empty tenants, sub-batch tenants, ragged tails);
* **router soundness** — ``TenantRouter`` never reorders within a tenant:
  each tenant's dispatched slab rows concatenate to exactly its stream,
  with exactly the batch boundaries a standalone ``BatchPipeline`` would
  produce, and the staging residency account drains back to zero;
* **one-checkpoint resume** — suspending mid-stream and restoring from the
  single fleet checkpoint (stacked state + per-tenant row vector) finishes
  with bit-identical labels to the uninterrupted run;
* **ragged-fleet no-ops** — tenants that are idle in a fleet step (all-PAD
  slab rows) are not perturbed: an all-idle fleet dispatch leaves every
  state row bit-identical, on every fleet path.
"""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from hypothesis_compat import given, settings, st  # noqa: E402

from repro.cluster import (  # noqa: E402
    ClusterConfig,
    FleetClusterer,
    FleetState,
    TenantRouter,
    cluster,
    cluster_fleet,
)
from repro.core.fleet import fleet_update_chunked, fleet_update_scan  # noqa: E402
from repro.graph.generators import chung_lu_segments  # noqa: E402
from repro.graph.pipeline import PAD, BatchPipeline  # noqa: E402
from repro.graph.sources import GeneratorSource, as_source  # noqa: E402
from repro.kernels.edge_stream.ops import pallas_fleet_update  # noqa: E402

FLEET_BACKENDS = ("chunked", "scan", "pallas")


def _streams(sizes, n, seed):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, n, size=(m, 2)).astype(np.int32) for m in sizes
    ]


def _config(backend, n, T, v_max=8, batch_edges=32):
    return ClusterConfig(
        n=n,
        v_max=v_max,
        backend=backend,
        chunk=16,
        batch_edges=batch_edges,
        tenants=T,
    )


def _assert_fleet_matches_singles(backend, streams, n, v_max=8):
    T = len(streams)
    cfg = _config(backend, n, T, v_max=v_max)
    res = FleetClusterer(cfg).fit(streams).finalize()
    single_cfg = cfg.replace(tenants=None)
    for t, stream in enumerate(streams):
        ref = cluster(stream, single_cfg)
        got = res.tenant(t)
        assert np.array_equal(got.labels, ref.labels), (backend, t)
        assert np.array_equal(
            np.asarray(got.state.d), np.asarray(ref.state.d)
        ), (backend, t)
        assert np.array_equal(
            np.asarray(got.state.v), np.asarray(ref.state.v)
        ), (backend, t)


# ---------------------------------------------------------------------------
# Bit-identity: fleet == T independent single-stream runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", FLEET_BACKENDS)
def test_fleet_bit_identical_to_single_stream_runs(backend):
    # 16 tenants spanning the adversarial size mix: empty, sub-batch,
    # exactly one batch, batch+1, many ragged batches
    sizes = [0, 1, 3, 17, 31, 32, 33, 40, 64, 65, 90, 100, 129, 150, 200, 7]
    streams = _streams(sizes, n=64, seed=0)
    _assert_fleet_matches_singles(backend, streams, n=64)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    sizes=st.lists(st.integers(0, 120), min_size=16, max_size=16),
)
def test_property_fleet_bit_identical(seed, sizes):
    streams = _streams(sizes, n=48, seed=seed)
    for backend in FLEET_BACKENDS:
        _assert_fleet_matches_singles(backend, streams, n=48, v_max=6)


def test_fleet_generator_sources_with_seed_offsets():
    # per-tenant seed offsets: T independent generator streams from one
    # base seed, drained out-of-core through the router
    n, T, rows = 64, 5, 200
    sources = [
        GeneratorSource(chung_lu_segments(n, seed=9, seed_offset=t), rows)
        for t in range(T)
    ]
    cfg = _config("chunked", n, T)
    res = FleetClusterer(cfg).fit(sources).finalize()
    single_cfg = cfg.replace(tenants=None)
    for t in range(T):
        src = GeneratorSource(
            chung_lu_segments(n, seed=9, seed_offset=t), rows
        )
        ref = cluster(src, single_cfg)
        assert np.array_equal(res.tenant(t).labels, ref.labels), t
    # distinct offsets produced distinct streams (not T copies of one run)
    assert not np.array_equal(res.raw_labels[0], res.raw_labels[1])


# ---------------------------------------------------------------------------
# Router soundness
# ---------------------------------------------------------------------------

def test_router_matches_standalone_pipeline_boundaries():
    sizes = [0, 5, 32, 33, 100, 64]
    streams = _streams(sizes, n=50, seed=3)
    B = 32
    router = TenantRouter(streams, B)
    got = [[] for _ in streams]
    for slab in router.fleet_slabs():
        for t in range(len(streams)):
            k = int(slab.n_rows[t])
            rows = slab.edges[t]
            if k:
                got[t].append(rows[:k].copy())
            # PAD tail beyond the real rows, always
            assert np.all(rows[k:] == PAD)
    assert router._inflight_bytes == 0
    for t, stream in enumerate(streams):
        ref = [
            b.edges[: b.n_rows].copy()
            for b in BatchPipeline(as_source(stream), B).batches()
        ]
        assert len(got[t]) == len(ref), t
        for g, r in zip(got[t], ref):
            assert np.array_equal(g, r), t


def test_router_resume_reproduces_remaining_rows():
    sizes = [40, 7, 90, 0]
    streams = _streams(sizes, n=30, seed=4)
    router = TenantRouter(streams, 16)
    slabs = list(router.fleet_slabs())
    # stop after 2 fleet steps; resume from the dispatched-row vector
    rows = np.zeros(len(streams), np.int64)
    for slab in slabs[:2]:
        rows += slab.n_rows
    resumed = list(TenantRouter(streams, 16).fleet_slabs(rows))
    per_tenant = lambda ss, t: np.concatenate(
        [s.edges[t, : int(s.n_rows[t])] for s in ss]
        or [np.zeros((0, 2), np.int32)]
    )
    for t in range(len(streams)):
        assert np.array_equal(
            per_tenant(resumed, t), per_tenant(slabs[2:], t)
        ), t


def test_router_rates_schedule_is_deterministic_and_complete():
    sizes = [100, 25, 50]
    streams = _streams(sizes, n=40, seed=5)
    for rates in ([1, 1, 1], [4, 1, 2]):
        a = list(TenantRouter(streams, 16, rates=rates).fleet_slabs())
        b = list(TenantRouter(streams, 16, rates=rates).fleet_slabs())
        assert len(a) == len(b)
        for sa, sb in zip(a, b):
            assert np.array_equal(sa.edges, sb.edges)
        delivered = np.sum([s.n_rows for s in a], axis=0)
        assert np.array_equal(delivered, sizes)


def test_router_validation():
    with pytest.raises(ValueError):
        TenantRouter([], 16)
    with pytest.raises(ValueError):
        TenantRouter([np.zeros((4, 2), np.int32)], 0)
    with pytest.raises(ValueError):
        TenantRouter([np.zeros((4, 2), np.int32)], 16, rates=[1, 2])
    router = TenantRouter([np.zeros((4, 2), np.int32)], 16)
    with pytest.raises(ValueError):
        list(router.fleet_slabs([9]))  # resume row beyond the stream


# ---------------------------------------------------------------------------
# One-checkpoint suspend / resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", FLEET_BACKENDS)
def test_fleet_checkpoint_resume_bit_identical(backend, tmp_path):
    sizes = [0, 3, 17, 40, 64, 129, 200, 5]
    streams = _streams(sizes, n=64, seed=1)
    cfg = _config(backend, 64, len(streams))
    full = FleetClusterer(cfg).fit(streams).finalize()

    fc = FleetClusterer(cfg).fit(streams, max_steps=2)
    d = str(tmp_path / backend)
    fc.save(d)
    fc2 = FleetClusterer.restore(d)
    assert np.array_equal(fc2.tenant_rows, fc.tenant_rows)
    assert np.array_equal(fc2.edges_seen, fc.edges_seen)
    res = fc2.fit(streams).finalize()
    assert np.array_equal(res.raw_labels, full.raw_labels)
    assert np.array_equal(
        np.asarray(res.state.v), np.asarray(full.state.v)
    )
    assert np.array_equal(
        np.asarray(res.state.d), np.asarray(full.state.d)
    )


def test_fleet_restore_rejects_single_stream_checkpoint(tmp_path):
    from repro.cluster import StreamClusterer

    cfg = ClusterConfig(n=16, v_max=4, backend="chunked", chunk=8)
    sc = StreamClusterer(cfg)
    sc.partial_fit(np.array([[0, 1], [1, 2]], np.int32))
    d = str(tmp_path / "single")
    sc.save(d)
    with pytest.raises(ValueError, match="tenant_rows"):
        FleetClusterer.restore(d)


# ---------------------------------------------------------------------------
# Ragged fleets: idle tenants are bit-untouched
# ---------------------------------------------------------------------------

def test_all_idle_tenants_not_perturbed():
    # adversarial regression: an all-PAD slab dispatch must be a perfect
    # no-op on every fleet path — state rows bit-identical, edges_seen flat
    n, T, B = 32, 4, 16
    rng = np.random.default_rng(7)
    warm = rng.integers(0, n, size=(T, B, 2)).astype(np.int32)
    idle = np.full((T, B, 2), PAD, np.int32)
    import jax.numpy as jnp

    paths = {
        "chunked": lambda s, e: fleet_update_chunked(
            s, jnp.asarray(e), jnp.int32(5), chunk=8
        ),
        "scan": lambda s, e: fleet_update_scan(
            s, jnp.asarray(e), jnp.int32(5)
        ),
        "pallas": lambda s, e: pallas_fleet_update(
            s, jnp.asarray(e), 5, interpret=True
        ),
    }
    for name, step in paths.items():
        state = step(FleetState.init(n, T), warm)
        before = state.to_numpy()
        after = step(before.to_device(), idle).to_numpy()
        for leaf in ("d", "c", "v", "edges_seen"):
            assert np.array_equal(
                np.asarray(getattr(after, leaf)),
                np.asarray(getattr(before, leaf)),
            ), (name, leaf)


def test_partially_idle_fleet_steps_leave_idle_rows_pristine():
    # tenants 0 and 2 idle from the start; their rows must equal a fresh
    # init even after many fleet steps driven by the other tenants
    n = 40
    sizes = [0, 300, 0, 45]
    streams = _streams(sizes, n=n, seed=8)
    for backend in FLEET_BACKENDS:
        cfg = _config(backend, n, len(sizes), batch_edges=16)
        res = FleetClusterer(cfg).fit(streams).finalize()
        fresh = FleetState.init(n, 1, numpy=True)
        for t in (0, 2):
            for leaf in ("d", "c", "v"):
                assert np.array_equal(
                    np.asarray(getattr(res.state, leaf))[t],
                    np.asarray(getattr(fresh, leaf))[0],
                ), (backend, t, leaf)
        assert res.info["tenant_rows"][0] == 0


# ---------------------------------------------------------------------------
# API surface
# ---------------------------------------------------------------------------

def test_fleet_config_and_constructor_validation():
    with pytest.raises(ValueError, match="tenants"):
        ClusterConfig(n=8, v_max=2, tenants=0)
    with pytest.raises(ValueError, match="config.tenants"):
        FleetClusterer(ClusterConfig(n=8, v_max=2, backend="chunked"))
    with pytest.raises(ValueError, match="fleet"):
        FleetClusterer(
            ClusterConfig(n=8, v_max=2, backend="dense", tenants=2)
        )
    cfg = ClusterConfig(n=8, v_max=2, backend="chunked", tenants=2)
    with pytest.raises(ValueError, match="match"):
        FleetClusterer(cfg, state=FleetState.init(8, 3))
    with pytest.raises(ValueError, match="sources"):
        FleetClusterer(cfg).fit([np.zeros((2, 2), np.int32)])


def test_cluster_fleet_defaults_tenants_and_counts_dispatches():
    streams = _streams([10, 0, 33], n=24, seed=2)
    res = cluster_fleet(
        streams, ClusterConfig(n=24, v_max=4, backend="chunked", chunk=8,
                               batch_edges=16)
    )
    assert res.tenants == 3
    assert res.info["dispatches_per_fleet_step"] == 1.0
    assert res.info["stream_dispatches"] == res.info["fleet_steps"]
    assert res.info["peak_staging_bytes"] > 0
    assert res.labels.shape == (3, 24)
    # tenant() view exposes the standard edge-free metrics
    assert res.tenant(2).entropy is not None


def test_fleet_state_views():
    fs = FleetState.init(6, 3)
    assert fs.n == 6 and fs.tenants == 3
    entry = fs.entry(1)
    assert np.asarray(entry.c).shape == (6,)
    host = fs.to_numpy()
    assert isinstance(np.asarray(host.d), np.ndarray)
    assert host.to_device().d.shape == (3, 6)
