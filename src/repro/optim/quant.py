"""Block-wise int8 tensor quantisation for optimizer state & gradients.

Per-block symmetric int8 over the last axis (block = 128 lanes): a tensor of
shape (..., D) stores ``q: int8 (..., D)`` + ``scale: f32 (..., D/128)``.
Used for (a) 8-bit Adam moments — the memory trick that fits llama3-405b
training state on 256 chips (DESIGN §4), and (b) gradient compression with
error feedback (dist/compress.py).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

Array = jax.Array
BLOCK = 128


def _pad_to_block(x: Array):
    d = x.shape[-1]
    pad = (-d) % BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, d


def quantize(x: Array) -> Dict[str, Array]:
    """float tensor -> {"q": int8, "scale": f32, "dim": orig last dim}."""
    xp, d = _pad_to_block(x.astype(jnp.float32))
    blocks = xp.reshape(*xp.shape[:-1], -1, BLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {
        "q": q.reshape(xp.shape),
        "scale": scale[..., 0].astype(jnp.float32),
    }


def dequantize(qt: Dict[str, Array]) -> Array:
    q = qt["q"]
    blocks = q.reshape(*q.shape[:-1], -1, BLOCK).astype(jnp.float32)
    x = blocks * qt["scale"][..., None]
    return x.reshape(q.shape)


def dequantize_to(qt: Dict[str, Array], d: int) -> Array:
    return dequantize(qt)[..., :d]


def zeros_like_quantized(x: Array) -> Dict[str, Array]:
    xp, d = _pad_to_block(x)
    nblk = xp.shape[-1] // BLOCK
    return {
        "q": jnp.zeros(xp.shape, jnp.int8),
        "scale": jnp.zeros((*xp.shape[:-1], nblk), jnp.float32),
    }
