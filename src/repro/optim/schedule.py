"""LR schedules: warmup-stable-decay (wsd) and cosine."""

from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    decay_frac: float = 0.1,
    floor: float = 0.0,
):
    decay_steps = max(1, int(total_steps * decay_frac))
    stable_end = total_steps - decay_steps

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / max(warmup_steps, 1))
        decay = peak_lr + (floor - peak_lr) * jnp.clip(
            (step - stable_end) / decay_steps, 0.0, 1.0
        )
        return jnp.where(step < stable_end, warm, decay)

    return lr


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    floor_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, step / max(warmup_steps, 1))
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * warm * cos

    return lr
