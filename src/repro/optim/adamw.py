"""AdamW from scratch with optional int8-quantised moment states.

State dtypes per moment: "float32" | "bfloat16" | "int8" (block-quantised,
see optim/quant.py).  8-bit moments cost 1 B + 1/128 scale per parameter —
the difference between llama3-405b training state fitting 256 chips or not:

    bf16 param + bf16 grad + fp32 m + fp32 v  = 12 B/param → 19.0 GB/chip
    bf16 param + bf16 grad + int8 m + int8 v  ≈ 6.1 B/param →  9.7 GB/chip
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.optim.quant import dequantize_to, quantize, zeros_like_quantized

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    m_dtype: str = "float32"
    v_dtype: str = "float32"
    clip_norm: float = 1.0

    # ------------------------------------------------------------------
    def _zeros(self, p: Array, dtype: str):
        if dtype == "int8":
            return zeros_like_quantized(p.astype(jnp.float32))
        return jnp.zeros_like(p, jnp.dtype(dtype))

    def _read(self, s, p: Array, dtype: str) -> Array:
        if dtype == "int8":
            return dequantize_to(s, p.shape[-1])
        return s.astype(jnp.float32)

    def _write(self, x: Array, dtype: str):
        if dtype == "int8":
            return quantize(x)
        return x.astype(jnp.dtype(dtype))

    # ------------------------------------------------------------------
    def init(self, params) -> Dict[str, Any]:
        return {
            "m": jax.tree.map(lambda p: self._zeros(p, self.m_dtype), params),
            "v": jax.tree.map(lambda p: self._zeros(p, self.v_dtype), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params, lr: Array):
        count = state["count"] + 1
        # Global-norm clip in f32.
        if self.clip_norm > 0:
            gnorm = jnp.sqrt(
                sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads)
                )
            )
            factor = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        else:
            gnorm = jnp.float32(0.0)
            factor = jnp.float32(1.0)

        bc1 = 1.0 - self.b1**count.astype(jnp.float32)
        bc2 = 1.0 - self.b2**count.astype(jnp.float32)

        def leaf(g, m_s, v_s, p):
            g = g.astype(jnp.float32) * factor
            m = self._read(m_s, p, self.m_dtype)
            v = self._read(v_s, p, self.v_dtype)
            m = self.b1 * m + (1.0 - self.b1) * g
            v = self.b2 * v + (1.0 - self.b2) * jnp.square(g)
            update = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay > 0:
                update = update + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
            return new_p, self._write(m, self.m_dtype), self._write(v, self.v_dtype)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        out = [leaf(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        new_state = {"m": new_m, "v": new_v, "count": count}
        return new_p, new_state, {"grad_norm": gnorm}
