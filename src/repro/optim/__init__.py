from repro.optim.adamw import AdamW  # noqa: F401
from repro.optim.schedule import wsd_schedule  # noqa: F401
