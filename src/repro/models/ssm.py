"""Mamba-2 SSD (state-space duality) — chunked dual form for train/prefill,
recurrent state update for decode.

The chunked algorithm (Dao & Gu 2024) computes, per chunk of length Q:
intra-chunk outputs with a masked decay matrix L (quadratic in Q only), and
inter-chunk contributions through a (H, P, N) running state carried by a
`lax.scan` over chunks — O(S·Q) compute on MXU-shaped einsums, exactly the
right TPU adaptation of the CUDA scan kernel the paper family ships.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _segsum(a: Array) -> Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < t <= i} a[..., t].

    a: (..., Q) -> (..., Q, Q) lower-triangular (−inf above diagonal).
    """
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: Array,  # (B, S, H, P) — already multiplied by dt
    a: Array,  # (B, S, H)    — log-decay per step (dt * A, negative)
    b: Array,  # (B, S, G, N)
    c: Array,  # (B, S, G, N)
    chunk: int,
    h0: Array | None = None,  # (B, H, P, N) initial state
) -> Tuple[Array, Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    hpg = H // G
    # Pad the tail to a chunk multiple: zero inputs with zero log-decay are
    # exact no-ops for the state (h' = 1*h + 0), outputs are sliced off.
    S0 = S
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // chunk

    # chunk the time axis
    xc = x.reshape(B, nc, chunk, H, P)
    ac = a.reshape(B, nc, chunk, H).astype(jnp.float32)
    bc = b.reshape(B, nc, chunk, G, N)
    cc = c.reshape(B, nc, chunk, G, N)

    a_cum = jnp.cumsum(ac, axis=2)  # (B, nc, Q, H)

    # --- intra-chunk (dual quadratic form) ------------------------------
    L = jnp.exp(_segsum(jnp.moveaxis(ac, 3, 2)))  # (B, nc, H, Q, Q)
    # scores: C_i · B_j  with groups broadcast over heads
    cb = jnp.einsum("bcqgn,bckgn->bcgqk", cc, bc, preferred_element_type=jnp.float32)
    cb = jnp.repeat(cb, hpg, axis=2)  # (B, nc, H, Q, K)
    y_diag = jnp.einsum(
        "bchqk,bckhp->bcqhp", (cb * L).astype(x.dtype), xc,
        preferred_element_type=jnp.float32,
    )

    # --- chunk states ----------------------------------------------------
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (B, nc, Q, H)
    xw = xc * decay_states[..., None].astype(x.dtype)
    states = jnp.einsum(
        "bcqgn,bcqhp->bchpn",
        bc,
        xw.reshape(B, nc, chunk, G, hpg, P).reshape(B, nc, chunk, H, P)
        if False
        else xw,
        preferred_element_type=jnp.float32,
    )  # broadcast of g over h handled below for G>1

    if G > 1:
        # recompute states with explicit group mapping
        xg = xw.reshape(B, nc, chunk, G, hpg, P)
        states = jnp.einsum(
            "bcqgn,bcqghp->bcghpn", bc, xg, preferred_element_type=jnp.float32
        ).reshape(B, nc, H, P, N)

    # --- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (B, nc, H)

    def step(h_prev, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev  # emit state *entering* the chunk

    init = (
        jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )
    final, h_in = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B, nc, H, P, N)

    # --- inter-chunk output ------------------------------------------------
    state_decay = jnp.exp(a_cum)  # (B, nc, Q, H)
    cg = cc  # (B, nc, Q, G, N)
    if G == 1:
        y_off = jnp.einsum(
            "bcqgn,bchpn->bcqhp", cg, h_in.astype(x.dtype),
            preferred_element_type=jnp.float32,
        )
    else:
        hg = h_in.reshape(B, nc, G, hpg, P, N)
        y_off = jnp.einsum(
            "bcqgn,bcghpn->bcqghp", cg, hg.astype(x.dtype),
            preferred_element_type=jnp.float32,
        ).reshape(B, nc, chunk, H, P)
    y_off = y_off * state_decay[..., None]

    y = (y_diag + y_off).reshape(B, S, H, P)[:, :S0]
    return y.astype(x.dtype), final


def ssd_ref(x, a, b, c, h0=None):
    """Sequential per-step reference (test oracle).  Same shapes as ssd_chunked."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    hpg = H // G

    def step(h, t):
        xt, at, bt, ct = t
        dec = jnp.exp(at)[..., None, None]  # (B,H,1,1)
        bh = jnp.repeat(bt, hpg, axis=1)  # (B,H,N)
        ch = jnp.repeat(ct, hpg, axis=1)
        h_new = h * dec + jnp.einsum("bhp,bhn->bhpn", xt, bh)
        y = jnp.einsum("bhpn,bhn->bhp", h_new, ch)
        return h_new, y

    init = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(a.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c.astype(jnp.float32), 1, 0),
    )
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1), final


def ssd_decode_step(
    h: Array,  # (B, H, P, N)
    x: Array,  # (B, H, P) — already multiplied by dt
    a: Array,  # (B, H) log-decay
    b: Array,  # (B, G, N)
    c: Array,  # (B, G, N)
) -> Tuple[Array, Array]:
    G = b.shape[1]
    H = x.shape[1]
    hpg = H // G
    bh = jnp.repeat(b, hpg, axis=1)
    ch = jnp.repeat(c, hpg, axis=1)
    h_new = h * jnp.exp(a.astype(jnp.float32))[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", x.astype(jnp.float32), bh.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bhn->bhp", h_new, ch.astype(jnp.float32))
    return y.astype(x.dtype), h_new
