"""RG-LRU recurrence (RecurrentGemma / Griffin) with log-depth associative
scan for train/prefill and O(1) state update for decode.

    r_t = sigmoid(x_t W_a + b_a)          (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)          (input gate)
    a_t = exp(-c * softplus(Λ) * r_t)     (per-channel decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The linear recurrence is evaluated with ``jax.lax.associative_scan`` over
(a, b) pairs — the TPU-native replacement for the paper family's sequential
CUDA scan.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
_C = 8.0


def rglru_gates(x: Array, w_a, b_a, w_x, b_x, lam) -> Tuple[Array, Array]:
    """Returns (log_a, gated_input), both float32.  x: (..., D)."""
    r = jax.nn.sigmoid(
        jnp.einsum("...d,de->...e", x, w_a).astype(jnp.float32) + b_a
    )
    i = jax.nn.sigmoid(
        jnp.einsum("...d,de->...e", x, w_x).astype(jnp.float32) + b_x
    )
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i * x.astype(jnp.float32)
    return log_a, b


def rglru_scan(x: Array, w_a, b_a, w_x, b_x, lam, h0: Array | None = None):
    """x: (B, S, D) -> (y (B,S,D), h_final (B,D))."""
    log_a, b = rglru_gates(x, w_a, b_a, w_x, b_x, lam)
    a = jnp.exp(log_a)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_decode_step(h: Array, x: Array, w_a, b_a, w_x, b_x, lam):
    """One-step update.  h: (B, D) float32; x: (B, D).  Returns (y, h_new)."""
    log_a, b = rglru_gates(x, w_a, b_a, w_x, b_x, lam)
    h_new = jnp.exp(log_a) * h.astype(jnp.float32) + b
    return h_new.astype(x.dtype), h_new


def rglru_ref(x, w_a, b_a, w_x, b_x, lam, h0=None):
    """Sequential reference for tests."""
    B, S, D = x.shape
    h = jnp.zeros((B, D), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    ys = []
    for t in range(S):
        y, h = rglru_decode_step(h, x[:, t], w_a, b_a, w_x, b_x, lam)
        ys.append(h)
    return jnp.stack(ys, 1).astype(x.dtype), h
