"""Attention kernels in pure JAX (TPU-idiomatic, blockwise/flash-style).

Never materialises an (S, S) score matrix: prefill/train attention streams KV
in blocks with a running (max, denom, acc) softmax — O(S·block) memory.
Variants:

* :func:`flash_attention` — causal / non-causal / sliding-window / cross,
  GQA-aware (q heads grouped over kv heads), separate K and V head dims
  (needed by MLA's expanded form).
* :func:`banded_local_attention` — sliding-window specialisation that gathers
  only the (window + block) KV band per query block, so compute is
  O(S·window) instead of O(S²·masked) — used by gemma3 / recurrentgemma
  local layers.
* :func:`decode_attention` — single-token decode against a KV cache with a
  length (and optional window) mask.
* :func:`mla_decode_attention` — DeepSeek-V2 absorbed-form latent decode: the
  cache stores the 512-d latent + shared rope key, never per-head K/V.

Masked-out score entries use a large finite negative (-1e30); the running
softmax self-corrects blocks that precede the first in-band block (their
contribution is scaled by exp(-1e30 - m) = 0 once a real block arrives).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import softcap as _softcap

Array = jax.Array
NEG = -1.0e30


def _mask_block(
    qpos: Array, kpos: Array, causal: bool, window: int, kv_len: Optional[Array]
) -> Array:
    """(bq, bk) bool mask of allowed attention."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window > 0:
        m &= qpos[:, None] - kpos[None, :] < window
    if kv_len is not None:
        m &= kpos[None, :] < kv_len
    return m


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset=0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    bq: int = 512,
    bk: int = 512,
    custom_grad: bool = True,
) -> Array:
    """Blockwise attention.  q: (B,Sq,Hq,Dk); k: (B,Skv,Hkv,Dk);
    v: (B,Skv,Hkv,Dv).  Returns (B,Sq,Hq,Dv).

    ``custom_grad=True`` uses the blockwise custom-VJP backward: plain AD of
    a blockwise forward re-materialises every (bq, bk) probability block into
    a stacked (S/bq, …, bq, bk) ≈ S×S HBM buffer for the backward pass
    (measured: 536 MB/device/layer at 4k×16 on qwen) — the classic reason
    flash attention needs a hand-written backward.  The custom VJP recomputes
    probability blocks from the saved (q, k, v, out, lse) instead.
    """
    if custom_grad:
        return _flash_vjp(
            q, k, v, causal, window, int(q_offset), float(softcap),
            float(Dk_scale(q, scale)), int(bq), int(bk),
        )
    return _flash_fwd(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        softcap=softcap, scale=scale, bq=bq, bk=bk,
    )[0]


def Dk_scale(q, scale):
    return q.shape[-1] ** -0.5 if scale is None else scale


def _block_pairs(nq, nk, bq, bk, causal, window, q_offset):
    """Static (iq, ik) schedule with BLOCK-LEVEL causal/window skip.

    Full-grid masking computes nq*nk blocks and throws half (causal) or
    almost all (sliding window) away; the pair list visits only blocks that
    contain >= 1 legal position — the same skip a fused flash kernel does
    with its grid.  Ordered by iq (running softmax needs in-order kv visits
    within each q row).

    Set REPRO_FLASH_FULL_GRID=1 to disable the skip (baseline-measurement
    mode for EXPERIMENTS.md §Perf before/after under one analyzer)."""
    import os
    if os.environ.get("REPRO_FLASH_FULL_GRID"):
        causal, window = False, 0  # visit every block (masks still applied)
    pairs = []
    for iq in range(nq):
        qlo = q_offset + iq * bq
        qhi = qlo + bq - 1
        for ik in range(nk):
            klo, khi = ik * bk, ik * bk + bk - 1
            if causal and klo > qhi:
                continue
            if window > 0 and khi < qlo - window + 1:
                continue
            pairs.append((iq, ik))
    return pairs


def _flash_fwd(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset=0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    bq: int = 512,
    bk: int = 512,
):
    """Returns (out, lse) with lse: (B, Hkv, G, Sq) row log-sum-exp."""
    B, Sq, Hq, Dk = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = Dk**-0.5 if scale is None else scale
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk

    qx = jnp.moveaxis(q.reshape(B, nq, bq, Hkv, G, Dk), 1, 0)
    kx = jnp.moveaxis(k.reshape(B, nk, bk, Hkv, Dk), 1, 0)
    vx = jnp.moveaxis(v.reshape(B, nk, bk, Hkv, Dv), 1, 0)

    pairs = _block_pairs(nq, nk, bq, bk, causal, window, int(q_offset))
    iqs = jnp.array([p[0] for p in pairs], jnp.int32)
    iks = jnp.array([p[1] for p in pairs], jnp.int32)

    def step(carry, pair):
        m, l, acc = carry
        iq, ik = pair
        qb = qx[iq]
        kb = kx[ik]
        vb = vx[ik]
        qpos = q_offset + iq * bq + jnp.arange(bq)
        kpos = ik * bk + jnp.arange(bk)
        s = (
            jnp.einsum(
                "bqhgd,bkhd->bhgqk", qb, kb,
                preferred_element_type=jnp.float32,
            )
            * scale
        )
        s = _softcap(s, softcap)
        mask = _mask_block(qpos, kpos, causal, window, None)
        s = jnp.where(mask[None, None, None], s, NEG)
        m_row = m[iq]
        m_new = jnp.maximum(m_row, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_row - m_new)
        l_new = l[iq] * corr + p.sum(axis=-1)
        pv = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc[iq] * corr[..., None] + pv
        return (
            m.at[iq].set(m_new), l.at[iq].set(l_new), acc.at[iq].set(acc_new)
        ), None

    init = (
        jnp.full((nq, B, Hkv, G, bq), NEG, jnp.float32),
        jnp.zeros((nq, B, Hkv, G, bq), jnp.float32),
        jnp.zeros((nq, B, Hkv, G, bq, Dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (iqs, iks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (nq, B, Hkv, G, bq, Dv) -> (B, nq, bq, Hkv, G, Dv) -> (B, Sq, Hq, Dv)
    out = jnp.transpose(out, (1, 0, 4, 2, 3, 5)).reshape(B, Sq, Hq, Dv)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (nq, B, Hkv, G, bq)
    lse = jnp.moveaxis(lse, 0, 3).reshape(B, Hkv, G, Sq)
    return out.astype(q.dtype), lse


# ---------------------------------------------------------------------------
# Custom-VJP flash attention (blockwise backward, no S x S residuals)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash_vjp(q, k, v, causal, window, q_offset, softcap, scale, bq, bk):
    out, _ = _flash_fwd(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        softcap=softcap, scale=scale, bq=bq, bk=bk,
    )
    return out


def _flash_vjp_fwd(q, k, v, causal, window, q_offset, softcap, scale, bq, bk):
    out, lse = _flash_fwd(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        softcap=softcap, scale=scale, bq=bq, bk=bk,
    )
    return out, (q, k, v, out, lse)


def _p_block(qb, kb, lse_b, qpos, kpos, causal, window, softcap, scale):
    """Recompute one probability block (B,Hkv,G,bq,bk) + pre-softcap factor."""
    s = (
        jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb,
                   preferred_element_type=jnp.float32)
        * scale
    )
    cap_factor = None
    if softcap > 0.0:
        t = jnp.tanh(s / softcap)
        cap_factor = 1.0 - jnp.square(t)  # d softcap / ds
        s = softcap * t
    mask = _mask_block(qpos, kpos, causal, window, None)
    p = jnp.where(
        mask[None, None, None], jnp.exp(s - lse_b[..., None]), 0.0
    )
    return p, cap_factor


def _flash_vjp_bwd(causal, window, q_offset, softcap, scale, bq, bk, res, g):
    """Single pass over the (block-skipped) pair schedule accumulating
    dq, dk, dv together — one probability recompute total."""
    q, k, v, out, lse = res
    B, Sq, Hq, Dk = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = Hq // Hkv
    bq_ = min(bq, Sq)
    bk_ = min(bk, Skv)
    nq, nk = Sq // bq_, Skv // bk_

    qx = jnp.moveaxis(q.reshape(B, nq, bq_, Hkv, G, Dk), 1, 0)
    gx = jnp.moveaxis(g.reshape(B, nq, bq_, Hkv, G, Dv), 1, 0)
    kx = jnp.moveaxis(k.reshape(B, nk, bk_, Hkv, Dk), 1, 0)
    vx = jnp.moveaxis(v.reshape(B, nk, bk_, Hkv, Dv), 1, 0)
    lse_x = jnp.moveaxis(lse.reshape(B, Hkv, G, nq, bq_), 3, 0)
    # D_i = rowsum(dout * out): (nq, B, Hkv, G, bq)
    delta = jnp.einsum(
        "bshgd,bshgd->bhgs",
        g.reshape(B, Sq, Hkv, G, Dv).astype(jnp.float32),
        out.reshape(B, Sq, Hkv, G, Dv).astype(jnp.float32),
    )
    delta_x = jnp.moveaxis(delta.reshape(B, Hkv, G, nq, bq_), 3, 0)

    pairs = _block_pairs(nq, nk, bq_, bk_, causal, window, int(q_offset))
    iqs = jnp.array([p[0] for p in pairs], jnp.int32)
    iks = jnp.array([p[1] for p in pairs], jnp.int32)

    def step(carry, pair):
        dq_s, dk_s, dv_s = carry
        iq, ik = pair
        qb, gb, lse_b, d_b = qx[iq], gx[iq], lse_x[iq], delta_x[iq]
        kb, vb = kx[ik], vx[ik]
        qpos = q_offset + iq * bq_ + jnp.arange(bq_)
        kpos = ik * bk_ + jnp.arange(bk_)
        p, cap = _p_block(qb, kb, lse_b, qpos, kpos, causal, window,
                          softcap, scale)
        dv_blk = jnp.einsum(
            "bhgqk,bqhgd->bkhd", p.astype(gb.dtype), gb,
            preferred_element_type=jnp.float32,
        )
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", gb, vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - d_b[..., None])
        if cap is not None:
            ds = ds * cap
        dq_blk = jnp.einsum(
            "bhgqk,bkhd->bqhgd", ds.astype(kb.dtype), kb,
            preferred_element_type=jnp.float32,
        )
        dk_blk = jnp.einsum(
            "bhgqk,bqhgd->bkhd", ds.astype(qb.dtype), qb,
            preferred_element_type=jnp.float32,
        )
        return (
            dq_s.at[iq].add(dq_blk),
            dk_s.at[ik].add(dk_blk),
            dv_s.at[ik].add(dv_blk),
        ), None

    init = (
        jnp.zeros((nq, B, bq_, Hkv, G, Dk), jnp.float32),
        jnp.zeros((nk, B, bk_, Hkv, Dk), jnp.float32),
        jnp.zeros((nk, B, bk_, Hkv, Dv), jnp.float32),
    )
    (dq_s, dk_s, dv_s), _ = jax.lax.scan(step, init, (iqs, iks))
    dq = (jnp.moveaxis(dq_s, 0, 1).reshape(B, Sq, Hq, Dk) * scale).astype(q.dtype)
    dk = (jnp.moveaxis(dk_s, 0, 1).reshape(B, Skv, Hkv, Dk) * scale).astype(k.dtype)
    dv = jnp.moveaxis(dv_s, 0, 1).reshape(B, Skv, Hkv, Dv).astype(v.dtype)
    return dq, dk, dv


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)



def banded_local_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    window: int,
    q_offset=0,
    softcap: float = 0.0,
    bq: int = 512,
) -> Array:
    """Sliding-window causal attention, gathering only the needed KV band.

    Compute is O(Sq · (window + bq)) — the full-mask version wastes
    O(Sq · Skv) at 32k context with a 512 window (~64×).
    """
    B, Sq, Hq, Dk = q.shape
    _, Skv, Hkv, Dv = v.shape
    G = Hq // Hkv
    scale = Dk**-0.5
    bq = min(bq, Sq)
    assert Sq % bq == 0
    nq = Sq // bq
    band = -(-(window + bq) // 128) * 128  # lane-aligned band length
    band = min(band, Skv)

    qx = jnp.moveaxis(q.reshape(B, nq, bq, Hkv, G, Dk), 1, 0)

    def per_q_block(_, q_in):
        iq, qb = q_in
        qpos = q_offset + iq * bq + jnp.arange(bq)
        start = jnp.clip(iq * bq + bq - band + q_offset * 0, 0, Skv - band)
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        kpos = start + jnp.arange(band)
        s = (
            jnp.einsum(
                "bqhgd,bkhd->bhgqk", qb, kb, preferred_element_type=jnp.float32
            )
            * scale
        )
        s = _softcap(s, softcap)
        mask = _mask_block(qpos, kpos, True, window, None)
        s = jnp.where(mask[None, None, None], s, NEG)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32,
        )
        return None, jnp.moveaxis(out, 3, 1)

    _, outs = jax.lax.scan(per_q_block, None, (jnp.arange(nq), qx))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, Dv).astype(q.dtype)


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    cache_len: Array,
    *,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
) -> Array:
    """One-token decode.  q: (B,Hq,Dk); caches: (B,S,Hkv,D*).  Returns (B,Hq,Dv)."""
    B, S, Hkv, Dk = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = Dk**-0.5 if scale is None else scale
    qg = q.reshape(B, Hkv, G, Dk)
    s = (
        jnp.einsum(
            "bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32
        )
        * scale
    )
    s = _softcap(s, softcap)
    pos = jnp.arange(S)
    mask = pos[None, :] < cache_len[:, None]  # (B, S)
    if window > 0:
        mask &= pos[None, :] >= cache_len[:, None] - window
    s = jnp.where(mask[:, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, Hq, -1).astype(q.dtype)


def mla_decode_attention(
    q_nope: Array,  # (B, H, dn)
    q_rope: Array,  # (B, H, dr)
    latent_cache: Array,  # (B, S, dl)
    rope_cache: Array,  # (B, S, dr)
    w_uk: Array,  # (H, dn, dl)  k up-projection (absorbed into q)
    w_uv: Array,  # (H, dl, dv)  v up-projection (absorbed into out)
    cache_len: Array,
    *,
    scale: float,
) -> Array:
    """DeepSeek-V2 absorbed MLA decode: score and aggregate in latent space."""
    B, S, dl = latent_cache.shape
    q_lat = jnp.einsum("bhn,hnl->bhl", q_nope, w_uk)  # (B, H, dl)
    s = jnp.einsum(
        "bhl,bsl->bhs", q_lat, latent_cache, preferred_element_type=jnp.float32
    )
    s += jnp.einsum(
        "bhr,bsr->bhs", q_rope, rope_cache, preferred_element_type=jnp.float32
    )
    s *= scale
    mask = jnp.arange(S)[None, :] < cache_len[:, None]
    s = jnp.where(mask[:, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum(
        "bhs,bsl->bhl", p.astype(latent_cache.dtype), latent_cache,
        preferred_element_type=jnp.float32,
    )
    out = jnp.einsum("bhl,hlv->bhv", ctx.astype(w_uv.dtype), w_uv)
    return out
