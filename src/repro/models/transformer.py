"""Config-driven model composition for all 10 assigned architectures.

Layer stacking uses *super-block scan*: the repeating ``block_pattern`` cycle
is scanned with per-position weights stacked on a leading ``n_cycles`` axis
(HLO size = one cycle, O(1) compile in depth); non-multiple remainders and
dense-prefix layers (deepseek) are unrolled.  Heterogeneous stacks (gemma3's
5 local : 1 global, recurrentgemma's 2 RG-LRU : 1 local-MQA, vision
cross-attn every 5th layer) map naturally onto the cycle.

Three execution modes share one ``apply_block``:
  * ``train``   — full sequence, no cache.
  * ``prefill`` — full sequence, emits per-layer cache slices.
  * ``decode``  — one token against the cache at position ``pos``.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models.attention import (
    banded_local_attention,
    decode_attention,
    flash_attention,
    mla_decode_attention,
)
from repro.models.layers import (
    apply_rope,
    dense_init,
    embed_init,
    gated_mlp,
    rms_norm,
    softcap,
)
from repro.models.moe import moe_ffn
from repro.models.rglru import rglru_decode_step, rglru_scan
from repro.models.ssm import ssd_chunked, ssd_decode_step

Array = jax.Array
Params = Dict[str, Any]


# ===========================================================================
# Parameter initialisation
# ===========================================================================

def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _attn_params(key, cfg: ModelConfig, cross: bool, gated: bool) -> Params:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "ln1": jnp.zeros((d,), jnp.float32),
        "wq": dense_init(ks[0], (d, H * Dh), dtype=_dt(cfg)),
        "wk": dense_init(ks[1], (d, Hkv * Dh), dtype=_dt(cfg)),
        "wv": dense_init(ks[2], (d, Hkv * Dh), dtype=_dt(cfg)),
        "wo": dense_init(ks[3], (H * Dh, d), dtype=_dt(cfg)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), _dt(cfg))
        p["bk"] = jnp.zeros((Hkv * Dh,), _dt(cfg))
        p["bv"] = jnp.zeros((Hkv * Dh,), _dt(cfg))
    if cross:
        p["lnc"] = jnp.zeros((d,), jnp.float32)
        p["wq_c"] = dense_init(ks[4], (d, H * Dh), dtype=_dt(cfg))
        p["wk_c"] = dense_init(ks[5], (d, Hkv * Dh), dtype=_dt(cfg))
        p["wv_c"] = dense_init(ks[6], (d, Hkv * Dh), dtype=_dt(cfg))
        p["wo_c"] = dense_init(ks[7], (H * Dh, d), dtype=_dt(cfg))
    if gated:
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
    return p


def _mla_params(key, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "wq_a": dense_init(ks[0], (d, cfg.q_lora), dtype=_dt(cfg)),
        "q_norm": jnp.zeros((cfg.q_lora,), jnp.float32),
        "wq_b": dense_init(ks[1], (cfg.q_lora, H * (dn + dr)), dtype=_dt(cfg)),
        "wkv_a": dense_init(ks[2], (d, cfg.kv_lora + dr), dtype=_dt(cfg)),
        "kv_norm": jnp.zeros((cfg.kv_lora,), jnp.float32),
        "w_uk": dense_init(ks[3], (H, dn, cfg.kv_lora), in_axis=2, dtype=_dt(cfg)),
        "w_uv": dense_init(ks[4], (H, cfg.kv_lora, dv), in_axis=1, dtype=_dt(cfg)),
        "wo": dense_init(ks[5], (H * dv, d), dtype=_dt(cfg)),
    }


def _ssm_params(key, cfg: ModelConfig) -> Params:
    d, di, G, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * G * N + H), dtype=_dt(cfg)),
        "conv_w": dense_init(ks[1], (cfg.conv_width, conv_dim), dtype=_dt(cfg)),
        "conv_b": jnp.zeros((conv_dim,), _dt(cfg)),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ),
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "ssm_norm": jnp.zeros((di,), jnp.float32),
        "out_proj": dense_init(ks[2], (di, d), dtype=_dt(cfg)),
    }


def _rec_params(key, cfg: ModelConfig) -> Params:
    d, L = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 5)
    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "in_x": dense_init(ks[0], (d, L), dtype=_dt(cfg)),
        "in_gate": dense_init(ks[1], (d, L), dtype=_dt(cfg)),
        "conv_w": dense_init(ks[2], (cfg.conv_width, L), dtype=_dt(cfg)),
        "conv_b": jnp.zeros((L,), _dt(cfg)),
        "w_a": dense_init(ks[3], (L, L), dtype=_dt(cfg)),
        "b_a": jnp.full((L,), 1.0, jnp.float32),
        "w_x": dense_init(ks[4], (L, L), dtype=_dt(cfg)),
        "b_x": jnp.zeros((L,), jnp.float32),
        "lam": jnp.full((L,), 0.7, jnp.float32),
        "out": dense_init(jax.random.fold_in(key, 9), (L, d), dtype=_dt(cfg)),
    }


def _ffn_params(key, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "ln2": jnp.zeros((d,), jnp.float32),
        "wi_gate": dense_init(ks[0], (d, f), dtype=_dt(cfg)),
        "wi_up": dense_init(ks[1], (d, f), dtype=_dt(cfg)),
        "wo_ff": dense_init(ks[2], (f, d), dtype=_dt(cfg)),
    }


def _moe_params(key, cfg: ModelConfig) -> Params:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 7)
    p = {
        "ln2": jnp.zeros((d,), jnp.float32),
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, f), in_axis=1, dtype=_dt(cfg)),
        "w_up": dense_init(ks[2], (E, d, f), in_axis=1, dtype=_dt(cfg)),
        "w_down": dense_init(ks[3], (E, f, d), in_axis=1, dtype=_dt(cfg)),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        p["sh_gate"] = dense_init(ks[4], (d, fs), dtype=_dt(cfg))
        p["sh_up"] = dense_init(ks[5], (d, fs), dtype=_dt(cfg))
        p["sh_down"] = dense_init(ks[6], (fs, d), dtype=_dt(cfg))
    return p


def init_block_params(key, cfg: ModelConfig, block: Tuple[str, str]) -> Params:
    mixing, ffn = block
    k1, k2 = jax.random.split(key)
    if mixing in ("global", "local", "enc"):
        p = _attn_params(k1, cfg, cross=False, gated=False)
    elif mixing == "dec_cross":
        p = _attn_params(k1, cfg, cross=True, gated=False)
    elif mixing == "cross":
        p = _attn_params(k1, cfg, cross=True, gated=True)
        # pure-cross layers have no self-attention projections
        for k in ("wq", "wk", "wv", "wo"):
            del p[k]
    elif mixing == "mla":
        p = _mla_params(k1, cfg)
    elif mixing == "ssm":
        p = _ssm_params(k1, cfg)
    elif mixing == "recurrent":
        p = _rec_params(k1, cfg)
    else:
        raise ValueError(mixing)
    if ffn == "dense":
        p.update(_ffn_params(k2, cfg))
    elif ffn == "moe":
        p.update(_moe_params(k2, cfg))
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    prefix, n_cycles, suffix = cfg.layer_stack
    keys = jax.random.split(key, 8)
    params: Params = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), _dt(cfg)),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), dtype=_dt(cfg)
        )
    params["prefix"] = [
        init_block_params(jax.random.fold_in(keys[2], i), cfg, b)
        for i, b in enumerate(prefix)
    ]
    stacked = []
    for p_idx, block in enumerate(cfg.block_pattern):
        ck = jax.random.split(jax.random.fold_in(keys[3], p_idx), max(n_cycles, 1))
        stacked.append(
            jax.vmap(lambda k: init_block_params(k, cfg, block))(ck)
            if n_cycles
            else None
        )
    params["cycles"] = stacked
    params["suffix"] = [
        init_block_params(jax.random.fold_in(keys[4], i), cfg, b)
        for i, b in enumerate(suffix)
    ]
    if cfg.encoder_layers:
        ek = jax.random.split(keys[5], cfg.encoder_layers)
        params["encoder"] = jax.vmap(
            lambda k: init_block_params(k, cfg, ("enc", "dense"))
        )(ek)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


# ===========================================================================
# Depthwise causal conv (ssm / recurrent blocks)
# ===========================================================================

def causal_conv(x: Array, w: Array, b: Array, state: Optional[Array]):
    """x: (B,S,D); w: (W,D).  state: (B,W-1,D) carried context or None.

    Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(
        xp[:, k : k + x.shape[1]] * w[k][None, None, :] for k in range(W)
    )
    return y + b[None, None, :], xp[:, -(W - 1) :]


def causal_conv_step(x: Array, w: Array, b: Array, state: Array):
    """x: (B,D); state: (B,W-1,D).  Returns (y, new_state)."""
    W = w.shape[0]
    xp = jnp.concatenate([state, x[:, None]], axis=1)  # (B, W, D)
    y = jnp.einsum("bwd,wd->bd", xp, w) + b[None, :]
    return y, xp[:, 1:]


# ===========================================================================
# Block application
# ===========================================================================

def _proj_qkv(p, cfg, h):
    B, S, _ = h.shape
    q = jnp.einsum("bsd,df->bsf", h, p["wq"])
    k = jnp.einsum("bsd,df->bsf", h, p["wk"])
    v = jnp.einsum("bsd,df->bsf", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    return q, k, v


def _self_attention(p, cfg, x, mixing, mode, cache, pos):
    """Self-attention sublayer.  Returns (out, new_cache)."""
    h = rms_norm(x, p["ln1"])
    window = cfg.window if mixing == "local" else 0
    if mode == "decode":
        B = x.shape[0]
        q, k, v = _proj_qkv(p, cfg, h)  # S == 1
        posn = jnp.full((B, 1), pos, jnp.int32)
        q = apply_rope(q, posn, cfg.rope_theta)
        k = apply_rope(k, posn, cfg.rope_theta)
        kvdt = jnp.dtype(cfg.kv_dtype)
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(kvdt), pos, axis=1
        )
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(kvdt), pos, axis=1
        )
        lens = jnp.full((B,), pos + 1, jnp.int32)
        out = decode_attention(
            q[:, 0], kc.astype(q.dtype), vc.astype(q.dtype), lens, window=window
        )
        out = out[:, None]
        new_cache = {"k": kc, "v": vc}
    else:
        B, S, _ = x.shape
        q, k, v = _proj_qkv(p, cfg, h)
        posn = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        if mixing != "enc":
            q = apply_rope(q, posn, cfg.rope_theta)
            k = apply_rope(k, posn, cfg.rope_theta)
        q = constrain(q, "q_heads")
        k = constrain(k, "kv_heads")
        v = constrain(v, "kv_heads")
        if mixing == "local" and window and mode == "prefill":
            # inference-only: banded single-shot softmax (fewest passes);
            # training uses pair-skip flash whose custom VJP avoids the
            # S x band probability stack in the backward.
            out = banded_local_attention(q, k, v, window=window)
        else:
            # flash with window does block-level skip (O(S*window))
            out = flash_attention(
                q, k, v, causal=(mixing != "enc"),
                window=window if mixing == "local" else 0,
            )
        new_cache = None
        if mode == "prefill":
            kvdt = jnp.dtype(cfg.kv_dtype)
            new_cache = {"k": k.astype(kvdt), "v": v.astype(kvdt)}
    out = constrain(out.reshape(*x.shape[:-1], -1), "act_heads")
    return jnp.einsum("bsf,fd->bsd", out, p["wo"]), new_cache


def _cross_attention(p, cfg, x, enc_out, mode, cache):
    """Cross-attention sublayer (whisper dec / vlm).  enc_out may be None in
    decode mode (cached KV used instead)."""
    h = rms_norm(x, p["lnc"])
    B, S, _ = h.shape
    q = jnp.einsum("bsd,df->bsf", h, p["wq_c"]).reshape(
        B, S, cfg.n_heads, cfg.head_dim
    )
    if mode == "decode":
        ck = cache["ck"].astype(q.dtype)
        cv = cache["cv"].astype(q.dtype)
        lens = jnp.full((B,), ck.shape[1], jnp.int32)
        out = decode_attention(q[:, 0], ck, cv, lens)[:, None]
        new_cache = None  # cross KV is static
    else:
        Se = enc_out.shape[1]
        ck = jnp.einsum("bsd,df->bsf", enc_out, p["wk_c"]).reshape(
            B, Se, cfg.n_kv_heads, cfg.head_dim
        )
        cv = jnp.einsum("bsd,df->bsf", enc_out, p["wv_c"]).reshape(
            B, Se, cfg.n_kv_heads, cfg.head_dim
        )
        out = flash_attention(q, ck, cv, causal=False)
        kvdt = jnp.dtype(cfg.kv_dtype)
        new_cache = (
            {"ck": ck.astype(kvdt), "cv": cv.astype(kvdt)}
            if mode == "prefill"
            else None
        )
    return (
        jnp.einsum("bsf,fd->bsd", out.reshape(B, S, -1), p["wo_c"]),
        new_cache,
    )


def _mla_attention(p, cfg, x, mode, cache, pos):
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    H = cfg.n_heads
    scale = (dn + dr) ** -0.5
    h = rms_norm(x, p["ln1"])
    B, S, _ = h.shape
    cq = rms_norm(jnp.einsum("bsd,dl->bsl", h, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("bsl,lf->bsf", cq, p["wq_b"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv = jnp.einsum("bsd,dl->bsl", h, p["wkv_a"])
    latent = rms_norm(kv[..., : cfg.kv_lora], p["kv_norm"])
    k_rope = kv[..., cfg.kv_lora :]  # (B, S, dr) shared across heads

    if mode == "decode":
        posn = jnp.full((B, 1), pos, jnp.int32)
        q_rope = apply_rope(q_rope, posn, cfg.rope_theta)
        k_rope = apply_rope(k_rope[:, :, None], posn, cfg.rope_theta)[:, :, 0]
        kvdt = jnp.dtype(cfg.kv_dtype)
        lat_c = jax.lax.dynamic_update_slice_in_dim(
            cache["lat"], latent.astype(kvdt), pos, axis=1
        )
        rk_c = jax.lax.dynamic_update_slice_in_dim(
            cache["rk"], k_rope.astype(kvdt), pos, axis=1
        )
        lens = jnp.full((B,), pos + 1, jnp.int32)
        out = mla_decode_attention(
            q_nope[:, 0], q_rope[:, 0],
            lat_c.astype(latent.dtype), rk_c.astype(latent.dtype),
            p["w_uk"], p["w_uv"], lens, scale=scale,
        )[:, None]
        new_cache = {"lat": lat_c, "rk": rk_c}
    else:
        posn = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        q_rope = apply_rope(q_rope, posn, cfg.rope_theta)
        k_rope_r = apply_rope(k_rope[:, :, None], posn, cfg.rope_theta)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_nope = jnp.einsum("bsl,hnl->bshn", latent, p["w_uk"])
        v = jnp.einsum("bsl,hlv->bshv", latent, p["w_uv"])
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope_r, (B, S, H, dr))], axis=-1
        )
        out = flash_attention(q_full, k_full, v, causal=True, scale=scale)
        # Cache stores the *roped* shared key (decode scores against it).
        kvdt = jnp.dtype(cfg.kv_dtype)
        new_cache = (
            {"lat": latent.astype(kvdt), "rk": k_rope_r[:, :, 0].astype(kvdt)}
            if mode == "prefill"
            else None
        )
    out = out.reshape(B, S, H * dv)
    return jnp.einsum("bsf,fd->bsd", out, p["wo"]), new_cache


def _ssm_block(p, cfg, x, mode, cache):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    h = rms_norm(x, p["ln1"])
    zxbcdt = jnp.einsum("bsd,df->bsf", h, p["in_proj"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * G * N]
    dt_raw = zxbcdt[..., 2 * di + 2 * G * N :]  # (B, S, H)

    if mode == "decode":
        y_c, conv_state = causal_conv_step(
            xbc[:, 0], p["conv_w"], p["conv_b"], cache["conv"]
        )
        xbc = y_c[:, None]
    else:
        xbc, conv_state = causal_conv(xbc, p["conv_w"], p["conv_b"], None)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di]
    b = xbc[..., di : di + G * N].reshape(*xbc.shape[:2], G, N)
    c = xbc[..., di + G * N :].reshape(*xbc.shape[:2], G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(*xs.shape[:2], H, P) * dt[..., None].astype(xs.dtype)
    a = -jnp.exp(p["a_log"]) * dt  # (B, S, H)

    if mode == "decode":
        y, h_new = ssd_decode_step(cache["h"], xh[:, 0], a[:, 0], b[:, 0], c[:, 0])
        y = y[:, None]
        new_cache = {"h": h_new, "conv": conv_state}
    else:
        y, h_final = ssd_chunked(xh, a, b, c, min(cfg.ssm_chunk, xs.shape[1]))
        new_cache = (
            {"h": h_final, "conv": conv_state} if mode == "prefill" else None
        )
    y = y + p["d_skip"][:, None].astype(y.dtype) * xs.reshape(
        *y.shape[:2], H, P
    )
    y = y.reshape(*x.shape[:-1], di)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"])
    return jnp.einsum("bsf,fd->bsd", y, p["out_proj"]), new_cache


def _recurrent_block(p, cfg, x, mode, cache):
    h = rms_norm(x, p["ln1"])
    xb = jnp.einsum("bsd,dl->bsl", h, p["in_x"])
    gate = jnp.einsum("bsd,dl->bsl", h, p["in_gate"])
    if mode == "decode":
        y_c, conv_state = causal_conv_step(
            xb[:, 0], p["conv_w"], p["conv_b"], cache["conv"]
        )
        y, h_new = rglru_decode_step(
            cache["h"], y_c, p["w_a"], p["b_a"], p["w_x"], p["b_x"], p["lam"]
        )
        y = y[:, None]
        new_cache = {"h": h_new, "conv": conv_state}
    else:
        xb, conv_state = causal_conv(xb, p["conv_w"], p["conv_b"], None)
        y, h_final = rglru_scan(
            xb, p["w_a"], p["b_a"], p["w_x"], p["b_x"], p["lam"]
        )
        new_cache = (
            {"h": h_final, "conv": conv_state} if mode == "prefill" else None
        )
    out = jax.nn.gelu(gate.astype(jnp.float32)).astype(y.dtype) * y
    return jnp.einsum("bsl,ld->bsd", out, p["out"]), new_cache


def _ffn(p, cfg, x, ffn_kind):
    h = rms_norm(x, p["ln2"])
    if ffn_kind == "dense":
        return gated_mlp(h, p["wi_gate"], p["wi_up"], p["wo_ff"], cfg.act), 0.0
    # MoE
    B, S, d = h.shape
    flat = h.reshape(B * S, d)
    out = moe_ffn(
        flat, p["router"], p["w_gate"], p["w_up"], p["w_down"],
        top_k=cfg.top_k, capacity_factor=cfg.capacity_factor, act=cfg.act,
    )
    y = out.y.reshape(B, S, d)
    if cfg.n_shared_experts:
        y = y + gated_mlp(h, p["sh_gate"], p["sh_up"], p["sh_down"], cfg.act)
    return y, out.aux_loss


def apply_block(
    p: Params,
    cfg: ModelConfig,
    block: Tuple[str, str],
    x: Array,
    *,
    mode: str,
    cache: Optional[Params] = None,
    pos=0,
    enc_out: Optional[Array] = None,
):
    """Returns (x, new_cache, aux_loss)."""
    mixing, ffn_kind = block
    new_cache = None
    if mixing in ("global", "local", "enc"):
        out, new_cache = _self_attention(p, cfg, x, mixing, mode, cache, pos)
        x = x + out
    elif mixing == "dec_cross":
        out, sc = _self_attention(p, cfg, x, "global", mode, cache, pos)
        x = x + out
        out, cc = _cross_attention(p, cfg, x, enc_out, mode, cache)
        x = x + out
        if mode == "prefill":
            new_cache = {**sc, **cc}
        elif mode == "decode":
            new_cache = {**sc, "ck": cache["ck"], "cv": cache["cv"]}
    elif mixing == "cross":
        out, cc = _cross_attention(p, cfg, x, enc_out, mode, cache)
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * out
        if mode == "prefill":
            new_cache = cc
        elif mode == "decode":
            new_cache = {"ck": cache["ck"], "cv": cache["cv"]}
    elif mixing == "mla":
        out, new_cache = _mla_attention(p, cfg, x, mode, cache, pos)
        x = x + out
    elif mixing == "ssm":
        out, new_cache = _ssm_block(p, cfg, x, mode, cache)
        x = x + out
    elif mixing == "recurrent":
        out, new_cache = _recurrent_block(p, cfg, x, mode, cache)
        x = x + out
    else:
        raise ValueError(mixing)

    aux = jnp.float32(0.0)
    if ffn_kind != "none":
        out, aux_l = _ffn(p, cfg, x, ffn_kind)
        if mixing == "cross":
            out = jnp.tanh(p["gate_mlp"]).astype(x.dtype) * out
        x = x + out
        aux = aux + aux_l
    x = constrain(x, "act")
    return x, new_cache, aux


# ===========================================================================
# Full-model forward passes
# ===========================================================================

def _embed(params, cfg, tokens):
    x = params["embed"][tokens]
    # gemma-family scales embeddings by sqrt(d_model)
    if cfg.name.startswith(("gemma", "recurrentgemma")):
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return constrain(x, "act")


def unembed(params, cfg, x):
    x = rms_norm(x, params["final_norm"])
    # f32 logits come from the MXU accumulator (preferred_element_type), not
    # from upcasting inputs — avoids XLA hoisting a full-tensor f32 convert
    # out of the CE chunk loop (measured +5 GB/device on qwen train_4k).
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", x, params["embed"],
            preferred_element_type=jnp.float32,
        )
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", x, params["lm_head"],
            preferred_element_type=jnp.float32,
        )
    logits = softcap(logits, cfg.logit_softcap)
    return constrain(logits, "logits")


def run_encoder(params, cfg, frames):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    x = frames

    def enc_cycle(x, p):
        x, _, _ = apply_block(p, cfg, ("enc", "dense"), x, mode="train")
        return x, None

    x, _ = jax.lax.scan(enc_cycle, x, params["encoder"])
    return rms_norm(x, params["enc_norm"])


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: Array,
    *,
    enc_inputs: Optional[Array] = None,
    remat: bool = True,
    remat_group: int = 0,
) -> Tuple[Array, Array]:
    """Training forward.  Returns (hidden (B,S,D), total aux loss)."""
    prefix, n_cycles, suffix = cfg.layer_stack
    enc_out = None
    if cfg.encoder_layers:
        enc_out = run_encoder(params, cfg, enc_inputs)
    elif cfg.n_image_tokens:
        enc_out = enc_inputs

    x = _embed(params, cfg, tokens)
    aux_total = jnp.float32(0.0)

    for p, b in zip(params["prefix"], prefix):
        x, _, aux = apply_block(p, cfg, b, x, mode="train", enc_out=enc_out)
        aux_total += aux

    def cycle_fn(x, pslices):
        aux_c = jnp.float32(0.0)
        for p, b in zip(pslices, cfg.block_pattern):
            x, _, aux = apply_block(p, cfg, b, x, mode="train", enc_out=enc_out)
            aux_c += aux
        return x, aux_c

    if n_cycles:
        body = jax.checkpoint(cycle_fn) if remat else cycle_fn
        if remat_group > 1 and n_cycles % remat_group == 0:
            # Two-level (sqrt-style) remat: outer scan over groups keeps
            # O(n_cycles / G) residency; inner scan recomputes within a group.
            def group_fn(x, pgroup):
                x, auxs_g = jax.lax.scan(body, x, pgroup)
                return x, auxs_g.sum()

            grouped = jax.tree.map(
                lambda a: a.reshape(
                    n_cycles // remat_group, remat_group, *a.shape[1:]
                ),
                tuple(params["cycles"]),
            )
            gbody = jax.checkpoint(group_fn) if remat else group_fn
            x, auxs = jax.lax.scan(gbody, x, grouped)
        else:
            x, auxs = jax.lax.scan(body, x, tuple(params["cycles"]))
        aux_total += auxs.sum()

    for p, b in zip(params["suffix"], suffix):
        x, _, aux = apply_block(p, cfg, b, x, mode="train", enc_out=enc_out)
        aux_total += aux

    return x, aux_total


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Parameter count from abstract init (no allocation)."""
    tree = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg)
    )

    def size(path, leaf):
        n = int(np.prod(leaf.shape))
        name = path[-1] if path else ""
        if active_only and name in ("w_gate", "w_up", "w_down") and cfg.n_experts:
            n = n * cfg.top_k // cfg.n_experts
        return n

    total = 0

    def walk(node, path):
        nonlocal total
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, path + [k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path)
        elif node is not None:
            total += size(path, node)

    walk(tree, [])
    return total


# ===========================================================================
# Serving: cache construction, prefill, decode
# ===========================================================================

def _block_cache_shapes(cfg: ModelConfig, block, B: int, S: int):
    """Zero-state cache entries for one block."""
    mixing, _ = block
    dt = jnp.dtype(cfg.kv_dtype)
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    if mixing in ("global", "local", "enc"):
        return {
            "k": jnp.zeros((B, S, Hkv, Dh), dt),
            "v": jnp.zeros((B, S, Hkv, Dh), dt),
        }
    if mixing == "dec_cross":
        return {
            "k": jnp.zeros((B, S, Hkv, Dh), dt),
            "v": jnp.zeros((B, S, Hkv, Dh), dt),
            "ck": jnp.zeros((B, cfg.n_frames, Hkv, Dh), dt),
            "cv": jnp.zeros((B, cfg.n_frames, Hkv, Dh), dt),
        }
    if mixing == "cross":
        return {
            "ck": jnp.zeros((B, cfg.n_image_tokens, Hkv, Dh), dt),
            "cv": jnp.zeros((B, cfg.n_image_tokens, Hkv, Dh), dt),
        }
    if mixing == "mla":
        return {
            "lat": jnp.zeros((B, S, cfg.kv_lora), dt),
            "rk": jnp.zeros((B, S, cfg.rope_head_dim), dt),
        }
    if mixing == "ssm":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        return {
            "h": jnp.zeros(
                (B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
            ),
            "conv": jnp.zeros((B, cfg.conv_width - 1, conv_dim), _dt(cfg)),
        }
    if mixing == "recurrent":
        return {
            "h": jnp.zeros((B, cfg.lru_width), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_width - 1, cfg.lru_width), _dt(cfg)),
        }
    raise ValueError(mixing)


def make_cache(cfg: ModelConfig, B: int, S: int) -> Params:
    """Zero-initialised decode cache for the whole stack."""
    prefix, n_cycles, suffix = cfg.layer_stack

    def stack(entry):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_cycles, *x.shape)), entry
        )

    return {
        "prefix": [_block_cache_shapes(cfg, b, B, S) for b in prefix],
        "cycles": [
            stack(_block_cache_shapes(cfg, b, B, S)) for b in cfg.block_pattern
        ],
        "suffix": [_block_cache_shapes(cfg, b, B, S) for b in suffix],
    }


def _pad_cache_seq(entry: Params, cache_size: int) -> Params:
    """Pad the sequence dim of prefill cache entries up to cache_size."""
    def pad(name, val):
        if name in ("k", "v", "lat", "rk"):
            pad_len = cache_size - val.shape[1]
            if pad_len > 0:
                cfgpad = [(0, 0)] * val.ndim
                cfgpad[1] = (0, pad_len)
                return jnp.pad(val, cfgpad)
        return val

    return {k: pad(k, v) for k, v in entry.items()}


def prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: Array,
    cache_size: Optional[int] = None,
    enc_inputs: Optional[Array] = None,
):
    """Full-sequence prefill.  Returns (last-position logits, cache)."""
    prefix, n_cycles, suffix = cfg.layer_stack
    cache_size = cache_size or tokens.shape[1]
    enc_out = None
    if cfg.encoder_layers:
        enc_out = run_encoder(params, cfg, enc_inputs)
    elif cfg.n_image_tokens:
        enc_out = enc_inputs

    x = _embed(params, cfg, tokens)
    pre_caches = []
    for p, b in zip(params["prefix"], prefix):
        x, c, _ = apply_block(p, cfg, b, x, mode="prefill", enc_out=enc_out)
        pre_caches.append(_pad_cache_seq(c, cache_size))

    def cycle_fn(x, pslices):
        cs = []
        for p, b in zip(pslices, cfg.block_pattern):
            x, c, _ = apply_block(p, cfg, b, x, mode="prefill", enc_out=enc_out)
            cs.append(_pad_cache_seq(c, cache_size))
        return x, tuple(cs)

    cyc_caches = []
    if n_cycles:
        x, ys = jax.lax.scan(cycle_fn, x, tuple(params["cycles"]))
        cyc_caches = list(ys)

    suf_caches = []
    for p, b in zip(params["suffix"], suffix):
        x, c, _ = apply_block(p, cfg, b, x, mode="prefill", enc_out=enc_out)
        suf_caches.append(_pad_cache_seq(c, cache_size))

    logits = unembed(params, cfg, x[:, -1:])
    cache = {"prefix": pre_caches, "cycles": cyc_caches, "suffix": suf_caches}
    return logits, cache


def decode_step(
    params: Params, cfg: ModelConfig, cache: Params, token: Array, pos
):
    """One-token decode.  token: (B, 1) int32; pos: scalar int32 (current
    cache length / write position, uniform across the batch).

    Returns (logits (B, 1, V), new_cache)."""
    prefix, n_cycles, suffix = cfg.layer_stack
    x = _embed(params, cfg, token)

    new_prefix = []
    for p, b, c in zip(params["prefix"], prefix, cache["prefix"]):
        x, nc, _ = apply_block(p, cfg, b, x, mode="decode", cache=c, pos=pos)
        new_prefix.append(nc)

    def cycle_fn(x, xs):
        pslices, cslices = xs
        ncs = []
        for p, b, c in zip(pslices, cfg.block_pattern, cslices):
            x, nc, _ = apply_block(p, cfg, b, x, mode="decode", cache=c, pos=pos)
            ncs.append(nc)
        return x, tuple(ncs)

    new_cycles = []
    if n_cycles:
        x, ys = jax.lax.scan(
            cycle_fn, x, (tuple(params["cycles"]), tuple(cache["cycles"]))
        )
        new_cycles = list(ys)

    new_suffix = []
    for p, b, c in zip(params["suffix"], suffix, cache["suffix"]):
        x, nc, _ = apply_block(p, cfg, b, x, mode="decode", cache=c, pos=pos)
        new_suffix.append(nc)

    logits = unembed(params, cfg, x)
    new_cache = {"prefix": new_prefix, "cycles": new_cycles, "suffix": new_suffix}
    return logits, new_cache
