"""Shared layer primitives: norms, activations, RoPE, embeddings, MLP."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    """RMSNorm with f32 variance accumulation but NO full-tensor upcast.

    Upcasting x to f32 here makes XLA hoist the convert out of the layer scan
    and store f32 residuals for the backward pass — measured +3.2 GB/device
    on qwen train_4k.  The (B,S,1) variance is f32; the normalise/scale
    multiply stays in the compute dtype.
    """
    var = (
        jnp.einsum(
            "...d,...d->...", x, x, preferred_element_type=jnp.float32
        )
        / x.shape[-1]
    )[..., None]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def gated_mlp(x: Array, wi_gate: Array, wi_up: Array, wo: Array, act: str) -> Array:
    """SwiGLU / GeGLU feed-forward."""
    g = act_fn(act)(jnp.einsum("...d,df->...f", x, wi_gate))
    u = jnp.einsum("...d,df->...f", x, wi_up)
    return jnp.einsum("...f,fd->...d", g * u, wo)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: Array, cap: float) -> Array:
    if cap <= 0.0:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16) -> Array:
    fan_in = shape[in_axis]
    std = 1.0 / jnp.sqrt(jnp.float32(fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
