"""Mixture-of-Experts FFN: top-k router + sort-based fixed-capacity dispatch.

Dispatch avoids the GShard O(T·E·C·d) one-hot einsum: (token, expert) pairs
are sorted by expert id (fixed-shape ``argsort``), written into an (E, C, d)
buffer by their rank within the expert segment, processed with one batched
per-expert matmul (MXU), and combined back with the router gates.  Overflow
beyond capacity ``C = ceil(cf · T · k / E)`` is dropped (standard).

Parallelism: tensor-parallel experts — the expert weight tensors are sharded
on the ``d_expert`` axis over "model" (no all-to-all).  An expert-parallel
all_to_all dispatch (experts over "model") is the next lever for the MoE
train cells (EXPERIMENTS.md §Perf stopping note); it requires a shard_map
rewrite of this function and is left as the documented follow-up.

Returns the load-balancing auxiliary loss alongside the output.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import act_fn

Array = jax.Array


class MoEOut(NamedTuple):
    y: Array
    aux_loss: Array


def capacity(tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(tokens * top_k * factor / n_experts) + 1
    return -(-c // 8) * 8  # sublane-aligned


def moe_ffn(
    x: Array,  # (T, d)
    router_w: Array,  # (d, E) — kept/used in float32
    w_gate: Array,  # (E, d, f)
    w_up: Array,  # (E, d, f)
    w_down: Array,  # (E, f, d)
    *,
    top_k: int,
    capacity_factor: float,
    act: str = "silu",
    renormalize: bool = True,
) -> MoEOut:
    T, d = x.shape
    E = router_w.shape[1]
    C = capacity(T, top_k, E, capacity_factor)

    logits = jnp.einsum(
        "td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gates, idx = jax.lax.top_k(probs, top_k)  # (T, k)
    if renormalize:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Load-balancing loss (Switch-style): E * sum_e f_e * p_e.
    me = probs.mean(axis=0)
    ce = jnp.zeros(E, jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * top_k)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch -------------------------------------------
    TK = T * top_k
    eid = idx.reshape(-1)
    tid = jnp.repeat(jnp.arange(T), top_k)
    g = gates.reshape(-1)
    order = jnp.argsort(eid, stable=True)
    eid_s, tid_s, g_s = eid[order], tid[order], g[order]
    seg_start = jnp.searchsorted(eid_s, jnp.arange(E))
    slot = jnp.arange(TK) - seg_start[eid_s]
    keep = slot < C
    buf = jnp.where(keep, eid_s * C + jnp.minimum(slot, C - 1), E * C)

    xin = jnp.zeros((E * C + 1, d), x.dtype).at[buf].set(x[tid_s])
    h = xin[: E * C].reshape(E, C, d)

    # ---- batched per-expert gated MLP (MXU) ----------------------------
    hg = act_fn(act)(
        jnp.einsum("ecd,edf->ecf", h, w_gate, preferred_element_type=jnp.float32)
    ).astype(x.dtype)
    hu = jnp.einsum("ecd,edf->ecf", h, w_up)
    out = jnp.einsum("ecf,efd->ecd", hg * hu, w_down)

    # ---- combine --------------------------------------------------------
    contrib = out.reshape(E * C, d)
    picked = jnp.where(keep[:, None], contrib[jnp.minimum(buf, E * C - 1)], 0.0)
    y = (
        jnp.zeros((T, d), jnp.float32)
        .at[tid_s]
        .add(picked.astype(jnp.float32) * g_s[:, None].astype(jnp.float32))
    )
    return MoEOut(y.astype(x.dtype), aux)


def moe_ffn_ref(
    x, router_w, w_gate, w_up, w_down, *, top_k, act="silu", renormalize=True
):
    """Dense per-token reference (no capacity drops) — test oracle."""
    logits = jnp.einsum(
        "td,de->te", x.astype(jnp.float32), router_w.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    if renormalize:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(x, dtype=jnp.float32)
    for kk in range(top_k):
        wg = w_gate[idx[:, kk]]  # (T, d, f)
        wu = w_up[idx[:, kk]]
        wd = w_down[idx[:, kk]]
        hg = act_fn(act)(jnp.einsum("td,tdf->tf", x, wg).astype(jnp.float32))
        hu = jnp.einsum("td,tdf->tf", x, wu).astype(jnp.float32)
        o = jnp.einsum("tf,tfd->td", (hg * hu).astype(x.dtype), wd)
        y += gates[:, kk : kk + 1].astype(jnp.float32) * o.astype(jnp.float32)
    return y.astype(x.dtype)
