"""The seven built-in backends behind ``repro.cluster.cluster``.

Thin adapters from the registry's uniform contract onto the state-threading
tiers in ``repro.core`` / ``repro.kernels`` (DESIGN.md §3):

=========== ============================== ========== ========= =========
name        implementation                 state kind resumable bit-exact
=========== ============================== ========== ========= =========
oracle      dict Algorithm 1 (paper space) cluster    yes       yes
dense       numpy loop, node-id space      cluster    yes       yes
scan        jax.lax.scan, 1 edge/step      cluster    yes       yes
chunked     Jacobi chunks on the VPU       cluster    yes (†)   no
pallas      serial-in-VMEM Pallas kernel   cluster    yes       yes
multiparam  one-pass multi-v_max sweep     sweep      yes       yes (‡)
distributed sharded local + merge pass     sharded    yes       no
=========== ============================== ========== ========= =========

Every tier is resumable: *resumable + out-of-core is the invariant, not the
special case* — each backend's ``fn`` is pure state threading over one edge
batch, and the two wide-state tiers derive labels at finalize time via
``finalize_fn`` (selection for the sweep, the contracted merge for the
sharded tier).

† chunked partial_fit is deterministic but batch boundaries are Jacobi chunk
  boundaries, so labels depend on how the stream was batched.
‡ per sweep entry; the selected entry equals a scan run at that v_max.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import multiparam as _multiparam
from repro.core.chunked import chunked_update, chunked_update_megabatch
from repro.core.decode import chunked_decode_update_megabatch
from repro.core.distributed import merge_sharded_state, sharded_update
from repro.core.fleet import fleet_update_chunked, fleet_update_scan
from repro.core.state import ClusterState, ShardedState, SweepState
from repro.core.streaming import dense_update, oracle_init, oracle_update, scan_update
from repro.cluster.registry import BackendResult, register_backend
from repro.core.wavefront import wavefront_update_megabatch
from repro.kernels.edge_stream.ops import (
    pallas_decode_update_megabatch,
    pallas_fleet_update,
    pallas_update,
    pallas_update_megabatch,
    pallas_wavefront_update,
)


# ---------------------------------------------------------------------------
# Sequential tiers (bit-exact with the paper's Algorithm 1)
# ---------------------------------------------------------------------------

@register_backend(
    "oracle",
    init_fn=lambda config: oracle_init(config.n),
    resumable=True,
    bit_exact=True,
    label_space="oracle",
    description="paper-faithful dictionary Algorithm 1 (pure Python)",
)
def _oracle(edges, config, state, mesh=None) -> BackendResult:
    state = oracle_update(state, np.asarray(edges), int(config.v_max))
    c = np.asarray(state.c)
    # Unseen nodes (label 0) become their own singletons, mirroring the dense
    # layout where an untouched node keeps its own id.
    labels = np.where(c > 0, c, config.n + 1 + np.arange(config.n))
    return BackendResult(state=state, labels=labels, info={})


@register_backend(
    "dense",
    resumable=True,
    bit_exact=True,
    description="dense-array Algorithm 1 (numpy loop, node-id label space)",
)
def _dense(edges, config, state, mesh=None) -> BackendResult:
    state = dense_update(state, np.asarray(edges), int(config.v_max))
    return BackendResult(state=state, labels=state.c, info={})


def _scan_fleet(edges, config, state) -> BackendResult:
    """Vmapped fleet ingest of one (T, B, 2) slab: per-tenant rows bit-exact
    with single-stream :func:`scan_update` over each tenant's own slabs."""
    state = fleet_update_scan(
        state.to_device(), jnp.asarray(edges), jnp.int32(config.v_max)
    )
    return BackendResult(state=state, labels=None, info={})


@register_backend(
    "scan",
    resumable=True,
    bit_exact=True,
    fleet_fn=_scan_fleet,
    description="jax.lax.scan port, one edge per step (on-device oracle)",
)
def _scan(edges, config, state, mesh=None) -> BackendResult:
    state = scan_update(
        state.to_device(), jnp.asarray(edges), jnp.int32(config.v_max)
    )
    return BackendResult(state=state, labels=state.c, info={})


def _pallas_megabatch(edges, config, state) -> BackendResult:
    """Fused (K, B, 2) ingest: one double-buffered-DMA kernel launch for the
    whole megabatch, state VMEM-resident throughout (bit-exact)."""
    state = pallas_update_megabatch(
        state.to_device(),
        jnp.asarray(edges),
        int(config.v_max),
        chunk=config.chunk,
        interpret=config.interpret,
    )
    return BackendResult(state=state, labels=state.c, info={})


def _pallas_wavefront(plan, config, state) -> BackendResult:
    """Wavefront ingest of one planned megabatch (DESIGN.md §12): vectorised
    node-disjoint waves with a runtime community-collision fallback, labels
    bit-identical to :func:`_pallas_megabatch` over the same stream.

    In interpret mode the Pallas kernel would trace every wave through the
    emulator, so we dispatch the pure-JAX reference apply instead — same
    wave math (``repro.core.wavefront``), real vector units; the kernel
    launch path is reserved for ``interpret=False`` hardware runs (and is
    pinned against the reference by the wavefront test suite)."""
    if config.interpret:
        state, stats = wavefront_update_megabatch(
            state.to_device(),
            jnp.asarray(plan.waves),
            jnp.asarray(plan.leftover),
            jnp.asarray(plan.meta),
            int(config.v_max),
        )
    else:
        state, stats = pallas_wavefront_update(
            state.to_device(),
            jnp.asarray(plan.waves),
            jnp.asarray(plan.leftover),
            jnp.asarray(plan.meta),
            int(config.v_max),
            chunk=config.chunk,
            interpret=False,
        )
    return BackendResult(
        state=state, labels=state.c, info={"wavefront_stats": stats}
    )


def _pallas_decode(cmega, config, state) -> BackendResult:
    """Device-resident compressed ingest (DESIGN.md §14): one fused
    decode→update dispatch per :class:`~repro.graph.pipeline
    .CompressedMegaBatch` — on hardware the DVE3 lanes never leave the
    chip (``kernel.edge_stream_decode_update_kernel`` unpacks descriptor
    ``t+1``'s byte span while ``t``'s decoded window runs the per-edge
    loop); in interpret mode the pure-JAX reference decode composes with
    the megabatch kernel under the same jit.  Labels bit-identical to
    host-decoding the same rows through :func:`_pallas_megabatch`."""
    state = pallas_decode_update_megabatch(
        state.to_device(),
        jnp.asarray(cmega.payload),
        jnp.asarray(cmega.desc),
        int(config.v_max),
        cmega.window,
        cmega.out_rows,
        chunk=config.chunk,
        interpret=config.interpret,
    )
    return BackendResult(state=state, labels=state.c, info={})


def _pallas_fleet(edges, config, state) -> BackendResult:
    """Tenant-major fleet kernel: one launch ingests the whole (T, B, 2)
    slab, per-tenant state tiles pipelined HBM→VMEM→HBM (DESIGN.md §13);
    every tenant row bit-exact with the sequential single-stream tiers."""
    state = pallas_fleet_update(
        state.to_device(),
        jnp.asarray(edges),
        int(config.v_max),
        interpret=config.interpret,
    )
    return BackendResult(state=state, labels=None, info={})


@register_backend(
    "pallas",
    resumable=True,
    bit_exact=True,
    chunk_aligned=True,
    megabatch_fn=_pallas_megabatch,
    wavefront_fn=_pallas_wavefront,
    decode_fn=_pallas_decode,
    fleet_fn=_pallas_fleet,
    description="serial-in-VMEM Pallas kernel (bit-exact, TPU-native)",
)
def _pallas(edges, config, state, mesh=None) -> BackendResult:
    state = pallas_update(
        state.to_device(),
        jnp.asarray(edges),
        int(config.v_max),
        chunk=config.chunk,
        interpret=config.interpret,
    )
    return BackendResult(state=state, labels=state.c, info={})


# ---------------------------------------------------------------------------
# Parallel tiers (quality parity measured, not assumed)
# ---------------------------------------------------------------------------

def _chunked_megabatch(edges, config, state) -> BackendResult:
    """Fused (K, B, 2) ingest: one ``lax.scan`` over all K * B / chunk Jacobi
    chunks per dispatch.  Bit-identical to K sequential per-batch calls when
    B is a chunk multiple — which the pipeline guarantees for this
    chunk-aligned backend."""
    state = chunked_update_megabatch(
        state.to_device(),
        jnp.asarray(edges),
        jnp.int32(config.v_max),
        chunk=config.chunk,
    )
    return BackendResult(state=state, labels=state.c, info={})


def _chunked_decode(cmega, config, state) -> BackendResult:
    """Compressed ingest for the Jacobi tier: reference decode + the fused
    chunk scan under one jit (``repro.core.decode``) — one dispatch per
    megabatch, bit-identical to host-decoding the same rows through
    :func:`_chunked_megabatch` (the decoded slab is *defined* to equal the
    host-staged one, and B is a chunk multiple for this chunk-aligned
    backend, so chunk grouping is unchanged)."""
    state = chunked_decode_update_megabatch(
        state.to_device(),
        jnp.asarray(cmega.payload),
        jnp.asarray(cmega.desc),
        int(config.v_max),
        cmega.window,
        cmega.out_rows,
        chunk=config.chunk,
    )
    return BackendResult(state=state, labels=state.c, info={})


def _chunked_fleet(edges, config, state) -> BackendResult:
    """Vmapped fleet ingest of one (T, B, 2) slab: the Jacobi chunk scan
    batched over the tenant axis — per-tenant rows bit-identical to
    single-stream :func:`chunked_update` over each tenant's own slabs
    (chunk grouping restarts per slab, exactly as it restarts per batch)."""
    state = fleet_update_chunked(
        state.to_device(),
        jnp.asarray(edges),
        jnp.int32(config.v_max),
        chunk=config.chunk,
    )
    return BackendResult(state=state, labels=None, info={})


@register_backend(
    "chunked",
    resumable=True,
    bit_exact=False,
    chunk_aligned=True,
    megabatch_fn=_chunked_megabatch,
    decode_fn=_chunked_decode,
    fleet_fn=_chunked_fleet,
    description="Jacobi chunked tier (vectorised decisions, scatter conflict "
    "resolution)",
)
def _chunked(edges, config, state, mesh=None) -> BackendResult:
    state = chunked_update(
        state.to_device(),
        jnp.asarray(edges),
        jnp.int32(config.v_max),
        chunk=config.chunk,
    )
    return BackendResult(state=state, labels=state.c, info={})


def _multiparam_finalize(state: SweepState, config) -> BackendResult:
    """Edge-free selection over the sweep columns; the result's state is the
    selected column as a plain ClusterState (shared ``d``)."""
    sel = _multiparam.select_result(state, criterion=config.criterion)
    best = sel["best_index"]
    selected = state.entry(best)
    info = {
        "best_index": best,
        "best_v_max": sel["best_v_max"],
        "rows": sel["rows"],
        # host snapshot: multiparam_update donates the sweep state, so the
        # live (A, n) array would be consumed by the next partial_fit
        "sweep_labels": np.asarray(state.c),
    }
    return BackendResult(state=selected, labels=selected.c, info=info)


@register_backend(
    "multiparam",
    init_fn=lambda config: SweepState.init(config.n, config.v_maxes),
    resumable=True,
    bit_exact=True,
    state_kind="sweep",
    finalize_fn=_multiparam_finalize,
    description="one-pass multi-v_max sweep + edge-free selection (paper "
    "§2.5), state-threaded",
)
def _multiparam_backend(edges, config, state, mesh=None) -> BackendResult:
    state = _multiparam.multiparam_update(state.to_device(), jnp.asarray(edges))
    return BackendResult(state=state, labels=None, info={})


def _resolved_shards(config) -> int:
    # n_shards is the leading state axis; every API path pins it into the
    # config (api._resolve_config) before init_fn runs.  One resolver only.
    if config.n_shards is None:
        raise ValueError(
            "distributed init_fn needs config.n_shards pinned; go through "
            "repro.cluster.cluster / StreamClusterer, or set it explicitly"
        )
    return int(config.n_shards)


def _distributed_finalize(state: ShardedState, config) -> BackendResult:
    v_max2 = config.v_max2 if config.v_max2 is not None else config.v_max
    labels, merged = merge_sharded_state(
        state, int(v_max2), chunk=config.chunk
    )
    return BackendResult(
        state=merged, labels=labels, info={"n_shards": state.n_shards}
    )


@register_backend(
    "distributed",
    init_fn=lambda config: ShardedState.init(config.n, _resolved_shards(config)),
    resumable=True,
    bit_exact=False,
    state_kind="sharded",
    # NOT chunk_aligned: batches are this tier's unit of shard assignment, so
    # rounding batch_edges up to a chunk multiple would merge windows and
    # starve trailing shards (the chunked tier pads each batch internally).
    finalize_fn=_distributed_finalize,
    description="sharded local passes + contracted merge from per-shard "
    "states (batch-dealt, out-of-core)",
)
def _distributed(edges, config, state, mesh=None) -> BackendResult:
    state = sharded_update(
        state.to_device(),
        jnp.asarray(edges),
        jnp.int32(config.v_max),
        chunk=config.chunk,
    )
    return BackendResult(state=state, labels=None, info={})
