"""The seven built-in backends behind ``repro.cluster.cluster``.

Thin adapters from the registry's uniform contract onto the state-threading
tiers in ``repro.core`` / ``repro.kernels`` (DESIGN.md §3):

======== ============================== ========= =========
name     implementation                 resumable bit-exact
======== ============================== ========= =========
oracle   dict Algorithm 1 (paper space) yes       yes
dense    numpy loop, node-id space      yes       yes
scan     jax.lax.scan, 1 edge/step      yes       yes
chunked  Jacobi chunks on the VPU       yes (†)   no
pallas   serial-in-VMEM Pallas kernel   yes       yes
multiparam  one-pass multi-v_max sweep  no        yes (‡)
distributed local shards + merge pass   no        no
======== ============================== ========= =========

† chunked partial_fit is deterministic but batch boundaries are Jacobi chunk
  boundaries, so labels depend on how the stream was batched.
‡ per sweep entry; the selected entry equals a scan run at that v_max.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multiparam as _multiparam
from repro.core.chunked import chunked_update
from repro.core.distributed import distributed_cluster
from repro.core.state import ClusterState
from repro.core.streaming import dense_update, oracle_init, oracle_update, scan_update
from repro.cluster.registry import BackendResult, register_backend
from repro.kernels.edge_stream.ops import pallas_update


def _require_fresh(state: ClusterState, name: str) -> None:
    if int(state.edges_seen) != 0:
        raise ValueError(
            f"backend {name!r} is one-shot and cannot resume from a non-empty "
            "state; use a resumable backend (oracle/dense/scan/chunked/pallas) "
            "for StreamClusterer.partial_fit"
        )


# ---------------------------------------------------------------------------
# Sequential tiers (bit-exact with the paper's Algorithm 1)
# ---------------------------------------------------------------------------

@register_backend(
    "oracle",
    init_fn=oracle_init,
    resumable=True,
    bit_exact=True,
    label_space="oracle",
    description="paper-faithful dictionary Algorithm 1 (pure Python)",
)
def _oracle(edges, config, state, mesh=None) -> BackendResult:
    state = oracle_update(state, np.asarray(edges), int(config.v_max))
    c = np.asarray(state.c)
    # Unseen nodes (label 0) become their own singletons, mirroring the dense
    # layout where an untouched node keeps its own id.
    labels = np.where(c > 0, c, config.n + 1 + np.arange(config.n))
    return BackendResult(state=state, labels=labels, info={})


@register_backend(
    "dense",
    resumable=True,
    bit_exact=True,
    description="dense-array Algorithm 1 (numpy loop, node-id label space)",
)
def _dense(edges, config, state, mesh=None) -> BackendResult:
    state = dense_update(state, np.asarray(edges), int(config.v_max))
    return BackendResult(state=state, labels=state.c, info={})


@register_backend(
    "scan",
    resumable=True,
    bit_exact=True,
    description="jax.lax.scan port, one edge per step (on-device oracle)",
)
def _scan(edges, config, state, mesh=None) -> BackendResult:
    state = scan_update(
        state.to_device(), jnp.asarray(edges), jnp.int32(config.v_max)
    )
    return BackendResult(state=state, labels=state.c, info={})


@register_backend(
    "pallas",
    resumable=True,
    bit_exact=True,
    chunk_aligned=True,
    description="serial-in-VMEM Pallas kernel (bit-exact, TPU-native)",
)
def _pallas(edges, config, state, mesh=None) -> BackendResult:
    state = pallas_update(
        state.to_device(),
        jnp.asarray(edges),
        int(config.v_max),
        chunk=config.chunk,
        interpret=config.interpret,
    )
    return BackendResult(state=state, labels=state.c, info={})


# ---------------------------------------------------------------------------
# Parallel tiers (quality parity measured, not assumed)
# ---------------------------------------------------------------------------

@register_backend(
    "chunked",
    resumable=True,
    bit_exact=False,
    chunk_aligned=True,
    description="Jacobi chunked tier (vectorised decisions, scatter conflict "
    "resolution)",
)
def _chunked(edges, config, state, mesh=None) -> BackendResult:
    state = chunked_update(
        state.to_device(),
        jnp.asarray(edges),
        jnp.int32(config.v_max),
        chunk=config.chunk,
    )
    return BackendResult(state=state, labels=state.c, info={})


@register_backend(
    "multiparam",
    resumable=False,
    bit_exact=True,
    description="one-pass multi-v_max sweep + edge-free selection (paper §2.5)",
)
def _multiparam_backend(edges, config, state, mesh=None) -> BackendResult:
    _require_fresh(state, "multiparam")
    ej = jnp.asarray(edges)
    sweep = _multiparam.cluster_stream_multiparam(
        ej, jnp.asarray(config.v_maxes, jnp.int32), config.n
    )
    sel = _multiparam.select_result(sweep, criterion=config.criterion)
    best = sel["best_index"]
    state = _multiparam.sweep_state(sweep, best, ej)
    info = {
        "best_index": best,
        "best_v_max": sel["best_v_max"],
        "rows": sel["rows"],
        # select_result above already pulls (A, n) to host once for the
        # edge-free metrics; keeping the device array here avoids storing a
        # second host copy for callers that never read sweep_labels.
        "sweep_labels": sweep.c,
    }
    return BackendResult(state=state, labels=state.c, info=info)


@register_backend(
    "distributed",
    resumable=False,
    bit_exact=False,
    accepts_source=True,
    description="multi-device local shards + contracted global merge pass",
)
def _distributed(edges, config, state, mesh=None) -> BackendResult:
    _require_fresh(state, "distributed")
    n_shards = config.n_shards
    if mesh is None and n_shards is None:
        n_shards = jax.device_count()
    labels, info = distributed_cluster(
        edges,  # array or EdgeSource; sharded out-of-core by ShardedSource
        int(config.v_max),
        config.n,
        mesh=mesh,
        n_shards=n_shards,
        chunk=config.chunk,
        v_max2=config.v_max2,
    )
    return BackendResult(state=None, labels=labels, info=info)
