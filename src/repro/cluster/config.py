""":class:`ClusterConfig` — the single, validated knob surface of the API.

One config drives every backend (DESIGN.md §6).  Validation happens at
construction so a bad parameterization fails before any edges stream.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Optional, Tuple, Union


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """Parameters of one clustering run.

    Args:
      n: number of nodes in the stream's id space (state is ``3n`` ints).
      v_max: the paper's volume threshold (required by every backend except
        ``multiparam``, which sweeps ``v_maxes`` instead).
      backend: registry key — one of ``repro.cluster.available_backends()``
        (``oracle`` / ``dense`` / ``scan`` / ``chunked`` / ``pallas`` /
        ``multiparam`` / ``distributed``).
      chunk: edges per device step for the ``chunked`` / ``pallas`` /
        ``distributed`` tiers (Jacobi batch size resp. DMA granularity).
      batch_edges: edges per ingest batch when streaming from an
        :class:`repro.graph.sources.EdgeSource` (host edge-buffer residency
        is O(batch_edges), the stream itself never materializes).  ``None``
        streams out-of-core sources at a default batch size and keeps
        in-memory arrays on the historical one-shot path; setting it forces
        batched ingestion even for arrays.  Every backend is resumable, so
        this applies uniformly — the sweep streams like any sequential
        tier, and the ``distributed`` tier deals batches onto shards (with
        ``batch_edges`` unset it defaults to one window per shard, capped
        at the default batch size).  Rounded up to a ``chunk`` multiple for
        the chunk-aligned tiers so batching never moves a Jacobi/DMA
        boundary.
      megabatch_k: stack this many consecutive ingest batches into one
        ``(K, batch_edges, 2)`` staging buffer and dispatch them to the
        device *fused* (one ``lax.scan``-over-chunks dispatch for
        ``chunked``, one double-buffered-DMA kernel launch for ``pallas``)
        — ~K-fold fewer dispatches/transfers, labels bit-identical to the
        per-batch path, checkpoint cursors still land on exact batch rows.
        ``None`` (default) keeps per-batch dispatch; set only for backends
        with a fused path (others ignore it).  Host staging memory grows to
        ``(prefetch + 1) * K * batch_edges`` rows — visible in the measured
        ``peak_buffer_bytes``.
      wavefront: wave width ``W`` for the conflict-free wavefront path of
        the ``pallas`` tier (DESIGN.md §12).  When set, the pipeline's
        prefetch thread plans each staged megabatch into contiguous waves
        of up to ``W`` node-disjoint edges and the device applies each wave
        vectorised (gathered loads / scattered stores), with a runtime
        community-collision check falling back to the sequential per-edge
        loop — labels stay bit-identical to every sequential tier.
        Requires ``megabatch_k`` (waves are planned per staged megabatch);
        backends without a wavefront path ignore it.  ``None`` (default)
        keeps the sequential megabatch kernel.  ``"auto"`` lets the planner
        pick ``W`` per megabatch from the observed node-disjoint run-length
        histogram (the width a fixed-``W`` sweep would have chosen for that
        megabatch's structure); the chosen widths surface as the
        ``wavefront_widths`` info counter.  Fixed integer widths plan
        bit-for-bit as before.
      prefetch: how many batches (or megabatches) the ingest pipeline
        produces ahead on its background thread (``None`` → 2, classic
        double buffering).  0 disables the prefetch thread entirely.
      v_maxes: multi-sweep thresholds for ``backend="multiparam"`` (paper
        §2.5: one pass, many parameters).
      criterion: edge-free sweep selector, ``"density"`` or ``"entropy"``.
      n_shards: stream shards for ``backend="distributed"`` (defaults to the
        visible device count — or the mesh's — at state-init time; pinned
        into the config then, since it is the leading axis of the
        :class:`~repro.core.state.ShardedState`).
      v_max2: merge-phase threshold for ``distributed`` (defaults to
        ``v_max``).  The merge clusters the cross-shard identity stream
        built from the per-shard states, so it only has effect when
        ``n_shards > 1`` — a single-shard run is exactly one chunked pass
        at ``v_max``.
      refine: post-stream refinement stage (``repro.cluster.refine``,
        DESIGN.md §11), dispatched at ``finalize()`` for every state kind:
        ``"louvain"`` or ``"labelprop"`` run weighted rounds on the
        contracted supergraph accumulated during the stream (plus
        modularity-scored community merge/split moves); a ``"+replay"``
        suffix (e.g. ``"louvain+replay"``) additionally re-plays the most
        recent ``K*batch_edges`` buffered edges through the refined labels
        — the split-capable stage — before they are discarded.  ``None``
        (default) keeps the raw streamed labels.  Requires a
        dense-label-space backend; runs with ``refine`` set always ingest
        through the streaming path so the sketch sees every batch.
      refine_rounds: refinement rounds on the supergraph (Louvain levels /
        label-propagation sweeps; ``None`` -> 10).
      refine_max_pairs: cap on inter-community sketch entries (``None`` ->
        2**20, a 16 MB ceiling at 16 B/entry).  Overflow evicts the
        lightest pairs into the sketch's ``dropped_weight`` counter —
        bounded memory, never silent truncation.
      wavefront_gap: dead-gap run-merging budget for the wavefront planner
        (DESIGN.md §12/§13).  When set, ``plan_waves`` packs only *live*
        rows into waves, merging contiguous live runs across interior dead
        gaps (PAD / self-loop rows) of up to this many rows — a gap longer
        than the budget closes the wave.  Dead rows are no-ops in every
        tier, so skipping them never reorders live work; occupancy rises on
        PAD-interleaved streams (ragged megabatch tails, fleet-style
        staging).  The plan's ``dead_rows_skipped`` counter surfaces as
        ``wavefront_dead_rows_skipped`` in the finalize info.  ``None``
        (default) keeps the historical plans: dead rows occupy wave slots.
        Requires ``wavefront``.
      device_decode: device-resident compressed ingest (DESIGN.md §14).
        When True and the source is a block-codec file
        (:class:`~repro.graph.sources.CodecFileSource` over a ``.dvc``),
        :meth:`StreamClusterer.fit` stages *compressed payload bytes* plus
        a descriptor table per megabatch instead of decoded edges, and the
        backend's ``decode_fn`` unpacks the DVE3 lanes on device — fused
        with the state update, one dispatch per megabatch, labels
        bit-identical to host decode.  Blocks the device cannot decode
        (varint/u8 fallback, mid-block resume remainders) are host-decoded
        and staged raw; the split surfaces as the ``device_decode_*`` info
        counters.  Requires ``megabatch_k`` and a backend with a
        ``decode_fn`` (``chunked`` / ``pallas``); sources without codec
        blocks (arrays, text files) fall back to host staging.
        Incompatible with ``wavefront`` and ``refine`` (both need
        host-visible decoded edges per megabatch).
      tenants: fleet size ``T`` for the multi-tenant fleet engine
        (``repro.cluster.fleet``, DESIGN.md §13) — the whole fleet's state
        is one ``(T, n)`` :class:`~repro.core.state.FleetState` advanced by
        a single donated dispatch per fleet step.  Only consumed by
        :class:`~repro.cluster.fleet.FleetClusterer` (single-stream entry
        points ignore it); requires a backend with a fleet path
        (``chunked`` / ``scan`` / ``pallas``).
      interpret: run Pallas kernels in interpret mode (True on CPU; set
        False on real TPUs).
      autosave_every: checkpoint the run from inside ``fit`` every this
        many ingested edges (rounded up to batch/megabatch boundaries —
        saves always land on exact resume cursors).  Requires
        ``autosave_dir``.  A killed run resumes from the newest valid
        generation via :meth:`StreamClusterer.restore` with labels
        bit-identical to an uninterrupted run.  ``None`` (default)
        disables autosave.
      autosave_dir: directory for autosave checkpoints (managed by
        :class:`repro.checkpoint.manager.CheckpointManager`: step-atomic
        swaps, per-leaf checksums, fallback to the previous generation on
        a torn newest one).
      on_corrupt: what a checksummed block-codec source does with a block
        that fails its checksum — ``"raise"`` (default, fail loudly) or
        ``"quarantine"`` (skip to the next sync marker, count the loss in
        the ``blocks_quarantined`` / ``edges_lost`` info counters, never
        silently wrong).  Quarantine needs the checksummed ``DVX``
        framing; plain sources ignore this knob.
      on_tenant_fault: fleet policy when one tenant's source dies
        mid-stream — ``"raise"`` (default) or ``"quarantine"`` (the dead
        tenant's remaining rows become PAD no-ops, surviving tenants
        stream on bit-identically; quarantined tenants surface in the
        fleet info).  Only consumed by
        :class:`~repro.cluster.fleet.FleetClusterer`.
      retries: max consecutive transient-read retries per fault in the
        ingest pipeline (``None`` -> 3; 0 disables retry).  Retries
        re-resume the source at the last delivered row, so a stream that
        survives its transients is bit-identical to a fault-free one;
        the attempt count surfaces as the ``ingest_retries`` info counter.
      stall_timeout: hard watchdog (seconds) on the ingest prefetch
        thread — a single produce exceeding it raises
        :class:`~repro.graph.errors.StallError` instead of hanging the
        run.  ``None`` (default) disables the hard watchdog (the
        heartbeat monitor still counts soft stragglers as
        ``ingest_stalls``).
    """

    n: int
    v_max: Optional[int] = None
    backend: str = "chunked"
    chunk: int = 1024
    batch_edges: Optional[int] = None
    megabatch_k: Optional[int] = None
    wavefront: Union[int, str, None] = None
    prefetch: Optional[int] = None
    v_maxes: Optional[Tuple[int, ...]] = None
    criterion: str = "density"
    n_shards: Optional[int] = None
    v_max2: Optional[int] = None
    refine: Optional[str] = None
    refine_rounds: Optional[int] = None
    refine_max_pairs: Optional[int] = None
    wavefront_gap: Optional[int] = None
    tenants: Optional[int] = None
    device_decode: bool = False
    interpret: bool = True
    autosave_every: Optional[int] = None
    autosave_dir: Optional[str] = None
    on_corrupt: str = "raise"
    on_tenant_fault: str = "raise"
    retries: Optional[int] = None
    stall_timeout: Optional[float] = None

    def __post_init__(self):
        from repro.cluster.registry import available_backends

        if self.backend not in available_backends():
            raise ValueError(
                f"unknown backend {self.backend!r}; registered backends: "
                f"{', '.join(available_backends())}"
            )
        if not isinstance(self.n, int) or self.n < 1:
            raise ValueError(f"n must be a positive int, got {self.n!r}")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if self.batch_edges is not None and self.batch_edges < 1:
            raise ValueError(
                f"batch_edges must be >= 1, got {self.batch_edges}"
            )
        if self.megabatch_k is not None and self.megabatch_k < 1:
            raise ValueError(
                f"megabatch_k must be >= 1, got {self.megabatch_k}"
            )
        if self.wavefront is not None:
            if isinstance(self.wavefront, str):
                if self.wavefront != "auto":
                    raise ValueError(
                        f"wavefront must be an int width or 'auto', got "
                        f"{self.wavefront!r}"
                    )
            elif self.wavefront < 1:
                raise ValueError(
                    f"wavefront must be >= 1, got {self.wavefront}"
                )
            if self.megabatch_k is None:
                raise ValueError(
                    "wavefront requires megabatch_k (waves are planned per "
                    "staged megabatch)"
                )
        if self.prefetch is not None and self.prefetch < 0:
            raise ValueError(
                f"prefetch must be >= 0, got {self.prefetch}"
            )
        if self.criterion not in ("density", "entropy"):
            raise ValueError(
                f"criterion must be 'density' or 'entropy', got "
                f"{self.criterion!r}"
            )
        if self.backend == "multiparam":
            if not self.v_maxes:
                raise ValueError("backend='multiparam' requires v_maxes")
            if any(int(v) < 1 for v in self.v_maxes):
                raise ValueError(f"v_maxes must be >= 1, got {self.v_maxes}")
            # normalise to a plain int tuple (hashable, JSON-friendly)
            object.__setattr__(self, "v_maxes", tuple(int(v) for v in self.v_maxes))
        else:
            if self.v_max is None or int(self.v_max) < 1:
                raise ValueError(
                    f"v_max must be >= 1 for backend={self.backend!r}, got "
                    f"{self.v_max!r}"
                )
        if self.n_shards is not None and self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.v_max2 is not None and self.v_max2 < 1:
            raise ValueError(f"v_max2 must be >= 1, got {self.v_max2}")
        if self.refine is not None:
            from repro.cluster.refine import parse_refine

            parse_refine(self.refine)  # raises on a malformed spec
        if self.refine_rounds is not None and self.refine_rounds < 1:
            raise ValueError(
                f"refine_rounds must be >= 1, got {self.refine_rounds}"
            )
        if self.refine_max_pairs is not None and self.refine_max_pairs < 1:
            raise ValueError(
                f"refine_max_pairs must be >= 1, got {self.refine_max_pairs}"
            )
        if self.wavefront_gap is not None:
            if self.wavefront_gap < 0:
                raise ValueError(
                    f"wavefront_gap must be >= 0, got {self.wavefront_gap}"
                )
            if self.wavefront is None:
                raise ValueError(
                    "wavefront_gap requires wavefront (it is a planner knob)"
                )
        if self.tenants is not None and self.tenants < 1:
            raise ValueError(f"tenants must be >= 1, got {self.tenants}")
        if self.device_decode:
            if self.megabatch_k is None:
                raise ValueError(
                    "device_decode requires megabatch_k (compressed slabs "
                    "are staged per megabatch)"
                )
            if self.wavefront is not None:
                raise ValueError(
                    "device_decode is incompatible with wavefront (waves "
                    "are planned from host-decoded edges)"
                )
            if self.refine is not None:
                raise ValueError(
                    "device_decode is incompatible with refine (the "
                    "supergraph sketch observes host-decoded edges)"
                )
        if self.autosave_every is not None:
            if self.autosave_every < 1:
                raise ValueError(
                    f"autosave_every must be >= 1, got {self.autosave_every}"
                )
            if not self.autosave_dir:
                raise ValueError(
                    "autosave_every requires autosave_dir (where the "
                    "checkpoints go)"
                )
        if self.on_corrupt not in ("raise", "quarantine"):
            raise ValueError(
                f"on_corrupt must be 'raise' or 'quarantine', got "
                f"{self.on_corrupt!r}"
            )
        if self.on_tenant_fault not in ("raise", "quarantine"):
            raise ValueError(
                f"on_tenant_fault must be 'raise' or 'quarantine', got "
                f"{self.on_tenant_fault!r}"
            )
        if self.retries is not None and self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.stall_timeout is not None and self.stall_timeout <= 0:
            raise ValueError(
                f"stall_timeout must be > 0, got {self.stall_timeout}"
            )

    # ------------------------------------------------------------------
    def replace(self, **changes: Any) -> "ClusterConfig":
        return dataclasses.replace(self, **changes)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, text: str) -> "ClusterConfig":
        raw = json.loads(text)
        if raw.get("v_maxes") is not None:
            raw["v_maxes"] = tuple(raw["v_maxes"])
        return cls(**raw)
