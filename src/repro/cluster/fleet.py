"""The multi-tenant fleet engine: thousands of tenant streams per dispatch.

``repro.cluster.fleet`` (DESIGN.md §13) turns "millions of users" from
millions of dispatches into one: ``T`` independent tenant streams — each a
small graph with the paper's 3n-int state — are stacked into one
:class:`~repro.core.state.FleetState` ``(T, n)`` pytree and advanced with
**one** donated device dispatch per fleet step.

* Ingest: :class:`~repro.graph.tenants.TenantRouter` demuxes the per-tenant
  sources under a deterministic arrival schedule and stages each fleet
  step's ``(T, B, 2)`` slab on its prefetch thread.
* Update: the backend's registered ``fleet_fn`` — the vmapped chunked /
  scan tiers (``repro.core.fleet``) or the tenant-major Pallas kernel
  (``repro.kernels.edge_stream``).
* Suspend/resume: one checkpoint carries the whole fleet — the stacked
  state plus the per-tenant dispatched-row vector (``tenant_rows``), from
  which the router's schedule replays deterministically.

Per-tenant labels are bit-identical to ``T`` independent single-stream
:class:`~repro.cluster.api.StreamClusterer` runs of the same backend and
batch geometry — the router guarantees identical per-tenant batch
boundaries, the update paths guarantee tenant isolation (see the module
docstrings for each half of the argument).
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional, Sequence

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.state import FleetState
from repro.core.streaming import canonical_labels
from repro.cluster.api import _CONFIG_FILE, DEFAULT_BATCH_EDGES, Clustering
from repro.cluster.config import ClusterConfig
from repro.cluster.registry import Backend, get_backend
from repro.graph.errors import RetryPolicy
from repro.graph.tenants import TenantRouter


class FleetClustering:
    """A fleet clustering result: per-tenant labels + run counters.

    ``state`` is a host (numpy) :class:`FleetState` snapshot — row ``t`` is
    tenant ``t``'s 3n-int result.  :meth:`tenant` views one tenant as a
    plain single-stream :class:`~repro.cluster.api.Clustering` so the
    edge-free metrics (entropy, density, community stats) work unchanged.
    """

    def __init__(
        self,
        state: FleetState,
        config: ClusterConfig,
        info: Optional[Dict[str, Any]] = None,
    ):
        self.state = state
        self.config = config
        self.info = dict(info or {})
        self._labels: Optional[np.ndarray] = None

    @property
    def tenants(self) -> int:
        return self.state.tenants

    @property
    def raw_labels(self) -> np.ndarray:
        """(T, n) per-tenant raw labels (node-id space)."""
        return np.asarray(self.state.c)

    @property
    def labels(self) -> np.ndarray:
        """(T, n) canonical labels, each tenant row canonicalised
        independently (comparable against its standalone run)."""
        if self._labels is None:
            self._labels = np.stack(
                [canonical_labels(row) for row in self.raw_labels]
            )
        return self._labels

    def tenant(self, t: int) -> Clustering:
        return Clustering(
            state=self.state.entry(t),
            config=self.config,
            raw_labels=self.raw_labels[t],
            info={"tenant": t},
        )

    def __repr__(self) -> str:
        return (
            f"FleetClustering(backend={self.config.backend!r}, "
            f"tenants={self.tenants}, n={self.config.n})"
        )


class FleetClusterer:
    """Incremental multi-tenant ingestion: one dispatch per fleet step.

    Mirrors :class:`~repro.cluster.api.StreamClusterer` for fleets:
    :meth:`partial_fit_fleet` per staged ``(T, B, 2)`` slab, :meth:`fit` to
    drain per-tenant sources through a :class:`TenantRouter`,
    :meth:`finalize` for the result, :meth:`save`/:meth:`restore` for
    one-checkpoint suspend/resume of the entire fleet.
    """

    def __init__(self, config: ClusterConfig, state: Optional[FleetState] = None):
        if config.tenants is None:
            raise ValueError(
                "FleetClusterer requires config.tenants (the fleet size T)"
            )
        self._backend: Backend = get_backend(config.backend)
        if self._backend.fleet_fn is None:
            raise ValueError(
                f"backend {config.backend!r} has no fleet path; fleet-capable "
                "backends register a fleet_fn (chunked / scan / pallas)"
            )
        self.config = config
        if state is None:
            state = FleetState.init(config.n, config.tenants)
        if not isinstance(state, FleetState):
            raise ValueError(
                f"FleetClusterer threads a FleetState, got {type(state).__name__}"
            )
        if state.n != config.n or state.tenants != config.tenants:
            raise ValueError(
                f"state has (tenants, n)=({state.tenants}, {state.n}) but "
                f"config has ({config.tenants}, {config.n}); a carried fleet "
                "state must match the config's shape"
            )
        self._state = state
        # Per-tenant dispatched-row cursor: the single extra checkpoint leaf
        # from which the router's arrival schedule resumes deterministically.
        self._rows = np.zeros(config.tenants, np.int64)
        self.fleet_steps = 0
        self.stream_dispatches = 0
        self.peak_staging_bytes = 0
        # Resilience accounting (DESIGN.md §15): tenants quarantined by the
        # router under config.on_tenant_fault="quarantine" (index ->
        # recorded failure), transient re-pulls across all tenants, and
        # autosaves taken from inside fit.
        self.tenants_quarantined: Dict[int, str] = {}
        self.ingest_retries = 0
        self.autosaves = 0
        self._last_autosave_rows = 0

    # ------------------------------------------------------------------
    @property
    def state(self) -> FleetState:
        return self._state

    @property
    def tenant_rows(self) -> np.ndarray:
        """(T,) raw rows dispatched per tenant (the fleet's stream cursor)."""
        return self._rows.copy()

    @property
    def edges_seen(self) -> np.ndarray:
        """(T,) live edges ingested per tenant."""
        return np.asarray(self._state.edges_seen)

    def partial_fit_fleet(
        self, slab, *, n_rows: Optional[Sequence[int]] = None
    ) -> "FleetClusterer":
        """Ingest one ``(T, B, 2)`` staged slab in a single donated
        dispatch; returns ``self`` for chaining.

        ``n_rows``: raw rows per tenant this slab represents (defaults to
        the full ``B`` per tenant); :meth:`fit` passes the router's
        pre-padding counts so :attr:`tenant_rows` tracks the sources.
        """
        T, B = int(np.shape(slab)[0]), int(np.shape(slab)[1])
        if T != self.config.tenants:
            raise ValueError(
                f"slab has {T} tenant rows but config.tenants="
                f"{self.config.tenants}"
            )
        result = self._backend.fleet_fn(slab, self.config, self._state)
        self._state = result.state
        if n_rows is None:
            self._rows += B
        else:
            self._rows += np.asarray(n_rows, np.int64)
        self.fleet_steps += 1
        self.stream_dispatches += 1
        return self

    def fit(
        self,
        sources: Sequence,
        *,
        rates: Optional[Sequence[int]] = None,
        granule: Optional[int] = None,
        max_steps: Optional[int] = None,
        preemption=None,
    ) -> "FleetClusterer":
        """Drain ``T`` per-tenant sources from :attr:`tenant_rows`.

        ``sources`` must have exactly ``config.tenants`` entries (arrays,
        paths, or :class:`~repro.graph.sources.EdgeSource`\\ s).  Ingestion
        starts at the current per-tenant rows, so ``fit`` after
        :meth:`restore` resumes every tenant mid-stream.  ``max_steps``
        bounds this call (a cooperative suspend point); returns ``self``.

        With ``config.on_tenant_fault="quarantine"``, a tenant whose source
        dies mid-stream is isolated to PAD no-op rows while the other
        ``T-1`` tenants stream on bit-identically (the failure surfaces in
        the finalize info); the default policy propagates the first tenant
        failure.  ``config.autosave_every`` / ``preemption`` work exactly
        as in :meth:`StreamClusterer.fit`, checkpointing the whole fleet
        from inside the drain loop.
        """
        if len(sources) != self.config.tenants:
            raise ValueError(
                f"{len(sources)} sources for config.tenants="
                f"{self.config.tenants}"
            )
        retry = None
        if self.config.retries is None or self.config.retries > 0:
            retry = RetryPolicy(
                max_retries=(
                    self.config.retries
                    if self.config.retries is not None
                    else RetryPolicy().max_retries
                )
            )
        router = TenantRouter(
            sources,
            self.config.batch_edges or DEFAULT_BATCH_EDGES,
            rates=rates,
            granule=granule,
            pad_multiple=(
                self.config.chunk if self._backend.chunk_aligned else 1
            ),
            on_fault=self.config.on_tenant_fault,
            retry=retry,
            **(
                {}
                if self.config.prefetch is None
                else {"prefetch": self.config.prefetch}
            ),
        )
        slabs = router.fleet_slabs(self._rows)
        n = 0
        stop = False
        try:
            for slab in slabs:
                self.partial_fit_fleet(slab.edges, n_rows=slab.n_rows)
                n += 1
                every = self.config.autosave_every
                total = int(self._rows.sum())
                if every is not None and total - self._last_autosave_rows >= every:
                    self.save(self.config.autosave_dir)
                    self._last_autosave_rows = total
                    self.autosaves += 1
                if preemption is not None and preemption.preempted:
                    stop = True
                    break
                if max_steps is not None and n >= max_steps:
                    break
        finally:
            slabs.close()
        self.peak_staging_bytes = max(
            self.peak_staging_bytes, router.peak_staging_bytes
        )
        self.tenants_quarantined.update(router.quarantined)
        self.ingest_retries += router.retries
        if (
            stop
            and self.config.autosave_dir
            and self._last_autosave_rows != int(self._rows.sum())
        ):
            self.save(self.config.autosave_dir)
            self._last_autosave_rows = int(self._rows.sum())
            self.autosaves += 1
        return self

    def finalize(self) -> FleetClustering:
        """The fleet clustering of everything ingested so far.  Snapshots
        the stacked state to host (the fleet updates donate their buffers),
        so the result outlives further ingestion."""
        info: Dict[str, Any] = {
            "tenants": self.config.tenants,
            "fleet_steps": self.fleet_steps,
            "stream_dispatches": self.stream_dispatches,
            "dispatches_per_fleet_step": (
                self.stream_dispatches / self.fleet_steps
                if self.fleet_steps
                else 0.0
            ),
            "peak_staging_bytes": self.peak_staging_bytes,
            "tenant_rows": self.tenant_rows,
        }
        if self.tenants_quarantined or self.ingest_retries or self.autosaves:
            info["tenants_quarantined"] = sorted(self.tenants_quarantined)
            info["tenant_faults"] = dict(self.tenants_quarantined)
            info["ingest_retries"] = self.ingest_retries
            info["autosaves"] = self.autosaves
        return FleetClustering(
            state=self._state.to_numpy(), config=self.config, info=info
        )

    # ------------------------------------------------------------------
    # Suspend / resume: ONE checkpoint for the whole fleet
    # ------------------------------------------------------------------

    def save(self, directory: str) -> str:
        """Checkpoint the entire fleet atomically: the stacked state plus
        the per-tenant dispatched-row vector, as one pytree (plus the config
        sidecar) — state and every tenant's stream position can never tear
        apart.  Step = total live edges across the fleet."""
        mgr = CheckpointManager(directory)
        tmp = os.path.join(directory, _CONFIG_FILE + ".tmp")
        with open(tmp, "w") as f:
            f.write(self.config.to_json())
        os.replace(tmp, os.path.join(directory, _CONFIG_FILE))
        tree = {
            "fleet_state": self._state,
            "tenant_rows": self._rows.copy(),
        }
        return mgr.save(int(np.sum(self.edges_seen)), tree)

    @classmethod
    def restore(
        cls, directory: str, config: Optional[ClusterConfig] = None
    ) -> "FleetClusterer":
        """Resume a fleet from :meth:`save`; ``config`` overrides the saved
        one (same fleet shape and a fleet-capable backend required — the
        shape checks in ``__init__`` enforce it)."""
        with open(os.path.join(directory, _CONFIG_FILE)) as f:
            saved = ClusterConfig.from_json(f.read())
        if config is None:
            config = saved
        elif config.tenants is None and saved.tenants is not None:
            config = config.replace(tenants=saved.tenants)
        mgr = CheckpointManager(directory)
        leaves = mgr.leaf_names()
        if "tenant_rows" not in leaves:
            raise ValueError(
                f"{directory!r} holds a single-stream checkpoint "
                "(no tenant_rows leaf); use StreamClusterer.restore"
            )
        template = {
            "fleet_state": FleetState.init(
                config.n, config.tenants, numpy=True
            ),
            "tenant_rows": np.zeros(config.tenants, np.int64),
        }
        restored = mgr.restore(template)
        fc = cls(config, state=restored["fleet_state"])
        fc._rows = np.asarray(restored["tenant_rows"], np.int64)
        return fc


def cluster_fleet(
    sources: Sequence,
    config: ClusterConfig,
    *,
    rates: Optional[Sequence[int]] = None,
) -> FleetClustering:
    """One-call fleet clustering: drain ``T`` per-tenant sources and return
    the :class:`FleetClustering` (``config.tenants`` defaults to
    ``len(sources)`` when unset)."""
    if config.tenants is None:
        config = config.replace(tenants=len(sources))
    return FleetClusterer(config).fit(sources, rates=rates).finalize()
