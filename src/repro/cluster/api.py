"""The unified public clustering API: ``cluster`` / ``StreamClusterer``.

One call — ``cluster(edges, ClusterConfig(...))`` — dispatches through the
backend registry; ``StreamClusterer`` exposes the same engine incrementally
(``partial_fit`` per arriving batch, ``finalize`` for the result), with the
:class:`ClusterState` suspendable to disk via ``repro.checkpoint.manager``
and resumable in a later session.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.metrics import community_stats, entropy_from_state
from repro.core.state import ClusterState
from repro.core.streaming import canonical_labels
from repro.cluster.config import ClusterConfig
from repro.cluster.registry import Backend, get_backend

_CONFIG_FILE = "cluster_config.json"


def _check_state_n(state: ClusterState, config: ClusterConfig) -> None:
    """A carried state must match config.n — out-of-range node ids would be
    silently dropped by device scatters otherwise."""
    if state.n != config.n:
        raise ValueError(
            f"state has n={state.n} but config.n={config.n}; a carried "
            "ClusterState must come from a run with the same node-id space"
        )


class Clustering:
    """A clustering result: labels + edge-free metrics (paper §2.5).

    Everything derivable is lazy/cached so benchmarks can time the backends
    without paying for canonicalisation or metrics they don't read.
    """

    def __init__(
        self,
        state: Optional[ClusterState],
        config: ClusterConfig,
        raw_labels,
        info: Optional[Dict[str, Any]] = None,
    ):
        self.state = state
        self.config = config
        self.raw_labels = raw_labels
        self.info = dict(info or {})
        self._labels: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    @property
    def labels(self) -> np.ndarray:
        """Canonical labels 0..K-1 by first appearance (cross-backend
        comparable)."""
        if self._labels is None:
            self._labels = canonical_labels(np.asarray(self.raw_labels))
        return self._labels

    @property
    def entropy(self) -> Optional[float]:
        """H over community volumes — edge-free, from ``(v, sum d)`` alone.
        ``None`` when the backend returns no state (distributed)."""
        if self.state is None:
            return None
        v = np.asarray(self.state.v)
        w = float(np.asarray(self.state.d).sum())
        return entropy_from_state(v, w) if w > 0 else 0.0

    @property
    def avg_density(self) -> Optional[float]:
        """Average community density — edge-free, from ``(c, v)`` alone.

        Works in any backend label space by looking up each node's community
        volume (dense: ``v[label]``; oracle: ``v[label - 1]``, synthesized
        singleton labels for never-seen nodes have volume 0)."""
        if self.state is None:
            return None
        v = np.asarray(self.state.v)
        raw = np.asarray(self.raw_labels)
        space = get_backend(self.config.backend).label_space
        idx = raw - 1 if space == "oracle" else raw
        in_bounds = (idx >= 0) & (idx < v.shape[0])
        node_vol = np.where(in_bounds, v[np.clip(idx, 0, v.shape[0] - 1)], 0)
        _, first, counts = np.unique(raw, return_index=True, return_counts=True)
        vol_u = node_vol[first].astype(np.float64)
        pairs = np.maximum(counts * (counts - 1.0), 1.0)
        dens = np.where(counts > 1, vol_u / pairs, 0.0)
        return float(dens.mean()) if dens.size else 0.0

    @property
    def community_stats(self) -> Dict[str, float]:
        return community_stats(self.labels)

    @property
    def n_communities(self) -> int:
        return int(self.community_stats["n_communities"])

    def block_until_ready(self) -> "Clustering":
        if self.state is not None:
            self.state.block_until_ready()
        return self

    def __repr__(self) -> str:
        return (
            f"Clustering(backend={self.config.backend!r}, n={self.config.n}, "
            f"edges_seen={int(self.state.edges_seen) if self.state else '?'})"
        )


def cluster(
    edges,
    config: ClusterConfig,
    *,
    state: Optional[ClusterState] = None,
    mesh=None,
) -> Clustering:
    """Cluster an edge stream in one call, via ``config.backend``.

    Args:
      edges: (m, 2) int array in stream order (PAD rows are no-ops).
      config: validated :class:`ClusterConfig`.
      state: optional carried :class:`ClusterState` (resumable backends only);
        fresh state is created when omitted.  Must come from a run with the
        same ``n`` and the same backend label space (see ``Backend.label_space``
        — an oracle-space state is not interchangeable with dense-space ones).
      mesh: optional ``jax.sharding.Mesh`` for ``backend="distributed"``.

    Returns:
      a :class:`Clustering` bundling labels, state, and edge-free metrics.
    """
    backend = get_backend(config.backend)
    if state is None:
        state = backend.init_fn(config.n)
    _check_state_n(state, config)
    result = backend.fn(edges, config, state, mesh=mesh)
    return Clustering(
        state=result.state,
        config=config,
        raw_labels=result.labels,
        info=result.info,
    )


class StreamClusterer:
    """Incremental ingestion: ``partial_fit`` per arriving edge batch.

    The production streaming scenario — edges arrive over time, state is the
    paper's ``3n`` ints, and the run can be suspended (:meth:`save`) and
    resumed (:meth:`restore`) across processes.  Only resumable backends
    (oracle / dense / scan / chunked / pallas) support ``partial_fit``; for
    the strictly-sequential tiers the result is identical to one
    :func:`cluster` call over the concatenated stream, regardless of batching.
    """

    def __init__(self, config: ClusterConfig, state: Optional[ClusterState] = None):
        self.config = config
        self._backend: Backend = get_backend(config.backend)
        if not self._backend.resumable:
            raise ValueError(
                f"backend {config.backend!r} does not support incremental "
                "partial_fit; use cluster() for one-shot runs"
            )
        if state is None:
            state = self._backend.init_fn(config.n)
        _check_state_n(state, config)
        self._state = state
        self._last_result = None

    # ------------------------------------------------------------------
    @property
    def state(self) -> ClusterState:
        return self._state

    @property
    def edges_seen(self) -> int:
        return int(self._state.edges_seen)

    def partial_fit(self, edge_batch) -> "StreamClusterer":
        """Ingest one batch of edges; returns ``self`` for chaining."""
        result = self._backend.fn(edge_batch, self.config, self._state)
        self._state = result.state
        self._last_result = result
        return self

    def finalize(self) -> Clustering:
        """The clustering of everything ingested so far.  Does not consume
        the state — more ``partial_fit`` calls may follow."""
        if self._last_result is not None:
            raw = self._last_result.labels
            info = self._last_result.info
        else:  # no batch ingested yet: every node is its own singleton
            empty = np.zeros((0, 2), np.int32)
            result = self._backend.fn(empty, self.config, self._state)
            self._state = result.state
            raw, info = result.labels, result.info
        return Clustering(
            state=self._state, config=self.config, raw_labels=raw, info=info
        )

    # ------------------------------------------------------------------
    # Suspend / resume across sessions (repro.checkpoint.manager)
    # ------------------------------------------------------------------

    def save(self, directory: str) -> str:
        """Checkpoint state (step-atomic, step = edges seen) + config sidecar.

        The config is written first via atomic replace, so a preemption at
        any point leaves either a restorable checkpoint or a clean
        "no checkpoints" failure — never a state/config torn pair.
        """
        mgr = CheckpointManager(directory)  # creates the directory
        tmp = os.path.join(directory, _CONFIG_FILE + ".tmp")
        with open(tmp, "w") as f:
            f.write(self.config.to_json())
        os.replace(tmp, os.path.join(directory, _CONFIG_FILE))
        return mgr.save(self.edges_seen, {"cluster_state": self._state})

    @classmethod
    def restore(
        cls, directory: str, config: Optional[ClusterConfig] = None
    ) -> "StreamClusterer":
        """Resume from :meth:`save`; ``config`` overrides the saved one.

        An override may switch backends only within the same label space
        (dense → scan → pallas → chunked); an oracle state read as dense
        state (or vice versa) would silently mislabel, so it is rejected.
        """
        with open(os.path.join(directory, _CONFIG_FILE)) as f:
            saved = ClusterConfig.from_json(f.read())
        if config is None:
            config = saved
        else:
            saved_space = get_backend(saved.backend).label_space
            new_space = get_backend(config.backend).label_space
            if saved_space != new_space:
                raise ValueError(
                    f"cannot restore a {saved.backend!r} checkpoint "
                    f"({saved_space} label space) with backend="
                    f"{config.backend!r} ({new_space} label space)"
                )
        backend = get_backend(config.backend)
        template = {"cluster_state": backend.init_fn(config.n)}
        restored = CheckpointManager(directory).restore(template)
        return cls(config, state=restored["cluster_state"])
