"""The unified public clustering API: ``cluster`` / ``StreamClusterer``.

One call — ``cluster(edges, ClusterConfig(...))`` — dispatches through the
backend registry; ``StreamClusterer`` exposes the same engine incrementally
(``partial_fit`` per arriving batch, ``fit`` to drain an
:class:`~repro.graph.sources.EdgeSource`, ``finalize`` for the result), with
the backend's state pytree suspendable to disk via
``repro.checkpoint.manager`` and resumable in a later session — including
mid-stream: checkpoints record the stream :class:`~repro.graph.codecs
.Cursor` (raw row + the source's opaque resume token), so ``restore`` +
``fit(source)`` picks up an out-of-core file — raw, delta+varint
compressed, or a multi-stream merge — exactly where the previous session
stopped.

*Resumable + out-of-core is the invariant, not the special case*: every
backend threads a state pytree (``ClusterState`` / ``SweepState`` /
``ShardedState`` — see ``Backend.state_kind``) through ``partial_fit``, so
the §2.5 multi-parameter sweep and the sharded distributed tier stream,
checkpoint, and resume exactly like the single-parameter tiers.  Backends
with a ``finalize_fn`` (sweep selection, shard merge) derive labels from
state at finalize time; the :class:`Clustering` they return always carries a
plain :class:`ClusterState` view, so the edge-free metrics are uniform.

``edges`` everywhere means *array, path, or EdgeSource*: in-memory arrays
auto-wrap (and keep the historical one-shot path), file/generator sources
stream through the :class:`~repro.graph.pipeline.BatchPipeline` with host
edge residency bounded by O(``batch_edges``) while the state stays the
paper's ``3n`` ints (``(2A+1) n`` for the sweep, ``3Pn`` for ``P`` shards).
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional

import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.metrics import community_stats, entropy_from_state
from repro.core.state import ClusterState, ShardedState, SweepState
from repro.core.streaming import canonical_labels
from repro.cluster.config import ClusterConfig
from repro.cluster.refine import RefineRuntime
from repro.cluster.registry import Backend, BackendResult, get_backend
from repro.graph.codecs import Cursor
from repro.graph.errors import RetryPolicy
from repro.graph.pipeline import D_KIND, DESC_RAW, BatchPipeline
from repro.graph.wavefront import plan_waves
from repro.graph.sources import ArraySource, EdgeSource, as_source

_CONFIG_FILE = "cluster_config.json"

# Default edges per ingest batch when streaming from a source (8 MB of int32
# pairs — small against any graph worth streaming, large enough to keep the
# device tiers fed).
DEFAULT_BATCH_EDGES = 1 << 20

_EMPTY_BATCH = np.zeros((0, 2), np.int32)


def _make_pipeline(
    source: EdgeSource, config: ClusterConfig, backend: Backend
) -> BatchPipeline:
    """The ingest pipeline for one run: fixed batch shape (one jit compile),
    chunk-aligned for the Jacobi/DMA tiers so batching never moves a chunk
    boundary (labels match the one-shot run even for ``chunked``), prefetch
    depth per config (``None`` defers to the pipeline's own default)."""
    kwargs: Dict[str, Any] = {}
    if config.prefetch is not None:
        kwargs["prefetch"] = config.prefetch
    if config.retries is not None:
        # 0 disables retry outright; k bounds consecutive attempts per fault
        kwargs["retry"] = (
            RetryPolicy(max_retries=config.retries) if config.retries else None
        )
    if config.stall_timeout is not None:
        kwargs["stall_timeout"] = config.stall_timeout
    return BatchPipeline(
        source,
        config.batch_edges or DEFAULT_BATCH_EDGES,
        pad_multiple=config.chunk if backend.chunk_aligned else 1,
        **kwargs,
    )


def _resolve_config(
    config: ClusterConfig, backend: Backend, mesh=None, state=None
) -> ClusterConfig:
    """Pin config fields the state shape depends on.  The sharded tier's
    ``n_shards`` must be concrete before ``init_fn`` runs (it is the leading
    state axis): a carried state fixes it, a ``mesh`` contributes its device
    count, otherwise the visible device count is used."""
    if backend.state_kind == "sharded" and config.n_shards is None:
        # getattr: a wrong-kind state has no n_shards — fall through so
        # _check_state can report the kind mismatch instead of crashing here
        if state is not None and getattr(state, "n_shards", None) is not None:
            n_shards = state.n_shards
        elif mesh is not None:
            from repro.core.distributed import mesh_shards

            n_shards = mesh_shards(mesh)
        else:
            import jax

            n_shards = jax.device_count()
        config = config.replace(n_shards=n_shards)
    return config


def _state_kind_of(state) -> str:
    """Kind of a state pytree.  The wide kinds are *defined* by their
    classes (a backend declaring ``state_kind="sweep"``/``"sharded"`` must
    thread ``SweepState``/``ShardedState``); everything else — including
    third-party custom states — is the open ``"cluster"`` kind."""
    if isinstance(state, SweepState):
        return "sweep"
    if isinstance(state, ShardedState):
        return "sharded"
    return "cluster"


def _check_state(state, config: ClusterConfig, backend: Backend) -> None:
    """A carried state must match the config's shape parameters — dispatched
    on the backend's state kind rather than assuming ``ClusterState``."""
    got_kind = _state_kind_of(state)
    if got_kind != backend.state_kind:
        raise ValueError(
            f"backend {backend.name!r} threads a {backend.state_kind} state "
            f"but was given a {got_kind} state; states are not "
            "interchangeable across kinds"
        )
    if state.n != config.n:
        raise ValueError(
            f"state has n={state.n} but config.n={config.n}; a carried "
            "state must come from a run with the same node-id space"
        )
    if backend.state_kind == "sweep":
        got = tuple(int(x) for x in np.asarray(state.v_maxes))
        if got != tuple(config.v_maxes):
            raise ValueError(
                f"sweep state was built for v_maxes={got} but config has "
                f"v_maxes={tuple(config.v_maxes)}; a resumed sweep cannot "
                "silently continue under different parameters"
            )
    elif backend.state_kind == "sharded":
        if state.n_shards != config.n_shards:
            raise ValueError(
                f"sharded state has n_shards={state.n_shards} but config "
                f"has n_shards={config.n_shards}; shard count is a state "
                "dimension and cannot change mid-run"
            )


class Clustering:
    """A clustering result: labels + edge-free metrics (paper §2.5).

    ``state`` is always a plain :class:`ClusterState` view of the result
    (for the sweep: the selected column; for the sharded tier: the merged
    state), whatever the backend's internal state kind — so the edge-free
    metrics below work uniformly across all seven tiers.  Everything
    derivable is lazy/cached so benchmarks can time the backends without
    paying for canonicalisation or metrics they don't read.
    """

    def __init__(
        self,
        state: Optional[ClusterState],
        config: ClusterConfig,
        raw_labels,
        info: Optional[Dict[str, Any]] = None,
    ):
        self.state = state
        self.config = config
        self.raw_labels = raw_labels
        self.info = dict(info or {})
        self._labels: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    @property
    def labels(self) -> np.ndarray:
        """Canonical labels 0..K-1 by first appearance (cross-backend
        comparable)."""
        if self._labels is None:
            self._labels = canonical_labels(np.asarray(self.raw_labels))
        return self._labels

    @property
    def entropy(self) -> Optional[float]:
        """H over community volumes — edge-free, from ``(v, sum d)`` alone.
        ``None`` only if a third-party backend returns no state."""
        if self.state is None:
            return None
        v = np.asarray(self.state.v)
        w = float(np.asarray(self.state.d).sum())
        return entropy_from_state(v, w) if w > 0 else 0.0

    @property
    def avg_density(self) -> Optional[float]:
        """Average community density — edge-free, from ``(c, v)`` alone.

        Works in any backend label space by looking up each node's community
        volume (dense: ``v[label]``; oracle: ``v[label - 1]``, synthesized
        singleton labels for never-seen nodes have volume 0)."""
        if self.state is None:
            return None
        v = np.asarray(self.state.v)
        raw = np.asarray(self.raw_labels)
        space = get_backend(self.config.backend).label_space
        idx = raw - 1 if space == "oracle" else raw
        in_bounds = (idx >= 0) & (idx < v.shape[0])
        node_vol = np.where(in_bounds, v[np.clip(idx, 0, v.shape[0] - 1)], 0)
        _, first, counts = np.unique(raw, return_index=True, return_counts=True)
        vol_u = node_vol[first].astype(np.float64)
        pairs = np.maximum(counts * (counts - 1.0), 1.0)
        dens = np.where(counts > 1, vol_u / pairs, 0.0)
        return float(dens.mean()) if dens.size else 0.0

    @property
    def peak_buffer_bytes(self) -> Optional[int]:
        """Measured peak host edge-buffer residency of the run that produced
        this result (``None`` for non-streamed runs).  Scales with the
        configured pipeline depth: ``(prefetch + 1) * batch_edges`` rows per
        buffered (mega)batch, times ``megabatch_k`` in megabatch mode."""
        v = self.info.get("peak_buffer_bytes")
        return None if v is None else int(v)

    @property
    def community_stats(self) -> Dict[str, float]:
        return community_stats(self.labels)

    @property
    def n_communities(self) -> int:
        return int(self.community_stats["n_communities"])

    def block_until_ready(self) -> "Clustering":
        if self.state is not None:
            self.state.block_until_ready()
        return self

    def __repr__(self) -> str:
        return (
            f"Clustering(backend={self.config.backend!r}, n={self.config.n}, "
            f"edges_seen={int(self.state.edges_seen) if self.state else '?'})"
        )


def cluster(
    edges,
    config: ClusterConfig,
    *,
    state=None,
    mesh=None,
) -> Clustering:
    """Cluster an edge stream in one call, via ``config.backend``.

    Args:
      edges: the stream, in stream order (PAD rows are no-ops) — a (m, 2)
        int array, a file path, or any :class:`repro.graph.sources
        .EdgeSource`.  Out-of-core sources are ingested in
        ``config.batch_edges``-sized batches through the resumable
        ``partial_fit`` machinery (host edge residency O(batch), labels
        identical to the in-memory run); arrays take the historical one-shot
        path unless ``batch_edges`` is set.  The sharded tier always streams
        (batches are its unit of shard assignment): with ``batch_edges``
        unset the stream is counted once and split into one window per
        shard (capped at the default batch size, which stripes longer
        streams across shards out-of-core).
      config: validated :class:`ClusterConfig`.
      state: optional carried state pytree (see ``Backend.state_kind``);
        fresh state is created when omitted.  Must come from a run with the
        same shape parameters (``n``; sweep ``v_maxes``; shard count) and
        the same backend label space.  Treated as *consumed*: the device
        tiers donate their state buffers, so a device-resident state passed
        here must not be reused afterwards (host/numpy states are copied at
        dispatch and stay valid).
      mesh: optional ``jax.sharding.Mesh`` — contributes its device count as
        the default ``n_shards`` for ``backend="distributed"``.

    Returns:
      a :class:`Clustering` bundling labels, state, and edge-free metrics.
      Streamed runs add ``info["peak_buffer_bytes"]`` /
      ``info["stream_batches"]`` (the paper's memory story, measured).
    """
    source = as_source(edges)
    backend = get_backend(config.backend)
    config = _resolve_config(config, backend, mesh, state)
    if state is None:
        state = backend.init_fn(config)
    _check_state(state, config, backend)

    in_memory = isinstance(source, ArraySource)
    # The sharded tier always streams — batches are its unit of shard
    # assignment (fit() sizes the default window per shard).  Refined runs
    # always stream too: the supergraph sketch is accumulated per ingested
    # batch, so the one-shot array path would never feed it.
    if (
        backend.state_kind == "sharded"
        or config.refine is not None
        or config.autosave_every is not None
        or (
            backend.resumable
            and (not in_memory or config.batch_edges is not None)
        )
    ):
        # One drain implementation for both entry points: the incremental
        # clusterer owns the pipeline lifecycle (close-on-error, residency
        # bookkeeping, info surfacing).
        return StreamClusterer(config, state=state).fit(source).finalize()

    if not in_memory:
        raise ValueError(
            f"backend {config.backend!r} is not resumable and cannot ingest "
            "an out-of-core source; materialize the stream yourself or use "
            "a resumable backend"
        )
    result = backend.fn(source.edges, config, state, mesh=mesh)
    if backend.finalize_fn is not None:
        result = backend.finalize_fn(result.state, config)
    return Clustering(
        state=result.state,
        config=config,
        raw_labels=result.labels,
        info=result.info,
    )


class StreamClusterer:
    """Incremental ingestion: ``partial_fit`` per arriving edge batch, or
    :meth:`fit` to drain an :class:`~repro.graph.sources.EdgeSource`.

    The production streaming scenario — edges arrive over time, state is the
    backend's state pytree (the paper's ``3n`` ints; ``(2A+1) n`` for the
    sweep; ``3Pn`` for ``P`` shards), and the run can be suspended
    (:meth:`save`) and resumed (:meth:`restore`) across processes —
    including mid-stream: the checkpoint records :attr:`stream_cursor` (raw
    source rows consumed plus the source's opaque resume token), so a
    restored clusterer's :meth:`fit` continues an out-of-core file from the
    exact row the previous session stopped at — seeking straight to a
    recorded sync point for compressed/text streams.
    Every built-in backend supports ``partial_fit``; for the
    strictly-sequential tiers (sweep included) the result is identical to
    one :func:`cluster` call over the concatenated stream, regardless of
    batching.
    """

    def __init__(self, config: ClusterConfig, state=None):
        self._backend: Backend = get_backend(config.backend)
        config = _resolve_config(config, self._backend, state=state)
        self.config = config
        if not self._backend.resumable:
            raise ValueError(
                f"backend {config.backend!r} does not support incremental "
                "partial_fit; use cluster() for one-shot runs"
            )
        if state is None:
            state = self._backend.init_fn(config)
        _check_state(state, config, self._backend)
        self._state = state
        self._last_result = None
        # Post-stream refinement (DESIGN.md §11): the runtime owns the
        # supergraph sketch (one per sweep column) and the optional replay
        # window; both are observed per dispatch and ride checkpoints.
        self._refine: Optional[RefineRuntime] = (
            RefineRuntime(config, self._backend)
            if config.refine is not None
            else None
        )
        self._cursor = Cursor(0)
        self.peak_buffer_bytes = 0
        self.stream_batches = 0
        self.stream_megabatches = 0
        # Device dispatches issued (one per partial_fit / fused megabatch) —
        # the denominator of the dispatch-amortisation story: megabatch mode
        # drops this ~K-fold for the same stream_batches.
        self.stream_dispatches = 0
        # Wavefront-mode counters (DESIGN.md §12), accumulated per planned
        # megabatch; surfaced by finalize() as the mean-wave-width /
        # fallback-rate / planner-overhead info entries.
        self.wavefront_megabatches = 0
        self.wavefront_waves = 0
        self.wavefront_rows_in_waves = 0
        self.wavefront_leftover_rows = 0
        self.wavefront_dead_rows_skipped = 0
        self.wavefront_plan_seconds = 0.0
        # adaptive widths chosen per planned megabatch (wavefront="auto";
        # fixed-W runs record the fixed width) — surfaced as the
        # ``wavefront_widths`` info counter
        self.wavefront_widths: list = []
        # (2,) device array [live_waves, fallback_waves], accumulated as lazy
        # device adds — no host sync until finalize() reads it
        self._wavefront_stats = None
        # Device-decode counters (DESIGN.md §14), accumulated per compressed
        # megabatch dispatched through the backend's decode_fn
        self.device_decoded_megabatches = 0
        self.device_fallback_rows = 0
        self.device_fallback_segments = 0
        self.device_total_segments = 0
        # Resilience counters (DESIGN.md §15): autosaves taken from inside
        # fit, transient-read retries and soft stalls observed by the ingest
        # pipeline, and the quarantine accounting of every checksummed
        # source this clusterer has drained — all surfaced by finalize().
        self.autosaves = 0
        self.ingest_retries = 0
        self.ingest_stalls = 0
        self._last_autosave_row = 0
        self._quarantine_sources: list = []

    # ------------------------------------------------------------------
    @property
    def state(self):
        return self._state

    @property
    def edges_seen(self) -> int:
        return int(self._state.edges_seen)

    @property
    def stream_cursor(self) -> Cursor:
        """The stream position as an opaque :class:`Cursor` — raw rows
        ingested plus whatever resume token the source minted for that row
        (block sync byte offsets for compressed files, per-source offsets
        for merged streams).  A leaf of every checkpoint."""
        return self._cursor

    @property
    def stream_offset(self) -> int:
        """Raw source rows ingested so far (counts PAD/self-loop rows too —
        this is a *stream position*, unlike ``edges_seen`` which counts live
        edges only).  The row coordinate of :attr:`stream_cursor`."""
        return self._cursor.row

    def partial_fit(self, edge_batch, *, raw_rows: Optional[int] = None) -> "StreamClusterer":
        """Ingest one batch of edges; returns ``self`` for chaining.

        ``raw_rows``: how many raw stream rows this batch represents (defaults
        to the batch length) — :meth:`fit` passes the pre-padding row count so
        ``stream_offset`` tracks the source, not the padded device shape.
        Directly pushed batches advance the cursor row with an empty token
        (there is no source to mint one); :meth:`fit` refreshes the token
        from its source after every batch.
        """
        result = self._backend.fn(edge_batch, self.config, self._state)
        self._state = result.state
        self._last_result = result
        if self._refine is not None:
            self._refine.observe(self._state, edge_batch)
        rows = int(raw_rows if raw_rows is not None else np.shape(edge_batch)[0])
        self._cursor = Cursor(self._cursor.row + rows)
        self.stream_dispatches += 1
        return self

    def partial_fit_megabatch(
        self, edge_batches, *, raw_rows: Optional[int] = None, plan=None
    ) -> "StreamClusterer":
        """Ingest ``(K, B, 2)`` stacked fixed-shape batches in *one* fused
        device dispatch; returns ``self`` for chaining.

        Requires the backend to register a ``megabatch_fn`` (``chunked``:
        one ``lax.scan`` over all K·B/chunk Jacobi chunks; ``pallas``: one
        double-buffered-DMA kernel launch) — results are bit-identical to
        ``K`` sequential :meth:`partial_fit` calls over the same batches,
        and trailing all-PAD batches are no-ops, so ragged tails ride the
        same shape.  ``raw_rows`` is the raw-source row count the megabatch
        represents (defaults to ``K * B``, the padded shape); :meth:`fit`
        passes the pre-padding count so the cursor tracks the source.

        With ``config.wavefront`` set and a backend that registers a
        ``wavefront_fn`` (``pallas``), the megabatch is dispatched through
        the wavefront path instead (DESIGN.md §12): ``plan`` is the
        :class:`~repro.graph.wavefront.WavePlan` staged by the pipeline's
        prefetch thread (:meth:`fit` passes it), or is computed inline here
        for directly pushed megabatches.  Labels stay bit-identical; the
        plan/fallback counters accumulate on this clusterer and surface in
        :meth:`finalize`'s info.  Backends without a wavefront path ignore
        the knob and take the sequential fused path.
        """
        if self._backend.megabatch_fn is None:
            raise ValueError(
                f"backend {self.config.backend!r} has no fused megabatch "
                "path; use partial_fit per batch"
            )
        use_wave = (
            self.config.wavefront is not None
            and self._backend.wavefront_fn is not None
        )
        if use_wave:
            if plan is None:
                plan = plan_waves(
                    np.asarray(edge_batches),
                    self.config.wavefront,
                    gap=self.config.wavefront_gap,
                )
            result = self._backend.wavefront_fn(plan, self.config, self._state)
            stats = result.info.pop("wavefront_stats", None)
            if stats is not None:
                # lazy device add — host sync deferred to finalize()
                self._wavefront_stats = (
                    stats
                    if self._wavefront_stats is None
                    else self._wavefront_stats + stats
                )
            self.wavefront_megabatches += 1
            self.wavefront_waves += plan.n_waves
            self.wavefront_rows_in_waves += plan.rows_in_waves
            self.wavefront_leftover_rows += plan.leftover_rows
            self.wavefront_dead_rows_skipped += plan.dead_rows_skipped
            self.wavefront_plan_seconds += plan.plan_seconds
            self.wavefront_widths.append(int(plan.width))
        else:
            result = self._backend.megabatch_fn(
                edge_batches, self.config, self._state
            )
        self._state = result.state
        self._last_result = result
        if self._refine is not None:
            # sketch observation follows dispatch granularity: one label
            # fetch per fused megabatch, all K*B edges bucketed under the
            # post-megabatch labels
            self._refine.observe(self._state, edge_batches)
        K = int(np.shape(edge_batches)[0])
        B = int(np.shape(edge_batches)[1])
        rows = int(raw_rows if raw_rows is not None else K * B)
        self._cursor = Cursor(self._cursor.row + rows)
        self.stream_dispatches += 1
        self.stream_megabatches += 1
        return self

    def partial_fit_cmegabatch(self, cmega) -> "StreamClusterer":
        """Ingest one :class:`~repro.graph.pipeline.CompressedMegaBatch` —
        DVE3 payload bytes plus a descriptor table — through the backend's
        device decode path (DESIGN.md §14); returns ``self`` for chaining.

        One fused decode→update dispatch per call, exactly like
        :meth:`partial_fit_megabatch` dispatches once per staged megabatch;
        labels are bit-identical to host-decoding the same rows, and the
        cursor advances by the same raw row count, so checkpoints taken on
        either path resume cleanly into the other.
        """
        if self._backend.decode_fn is None:
            raise ValueError(
                f"backend {self.config.backend!r} has no device decode "
                "path; use partial_fit_megabatch with host-decoded edges"
            )
        result = self._backend.decode_fn(
            cmega.validate(), self.config, self._state
        )
        self._state = result.state
        self._last_result = result
        self._cursor = Cursor(self._cursor.row + int(cmega.n_rows))
        self.stream_dispatches += 1
        self.stream_megabatches += 1
        self.device_decoded_megabatches += 1
        self.device_fallback_rows += int(cmega.fallback_rows)
        kinds = np.asarray(cmega.desc[: cmega.n_desc, D_KIND])
        self.device_fallback_segments += int(
            np.count_nonzero(kinds == DESC_RAW)
        )
        self.device_total_segments += int(cmega.n_desc)
        return self

    def _autosave_due(self, config: ClusterConfig) -> bool:
        return (
            config.autosave_every is not None
            and self._cursor.row - self._last_autosave_row
            >= config.autosave_every
        )

    def _autosave(self, config: ClusterConfig) -> None:
        self.save(config.autosave_dir)
        self._last_autosave_row = self._cursor.row
        self.autosaves += 1

    def fit(
        self,
        edges,
        *,
        max_batches: Optional[int] = None,
        preemption=None,
    ) -> "StreamClusterer":
        """Stream a source through ``partial_fit`` from :attr:`stream_offset`.

        ``edges``: array, path, or :class:`~repro.graph.sources.EdgeSource`.
        Ingestion starts at the current :attr:`stream_offset` (0 for a fresh
        clusterer), so calling ``fit`` with the same source after a
        :meth:`restore` resumes mid-stream rather than replaying.
        ``max_batches`` bounds this call (suspend points for cooperative
        preemption); returns ``self``.

        With ``config.megabatch_k = K`` set and a backend that registers a
        fused ``megabatch_fn`` (``chunked``, ``pallas``), ingestion runs in
        *megabatch mode*: the pipeline stages ``K`` consecutive batches into
        one ``(K, batch_edges, 2)`` host buffer on its prefetch thread and
        the device is dispatched once per megabatch — ~K-fold fewer
        dispatches/transfers, labels bit-identical to per-batch ingestion,
        and the stream cursor still lands on exact batch-row boundaries (so
        checkpoints taken at any per-batch suspend point resume cleanly
        into megabatch mode, and vice versa).  A ``max_batches`` budget that
        is not a megabatch multiple drains the remainder per-batch.

        For the sharded tier with ``batch_edges`` unset, the stream is
        counted once and the batch sized to one window per shard (capped at
        the default batch size, which stripes longer streams) — batches are
        that tier's unit of shard assignment, so a single giant batch would
        silently pile the whole stream onto shard 0.  The sizing depends
        only on the source length, so resumed sessions deal identically.

        ``preemption``: an optional
        :class:`~repro.dist.fault_tolerance.PreemptionHandler` polled after
        every ingested (mega)batch — once it fires, the in-flight unit is
        drained, a final checkpoint is written (when ``autosave_dir`` is
        configured), and ``fit`` returns early with the cursor on an exact
        resume point.  Combined with ``config.autosave_every`` this is the
        crash-recovery story: a killed run restores from the newest valid
        generation and finishes with labels bit-identical to an
        uninterrupted one.
        """
        source = as_source(edges)
        config = self.config
        if config.on_corrupt == "quarantine" and getattr(
            source, "supports_quarantine", False
        ):
            # policy is config-driven at fit time: the source skips corrupt
            # blocks to the next sync marker and counts the loss instead of
            # raising (sources without checksummed framing keep raising)
            source.on_corrupt = "quarantine"
        if getattr(source, "supports_quarantine", False) and all(
            s is not source for s in self._quarantine_sources
        ):
            self._quarantine_sources.append(source)
        if self._backend.state_kind == "sharded" and config.batch_edges is None:
            m = source.count_edges()
            per_shard = max(1, -(-m // config.n_shards))
            config = config.replace(
                batch_edges=min(per_shard, DEFAULT_BATCH_EDGES)
            )
        pipe = _make_pipeline(source, config, self._backend)
        K = config.megabatch_k
        use_mega = (
            K is not None
            and K > 1
            and self._backend.megabatch_fn is not None
        )
        # Device-resident compressed ingest (DESIGN.md §14): stage payload
        # bytes + descriptor tables and let the backend's decode_fn unpack
        # them on device.  Requires a block-codec source — anything else
        # (arrays, text files) falls through to host-decoded staging, so
        # device_decode=True is safe to set unconditionally.
        use_cmega = (
            use_mega
            and config.device_decode
            and self._backend.decode_fn is not None
            and getattr(source, "block_rows", None) is not None
            and hasattr(source, "scan_blocks")
        )
        n = 0
        exhausted = False
        stop = False  # preemption fired: drain-in-flight done, exit early
        if use_cmega and (max_batches is None or max_batches >= K):
            cmegas = pipe.compressed_megabatches(K, start=self._cursor)
            try:
                exhausted = True  # flipped back if we stop for the budget
                for cm in cmegas:
                    self.partial_fit_cmegabatch(cm)
                    # refresh the resume token (see the per-batch loop below)
                    self._cursor = source.cursor_at(self._cursor.row)
                    n += cm.n_batches
                    if self._autosave_due(config):
                        self._autosave(config)
                    if preemption is not None and preemption.preempted:
                        stop = True
                        break
                    if cm.n_batches < K:
                        break  # ragged tail: the stream is exhausted
                    if max_batches is not None and max_batches - n < K:
                        exhausted = False
                        break
            finally:
                cmegas.close()
        elif use_mega and (max_batches is None or max_batches >= K):
            # waves are planned on the pipeline's prefetch thread while the
            # megabatch is staged (None when the backend has no wavefront_fn
            # or the knob is unset — partial_fit_megabatch then ignores it)
            wf = (
                config.wavefront
                if self._backend.wavefront_fn is not None
                else None
            )
            megas = pipe.megabatches(
                K,
                start=self._cursor,
                wavefront=wf,
                wavefront_gap=(
                    config.wavefront_gap if wf is not None else None
                ),
            )
            try:
                exhausted = True  # flipped back if we stop for the budget
                for mega in megas:
                    self.partial_fit_megabatch(
                        mega.edges, raw_rows=mega.n_rows, plan=mega.plan
                    )
                    # refresh the resume token (see the per-batch loop below)
                    self._cursor = source.cursor_at(self._cursor.row)
                    n += mega.n_batches
                    if self._autosave_due(config):
                        self._autosave(config)
                    if preemption is not None and preemption.preempted:
                        stop = True
                        break
                    if mega.n_batches < K:
                        break  # ragged tail: the stream is exhausted
                    if max_batches is not None and max_batches - n < K:
                        # not enough budget for another full megabatch; any
                        # remainder drains per-batch below
                        exhausted = False
                        break
            finally:
                megas.close()
        if not stop and not exhausted and (
            max_batches is None or n < max_batches
        ):
            batches = pipe.batches(start=self._cursor)
            try:
                for batch in batches:
                    self.partial_fit(batch.edges, raw_rows=batch.n_rows)
                    # refresh the resume token: the source knows the best
                    # sync point (codec block, text byte offset, merge
                    # positions) for the row partial_fit just advanced to
                    self._cursor = source.cursor_at(self._cursor.row)
                    n += 1
                    if self._autosave_due(config):
                        self._autosave(config)
                    if preemption is not None and preemption.preempted:
                        stop = True
                        break
                    if max_batches is not None and n >= max_batches:
                        break
            finally:
                # deterministic suspension: shut the prefetch thread down
                # before reading the residency figure or returning control
                batches.close()
        self.peak_buffer_bytes = max(
            self.peak_buffer_bytes, pipe.peak_buffer_bytes
        )
        self.stream_batches += n
        self.ingest_retries += pipe.retries
        self.ingest_stalls += pipe.stalls
        if (
            stop
            and config.autosave_dir
            and self._last_autosave_row != self._cursor.row
        ):
            # preemption drain: the in-flight unit landed, persist it so the
            # next session resumes from this exact cursor
            self._autosave(config)
        return self

    def finalize(self) -> Clustering:
        """The clustering of everything ingested so far.  Does not consume
        the state — more ``partial_fit`` calls may follow.

        Backends with a ``finalize_fn`` (sweep, sharded) derive labels and
        the :class:`ClusterState` view from the current state; the others
        reuse the labels of the last ingested batch.
        """
        if self._backend.finalize_fn is not None:
            result = self._backend.finalize_fn(self._state, self.config)
        elif self._last_result is not None:
            result = self._last_result
        else:  # no batch ingested yet: every node is its own singleton
            result = self._backend.fn(_EMPTY_BATCH, self.config, self._state)
            self._state = result.state
        info = result.info
        if self._refine is not None and result.state is not None:
            # Multi-stage refinement (DESIGN.md §11): contract the streamed
            # communities through the accumulated sketch, refine the
            # supergraph, project back, optionally re-play the buffered
            # window.  Nothing is consumed — the sketch keeps accumulating
            # if more partial_fit calls follow.
            labels, state, info = self._refine.apply(
                np.asarray(result.labels), result.state, info, self.config
            )
            result = BackendResult(state=state, labels=labels, info=info)
        if self.stream_batches:  # surfaced like streamed cluster() calls
            info = dict(info)
            info["peak_buffer_bytes"] = self.peak_buffer_bytes
            info["stream_batches"] = self.stream_batches
            info["stream_dispatches"] = self.stream_dispatches
            if self.stream_megabatches:
                info["stream_megabatches"] = self.stream_megabatches
        if self.wavefront_megabatches:  # §12 counters (directly pushed
            info = dict(info)  # megabatches count too, so copy again here)
            if self._wavefront_stats is not None:
                live, fall = (int(x) for x in np.asarray(self._wavefront_stats))
            else:
                live = fall = 0
            info["wavefront_megabatches"] = self.wavefront_megabatches
            info["wavefront_waves"] = self.wavefront_waves
            info["wavefront_mean_wave_width"] = (
                self.wavefront_rows_in_waves / self.wavefront_waves
                if self.wavefront_waves
                else 0.0
            )
            info["wavefront_leftover_rows"] = self.wavefront_leftover_rows
            info["wavefront_dead_rows_skipped"] = (
                self.wavefront_dead_rows_skipped
            )
            info["wavefront_plan_seconds"] = self.wavefront_plan_seconds
            info["wavefront_live_waves"] = live
            info["wavefront_fallback_waves"] = fall
            info["wavefront_fallback_rate"] = fall / live if live else 0.0
            info["wavefront_widths"] = list(self.wavefront_widths)
        if (  # §15 resilience counters: surfaced whenever the machinery
            self.autosaves  # was active, even if every count is zero —
            or self.ingest_retries  # "nothing lost" is a reportable fact
            or self.ingest_stalls
            or self._quarantine_sources
        ):
            info = dict(info)
            info["autosaves"] = self.autosaves
            info["ingest_retries"] = self.ingest_retries
            info["ingest_stalls"] = self.ingest_stalls
            info["blocks_quarantined"] = sum(
                s.blocks_quarantined for s in self._quarantine_sources
            )
            info["edges_lost"] = sum(
                s.edges_lost for s in self._quarantine_sources
            )
        if self.device_decoded_megabatches:  # §14 counters
            info = dict(info)
            info["device_decoded_megabatches"] = self.device_decoded_megabatches
            info["device_fallback_rows"] = self.device_fallback_rows
            info["device_fallback_segments"] = self.device_fallback_segments
            info["device_total_segments"] = self.device_total_segments
            info["device_fallback_segment_rate"] = (
                self.device_fallback_segments / self.device_total_segments
                if self.device_total_segments
                else 0.0
            )
        # The device tiers *donate* their state buffers (chunked / pallas /
        # multiparam / sharded updates), so the live self._state — which
        # result.state/labels may alias via to_device() — is consumed by the
        # next partial_fit.  Snapshot the result to host so a finalized
        # Clustering outlives further ingestion, per this method's contract.
        return Clustering(
            state=None if result.state is None else result.state.to_numpy(),
            config=self.config,
            raw_labels=np.asarray(result.labels),
            info=info,
        )

    # ------------------------------------------------------------------
    # Suspend / resume across sessions (repro.checkpoint.manager)
    # ------------------------------------------------------------------

    def save(self, directory: str) -> str:
        """Checkpoint state (step-atomic, step = edges seen) + config sidecar.

        The config is written first via atomic replace, so a preemption at
        any point leaves either a restorable checkpoint or a clean
        "no checkpoints" failure — never a state/config torn pair.  The
        stream cursor (row + opaque codec token, as a flat int64 leaf) is
        part of the checkpoint pytree itself, so state and stream position
        can never tear apart.  Wide states (sweep, sharded) are just wider
        pytrees — they ride the same manager, and so does the refinement
        runtime when ``config.refine`` is set: the supergraph sketch (and
        the replay window, for ``+replay``) becomes an extra leaf-set, so a
        resumed run's refinement is bit-identical to an uninterrupted one.
        """
        mgr = CheckpointManager(directory)  # creates the directory
        tmp = os.path.join(directory, _CONFIG_FILE + ".tmp")
        with open(tmp, "w") as f:
            f.write(self.config.to_json())
        os.replace(tmp, os.path.join(directory, _CONFIG_FILE))
        tree = {
            "cluster_state": self._state,
            "stream_cursor": self._cursor.to_array(),
        }
        if self._refine is not None:
            tree["refine"] = self._refine.to_leaves()
        return mgr.save(self.edges_seen, tree)

    @classmethod
    def restore(
        cls, directory: str, config: Optional[ClusterConfig] = None
    ) -> "StreamClusterer":
        """Resume from :meth:`save`; ``config`` overrides the saved one.

        An override may switch backends only within the same label space
        *and* state kind (dense → scan → pallas → chunked); an oracle state
        read as dense state — or a sweep pytree read as a 3n-int state —
        would silently mislabel, so both are rejected.
        """
        with open(os.path.join(directory, _CONFIG_FILE)) as f:
            saved = ClusterConfig.from_json(f.read())
        if config is None:
            config = saved
        else:
            saved_backend = get_backend(saved.backend)
            new_backend = get_backend(config.backend)
            if saved_backend.state_kind != new_backend.state_kind:
                raise ValueError(
                    f"cannot restore a {saved.backend!r} checkpoint "
                    f"({saved_backend.state_kind} state kind) with backend="
                    f"{config.backend!r} ({new_backend.state_kind} state "
                    "kind)"
                )
            if saved_backend.label_space != new_backend.label_space:
                raise ValueError(
                    f"cannot restore a {saved.backend!r} checkpoint "
                    f"({saved_backend.label_space} label space) with backend="
                    f"{config.backend!r} ({new_backend.label_space} label "
                    "space)"
                )
            if config.n_shards is None and saved.n_shards is not None:
                # shape fields the override leaves unset come from the saved
                # config, never from the restoring host's device count — the
                # checkpoint's shard axis is fixed on disk
                config = config.replace(n_shards=saved.n_shards)
        backend = get_backend(config.backend)
        config = _resolve_config(config, backend)
        mgr = CheckpointManager(directory)
        # Restore against a host-side, state-shape-aware template: the
        # backend's init_fn builds the right pytree kind (ClusterState /
        # SweepState / ShardedState) and numpy leaves come back with the
        # exact on-disk dtypes, so the int64 counters (edges_seen,
        # stream_offset) are not demoted to int32 the way device placement
        # would.  Device tiers re-place the state themselves (to_device).
        state_template = backend.init_fn(config).to_numpy()
        leaves = mgr.leaf_names()
        if "stream_cursor" in leaves:
            template = {
                "cluster_state": state_template,
                # variable-length leaf: the manager restores host leaves at
                # their on-disk shape, so any token width round-trips
                "stream_cursor": np.zeros(1, np.int64),
            }
            restored = mgr.restore(template)
            cursor = Cursor.from_array(restored["stream_cursor"])
        elif "stream_offset" in leaves:
            # pre-cursor checkpoint layout: a bare int64 raw-row offset —
            # restore it as a token-less cursor (always a valid position)
            template = {
                "cluster_state": state_template,
                "stream_offset": np.int64(0),
            }
            restored = mgr.restore(template)
            cursor = Cursor(int(restored["stream_offset"]))
        else:
            # pre-offset layout (state only): stream accounting from zero
            restored = mgr.restore({"cluster_state": state_template})
            cursor = Cursor(0)
        sc = cls(config, state=restored["cluster_state"])
        sc._cursor = cursor
        sc._last_autosave_row = cursor.row  # periodic saves resume from here
        if sc._refine is not None:
            # Refine leaves ride the same checkpoint (flattened as
            # refine_acc{i}_{kv,meta} / refine_replay_rows).  Restore them
            # only when the saved run recorded a matching set — an old or
            # refine-less checkpoint resumes with a fresh (empty) sketch,
            # which simply means the refinement only sees post-resume edges.
            n_accs = len(sc._refine.accumulators)
            acc_names = {
                f"refine_acc{i}_{part}"
                for i in range(n_accs)
                for part in ("kv", "meta")
            }
            if acc_names <= leaves:
                tmpl = {
                    f"acc{i}": {
                        "kv": np.zeros((0, 2), np.int64),
                        "meta": np.zeros(4, np.int64),
                    }
                    for i in range(n_accs)
                }
                if (
                    sc._refine.replay_buffer is not None
                    and "refine_replay_rows" in leaves
                ):
                    tmpl["replay"] = {"rows": np.zeros((0, 2), np.int32)}
                sc._refine.load_leaves(mgr.restore({"refine": tmpl})["refine"])
        return sc
