"""The multi-stage refinement subsystem behind ``ClusterConfig.refine``.

Closes the streaming quality gap (ROADMAP item 1, CluStRE-style): after any
streamed fit, the final communities are contracted into a weighted
supergraph — O(#clusters), in memory even when the edge list never was — a
few weighted Louvain / label-propagation rounds refine it, and the refined
labels project back onto nodes.  Three cooperating pieces (DESIGN.md §11):

* :class:`SupergraphAccumulator` — a bounded-memory sketch of
  inter-community edge weight, updated per (mega)batch as the stream is
  ingested (labels observed at dispatch granularity), so the contraction
  needs **no second pass over the edges**.  Dense ``O(k^2)`` while the
  community count is small; a capped top-weight hash after that, with a
  ``dropped_weight`` counter so truncation is never silent.
* :class:`ReplayBuffer` — the buffered variant (Faraj & Schulz): the most
  recent ``K*batch_edges`` live edges are kept (row-exact, a pure function
  of the stream position) and re-played through the projected labels as
  weighted plurality sweeps — the one stage that can move *individual*
  nodes, i.e. split streamed clusters, at zero extra I/O.
* :class:`RefineRuntime` — per-run wiring: creates one accumulator per
  sweep column (``SweepState``), one for the single-state kinds; observes
  batches against the right labels per state kind; serializes sketch +
  replay window as checkpoint leaves (bit-identical resume); applies the
  refinement at ``finalize()`` and rebuilds the :class:`ClusterState` view.

Everything here is host-side numpy — refinement is a post-stream,
O(#clusters)-sized stage; the device pipeline is untouched unless
``config.refine`` is set.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.labelprop import label_propagation
from repro.core.refine import contract_pairs, project_labels, refine_partition
from repro.core.state import ClusterState
from repro.graph.pipeline import PAD

# Sketch defaults: dense matrix while distinct labels fit DENSE_K; hash with
# at most MAX_PAIRS entries after that (16 B/entry -> 16 MB ceiling).
DENSE_K = 512
MAX_PAIRS = 1 << 20

# Replay sweeps mirror the bench's LabelProp setting.
REPLAY_SWEEPS = 3

_MODE_DENSE, _MODE_HASH = 0, 1


def parse_refine(spec: Optional[str]) -> Optional[Tuple[str, bool]]:
    """``"louvain" | "labelprop" ["+replay"]`` -> ``(engine, replay)``."""
    if spec is None:
        return None
    engine, plus, mod = spec.partition("+")
    if engine not in ("louvain", "labelprop") or (plus and mod != "replay"):
        raise ValueError(
            f"refine must be 'louvain' or 'labelprop', optionally with "
            f"'+replay', got {spec!r}"
        )
    return engine, bool(plus)


class SupergraphAccumulator:
    """Bounded-memory inter-community edge-weight sketch.

    ``observe(edges, labels)`` buckets each live edge under its endpoints'
    *current* community labels (unordered pair; equal labels accumulate as
    internal weight).  Storage starts as a dense ``(DENSE_K, DENSE_K)``
    int64 matrix behind a label->slot map and spills to a hash of packed
    ``lo * n + hi`` keys once more than ``dense_k`` distinct labels appear;
    the hash is capped at ``max_pairs`` entries — overflow evicts the
    lightest pairs (deterministically, by ``(weight, key)``) into
    ``dropped_weight``, so truncation is visible, never silent.

    The sketch's content is a pure mapping ``{packed pair -> weight}`` plus
    the counter: :meth:`to_leaves` serializes exactly that (key-sorted), and
    a restored accumulator continues bit-identically — internal slot order
    never leaks into :meth:`entries`, eviction, or spill decisions.
    """

    def __init__(
        self, n: int, dense_k: int = DENSE_K, max_pairs: int = MAX_PAIRS
    ):
        self.n = int(n)
        self.dense_k = int(dense_k)
        self.max_pairs = int(max_pairs)
        self.dropped_weight = 0
        self._idx: Dict[int, int] = {}  # label -> dense slot
        self._mat: Optional[np.ndarray] = None  # (dense_k, dense_k) int64
        self._pairs: Optional[Dict[int, int]] = None  # packed key -> weight
        self._peak_bytes = 0

    # ------------------------------------------------------------------
    @property
    def spilled(self) -> bool:
        return self._pairs is not None

    @property
    def nbytes(self) -> int:
        if self._pairs is not None:
            return 16 * len(self._pairs)  # packed int64 key + int64 weight
        return 0 if self._mat is None else int(self._mat.nbytes)

    @property
    def peak_bytes(self) -> int:
        return max(self._peak_bytes, self.nbytes)

    # ------------------------------------------------------------------
    def observe(self, edges: np.ndarray, labels: np.ndarray) -> None:
        """Accumulate one batch of edges under the given labelling."""
        e = np.asarray(edges).reshape(-1, 2)
        if e.shape[0] == 0:
            return
        live = (e[:, 0] != PAD) & (e[:, 1] != PAD) & (e[:, 0] != e[:, 1])
        e = e[live]
        if e.shape[0] == 0:
            return
        labels = np.asarray(labels)
        a = labels[e[:, 0]].astype(np.int64)
        b = labels[e[:, 1]].astype(np.int64)
        lo, hi = np.minimum(a, b), np.maximum(a, b)
        keys, w = np.unique(lo * self.n + hi, return_counts=True)
        if not self.spilled:
            fresh = np.unique(
                np.concatenate([keys // self.n, keys % self.n])
            )
            new_labels = [x for x in fresh.tolist() if x not in self._idx]
            if len(self._idx) + len(new_labels) > self.dense_k:
                self._spill()
            else:
                for x in new_labels:
                    self._idx[x] = len(self._idx)
                self._observe_dense(keys, w)
                return
        self._observe_hash(keys, w)

    def _observe_dense(self, keys: np.ndarray, w: np.ndarray) -> None:
        if self._mat is None:
            self._mat = np.zeros((self.dense_k, self.dense_k), np.int64)
            self._peak_bytes = max(self._peak_bytes, int(self._mat.nbytes))
        ia = np.fromiter(
            (self._idx[int(x)] for x in keys // self.n), np.int64, len(keys)
        )
        ib = np.fromiter(
            (self._idx[int(x)] for x in keys % self.n), np.int64, len(keys)
        )
        np.add.at(self._mat, (ia, ib), w)

    def _observe_hash(self, keys: np.ndarray, w: np.ndarray) -> None:
        pairs = self._pairs
        for k, c in zip(keys.tolist(), w.tolist()):
            pairs[k] = pairs.get(k, 0) + c
        if len(pairs) > self.max_pairs:
            self._evict()
        self._peak_bytes = max(self._peak_bytes, self.nbytes)

    def _spill(self) -> None:
        """Dense -> hash conversion (content-preserving)."""
        self._pairs = {}
        if self._mat is not None:
            back = np.empty(len(self._idx), np.int64)
            for label, slot in self._idx.items():
                back[slot] = label
            ia, ib = np.nonzero(self._mat)
            la, lb = back[ia], back[ib]
            lo, hi = np.minimum(la, lb), np.maximum(la, lb)
            for k, c in zip(
                (lo * self.n + hi).tolist(), self._mat[ia, ib].tolist()
            ):
                self._pairs[k] = self._pairs.get(k, 0) + c
        self._idx = {}
        self._mat = None

    def _evict(self) -> None:
        """Drop the lightest pairs down to 3/4 of the cap; deterministic
        (ordered by ``(weight, key)``) and counted, never silent."""
        target = (3 * self.max_pairs) // 4
        by_weight = sorted((w, k) for k, w in self._pairs.items())
        for w, k in by_weight[: len(self._pairs) - target]:
            self.dropped_weight += w
            del self._pairs[k]

    # ------------------------------------------------------------------
    def entries(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Accumulated ``(a, b, weight)`` label pairs, key-sorted (so the
        output is independent of internal storage mode or slot order)."""
        if self.spilled:
            if not self._pairs:
                z = np.zeros(0, np.int64)
                return z, z, z
            keys = np.sort(np.fromiter(self._pairs, np.int64, len(self._pairs)))
            w = np.fromiter(
                (self._pairs[int(k)] for k in keys), np.int64, len(keys)
            )
        else:
            if self._mat is None:
                z = np.zeros(0, np.int64)
                return z, z, z
            back = np.empty(len(self._idx), np.int64)
            for label, slot in self._idx.items():
                back[slot] = label
            ia, ib = np.nonzero(self._mat)
            la, lb = back[ia], back[ib]
            lo, hi = np.minimum(la, lb), np.maximum(la, lb)
            keys = lo * self.n + hi
            order = np.argsort(keys, kind="stable")
            keys, w = keys[order], self._mat[ia, ib][order]
        return keys // self.n, keys % self.n, w

    # ------------------------------------------------------------------
    def to_leaves(self) -> Dict[str, np.ndarray]:
        """Checkpoint leaves: key-sorted ``(key, weight)`` rows + counters."""
        a, b, w = self.entries()
        kv = np.stack([a * self.n + b, w], axis=1) if len(a) else np.zeros(
            (0, 2), np.int64
        )
        meta = np.array(
            [
                _MODE_HASH if self.spilled else _MODE_DENSE,
                self.dropped_weight,
                self.peak_bytes,
                self.n,
            ],
            np.int64,
        )
        return {"kv": kv.astype(np.int64), "meta": meta}

    @classmethod
    def from_leaves(
        cls,
        leaves: Dict[str, np.ndarray],
        dense_k: int = DENSE_K,
        max_pairs: int = MAX_PAIRS,
    ) -> "SupergraphAccumulator":
        meta = np.asarray(leaves["meta"], np.int64)
        acc = cls(int(meta[3]), dense_k=dense_k, max_pairs=max_pairs)
        acc.dropped_weight = int(meta[1])
        acc._peak_bytes = int(meta[2])
        kv = np.asarray(leaves["kv"], np.int64).reshape(-1, 2)
        if int(meta[0]) == _MODE_HASH:
            acc._pairs = dict(zip(kv[:, 0].tolist(), kv[:, 1].tolist()))
        elif len(kv):
            for x in np.unique(
                np.concatenate([kv[:, 0] // acc.n, kv[:, 0] % acc.n])
            ).tolist():
                acc._idx[x] = len(acc._idx)
            acc._observe_dense(kv[:, 0], kv[:, 1])
        return acc


class ReplayBuffer:
    """The most recent ``cap_rows`` live stream edges, row-exact.

    Eviction is by rows, not batches, so the contents are a pure function of
    the stream position — which is what makes a checkpointed-and-resumed
    run's replay window bit-identical to the uninterrupted run's.
    """

    def __init__(self, cap_rows: int):
        self.cap_rows = int(cap_rows)
        self._chunks: deque = deque()
        self._total = 0

    def append(self, edges: np.ndarray) -> None:
        e = np.asarray(edges).reshape(-1, 2)
        live = (e[:, 0] != PAD) & (e[:, 1] != PAD) & (e[:, 0] != e[:, 1])
        e = np.ascontiguousarray(e[live], dtype=np.int32)  # copy: never pin
        if e.shape[0] == 0:  # pipeline buffers via a view
            return
        self._chunks.append(e)
        self._total += e.shape[0]
        while self._total > self.cap_rows:
            excess = self._total - self.cap_rows
            head = self._chunks[0]
            if head.shape[0] <= excess:
                self._chunks.popleft()
                self._total -= head.shape[0]
            else:
                self._chunks[0] = head[excess:]
                self._total -= excess

    @property
    def n_rows(self) -> int:
        return self._total

    def rows(self) -> np.ndarray:
        if not self._chunks:
            return np.zeros((0, 2), np.int32)
        return np.concatenate(list(self._chunks), axis=0)

    def to_leaf(self) -> np.ndarray:
        return self.rows()

    def load_leaf(self, leaf: np.ndarray) -> None:
        self._chunks.clear()
        self._total = 0
        self.append(np.asarray(leaf, np.int32).reshape(-1, 2))


class RefineRuntime:
    """Per-run refinement wiring for a :class:`StreamClusterer`.

    Owns the accumulator(s) (one per sweep column for the sweep kind) and
    the optional replay buffer; dispatches observation and application on
    the backend's state kind.
    """

    def __init__(self, config, backend):
        parsed = parse_refine(config.refine)
        assert parsed is not None, "RefineRuntime requires config.refine"
        if backend.label_space != "dense":
            raise ValueError(
                f"refine requires a dense-label-space backend; "
                f"{backend.name!r} labels live in the "
                f"{backend.label_space!r} space"
            )
        self.engine, self.replay = parsed
        self.rounds = (
            10 if config.refine_rounds is None else int(config.refine_rounds)
        )
        max_pairs = (
            MAX_PAIRS
            if config.refine_max_pairs is None
            else int(config.refine_max_pairs)
        )
        self._kind = backend.state_kind
        n_accs = len(config.v_maxes) if self._kind == "sweep" else 1
        self.accumulators: List[SupergraphAccumulator] = [
            SupergraphAccumulator(config.n, max_pairs=max_pairs)
            for _ in range(n_accs)
        ]
        self.replay_buffer: Optional[ReplayBuffer] = None
        if self.replay:
            from repro.cluster.api import DEFAULT_BATCH_EDGES

            cap = (config.megabatch_k or 1) * (
                config.batch_edges or DEFAULT_BATCH_EDGES
            )
            self.replay_buffer = ReplayBuffer(cap)

    # ------------------------------------------------------------------
    def observe(self, state, edges) -> None:
        """Bucket one ingested (mega)batch under the post-update labels.

        Observation runs at dispatch granularity: per batch in per-batch
        mode, per fused megabatch in megabatch mode (one label fetch per
        dispatch — the sketch, like the labels it reads, is a host-visible
        side channel of the device run).
        """
        e = np.asarray(edges).reshape(-1, 2)
        if self._kind == "sweep":
            c = np.asarray(state.c)  # (A, n)
            for a, acc in enumerate(self.accumulators):
                acc.observe(e, c[a])
        elif self._kind == "sharded":
            # the batch just ingested went to shard (cursor - 1) % P
            s = (int(state.cursor) - 1) % state.n_shards
            self.accumulators[0].observe(e, np.asarray(state.c[s]))
        else:
            self.accumulators[0].observe(e, np.asarray(state.c))
        if self.replay_buffer is not None:
            self.replay_buffer.append(e)

    # ------------------------------------------------------------------
    def apply(self, labels: np.ndarray, state, info: dict, config):
        """Refine final labels through the contracted supergraph.

        ``labels``: the backend's finalized raw labels (dense space);
        ``state``: the finalized :class:`ClusterState` view.  Returns
        ``(labels, state, info)`` with refined labels, a rebuilt state view
        (volumes re-derived over the refined communities), and refinement
        diagnostics.  Consumes nothing — later ``partial_fit`` calls keep
        accumulating into the same sketch.
        """
        acc = self.accumulators[
            info["best_index"] if self._kind == "sweep" else 0
        ]
        labels = np.asarray(labels)
        a, b, w = acc.entries()
        sg = contract_pairs(a, b, w, labels)
        sg_labels = refine_partition(
            sg, engine=self.engine, rounds=self.rounds
        )
        refined = project_labels(labels, sg, sg_labels)
        replay_rows = 0
        if self.replay_buffer is not None:
            window = self.replay_buffer.rows()
            replay_rows = window.shape[0]
            if replay_rows:
                # The split-capable stage: supergraph moves can never break a
                # supernode apart, and plurality votes seeded from the coarse
                # refined labels would only ever ratify them — so nodes the
                # window covers restart from the *fine* streamed labels and
                # are re-played at node granularity, while out-of-window
                # nodes keep the supergraph-refined labels (the global
                # coarse-grained fix is all the evidence we still have for
                # them).  Both label spaces are founder/representative node
                # ids, so mixing them cannot collide two unrelated groups.
                init = refined.astype(np.int64)
                touched = np.unique(window)
                init[touched] = np.asarray(labels, np.int64)[touched]
                refined = label_propagation(
                    window,
                    len(refined),
                    sweeps=REPLAY_SWEEPS,
                    init_labels=init,
                ).astype(np.int32)
        d = np.asarray(state.d)
        v = np.zeros(len(refined), np.int64)
        np.add.at(v, refined, d.astype(np.int64))
        new_state = ClusterState(
            d=d,
            c=refined.astype(np.int32),
            v=np.minimum(v, np.iinfo(np.int32).max).astype(np.int32),
            edges_seen=np.int64(state.edges_seen),
        )
        info = dict(info)
        info.update(
            refine_engine=self.engine,
            refine_replay_rows=replay_rows,
            refine_supernodes=sg.k,
            refine_communities=int(np.unique(refined).shape[0]),
            refine_sketch_bytes=acc.nbytes,
            refine_sketch_peak_bytes=max(
                x.peak_bytes for x in self.accumulators
            ),
            refine_dropped_weight=acc.dropped_weight,
        )
        return refined, new_state, info

    # ------------------------------------------------------------------
    # Checkpoint leaves (ride CheckpointManager with the state pytree)
    # ------------------------------------------------------------------

    def to_leaves(self) -> Dict[str, Dict[str, np.ndarray]]:
        out: Dict[str, Dict[str, np.ndarray]] = {
            f"acc{i}": acc.to_leaves()
            for i, acc in enumerate(self.accumulators)
        }
        if self.replay_buffer is not None:
            out["replay"] = {"rows": self.replay_buffer.to_leaf()}
        return out

    def leaves_template(self) -> Dict[str, Dict[str, np.ndarray]]:
        """Restore template mirroring :meth:`to_leaves` — variable-length
        host leaves come back at their on-disk shape."""
        out: Dict[str, Dict[str, np.ndarray]] = {
            f"acc{i}": {
                "kv": np.zeros((0, 2), np.int64),
                "meta": np.zeros(4, np.int64),
            }
            for i in range(len(self.accumulators))
        }
        if self.replay_buffer is not None:
            out["replay"] = {"rows": np.zeros((0, 2), np.int32)}
        return out

    def load_leaves(self, leaves: Dict[str, Dict[str, np.ndarray]]) -> None:
        for i in range(len(self.accumulators)):
            old = self.accumulators[i]
            self.accumulators[i] = SupergraphAccumulator.from_leaves(
                leaves[f"acc{i}"],
                dense_k=old.dense_k,
                max_pairs=old.max_pairs,
            )
        if self.replay_buffer is not None and "replay" in leaves:
            self.replay_buffer.load_leaf(leaves["replay"]["rows"])
