"""``repro.cluster`` — the single public API for streaming graph clustering.

    from repro.cluster import cluster, StreamClusterer, ClusterConfig

    cfg = ClusterConfig(n=10_000, v_max=64, backend="chunked")
    result = cluster(edges, cfg)                  # one-shot
    sc = StreamClusterer(cfg)                     # incremental
    for batch in arriving_batches:
        sc.partial_fit(batch)
    result = sc.finalize()

Backends (``ClusterConfig(backend=...)``): oracle, dense, scan, chunked,
pallas, multiparam, distributed — see ``available_backends()`` and
DESIGN.md §3/§6.  Quality metrics are re-exported for convenience so
examples and benchmarks need only this package.
"""

from repro.core.metrics import (  # noqa: F401
    avg_f1,
    community_stats,
    modularity,
    nmi,
)
from repro.core.state import ClusterState  # noqa: F401
from repro.core.streaming import PAD, canonical_labels  # noqa: F401
from repro.cluster.api import Clustering, StreamClusterer, cluster  # noqa: F401
from repro.cluster.config import ClusterConfig  # noqa: F401
from repro.cluster.registry import (  # noqa: F401
    Backend,
    BackendResult,
    available_backends,
    get_backend,
    register_backend,
)

__all__ = [
    "PAD",
    "Backend",
    "BackendResult",
    "ClusterConfig",
    "ClusterState",
    "Clustering",
    "StreamClusterer",
    "available_backends",
    "avg_f1",
    "canonical_labels",
    "cluster",
    "community_stats",
    "get_backend",
    "modularity",
    "nmi",
    "register_backend",
]
