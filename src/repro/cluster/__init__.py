"""``repro.cluster`` — the single public API for streaming graph clustering.

    from repro.cluster import cluster, StreamClusterer, ClusterConfig

    cfg = ClusterConfig(n=10_000, v_max=64, backend="chunked")
    result = cluster(edges, cfg)                  # one-shot
    sc = StreamClusterer(cfg)                     # incremental
    for batch in arriving_batches:
        sc.partial_fit(batch)
    result = sc.finalize()

Backends (``ClusterConfig(backend=...)``): oracle, dense, scan, chunked,
pallas, multiparam, distributed — see ``available_backends()`` and
DESIGN.md §3/§6.  Quality metrics are re-exported for convenience so
examples and benchmarks need only this package.

``edges`` may be an array, a file path, or an ``EdgeSource``
(``repro.graph.sources``) — out-of-core streams are ingested in O(batch)
host memory (DESIGN.md §"Ingestion"); the source types are re-exported here.
"""

from repro.core.metrics import (  # noqa: F401
    avg_f1,
    community_stats,
    modularity,
    nmi,
    weighted_modularity,
)
from repro.core.state import (  # noqa: F401
    ClusterState,
    FleetState,
    ShardedState,
    SweepState,
)
from repro.core.streaming import canonical_labels  # noqa: F401
from repro.graph.pipeline import PAD  # noqa: F401
from repro.cluster.api import Clustering, StreamClusterer, cluster  # noqa: F401
from repro.cluster.config import ClusterConfig  # noqa: F401
from repro.cluster.fleet import (  # noqa: F401
    FleetClusterer,
    FleetClustering,
    cluster_fleet,
)
from repro.cluster.refine import (  # noqa: F401
    RefineRuntime,
    ReplayBuffer,
    SupergraphAccumulator,
)
from repro.cluster.registry import (  # noqa: F401
    Backend,
    BackendResult,
    available_backends,
    get_backend,
    register_backend,
)
from repro.checkpoint.manager import (  # noqa: F401
    CheckpointCorruptError,
    CheckpointError,
)
from repro.graph.codecs import Cursor, DeltaVarintCodec, RawCodec  # noqa: F401
from repro.graph.errors import (  # noqa: F401
    CorruptBlockError,
    CorruptStreamError,
    RetryPolicy,
    SourceDeadError,
    StallError,
    TransientReadError,
    TruncatedStreamError,
)
from repro.graph.faults import ChaosSource, FaultInjector, FaultPlan  # noqa: F401
from repro.graph.pipeline import BatchPipeline, MegaBatch  # noqa: F401
from repro.graph.tenants import FleetSlab, TenantRouter  # noqa: F401
from repro.graph.wavefront import WavePlan, plan_waves  # noqa: F401
from repro.graph.sources import (  # noqa: F401
    ArraySource,
    BinaryFileSource,
    CodecFileSource,
    EdgeListFileSource,
    EdgeSource,
    GeneratorSource,
    MergedSource,
    ShardedSource,
    as_source,
)

__all__ = [
    "PAD",
    "ArraySource",
    "Backend",
    "BackendResult",
    "BatchPipeline",
    "BinaryFileSource",
    "ChaosSource",
    "CheckpointCorruptError",
    "CheckpointError",
    "CodecFileSource",
    "ClusterConfig",
    "ClusterState",
    "Clustering",
    "CorruptBlockError",
    "CorruptStreamError",
    "Cursor",
    "DeltaVarintCodec",
    "EdgeListFileSource",
    "EdgeSource",
    "FaultInjector",
    "FaultPlan",
    "FleetClusterer",
    "FleetClustering",
    "FleetSlab",
    "FleetState",
    "GeneratorSource",
    "MegaBatch",
    "MergedSource",
    "RawCodec",
    "RefineRuntime",
    "ReplayBuffer",
    "RetryPolicy",
    "ShardedSource",
    "ShardedState",
    "SourceDeadError",
    "StallError",
    "StreamClusterer",
    "SupergraphAccumulator",
    "SweepState",
    "TenantRouter",
    "TransientReadError",
    "TruncatedStreamError",
    "WavePlan",
    "as_source",
    "available_backends",
    "avg_f1",
    "canonical_labels",
    "cluster",
    "cluster_fleet",
    "community_stats",
    "get_backend",
    "modularity",
    "nmi",
    "plan_waves",
    "register_backend",
    "weighted_modularity",
]
