"""String-keyed backend registry (mirrors ``configs/registry.py``).

Every clustering implementation registers here under a stable name; the
unified :func:`repro.cluster.cluster` call and
:class:`repro.cluster.StreamClusterer` dispatch through this table, so later
subsystems (sharding, caching, serving) plug in new backends once instead of
adding an eighth top-level entry point.

Backend contract::

    init_fn(config) -> state                      # fresh state pytree
    fn(edges, config, state, mesh=None) -> BackendResult(state, labels, info)
    finalize_fn(state, config) -> BackendResult   # optional
    megabatch_fn(edges, config, state) -> BackendResult  # optional fused path

* ``edges``: (m, 2) int array in stream order (PAD rows are no-ops).
* ``state``: the pytree produced by this backend's ``init_fn`` (fresh or
  carried from a previous batch) — its *kind* is declared by
  ``state_kind`` (``"cluster"``: the 3n-int :class:`ClusterState`;
  ``"sweep"``: the §2.5 :class:`~repro.core.state.SweepState`;
  ``"sharded"``: the distributed :class:`~repro.core.state.ShardedState`).
  The API layer dispatches on the kind instead of assuming ``ClusterState``,
  which is what lets every tier ride the same resumable, out-of-core
  ``partial_fit`` spine.
* ``labels``: raw per-node label array in the backend's label space; a
  backend with a ``finalize_fn`` may return ``labels=None`` from ``fn`` —
  labels are then derived from state at finalize time (so per-batch ingest
  stays pure state threading).  ``finalize_fn`` returns the
  :class:`ClusterState` *view* of the result (e.g. the selected sweep entry,
  the merged shard state), which is what :class:`repro.cluster.Clustering`
  carries — so edge-free metrics work uniformly across state kinds.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

from repro.core.state import ClusterState

STATE_KINDS = ("cluster", "sweep", "sharded")


class BackendResult(NamedTuple):
    state: Any  # the backend's state pytree (kind per Backend.state_kind)
    labels: Any  # (n,) raw label array; None from fn when finalize_fn derives
    info: Dict[str, Any]


def _default_init(config) -> ClusterState:
    return ClusterState.init(config.n)


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered clustering implementation + its capabilities."""

    name: str
    fn: Callable[..., BackendResult]
    init_fn: Callable[[Any], Any]  # config -> fresh state pytree
    resumable: bool  # supports partial_fit state threading
    bit_exact: bool  # strict stream order (identical to Algorithm 1)
    state_kind: str = "cluster"  # which state pytree init_fn/fn thread
    label_space: str = "dense"  # "dense": c[i] is a node id, v[cid] its volume
    #                             "oracle": 1-based paper ids, v[cid-1]
    chunk_aligned: bool = False  # ingest batches must be config.chunk
    #   multiples for batching-invariant labels (Jacobi/DMA granularity); the
    #   BatchPipeline rounds its batch size up accordingly
    finalize_fn: Optional[Callable[[Any, Any], BackendResult]] = None
    #   derive labels/info (and the ClusterState view of the result) from
    #   state alone — required when fn returns labels=None
    megabatch_fn: Optional[Callable[..., BackendResult]] = None
    #   fused megabatch ingest: one dispatch over (K, batch_edges, 2) stacked
    #   fixed-shape batches (DESIGN.md §10 device pipelining).  Must be
    #   bit-identical to K sequential fn calls over the same batches;
    #   trailing all-PAD batches (a ragged tail megabatch) are no-ops.  The
    #   API layer uses it when ClusterConfig.megabatch_k is set.
    wavefront_fn: Optional[Callable[..., BackendResult]] = None
    #   wavefront megabatch ingest (DESIGN.md §12): consumes a host
    #   :class:`~repro.graph.wavefront.WavePlan` instead of raw stacked
    #   batches — node-disjoint waves applied vectorised with a runtime
    #   community-collision fallback.  Must stay bit-identical to
    #   megabatch_fn over the planned stream.  Signature
    #   ``wavefront_fn(plan, config, state) -> BackendResult``; the API layer
    #   uses it when ClusterConfig.wavefront is set (and megabatch_k drives
    #   staging as usual).
    decode_fn: Optional[Callable[..., BackendResult]] = None
    #   device-resident compressed ingest (DESIGN.md §14): consumes a
    #   :class:`~repro.graph.pipeline.CompressedMegaBatch` — DVE3 payload
    #   bytes plus a descriptor table — and decodes it *on device* before
    #   (or fused with) the state update.  Must be bit-identical to
    #   host-decoding the same rows and feeding them through
    #   ``megabatch_fn``, and must keep the one-dispatch-per-megabatch
    #   contract (decode and update under one jit / one kernel launch).
    #   Signature ``decode_fn(cmega, config, state) -> BackendResult``; the
    #   API layer uses it when ``ClusterConfig.device_decode`` is set and
    #   the source exposes codec blocks.
    fleet_fn: Optional[Callable[..., BackendResult]] = None
    #   multi-tenant fleet ingest (DESIGN.md §13): one donated dispatch over
    #   a ``(T, B, 2)`` staged slab threading a
    #   :class:`~repro.core.state.FleetState` — tenant ``t``'s row must be
    #   bit-identical to this backend's single-stream ``fn`` applied to
    #   tenant ``t``'s slab alone (all-PAD rows are no-ops).  Signature
    #   ``fleet_fn(edges, config, state) -> BackendResult``; used by
    #   :class:`repro.cluster.fleet.FleetClusterer` when
    #   ``ClusterConfig.tenants`` is set.
    description: str = ""


_REGISTRY: Dict[str, Backend] = {}


def register_backend(
    name: str,
    *,
    init_fn: Callable[[Any], Any] = _default_init,
    resumable: bool = False,
    bit_exact: bool = False,
    state_kind: str = "cluster",
    label_space: str = "dense",
    chunk_aligned: bool = False,
    finalize_fn: Optional[Callable[[Any, Any], BackendResult]] = None,
    megabatch_fn: Optional[Callable[..., BackendResult]] = None,
    wavefront_fn: Optional[Callable[..., BackendResult]] = None,
    decode_fn: Optional[Callable[..., BackendResult]] = None,
    fleet_fn: Optional[Callable[..., BackendResult]] = None,
    description: str = "",
):
    """Decorator: register ``fn`` as backend ``name``.  Re-registration under
    an existing name is an error (shadowing a tier silently would poison the
    cross-backend equivalence tests)."""
    if state_kind not in STATE_KINDS:
        raise ValueError(
            f"unknown state_kind {state_kind!r}; expected one of {STATE_KINDS}"
        )

    def deco(fn: Callable[..., BackendResult]):
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = Backend(
            name=name,
            fn=fn,
            init_fn=init_fn,
            resumable=resumable,
            bit_exact=bit_exact,
            state_kind=state_kind,
            label_space=label_space,
            chunk_aligned=chunk_aligned,
            finalize_fn=finalize_fn,
            megabatch_fn=megabatch_fn,
            wavefront_fn=wavefront_fn,
            decode_fn=decode_fn,
            fleet_fn=fleet_fn,
            description=description,
        )
        return fn

    return deco


def get_backend(name: str) -> Backend:
    _ensure_builtin_backends()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: {', '.join(sorted(_REGISTRY))}"
        ) from None


def available_backends() -> Tuple[str, ...]:
    _ensure_builtin_backends()
    return tuple(sorted(_REGISTRY))


def _ensure_builtin_backends() -> None:
    # Import for side effect: backends.py registers the seven built-in tiers.
    # Deferred (not at module import) to keep registry importable from the
    # backend module itself without a cycle.
    from repro.cluster import backends  # noqa: F401
