"""String-keyed backend registry (mirrors ``configs/registry.py``).

Every clustering implementation registers here under a stable name; the
unified :func:`repro.cluster.cluster` call and
:class:`repro.cluster.StreamClusterer` dispatch through this table, so later
subsystems (sharding, caching, serving) plug in new backends once instead of
adding an eighth top-level entry point.

Backend contract::

    fn(edges, config, state, mesh=None) -> BackendResult(state, labels, info)

* ``edges``: (m, 2) int array in stream order (PAD rows are no-ops).
* ``state``: a :class:`ClusterState` produced by this backend's ``init_fn``
  (fresh or carried from a previous batch when ``resumable``).
* ``labels``: raw per-node label array in the backend's label space;
  compare across backends via ``canonical_labels``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

from repro.core.state import ClusterState


class BackendResult(NamedTuple):
    state: Optional[ClusterState]  # None if the backend has no state pullback
    labels: Any  # (n,) raw label array
    info: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered clustering implementation + its capabilities."""

    name: str
    fn: Callable[..., BackendResult]
    init_fn: Callable[[int], ClusterState]
    resumable: bool  # supports partial_fit state threading
    bit_exact: bool  # strict stream order (identical to Algorithm 1)
    label_space: str = "dense"  # "dense": c[i] is a node id, v[cid] its volume
    #                             "oracle": 1-based paper ids, v[cid-1]
    chunk_aligned: bool = False  # ingest batches must be config.chunk
    #   multiples for batching-invariant labels (Jacobi/DMA granularity); the
    #   BatchPipeline rounds its batch size up accordingly
    accepts_source: bool = False  # fn handles an EdgeSource itself (no
    #   materialization needed even though not resumable)
    description: str = ""


_REGISTRY: Dict[str, Backend] = {}


def register_backend(
    name: str,
    *,
    init_fn: Callable[[int], ClusterState] = ClusterState.init,
    resumable: bool = False,
    bit_exact: bool = False,
    label_space: str = "dense",
    chunk_aligned: bool = False,
    accepts_source: bool = False,
    description: str = "",
):
    """Decorator: register ``fn`` as backend ``name``.  Re-registration under
    an existing name is an error (shadowing a tier silently would poison the
    cross-backend equivalence tests)."""

    def deco(fn: Callable[..., BackendResult]):
        if name in _REGISTRY:
            raise ValueError(f"backend {name!r} already registered")
        _REGISTRY[name] = Backend(
            name=name,
            fn=fn,
            init_fn=init_fn,
            resumable=resumable,
            bit_exact=bit_exact,
            label_space=label_space,
            chunk_aligned=chunk_aligned,
            accepts_source=accepts_source,
            description=description,
        )
        return fn

    return deco


def get_backend(name: str) -> Backend:
    _ensure_builtin_backends()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: {', '.join(sorted(_REGISTRY))}"
        ) from None


def available_backends() -> Tuple[str, ...]:
    _ensure_builtin_backends()
    return tuple(sorted(_REGISTRY))


def _ensure_builtin_backends() -> None:
    # Import for side effect: backends.py registers the seven built-in tiers.
    # Deferred (not at module import) to keep registry importable from the
    # backend module itself without a cycle.
    from repro.cluster import backends  # noqa: F401
