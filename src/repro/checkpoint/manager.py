"""Step-atomic sharded checkpointing with elastic, corruption-tolerant restore.

Layout: ``<dir>/step_<n>/`` holding one ``.npy`` per pytree leaf (keyed by
its tree path) plus ``manifest.json`` recording each leaf's shape, dtype and
CRC32.  Writes go to ``tmp_step_<n>`` and are renamed into place; when a
previous ``step_<n>`` exists it is first renamed aside to ``step_<n>.old``
and only removed *after* the new directory has landed — at every instant of
the swap some complete generation of that step exists on disk, and
``__init__`` heals any ``.old`` orphan a crash may have left behind.

Restore verifies the manifest checksums and, when the newest generation is
torn (truncated manifest, missing or bit-flipped leaf), falls back to the
previous generation rather than returning silent garbage — corrupt artifacts
raise :class:`CheckpointCorruptError`, never a bare ``ValueError``.

Elastic restore: leaves are saved as *logical* (global) arrays and re-placed
with whatever shardings the restoring mesh provides — so a run checkpointed
on a (16,16) mesh restores onto (8,16) or (2,16,16) unchanged.  (At real
multi-host scale each host would write only its addressable shards and the
manifest would carry the index map; the single-host container collapses that
to full arrays — interface and atomicity are identical.)
"""

from __future__ import annotations

import io
import json
import os
import re
import shutil
import zlib
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

_LEAF_RE = re.compile(r"[^a-zA-Z0-9_.-]+")
_STEP_RE = re.compile(r"step_(\d+)")

# numpy can't round-trip ml_dtypes (bfloat16/fp8 save as void) — store a
# uint8 byte view and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16", "float8_e4m3fn", "float8_e5m2", "float8_e4m3"}


class CheckpointError(RuntimeError):
    """Base class for checkpoint storage failures."""


class CheckpointCorruptError(CheckpointError):
    """A checkpoint generation on disk is torn or damaged (unreadable
    manifest, missing leaf file, checksum mismatch)."""


def _leaf_name(path) -> str:
    return _LEAF_RE.sub("_", jax.tree_util.keystr(path)).strip("_")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._recover()

    # ------------------------------------------------------------------
    def _recover(self) -> None:
        """Heal the swap window: a crash between "rename old aside" and
        "rename tmp in" leaves ``step_<n>.old`` as the only copy — put it
        back; if both exist the new generation won, drop the aside."""
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)\.old", name)
            if not m:
                continue
            aside = os.path.join(self.directory, name)
            final = os.path.join(self.directory, f"step_{m.group(1)}")
            if os.path.exists(final):
                shutil.rmtree(aside)
            else:
                os.rename(aside, final)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any) -> str:
        tmp = os.path.join(self.directory, f"tmp_step_{step}")
        final = os.path.join(self.directory, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = jax.tree_util.tree_flatten_with_path(state)[0]
        manifest = {"step": step, "leaves": []}
        names = set()
        for path, leaf in leaves:
            name = _leaf_name(path)
            assert name not in names, f"duplicate leaf name {name}"
            names.add(name)
            arr = np.asarray(jax.device_get(leaf))
            dtype_name = str(leaf.dtype) if hasattr(leaf, "dtype") else str(arr.dtype)
            to_save = (
                np.ascontiguousarray(arr).view(np.uint8)
                if dtype_name in _EXOTIC
                else arr
            )
            leaf_path = os.path.join(tmp, name + ".npy")
            np.save(leaf_path, to_save)
            with open(leaf_path, "rb") as f:
                crc = zlib.crc32(f.read())
            manifest["leaves"].append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "dtype": dtype_name,
                    "crc32": crc,
                }
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        # Swap with an aside rename instead of rmtree-then-rename: a crash
        # at any point leaves either step_<n> or step_<n>.old complete on
        # disk (healed by _recover), never zero copies.
        aside = None
        if os.path.exists(final):
            aside = final + ".old"
            if os.path.exists(aside):
                shutil.rmtree(aside)
            os.rename(final, aside)
        os.rename(tmp, final)
        if aside is not None:
            shutil.rmtree(aside)
        self._prune()
        return final

    # ------------------------------------------------------------------
    def restore(
        self,
        template: Any,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> Any:
        """Rebuild ``template``-structured state from disk.

        With ``step=None`` the newest generation is tried first and torn
        generations are skipped (falling back through ``all_steps()``);
        an explicit ``step`` is restored exactly or raises
        :class:`CheckpointCorruptError`.

        ``shardings``: optional pytree (same structure) of NamedSharding for
        elastic re-placement on a (possibly different) mesh.
        """
        if step is not None:
            return self._restore_step(template, step, shardings)
        steps = self.all_steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        errors: List[str] = []
        for s in reversed(steps):
            try:
                return self._restore_step(template, s, shardings)
            except CheckpointCorruptError as e:
                errors.append(str(e))
        raise CheckpointCorruptError(
            f"every checkpoint generation in {self.directory} is corrupt: "
            + "; ".join(errors)
        )

    def _restore_step(
        self, template: Any, step: int, shardings: Optional[Any]
    ) -> Any:
        d = os.path.join(self.directory, f"step_{step}")
        if not os.path.isdir(d):
            raise FileNotFoundError(f"no checkpoint step_{step} in {self.directory}")
        manifest = self._manifest(step)
        crcs: Dict[str, int] = {
            leaf["name"]: leaf["crc32"]
            for leaf in manifest.get("leaves", [])
            if "crc32" in leaf
        }
        paths_and_leaves = jax.tree_util.tree_flatten_with_path(template)
        leaves, treedef = paths_and_leaves
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else None
        )
        out = []
        for i, (path, leaf) in enumerate(leaves):
            name = _leaf_name(path)
            leaf_path = os.path.join(d, name + ".npy")
            try:
                with open(leaf_path, "rb") as f:
                    raw = f.read()
            except FileNotFoundError:
                raise CheckpointCorruptError(
                    f"{d}: leaf file {name}.npy is missing"
                ) from None
            if name in crcs and zlib.crc32(raw) != crcs[name]:
                raise CheckpointCorruptError(
                    f"{d}: leaf {name}.npy fails its manifest checksum — "
                    "the file was altered or torn after save"
                )
            try:
                arr = np.load(io.BytesIO(raw))
            except Exception as e:
                raise CheckpointCorruptError(
                    f"{d}: leaf {name}.npy is unreadable: {e}"
                ) from e
            want = str(leaf.dtype) if hasattr(leaf, "dtype") else None
            if want in _EXOTIC:
                arr = arr.view(getattr(ml_dtypes, want)).reshape(leaf.shape)
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            elif isinstance(leaf, jax.Array):
                out.append(jax.numpy.asarray(arr))
            else:
                # Host-side template leaf (numpy tiers, plain counters): keep
                # the dtype saved on disk — jnp.asarray would silently demote
                # int64 counters (edges_seen, stream_offset) to int32.
                out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------
    def _manifest(self, step: int) -> Dict:
        path = os.path.join(self.directory, f"step_{step}", "manifest.json")
        try:
            with open(path) as f:
                return json.load(f)
        except FileNotFoundError:
            raise CheckpointCorruptError(f"{path} is missing") from None
        except json.JSONDecodeError as e:
            raise CheckpointCorruptError(
                f"{path} is truncated or not valid JSON: {e}"
            ) from e

    def leaf_names(self, step: Optional[int] = None) -> set:
        """Leaf names recorded in a checkpoint's manifest (newest *valid*
        generation by default; empty set when no checkpoint exists).

        Lets callers dispatch on checkpoint *layout* before building a
        restore template — e.g. the cluster API restores the new
        variable-length ``stream_cursor`` leaf when present and falls back
        to the legacy scalar ``stream_offset`` otherwise, instead of
        exception-probing with trial templates.
        """
        if step is not None:
            return {
                leaf["name"] for leaf in self._manifest(step).get("leaves", [])
            }
        for s in reversed(self.all_steps()):
            try:
                return {
                    leaf["name"] for leaf in self._manifest(s).get("leaves", [])
                }
            except CheckpointCorruptError:
                continue
        return set()

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self):
        return sorted(
            int(_STEP_RE.fullmatch(n).group(1))
            for n in os.listdir(self.directory)
            if _STEP_RE.fullmatch(n)
        )

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"))
