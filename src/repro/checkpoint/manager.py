"""Step-atomic sharded checkpointing with elastic restore.

Layout: ``<dir>/step_<n>/`` holding one ``.npy`` per pytree leaf (keyed by
its tree path) plus ``manifest.json``.  Writes go to ``tmp_step_<n>`` and are
renamed into place — a preempted save never corrupts the latest checkpoint.

Elastic restore: leaves are saved as *logical* (global) arrays and re-placed
with whatever shardings the restoring mesh provides — so a run checkpointed
on a (16,16) mesh restores onto (8,16) or (2,16,16) unchanged.  (At real
multi-host scale each host would write only its addressable shards and the
manifest would carry the index map; the single-host container collapses that
to full arrays — interface and atomicity are identical.)
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

_LEAF_RE = re.compile(r"[^a-zA-Z0-9_.-]+")

# numpy can't round-trip ml_dtypes (bfloat16/fp8 save as void) — store a
# uint8 byte view and record the logical dtype in the manifest.
_EXOTIC = {"bfloat16", "float8_e4m3fn", "float8_e5m2", "float8_e4m3"}


def _leaf_name(path) -> str:
    return _LEAF_RE.sub("_", jax.tree_util.keystr(path)).strip("_")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any) -> str:
        tmp = os.path.join(self.directory, f"tmp_step_{step}")
        final = os.path.join(self.directory, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves = jax.tree_util.tree_flatten_with_path(state)[0]
        manifest = {"step": step, "leaves": []}
        names = set()
        for path, leaf in leaves:
            name = _leaf_name(path)
            assert name not in names, f"duplicate leaf name {name}"
            names.add(name)
            arr = np.asarray(jax.device_get(leaf))
            dtype_name = str(leaf.dtype) if hasattr(leaf, "dtype") else str(arr.dtype)
            to_save = (
                np.ascontiguousarray(arr).view(np.uint8)
                if dtype_name in _EXOTIC
                else arr
            )
            np.save(os.path.join(tmp, name + ".npy"), to_save)
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape), "dtype": dtype_name}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()
        return final

    # ------------------------------------------------------------------
    def restore(
        self,
        template: Any,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> Any:
        """Rebuild ``template``-structured state from disk.

        ``shardings``: optional pytree (same structure) of NamedSharding for
        elastic re-placement on a (possibly different) mesh.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step}")
        paths_and_leaves = jax.tree_util.tree_flatten_with_path(template)
        leaves, treedef = paths_and_leaves
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else None
        )
        out = []
        for i, (path, leaf) in enumerate(leaves):
            arr = np.load(os.path.join(d, _leaf_name(path) + ".npy"))
            want = str(leaf.dtype) if hasattr(leaf, "dtype") else None
            if want in _EXOTIC:
                arr = arr.view(getattr(ml_dtypes, want)).reshape(leaf.shape)
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            elif isinstance(leaf, jax.Array):
                out.append(jax.numpy.asarray(arr))
            else:
                # Host-side template leaf (numpy tiers, plain counters): keep
                # the dtype saved on disk — jnp.asarray would silently demote
                # int64 counters (edges_seen, stream_offset) to int32.
                out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------------------------
    def leaf_names(self, step: Optional[int] = None) -> set:
        """Leaf names recorded in a checkpoint's manifest (latest by
        default; empty set when no checkpoint exists).

        Lets callers dispatch on checkpoint *layout* before building a
        restore template — e.g. the cluster API restores the new
        variable-length ``stream_cursor`` leaf when present and falls back
        to the legacy scalar ``stream_offset`` otherwise, instead of
        exception-probing with trial templates.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            return set()
        path = os.path.join(self.directory, f"step_{step}", "manifest.json")
        try:
            with open(path) as f:
                manifest = json.load(f)
        except FileNotFoundError:
            return set()
        return {leaf["name"] for leaf in manifest.get("leaves", [])}

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                steps.append(int(m.group(1)))
        return max(steps) if steps else None

    def all_steps(self):
        return sorted(
            int(re.fullmatch(r"step_(\d+)", n).group(1))
            for n in os.listdir(self.directory)
            if re.fullmatch(r"step_(\d+)", n)
        )

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"))
