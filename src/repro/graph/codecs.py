"""Edge-stream codecs: how bytes on a transport become ``(k, 2)`` edge rows.

The ingestion engine is split in two (DESIGN.md §10):

* the **transport** layer (:mod:`repro.graph.sources`) knows *where* bytes
  live — a file, an mmap, a generator — and owns iteration/resume plumbing;
* the **codec** layer (this module) knows *what the bytes mean* — how to
  turn them into edge rows and back, and how to name a mid-file position.

The paper's billion-edge regime is bandwidth-bound: the algorithm holds only
``3n`` ints, so wall-clock is dominated by moving edge bytes.  A codec
trades decode compute (cheap, vectorized, and overlapped with device work on
the pipeline's prefetch thread) for stream bandwidth.

Two codecs:

* :class:`RawCodec` — fixed-width little-endian int32 pairs (8 bytes/edge),
  extracted from the old ``BinaryFileSource``; decoding is a zero-copy
  memmap view.
* :class:`DeltaVarintCodec` — block-compressed: within each block the
  source column is delta-encoded (consecutive ``i`` values), the target
  column is stored as the residual ``j - i``, and both columns are zigzag
  varint packed.  Sorted-by-source streams with community locality (the
  common on-disk layout — SNAP dumps, CSR-ish edge lists) compress to
  ~2-3 bytes/edge.  Blocks are self-contained sync points: each starts a
  fresh delta chain behind a ``(payload_nbytes, n_rows)`` header, so any
  block boundary is a seekable resume position and skipping unread blocks
  costs two header reads, not a decode.

**Cursors.**  A stream position is a :class:`Cursor` — the universal raw
``row`` index plus an opaque codec/source-defined integer ``token`` (for
block codecs: the byte offset and first-row index of a containing sync
block; for merged streams: per-source row offsets).  Cursors serialize to a
flat int64 vector so checkpoints carry them as ordinary pytree leaves;
``token`` is a *hint*: resume from a bare row is always correct, a token
merely makes it O(1) instead of O(row) header-skips.
"""

from __future__ import annotations

import io
import os
import struct
import zlib
from typing import (
    BinaryIO,
    Callable,
    Iterable,
    Iterator,
    NamedTuple,
    Optional,
    Tuple,
    Union,
)

import numpy as np

from repro.graph.errors import (
    CorruptBlockError,
    CorruptStreamError,
    TruncatedStreamError,
)

PathLike = Union[str, os.PathLike]

# ---------------------------------------------------------------------------
# Cursors: opaque stream positions
# ---------------------------------------------------------------------------


class Cursor(NamedTuple):
    """A resumable stream position.

    ``row`` — raw rows of the stream consumed before this position (the
    universal coordinate every source understands).  ``token`` — an opaque
    tuple of ints owned by whichever source/codec minted the cursor (block
    byte offsets, per-source merge positions, ...).  A foreign or stale
    token may be dropped; ``row`` alone must always resume correctly.

    So that a foreign token is *recognized* and dropped rather than
    misread, single-source tokens lead with a negative type tag (see
    :data:`TEXT_TOKEN_TAG` / :data:`DVC_TOKEN_TAG`) — merge tokens are
    per-source row offsets, which are all non-negative, so the namespaces
    cannot collide.
    """

    row: int
    token: Tuple[int, ...] = ()

    def to_array(self) -> np.ndarray:
        """Flat int64 vector ``[row, *token]`` — a checkpointable leaf."""
        return np.asarray([self.row, *self.token], np.int64)

    @classmethod
    def from_array(cls, arr) -> "Cursor":
        arr = np.asarray(arr, np.int64).reshape(-1)
        if arr.size == 0:
            return cls(0)
        return cls(int(arr[0]), tuple(int(x) for x in arr[1:]))


# Leading type tags for cursor tokens (negative on purpose: a merged-stream
# token is a vector of non-negative per-source row offsets, so a negative
# first element unambiguously marks a single-source token and its format).
# The second element is always the file size at mint time — a cheap
# fingerprint that invalidates the token when the file is replaced or
# regenerated between checkpoint and restore (staleness the byte offsets
# themselves cannot reveal).
TEXT_TOKEN_TAG = -2  # (tag, file_size, sync_row, byte_pos, lineno)
DVC_TOKEN_TAG = -3  # (tag, file_size, block_byte, block_first_row)


def as_cursor(pos: Union[int, Cursor]) -> Cursor:
    """Coerce a raw row offset (the historical ``start`` int) to a Cursor."""
    if isinstance(pos, Cursor):
        return pos
    return Cursor(int(pos))


# ---------------------------------------------------------------------------
# Vectorized zigzag + varint primitives
# ---------------------------------------------------------------------------

_U = np.uint64
_MAX_VARINT_BYTES = 10  # ceil(64 / 7)


def zigzag_encode(x: np.ndarray) -> np.ndarray:
    """int64 -> uint64 zigzag (small magnitudes -> small codes)."""
    x = np.asarray(x, np.int64)
    return (x.astype(_U) << _U(1)) ^ (x >> np.int64(63)).astype(_U)


def zigzag_decode(z: np.ndarray) -> np.ndarray:
    z = np.asarray(z, _U)
    return (z >> _U(1)).astype(np.int64) ^ np.negative(
        (z & _U(1)).astype(np.int64)
    )


def varint_nbytes(values: np.ndarray) -> int:
    """Total LEB128-encoded size of a uint64 vector *without* encoding it.

    The encoder's column-mode chooser only needs the varint size to compare
    against fixed-width candidates; materializing the actual byte stream
    (cumsum + up to 10 scatter passes) just to measure it was the encode
    hot spot.  This is ≤ 10 vectorized compare-sums and no allocation
    proportional to the output.
    """
    v = np.asarray(values, _U)
    if v.size == 0:
        return 0
    total = v.size
    for k in range(1, _MAX_VARINT_BYTES):
        above = int(np.count_nonzero(v >= _U(1) << _U(7 * k)))
        if not above:
            break
        total += above
    return total


def encode_varints(values: np.ndarray) -> np.ndarray:
    """LEB128-encode a uint64 vector into one uint8 stream (vectorized).

    One scatter per byte position — at most 10 numpy passes regardless of
    how many values are encoded.
    """
    v = np.asarray(values, _U)
    if v.size == 0:
        return np.zeros(0, np.uint8)
    nbytes = np.ones(v.shape, np.int64)
    for k in range(1, _MAX_VARINT_BYTES):
        above = v >= _U(1) << _U(7 * k)
        if not above.any():
            break
        nbytes += above
    ends = np.cumsum(nbytes)
    starts = ends - nbytes
    out = np.zeros(int(ends[-1]), np.uint8)
    for k in range(int(nbytes.max())):
        m = nbytes > k
        byte = (v[m] >> _U(7 * k)) & _U(0x7F)
        byte |= np.where(nbytes[m] > k + 1, _U(0x80), _U(0))
        out[starts[m] + k] = byte.astype(np.uint8)
    return out


def decode_varints(buf: np.ndarray, count: int) -> Tuple[np.ndarray, int]:
    """Decode exactly ``count`` LEB128 varints from a uint8 buffer.

    Returns ``(values, bytes_consumed)``.  Vectorized: terminator bytes are
    found in one pass, then one gather per byte position (≤ 10 passes).
    """
    b = np.asarray(buf, np.uint8)
    if count == 0:
        return np.zeros(0, _U), 0
    ends = np.flatnonzero((b & 0x80) == 0)
    if ends.size < count:
        raise CorruptStreamError(
            f"varint stream truncated: {ends.size} complete values in "
            f"{b.size} bytes, expected {count}"
        )
    ends = ends[:count]
    starts = np.concatenate([[0], ends[:-1] + 1])
    lens = ends - starts + 1
    if int(lens.max()) > _MAX_VARINT_BYTES:
        raise CorruptStreamError("varint longer than 10 bytes (corrupt stream)")
    vals = np.zeros(count, _U)
    for k in range(int(lens.max())):
        m = lens > k
        vals[m] |= (b[starts[m] + k].astype(_U) & _U(0x7F)) << _U(7 * k)
    return vals, int(ends[-1]) + 1


# ---------------------------------------------------------------------------
# The codec protocol
# ---------------------------------------------------------------------------


class EdgeCodec:
    """How an on-disk byte stream maps to edge rows (and back).

    ``decode_from(path, cursor)`` yields ``(rows, next_sync)`` pairs: the
    rows strictly from ``cursor.row`` on, plus the :class:`Cursor` of the
    first row *after* them — always a self-contained sync point whose token
    a caller may record and later hand back for O(1) resume.  ``encode``
    streams arbitrary ``(k, 2)`` slices to a binary file object.
    """

    name: str = "abstract"
    suffixes: Tuple[str, ...] = ()
    magic: bytes = b""  # the magic this codec *writes*
    magics: Tuple[bytes, ...] = ()  # every magic it *reads* (defaults to
    #   (magic,); versioned codecs list older formats they stay able to read)

    def encode(self, slices: Iterable[np.ndarray], f: BinaryIO) -> int:
        """Write the stream; returns rows written."""
        raise NotImplementedError

    def n_edges(self, path: PathLike) -> Optional[int]:
        """Total rows in the file; also the open-time validation hook —
        raises ``ValueError`` on a structurally torn file."""
        raise NotImplementedError

    def decode_from(
        self, path: PathLike, cursor: Cursor
    ) -> Iterator[Tuple[np.ndarray, Cursor]]:
        raise NotImplementedError


class RawCodec(EdgeCodec):
    """Fixed-width little-endian int32 ``(i, j)`` pairs — 8 bytes/edge.

    The identity codec: decoding is a zero-copy memmap view, every row is
    its own sync point (byte offset = ``8 * row``), and tokens are empty.
    """

    name = "raw"
    suffixes = (".bin",)
    RECORD_BYTES = 8

    def __init__(self, rows_per_slice: int = 1 << 20):
        if rows_per_slice < 1:
            raise ValueError(f"rows_per_slice must be >= 1, got {rows_per_slice}")
        self.rows_per_slice = rows_per_slice

    def encode(self, slices: Iterable[np.ndarray], f: BinaryIO) -> int:
        rows = 0
        for sl in slices:
            arr = np.ascontiguousarray(sl, dtype="<i4")
            f.write(arr.tobytes())
            rows += int(arr.shape[0])
        return rows

    def n_edges(self, path: PathLike) -> int:
        nbytes = os.path.getsize(path)
        if nbytes % self.RECORD_BYTES:
            raise TruncatedStreamError(
                f"{os.fspath(path)}: size {nbytes} is not a whole number of "
                f"int32 edge pairs ({self.RECORD_BYTES}-byte records) — "
                "truncated or not a raw edge file"
            )
        return nbytes // self.RECORD_BYTES

    def decode_from(
        self, path: PathLike, cursor: Cursor
    ) -> Iterator[Tuple[np.ndarray, Cursor]]:
        m = self.n_edges(path)
        if cursor.row >= m:
            return
        mm = np.memmap(path, dtype="<i4", mode="r").reshape(-1, 2)
        for pos in range(cursor.row, m, self.rows_per_slice):
            nxt = min(pos + self.rows_per_slice, m)
            yield mm[pos:nxt], Cursor(nxt)


class FixedBlockMeta(NamedTuple):
    """Where a device-decodable DVE3 block's column lanes live.

    Offsets are relative to the block *payload* start; widths are bytes
    per zigzag value.  ``base_i`` seeds the source-column delta chain.
    Only minted when both columns are fixed-width ≤ 4 bytes (exact under
    int32 device arithmetic); every other block host-decodes.
    """

    off_i: int
    w_i: int
    off_j: int
    w_j: int
    base_i: int


class CodecBlock(NamedTuple):
    """One self-contained sync block, as seen by the compressed staging
    path: absolute row coordinates, the raw payload bytes, and — iff the
    block can be decoded on device — its :class:`FixedBlockMeta`.
    ``next_cursor`` names the sync point after the block (same token the
    decode path would mint), so staging records resume positions exactly
    like host decoding does."""

    first_row: int
    n_rows: int
    payload: bytes
    version: int
    fixed: Optional[FixedBlockMeta]
    next_cursor: Cursor


class DeltaVarintCodec(EdgeCodec):
    """Delta + zigzag-varint block compression with seekable sync points.

    File layout (all little-endian)::

        header : b"DVE2" | u32 block_edges | u64 n_edges
        block  : u32 payload_nbytes | u32 n_rows | payload
        ...

    Each block is self-contained: the payload holds the source-column
    deltas (first delta taken from 0, so no cross-block state) followed by
    the residuals ``j - i``, both zigzagged.  Sorted-by-source streams make
    the deltas mostly 0/1 and community locality keeps ``|j - i|`` small —
    the regimes the paper's stream spends its bandwidth on.

    In the current format (magic ``DVE2``) each of the two columns is
    independently mode-tagged::

        column : u8 mode | data
        mode 0       : n_rows LEB128 varints (the DVE1 encoding)
        mode 1/2/4   : n_rows fixed-width little-endian unsigned zigzag
                       values of that byte width

    The fixed-width modes are the decode fast path: when every zigzagged
    value of a column fits the width *and* the fixed column is no larger
    than its varint encoding, decode is a single vectorised ``frombuffer``
    + cumsum instead of the per-byte varint scatter loop.  Ties go to
    fixed-width (same bytes, faster decode).  ``DVE1`` files (two bare
    varint columns, no mode bytes) remain fully readable; pass
    ``version=1`` to *write* the old format.

    ``DVE3`` (``version=3``) is the *device-decodable* block mode
    (DESIGN.md §14).  Same file/block framing, but the payload leads with
    the block's first source value so the delta chain is base-relative::

        payload : i64 first_i | u8 mode_i | data_i | u8 mode_j | data_j
        mode 0       : varints (host-only fallback)
        mode 1/2/4/8 : fixed-width little-endian unsigned zigzag values

    Base-relative deltas remove the one huge leading delta that forced
    whole DVE2 columns into varints or u4 on sorted streams — a DVE3
    source column is u1 whenever consecutive gaps fit a byte.  Width 4 is
    only chosen when every zigzag value stays below ``2**31`` so int32
    zigzag arithmetic is exact on device; wider values take u8 or varint
    and the block is host-decoded.  A block is **device-decodable** iff
    both columns are fixed-width ≤ 4 — :meth:`scan_blocks` surfaces the
    raw column bytes plus offsets/widths/base for the compressed-slab
    staging path, everything else falls back to host ``_decode_block``.

    ``n_edges`` in the header is patched in at encode close; the sentinel
    ``2**64 - 1`` (unseekable output) degrades to a header-skipping count.

    **Checksummed framing** (magics ``DVX2``/``DVX3``, the minor-version
    default since ``checksum=True``).  Block payloads and the v2/v3 column
    encodings are byte-identical; only the per-block header grows::

        block : b"\\xb5\\x1e\\xcb\\x5d" sync | u32 payload_nbytes
                | u32 n_rows | i64 first_row | u32 crc32 | payload

    ``crc32`` covers the header fields and the payload, so a bit-flipped
    or torn block is *detected* rather than decoded into silently-wrong
    edges; ``first_row`` (the block's absolute row in the original
    stream) makes loss under quarantine exactly countable; and the sync
    marker lets the decoder resync to the next healthy block even when a
    header itself is damaged.  On a checksum mismatch the decoder raises
    :class:`~repro.graph.errors.CorruptBlockError` — or, when the caller
    supplies ``on_lost``, *quarantines*: it skips to the next block whose
    header and checksum validate, reports the exact absolute rows lost
    via ``on_lost(byte_pos, rows_lost)`` (stable ``byte_pos`` keys make
    re-walks idempotent), and streams on.  Under quarantine, yielded row
    coordinates count only delivered rows, so cursors and resume remain
    bit-identical across passes — corruption is a deterministic property
    of the bytes on disk.  Plain ``DVE1/2/3`` files remain fully
    readable; pass ``checksum=False`` to write them.
    """

    name = "dvc"
    suffixes = (".dvc",)
    magic = b"DVE2"
    magics = (b"DVX3", b"DVX2", b"DVE3", b"DVE2", b"DVE1")
    _HEADER = struct.Struct("<4sIQ")
    _BLOCK = struct.Struct("<II")
    # checksummed block header: sync marker, payload_nbytes, n_rows,
    # absolute first_row, crc32(header fields + payload)
    _CSYNC = b"\xb5\x1e\xcb\x5d"
    _CBLOCK = struct.Struct("<4sIIqI")
    _CCRC = struct.Struct("<IIq")
    _V3_BASE = struct.Struct("<q")
    _UNKNOWN = (1 << 64) - 1
    _FIXED_WIDTHS = (1, 2, 4)
    _FIXED_WIDTHS_V3 = (1, 2, 4, 8)
    # widths int32 zigzag math handles exactly on device (u4 capped below)
    _DEVICE_WIDTHS = (1, 2, 4)
    _U4_DEVICE_TOP = 1 << 31  # u4 chosen only when every zz value is below

    def __init__(
        self,
        block_edges: int = 1 << 16,
        version: int = 2,
        checksum: Optional[bool] = None,
    ):
        if block_edges < 1:
            raise ValueError(f"block_edges must be >= 1, got {block_edges}")
        if version not in (1, 2, 3):
            raise ValueError(f"dvc version must be 1, 2 or 3, got {version}")
        if checksum is None:
            checksum = version != 1  # v1 framing predates the sync header
        if checksum and version == 1:
            raise ValueError(
                "checksummed framing requires dvc version >= 2; "
                "pass checksum=False to write legacy DVE1"
            )
        self.block_edges = block_edges
        self.version = version
        self.checksum = checksum

    # -- encode --------------------------------------------------------
    def _encode_column_v2(self, zz: np.ndarray) -> bytes:
        """One mode-tagged column: the smallest fixed width that both fits
        every value and does not exceed the varint size, else varints."""
        vsize = varint_nbytes(zz)
        n = int(zz.shape[0])
        top = int(zz.max()) if n else 0
        for w in self._FIXED_WIDTHS:
            if top < 1 << (8 * w) and w * n <= vsize:
                return bytes([w]) + zz.astype(f"<u{w}").tobytes()
        return bytes([0]) + encode_varints(zz).tobytes()

    def _encode_column_v3(self, zz: np.ndarray) -> bytes:
        """DVE3 column: widths 1/2/4/8, with u4 additionally capped at
        ``2**31`` so device int32 zigzag decode is exact; varints only when
        every fixed width loses on size (the host-decoded fallback)."""
        vsize = varint_nbytes(zz)
        n = int(zz.shape[0])
        top = int(zz.max()) if n else 0
        for w in self._FIXED_WIDTHS_V3:
            cap = self._U4_DEVICE_TOP if w == 4 else 1 << (8 * w)
            if top < cap and w * n <= vsize:
                return bytes([w]) + zz.astype(f"<u{w}").tobytes()
        return bytes([0]) + encode_varints(zz).tobytes()

    def _encode_block(self, rows: np.ndarray) -> bytes:
        rows = np.asarray(rows, np.int64)
        i, j = rows[:, 0], rows[:, 1]
        if self.version == 3:
            base = int(i[0]) if i.shape[0] else 0
            deltas = np.diff(i, prepend=np.int64(base))
        else:
            deltas = np.diff(i, prepend=np.int64(0))
        zz_i, zz_j = zigzag_encode(deltas), zigzag_encode(j - i)
        if self.version == 1:
            payload = encode_varints(np.concatenate([zz_i, zz_j])).tobytes()
        elif self.version == 2:
            payload = self._encode_column_v2(zz_i) + self._encode_column_v2(
                zz_j
            )
        else:
            payload = (
                self._V3_BASE.pack(int(i[0]) if i.shape[0] else 0)
                + self._encode_column_v3(zz_i)
                + self._encode_column_v3(zz_j)
            )
        return (
            self._BLOCK.pack(len(payload), int(rows.shape[0])) + payload
        )

    def _write_magic(self) -> bytes:
        return {
            (1, False): b"DVE1",
            (2, False): b"DVE2",
            (3, False): b"DVE3",
            (2, True): b"DVX2",
            (3, True): b"DVX3",
        }[(self.version, self.checksum)]

    def _encode_cblock(self, rows: np.ndarray, first_row: int) -> bytes:
        """Checksummed framing around the same block payload bytes."""
        blk = self._encode_block(rows)
        payload = blk[self._BLOCK.size :]
        n_rows = int(np.asarray(rows).shape[0])
        crc = zlib.crc32(
            payload,
            zlib.crc32(self._CCRC.pack(len(payload), n_rows, first_row)),
        )
        return (
            self._CBLOCK.pack(
                self._CSYNC, len(payload), n_rows, first_row, crc
            )
            + payload
        )

    def encode(self, slices: Iterable[np.ndarray], f: BinaryIO) -> int:
        from repro.graph.pipeline import rechunk

        magic = self._write_magic()
        header_pos = f.tell()
        f.write(self._HEADER.pack(magic, self.block_edges, self._UNKNOWN))
        rows = 0
        for block in rechunk(slices, self.block_edges):
            if self.checksum:
                f.write(self._encode_cblock(block, rows))
            else:
                f.write(self._encode_block(block))
            rows += int(block.shape[0])
        if f.seekable():
            end = f.tell()
            f.seek(header_pos)
            f.write(self._HEADER.pack(magic, self.block_edges, rows))
            f.seek(end)
        return rows

    # -- decode --------------------------------------------------------
    def _read_header(
        self, f: BinaryIO
    ) -> Tuple[int, Optional[int], int, bool]:
        """Returns ``(block_edges, n_edges, version, checksummed)`` — the
        version/framing of the *file*, which drives block decoding
        regardless of this instance's write settings."""
        head = f.read(self._HEADER.size)
        if len(head) < self._HEADER.size:
            raise TruncatedStreamError("dvc file shorter than its header")
        magic, block_edges, n_edges = self._HEADER.unpack(head)
        if magic not in self.magics:
            raise CorruptStreamError(
                f"bad magic {magic!r}; not a {self.name} edge file"
            )
        version = {
            b"DVE1": (1, False),
            b"DVE2": (2, False),
            b"DVE3": (3, False),
            b"DVX2": (2, True),
            b"DVX3": (3, True),
        }[magic]
        return (
            block_edges,
            None if n_edges == self._UNKNOWN else n_edges,
            version[0],
            version[1],
        )

    def file_checksummed(self, path: PathLike) -> bool:
        """Whether the *file* carries per-block checksums (quarantine and
        exact loss accounting need the ``DVX`` framing)."""
        with open(path, "rb") as f:
            return self._read_header(f)[3]

    def _next_block_header(self, f: BinaryIO) -> Optional[Tuple[int, int]]:
        head = f.read(self._BLOCK.size)
        if not head:
            return None
        if len(head) < self._BLOCK.size:
            raise TruncatedStreamError("dvc file truncated inside a block header")
        return self._BLOCK.unpack(head)

    def _decode_column_v2(
        self, buf: np.ndarray, off: int, n_rows: int
    ) -> Tuple[np.ndarray, int]:
        """Decode one mode-tagged column from ``buf[off:]``; returns the
        zigzagged uint64 values and the offset past the column."""
        if off >= buf.size:
            raise CorruptStreamError("dvc block truncated before a column mode byte")
        mode = int(buf[off])
        off += 1
        if mode == 0:
            vals, consumed = decode_varints(buf[off:], n_rows)
            return vals, off + consumed
        if mode not in self._FIXED_WIDTHS:
            raise CorruptStreamError(f"dvc block has unknown column mode {mode}")
        end = off + mode * n_rows
        if end > buf.size:
            raise CorruptStreamError("dvc block truncated inside a fixed-width column")
        vals = np.frombuffer(buf, dtype=f"<u{mode}", count=n_rows, offset=off)
        return vals.astype(_U), end

    def _decode_column_v3(
        self, buf: np.ndarray, off: int, n_rows: int
    ) -> Tuple[np.ndarray, int]:
        """Like v2 but accepts the u8 width."""
        if off >= buf.size:
            raise CorruptStreamError("dvc block truncated before a column mode byte")
        mode = int(buf[off])
        off += 1
        if mode == 0:
            vals, consumed = decode_varints(buf[off:], n_rows)
            return vals, off + consumed
        if mode not in self._FIXED_WIDTHS_V3:
            raise CorruptStreamError(f"dvc block has unknown column mode {mode}")
        end = off + mode * n_rows
        if end > buf.size:
            raise CorruptStreamError("dvc block truncated inside a fixed-width column")
        vals = np.frombuffer(buf, dtype=f"<u{mode}", count=n_rows, offset=off)
        return vals.astype(_U), end

    def _decode_block(
        self, payload: bytes, n_rows: int, version: int = 2
    ) -> np.ndarray:
        buf = np.frombuffer(payload, np.uint8)
        base = np.int64(0)
        if version == 1:
            vals, consumed = decode_varints(buf, 2 * n_rows)
            zz_i, zz_j = vals[:n_rows], vals[n_rows:]
        elif version == 2:
            zz_i, off = self._decode_column_v2(buf, 0, n_rows)
            zz_j, consumed = self._decode_column_v2(buf, off, n_rows)
        else:
            if buf.size < self._V3_BASE.size:
                raise CorruptStreamError("dvc v3 block truncated before its base")
            (base,) = self._V3_BASE.unpack_from(payload, 0)
            base = np.int64(base)
            zz_i, off = self._decode_column_v3(buf, self._V3_BASE.size, n_rows)
            zz_j, consumed = self._decode_column_v3(buf, off, n_rows)
        if consumed != buf.size:
            raise CorruptStreamError(
                f"dvc block has {buf.size - consumed} trailing bytes"
            )
        i = base + np.cumsum(zigzag_decode(zz_i))
        j = i + zigzag_decode(zz_j)
        return np.stack([i, j], axis=1).astype(np.int32)

    def decode_block(
        self, payload: bytes, n_rows: int, version: int = 2
    ) -> np.ndarray:
        """Public host decode of one block payload — the fallback path the
        compressed staging layer uses for varint/u8/partial blocks."""
        return self._decode_block(payload, n_rows, version)

    def file_block_edges(self, path: PathLike) -> int:
        """The ``block_edges`` the *file* header declares (the sync-block
        granularity staging sizes its descriptor windows from)."""
        with open(path, "rb") as f:
            block_edges = self._read_header(f)[0]
        return block_edges

    def n_edges(self, path: PathLike) -> int:
        with open(path, "rb") as f:
            _, n, _, checksummed = self._read_header(f)
            if n is not None:
                return n
            # sentinel header (unseekable encode): count by skipping block
            # headers — verifying each payload actually fits in the file,
            # so a mid-payload truncation fails here at open, not as a
            # confusing short-stream error mid-fit
            size = os.fstat(f.fileno()).st_size
            hdr_struct = self._CBLOCK if checksummed else self._BLOCK
            total = 0
            while True:
                head = f.read(hdr_struct.size)
                if not head:
                    return total
                if len(head) < hdr_struct.size:
                    raise TruncatedStreamError(
                        f"{os.fspath(path)}: dvc file truncated inside a "
                        "block header"
                    )
                if checksummed:
                    marker, payload_nbytes, n_rows, _, _ = hdr_struct.unpack(
                        head
                    )
                    if marker != self._CSYNC:
                        raise CorruptBlockError(
                            f"{os.fspath(path)}: lost block framing at byte "
                            f"{f.tell() - hdr_struct.size}"
                        )
                else:
                    payload_nbytes, n_rows = hdr_struct.unpack(head)
                total += n_rows
                f.seek(payload_nbytes, io.SEEK_CUR)
                if f.tell() > size:
                    raise TruncatedStreamError(
                        f"{os.fspath(path)}: dvc file truncated inside a "
                        "block payload"
                    )

    def _token_seek(
        self, f: BinaryIO, cursor: Cursor, hdr_size: int
    ) -> Optional[int]:
        """Seek to the token's sync block and return its first-row index —
        or ``None`` when the token is foreign or stale (wrong tag, file
        size changed since mint, out of bounds, or ahead of the cursor
        row), in which case the caller falls back to the always-correct
        header-skip path from the top."""
        tok = cursor.token
        if len(tok) != 4 or tok[0] != DVC_TOKEN_TAG:
            return None
        _, size, block_byte, block_row = tok
        end = os.fstat(f.fileno()).st_size
        if size != end:  # file replaced since the token was minted
            return None
        if not (0 <= block_row <= cursor.row):
            return None
        # must land on a block header (an exact-EOF sync is only ever
        # reached when the cursor row is past the stream, which callers
        # short-circuit before decoding)
        if not (self._HEADER.size <= block_byte <= end - hdr_size):
            return None
        f.seek(block_byte)
        return block_row

    # -- checksummed walk ----------------------------------------------
    def _read_cblock(self, f: BinaryIO, pos: int, size: int, block_edges: int,
                     n_edges: Optional[int]):
        """Read and validate one checksummed block at ``pos`` (``f`` already
        positioned there).  Returns ``None`` at clean EOF, a ``str`` reason
        when the block cannot be trusted, or ``(n_rows, first_row, payload,
        end_byte)`` on success."""
        head = f.read(self._CBLOCK.size)
        if not head:
            return None
        if len(head) < self._CBLOCK.size:
            return "file ends inside a block header"
        marker, payload_nbytes, n_rows, first_row, crc = self._CBLOCK.unpack(
            head
        )
        if marker != self._CSYNC:
            return "lost block framing (bad sync marker)"
        if not (1 <= n_rows <= block_edges):
            return f"implausible block row count {n_rows}"
        if first_row < 0 or (
            n_edges is not None and first_row + n_rows > n_edges
        ):
            return f"implausible block first-row {first_row}"
        end = pos + self._CBLOCK.size + payload_nbytes
        if end > size:
            return "file ends inside a block payload"
        payload = f.read(payload_nbytes)
        if len(payload) < payload_nbytes:
            return "file ends inside a block payload"
        want = zlib.crc32(
            payload,
            zlib.crc32(self._CCRC.pack(payload_nbytes, n_rows, first_row)),
        )
        if want != crc:
            return "block checksum mismatch"
        return n_rows, first_row, payload, end

    def _scan_forward(self, f: BinaryIO, start: int, size: int,
                      block_edges: int, n_edges: Optional[int]):
        """Resync: find the next byte position at/after ``start`` holding a
        block whose header and checksum validate.  Returns ``(pos, parsed)``
        or ``None`` when no healthy block remains."""
        window = 1 << 20
        overlap = len(self._CSYNC) - 1
        pos = start
        while pos < size:
            f.seek(pos)
            buf = f.read(window + overlap)
            idx = 0
            while True:
                hit = buf.find(self._CSYNC, idx)
                if hit == -1 or hit >= window:
                    break
                cand = pos + hit
                f.seek(cand)
                blk = self._read_cblock(f, cand, size, block_edges, n_edges)
                if isinstance(blk, tuple):
                    return cand, blk
                idx = hit + 1
            pos += window
        return None

    def _walk_plain(self, f: BinaryIO, cursor: Cursor):
        """Original unchecked framing: yields ``(block_row, n_rows,
        payload_or_None, end_byte)`` — payload ``None`` for blocks wholly
        before the cursor (seek-skipped)."""
        block_row = self._token_seek(f, cursor, self._BLOCK.size)
        if block_row is None:  # bare/foreign token: header-skip from 0
            f.seek(self._HEADER.size)
            block_row = 0
        while True:
            hdr = self._next_block_header(f)
            if hdr is None:
                return
            payload_nbytes, n_rows = hdr
            next_row = block_row + n_rows
            if cursor.row >= next_row:  # wholly before the cursor: skip
                f.seek(payload_nbytes, io.SEEK_CUR)
                yield block_row, n_rows, None, f.tell()
            else:
                payload = f.read(payload_nbytes)
                if len(payload) < payload_nbytes:
                    raise TruncatedStreamError(
                        "dvc file truncated inside a block"
                    )
                yield block_row, n_rows, payload, f.tell()
            block_row = next_row

    def _walk_checksummed(
        self,
        f: BinaryIO,
        size: int,
        cursor: Cursor,
        block_edges: int,
        n_edges: Optional[int],
        on_lost: Optional[Callable[[int, int], None]],
        path: str,
    ):
        """Checksummed framing walk with optional quarantine.

        Yields the same ``(block_row, n_rows, payload_or_None, end_byte)``
        tuples as :meth:`_walk_plain`, but every block — skipped or not —
        is checksum-verified, so yielded row coordinates count only
        *delivered* rows and are identical on every pass over the same
        bytes.  On a bad block: raise :class:`CorruptBlockError` when
        ``on_lost`` is ``None``, else resync to the next healthy block and
        report ``on_lost(detect_byte, rows_lost)`` with the exact absolute
        row count the ``first_row`` chain proves missing.
        """
        block_row = self._token_seek(f, cursor, self._CBLOCK.size)
        expected_abs: Optional[int] = None
        if block_row is None:
            f.seek(self._HEADER.size)
            block_row = 0
            expected_abs = 0
        while True:
            pos = f.tell()
            blk = self._read_cblock(f, pos, size, block_edges, n_edges)
            if blk is None:  # clean EOF at a block boundary
                if (
                    n_edges is not None
                    and expected_abs is not None
                    and expected_abs < n_edges
                ):
                    if on_lost is None:
                        raise TruncatedStreamError(
                            f"{path}: truncated — stream ends "
                            f"{n_edges - expected_abs} rows short of its "
                            f"declared {n_edges} edges"
                        )
                    on_lost(size, n_edges - expected_abs)
                return
            if isinstance(blk, str):
                if on_lost is None:
                    msg = f"{path}: {blk} at byte {pos}"
                    if blk.startswith("file ends"):
                        raise TruncatedStreamError(f"{msg} (truncated)")
                    raise CorruptBlockError(msg)
                if expected_abs is None:
                    # corruption before the first block a (stale) token
                    # landed on — no absolute anchor yet, so restart the
                    # walk from the top, which always has one
                    f.seek(self._HEADER.size)
                    block_row = 0
                    expected_abs = 0
                    continue
                nxt = self._scan_forward(
                    f, pos + 1, size, block_edges, n_edges
                )
                if nxt is None:
                    # nothing healthy to EOF: the tail is lost
                    if n_edges is None:
                        raise TruncatedStreamError(
                            f"{path}: {blk} at byte {pos}, truncated — no "
                            "healthy block follows (unknown total, cannot "
                            "account)"
                        )
                    if n_edges > expected_abs:
                        on_lost(pos, n_edges - expected_abs)
                    return
                _, (n_rows, first_row, payload, end) = nxt
                if first_row < expected_abs:
                    raise CorruptStreamError(
                        f"{path}: resync block at byte {nxt[0]} rewinds to "
                        f"row {first_row} (expected {expected_abs})"
                    )
                if first_row > expected_abs:
                    on_lost(pos, first_row - expected_abs)
                expected_abs = first_row
            else:
                n_rows, first_row, payload, end = blk
                if expected_abs is None:
                    expected_abs = first_row  # anchor from the token block
                elif first_row != expected_abs:
                    raise CorruptStreamError(
                        f"{path}: block at byte {pos} starts at absolute "
                        f"row {first_row}, expected {expected_abs} — "
                        "stream spliced or rewritten mid-walk"
                    )
            next_row = block_row + n_rows
            if cursor.row >= next_row:
                yield block_row, n_rows, None, end
            else:
                yield block_row, n_rows, payload, end
            block_row = next_row
            expected_abs += n_rows
            f.seek(end)

    def decode_from(
        self,
        path: PathLike,
        cursor: Cursor,
        *,
        on_lost: Optional[Callable[[int, int], None]] = None,
    ) -> Iterator[Tuple[np.ndarray, Cursor]]:
        with open(path, "rb") as f:
            size = os.fstat(f.fileno()).st_size
            # header first: the file's version/framing drives block
            # decoding, so it must be known before any token fast-forward
            block_edges, n_edges, version, checksummed = self._read_header(f)
            if checksummed:
                walk = self._walk_checksummed(
                    f, size, cursor, block_edges, n_edges, on_lost,
                    os.fspath(path),
                )
            else:
                walk = self._walk_plain(f, cursor)
            for block_row, n_rows, payload, end in walk:
                if payload is None:  # wholly before the cursor
                    continue
                next_row = block_row + n_rows
                rows = self._decode_block(payload, n_rows, version)
                if cursor.row > block_row:
                    rows = rows[cursor.row - block_row :]
                yield rows, Cursor(
                    next_row, (DVC_TOKEN_TAG, size, end, next_row)
                )

    # -- block scan (compressed-slab staging) --------------------------
    def _parse_v3_meta(self, payload: bytes, n_rows: int) -> Optional[FixedBlockMeta]:
        """Fixed-lane metadata of a v3 payload, or ``None`` when either
        column needs the host (varint mode or u8 width)."""
        buf = np.frombuffer(payload, np.uint8)
        if buf.size < self._V3_BASE.size + 1:
            raise CorruptStreamError("dvc v3 block truncated before its base")
        (base,) = self._V3_BASE.unpack_from(payload, 0)
        off = self._V3_BASE.size
        w_i = int(buf[off])
        off_i = off + 1
        if w_i not in self._DEVICE_WIDTHS:
            return None
        off = off_i + w_i * n_rows
        if off >= buf.size:
            raise CorruptStreamError("dvc v3 block truncated inside a column")
        w_j = int(buf[off])
        off_j = off + 1
        if w_j not in self._DEVICE_WIDTHS:
            return None
        if off_j + w_j * n_rows != buf.size:
            raise CorruptStreamError("dvc v3 block has trailing bytes")
        return FixedBlockMeta(off_i, w_i, off_j, w_j, int(base))

    def scan_blocks(
        self,
        path: PathLike,
        cursor: Cursor,
        *,
        on_lost: Optional[Callable[[int, int], None]] = None,
    ) -> Iterator[CodecBlock]:
        """Yield every sync block that contains rows at/after ``cursor``,
        *without* decoding them.

        This is the compressed staging path's read primitive: payload
        bytes move from file to slab untouched, and :class:`FixedBlockMeta`
        tells the device decoder where the lanes are.  Blocks are yielded
        whole — a cursor landing mid-block yields the *containing* block
        (``first_row < cursor.row``); the caller host-decodes and slices
        that one (DESIGN.md §14).  The cursor token fast-forward, framing
        checks, and quarantine semantics (``on_lost``) are identical to
        :meth:`decode_from`, so resume positions name the same blocks
        bit-for-bit.
        """
        with open(path, "rb") as f:
            size = os.fstat(f.fileno()).st_size
            block_edges, n_edges, version, checksummed = self._read_header(f)
            if checksummed:
                walk = self._walk_checksummed(
                    f, size, cursor, block_edges, n_edges, on_lost,
                    os.fspath(path),
                )
            else:
                walk = self._walk_plain(f, cursor)
            for block_row, n_rows, payload, end in walk:
                if payload is None:
                    continue
                next_row = block_row + n_rows
                fixed = (
                    self._parse_v3_meta(payload, n_rows)
                    if version == 3
                    else None
                )
                yield CodecBlock(
                    block_row,
                    n_rows,
                    payload,
                    version,
                    fixed,
                    Cursor(
                        next_row,
                        (DVC_TOKEN_TAG, size, end, next_row),
                    ),
                )


# ---------------------------------------------------------------------------
# Registry / sniffing
# ---------------------------------------------------------------------------

CODECS = {"raw": RawCodec, "dvc": DeltaVarintCodec}


def get_codec(name: str, **kwargs) -> EdgeCodec:
    try:
        return CODECS[name](**kwargs)
    except KeyError:
        raise KeyError(
            f"unknown codec {name!r}; registered: {', '.join(sorted(CODECS))}"
        ) from None


def default_codec_for_path(path: PathLike) -> EdgeCodec:
    """The codec an output *path* implies: the first registered codec whose
    suffix matches, else raw fixed-width.  The single home of the
    suffix-default rule (used by ``CodecFileSource.write`` and the
    ``repro.graph.convert`` CLI)."""
    p = os.fspath(path)
    for cls in CODECS.values():
        codec = cls()
        if any(p.endswith(s) for s in codec.suffixes):
            return codec
    return RawCodec()


def sniff_codec(path: PathLike) -> Optional[EdgeCodec]:
    """Identify a codec by magic bytes, falling back to the file suffix.

    Returns ``None`` when the file is neither a known magic nor a known
    binary suffix (callers then treat it as a text edge list).
    """
    p = os.fspath(path)
    try:
        with open(p, "rb") as f:
            head = f.read(4)
    except OSError:
        head = b""
    for cls in CODECS.values():
        codec = cls()
        accepted = codec.magics or ((codec.magic,) if codec.magic else ())
        if any(head.startswith(mg) for mg in accepted):
            return codec
    for cls in CODECS.values():
        codec = cls()
        if any(p.endswith(s) for s in codec.suffixes):
            return codec
    return None
