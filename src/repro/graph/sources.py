"""Out-of-core edge sources: the *transport* layer of the ingestion engine.

The paper's setting is a stream far larger than host memory (up to 1.8e9
edges) against ``3n`` ints of state — so no entry point may require the full
``(m, 2)`` edge array materialized.  An :class:`EdgeSource` abstracts *where
the stream comes from*; :mod:`repro.graph.codecs` abstracts *what stored
bytes mean* (fixed-width raw vs delta+varint compression); the
:class:`repro.graph.pipeline.BatchPipeline` handles *how rows reach the
device* (fixed shapes, PAD padding, double buffering, decode on the
prefetch thread).  Sources yield raw variable-length slices; batch
boundaries are set solely by the pipeline, so a given stream produces
identical batches — and identical labels — no matter which source or codec
backs it.

Concrete sources:

* :class:`ArraySource` — in-memory ``(m, 2)`` array (the auto-wrap for the
  existing array-based API).
* :class:`EdgeListFileSource` — whitespace-separated text edge lists (SNAP
  format), constant-memory line parsing.
* :class:`CodecFileSource` — binary files behind any
  :class:`~repro.graph.codecs.EdgeCodec`; :class:`BinaryFileSource` is its
  raw-codec specialization (mmap'd int32 pairs, zero-copy slices).
* :class:`GeneratorSource` — deterministic per-offset synthetic segments
  (SBM / Chung–Lu) so benchmark-scale graphs stream without materialization.
* :class:`MergedSource` — deterministic arrival-time interleave of several
  sources into one resumable stream (multi-stream ingest).
* :class:`ShardedSource` — contiguous equal split for the distributed tier.

**Positions are cursors.**  Every source is readable from any raw-row
offset, and additionally mints :class:`~repro.graph.codecs.Cursor` values
(row + opaque token) via :meth:`EdgeSource.cursor_at`; :meth:`resume`
accepts them back.  Tokens are resume *hints* — a recorded block sync
point, a text byte offset, per-source merge positions — that make resume
O(remaining) or O(1) instead of a prefix re-read; a bare row is always
valid.
"""

from __future__ import annotations

import bisect
import os
import threading
from typing import Callable, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.graph.codecs import (
    TEXT_TOKEN_TAG,
    Cursor,
    DeltaVarintCodec,
    EdgeCodec,
    RawCodec,
    as_cursor,
    sniff_codec,
)
from repro.graph.errors import (
    RetryPolicy,
    SourceDeadError,
    TruncatedStreamError,
    retrying_slices,
)
from repro.graph.pipeline import PAD, rechunk

PathLike = Union[str, os.PathLike]


class _SyncPoints:
    """Recorded ``row -> payload`` sync points of one file source.

    Writes come from the pipeline's prefetch thread while lookups come from
    the consumer's per-batch ``cursor_at`` calls, so access is locked; rows
    are kept sorted so the best-sync lookup is O(log n) bisect, not a scan
    of every recorded point (at 1.8e9-edge scale that scan would dominate
    the fit loop)."""

    def __init__(self, first_payload):
        self._rows = [0]
        self._payloads = {0: first_payload}
        self._lock = threading.Lock()

    def record(self, row: int, payload) -> None:
        with self._lock:
            if row not in self._payloads:
                bisect.insort(self._rows, row)
                self._payloads[row] = payload

    def best(self, row: int) -> Tuple[int, object]:
        """The recorded sync with the largest row ``<= row``."""
        with self._lock:
            i = bisect.bisect_right(self._rows, row) - 1
            r = self._rows[i]
            return r, self._payloads[r]

    def __len__(self) -> int:
        with self._lock:
            return len(self._rows)

    def __iter__(self):
        with self._lock:
            return iter(list(self._rows))


class EdgeSource:
    """An ordered edge stream readable from any raw-row offset.

    Contract: :meth:`iter_slices` yields ``(k, 2)`` integer arrays (any
    ``k >= 0``, any internal slicing) whose concatenation from ``start`` is
    the tail of *the* stream — the slicing must not depend on anything but
    the source's own constants, and restarting from the same ``start`` must
    reproduce the same rows (required for suspend/resume mid-stream).
    ``n_edges`` is ``None`` when the length is unknown without a full scan
    (text files).
    """

    @property
    def n_edges(self) -> Optional[int]:
        return None

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        raise NotImplementedError

    # ------------------------------------------------------------------
    # Cursor protocol (codec-defined stream positions)
    # ------------------------------------------------------------------
    def cursor_at(self, row: int) -> Cursor:
        """The best :class:`Cursor` this source can mint for ``row`` —
        sources with seekable sync structure attach a token; the default is
        the bare row (always correct, possibly slower to resume)."""
        return Cursor(int(row))

    def resume(self, cursor: Union[int, Cursor]) -> Iterator[np.ndarray]:
        """Iterate the stream tail from a cursor (or raw row offset).

        Equivalent to ``iter_slices(cursor.row)``; sources override to
        exploit the token (seek to a recorded sync point instead of
        re-reading/skipping the prefix)."""
        return self.iter_slices(as_cursor(cursor).row)

    # ------------------------------------------------------------------
    def batches(self, batch_edges: int, start: int = 0) -> Iterator[np.ndarray]:
        """Exact ``batch_edges``-row batches (final may be short), unpadded.
        Boundary placement depends only on ``batch_edges`` and ``start``."""
        return rechunk(self.iter_slices(start), batch_edges)

    def count_edges(self) -> int:
        """Total raw rows; scans the stream when ``n_edges`` is unknown."""
        if self.n_edges is not None:
            return self.n_edges
        return sum(int(sl.shape[0]) for sl in self.iter_slices(0))

    def materialize(self) -> np.ndarray:
        """The full stream as one host array — O(m) memory.  Tests and
        non-streaming baselines only: every registered backend ingests
        sources out-of-core, so no API path calls this."""
        parts = [np.asarray(sl, np.int32) for sl in self.iter_slices(0)]
        if not parts:
            return np.zeros((0, 2), np.int32)
        return np.concatenate(parts, axis=0)


# ---------------------------------------------------------------------------
# In-memory
# ---------------------------------------------------------------------------

class ArraySource(EdgeSource):
    """Wraps an in-memory ``(m, 2)`` array; slices are views."""

    def __init__(self, edges):
        edges = np.asarray(edges)
        if edges.ndim != 2 or edges.shape[1] != 2:
            raise ValueError(f"expected (m, 2) edge array, got {edges.shape}")
        self.edges = edges

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        if start < self.edges.shape[0]:
            yield self.edges[start:]

    def materialize(self) -> np.ndarray:
        return self.edges


# ---------------------------------------------------------------------------
# Files
# ---------------------------------------------------------------------------

class EdgeListFileSource(EdgeSource):
    """Text edge list (SNAP format): one ``i j`` pair per line.  Skipped:
    blank lines, ``#``/``%`` comment lines, and textual header lines (first
    character not a digit/sign — e.g. ``FromNodeId  ToNodeId``).  Extra
    columns (weights/timestamps) are ignored; a numeric line with fewer than
    two fields is a hard error naming the file and line.  Parsing is
    line-buffered — O(block_lines) memory regardless of file size.

    Byte-offset resume points are recorded at every slice boundary as the
    file is read, so a later ``iter_slices(start)`` (the suspend/resume
    preemption loop) seeks near ``start`` instead of re-parsing the whole
    prefix — resume cost is O(remaining), not O(file).
    """

    def __init__(
        self,
        path: PathLike,
        comments: Sequence[str] = ("#", "%"),
        block_lines: int = 1 << 16,
        retry: Optional[RetryPolicy] = None,
    ):
        if block_lines < 1:
            raise ValueError(f"block_lines must be >= 1, got {block_lines}")
        self.path = os.fspath(path)
        self.comments = tuple(comments)
        self._comments = tuple(c.encode() for c in comments)
        self.block_lines = block_lines
        self.retry = retry
        self.retries = 0  # transient read errors survived via re-resume
        self._n: Optional[int] = None  # cached after any full pass
        # row -> (byte offset, line number): seekable resume points
        self._resume = _SyncPoints((0, 0))

    @property
    def n_edges(self) -> Optional[int]:
        return self._n

    def _best_resume(self, start: int) -> tuple:
        row, (pos, lineno) = self._resume.best(start)
        return row, pos, lineno

    def cursor_at(self, row: int) -> Cursor:
        """Token = tagged ``(file_size, sync_row, byte_pos, lineno)`` of the
        best recorded seek point at or before ``row`` — carried into
        checkpoints, it makes a fresh process's resume O(remaining) instead
        of a prefix re-parse."""
        sync_row, pos, lineno = self._best_resume(row)
        try:
            size = os.path.getsize(self.path)
        except OSError:
            # path gone (unlinked while an open handle still streams):
            # mint a bare-row cursor instead of killing the fit loop
            return Cursor(int(row))
        return Cursor(int(row), (TEXT_TOKEN_TAG, size, sync_row, pos, lineno))

    def _token_ok(self, tok: tuple, row: int) -> bool:
        """A token may seed the seek map only when it is demonstrably ours
        and fresh: right tag, the file size it was minted against still
        matches (a replaced/regenerated file invalidates every byte
        offset), bounds hold, and the byte position is a line start — a
        mid-line seek would silently re-parse garbage."""
        if len(tok) != 5 or tok[0] != TEXT_TOKEN_TAG:
            return False
        _, size, sync_row, pos, lineno = tok
        try:
            if size != os.path.getsize(self.path):
                return False
            if not (0 <= sync_row <= row and lineno >= 0):
                return False
            if pos == 0:
                return True
            # reject EOF positions too: a stale EOF seek parses zero rows,
            # which would silently truncate the resumed stream instead of
            # falling back
            if not 0 < pos < size:
                return False
            with open(self.path, "rb") as f:
                f.seek(pos - 1)
                return f.read(1) == b"\n"
        except OSError:
            return False

    def resume(self, cursor) -> Iterator[np.ndarray]:
        cursor = as_cursor(cursor)
        tok = cursor.token
        if self._token_ok(tok, cursor.row):
            self._resume.record(tok[2], (tok[3], tok[4]))
        if self.retry is None:
            return self.iter_slices(cursor.row)
        return retrying_slices(
            lambda c: self.iter_slices(c.row),
            self.cursor_at,
            cursor,
            self.retry,
            self._count_retry,
        )

    def _count_retry(self, attempt: int, exc: BaseException) -> None:
        self.retries += 1

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        buf: List[int] = []
        row, pos, lineno = self._best_resume(start)
        with open(self.path, "rb") as f:
            f.seek(pos)
            while True:
                line = f.readline()
                if not line:
                    break
                lineno += 1
                s = line.strip()
                if not s or s.startswith(self._comments):
                    continue
                head = s[:1]
                if not (head.isdigit() or head in (b"+", b"-")):
                    continue  # textual header line
                row += 1
                if row <= start:
                    continue
                parts = s.split(maxsplit=2)
                try:
                    i, j = int(parts[0]), int(parts[1])
                except (IndexError, ValueError):
                    raise ValueError(
                        f"{self.path}:{lineno}: expected an 'i j' edge "
                        f"line, got {s.decode(errors='replace')!r}"
                    ) from None
                buf.append(i)
                buf.append(j)
                if len(buf) >= 2 * self.block_lines:
                    self._resume.record(row, (f.tell(), lineno))
                    yield np.array(buf, np.int32).reshape(-1, 2)
                    buf = []
        if buf:
            yield np.array(buf, np.int32).reshape(-1, 2)
        # reaching EOF pins the exact stream length wherever we started
        self._n = row

    def count_edges(self) -> int:
        if self._n is None:
            for _ in self.iter_slices(0):
                pass
        return self._n if self._n is not None else 0


class CodecFileSource(EdgeSource):
    """A binary edge file behind an :class:`~repro.graph.codecs.EdgeCodec`.

    The transport half of the codec/transport split: this class owns the
    path, the stream-length validation at open (``codec.n_edges`` raises on
    a structurally torn file — a truncated raw file must fail loudly, not
    silently drop its tail edge), and the sync-point bookkeeping; the codec
    owns the byte format.  Block sync cursors yielded during decoding are
    recorded, so :meth:`cursor_at` mints tokens that let a *fresh* process
    seek straight to the containing block instead of header-skipping from
    the top.

    **Failure policy.**  ``retry`` re-resumes from the last delivered row
    on transient ``OSError``\\ s (bounded, backed off).  ``on_corrupt``
    selects what a failed per-block checksum does on checksummed (``DVX``)
    files: ``"raise"`` (default) raises a typed
    :class:`~repro.graph.errors.CorruptBlockError`; ``"quarantine"`` skips
    to the next healthy sync block and accounts the exact loss —
    ``blocks_quarantined``/``edges_lost`` — instead of dying or going
    silently wrong.  Quarantine discovery is keyed by byte position, so
    repeated passes (resume, re-fit) never double-count.
    """

    def __init__(
        self,
        path: PathLike,
        codec: Optional[EdgeCodec] = None,
        *,
        on_corrupt: str = "raise",
        retry: Optional[RetryPolicy] = None,
    ):
        if on_corrupt not in ("raise", "quarantine"):
            raise ValueError(
                f"on_corrupt must be 'raise' or 'quarantine', got {on_corrupt!r}"
            )
        self.path = os.fspath(path)
        if codec is None:
            codec = sniff_codec(self.path)
            if codec is None:
                raise ValueError(
                    f"{self.path}: no codec magic/suffix recognized; pass "
                    "codec= explicitly"
                )
        self.codec = codec
        self.on_corrupt = on_corrupt
        self.retry = retry
        self.retries = 0
        checksummed = getattr(codec, "file_checksummed", None)
        self._checksummed = bool(checksummed(self.path)) if checksummed else False
        if on_corrupt == "quarantine" and not self._checksummed:
            raise ValueError(
                f"{self.path}: quarantine needs per-block checksums (DVX "
                "framing) to skip and account corrupt blocks — re-encode "
                "with a checksummed codec or use on_corrupt='raise'"
            )
        self._m = codec.n_edges(self.path)  # open-time validation
        self._sync = _SyncPoints(())  # row -> codec token (sync points)
        # byte position of each quarantined region -> absolute rows lost;
        # a stable key makes re-walks of the same bytes idempotent
        self._quarantined: dict = {}

    @property
    def n_edges(self) -> int:
        return self._m

    @property
    def supports_quarantine(self) -> bool:
        """True when the file's framing carries per-block checksums, so
        ``on_corrupt='quarantine'`` can skip-and-count."""
        return self._checksummed

    @property
    def blocks_quarantined(self) -> int:
        return len(self._quarantined)

    @property
    def edges_lost(self) -> int:
        return int(sum(self._quarantined.values()))

    def _on_lost(self, byte_pos: int, rows: int) -> None:
        self._quarantined[int(byte_pos)] = int(rows)

    def _count_retry(self, attempt: int, exc: BaseException) -> None:
        self.retries += 1

    def cursor_at(self, row: int) -> Cursor:
        _, token = self._sync.best(row)
        return Cursor(int(row), token)

    def resume(self, cursor) -> Iterator[np.ndarray]:
        cursor = as_cursor(cursor)
        if self.retry is None:
            return self._iter(cursor)
        return retrying_slices(
            self._iter, self.cursor_at, cursor, self.retry, self._count_retry
        )

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        # consult locally recorded sync points even for bare-row starts
        return self.resume(self.cursor_at(start))

    def _iter(self, cursor: Cursor) -> Iterator[np.ndarray]:
        if cursor.row >= self._m:
            return
        quarantine = self.on_corrupt == "quarantine" and self._checksummed
        if quarantine:
            gen = self.codec.decode_from(
                self.path, cursor, on_lost=self._on_lost
            )
        else:
            gen = self.codec.decode_from(self.path, cursor)
        produced = 0
        for rows, nxt in gen:
            self._sync.record(nxt.row, nxt.token)
            if rows.shape[0]:
                produced += int(rows.shape[0])
                yield rows
        if quarantine:
            # the checksummed walk accounts every missing row itself (the
            # first_row chain), so the declared length holds minus the loss
            return
        # a file truncated at a block boundary decodes cleanly but short —
        # without this cross-check the tail would drop silently (the same
        # torn-file failure RawCodec rejects at open)
        if cursor.row + produced != self._m:
            raise TruncatedStreamError(
                f"{self.path}: stream ended at row {cursor.row + produced} "
                f"but declares {self._m} edges — file truncated?"
            )

    @property
    def block_rows(self) -> Optional[int]:
        """Sync-block row granularity of the underlying file, or ``None``
        when the codec has no block structure (raw files)."""
        reader = getattr(self.codec, "file_block_edges", None)
        if reader is None:
            return None
        return int(reader(self.path))

    def scan_blocks(self, cursor):
        """Yield raw :class:`~repro.graph.codecs.CodecBlock` sync blocks
        from ``cursor`` on — the compressed-slab staging read path.

        Payload bytes are *not* decoded here; the pipeline ships them (plus
        descriptor metadata) toward the device.  Sync points are recorded
        exactly as in the decode path, so cursors minted during compressed
        ingest are interchangeable with host-decode cursors, and the same
        declared-length cross-check rejects a file truncated at a block
        boundary.
        """
        scan = getattr(self.codec, "scan_blocks", None)
        if scan is None:
            raise ValueError(
                f"{self.path}: codec {self.codec.name!r} has no block "
                "structure to scan"
            )
        cursor = as_cursor(cursor)
        if cursor.row >= self._m:
            return
        quarantine = self.on_corrupt == "quarantine" and self._checksummed
        blocks = (
            scan(self.path, cursor, on_lost=self._on_lost)
            if quarantine
            else scan(self.path, cursor)
        )
        end = cursor.row
        for block in blocks:
            self._sync.record(block.next_cursor.row, block.next_cursor.token)
            end = block.first_row + block.n_rows
            yield block
        if quarantine:
            return
        if end != self._m:
            raise TruncatedStreamError(
                f"{self.path}: stream ended at row {end} but declares "
                f"{self._m} edges — file truncated?"
            )

    @classmethod
    def write(
        cls,
        path: PathLike,
        source: "EdgeSource | np.ndarray",
        codec: Optional[EdgeCodec] = None,
    ) -> "CodecFileSource":
        """Stream any source (or array) to disk through ``codec`` — O(slice)
        memory.  The codec defaults to the path's suffix (``.dvc`` →
        delta+varint, anything else → raw)."""
        if codec is None:
            from repro.graph.codecs import default_codec_for_path

            codec = default_codec_for_path(path)
        src = as_source(source)
        # write-then-rename: a crash mid-encode must not leave a file that
        # parses as a valid-but-shorter stream (a dvc file cut at a block
        # boundary would otherwise read back cleanly minus its tail)
        path = os.fspath(path)
        tmp = path + ".tmp"
        try:
            with open(tmp, "wb") as f:
                codec.encode(src.iter_slices(0), f)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return cls(path, codec)


class BinaryFileSource(CodecFileSource):
    """mmap'd little-endian int32 ``(i, j)`` pairs (:class:`RawCodec`);
    slices are zero-copy memmap views, so even full-batch reads never copy
    into the heap.  File length is validated at open: a size that is not a
    whole number of 8-byte records raises instead of dropping the tail."""

    def __init__(self, path: PathLike, rows_per_slice: int = 1 << 20):
        super().__init__(path, RawCodec(rows_per_slice=rows_per_slice))
        self.rows_per_slice = rows_per_slice

    @staticmethod
    def write(path: PathLike, source: "EdgeSource | np.ndarray") -> "BinaryFileSource":
        """Stream any source (or array) to raw fixed-width format — O(slice)
        memory."""
        CodecFileSource.write(path, source, RawCodec())
        return BinaryFileSource(path)


# ---------------------------------------------------------------------------
# Synthetic generators
# ---------------------------------------------------------------------------

class GeneratorSource(EdgeSource):
    """Deterministic synthetic stream generated segment-by-segment.

    ``segment_fn(start, length)`` must return rows ``start .. start+length``
    of the stream as a ``(length, 2)`` array, depending only on ``start`` /
    ``length`` (e.g. seed the RNG with ``(seed, start)`` — see
    ``repro.graph.generators.chung_lu_segments``).  Determinism per absolute
    offset is what makes the stream resumable at any row and independent of
    batch size; segments are fixed at ``segment_edges`` rows so the realized
    stream never depends on how it is read.  Memory is O(segment_edges).
    """

    def __init__(
        self,
        segment_fn: Callable[[int, int], np.ndarray],
        n_edges: int,
        segment_edges: int = 1 << 16,
    ):
        if n_edges < 0:
            raise ValueError(f"n_edges must be >= 0, got {n_edges}")
        if segment_edges < 1:
            raise ValueError(f"segment_edges must be >= 1, got {segment_edges}")
        self.segment_fn = segment_fn
        self._m = int(n_edges)
        self.segment_edges = segment_edges

    @property
    def n_edges(self) -> int:
        return self._m

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        seg = self.segment_edges
        for seg_start in range((start // seg) * seg, self._m, seg):
            length = min(seg, self._m - seg_start)
            arr = np.asarray(self.segment_fn(seg_start, length), np.int32)
            if arr.shape != (length, 2):
                raise ValueError(
                    f"segment_fn({seg_start}, {length}) returned shape "
                    f"{arr.shape}, expected ({length}, 2)"
                )
            if seg_start < start:
                arr = arr[start - seg_start :]
            if arr.shape[0]:
                yield arr


# ---------------------------------------------------------------------------
# Multi-stream merge
# ---------------------------------------------------------------------------

class _SlicePuller:
    """Pull exactly-``k``-row arrays from one source's slice iterator,
    buffering at most one raw slice of leftover.

    With ``retry`` set, a transient error during a pull re-opens the
    source's iterator at the exact row already consumed (bounded,
    backed-off) — buffered rows are never dropped or repeated, so the
    delivered stream is bit-identical to a fault-free read."""

    def __init__(
        self,
        source: EdgeSource,
        start: int,
        retry: Optional[RetryPolicy] = None,
    ):
        self._source = source
        self._row = int(start)  # rows of the source consumed from the iter
        self._retry = retry
        self._attempt = 0
        self.retries = 0
        self._it = source.iter_slices(start)
        self._buf: List[np.ndarray] = []
        self._have = 0

    def _pull(self) -> np.ndarray:
        while True:
            try:
                sl = np.asarray(next(self._it))
            except StopIteration:
                raise
            except Exception as exc:
                policy = self._retry
                if (
                    policy is None
                    or not policy.is_retryable(exc)
                    or self._attempt >= policy.max_retries
                ):
                    raise
                self._attempt += 1
                self.retries += 1
                self.close()
                policy.backoff(self._attempt)
                self._it = self._source.iter_slices(self._row)
                continue
            self._attempt = 0
            self._row += int(sl.shape[0])
            return sl

    def take(self, k: int) -> np.ndarray:
        while self._have < k:
            try:
                sl = self._pull()
            except StopIteration:
                raise ValueError(
                    "merged sub-source ended before its counted length"
                ) from None
            if sl.shape[0]:
                self._buf.append(sl)
                self._have += int(sl.shape[0])
        if len(self._buf) == 1 and self._have == k:
            out = self._buf[0]
            self._buf, self._have = [], 0
            return out
        out_parts: List[np.ndarray] = []
        need = k
        rest: List[np.ndarray] = []
        for sl in self._buf:
            if need >= sl.shape[0]:
                out_parts.append(sl)
                need -= sl.shape[0]
            elif need > 0:
                out_parts.append(sl[:need])
                rest.append(sl[need:])
                need = 0
            else:
                rest.append(sl)
        self._buf, self._have = rest, self._have - k
        return np.concatenate(out_parts).astype(np.int32, copy=False)

    def close(self) -> None:
        close = getattr(self._it, "close", None)
        if close is not None:
            close()


class MergedSource(EdgeSource):
    """Deterministic arrival-time interleave of several sources.

    Models concurrent ingest feeds (the ROADMAP multi-stream item): source
    ``s`` produces its ``r``-th row at virtual time ``r / rates[s]``, and the
    merge emits rows in arrival order, quantized to ``granule``-row turns
    (one turn = the next ``granule`` rows of whichever source has the
    earliest virtual clock; ties break to the lowest source index; integer
    cross-multiplied comparisons, so the schedule is exact and
    platform-independent).

    Because the schedule is a pure function of the per-source consumed-row
    vector, the merged stream is *one* well-defined `EdgeSource`: readable
    from any row (the schedule prefix is replayed arithmetically — no I/O —
    and each sub-source seeks by its own row offset / sync token), so
    suspend/resume and label invariance work exactly as for a single file.
    :meth:`cursor_at` tokens carry the per-source row offsets.

    All sub-sources must have countable length (text sources pay one
    counting pass, as for :class:`ShardedSource`).
    """

    def __init__(
        self,
        sources: Sequence[EdgeSource],
        rates: Optional[Sequence[int]] = None,
        granule: int = 1 << 13,
    ):
        if not sources:
            raise ValueError("MergedSource needs at least one source")
        if granule < 1:
            raise ValueError(f"granule must be >= 1, got {granule}")
        self.sources = [as_source(s) for s in sources]
        if rates is None:
            rates = [1] * len(self.sources)
        if len(rates) != len(self.sources):
            raise ValueError(
                f"{len(rates)} rates for {len(self.sources)} sources"
            )
        self.rates = [int(w) for w in rates]
        if any(w < 1 for w in self.rates):
            raise ValueError(f"rates must be positive ints, got {rates}")
        self.granule = granule
        self._ms = [int(s.count_edges()) for s in self.sources]
        self._m = sum(self._ms)
        self._cache: tuple = (0, (0,) * len(self.sources))  # (row, r-vector)

    @property
    def n_edges(self) -> int:
        return self._m

    # -- the schedule ---------------------------------------------------
    def _next_turn(self, r: List[int]) -> Optional[int]:
        """Source whose next turn arrives first: argmin of ``r[s]/rates[s]``
        over unfinished sources (exact integer compare, ties -> lowest s)."""
        best = None
        for s in range(len(self.sources)):
            if r[s] >= self._ms[s]:
                continue
            if best is None or r[s] * self.rates[best] < r[best] * self.rates[s]:
                best = s
        return best

    def _active(self, r: List[int]) -> Optional[int]:
        """The unique source with a partially-consumed turn, if any."""
        for s in range(len(self.sources)):
            if r[s] < self._ms[s] and r[s] % self.granule:
                return s
        return None

    def _turn_remainder(self, r: List[int], s: int) -> int:
        """Rows left in source ``s``'s current (or next) turn at state r."""
        turn_start = (r[s] // self.granule) * self.granule
        take = min(self.granule, self._ms[s] - turn_start)
        return turn_start + take - r[s]

    def _replay(self, row: int) -> List[int]:
        """Per-source consumed-row vector after ``row`` merged rows —
        arithmetic only, monotone-cached so sequential callers pay O(1)."""
        row = min(int(row), self._m)
        emitted, r_t = self._cache
        if emitted <= row:
            r = list(r_t)
        else:
            emitted, r = 0, [0] * len(self.sources)
        while emitted < row:
            s = self._active(r)
            if s is None:
                s = self._next_turn(r)
            step = min(self._turn_remainder(r, s), row - emitted)
            r[s] += step
            emitted += step
        self._cache = (emitted, tuple(r))
        return r

    # -- EdgeSource -----------------------------------------------------
    def cursor_at(self, row: int) -> Cursor:
        """Token = the per-source row offsets at ``row`` (sums to ``row``)."""
        return Cursor(int(row), tuple(self._replay(row)))

    def resume(self, cursor) -> Iterator[np.ndarray]:
        # The schedule replay is the canonical truth and costs only
        # O(row/granule) integer arithmetic (cached, no I/O), so the token
        # is never *trusted* — iter_slices recomputes the per-source
        # positions, and a token that disagrees (a checkpoint restored
        # against different rates/granule, or a foreign token) is thereby
        # dropped rather than silently reordering the resumed stream.
        return self.iter_slices(as_cursor(cursor).row)

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        if start >= self._m:
            return
        r = self._replay(start)
        pullers = {}
        try:
            while True:
                s = self._active(r)
                if s is None:
                    s = self._next_turn(r)
                    if s is None:
                        return
                take = self._turn_remainder(r, s)
                if s not in pullers:
                    pullers[s] = _SlicePuller(self.sources[s], r[s])
                yield pullers[s].take(take)
                r[s] += take
        finally:
            for p in pullers.values():
                p.close()


# ---------------------------------------------------------------------------
# Sharding (distributed tier)
# ---------------------------------------------------------------------------

class _WindowSource(EdgeSource):
    """A contiguous ``[start, start + length)`` raw-row window of a base
    source (one shard of a :class:`ShardedSource`)."""

    def __init__(self, base: EdgeSource, start: int, length: int):
        self.base = base
        self.start = start
        self.length = length

    @property
    def n_edges(self) -> int:
        return self.length

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        remaining = self.length - start
        if remaining <= 0:
            return
        for sl in self.base.iter_slices(self.start + start):
            if sl.shape[0] >= remaining:
                yield sl[:remaining]
                return
            remaining -= sl.shape[0]
            yield sl


class ShardedSource(EdgeSource):
    """Contiguous split of a stream into ``n_shards`` equal windows.

    Contiguous (not strided) so each shard preserves the stream order of its
    slice — the paper's streaming argument ("early edges are
    intra-community") applies within every shard.  Requires a known or
    countable stream length (text sources pay one counting pass).
    """

    def __init__(self, base: EdgeSource, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.base = base
        self.n_shards = n_shards
        self._m = base.count_edges()
        self.shard_len = -(-self._m // n_shards) if self._m else 1

    @property
    def n_edges(self) -> int:
        return self._m

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        return self.base.iter_slices(start)

    def shards(self) -> List[EdgeSource]:
        L = self.shard_len
        return [
            _WindowSource(self.base, s * L, max(0, min(L, self._m - s * L)))
            for s in range(self.n_shards)
        ]

    def stacked(self) -> np.ndarray:
        """The ``(n_shards, shard_len, 2)`` PAD-padded stack — O(m) output.

        Reference implementation only (kept for its unit test against the
        vectorized ``shard_stream``): the distributed tier now drains
        :meth:`shards` window by window through the chunked tier's
        ``partial_fit``, so no production path materializes this array.
        """
        L = self.shard_len
        out = np.full((self.n_shards * L, 2), PAD, dtype=np.int32)
        pos = 0
        for sl in self.base.iter_slices(0):
            out[pos : pos + sl.shape[0]] = sl
            pos += sl.shape[0]
        return out.reshape(self.n_shards, L, 2)


# ---------------------------------------------------------------------------
# Coercion
# ---------------------------------------------------------------------------

def as_source(edges) -> EdgeSource:
    """Coerce the public API's ``edges`` argument to an :class:`EdgeSource`.

    Sources pass through; paths dispatch on codec magic bytes, then file
    suffix (``.bin`` → raw mmap'd int32 pairs, ``.dvc`` → delta+varint
    compressed blocks, anything else → text edge list); everything else is
    treated as an in-memory array.
    """
    if isinstance(edges, EdgeSource):
        return edges
    if isinstance(edges, (str, os.PathLike)):
        path = os.fspath(edges)
        codec = sniff_codec(path)
        if isinstance(codec, RawCodec):
            return BinaryFileSource(path)
        if codec is not None:
            return CodecFileSource(path, codec)
        return EdgeListFileSource(path)
    return ArraySource(edges)
