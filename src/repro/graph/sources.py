"""Out-of-core edge sources: the ingestion stage of the streaming clusterer.

The paper's setting is a stream far larger than host memory (up to 1.8e9
edges) against ``3n`` ints of state — so no entry point may require the full
``(m, 2)`` edge array materialized.  An :class:`EdgeSource` abstracts *where
the stream comes from*; the :class:`repro.graph.pipeline.BatchPipeline`
handles *how it reaches the device* (fixed shapes, PAD padding, double
buffering).  Sources yield raw variable-length slices; batch boundaries are
set solely by the pipeline, so a given stream produces identical batches —
and identical labels — no matter which source backs it.

Concrete sources:

* :class:`ArraySource` — in-memory ``(m, 2)`` array (the auto-wrap for the
  existing array-based API).
* :class:`EdgeListFileSource` — whitespace-separated text edge lists (SNAP
  format), constant-memory line parsing.
* :class:`BinaryFileSource` — mmap'd int32 pairs; slices are zero-copy views.
* :class:`GeneratorSource` — deterministic per-offset synthetic segments
  (SBM / Chung–Lu) so benchmark-scale graphs stream without materialization.
* :class:`ShardedSource` — contiguous equal split for the distributed tier.
"""

from __future__ import annotations

import os
from typing import Callable, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.graph.pipeline import PAD, rechunk

PathLike = Union[str, os.PathLike]


class EdgeSource:
    """An ordered edge stream readable from any raw-row offset.

    Contract: :meth:`iter_slices` yields ``(k, 2)`` integer arrays (any
    ``k >= 0``, any internal slicing) whose concatenation from ``start`` is
    the tail of *the* stream — the slicing must not depend on anything but
    the source's own constants, and restarting from the same ``start`` must
    reproduce the same rows (required for suspend/resume mid-stream).
    ``n_edges`` is ``None`` when the length is unknown without a full scan
    (text files).
    """

    @property
    def n_edges(self) -> Optional[int]:
        return None

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        raise NotImplementedError

    # ------------------------------------------------------------------
    def batches(self, batch_edges: int, start: int = 0) -> Iterator[np.ndarray]:
        """Exact ``batch_edges``-row batches (final may be short), unpadded.
        Boundary placement depends only on ``batch_edges`` and ``start``."""
        return rechunk(self.iter_slices(start), batch_edges)

    def count_edges(self) -> int:
        """Total raw rows; scans the stream when ``n_edges`` is unknown."""
        if self.n_edges is not None:
            return self.n_edges
        return sum(int(sl.shape[0]) for sl in self.iter_slices(0))

    def materialize(self) -> np.ndarray:
        """The full stream as one host array — O(m) memory.  Tests and
        non-streaming baselines only: every registered backend ingests
        sources out-of-core, so no API path calls this."""
        parts = [np.asarray(sl, np.int32) for sl in self.iter_slices(0)]
        if not parts:
            return np.zeros((0, 2), np.int32)
        return np.concatenate(parts, axis=0)


# ---------------------------------------------------------------------------
# In-memory
# ---------------------------------------------------------------------------

class ArraySource(EdgeSource):
    """Wraps an in-memory ``(m, 2)`` array; slices are views."""

    def __init__(self, edges):
        edges = np.asarray(edges)
        if edges.ndim != 2 or edges.shape[1] != 2:
            raise ValueError(f"expected (m, 2) edge array, got {edges.shape}")
        self.edges = edges

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        if start < self.edges.shape[0]:
            yield self.edges[start:]

    def materialize(self) -> np.ndarray:
        return self.edges


# ---------------------------------------------------------------------------
# Files
# ---------------------------------------------------------------------------

class EdgeListFileSource(EdgeSource):
    """Text edge list (SNAP format): one ``i j`` pair per line.  Skipped:
    blank lines, ``#``/``%`` comment lines, and textual header lines (first
    character not a digit/sign — e.g. ``FromNodeId  ToNodeId``).  Extra
    columns (weights/timestamps) are ignored; a numeric line with fewer than
    two fields is a hard error naming the file and line.  Parsing is
    line-buffered — O(block_lines) memory regardless of file size.

    Byte-offset resume points are recorded at every slice boundary as the
    file is read, so a later ``iter_slices(start)`` (the suspend/resume
    preemption loop) seeks near ``start`` instead of re-parsing the whole
    prefix — resume cost is O(remaining), not O(file).
    """

    def __init__(
        self,
        path: PathLike,
        comments: Sequence[str] = ("#", "%"),
        block_lines: int = 1 << 16,
    ):
        if block_lines < 1:
            raise ValueError(f"block_lines must be >= 1, got {block_lines}")
        self.path = os.fspath(path)
        self.comments = tuple(comments)
        self._comments = tuple(c.encode() for c in comments)
        self.block_lines = block_lines
        self._n: Optional[int] = None  # cached after any full pass
        # row -> (byte offset, line number): seekable resume points
        self._resume = {0: (0, 0)}

    @property
    def n_edges(self) -> Optional[int]:
        return self._n

    def _best_resume(self, start: int) -> tuple:
        row = max(r for r in self._resume if r <= start)
        pos, lineno = self._resume[row]
        return row, pos, lineno

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        buf: List[int] = []
        row, pos, lineno = self._best_resume(start)
        with open(self.path, "rb") as f:
            f.seek(pos)
            while True:
                line = f.readline()
                if not line:
                    break
                lineno += 1
                s = line.strip()
                if not s or s.startswith(self._comments):
                    continue
                head = s[:1]
                if not (head.isdigit() or head in (b"+", b"-")):
                    continue  # textual header line
                row += 1
                if row <= start:
                    continue
                parts = s.split(maxsplit=2)
                try:
                    i, j = int(parts[0]), int(parts[1])
                except (IndexError, ValueError):
                    raise ValueError(
                        f"{self.path}:{lineno}: expected an 'i j' edge "
                        f"line, got {s.decode(errors='replace')!r}"
                    ) from None
                buf.append(i)
                buf.append(j)
                if len(buf) >= 2 * self.block_lines:
                    self._resume[row] = (f.tell(), lineno)
                    yield np.array(buf, np.int32).reshape(-1, 2)
                    buf = []
        if buf:
            yield np.array(buf, np.int32).reshape(-1, 2)
        # reaching EOF pins the exact stream length wherever we started
        self._n = row

    def count_edges(self) -> int:
        if self._n is None:
            for _ in self.iter_slices(0):
                pass
        return self._n if self._n is not None else 0


class BinaryFileSource(EdgeSource):
    """mmap'd little-endian int32 ``(i, j)`` pairs; slices are zero-copy
    memmap views, so even full-batch reads never copy into the heap."""

    def __init__(self, path: PathLike, rows_per_slice: int = 1 << 20):
        self.path = os.fspath(path)
        self.rows_per_slice = rows_per_slice
        nbytes = os.path.getsize(self.path)
        if nbytes % 8:
            raise ValueError(
                f"{self.path}: size {nbytes} is not a whole number of int32 "
                "edge pairs"
            )
        self._m = nbytes // 8

    @property
    def n_edges(self) -> int:
        return self._m

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        if start >= self._m:
            return
        mm = np.memmap(self.path, dtype=np.int32, mode="r").reshape(-1, 2)
        for pos in range(start, self._m, self.rows_per_slice):
            yield mm[pos : pos + self.rows_per_slice]

    @staticmethod
    def write(path: PathLike, source: "EdgeSource | np.ndarray") -> "BinaryFileSource":
        """Stream any source (or array) to disk in this format — O(slice)
        memory."""
        src = as_source(source)
        with open(path, "wb") as f:
            for sl in src.iter_slices(0):
                np.ascontiguousarray(sl, dtype=np.int32).tofile(f)
        return BinaryFileSource(path)


# ---------------------------------------------------------------------------
# Synthetic generators
# ---------------------------------------------------------------------------

class GeneratorSource(EdgeSource):
    """Deterministic synthetic stream generated segment-by-segment.

    ``segment_fn(start, length)`` must return rows ``start .. start+length``
    of the stream as a ``(length, 2)`` array, depending only on ``start`` /
    ``length`` (e.g. seed the RNG with ``(seed, start)`` — see
    ``repro.graph.generators.chung_lu_segments``).  Determinism per absolute
    offset is what makes the stream resumable at any row and independent of
    batch size; segments are fixed at ``segment_edges`` rows so the realized
    stream never depends on how it is read.  Memory is O(segment_edges).
    """

    def __init__(
        self,
        segment_fn: Callable[[int, int], np.ndarray],
        n_edges: int,
        segment_edges: int = 1 << 16,
    ):
        if n_edges < 0:
            raise ValueError(f"n_edges must be >= 0, got {n_edges}")
        if segment_edges < 1:
            raise ValueError(f"segment_edges must be >= 1, got {segment_edges}")
        self.segment_fn = segment_fn
        self._m = int(n_edges)
        self.segment_edges = segment_edges

    @property
    def n_edges(self) -> int:
        return self._m

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        seg = self.segment_edges
        for seg_start in range((start // seg) * seg, self._m, seg):
            length = min(seg, self._m - seg_start)
            arr = np.asarray(self.segment_fn(seg_start, length), np.int32)
            if arr.shape != (length, 2):
                raise ValueError(
                    f"segment_fn({seg_start}, {length}) returned shape "
                    f"{arr.shape}, expected ({length}, 2)"
                )
            if seg_start < start:
                arr = arr[start - seg_start :]
            if arr.shape[0]:
                yield arr


# ---------------------------------------------------------------------------
# Sharding (distributed tier)
# ---------------------------------------------------------------------------

class _WindowSource(EdgeSource):
    """A contiguous ``[start, start + length)`` raw-row window of a base
    source (one shard of a :class:`ShardedSource`)."""

    def __init__(self, base: EdgeSource, start: int, length: int):
        self.base = base
        self.start = start
        self.length = length

    @property
    def n_edges(self) -> int:
        return self.length

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        remaining = self.length - start
        if remaining <= 0:
            return
        for sl in self.base.iter_slices(self.start + start):
            if sl.shape[0] >= remaining:
                yield sl[:remaining]
                return
            remaining -= sl.shape[0]
            yield sl


class ShardedSource(EdgeSource):
    """Contiguous split of a stream into ``n_shards`` equal windows.

    Contiguous (not strided) so each shard preserves the stream order of its
    slice — the paper's streaming argument ("early edges are
    intra-community") applies within every shard.  Requires a known or
    countable stream length (text sources pay one counting pass).
    """

    def __init__(self, base: EdgeSource, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.base = base
        self.n_shards = n_shards
        self._m = base.count_edges()
        self.shard_len = -(-self._m // n_shards) if self._m else 1

    @property
    def n_edges(self) -> int:
        return self._m

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        return self.base.iter_slices(start)

    def shards(self) -> List[EdgeSource]:
        L = self.shard_len
        return [
            _WindowSource(self.base, s * L, max(0, min(L, self._m - s * L)))
            for s in range(self.n_shards)
        ]

    def stacked(self) -> np.ndarray:
        """The ``(n_shards, shard_len, 2)`` PAD-padded stack — O(m) output.

        Reference implementation only (kept for its unit test against the
        vectorized ``shard_stream``): the distributed tier now drains
        :meth:`shards` window by window through the chunked tier's
        ``partial_fit``, so no production path materializes this array.
        """
        L = self.shard_len
        out = np.full((self.n_shards * L, 2), PAD, dtype=np.int32)
        pos = 0
        for sl in self.base.iter_slices(0):
            out[pos : pos + sl.shape[0]] = sl
            pos += sl.shape[0]
        return out.reshape(self.n_shards, L, 2)


# ---------------------------------------------------------------------------
# Coercion
# ---------------------------------------------------------------------------

def as_source(edges) -> EdgeSource:
    """Coerce the public API's ``edges`` argument to an :class:`EdgeSource`.

    Sources pass through; paths dispatch on extension (``.bin`` → mmap'd
    int32 pairs, anything else → text edge list); everything else is treated
    as an in-memory array.
    """
    if isinstance(edges, EdgeSource):
        return edges
    if isinstance(edges, (str, os.PathLike)):
        path = os.fspath(edges)
        if path.endswith(".bin"):
            return BinaryFileSource(path)
        return EdgeListFileSource(path)
    return ArraySource(edges)
