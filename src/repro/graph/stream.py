"""Edge-stream plumbing: chunking/padding to fixed shapes, device sharding.

The streaming setting (paper §2.1): the graph arrives as an ordered sequence
of edges processed strictly once.  TPUs want fixed shapes, so streams are cut
into fixed-size chunks padded with ``PAD`` sentinel edges (no-ops in every
clustering tier).

The padding primitives live in :mod:`repro.graph.pipeline` (one
implementation for host and device) — import ``pad_to_chunks`` /
``pad_edges_to_chunks`` from there; this module keeps only the
stream-memory accounting helpers and the vectorized ``shard_stream``.
"""

from __future__ import annotations

import numpy as np

from repro.graph.pipeline import PAD


def shard_stream(edges: np.ndarray, n_shards: int) -> np.ndarray:
    """Contiguous split of the stream into ``n_shards`` equal padded shards.

    Contiguous (not strided) so each shard preserves the stream order of its
    slice — the streaming argument ("early edges are intra-community") applies
    within every shard.  A single pad + reshape: shard ``s`` is rows
    ``[s * shard_len, (s + 1) * shard_len)``, with PAD only in the tail of
    the last non-empty shard.  Returns (n_shards, shard_len, 2).
    """
    edges = np.asarray(edges)
    m = edges.shape[0]
    shard_len = -(-m // n_shards) if m else 1
    out = np.full((n_shards * shard_len, 2), PAD, dtype=np.int32)
    out[:m] = edges
    return out.reshape(n_shards, shard_len, 2)


def edge_list_bytes(m: int, int_bytes: int = 8) -> int:
    """Memory to store the edge list (paper's lower bound for non-streaming)."""
    return 2 * m * int_bytes


def state_bytes(n: int, int_bytes: int = 4) -> int:
    """The streaming state: exactly three integers per node."""
    return 3 * n * int_bytes
