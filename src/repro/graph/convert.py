"""Edge-stream transcoder CLI: any source format -> any codec.

    python -m repro.graph.convert IN OUT [--codec raw|dvc]
                                         [--block-edges N] [--quiet]

``IN`` is anything :func:`repro.graph.sources.as_source` accepts — a SNAP
text edge list, a raw ``.bin``, or a ``.dvc`` compressed stream (sniffed by
magic, then suffix).  ``OUT`` is written through the chosen codec
(defaulting to ``OUT``'s suffix: ``.dvc`` → delta+varint, else raw) with
O(block) memory, preserving stream order exactly — a transcoded file
clusters bit-identically to its source.

Prints a one-line summary: edges, output bytes/edge, the compression ratio
against raw fixed-width (8 B/edge), and encode throughput in raw-equivalent
MB/s.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

from repro.graph.codecs import (
    CODECS,
    DeltaVarintCodec,
    default_codec_for_path,
    get_codec,
)
from repro.graph.sources import CodecFileSource, as_source


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.graph.convert",
        description="Transcode an edge stream between codecs "
        "(order-preserving, O(block) memory).",
    )
    ap.add_argument("input", help="edge stream: text edge list, .bin, or .dvc")
    ap.add_argument("output", help="output path")
    ap.add_argument(
        "--codec",
        choices=sorted(CODECS),
        default=None,
        help="output codec (default: by output suffix; .dvc -> dvc, else raw)",
    )
    ap.add_argument(
        "--block-edges",
        type=int,
        default=None,
        help="edges per compressed sync block (dvc only; default 65536)",
    )
    ap.add_argument("--quiet", action="store_true", help="suppress the summary")
    args = ap.parse_args(argv)

    codec = (
        get_codec(args.codec)
        if args.codec is not None
        else default_codec_for_path(args.output)
    )
    if args.block_edges is not None:
        # tunes an already-selected dvc codec; never changes the format
        if not isinstance(codec, DeltaVarintCodec):
            ap.error(
                f"--block-edges only applies to the dvc codec (resolved "
                f"codec: {codec.name})"
            )
        codec = DeltaVarintCodec(block_edges=args.block_edges)

    t0 = time.time()
    # CodecFileSource.write owns the write-then-rename torn-output
    # protection — one home for the atomicity rule
    rows = CodecFileSource.write(args.output, as_source(args.input), codec).n_edges
    dt = time.time() - t0

    if not args.quiet:
        out_bytes = os.path.getsize(args.output)
        raw_bytes = 8 * rows
        bpe = out_bytes / rows if rows else float("nan")
        print(
            f"{args.output}: {rows} edges, {out_bytes} B "
            f"({bpe:.2f} B/edge, {out_bytes / raw_bytes if rows else 0:.3f}x "
            f"raw), codec={codec.name}, "
            f"{raw_bytes / dt / 1e6 if dt else 0:.0f} MB/s raw-equivalent",
            file=sys.stderr,
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
