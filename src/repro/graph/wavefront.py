"""Host-side wave planner for the conflict-free wavefront kernel path.

The bit-exact tiers apply edges strictly one at a time; the wavefront
subsystem (DESIGN.md §12) recovers vector parallelism *without* giving up
the paper's sequential semantics.  Two edges commute iff they touch
disjoint state cells: ``d``/``c`` are node-indexed (node-disjointness
covers them) while ``v`` and the join decisions read *community* volumes —
which the host cannot know, because communities are rewritten by the very
edges being planned.  The split of responsibilities is therefore:

* **planner (here, host, prefetch thread)** — segment the stream into
  *waves*: maximal contiguous runs of node-disjoint edges.  A wave closes
  when the next edge repeats an endpoint already stamped in the current
  wave's scoreboard, or when the wave reaches the configured width.
  Contiguity is what preserves bit-exactness: edges are never reordered,
  only grouped, so "apply wave ``w`` atomically" is exactly the sequential
  order whenever the within-wave vector step itself is exact.
* **kernel (device, apply time)** — per wave, a runtime community-
  disjointness check against the *live* ``(c, v)`` state decides whether
  the vectorised apply is exact; colliding waves fall back to the
  sequential per-edge loop (``repro.core.wavefront``).

The emitted :class:`WavePlan` has fixed shapes that depend only on
``(K * B, width)`` — one device compile per run:

* ``waves``: ``(n_waves_max, width, 2)`` int32, wave ``w``'s live rows in
  slots ``[0, counts[w])``, PAD elsewhere; unused trailing waves are
  all-PAD (carved from the shared PAD template, no per-plan ``np.full``).
* ``leftover``: ``(K * B, 2)`` int32 — the uncovered stream *suffix* when
  the wave budget (``slack * ceil(M / width)`` waves) runs out; processed
  sequentially after the waves.  A zero-copy PAD-template view in the
  common case where every row was planned.
* ``meta``: ``[n_waves_used, leftover_rows]`` int32 — traced loop bounds
  for the kernel (skip trailing all-PAD waves without recompiling).

Every wave holds at least one row (an edge never conflicts with itself),
so ``slack >= 1`` guarantees forward progress and ``slack = s`` covers any
stream whose mean wave width is at least ``width / s``.  Slack costs
*staging memory only*: both apply paths loop over ``meta[0]`` used waves,
never the full budget, so the default is a generous 4 — real streams close
waves early around hub nodes, and a sequential leftover is the one thing
that can sink the speedup.  Trailing dead rows (PAD padding, self-loops at
the very end) are trimmed — they constrain nothing and would only spend
wave slots.

Dead-gap merging (``gap``): historically *interior* dead rows (PAD rows,
self-loops) occupied wave slots — harmless for bit-exactness (they are
no-ops in every apply path) but ruinous for occupancy on PAD-interleaved
streams such as ragged megabatch tails or fleet-style staging, where a
mostly-dead batch burns a full wave per ``width`` dead rows.  With ``gap``
set, waves pack only *live* rows: contiguous live runs are merged across
interior dead gaps of up to ``gap`` rows, a longer gap closes the wave,
and the skipped dead rows are dropped from staging entirely (counted in
``dead_rows_skipped``).  Correctness is unchanged — dead rows commute with
everything, so removing them never reorders live work — and the leftover
suffix is still carved from the raw stream, so the sequential fallback
path needs no new logic.  ``gap=None`` (the default) preserves the
historical plans bit-for-bit.
"""

from __future__ import annotations

import time
from typing import NamedTuple, Optional

import numpy as np

from repro.graph.pipeline import PAD, pad_template


class WavePlan(NamedTuple):
    """A fixed-shape wavefront schedule for one megabatch (host arrays)."""

    waves: np.ndarray  # (n_waves_max, width, 2) int32, PAD-padded
    counts: np.ndarray  # (n_waves_max,) int32 rows staged per wave
    leftover: np.ndarray  # (M, 2) int32 uncovered suffix (PAD-padded)
    meta: np.ndarray  # (2,) int32 [n_waves_used, leftover_rows]
    n_waves: int  # waves actually used (<= waves.shape[0])
    rows_in_waves: int  # stream rows staged into waves
    leftover_rows: int  # stream rows in the sequential leftover suffix
    plan_seconds: float  # host planning time (the overhead counter)
    nbytes: int  # bytes of *owned* buffers (template views excluded)
    dead_rows_skipped: int = 0  # interior dead rows dropped from staging
    #   (gap mode only; 0 for gap=None historical plans)
    width: int = 0  # wave width this plan was laid out at — the fixed W, or
    #   the per-megabatch width ``plan_waves(..., "auto")`` chose

    @property
    def mean_wave_width(self) -> float:
        return self.rows_in_waves / self.n_waves if self.n_waves else 0.0


def _prev_conflict(flat: np.ndarray, live: np.ndarray) -> np.ndarray:
    """For each row ``e``: the largest row index ``p < e`` sharing an
    endpoint with ``e`` (-1 if none, and for dead rows).  Vectorised: one
    lexsort over the (node, row) incidence pairs, then a scatter-max of
    each pair's same-node predecessor row."""
    M = flat.shape[0]
    p = np.full(M, -1, np.int64)
    le = np.flatnonzero(live)
    if le.size == 0:
        return p
    nodes = np.concatenate([flat[le, 0], flat[le, 1]]).astype(np.int64)
    eids = np.concatenate([le, le])
    order = np.lexsort((eids, nodes))
    sn, se = nodes[order], eids[order]
    same = sn[1:] == sn[:-1]
    prev = np.where(same, se[:-1], -1)
    np.maximum.at(p, se[1:], prev)
    return p


# Adaptive-width clamp: powers of two in [8, 1024], so an "auto" run
# compiles at most 8 distinct device shapes however the stream's structure
# drifts between megabatches.
_AUTO_WIDTH_MIN = 8
_AUTO_WIDTH_MAX = 1024


def _auto_width(p: np.ndarray, live_idx: np.ndarray) -> int:
    """Pick a wave width from the observed live-run-length structure.

    ``p[e] = `` the nearest earlier row sharing an endpoint with ``e``, so
    ``g = e - p[e]`` (over constrained live rows) is the largest width at
    which row ``e`` does *not* close a wave opened within ``g`` rows — the
    per-row run-length scale of the stream.  The median of that histogram
    is the width half the conflicts won't bind at: wider mostly burns
    staging and vector lanes on early-closed waves, narrower splits runs
    that were free.  Rounded up to a power of two and clamped so device
    shapes stay enumerable.
    """
    if live_idx.size == 0:
        return _AUTO_WIDTH_MIN
    pl = p[live_idx]
    constrained = pl >= 0
    if not constrained.any():
        return _AUTO_WIDTH_MAX  # node-disjoint stream: nothing ever closes
    g = live_idx[constrained] - pl[constrained]
    w = int(np.median(g))
    w = max(_AUTO_WIDTH_MIN, min(_AUTO_WIDTH_MAX, w))
    return 1 << (w - 1).bit_length()


def plan_waves(
    edges: np.ndarray,
    width,
    *,
    slack: int = 4,
    gap: Optional[int] = None,
) -> WavePlan:
    """Greedily color a (mega)batch into contiguous node-disjoint waves.

    ``edges`` is any ``(..., 2)`` int stream (a ``(K, B, 2)`` megabatch or
    a flat ``(m, 2)`` batch) — flattened in stream order.  ``width`` caps
    rows per wave — an int, or ``"auto"`` to pick a per-megabatch width
    from the observed live-run-length histogram (:func:`_auto_width`;
    integer widths plan bit-for-bit as they always have).  ``slack``
    scales the fixed wave budget; ``gap`` (module docstring) packs only
    live rows, merging runs across interior dead gaps of at most ``gap``
    rows.  Stateless per call: planning depends only on the rows handed
    in, never on cluster state, so checkpoints/cursors are untouched by
    wavefront mode.
    """
    auto = isinstance(width, str)
    if auto and width != "auto":
        raise ValueError(f"wavefront width must be an int or 'auto', got {width!r}")
    if not auto and width < 1:
        raise ValueError(f"wavefront width must be >= 1, got {width}")
    if slack < 1:
        raise ValueError(f"wavefront slack must be >= 1, got {slack}")
    if gap is not None and gap < 0:
        raise ValueError(f"wavefront gap must be >= 0, got {gap}")
    t0 = time.perf_counter()
    flat = np.ascontiguousarray(np.asarray(edges, np.int32).reshape(-1, 2))
    M = flat.shape[0]

    live = (flat[:, 0] != PAD) & (flat[:, 1] != PAD) & (flat[:, 0] != flat[:, 1])
    live_idx = np.flatnonzero(live)
    # trailing dead rows (PAD tails, trailing self-loops) constrain nothing
    m_eff = int(live_idx[-1]) + 1 if live_idx.size else 0
    p = _prev_conflict(flat[:m_eff], live[:m_eff])
    if auto:
        width = _auto_width(p, live_idx)
    n_waves_max = max(1, slack * -(-M // width))

    waves = np.empty((n_waves_max, width, 2), np.int32)
    counts = np.zeros(n_waves_max, np.int32)
    s = 0  # stream rows covered (waves + skipped interior dead rows)
    w = 0
    dead_rows_skipped = 0
    rows_in_waves = 0
    if gap is None:
        # historical contiguous planning: dead rows occupy wave slots
        while s < m_eff and w < n_waves_max:
            hi = min(s + width, m_eff)
            # the wave ends at the first row conflicting with a row >= s; a
            # row never conflicts with itself (p[e] < e), so cnt >= 1 always
            bad = np.flatnonzero(p[s:hi] >= s)
            cnt = int(bad[0]) if bad.size else hi - s
            waves[w, :cnt] = flat[s : s + cnt]
            if cnt < width:
                waves[w, cnt:] = pad_template(width - cnt)
            counts[w] = cnt
            s += cnt
            w += 1
        rows_in_waves = s
    else:
        # gap mode: waves take *consecutive live rows*, so the in-wave
        # conflict test is unchanged — every live row in [seg[0], e) is in
        # the wave, dead rows between them constrain nothing
        li = 0
        L = live_idx.size
        while li < L and w < n_waves_max:
            seg = live_idx[li : li + width]
            # close at the first live row whose dead gap from its
            # predecessor exceeds the budget, or that conflicts in-wave
            brk = np.flatnonzero(
                (np.diff(seg) - 1 > gap) | (p[seg[1:]] >= seg[0])
            )
            cnt = int(brk[0]) + 1 if brk.size else int(seg.size)
            waves[w, :cnt] = flat[seg[:cnt]]
            if cnt < width:
                waves[w, cnt:] = pad_template(width - cnt)
            counts[w] = cnt
            li += cnt
            w += 1
        s = m_eff if li >= L else int(live_idx[li])
        rows_in_waves = li
        dead_rows_skipped = s - li
    if w < n_waves_max:
        waves[w:] = pad_template((n_waves_max - w) * width).reshape(-1, width, 2)

    leftover_rows = m_eff - s
    if leftover_rows:
        leftover = np.empty((M, 2), np.int32)
        leftover[:leftover_rows] = flat[s:m_eff]
        leftover[leftover_rows:] = pad_template(M - leftover_rows)
        owned = leftover.nbytes
    else:
        leftover = pad_template(M)  # zero-copy: nothing was left over
        owned = 0
    meta = np.array([w, leftover_rows], np.int32)
    return WavePlan(
        waves=waves,
        counts=counts,
        leftover=leftover,
        meta=meta,
        n_waves=w,
        rows_in_waves=rows_in_waves,
        leftover_rows=leftover_rows,
        plan_seconds=time.perf_counter() - t0,
        nbytes=waves.nbytes + counts.nbytes + meta.nbytes + owned,
        dead_rows_skipped=dead_rows_skipped,
        width=int(width),
    )
