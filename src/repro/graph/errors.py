"""Typed ingest failure taxonomy and the bounded retry policy.

The one-pass model makes ingest failures uniquely costly: an edge the
stream never delivers can never be re-read, so every failure either
recovers exactly (retry + resume from the cursor) or degrades
*accountably* (quarantine + counted loss).  This module is the shared
vocabulary for that contract — it has no dependencies on the rest of
``repro.graph`` so codecs, sources, the pipeline, and the fault
injectors can all import it without cycles.

Error classes
-------------

``CorruptStreamError`` (a ``ValueError``) covers data-level damage: the
bytes arrived but decode cannot trust them.  ``TruncatedStreamError``
(file shorter than its framing declares) and ``CorruptBlockError``
(per-block checksum mismatch) narrow it.  These are *not* retryable —
re-reading the same bytes reproduces the same damage.

``TransientReadError`` (an ``OSError``) marks failures worth retrying:
the bytes may well arrive on the next attempt.  ``RetryPolicy`` treats
any ``OSError`` as transient by default.  ``SourceDeadError`` is the
opposite verdict — the source is gone for good (mid-stream death,
deleted feed) — and deliberately subclasses ``RuntimeError`` so the
default policy never spins on it.

``StallError`` (a ``TimeoutError``) is raised by the prefetch watchdog
when a single produce exceeds the configured hard timeout.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Tuple, Type


class CorruptStreamError(ValueError):
    """Stream bytes are present but cannot be trusted (bad framing,
    checksum mismatch, undecodable varints).  Not retryable."""


class TruncatedStreamError(CorruptStreamError):
    """The file ends before its own framing says it should."""


class CorruptBlockError(CorruptStreamError):
    """A codec block failed its checksum (or lost framing) — the block's
    rows are unrecoverable, though later blocks may resync."""


class TransientReadError(OSError):
    """A read failure that may succeed on retry (flaky filesystem, NFS
    hiccup, injected chaos).  Retryable under the default policy."""


class SourceDeadError(RuntimeError):
    """The source is permanently gone mid-stream; retrying is useless.
    Fleet routers quarantine the tenant instead of retrying."""


class StallError(TimeoutError):
    """The prefetch producer exceeded the hard stall timeout."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with capped exponential backoff, per error class.

    ``retryable`` names the exception classes worth re-attempting;
    everything else propagates immediately.  ``max_retries`` bounds the
    *consecutive* failed attempts for one fault — a successful read
    resets the counter, so a long stream tolerates many independent
    transients while a hard failure still surfaces after a bounded
    number of attempts.  Backoff for attempt ``k`` (1-based) is
    ``min(backoff_cap, backoff_base * 2**(k-1))`` seconds.
    """

    max_retries: int = 3
    backoff_base: float = 0.01
    backoff_cap: float = 1.0
    retryable: Tuple[Type[BaseException], ...] = (TransientReadError, OSError)
    sleep: Callable[[float], None] = time.sleep

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base < 0 or self.backoff_cap < 0:
            raise ValueError("backoff_base/backoff_cap must be >= 0")

    def is_retryable(self, exc: BaseException) -> bool:
        return isinstance(exc, self.retryable) and not isinstance(
            exc, SourceDeadError
        )

    def delay(self, attempt: int) -> float:
        """Backoff before the ``attempt``-th retry (1-based)."""
        return min(self.backoff_cap, self.backoff_base * (2.0 ** (attempt - 1)))

    def backoff(self, attempt: int) -> None:
        d = self.delay(attempt)
        if d > 0:
            self.sleep(d)


def retrying_slices(resume, cursor_at, cursor, policy, on_retry=None):
    """Iterate ``resume(cursor)`` with bounded re-resume on transient
    errors.

    Every row-resumable source can turn a retry into a re-resume: we
    track how many rows have been yielded, and on a retryable failure
    re-open the iterator at ``cursor_at(row)`` after backoff.  Yielded
    slices are never repeated and never skipped, so a stream that
    survives its transients is bit-identical to a fault-free one.

    ``resume`` takes a cursor and returns a slice iterator; ``cursor_at``
    takes a row and mints the best cursor for it.  ``on_retry(attempt,
    exc)`` is called before each backoff (counters, logging).
    Non-retryable errors and exhausted budgets propagate.
    """
    row = int(cursor.row)
    it = resume(cursor)
    attempt = 0
    try:
        while True:
            try:
                sl = next(it)
            except StopIteration:
                return
            except Exception as exc:
                if not policy.is_retryable(exc) or attempt >= policy.max_retries:
                    raise
                attempt += 1
                if on_retry is not None:
                    on_retry(attempt, exc)
                close = getattr(it, "close", None)
                if close is not None:
                    try:
                        close()
                    except Exception:
                        pass
                policy.backoff(attempt)
                it = resume(cursor_at(row))
                continue
            attempt = 0
            n = int(sl.shape[0]) if hasattr(sl, "shape") else len(sl)
            row += n
            if n:
                yield sl
    finally:
        close = getattr(it, "close", None)
        if close is not None:
            close()
