"""Tenant-keyed demux: many per-tenant edge streams → one fleet slab.

The fleet engine (DESIGN.md §13) advances ``T`` independent tenant streams
with one device dispatch per fleet step.  :class:`TenantRouter` is the
ingest half: it drains ``T`` per-tenant :class:`~repro.graph.sources
.EdgeSource`\\ s under a :class:`~repro.graph.sources.MergedSource`-style
deterministic arrival schedule and carves their rows, *per tenant*, into a
``(T, B, 2)`` PAD-template staging buffer (:class:`FleetSlab`) on the
prefetch thread.

The batch-boundary contract — the router's half of the fleet bit-identity
guarantee (``repro.core.fleet``) — is:

* tenant ``t``'s dispatched slabs, concatenated, are exactly its stream;
* every dispatched slab holds a *full* ``B``-row batch, except the final
  slab once tenant ``t``'s source is exhausted, which may be short.

That is precisely the batch sequence a standalone single-stream
``BatchPipeline(source_t, B)`` yields, so each tenant's labels are
bit-identical to its standalone run no matter how slabs were grouped into
fleet steps.  Tenants with no full batch pending in a step get an all-PAD
row (a true no-op in every fleet update path) — the ragged-fleet case.

Arrival schedule: tenant ``t``'s ``r``-th row arrives at virtual time
``r / rates[t]`` and rows are pulled in ``granule``-row turns, the schedule
:class:`MergedSource` uses.  A fleet step is emitted once every unfinished
tenant either has a full batch pending or is exhausted, and a tenant with a
full batch pending is never pulled further (bounded pending memory).  That
skip rule makes each tenant's pre-emit need *independent* — the set of
turns pulled before an emit is the same whatever order the schedule visits
tenants in — so slab content is rate-independent and the router pulls in
tenant index order with a vectorised needy-tenant scan (an O(T) argmin per
turn would cost O(T²) per fleet step and sink thousand-tenant fleets;
``rates`` stay as pacing metadata for future partial-batch emission).  The
producer is a pure function of the per-tenant *dispatched-row* vector: the
whole fleet suspends/resumes from just that ``(T,)`` vector (one checkpoint
leaf — rows pulled but not yet dispatched are simply re-pulled on resume;
the per-tenant slab sequences, and therefore all labels, are unchanged).
"""

from __future__ import annotations

from typing import Dict, Iterator, List, NamedTuple, Optional, Sequence

import numpy as np

from repro.graph.errors import RetryPolicy
from repro.graph.pipeline import _prefetch_iter, pad_template, round_up
from repro.graph.sources import EdgeSource, _SlicePuller, as_source


class FleetSlab(NamedTuple):
    """One fleet step's staged ingest: a fixed-shape ``(T, B, 2)`` buffer.

    Row ``t`` holds tenant ``t``'s next batch (PAD tail for a short final
    batch) or all-PAD if the tenant has nothing to dispatch this step.
    """

    edges: np.ndarray  # (T, B, 2) int32, PAD-padded
    n_rows: np.ndarray  # (T,) int64 raw rows dispatched per tenant
    offsets: np.ndarray  # (T,) int64 rows dispatched per tenant before this
    active: int  # tenants with >= 1 real row in this slab


class TenantRouter:
    """Demux ``T`` per-tenant sources into fixed-shape fleet slabs.

    ``batch_edges`` is rounded up to ``pad_multiple`` (the Jacobi/DMA chunk
    of chunk-aligned fleet backends), exactly like ``BatchPipeline``.
    Staging runs on a background prefetch thread (``prefetch`` slabs ahead)
    so per-tenant parsing/generation/decoding overlaps the device's fleet
    dispatch; ``peak_staging_bytes`` tracks staged buffers plus pulled-but-
    undispatched pending rows.

    **Tenant isolation** (DESIGN.md §15): with ``on_fault="quarantine"`` a
    tenant whose source fails for good mid-stream — dead source, corrupt
    stream, exhausted retry budget — is *quarantined* instead of killing
    the fleet: its already-arrived rows dispatch as its short final batch,
    after which its slab row is all-PAD (a true no-op in every fleet update
    path), and the failure is recorded in :attr:`quarantined`.  The other
    ``T-1`` tenants' slab sequences are untouched (the skip rule makes
    per-tenant pulls independent), so survivors stay bit-identical to their
    standalone runs.  ``on_fault="raise"`` (default) propagates the first
    tenant failure.  ``retry`` bounds transient re-pulls per tenant before
    a failure counts as final.
    """

    def __init__(
        self,
        sources: Sequence,
        batch_edges: int,
        *,
        rates: Optional[Sequence[int]] = None,
        granule: Optional[int] = None,
        pad_multiple: int = 1,
        prefetch: int = 2,
        on_fault: str = "raise",
        retry: Optional[RetryPolicy] = None,
    ):
        if on_fault not in ("raise", "quarantine"):
            raise ValueError(
                f"on_fault must be 'raise' or 'quarantine', got {on_fault!r}"
            )
        if not sources:
            raise ValueError("TenantRouter needs at least one tenant source")
        if batch_edges < 1:
            raise ValueError(f"batch_edges must be >= 1, got {batch_edges}")
        if pad_multiple < 1:
            raise ValueError(f"pad_multiple must be >= 1, got {pad_multiple}")
        self.sources: List[EdgeSource] = [as_source(s) for s in sources]
        self.batch_edges = round_up(batch_edges, pad_multiple)
        if rates is None:
            rates = [1] * len(self.sources)
        if len(rates) != len(self.sources):
            raise ValueError(
                f"{len(rates)} rates for {len(self.sources)} tenants"
            )
        self.rates = [int(w) for w in rates]
        if any(w < 1 for w in self.rates):
            raise ValueError(f"rates must be positive ints, got {rates}")
        if granule is None:
            granule = self.batch_edges
        if granule < 1:
            raise ValueError(f"granule must be >= 1, got {granule}")
        self.granule = int(granule)
        self.prefetch = max(0, int(prefetch))
        self.on_fault = on_fault
        self.retry = retry
        self._ms = [int(s.count_edges()) for s in self.sources]
        self.peak_staging_bytes = 0
        self.slabs_produced = 0
        self._inflight_bytes = 0
        # tenant index -> "ErrorType: message" for every quarantined tenant,
        # and total transient re-pulls across all tenants' pullers
        self.quarantined: Dict[int, str] = {}
        self.retries = 0

    # ------------------------------------------------------------------
    @property
    def tenants(self) -> int:
        return len(self.sources)

    def count_edges(self) -> List[int]:
        """Per-tenant stream lengths (rows)."""
        return list(self._ms)

    def _acquire(self, nbytes: int) -> None:
        self._inflight_bytes += nbytes
        if self._inflight_bytes > self.peak_staging_bytes:
            self.peak_staging_bytes = self._inflight_bytes

    def _release(self, nbytes: int) -> None:
        self._inflight_bytes -= nbytes

    def _turn_remainder(self, a, t: int) -> int:
        """Rows left in tenant ``t``'s current ``granule`` turn (a partial
        turn is only possible immediately after a mid-turn resume)."""
        turn_start = (a[t] // self.granule) * self.granule
        take = min(self.granule, self._ms[t] - turn_start)
        return turn_start + take - a[t]

    # ------------------------------------------------------------------
    def _produce(self, start_rows: np.ndarray) -> Iterator[FleetSlab]:
        """Raw slab producer — runs entirely on the prefetch thread."""
        T = len(self.sources)
        B = self.batch_edges
        r = np.asarray(start_rows, np.int64).copy()  # dispatched per tenant
        ms = np.asarray(self._ms, np.int64)
        for t in range(T):
            if r[t] < 0 or r[t] > ms[t]:
                raise ValueError(
                    f"tenant {t} resume row {r[t]} outside [0, {ms[t]}]"
                )
        a = r.copy()  # arrived rows per tenant (dispatched + pending)
        pending: List[List[np.ndarray]] = [[] for _ in range(T)]
        have = np.zeros(T, np.int64)
        pullers: List[Optional[_SlicePuller]] = [None] * T
        try:
            while True:
                # Pull turns until every unfinished tenant has a full batch
                # pending (or its stream ended).  Index order, not schedule
                # order: the ready-skip rule makes the pulled turn set
                # order-independent (module docstring), and the vectorised
                # needy scan keeps the step O(T), not O(T^2).
                while True:
                    need = np.flatnonzero((have < B) & (a < ms))
                    if need.size == 0:
                        break
                    for t in need:
                        t = int(t)
                        while have[t] < B and a[t] < ms[t]:
                            take = self._turn_remainder(a, t)
                            try:
                                if pullers[t] is None:
                                    pullers[t] = _SlicePuller(
                                        self.sources[t],
                                        int(a[t]),
                                        retry=self.retry,
                                    )
                                sl = np.asarray(pullers[t].take(take))
                            except Exception as exc:
                                if self.on_fault != "quarantine":
                                    raise
                                # Tenant isolation: this source is gone for
                                # good (dead, corrupt, retries exhausted).
                                # Clamp its stream at the rows already
                                # arrived — the pending rows dispatch as its
                                # short final batch, after which its slab
                                # row is all-PAD; the other tenants' pull
                                # sets are unchanged (skip rule), so their
                                # slabs stay bit-identical.
                                self.quarantined[t] = (
                                    f"{type(exc).__name__}: {exc}"
                                )
                                ms[t] = a[t]
                                break
                            self._acquire(int(sl.nbytes))
                            pending[t].append(sl)
                            have[t] += take
                            a[t] += take

                # Emit one fleet step: a full batch from every ready
                # tenant, the short final batch from exhausted tenants,
                # all-PAD rows for the rest.
                takes = [0] * T
                for t in range(T):
                    if have[t] >= B:
                        takes[t] = B
                    elif a[t] >= ms[t] and have[t] > 0:
                        takes[t] = int(have[t])  # t's final short batch
                if not any(takes):
                    return  # every tenant exhausted and drained
                buf = np.empty((T, B, 2), np.int32)
                self._acquire(buf.nbytes)
                for t in range(T):
                    k = takes[t]
                    if k < B:
                        buf[t, k:] = pad_template(B - k)
                    if k == 0:
                        continue
                    pos = 0
                    rest: List[np.ndarray] = []
                    for sl in pending[t]:
                        if pos >= k:
                            rest.append(sl)
                            continue
                        use = min(k - pos, sl.shape[0])
                        buf[t, pos : pos + use] = sl[:use]
                        pos += use
                        if use < sl.shape[0]:
                            tail = sl[use:]
                            rest.append(tail)
                            # release only the consumed prefix; the tail view
                            # stays counted until it is dispatched
                            self._release(int(sl.nbytes) - int(tail.nbytes))
                        else:
                            self._release(int(sl.nbytes))
                    pending[t] = rest
                    have[t] -= k
                yield FleetSlab(
                    edges=buf,
                    n_rows=np.asarray(takes, np.int64),
                    offsets=r.copy(),
                    active=sum(1 for k in takes if k),
                )
                r += np.asarray(takes, np.int64)
        finally:
            for sl_list in pending:
                for sl in sl_list:
                    self._release(int(sl.nbytes))
            for p in pullers:
                if p is not None:
                    self.retries += p.retries
                    p.close()

    def fleet_slabs(
        self, start_rows: Optional[Sequence[int]] = None
    ) -> Iterator[FleetSlab]:
        """Yield fleet slabs from a per-tenant dispatched-row vector
        (all-zeros for a fresh run; a restored checkpoint's ``tenant_rows``
        leaf to resume)."""
        if start_rows is None:
            start_rows = np.zeros(len(self.sources), np.int64)
        start_rows = np.asarray(start_rows, np.int64)
        if start_rows.shape != (len(self.sources),):
            raise ValueError(
                f"start_rows must have shape ({len(self.sources)},), "
                f"got {start_rows.shape}"
            )
        inner = _prefetch_iter(
            self._produce(start_rows),
            self.prefetch,
            on_drop=lambda s: self._release(s.edges.nbytes),
        )
        prev: Optional[FleetSlab] = None
        try:
            for slab in inner:
                if prev is not None:
                    self._release(prev.edges.nbytes)
                prev = slab
                self.slabs_produced += 1
                yield slab
        finally:
            if prev is not None:
                self._release(prev.edges.nbytes)
            inner.close()
