"""Deterministic fault injection for chaos-testing the ingest stack.

Chaos tests are only CI-stable if the chaos itself is reproducible: every
fault this module injects — transient read errors, latency stalls, hard
mid-stream source death, bit-flipped or truncated codec blocks — is drawn
from a seeded :class:`numpy.random.Generator`, so the same seed plants the
same faults at the same stream rows / file bytes on every run.

Three layers:

* :class:`FaultInjector` — turns ``(seed, counts)`` into a concrete
  :class:`FaultPlan` (sorted fault rows) for a stream of known length.
* :class:`ChaosSource` — wraps any :class:`~repro.graph.sources.EdgeSource`
  and executes a plan *without ever changing the delivered rows*: a
  transient raises :class:`~repro.graph.errors.TransientReadError` exactly
  once at its planned row (a retrying reader that re-resumes at the failure
  row sees a bit-identical stream), a stall sleeps, and ``die_row`` makes
  the source permanently raise
  :class:`~repro.graph.errors.SourceDeadError`.
* File corruptors — :func:`list_blocks`, :func:`corrupt_blocks`,
  :func:`truncate_blocks` operate on *checksummed* ``.dvc`` files (``DVX``
  framing) and return the exact planted loss in rows, so tests can assert
  ``edges_lost`` equals the plan to the edge.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.graph.codecs import Cursor, DeltaVarintCodec, as_cursor
from repro.graph.errors import (  # noqa: F401  (re-exported chaos vocabulary)
    CorruptBlockError,
    CorruptStreamError,
    RetryPolicy,
    SourceDeadError,
    StallError,
    TransientReadError,
    TruncatedStreamError,
)
from repro.graph.sources import EdgeSource


# ---------------------------------------------------------------------------
# Seeded fault plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A concrete, fully deterministic fault schedule over a row span.

    ``transients``/``stalls`` are stream rows *before which* the fault
    fires (each exactly once); ``die_row`` is the row at which the source
    dies for good (every read at or after it — including retries — raises
    :class:`SourceDeadError`)."""

    transients: Tuple[int, ...] = ()
    stalls: Tuple[int, ...] = ()
    die_row: Optional[int] = None
    stall_seconds: float = 0.05

    def __post_init__(self):
        if any(r < 0 for r in self.transients + self.stalls):
            raise ValueError("fault rows must be >= 0")
        if self.die_row is not None and self.die_row < 0:
            raise ValueError(f"die_row must be >= 0, got {self.die_row}")


class FaultInjector:
    """Seed-driven fault planner.

    ``plan(n_rows)`` draws the requested number of transient / stall rows
    (and optionally a death row) uniformly over ``[1, n_rows)`` from
    ``np.random.default_rng(seed)`` — same seed, same plan, every time.
    """

    def __init__(
        self,
        seed: int,
        *,
        transients: int = 0,
        stalls: int = 0,
        stall_seconds: float = 0.05,
        die: bool = False,
    ):
        if transients < 0 or stalls < 0:
            raise ValueError("fault counts must be >= 0")
        self.seed = int(seed)
        self.transients = int(transients)
        self.stalls = int(stalls)
        self.stall_seconds = float(stall_seconds)
        self.die = bool(die)

    def plan(self, n_rows: int) -> FaultPlan:
        if n_rows < 2:
            raise ValueError(f"need n_rows >= 2 to place faults, got {n_rows}")
        rng = np.random.default_rng(self.seed)
        need = self.transients + self.stalls + (1 if self.die else 0)
        rows = (
            rng.choice(np.arange(1, n_rows), size=need, replace=False)
            if need
            else np.empty(0, np.int64)
        )
        t = tuple(sorted(int(r) for r in rows[: self.transients]))
        s = tuple(
            sorted(
                int(r)
                for r in rows[self.transients : self.transients + self.stalls]
            )
        )
        die_row = int(rows[-1]) if self.die else None
        return FaultPlan(
            transients=t,
            stalls=s,
            die_row=die_row,
            stall_seconds=self.stall_seconds,
        )


# ---------------------------------------------------------------------------
# Stream-level chaos
# ---------------------------------------------------------------------------


class ChaosSource(EdgeSource):
    """Wrap an :class:`EdgeSource` and execute a :class:`FaultPlan`.

    The wrapper never alters the rows themselves: a planned transient
    splits the in-flight slice at the fault row, yields the clean prefix,
    and raises — so a reader that retries by re-resuming at the failure
    row reconstructs the exact base stream.  Each transient/stall fires
    once per wrapper instance; ``die_row`` is permanent (the wrapped
    source is "gone").
    """

    def __init__(self, base: EdgeSource, plan: FaultPlan):
        self.base = base
        self.plan = plan
        self._pending_transients = set(plan.transients)
        self._pending_stalls = set(plan.stalls)
        self._dead = False
        self.faults_fired = 0

    # -- delegated geometry --------------------------------------------
    @property
    def n_edges(self) -> Optional[int]:
        return self.base.n_edges

    def cursor_at(self, row: int) -> Cursor:
        return self.base.cursor_at(row)

    # -- chaos walk ----------------------------------------------------
    def _next_fault(self, row: int, end: int):
        """Earliest pending fault with ``row < fault_row <= end`` (a fault
        at ``r`` fires after ``r`` rows have been delivered)."""
        hits = []
        if self._dead or (
            self.plan.die_row is not None and row >= self.plan.die_row
        ):
            # already past the death row on resume: dead immediately
            return ("die", row)
        for r in self._pending_transients:
            if row < r <= end:
                hits.append((r, "transient"))
        for r in self._pending_stalls:
            if row < r <= end:
                hits.append((r, "stall"))
        d = self.plan.die_row
        if d is not None and row < d <= end:
            hits.append((d, "die"))
        if not hits:
            return None
        r, kind = min(hits)
        return (kind, r)

    def _chaos_iter(self, it: Iterator[np.ndarray], row: int):
        try:
            for sl in it:
                sl = np.asarray(sl)
                while sl.shape[0]:
                    end = row + sl.shape[0]
                    hit = self._next_fault(row, end)
                    if hit is None:
                        yield sl
                        row = end
                        break
                    kind, r = hit
                    head, sl = sl[: r - row], sl[r - row :]
                    if head.shape[0]:
                        yield head
                    row = r
                    if kind == "transient":
                        self._pending_transients.discard(r)
                        self.faults_fired += 1
                        raise TransientReadError(
                            f"injected transient read error at row {r}"
                        )
                    if kind == "stall":
                        self._pending_stalls.discard(r)
                        self.faults_fired += 1
                        time.sleep(self.plan.stall_seconds)
                        continue
                    # kind == "die"
                    self._dead = True
                    self.faults_fired += 1
                    raise SourceDeadError(
                        f"injected source death at row {r}"
                    )
        finally:
            close = getattr(it, "close", None)
            if close is not None:
                close()

    def iter_slices(self, start: int = 0) -> Iterator[np.ndarray]:
        if self._dead:
            raise SourceDeadError("source died earlier in this stream")
        return self._chaos_iter(self.base.iter_slices(start), start)

    def resume(self, cursor) -> Iterator[np.ndarray]:
        cursor = as_cursor(cursor)
        if self._dead:
            raise SourceDeadError("source died earlier in this stream")
        return self._chaos_iter(self.base.resume(cursor), int(cursor.row))


# ---------------------------------------------------------------------------
# File-level chaos (checksummed .dvc)
# ---------------------------------------------------------------------------


def list_blocks(path) -> List[Tuple[int, int, int, int]]:
    """Walk a checksummed ``.dvc`` file and return its block table as
    ``(byte_pos, n_rows, first_row, end_byte)`` tuples (fails on plain
    unchecksummed framing — file chaos needs ``DVX`` files)."""
    codec = DeltaVarintCodec()
    size = os.path.getsize(path)
    out: List[Tuple[int, int, int, int]] = []
    with open(path, "rb") as f:
        block_edges, n_edges, _version, checksummed = codec._read_header(f)
        if not checksummed:
            raise ValueError(
                f"{path}: not a checksummed (DVX) file — corrupt_blocks/"
                "truncate_blocks need per-block checksums to plant "
                "detectable damage"
            )
        pos = codec._HEADER.size
        while True:
            got = codec._read_cblock(f, pos, size, block_edges, n_edges)
            if got is None:
                break
            if isinstance(got, str):
                raise CorruptStreamError(f"{path} at byte {pos}: {got}")
            n_rows, first_row, _payload, end = got
            out.append((pos, n_rows, first_row, end))
            pos = end
    return out


def corrupt_blocks(path, seed: int, n_blocks: int = 1) -> dict:
    """Flip one payload byte in ``n_blocks`` seed-chosen blocks of a
    checksummed ``.dvc`` file.

    Returns ``{"blocks": [(index, first_row, n_rows), ...], "rows_lost":
    total}`` — the *exact* loss a quarantining reader must report, since
    each damaged block fails its checksum and is skipped whole while every
    other block still parses (the flip never touches framing bytes).
    """
    blocks = list_blocks(path)
    if n_blocks > len(blocks):
        raise ValueError(
            f"asked to corrupt {n_blocks} of {len(blocks)} blocks"
        )
    rng = np.random.default_rng(seed)
    picks = sorted(
        int(i) for i in rng.choice(len(blocks), size=n_blocks, replace=False)
    )
    hdr = DeltaVarintCodec._CBLOCK.size
    planted = []
    with open(path, "r+b") as f:
        for i in picks:
            pos, n_rows, first_row, end = blocks[i]
            payload_nbytes = end - pos - hdr
            assert payload_nbytes > 0
            off = pos + hdr + int(rng.integers(payload_nbytes))
            f.seek(off)
            b = f.read(1)
            f.seek(off)
            f.write(bytes([b[0] ^ 0xFF]))
            planted.append((i, first_row, n_rows))
    return {
        "blocks": planted,
        "rows_lost": sum(n for _, _, n in planted),
    }


def truncate_blocks(path, n_blocks: int = 1, partial: int = 7) -> dict:
    """Truncate a checksummed ``.dvc`` file mid-block: drop the last
    ``n_blocks`` blocks entirely, then leave ``partial`` stray bytes of the
    first dropped block so the tail is torn, not clean.

    Returns ``{"rows_lost": ..., "first_lost_row": ...}`` — what a
    quarantining reader must account for the missing tail.
    """
    blocks = list_blocks(path)
    if not 1 <= n_blocks <= len(blocks):
        raise ValueError(
            f"asked to truncate {n_blocks} of {len(blocks)} blocks"
        )
    keep = blocks[: len(blocks) - n_blocks]
    first_dropped = blocks[len(blocks) - n_blocks]
    cut = (keep[-1][3] if keep else DeltaVarintCodec._HEADER.size) + min(
        partial, first_dropped[3] - first_dropped[0] - 1
    )
    with open(path, "r+b") as f:
        f.truncate(cut)
    rows_lost = sum(n for _, n, _, _ in blocks[len(blocks) - n_blocks :])
    return {"rows_lost": rows_lost, "first_lost_row": first_dropped[2]}
