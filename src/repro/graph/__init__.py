from repro.graph import (  # noqa: F401
    codecs,
    errors,
    faults,
    generators,
    pipeline,
    sources,
    stream,
    wavefront,
)
from repro.graph.codecs import (  # noqa: F401
    Cursor,
    DeltaVarintCodec,
    EdgeCodec,
    RawCodec,
    as_cursor,
)
from repro.graph.errors import (  # noqa: F401
    CorruptBlockError,
    CorruptStreamError,
    RetryPolicy,
    SourceDeadError,
    StallError,
    TransientReadError,
    TruncatedStreamError,
)
from repro.graph.faults import ChaosSource, FaultInjector, FaultPlan  # noqa: F401
from repro.graph.pipeline import PAD, Batch, BatchPipeline  # noqa: F401
from repro.graph.sources import (  # noqa: F401
    ArraySource,
    BinaryFileSource,
    CodecFileSource,
    EdgeListFileSource,
    EdgeSource,
    GeneratorSource,
    MergedSource,
    ShardedSource,
    as_source,
)
