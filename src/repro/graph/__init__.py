from repro.graph import codecs, generators, pipeline, sources, stream, wavefront  # noqa: F401
from repro.graph.codecs import (  # noqa: F401
    Cursor,
    DeltaVarintCodec,
    EdgeCodec,
    RawCodec,
    as_cursor,
)
from repro.graph.pipeline import PAD, Batch, BatchPipeline  # noqa: F401
from repro.graph.sources import (  # noqa: F401
    ArraySource,
    BinaryFileSource,
    CodecFileSource,
    EdgeListFileSource,
    EdgeSource,
    GeneratorSource,
    MergedSource,
    ShardedSource,
    as_source,
)
