from repro.graph import generators, stream  # noqa: F401
