from repro.graph import generators, pipeline, sources, stream  # noqa: F401
from repro.graph.pipeline import PAD, Batch, BatchPipeline  # noqa: F401
from repro.graph.sources import (  # noqa: F401
    ArraySource,
    BinaryFileSource,
    EdgeListFileSource,
    EdgeSource,
    GeneratorSource,
    ShardedSource,
    as_source,
)
