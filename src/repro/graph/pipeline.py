"""Batching/padding pipeline between an edge source and the device tiers.

This module is the single home of the stream-shape plumbing (DESIGN.md
§"Ingestion"):

* :data:`PAD` — the sentinel node id padding fixed device shapes (a PAD edge
  is a no-op in every clustering tier).
* :func:`pad_batch` / :func:`pad_to_chunks` (host, numpy) and
  :func:`pad_edges_to_chunks` (device, jit-traceable) — previously duplicated
  between ``graph/stream.py`` and ``core/streaming.py``; both old names
  remain as shims over these.
* :class:`BatchPipeline` — pulls raw slices from an
  :class:`repro.graph.sources.EdgeSource`, re-chunks them into *fixed-size*
  batches (so every jitted tier compiles exactly once per run), pads with
  PAD, and double-buffers production on a background thread so host parsing
  /generation — *and codec block decompression*: the source's
  ``resume``/``iter_slices`` generators, where
  :class:`~repro.graph.codecs.DeltaVarintCodec` decoding happens, are pulled
  entirely on the prefetch worker — overlaps device compute.  Peak host
  edge-buffer residency is tracked (``peak_buffer_bytes``) — the paper's
  memory claim is state = ``3n`` ints; the pipeline keeps edges at O(batch),
  not O(m).
* :meth:`BatchPipeline.megabatches` — the device-pipelining staging mode
  (DESIGN.md §10): ``K`` consecutive fixed-shape batches are stacked into
  one ``(K, B, 2)`` host buffer on the prefetch thread, so a fused backend
  (``lax.scan``-over-chunks, double-buffered-DMA Pallas) dispatches *once*
  per ``K`` batches instead of once per batch.  A ragged tail megabatch is
  padded with all-PAD batches (no-ops on every tier), keeping the device
  shape constant — one compile per run, bit-identical labels to the
  per-batch path.

Stream positions are :class:`~repro.graph.codecs.Cursor` values;
``batches(start=...)`` accepts either a cursor or the historical raw-row
int.
"""

from __future__ import annotations

import threading
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FuturesTimeout
from typing import Iterable, Iterator, NamedTuple, Optional, Union

import numpy as np

from repro.graph.codecs import Cursor, as_cursor
from repro.graph.errors import RetryPolicy, StallError, retrying_slices

# Sentinel node id used to pad edge batches/chunks to fixed shapes; padded
# edges are no-ops in every clustering tier.  (Canonical definition — re-
# exported by ``repro.core.streaming`` and ``repro.graph.stream`` for
# backwards compatibility.)
PAD = -1


# ---------------------------------------------------------------------------
# Padding primitives (host + device)
# ---------------------------------------------------------------------------

# Preallocated all-PAD row template backing pad_batch / megabatch staging:
# padded buffers are carved by copying template rows instead of a fresh
# ``np.full`` fill per batch.  Grown geometrically under a lock (reads of an
# already-large-enough template are lock-free); ``_pad_template_allocs``
# counts the growths so the smoke bench can assert the steady state
# allocates nothing new.
_pad_template = np.full((1 << 10, 2), PAD, dtype=np.int32)
_pad_template_lock = threading.Lock()
_pad_template_allocs = 1


_pad_template.flags.writeable = False  # a stray write would poison all pads


def pad_template(rows: int) -> np.ndarray:
    """A read-only view of ``rows`` all-PAD ``(rows, 2)`` int32 rows."""
    global _pad_template, _pad_template_allocs
    tmpl = _pad_template
    if tmpl.shape[0] < rows:
        with _pad_template_lock:
            if _pad_template.shape[0] < rows:
                size = max(rows, 2 * _pad_template.shape[0])
                grown = np.full((size, 2), PAD, dtype=np.int32)
                grown.flags.writeable = False
                _pad_template = grown
                _pad_template_allocs += 1
            tmpl = _pad_template
    return tmpl[:rows]


def pad_template_allocs() -> int:
    """How many times the shared PAD template has been (re)allocated —
    a growth counter, not a per-batch one; the smoke bench asserts it stays
    flat across steady-state streaming."""
    return _pad_template_allocs


def pad_batch(edges: np.ndarray, length: int) -> np.ndarray:
    """Pad a host ``(m, 2)`` batch with PAD rows up to exactly ``length``.

    Zero-copy when the batch is already full-length int32 (the steady-state
    case: every non-final pipeline batch); the padded tail is filled from
    the preallocated PAD template rather than a fresh ``np.full``.
    """
    edges = np.asarray(edges)
    m = edges.shape[0]
    if m > length:
        raise ValueError(f"batch of {m} rows exceeds pad length {length}")
    if m == length and edges.dtype == np.int32:
        return edges
    out = np.empty((length, 2), dtype=np.int32)
    out[:m] = edges
    out[m:] = pad_template(length - m)
    return out


def pad_to_chunks(edges: np.ndarray, chunk: int) -> np.ndarray:
    """(m, 2) -> (ceil(m/chunk), chunk, 2), padded with PAD edges (host).

    Always a fresh array (historical contract) — callers may mutate the
    result without aliasing their input; the pipeline's zero-copy fast path
    lives in :func:`pad_batch` instead.
    """
    edges = np.asarray(edges)
    m = edges.shape[0]
    n_chunks = max(1, -(-m // chunk))
    out = np.empty((n_chunks * chunk, 2), dtype=np.int32)
    out[:m] = edges
    out[m:] = pad_template(n_chunks * chunk - m)
    return out.reshape(n_chunks, chunk, 2)


def pad_edges_to_chunks(edges, chunk: int):
    """Pad a (m, 2) *device* batch with PAD rows up to a ``chunk`` multiple.

    Jit-traceable (shapes depend only on ``edges.shape`` and ``chunk``) —
    the DMA/Jacobi granularity of the chunked and Pallas tiers.  Returns
    ``(padded, n_chunks)`` with ``padded`` of shape ``(n_chunks * chunk, 2)``;
    empty batches yield one all-PAD chunk.
    """
    import jax
    import jax.numpy as jnp

    m = edges.shape[0]
    n_chunks = max(1, -(-m // chunk))
    padded = jnp.full((n_chunks * chunk, 2), PAD, dtype=jnp.int32)
    padded = jax.lax.dynamic_update_slice(padded, edges.astype(jnp.int32), (0, 0))
    return padded, n_chunks


def round_up(value: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= ``value``."""
    return -(-value // multiple) * multiple


# ---------------------------------------------------------------------------
# Re-chunking: arbitrary-size raw slices -> exact-size batches
# ---------------------------------------------------------------------------

def rechunk(slices: Iterable[np.ndarray], size: int) -> Iterator[np.ndarray]:
    """Regroup arbitrary-length ``(k, 2)`` slices into exact ``size``-row
    batches (final batch may be short).

    The batch boundaries depend only on ``size`` — never on how the source
    happened to slice the stream — which is what makes labels invariant
    across sources for a fixed batch size.  Full batches carved out of a
    single large slice are views (zero-copy; mmap'd sources never touch the
    heap for them).
    """
    pending: list = []
    have = 0
    for sl in slices:
        sl = np.asarray(sl)
        if sl.size == 0:
            continue
        pos = 0
        if have:
            take = min(size - have, sl.shape[0])
            pending.append(sl[:take])
            have += take
            pos = take
            if have == size:
                yield np.concatenate(pending).astype(np.int32, copy=False)
                pending, have = [], 0
        while sl.shape[0] - pos >= size:
            yield sl[pos : pos + size]
            pos += size
        if pos < sl.shape[0]:
            pending.append(sl[pos:])
            have = sl.shape[0] - pos
    if have:
        yield np.concatenate(pending).astype(np.int32, copy=False)


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------

class Batch(NamedTuple):
    """One pipeline batch: fixed-shape padded edges + raw-stream bookkeeping."""

    edges: np.ndarray  # (batch_edges, 2) int32, PAD tail
    n_rows: int  # raw source rows in this batch (before PAD padding)
    offset: int  # raw rows consumed from the source before this batch


# ---------------------------------------------------------------------------
# Compressed-slab descriptor table (DESIGN.md §14)
# ---------------------------------------------------------------------------
# One descriptor row per staged segment of a compressed megabatch.  The
# decoder (Pallas kernel or pure-JAX reference) walks rows in order; dest
# windows may overlap the previous segment's PAD tail, so ascending-order
# full-window writes reconstruct exactly the slab the host-decode path
# stages.  Kinds:
#   DESC_EMPTY — unused row (table is fixed-shape), a no-op
#   DESC_FIXED — lane-packed DVE3 block: zigzag cols at off_i/off_j with
#                byte widths w_i/w_j, source column cumsum seeded by base
#   DESC_RAW   — host-decoded int32 (n, 2) rows at off_i (fallback blocks,
#                partial blocks at resume/megabatch boundaries)
DESC_COLS = 8
DESC_EMPTY, DESC_FIXED, DESC_RAW = 0, 1, 2
(
    D_KIND,
    D_ROW,
    D_NROWS,
    D_OFF_I,
    D_OFF_J,
    D_W_I,
    D_W_J,
    D_BASE,
) = range(DESC_COLS)

# Payload slab capacity per staged row.  Fixed lanes cost w_i + w_j <= 8
# bytes/row and raw fallback rows cost exactly 8, so 8 bytes/row (plus the
# per-segment alignment slack added in the producer) can never overflow —
# the compressed path trades *host decode compute* and disk bandwidth, not
# slab bytes, and never needs a mid-stream shape change.
_PAYLOAD_BYTES_PER_ROW = 8
_SEGMENT_ALIGN = 8  # every lane/raw segment starts 8-byte aligned


class MegaBatch(NamedTuple):
    """``K`` stacked pipeline batches staged as one fixed-shape host buffer.

    The fused device paths (``lax.scan``-over-chunks, double-buffered-DMA
    Pallas) consume one of these per dispatch.  ``edges`` always has the
    full ``(K, batch_edges, 2)`` shape — a ragged tail (fewer than ``K``
    real batches left in the stream) is padded with all-PAD batches, which
    are no-ops in every tier, so the device sees exactly one shape per run.
    """

    edges: np.ndarray  # (K, batch_edges, 2) int32, PAD-padded
    n_rows: int  # raw source rows across the megabatch (before padding)
    offset: int  # raw rows consumed from the source before this megabatch
    n_batches: int  # real (non-padding) batches stacked (1..K)
    plan: Optional[object] = None  # WavePlan staged on the prefetch thread
    #   when megabatches(..., wavefront=W) is used (DESIGN.md §12); None in
    #   sequential megabatch mode


class CompressedMegaBatch(NamedTuple):
    """``K`` batches' worth of stream staged as *compressed bytes* plus a
    descriptor table, instead of decoded edges (DESIGN.md §14).

    Decoding the slab (device kernel or pure-JAX reference) must
    reconstruct exactly the ``(K, batch_edges, 2)`` PAD-carved buffer the
    plain :class:`MegaBatch` producer would have staged for the same rows
    — that invariant is what keeps labels bit-identical and cursors
    interchangeable between ``device_decode`` on and off.
    """

    payload: np.ndarray  # (P,) uint8 — lane segments + raw fallback rows
    desc: np.ndarray  # (D_max, DESC_COLS) int32 descriptor table
    n_rows: int  # raw source rows staged (before PAD padding)
    offset: int  # raw rows consumed from the source before this megabatch
    n_batches: int  # real (non-padding) batches covered (1..K)
    n_desc: int  # live descriptor rows (the rest are DESC_EMPTY)
    window: int  # max rows any one descriptor covers (static per run)
    fallback_rows: int  # rows staged as DESC_RAW (host-decoded)
    out_rows: int  # decoded slab rows = k * batch_edges (static per run)

    def validate(self) -> "CompressedMegaBatch":
        """Reject a torn descriptor table before it reaches a decode
        dispatch.  Live descriptors must tile ``[0, n_rows)`` contiguously
        in order, stay inside the payload, and carry device-decodable
        widths — anything else means the slab was corrupted in transit
        (truncated payload, spliced table, bad checkpoint) and decoding it
        would silently produce wrong edges rather than fail.  Returns
        ``self`` so call sites can chain.  O(n_desc), host-side.
        """
        desc = np.asarray(self.desc)
        if not (0 <= self.n_desc <= desc.shape[0]):
            raise ValueError(
                f"torn descriptor table: n_desc {self.n_desc} outside "
                f"table of {desc.shape[0]} rows"
            )
        live, tail = desc[: self.n_desc], desc[self.n_desc :]
        if tail.size and (tail[:, D_KIND] != DESC_EMPTY).any():
            raise ValueError(
                "torn descriptor table: live descriptor past n_desc"
            )
        kind, nrows = live[:, D_KIND], live[:, D_NROWS]
        if not np.isin(kind, (DESC_FIXED, DESC_RAW)).all():
            raise ValueError(
                "torn descriptor table: unknown descriptor kind"
            )
        if ((nrows < 1) | (nrows > self.window)).any():
            raise ValueError(
                "torn descriptor table: segment rows outside (0, window]"
            )
        expect = np.concatenate(([0], np.cumsum(nrows[:-1], dtype=np.int64)))
        if (live[:, D_ROW].astype(np.int64) != expect).any() or (
            int(nrows.sum()) != self.n_rows
        ):
            raise ValueError(
                "torn descriptor table: segments do not tile "
                f"[0, {self.n_rows}) contiguously"
            )
        P = np.int64(self.payload.shape[0])
        fixed = kind == DESC_FIXED
        w_i, w_j = live[:, D_W_I], live[:, D_W_J]
        if not np.isin(w_i[fixed], (1, 2, 4)).all() or not np.isin(
            w_j[fixed], (1, 2, 4)
        ).all():
            raise ValueError(
                "torn descriptor table: fixed width not in {1, 2, 4}"
            )
        end_i = live[:, D_OFF_I].astype(np.int64) + np.where(
            fixed, w_i.astype(np.int64) * nrows, 8 * nrows.astype(np.int64)
        )
        end_j = np.where(
            fixed,
            live[:, D_OFF_J].astype(np.int64)
            + w_j.astype(np.int64) * nrows,
            0,
        )
        if (
            (live[:, D_OFF_I] < 0).any()
            or (live[:, D_OFF_J][fixed] < 0).any()
            or (end_i > P).any()
            or (end_j > P).any()
        ):
            raise ValueError(
                "torn descriptor table: segment span outside the payload"
            )
        return self


class BatchPipeline:
    """Fixed-shape batching + host/device overlap for an edge source.

    Every yielded :class:`Batch` has shape ``(batch_edges, 2)`` (PAD-padded),
    so jitted backends compile once.  ``batch_edges`` is rounded up to
    ``pad_multiple`` (the Jacobi/DMA chunk of the chunked/pallas tiers) —
    with full batches aligned to chunk boundaries, the chunked tier's
    grouping is identical to a one-shot run over the whole stream.

    ``prefetch`` batches are produced ahead on a background thread (double
    buffering by default), so file parsing / synthetic generation overlaps
    device compute.  Host edge residency is bounded by
    ``(prefetch + 1) * batch_edges`` rows of pipeline buffering plus the raw
    source slices still pinnable by re-chunking views (a slice is counted
    until a full batch of rows has arrived after it).
    :attr:`peak_buffer_bytes` tracks both levels — zero-copy views are
    counted twice, so the steady-state figure is an over- rather than
    under-estimate (transient concatenation copies are the one exclusion).
    An ``ArraySource``'s single slice is the whole array: for in-memory
    streams the metric honestly reports the resident edge list.
    """

    def __init__(
        self,
        source,
        batch_edges: int,
        *,
        pad_multiple: int = 1,
        prefetch: int = 2,
        retry: Optional[RetryPolicy] = RetryPolicy(),
        stall_timeout: Optional[float] = None,
    ):
        if batch_edges < 1:
            raise ValueError(f"batch_edges must be >= 1, got {batch_edges}")
        if pad_multiple < 1:
            raise ValueError(f"pad_multiple must be >= 1, got {pad_multiple}")
        if stall_timeout is not None and stall_timeout <= 0:
            raise ValueError(
                f"stall_timeout must be > 0 (or None), got {stall_timeout}"
            )
        self.source = source
        self.batch_edges = round_up(batch_edges, pad_multiple)
        self.prefetch = max(0, int(prefetch))
        # Resilience knobs: the pipeline re-resumes the source at the last
        # delivered row on transient read errors (retry=None disables), and
        # the consumer side of the prefetch queue raises StallError when a
        # single produce exceeds stall_timeout seconds.  The heartbeat
        # monitor brackets every producer pull so soft stalls (straggling
        # but not dead) are visible in ``stalls`` without killing the run.
        self.retry = retry
        self.stall_timeout = stall_timeout
        from repro.dist.fault_tolerance import HeartbeatMonitor

        self.heartbeat = HeartbeatMonitor()
        self.retries = 0
        self.peak_buffer_bytes = 0
        self.batches_produced = 0
        self.megabatches_produced = 0
        self._inflight_bytes = 0
        self._lock = threading.Lock()

    @property
    def stalls(self) -> int:
        """Producer pulls flagged as stragglers by the heartbeat monitor
        (soft stalls — a hard ``stall_timeout`` breach raises instead)."""
        return len(self.heartbeat.stragglers)

    def _count_retry(self, attempt: int, exc: BaseException) -> None:
        with self._lock:
            self.retries += 1

    # ------------------------------------------------------------------
    def _acquire(self, nbytes: int) -> None:
        with self._lock:
            self._inflight_bytes += nbytes
            if self._inflight_bytes > self.peak_buffer_bytes:
                self.peak_buffer_bytes = self._inflight_bytes

    def _release(self, nbytes: int) -> None:
        with self._lock:
            self._inflight_bytes -= nbytes

    def _counted_slices(self, start: Cursor) -> Iterator[np.ndarray]:
        """Pass raw source slices through while counting them toward
        residency — parse blocks / generator segments are real host memory
        even when the batches carved from them are views.

        A slice stays counted until ``batch_edges`` rows have arrived after
        it: only then can no ``rechunk`` pending-view still pin it alive.
        """
        held: deque = deque()  # (nbytes, rows) per still-pinnable slice
        held_rows = 0  # running total, so pruning is O(1) per slice
        if self.retry is not None:
            src_iter = retrying_slices(
                self.source.resume,
                self.source.cursor_at,
                start,
                self.retry,
                self._count_retry,
            )
        else:
            src_iter = self.source.resume(start)
        try:
            for sl in src_iter:
                sl = np.asarray(sl)
                held.append((int(sl.nbytes), int(sl.shape[0])))
                held_rows += int(sl.shape[0])
                while len(held) > 1 and held_rows - held[0][1] >= self.batch_edges:
                    nbytes, rows = held.popleft()
                    held_rows -= rows
                    self._release(nbytes)
                self._acquire(int(sl.nbytes))
                yield sl
        finally:
            close = getattr(src_iter, "close", None)
            if close is not None:
                close()
            for nbytes, _ in held:
                self._release(nbytes)

    def _produce(self, start: Cursor) -> Iterator[Batch]:
        """Raw producer: rechunk + pad + residency accounting.  Runs on the
        prefetch thread — so source-side work (file parsing, synthetic
        generation, codec block decode) overlaps the consumer's device
        compute."""
        offset = start.row
        slices = self._counted_slices(start)
        stream = rechunk(slices, self.batch_edges)
        try:
            for raw in stream:
                padded = pad_batch(raw, self.batch_edges)
                self._acquire(padded.nbytes)
                yield Batch(edges=padded, n_rows=raw.shape[0], offset=offset)
                offset += raw.shape[0]
        finally:
            stream.close()
            slices.close()

    def batches(self, start: Union[int, Cursor] = 0) -> Iterator[Batch]:
        """Yield fixed-shape batches from a stream position — a
        :class:`~repro.graph.codecs.Cursor` (token-accelerated resume) or a
        raw row offset."""
        inner = _prefetch_iter(
            self._produce(as_cursor(start)),
            self.prefetch,
            on_drop=lambda b: self._release(b.edges.nbytes),
            heartbeat=self.heartbeat,
            stall_timeout=self.stall_timeout,
        )
        prev: Optional[Batch] = None
        try:
            for batch in inner:
                if prev is not None:
                    self._release(prev.edges.nbytes)
                prev = batch
                self.batches_produced += 1
                yield batch
        finally:
            if prev is not None:
                self._release(prev.edges.nbytes)
            inner.close()

    def _produce_mega(
        self,
        k: int,
        start: Cursor,
        wavefront: Union[int, str, None] = None,
        wavefront_gap: Optional[int] = None,
    ) -> Iterator[MegaBatch]:
        """Raw megabatch producer: stack ``k`` consecutive batches into one
        ``(k, batch_edges, 2)`` buffer.  Runs entirely on the prefetch
        thread, so the stacking memcpy (and everything upstream of it —
        parsing, generation, codec decode) overlaps the consumer's device
        dispatch.  The buffer is carved PAD-filled from the shared template
        (no per-megabatch ``np.full``), and a ragged tail keeps the full
        ``k``-batch shape with all-PAD trailing batches.

        With ``wavefront`` set, each staged buffer is additionally planned
        into node-disjoint waves (``repro.graph.wavefront.plan_waves``) here
        on the prefetch thread — the planner's host work overlaps device
        compute exactly like parsing and codec decode do.
        """
        B = self.batch_edges
        offset = start.row
        slices = self._counted_slices(start)
        stream = rechunk(slices, B)
        if wavefront is not None:
            # deferred: graph.wavefront imports this module's PAD template
            from repro.graph.wavefront import plan_waves
        try:
            while True:
                buf = None
                plan = None
                rows = 0
                n_batches = 0
                try:
                    for raw in stream:
                        m = raw.shape[0]
                        if buf is None:
                            # uninitialised on purpose: every row is either
                            # overwritten with real edges below or PAD-filled
                            # from the template before the yield
                            buf = np.empty((k, B, 2), np.int32)
                            self._acquire(buf.nbytes)
                        buf[n_batches, :m] = raw
                        if m < B:  # short final batch of the stream
                            buf[n_batches, m:] = pad_template(B - m)
                        rows += m
                        n_batches += 1
                        if n_batches == k:
                            break
                    if buf is not None and n_batches < k:
                        # ragged tail: trailing all-PAD no-op batches
                        buf[n_batches:] = pad_template(
                            (k - n_batches) * B
                        ).reshape(-1, B, 2)
                    if buf is not None and wavefront is not None:
                        plan = plan_waves(buf, wavefront, gap=wavefront_gap)
                        self._acquire(plan.nbytes)
                except BaseException:
                    # a producer error between _acquire and yield: the buffer
                    # never reaches a consumer, so unwind its accounting here
                    if plan is not None:
                        self._release(plan.nbytes)
                    if buf is not None:
                        self._release(buf.nbytes)
                    raise
                if buf is None:
                    return
                yield MegaBatch(
                    edges=buf,
                    n_rows=rows,
                    offset=offset,
                    n_batches=n_batches,
                    plan=plan,
                )
                offset += rows
                if n_batches < k:
                    return  # ragged tail: the stream is exhausted
        finally:
            stream.close()
            slices.close()

    def _produce_cmega(
        self, k: int, start: Cursor
    ) -> Iterator[CompressedMegaBatch]:
        """Raw compressed-slab producer (DESIGN.md §14): walk the source's
        sync blocks and stage *payload bytes* plus a descriptor table
        instead of decoded edges.

        Device-decodable blocks (DVE3 fixed lanes) are memcpy'd into the
        slab untouched — the host never runs their zigzag/cumsum.  Varint
        or u8 blocks, a partial first block after a mid-block resume, and
        blocks straddling the megabatch boundary are host-decoded into a
        ``carry`` buffer and staged as ``DESC_RAW`` int32 segments, split
        to the descriptor window so every segment fits one decode window.
        Decoded, the slab reproduces exactly what :meth:`_produce_mega`
        would have staged for the same rows.
        """
        B = self.batch_edges
        KB = k * B
        codec = self.source.codec
        N_win = max(1, min(int(self.source.block_rows), KB))
        D_max = KB // N_win + 3
        # capacity: 8 bytes/staged row + per-segment alignment slack, plus
        # one full decode-window span of tail slack so the kernel's
        # fixed-size descriptor DMA (payload[off : off + 8 * window + 8])
        # stays in bounds even for the last segment
        P = round_up(
            KB * _PAYLOAD_BYTES_PER_ROW
            + 2 * _SEGMENT_ALIGN * D_max
            + _PAYLOAD_BYTES_PER_ROW * N_win
            + _SEGMENT_ALIGN,
            _SEGMENT_ALIGN,
        )
        offset = start.row
        consumed = start.row  # absolute row index of the next unstaged row
        blocks = self.source.scan_blocks(start)
        carry: Optional[np.ndarray] = None  # decoded rows awaiting staging
        carry_charge = 0
        try:
            while True:
                payload: Optional[np.ndarray] = None
                desc: Optional[np.ndarray] = None
                filled = 0  # rows staged into this megabatch
                pos = 0  # payload bytes used
                nd = 0  # live descriptor rows
                fallback_rows = 0

                def ensure_buffers():
                    nonlocal payload, desc
                    if payload is None:
                        payload = np.zeros(P, np.uint8)
                        desc = np.zeros((D_max, DESC_COLS), np.int32)
                        self._acquire(payload.nbytes + desc.nbytes)

                try:
                    while filled < KB:
                        if carry is not None:
                            take = min(N_win, KB - filled, carry.shape[0])
                            ensure_buffers()
                            off = round_up(pos, _SEGMENT_ALIGN)
                            seg = np.ascontiguousarray(
                                carry[:take], dtype="<i4"
                            ).reshape(-1).view(np.uint8)
                            payload[off : off + seg.nbytes] = seg
                            desc[nd, D_KIND] = DESC_RAW
                            desc[nd, D_ROW] = filled
                            desc[nd, D_NROWS] = take
                            desc[nd, D_OFF_I] = off
                            pos = off + seg.nbytes
                            nd += 1
                            filled += take
                            fallback_rows += take
                            if take < carry.shape[0]:
                                carry = carry[take:]
                            else:
                                carry = None
                                self._release(carry_charge)
                                carry_charge = 0
                            continue
                        block = next(blocks, None)
                        if block is None:
                            break
                        skip = consumed - block.first_row
                        consumed = block.first_row + block.n_rows
                        n = block.n_rows - skip
                        meta = block.fixed
                        if (
                            meta is not None
                            and skip == 0
                            and n <= N_win
                            and filled + n <= KB
                            and -(1 << 31) <= meta.base_i < (1 << 31)
                        ):
                            ensure_buffers()
                            pay = np.frombuffer(block.payload, np.uint8)
                            off_i = round_up(pos, _SEGMENT_ALIGN)
                            li = meta.w_i * n
                            payload[off_i : off_i + li] = pay[
                                meta.off_i : meta.off_i + li
                            ]
                            off_j = round_up(off_i + li, _SEGMENT_ALIGN)
                            lj = meta.w_j * n
                            payload[off_j : off_j + lj] = pay[
                                meta.off_j : meta.off_j + lj
                            ]
                            desc[nd] = (
                                DESC_FIXED,
                                filled,
                                n,
                                off_i,
                                off_j,
                                meta.w_i,
                                meta.w_j,
                                meta.base_i,
                            )
                            pos = off_j + lj
                            nd += 1
                            filled += n
                        else:
                            rows = codec.decode_block(
                                block.payload, block.n_rows, block.version
                            )
                            if skip:
                                rows = rows[skip:]
                            carry = rows
                            carry_charge = int(rows.nbytes)
                            self._acquire(carry_charge)
                except BaseException:
                    if payload is not None:
                        self._release(payload.nbytes + desc.nbytes)
                    raise
                if filled == 0:
                    return
                yield CompressedMegaBatch(
                    payload=payload,
                    desc=desc,
                    n_rows=filled,
                    offset=offset,
                    n_batches=-(-filled // B),
                    n_desc=nd,
                    window=N_win,
                    fallback_rows=fallback_rows,
                    out_rows=KB,
                )
                offset += filled
                if filled < KB:
                    return  # ragged tail: the stream is exhausted
        finally:
            if carry is not None:
                self._release(carry_charge)
            blocks.close()

    def compressed_megabatches(
        self, k: int, start: Union[int, Cursor] = 0
    ) -> Iterator[CompressedMegaBatch]:
        """Yield compressed-slab megabatches from a stream position.

        The device-decode analogue of :meth:`megabatches`: identical row
        coverage per megabatch (``k * batch_edges`` rows from the same
        start), but the staged buffer holds compressed payload bytes plus
        a :data:`DESC_COLS`-column descriptor table; decoding it on device
        (or via the pure-JAX reference) reconstructs the exact
        ``(k, batch_edges, 2)`` PAD-carved slab.  Requires a block-codec
        file source (a ``.dvc`` behind :class:`CodecFileSource`).
        """
        if k < 1:
            raise ValueError(f"megabatch k must be >= 1, got {k}")
        if getattr(self.source, "block_rows", None) is None or not hasattr(
            self.source, "scan_blocks"
        ):
            raise ValueError(
                "compressed staging needs a block-codec file source "
                "(CodecFileSource over a dvc file)"
            )
        inner = _prefetch_iter(
            self._produce_cmega(k, as_cursor(start)),
            self.prefetch,
            on_drop=lambda cm: self._release(cm.payload.nbytes + cm.desc.nbytes),
            heartbeat=self.heartbeat,
            stall_timeout=self.stall_timeout,
        )
        prev: Optional[CompressedMegaBatch] = None
        try:
            for cm in inner:
                if prev is not None:
                    self._release(prev.payload.nbytes + prev.desc.nbytes)
                prev = cm
                self.megabatches_produced += 1
                self.batches_produced += cm.n_batches
                yield cm
        finally:
            if prev is not None:
                self._release(prev.payload.nbytes + prev.desc.nbytes)
            inner.close()

    @staticmethod
    def _mega_nbytes(mb: MegaBatch) -> int:
        """Residency charged for one staged megabatch (edges + wave plan)."""
        return mb.edges.nbytes + (mb.plan.nbytes if mb.plan is not None else 0)

    def megabatches(
        self,
        k: int,
        start: Union[int, Cursor] = 0,
        *,
        wavefront: Union[int, str, None] = None,
        wavefront_gap: Optional[int] = None,
    ) -> Iterator[MegaBatch]:
        """Yield ``(k, batch_edges, 2)`` megabatches from a stream position.

        The fused-dispatch analogue of :meth:`batches`: identical batch
        boundaries (``rechunk`` by ``batch_edges`` from the same start row),
        so a megabatch is exactly the concatenation of the next ``k``
        :meth:`batches` results — which is what makes the fused device paths
        bit-identical to per-batch ingestion.  Residency accounting counts
        each staged ``k``-batch buffer — plus its wave plan when
        ``wavefront`` is set — so ``peak_buffer_bytes`` honestly reflects
        the larger staging footprint.
        """
        if k < 1:
            raise ValueError(f"megabatch k must be >= 1, got {k}")
        if (
            wavefront is not None
            and not isinstance(wavefront, str)
            and wavefront < 1
        ):
            raise ValueError(f"wavefront width must be >= 1, got {wavefront}")
        inner = _prefetch_iter(
            self._produce_mega(k, as_cursor(start), wavefront, wavefront_gap),
            self.prefetch,
            on_drop=lambda mb: self._release(self._mega_nbytes(mb)),
            heartbeat=self.heartbeat,
            stall_timeout=self.stall_timeout,
        )
        prev: Optional[MegaBatch] = None
        try:
            for mega in inner:
                if prev is not None:
                    self._release(self._mega_nbytes(prev))
                prev = mega
                self.megabatches_produced += 1
                self.batches_produced += mega.n_batches
                yield mega
        finally:
            if prev is not None:
                self._release(self._mega_nbytes(prev))
            inner.close()

    def __iter__(self) -> Iterator[Batch]:
        return self.batches()


def _prefetch_iter(
    gen: Iterator,
    depth: int,
    on_drop=None,
    heartbeat=None,
    stall_timeout: Optional[float] = None,
) -> Iterator:
    """Run ``gen`` up to ``depth`` items ahead on one background thread.

    The single worker pulls items sequentially (generators are not
    thread-safe — one puller only); at most ``depth`` results are buffered,
    so producer memory stays bounded even if the consumer stalls.  On early
    close, items already produced but never consumed are handed to
    ``on_drop`` so the caller can undo any per-item accounting.

    A producer exception (decode error, torn file, generator bug) is
    captured on the worker, the queue of already-produced items is drained
    through ``on_drop``, the worker thread is *joined*, and only then is the
    exception re-raised on the consumer — so a failure mid-stream can never
    leave a dangling producer thread or leaked residency accounting behind
    the caller's back.

    ``heartbeat`` (a :class:`repro.dist.fault_tolerance.HeartbeatMonitor`)
    brackets each producer pull, so straggling produces show up as soft
    stalls without killing the run.  ``stall_timeout`` is the hard
    watchdog: when the *consumer* has waited more than that many seconds
    for the next item, :class:`~repro.graph.errors.StallError` is raised
    (a wedged worker cannot be interrupted, but it holds no further
    items: the queue is drained and the run fails loudly instead of
    hanging forever).  Neither applies on the synchronous ``depth <= 0``
    path, where there is no worker to watch.
    """
    if depth <= 0:
        yield from gen
        return
    ex = ThreadPoolExecutor(max_workers=1)
    pulls = 0

    def pull():
        # Capture *every* outcome as a tagged pair: the consumer must be
        # able to tell produced items (which need on_drop accounting if
        # never consumed) from terminal signals without re-raising inside
        # the cleanup path.
        nonlocal pulls
        if heartbeat is not None:
            heartbeat.step_start()
        try:
            try:
                out = ("item", next(gen))
            except StopIteration:
                out = ("stop", None)
            except BaseException as e:  # propagated on consumer after join
                out = ("raise", e)
        finally:
            if heartbeat is not None:
                heartbeat.step_end(pulls)
                pulls += 1
        return out

    futures: deque = deque()
    stalled = False
    try:
        for _ in range(depth):
            futures.append(ex.submit(pull))
        while futures:
            try:
                kind, value = futures[0].result(timeout=stall_timeout)
            except _FuturesTimeout:
                stalled = True
                raise StallError(
                    f"prefetch producer stalled: no item within "
                    f"{stall_timeout}s (source wedged or deadlocked)"
                ) from None
            futures.popleft()
            if kind == "stop":
                break
            if kind == "raise":
                # the finally below drains the queue and joins the worker
                # before this leaves the generator
                raise value
            futures.append(ex.submit(pull))
            yield value
    finally:
        for f in futures:
            if not f.cancel():
                if stalled and not f.done():
                    continue  # wedged worker: never block cleanup on it
                kind, value = f.result()
                if kind == "item" and on_drop is not None:
                    on_drop(value)
        # A wedged worker cannot be joined and its generator frame cannot
        # be closed from here ("generator already executing") — leak the
        # thread and let the StallError surface; every healthy path still
        # joins and closes.
        ex.shutdown(wait=not stalled)
        if not stalled:
            gen.close()
