"""Synthetic graph generators reproducing the paper's experimental regimes.

SNAP datasets are not available offline; these generators produce graphs with
the same *structure* the paper exploits: planted communities (SBM — quality
benchmarks, F1/NMI vs ground truth) and heavy-tailed degree graphs
(Chung–Lu — speed benchmarks up to ~1e8 edges).  All return edge *streams*
(random order, as the paper assumes) as ``(m, 2) int32`` arrays.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class GraphSpec:
    name: str
    n: int
    m: int  # number of streamed edges (multi-edges possible, as in the paper)


def sbm_stream(
    n: int,
    n_communities: int,
    avg_degree: float = 16.0,
    p_intra: float = 0.8,
    seed: int = 0,
    shuffle: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Planted-partition stream: returns (edges, ground_truth labels).

    ``p_intra`` is the probability an edge is intra-community.  Endpoints are
    drawn uniformly inside the chosen block(s); self-loops resampled cheaply.
    Multi-edges may occur (the paper's setting is an unweighted multi-graph).
    """
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    labels = rng.integers(0, n_communities, size=n).astype(np.int32)
    # Bucket nodes by community for O(1) within-block sampling.
    order = np.argsort(labels, kind="stable")
    sorted_labels = labels[order]
    starts = np.searchsorted(sorted_labels, np.arange(n_communities))
    ends = np.searchsorted(sorted_labels, np.arange(n_communities), side="right")
    sizes = ends - starts

    intra = rng.random(m) < p_intra
    # Community of each intra edge ~ proportional to block size (uniform edge).
    comm = rng.integers(0, n_communities, size=m)
    u = np.empty(m, dtype=np.int64)
    w = np.empty(m, dtype=np.int64)

    ss = np.maximum(sizes[comm], 1)
    a = starts[comm] + rng.integers(0, 2**62, size=m) % ss
    b = starts[comm] + rng.integers(0, 2**62, size=m) % ss
    u_i, w_i = order[a], order[b]

    u_o = rng.integers(0, n, size=m)
    w_o = rng.integers(0, n, size=m)

    u = np.where(intra, u_i, u_o)
    w = np.where(intra, w_i, w_o)
    # Remove self-loops by shifting one endpoint (keeps the distribution close
    # enough; the paper assumes no self-loops).
    loops = u == w
    w = np.where(loops, (w + 1) % n, w)

    edges = np.stack([u, w], axis=1).astype(np.int32)
    if shuffle:
        rng.shuffle(edges, axis=0)
    return edges, labels


def chung_lu_stream(
    n: int, m: int, gamma: float = 2.5, seed: int = 0
) -> np.ndarray:
    """Power-law expected-degree stream (speed benchmarks; no ground truth)."""
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-1.0 / (gamma - 1.0))
    p = w / w.sum()
    cdf = np.cumsum(p)
    u = np.searchsorted(cdf, rng.random(m))
    v = np.searchsorted(cdf, rng.random(m))
    v = np.where(u == v, (v + 1) % n, v)
    perm = rng.permutation(n)  # decorrelate node id from degree
    return np.stack([perm[u], perm[v]], axis=1).astype(np.int32)


def ring_of_cliques(
    n_cliques: int, clique_size: int, seed: int = 0, shuffle: bool = True
) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic ground truth used in unit tests: cliques + one ring edge."""
    rng = np.random.default_rng(seed)
    edges = []
    for k in range(n_cliques):
        base = k * clique_size
        for a in range(clique_size):
            for b in range(a + 1, clique_size):
                edges.append((base + a, base + b))
        nxt = ((k + 1) % n_cliques) * clique_size
        edges.append((base, nxt))
    edges = np.array(edges, dtype=np.int32)
    labels = np.repeat(np.arange(n_cliques, dtype=np.int32), clique_size)
    if shuffle:
        rng.shuffle(edges, axis=0)
    return edges, labels
