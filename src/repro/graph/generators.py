"""Synthetic graph generators reproducing the paper's experimental regimes.

SNAP datasets are not available offline; these generators produce graphs with
the same *structure* the paper exploits: planted communities (SBM — quality
benchmarks, F1/NMI vs ground truth) and heavy-tailed degree graphs
(Chung–Lu — speed benchmarks up to ~1e8 edges).  All return edge *streams*
(random order, as the paper assumes) as ``(m, 2) int32`` arrays.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class GraphSpec:
    name: str
    n: int
    m: int  # number of streamed edges (multi-edges possible, as in the paper)


def sbm_stream(
    n: int,
    n_communities: int,
    avg_degree: float = 16.0,
    p_intra: float = 0.8,
    seed: int = 0,
    shuffle: bool = True,
) -> Tuple[np.ndarray, np.ndarray]:
    """Planted-partition stream: returns (edges, ground_truth labels).

    ``p_intra`` is the probability an edge is intra-community.  Endpoints are
    drawn uniformly inside the chosen block(s); self-loops resampled cheaply.
    Multi-edges may occur (the paper's setting is an unweighted multi-graph).
    """
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    labels = rng.integers(0, n_communities, size=n).astype(np.int32)
    # Bucket nodes by community for O(1) within-block sampling.
    order = np.argsort(labels, kind="stable")
    sorted_labels = labels[order]
    starts = np.searchsorted(sorted_labels, np.arange(n_communities))
    ends = np.searchsorted(sorted_labels, np.arange(n_communities), side="right")
    sizes = ends - starts
    # Intra edges draw only from communities that actually got nodes — an
    # empty block's `starts` would index past `order` (or into the next
    # block).  When nothing is empty this is draw-for-draw identical to
    # sampling community ids directly.
    nonempty = np.flatnonzero(sizes > 0)

    intra = rng.random(m) < p_intra
    # Community of each intra edge ~ proportional to block size (uniform edge).
    comm = nonempty[rng.integers(0, len(nonempty), size=m)]
    u = np.empty(m, dtype=np.int64)
    w = np.empty(m, dtype=np.int64)

    ss = sizes[comm]
    a = starts[comm] + rng.integers(0, 2**62, size=m) % ss
    b = starts[comm] + rng.integers(0, 2**62, size=m) % ss
    u_i, w_i = order[a], order[b]

    u_o = rng.integers(0, n, size=m)
    w_o = rng.integers(0, n, size=m)

    u = np.where(intra, u_i, u_o)
    w = np.where(intra, w_i, w_o)
    # Remove self-loops by shifting one endpoint (keeps the distribution close
    # enough; the paper assumes no self-loops).
    loops = u == w
    w = np.where(loops, (w + 1) % n, w)

    edges = np.stack([u, w], axis=1).astype(np.int32)
    if shuffle:
        rng.shuffle(edges, axis=0)
    return edges, labels


def chung_lu_stream(
    n: int, m: int, gamma: float = 2.5, seed: int = 0
) -> np.ndarray:
    """Power-law expected-degree stream (speed benchmarks; no ground truth)."""
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-1.0 / (gamma - 1.0))
    p = w / w.sum()
    cdf = np.cumsum(p)
    cdf[-1] = 1.0  # float cumsum undershoots 1.0; a draw past it would
    #               searchsorted to index n, off the end of `perm`
    u = np.searchsorted(cdf, rng.random(m))
    v = np.searchsorted(cdf, rng.random(m))
    v = np.where(u == v, (v + 1) % n, v)
    perm = rng.permutation(n)  # decorrelate node id from degree
    return np.stack([perm[u], perm[v]], axis=1).astype(np.int32)


def chung_lu_segments(
    n: int, gamma: float = 2.5, seed: int = 0, seed_offset: int = 0
):
    """Segment generator for a power-law stream (``GeneratorSource`` form).

    Returns ``segment(start, length) -> (length, 2) int32`` where the RNG is
    seeded per absolute offset ``(seed, start)``, so any row range of the
    stream can be regenerated independently — benchmark-scale graphs stream
    with O(segment) edge memory, and a suspended run resumes mid-stream
    without replaying.  (A different realization than :func:`chung_lu_stream`,
    which draws the full stream from one RNG; same distribution.)

    ``seed_offset`` folds a tenant index into the per-segment seed, so a
    fleet of ``T`` sources (``seed_offset=t``) draws ``T`` independent
    streams from one base ``seed`` without O(T) seed bookkeeping.  The
    default ``0`` reproduces the historical single-stream realization
    exactly (same seed sequence, same rows).

    The O(n) weight CDF and id permutation are computed once per source —
    node-space memory, like the clustering state itself.
    """
    rng = np.random.default_rng(seed)
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-1.0 / (gamma - 1.0))
    cdf = np.cumsum(w / w.sum())
    cdf[-1] = 1.0  # float cumsum undershoots 1.0; a draw past it would
    #               searchsorted to index n, off the end of `perm`
    perm = rng.permutation(n)
    key = [seed] if seed_offset == 0 else [seed, 2, seed_offset]

    def segment(start: int, length: int) -> np.ndarray:
        r = np.random.default_rng(key + [start])
        u = np.searchsorted(cdf, r.random(length))
        v = np.searchsorted(cdf, r.random(length))
        v = np.where(u == v, (v + 1) % n, v)
        return np.stack([perm[u], perm[v]], axis=1).astype(np.int32)

    return segment


def sbm_segments(
    n: int,
    n_communities: int,
    p_intra: float = 0.8,
    seed: int = 0,
    seed_offset: int = 0,
):
    """Segment generator for a planted-partition stream + its ground truth.

    Returns ``(segment_fn, labels)``; like :func:`chung_lu_segments`, each
    segment is regenerable from its absolute offset alone.  The community
    assignment (O(n), node-space memory) is fixed by ``seed``.

    ``seed_offset`` folds a tenant index into both the partition and the
    per-segment seeds — a fleet of ``T`` sources (``seed_offset=t``) gets
    ``T`` independent planted partitions and streams from one base
    ``seed``.  The default ``0`` reproduces the historical realization
    exactly.
    """
    rng = np.random.default_rng(
        seed if seed_offset == 0 else [seed, 3, seed_offset]
    )
    labels = rng.integers(0, n_communities, size=n).astype(np.int32)
    order = np.argsort(labels, kind="stable")
    sorted_labels = labels[order]
    starts = np.searchsorted(sorted_labels, np.arange(n_communities))
    ends = np.searchsorted(sorted_labels, np.arange(n_communities), side="right")
    sizes = ends - starts
    # See sbm_stream: empty communities must not be drawn for intra edges.
    nonempty = np.flatnonzero(sizes > 0)
    key = [seed, 1] if seed_offset == 0 else [seed, 3, seed_offset]

    def segment(start: int, length: int) -> np.ndarray:
        r = np.random.default_rng(key + [start])
        intra = r.random(length) < p_intra
        comm = nonempty[r.integers(0, len(nonempty), size=length)]
        ss = sizes[comm]
        a = starts[comm] + r.integers(0, 2**62, size=length) % ss
        b = starts[comm] + r.integers(0, 2**62, size=length) % ss
        u = np.where(intra, order[a], r.integers(0, n, size=length))
        w_ = np.where(intra, order[b], r.integers(0, n, size=length))
        w_ = np.where(u == w_, (w_ + 1) % n, w_)
        return np.stack([u, w_], axis=1).astype(np.int32)

    return segment, labels


def ring_of_cliques(
    n_cliques: int, clique_size: int, seed: int = 0, shuffle: bool = True
) -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic ground truth used in unit tests: cliques + one ring edge."""
    rng = np.random.default_rng(seed)
    edges = []
    for k in range(n_cliques):
        base = k * clique_size
        for a in range(clique_size):
            for b in range(a + 1, clique_size):
                edges.append((base + a, base + b))
        nxt = ((k + 1) % n_cliques) * clique_size
        edges.append((base, nxt))
    edges = np.array(edges, dtype=np.int32)
    labels = np.repeat(np.arange(n_cliques, dtype=np.int32), clique_size)
    if shuffle:
        rng.shuffle(edges, axis=0)
    return edges, labels
