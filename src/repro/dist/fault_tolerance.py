"""Fault tolerance for long training runs: preemption drain + straggler watch.

:class:`PreemptionHandler` turns SIGTERM/SIGINT into a cooperative flag the
training loop polls (checkpoint, then exit cleanly).  :class:`HeartbeatMonitor`
tracks per-step wall time over a sliding window and flags steps that exceed
``straggler_factor`` × the window median — the single-host stand-in for the
multi-host heartbeat service.
"""

from __future__ import annotations

import signal
import statistics
import time
from collections import deque
from typing import Deque, Dict, List, Optional


class PreemptionHandler:
    """Cooperative preemption: ``install()`` hooks SIGTERM, loops poll
    ``preempted`` and drain (checkpoint + exit) instead of dying mid-step."""

    def __init__(self):
        self._preempted = False
        self._prev_handlers: Dict[int, object] = {}

    @property
    def preempted(self) -> bool:
        return self._preempted

    def request(self) -> None:
        """Mark preemption requested (signal handler / tests / schedulers)."""
        self._preempted = True

    def install(self, signals=(signal.SIGTERM,)) -> Dict[int, object]:
        """Hook ``signals`` and return the handlers they displaced.

        The returned mapping (also remembered for :meth:`uninstall`) lets
        nested users compose: install, drain, then hand the signals back
        exactly as they were found.
        """
        prev: Dict[int, object] = {}
        for sig in signals:
            try:
                prev[sig] = signal.signal(sig, lambda *_: self.request())
            except ValueError:  # not in main thread — polling still works
                continue
            self._prev_handlers.setdefault(sig, prev[sig])
        return prev

    def uninstall(self) -> None:
        """Restore every handler displaced by :meth:`install`."""
        for sig, handler in self._prev_handlers.items():
            try:
                signal.signal(sig, handler)
            except (ValueError, TypeError):
                pass
        self._prev_handlers.clear()


class HeartbeatMonitor:
    """Sliding-window step timer with straggler detection.

    ``step_start()`` / ``step_end(step)`` bracket each training step;
    ``step_end`` returns True (and records the event in ``stragglers``) when
    the step took more than ``straggler_factor`` × the median of the last
    ``window`` step durations.  Needs ``min_history`` samples before flagging
    so compile-heavy first steps don't trip it.
    """

    def __init__(
        self,
        window: int = 20,
        straggler_factor: float = 3.0,
        min_history: int = 3,
    ):
        self.window = window
        self.straggler_factor = straggler_factor
        self.min_history = min_history
        self._durations: Deque[float] = deque(maxlen=window)
        self._t0: Optional[float] = None
        self.stragglers: List[Dict] = []

    @property
    def median(self) -> Optional[float]:
        if not self._durations:
            return None
        return statistics.median(self._durations)

    def step_start(self) -> None:
        self._t0 = time.perf_counter()

    def step_end(self, step: int) -> bool:
        assert self._t0 is not None, "step_end without step_start"
        dur = time.perf_counter() - self._t0
        self._t0 = None
        med = self.median
        is_straggler = (
            len(self._durations) >= self.min_history
            and med is not None
            and dur > self.straggler_factor * med
        )
        if is_straggler:
            self.stragglers.append({"step": step, "seconds": dur, "median": med})
        self._durations.append(dur)
        return is_straggler
