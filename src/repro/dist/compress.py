"""int8 gradient compression with error feedback (EF-SGD style).

Gradients are quantised to per-tensor symmetric int8 before the (simulated)
all-reduce; the quantisation residual is carried in an error-feedback buffer
and added back the next step, so the *accumulated* applied update is unbiased
— ``mean_t(dequant(g + e_t)) -> g`` with a bounded residual.  Pure ``jnp``,
traceable inside the jitted train step.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params: Any) -> Any:
    """Zero residual buffer, one per parameter leaf (float32)."""
    return jax.tree.map(lambda p: jnp.zeros(jnp.shape(p), jnp.float32), params)


def _compress_leaf(g: jax.Array, e: jax.Array) -> Tuple[jax.Array, jax.Array]:
    x = g.astype(jnp.float32) + e
    scale = jnp.max(jnp.abs(x)) / 127.0
    safe = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / safe), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * safe
    deq = jnp.where(scale > 0, deq, jnp.zeros_like(deq))
    return deq, x - deq


def compress_grads(grads: Any, ef: Any) -> Tuple[Any, Any]:
    """Quantise+dequantise ``grads`` with error feedback ``ef``.

    Returns ``(applied_grads, new_ef)`` — the dequantised gradients actually
    applied this step and the updated residual buffer.
    """
    out = jax.tree.map(_compress_leaf, grads, ef)
    applied = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return applied, new_ef
