"""Distribution substrate: sharding rules, gradient compression, fault
tolerance.  Consumed by ``models/transformer.py`` (logical sharding
constraints), ``train/train_step.py`` (int8 grad compression with error
feedback), and the launch drivers (preemption drain, straggler detection)."""

from repro.dist import compress, fault_tolerance, sharding  # noqa: F401
