"""Mesh-aware sharding rules for params, batches, caches, and activations.

Two mesh axes: ``data`` (batch parallel) and ``model`` (tensor parallel).
All helpers degrade gracefully — an axis is only used when it divides the
corresponding array dimension (``_fit_spec``), so smoke configs with tiny
dims run replicated instead of failing.

:func:`constrain` applies *logical* activation constraints by name
("q_heads", "act", "logits", ...) and is a no-op outside a
:func:`sharding_context` — single-device code paths pay nothing.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_dist_active_mesh", default=None
)

# Logical activation names -> per-axis mesh axes, aligned to the LAST dims of
# the array (leading dims replicated).  Shapes: acts (B, S, D), per-head
# tensors (B, S, H, Dh), logits (B, S, V).
_LOGICAL_RULES = {
    "act": ("data", None, None),
    "act_heads": ("data", None, None),
    "q_heads": ("data", None, "model", None),
    "kv_heads": ("data", None, "model", None),
    "logits": ("data", None, "model"),
}


@contextlib.contextmanager
def sharding_context(mesh: Mesh):
    """Activate ``mesh`` for :func:`constrain` within the block."""
    token = _ACTIVE_MESH.set(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE_MESH.reset(token)


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH.get()


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return size


def _fit_spec(mesh: Mesh, spec: P, shape: Sequence[int]) -> P:
    """Drop spec axes that are absent from the mesh or don't divide the dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, axes in zip(shape, entries):
        if axes is None:
            out.append(None)
            continue
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        if all(a in mesh.shape for a in names) and dim % _axis_size(mesh, names) == 0:
            out.append(axes)
        else:
            out.append(None)
    return P(*out)


def _aligned_spec(rule: Sequence, ndim: int) -> P:
    """Align a logical rule to the trailing dims of an ``ndim``-array."""
    rule = tuple(rule)
    if ndim >= len(rule):
        return P(*([None] * (ndim - len(rule)) + list(rule)))
    return P(*rule[len(rule) - ndim :])


def constrain(x: jax.Array, name: str) -> jax.Array:
    """Apply the logical sharding constraint ``name`` (no-op w/o a mesh)."""
    mesh = _ACTIVE_MESH.get()
    if mesh is None:
        return x
    rule = _LOGICAL_RULES.get(name)
    if rule is None:
        return x
    spec = _fit_spec(mesh, _aligned_spec(rule, x.ndim), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh, shape: Sequence[int]) -> NamedSharding:
    """Shard the leading (batch) dim over ``data`` when divisible."""
    return NamedSharding(mesh, _fit_spec(mesh, P("data"), shape))


# ---------------------------------------------------------------------------
# Parameter shardings (name-rule + generic fallback)
# ---------------------------------------------------------------------------

# Leaf-name rules, aligned to trailing dims (stacked cycle leaves carry a
# leading n_cycles axis).  Column-parallel projections shard their output
# features; row-parallel (wo/out_proj/w2) shard their input features.
_PARAM_RULES = {
    "embed": ("model", None),
    "lm_head": (None, "model"),
    "wq": (None, "model"),
    "wk": (None, "model"),
    "wv": (None, "model"),
    "wq_c": (None, "model"),
    "wk_c": (None, "model"),
    "wv_c": (None, "model"),
    "wq_b": (None, "model"),
    "w1": (None, "model"),
    "w3": (None, "model"),
    "in_proj": (None, "model"),
    "wo": ("model", None),
    "wo_c": ("model", None),
    "w2": ("model", None),
    "out_proj": ("model", None),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _generic_spec(mesh: Mesh, shape: Sequence[int]) -> P:
    """Fallback: shard the largest dim that the ``model`` axis divides."""
    if "model" not in mesh.shape or not shape:
        return P()
    msize = mesh.shape["model"]
    best, best_dim = -1, 0
    for i, dim in enumerate(shape):
        if dim % msize == 0 and dim > best_dim and dim >= msize:
            best, best_dim = i, dim
    if best < 0:
        return P()
    out = [None] * len(shape)
    out[best] = "model"
    return P(*out)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    """NamedSharding pytree for a parameter (shape-)pytree."""

    def leaf(path, p):
        shape = tuple(np.shape(p)) if not hasattr(p, "shape") else tuple(p.shape)
        rule = _PARAM_RULES.get(_leaf_name(path))
        if rule is not None and len(shape) >= 1:
            spec = _fit_spec(mesh, _aligned_spec(rule, len(shape)), shape)
        else:
            spec = _fit_spec(mesh, _generic_spec(mesh, shape), shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, params)


def cache_shardings(cache: Any, mesh: Mesh) -> Any:
    """NamedSharding pytree for a decode cache: batch dim over ``data``."""

    def leaf(p):
        shape = tuple(p.shape) if hasattr(p, "shape") else ()
        return NamedSharding(mesh, _fit_spec(mesh, P("data"), shape))

    return jax.tree_util.tree_map(leaf, cache)
