"""llama-3.2-vision-90b [vlm]: cross-attn image layers every 5th layer.
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (B, n_image_tokens, d_model).  [hf:meta-llama/...-Vision; unverified]"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=(("global", "dense"),) * 4 + (("cross", "dense"),),
    rope_theta=500_000.0,
    tie_embeddings=False,
    n_image_tokens=2048,
    notes="80 self-attn + 20 gated cross-attn layers; full attention → "
    "long_500k skipped",
)

SMOKE = FULL.replace(
    n_layers=5,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab_size=512,
    n_image_tokens=128,
)
