"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 2:1.
[arXiv:2402.19427; hf]"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=(
        ("recurrent", "dense"),
        ("recurrent", "dense"),
        ("local", "dense"),
    ),
    window=2048,
    lru_width=2560,
    conv_width=4,
    tie_embeddings=True,
    act="gelu",
    supports_long_context=True,  # recurrent state + windowed attention
    notes="Griffin-style: 2 RG-LRU blocks : 1 local-MQA (w=2048)",
)

SMOKE = FULL.replace(
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    lru_width=64,
    window=16,
)
