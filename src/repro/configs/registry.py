"""Architecture registry: ``--arch <id>`` resolution + per-arch shape cells.

Every architecture runs ``train_4k``, ``prefill_32k``, ``decode_32k``.
``long_500k`` requires sub-quadratic attention and runs only for
gemma3-1b (5:1 sliding window), recurrentgemma-2b (hybrid), mamba2-1.3b
(SSM); the skip rationale per arch is in each config's ``notes`` and
DESIGN.md §5.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import (
    deepseek_v2_236b,
    gemma3_1b,
    llama3_405b,
    llama32_vision_90b,
    mamba2_13b,
    phi3_mini,
    phi35_moe,
    qwen15_05b,
    recurrentgemma_2b,
    whisper_medium,
)
from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
)

_MODULES = {
    "gemma3-1b": gemma3_1b,
    "llama3-405b": llama3_405b,
    "qwen1.5-0.5b": qwen15_05b,
    "phi3-mini-3.8b": phi3_mini,
    "recurrentgemma-2b": recurrentgemma_2b,
    "mamba2-1.3b": mamba2_13b,
    "llama-3.2-vision-90b": llama32_vision_90b,
    "whisper-medium": whisper_medium,
    "deepseek-v2-236b": deepseek_v2_236b,
    "phi3.5-moe-42b-a6.6b": phi35_moe,
}

REGISTRY: Dict[str, ModelConfig] = {k: m.FULL for k, m in _MODULES.items()}
SMOKE_REGISTRY: Dict[str, ModelConfig] = {k: m.SMOKE for k, m in _MODULES.items()}


def get_config(name: str) -> ModelConfig:
    return REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    return SMOKE_REGISTRY[name]


def shapes_for(cfg: ModelConfig) -> List[ShapeConfig]:
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        shapes.append(LONG_500K)
    return shapes


def all_cells() -> List[Tuple[str, ShapeConfig, bool]]:
    """All 40 (arch, shape, live) cells; live=False are documented skips."""
    cells = []
    for name, cfg in REGISTRY.items():
        for shape in ALL_SHAPES:
            live = shape.name != "long_500k" or cfg.supports_long_context
            cells.append((name, shape, live))
    return cells
