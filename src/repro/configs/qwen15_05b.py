"""qwen1.5-0.5b [dense]: 24L MHA with QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    block_pattern=(("global", "dense"),),
    qkv_bias=True,
    tie_embeddings=True,
    notes="MHA (kv=16), QKV bias, 152k vocab",
)

SMOKE = FULL.replace(
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
)
