"""phi3-mini-3.8b [dense]: 32L RoPE SwiGLU.  [arXiv:2404.14219; unverified]"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    block_pattern=(("global", "dense"),),
    tie_embeddings=False,
    notes="MHA (kv=32), RoPE + SwiGLU",
)

SMOKE = FULL.replace(
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
)
