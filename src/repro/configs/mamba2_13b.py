"""mamba2-1.3b [ssm]: attention-free SSD.  [arXiv:2405.21060; unverified]"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    head_dim=1,
    d_ff=0,
    vocab_size=50280,
    block_pattern=(("ssm", "none"),),
    d_inner=4096,
    ssm_state=128,
    ssm_heads=64,
    ssm_head_dim=64,
    ssm_groups=1,
    conv_width=4,
    ssm_chunk=128,
    tie_embeddings=True,
    supports_long_context=True,  # O(1) state decode
    notes="SSD (state-space duality); no attention, no FFN",
)

SMOKE = FULL.replace(
    n_layers=4,
    d_model=64,
    d_inner=128,
    ssm_state=16,
    ssm_heads=4,
    ssm_head_dim=32,
    vocab_size=512,
    ssm_chunk=32,
)
