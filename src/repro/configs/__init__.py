from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
)
from repro.configs.registry import REGISTRY, get_config, get_smoke_config  # noqa: F401
