"""deepseek-v2-236b [moe]: MLA (kv_lora=512) + 2 shared / 160 routed top-6
experts; first layer dense.  [arXiv:2405.04434; hf]"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,  # unused by MLA (nope/rope/v dims below)
    d_ff=12288,  # dense FFN of the first (prefix) layer
    vocab_size=102400,
    prefix_pattern=(("mla", "dense"),),
    block_pattern=(("mla", "moe"),),
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    d_expert=1536,
    q_lora=1536,
    kv_lora=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    tie_embeddings=False,
    notes="MLA latent cache (512+64); MoE 160e top-6 + 2 shared; "
    "full attention → long_500k skipped",
)

SMOKE = FULL.replace(
    n_layers=3,
    d_model=64,
    n_heads=4,
    d_ff=128,
    vocab_size=512,
    n_experts=8,
    top_k=2,
    n_shared_experts=1,
    d_expert=32,
    q_lora=48,
    kv_lora=32,
    rope_head_dim=8,
    nope_head_dim=16,
    v_head_dim=16,
)
