"""Model configuration schema covering all 10 assigned architectures.

A model is a stack of *blocks*; each block is ``(mixing, ffn)`` where

  mixing ∈ {"global", "local", "cross", "dec_cross", "enc", "mla",
            "recurrent", "ssm"}
  ffn    ∈ {"dense", "moe", "none"}

The stack is ``prefix_blocks`` (unrolled, e.g. deepseek's first dense layer)
followed by ``n_cycles`` repetitions of ``block_pattern`` (scanned — weights
stacked on a leading cycle axis) followed by ``suffix_blocks`` (unrolled
remainder when n_layers is not a multiple of the pattern length).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

Block = Tuple[str, str]  # (mixing, ffn)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[Block, ...] = (("global", "dense"),)
    prefix_pattern: Tuple[Block, ...] = ()
    # attention
    window: int = 0
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    logit_softcap: float = 0.0
    # embeddings / head
    tie_embeddings: bool = True
    act: str = "silu"
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # MLA (deepseek)
    q_lora: int = 0
    kv_lora: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 SSD)
    d_inner: int = 0
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 128
    # RG-LRU (recurrentgemma)
    lru_width: int = 0
    # encoder-decoder (whisper) / VLM
    encoder_layers: int = 0
    n_frames: int = 0  # whisper stub: precomputed frame embeddings length
    n_image_tokens: int = 0  # vlm stub: precomputed patch embeddings length
    # numerics
    dtype: str = "bfloat16"
    kv_dtype: str = "bfloat16"  # "float8_e4m3fn" halves big decode caches
    # serving
    supports_long_context: bool = False  # sub-quadratic → long_500k cell runs
    # harness
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def layer_stack(self) -> Tuple[Tuple[Block, ...], int, Tuple[Block, ...]]:
        """(prefix, n_cycles, suffix) covering exactly n_layers blocks."""
        body = self.n_layers - len(self.prefix_pattern)
        cyc = len(self.block_pattern)
        n_cycles = body // cyc
        rem = body - n_cycles * cyc
        return self.prefix_pattern, n_cycles, self.block_pattern[:rem]

    @property
    def q_heads_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        from repro.models.transformer import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.transformer import count_params_analytic

        return count_params_analytic(self, active_only=True)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell input shape (assigned per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
