"""llama3-405b [dense]: 126L GQA, 128k vocab.  [arXiv:2407.21783; unverified]"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    block_pattern=(("global", "dense"),),
    rope_theta=500_000.0,
    tie_embeddings=False,
    notes="dense GQA; full attention → long_500k skipped",
)

SMOKE = FULL.replace(
    n_layers=4,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=192,
    vocab_size=512,
)
