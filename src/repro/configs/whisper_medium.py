"""whisper-medium [audio]: enc-dec; conv frontend is a STUB — input_specs()
provides precomputed frame embeddings (B, n_frames, d_model).
[arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,  # decoder layers; encoder_layers below
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    block_pattern=(("dec_cross", "dense"),),
    encoder_layers=24,
    n_frames=1536,  # 1500 mel frames, lane-padded
    tie_embeddings=True,
    act="gelu",
    notes="24 enc + 24 dec layers; decoder = self + cross per layer; "
    "full attention → long_500k skipped",
)

SMOKE = FULL.replace(
    n_layers=2,
    encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    n_frames=64,
)
