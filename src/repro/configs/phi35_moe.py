"""phi3.5-moe-42b-a6.6b [moe]: 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,  # per-expert hidden
    vocab_size=32064,
    block_pattern=(("global", "moe"),),
    n_experts=16,
    top_k=2,
    d_expert=6400,
    tie_embeddings=False,
    notes="GQA kv=8; 16 experts top-2; full attention → long_500k skipped",
)

SMOKE = FULL.replace(
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=512,
    n_experts=4,
    top_k=2,
    d_expert=64,
)
