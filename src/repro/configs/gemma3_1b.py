"""gemma3-1b [dense]: 26L, 5:1 local:global sliding-window attention.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    block_pattern=(("local", "dense"),) * 5 + (("global", "dense"),),
    window=512,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    act="gelu",
    supports_long_context=True,  # 5:1 sliding-window → sub-quadratic
    notes="5 local (w=512) : 1 global; 128k context; 262k vocab",
)

SMOKE = FULL.replace(
    n_layers=12,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    window=16,
)
