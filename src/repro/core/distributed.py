"""Multi-device streaming clustering: local pass + contracted global pass.

Beyond-paper distributed extension (paper §5 names parallelism as future
work).  The stream is split into ``P`` contiguous shards, one per device on
the ``data`` mesh axis:

1. **Local phase** (``shard_map``): every device runs the chunked Tier-2
   clusterer on its shard only — zero communication.
2. **Merge phase**: shard-local labels live in the global node-id space (a
   label is the founding node's id), so merging is a second clustering run on
   a *contracted stream*: (i) identity edges ``(c_s[i], c_{s+1}[i])`` linking
   each node's supernodes across consecutive shards — streamed FIRST so merges
   happen while volumes are small, then (ii) every original edge rewritten to
   its shard's supernodes.  Final label of node ``i`` is the phase-2 label of
   its first-active shard supernode.

Quality vs the single-stream algorithm is measured in
``benchmarks/table2_quality.py`` — not assumed.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.chunked import chunked_update
from repro.core.state import ClusterState
from repro.core.streaming import PAD
from repro.graph.sources import ShardedSource, as_source

Array = jax.Array


def _local_phase(shards: Array, v_max: int, n: int, chunk: int):
    """vmapped local clustering; one shard per device under pjit."""

    def one(shard):
        s = chunked_update(
            ClusterState.init(n), shard, jnp.int32(v_max), chunk=chunk
        )
        return s.c, s.d, s.v

    return jax.vmap(one)(shards)


@functools.partial(
    jax.jit, static_argnames=("v_max", "n", "chunk", "v_max2")
)
def _merge_phase(
    shards: Array,
    cs: Array,
    ds: Array,
    v_max: int,
    n: int,
    chunk: int,
    v_max2: int,
):
    """Contract + global clustering + label pull-back (replicated compute)."""
    Pn = cs.shape[0]
    # Identity edges: consecutive-shard supernodes of each active node.
    active = ds > 0  # (P, n)
    ident = []
    for s in range(Pn - 1):
        both = active[s] & active[s + 1]
        a = jnp.where(both, cs[s], PAD)
        b = jnp.where(both, cs[s + 1], PAD)
        ident.append(jnp.stack([a, b], axis=1))
    ident = (
        jnp.concatenate(ident, axis=0)
        if ident
        else jnp.zeros((0, 2), jnp.int32)
    )
    # Original edges rewritten to their own shard's supernodes.
    def rewrite(shard, c_s):
        live = (shard[:, 0] != PAD) & (shard[:, 1] != PAD)
        a = jnp.where(live, c_s[jnp.maximum(shard[:, 0], 0)], PAD)
        b = jnp.where(live, c_s[jnp.maximum(shard[:, 1], 0)], PAD)
        return jnp.stack([a, b], axis=1)

    contracted = jax.vmap(rewrite)(shards, cs).reshape(-1, 2)
    stream2 = jnp.concatenate([ident, contracted], axis=0)
    # Intra-supernode contracted edges become self-loops, which the clusterer
    # skips — seed the phase-2 state with that internal mass (+2 per edge) so
    # the v_max threshold still sees each supernode's true volume.
    selfmask = (stream2[:, 0] == stream2[:, 1]) & (stream2[:, 0] != PAD)
    tgt = jnp.where(selfmask, stream2[:, 0], n)
    self_mass = (
        jnp.zeros(n + 1, jnp.int32).at[tgt].add(2 * selfmask.astype(jnp.int32))
    )[:n]
    seed = ClusterState.init(n)
    seed.d = self_mass
    seed.v = self_mass
    c2 = chunked_update(seed, stream2, jnp.int32(v_max2), chunk=chunk).c

    # Pull back: node -> first-active-shard supernode -> phase-2 label.
    any_active = active.any(axis=0)
    s_first = jnp.argmax(active, axis=0)
    label1 = jnp.where(
        any_active, cs[s_first, jnp.arange(n)], jnp.arange(n, dtype=jnp.int32)
    )
    return c2[label1]


def distributed_cluster(
    edges,
    v_max: int,
    n: int,
    mesh: Optional[Mesh] = None,
    n_shards: Optional[int] = None,
    chunk: int = 1024,
    v_max2: Optional[int] = None,
) -> Tuple[np.ndarray, dict]:
    """Cluster an edge stream across devices.  Returns (labels, info).

    ``edges`` may be a host array or any :class:`repro.graph.sources
    .EdgeSource`; out-of-core sources are split contiguously by
    ``ShardedSource`` with a single streaming fill (the stacked shard array
    itself is O(m) by necessity — all shards live on devices at once).
    """
    if mesh is not None:
        n_shards = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    n_shards = n_shards or 1
    v_max2 = v_max2 if v_max2 is not None else v_max
    # ShardedSource.stacked fills (n_shards, shard_len, 2) with one streaming
    # pass; for an in-memory array that is the same single copy shard_stream
    # would make, so every source type takes this one path.
    shards = jnp.asarray(ShardedSource(as_source(edges), n_shards).stacked())

    local = jax.jit(
        functools.partial(_local_phase, v_max=v_max, n=n, chunk=chunk)
    )
    if mesh is not None:
        spec = NamedSharding(mesh, P(mesh.axis_names))
        shards = jax.device_put(shards, spec)
        local = jax.jit(
            functools.partial(_local_phase, v_max=v_max, n=n, chunk=chunk),
            in_shardings=spec,
        )
    cs, ds, vs = local(shards)
    labels = _merge_phase(shards, cs, ds, v_max, n, chunk, v_max2)
    info = {"n_shards": n_shards}
    return np.asarray(labels), info
