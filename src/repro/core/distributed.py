"""Sharded streaming clustering: per-shard local passes + contracted merge.

Beyond-paper distributed extension (paper §5 names parallelism as future
work).  The stream is dealt onto ``P`` shards at batch granularity
(:class:`~repro.core.state.ShardedState` — one batch per shard reproduces
contiguous window sharding; more batches stripe an order-preserving
subsequence onto each shard):

1. **Local phase** (:func:`sharded_update`): each arriving batch runs the
   chunked Tier-2 clusterer against its shard's slice of the stacked state —
   zero cross-shard communication, host edge residency O(batch).  The old
   path that stacked the whole stream into one O(m) ``(P, shard_len, 2)``
   device array is gone; :func:`distributed_cluster` now drains
   ``ShardedSource.shards()`` window by window through the same update.
2. **Merge phase** (:func:`merge_sharded_state`): built *from the per-shard
   states alone* — no replay of the stream.  Shard-local labels live in the
   global node-id space (a label is the founding node's id), so merging is a
   second clustering run over the identity edges ``(c_s[i], c_{s+1}[i])``
   linking each node's supernodes across consecutive shards, with the
   phase-2 state seeded by each supernode's shard-local volume (its internal
   mass — what the old contracted self-loop pass approximated).  Final label
   of node ``i`` is the phase-2 label of its first-active shard supernode.

Because the merge needs only ``(c, d, v)`` per shard, the tier is resumable:
a :class:`ShardedState` checkpoints mid-stream like any other state pytree
and labels can be derived at any point.  Quality vs the single-stream
algorithm is measured in ``benchmarks/table2_quality.py`` — not assumed.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.chunked import chunked_update
from repro.core.state import ClusterState, ShardedState, count_live_edges
from repro.graph.pipeline import PAD
from repro.graph.sources import ShardedSource, as_source

Array = jax.Array

# Edges per drain batch in the one-shot ``distributed_cluster`` driver —
# bounds host residency per shard regardless of shard length.
_DRAIN_BATCH_EDGES = 1 << 20


def mesh_shards(mesh: Optional[Mesh]) -> Optional[int]:
    """Shard count implied by a mesh (product over all axes), or ``None``."""
    if mesh is None:
        return None
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


@functools.partial(
    jax.jit, static_argnames=("chunk",), donate_argnums=(0,)
)
def _sharded_update_jit(
    state: ShardedState, edges: Array, v_max: Array, shard: Array, chunk: int
) -> ShardedState:
    """One fused dispatch per batch: gather the shard's slice, run the
    chunked scan, scatter it back.  The stacked state is *donated*, so on
    accelerator backends the ``3Pn``-int update happens in place instead of
    copying the whole stack every step."""
    sub = ClusterState(
        d=state.d[shard], c=state.c[shard], v=state.v[shard],
        edges_seen=jnp.int32(0),
    )
    sub = chunked_update(sub, edges, v_max, chunk=chunk)
    return ShardedState(
        d=state.d.at[shard].set(sub.d),
        c=state.c.at[shard].set(sub.c),
        v=state.v.at[shard].set(sub.v),
        cursor=state.cursor + 1,
        # chunked_update seeded edges_seen=0, so sub carries this batch's
        # live-edge count
        edges_seen=state.edges_seen + sub.edges_seen,
    )


def sharded_update(
    state: ShardedState,
    edges: Array,
    v_max: Array,
    chunk: int = 1024,
    shard: Optional[int] = None,
) -> ShardedState:
    """Ingest one edge batch into one shard of a :class:`ShardedState`.

    ``shard`` defaults to ``cursor % P`` (round-robin batch dealing); the
    explicit form is used by :func:`distributed_cluster` to drain contiguous
    ``ShardedSource`` windows.  The cursor advances either way, so resumed
    runs continue the dealing sequence deterministically.

    The whole gather → chunked scan → scatter step is one jitted dispatch
    with the stacked state donated (callers must treat the passed-in state
    as consumed — the ``partial_fit`` contract).
    """
    P = state.n_shards
    # round-robin stays lazy (cursor % P on device) — no host sync per batch
    s = (
        jnp.asarray(state.cursor % P, jnp.int32)
        if shard is None
        else jnp.int32(shard)
    )
    return _sharded_update_jit(
        state, jnp.asarray(edges), jnp.int32(v_max), s, chunk=chunk
    )


def merge_sharded_state(
    state: ShardedState,
    v_max2: int,
    chunk: int = 1024,
) -> Tuple[np.ndarray, ClusterState]:
    """Contract + global clustering + label pull-back, from per-shard states.

    Returns ``(labels, merged_state)``: dense-space labels for every node and
    a merged :class:`ClusterState` (true node degrees, final labels, volumes
    re-derived as per-community degree sums) so the edge-free metrics
    (entropy / avg density) are available for this tier like any other.
    """
    n, P = state.n, state.n_shards
    cs = np.asarray(state.c)
    ds = np.asarray(state.d)
    vs = np.asarray(state.v)
    active = ds > 0  # (P, n)

    # Identity edges: each active node links its supernodes in *successive
    # active* shards (not adjacent shard indices — under batch striping a
    # node may skip a shard, and its chain must not break there).
    ident = []
    prev_label = np.full(n, PAD, np.int32)  # label at the node's last active shard
    for s in range(P):
        both = active[s] & (prev_label != PAD)
        if s > 0:
            a = np.where(both, prev_label, PAD).astype(np.int32)
            b = np.where(both, cs[s], PAD).astype(np.int32)
            ident.append(np.stack([a, b], axis=1))
        prev_label = np.where(active[s], cs[s], prev_label)
    ident_edges = (
        np.concatenate(ident, axis=0) if ident else np.zeros((0, 2), np.int32)
    )

    # Phase-2 seed: each supernode's shard-local volume is its internal mass;
    # masked to communities actually founded in that shard (stale volume
    # residue of absorbed communities must not leak in).
    seed_mass = np.zeros(n, np.int64)
    idx = np.arange(n)
    for s in range(P):
        live = np.zeros(n, bool)
        live[cs[s][active[s]]] = True
        seed_mass += np.where(live, vs[s], 0)
    seed = ClusterState.init(n)
    seed32 = np.minimum(seed_mass, np.iinfo(np.int32).max).astype(np.int32)
    # two placements, not one aliased buffer: chunked_update donates its
    # state, and donation rejects pytrees whose leaves share a buffer
    seed.d = jnp.asarray(seed32)
    seed.v = jnp.array(seed32)
    c2 = np.asarray(
        chunked_update(
            seed, jnp.asarray(ident_edges), jnp.int32(v_max2), chunk=chunk
        ).c
    )

    # Pull back: node -> first-active-shard supernode -> phase-2 label.
    any_active = active.any(axis=0)
    s_first = np.argmax(active, axis=0)
    label1 = np.where(any_active, cs[s_first, idx], idx.astype(np.int32))
    labels = c2[label1]

    d_total = ds.sum(axis=0, dtype=np.int64)
    d32 = np.minimum(d_total, np.iinfo(np.int32).max).astype(np.int32)
    v_merged = np.zeros(n, np.int64)
    np.add.at(v_merged, labels, d_total)
    merged = ClusterState(
        d=d32,
        c=labels.astype(np.int32),
        v=np.minimum(v_merged, np.iinfo(np.int32).max).astype(np.int32),
        edges_seen=np.int64(state.edges_seen),
    )
    return labels, merged


def distributed_cluster(
    edges,
    v_max: int,
    n: int,
    mesh: Optional[Mesh] = None,
    n_shards: Optional[int] = None,
    chunk: int = 1024,
    v_max2: Optional[int] = None,
) -> Tuple[np.ndarray, dict]:
    """Cluster an edge stream across ``P`` contiguous shards.

    .. deprecated:: use ``repro.cluster.cluster(..., backend="distributed")``.

    ``edges`` may be a host array or any :class:`repro.graph.sources
    .EdgeSource`.  Each ``ShardedSource`` window is drained batch-by-batch
    through the chunked tier's state threading (:func:`sharded_update`), so
    host edge residency is O(batch) per shard — the stacked O(m) device
    array of the previous implementation no longer exists.  ``mesh`` is
    accepted for the shard count only (``P = prod(mesh axes)``).
    """
    n_shards = mesh_shards(mesh) or n_shards or 1
    v_max2 = v_max2 if v_max2 is not None else v_max
    sharded = ShardedSource(as_source(edges), n_shards)
    state = ShardedState.init(n, n_shards)
    for s, window in enumerate(sharded.shards()):
        for batch in window.batches(_DRAIN_BATCH_EDGES):
            state = sharded_update(state, batch, v_max, chunk=chunk, shard=s)
    labels, _ = merge_sharded_state(state, v_max2, chunk=chunk)
    info = {"n_shards": n_shards}
    return np.asarray(labels), info
