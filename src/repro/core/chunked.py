"""Tier-2 chunked-batch streaming clustering (TPU-native, beyond-paper).

Processes the stream in fixed-size chunks.  All edges in a chunk read the
*pre-chunk* state ("Jacobi" semantics): decisions are computed vectorised on
the VPU, write conflicts are resolved first-in-stream-order-wins via
scatter-min, and state updates are applied with commutative scatter-adds.

This trades bit-exactness with the paper's strictly-sequential order for
parallelism; quality parity is *measured* in benchmarks (not assumed), and a
bit-exact serial-in-VMEM Pallas kernel is provided in
``repro.kernels.edge_stream`` for when exact semantics are required.

State layout: arrays of size ``n + 1`` — slot ``n`` is a write sink for
padded/no-op edges, so the inner loop is branch-free.  The public surface
takes/returns :class:`repro.core.state.ClusterState` (size ``n``); the sink
slot is an internal detail appended/stripped here.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.state import ClusterState, count_live_edges
from repro.graph.pipeline import PAD, pad_edges_to_chunks

Array = jax.Array


def _chunk_update(state, chunk, *, v_max: int, n: int):
    """Apply one chunk (B, 2) of edges with Jacobi semantics."""
    d, c, v = state  # each (n + 1,)
    B = chunk.shape[0]
    i_raw, j_raw = chunk[:, 0], chunk[:, 1]
    live = (i_raw != PAD) & (j_raw != PAD) & (i_raw != j_raw)
    sink = jnp.int32(n)
    i = jnp.where(live, i_raw, sink)
    j = jnp.where(live, j_raw, sink)
    one = live.astype(jnp.int32)

    # Degree update — commutative, exact regardless of intra-chunk order.
    d = d.at[i].add(one).at[j].add(one)

    ci = c[i]
    cj = c[j]
    # Arrival volume update (+1 per endpoint community, labels frozen).
    v = v.at[ci].add(one).at[cj].add(one)

    vci = v[ci]
    vcj = v[cj]
    ok = live & (vci <= v_max) & (vcj <= v_max)
    i_joins = ok & (vci <= vcj)
    j_joins = ok & (vci > vcj)

    mover = jnp.where(i_joins, i, jnp.where(j_joins, j, sink))
    target = jnp.where(i_joins, cj, ci)
    src = jnp.where(i_joins, ci, cj)

    # First edge in stream order wins the right to move a given node.
    order = jnp.arange(B, dtype=jnp.int32)
    winner = jnp.full(n + 1, B, dtype=jnp.int32).at[mover].min(order)
    win = (mover != sink) & (winner[mover] == order)

    mover_w = jnp.where(win, mover, sink)
    dm = jnp.where(win, d[mover_w], 0)
    v = v.at[jnp.where(win, target, sink)].add(dm)
    v = v.at[jnp.where(win, src, sink)].add(-dm)
    c = c.at[mover_w].set(jnp.where(win, target, c[mover_w]))
    return (d, c, v), ()


def _scan_chunks(
    state: ClusterState, chunks: Array, v_max: Array, n: int
) -> ClusterState:
    """Scan the Jacobi chunk update over ``(n_chunks, chunk, 2)`` edges —
    the shared core of the per-batch and fused megabatch entry points (one
    compile, ``n_chunks`` chunk steps per dispatch)."""
    init = (
        jnp.concatenate([state.d.astype(jnp.int32), jnp.int32([0])]),
        jnp.concatenate([state.c.astype(jnp.int32), jnp.int32([n])]),
        jnp.concatenate([state.v.astype(jnp.int32), jnp.int32([0])]),
    )
    (d, c, v), _ = jax.lax.scan(
        functools.partial(_chunk_update, v_max=jnp.int32(v_max), n=n), init, chunks
    )
    return ClusterState(
        d=d[:n],
        c=c[:n],
        v=v[:n],
        edges_seen=state.edges_seen + count_live_edges(chunks.reshape(-1, 2), PAD),
    )


@functools.partial(
    jax.jit, static_argnames=("chunk",), donate_argnums=(0,)
)
def chunked_update(
    state: ClusterState, edges: Array, v_max: Array, chunk: int = 1024
) -> ClusterState:
    """State-threading chunked tier: ingest ``edges`` into ``state``.

    ``edges``: (m, 2) int32 (PAD-padded ok); the batch is padded up to a
    multiple of ``chunk`` internally, and PAD edges are no-ops — but note the
    *grouping* of edges into Jacobi chunks restarts at every call, so batch
    boundaries are chunk boundaries (deterministic, batching-dependent).

    ``state`` is *donated*: on accelerator backends its buffers are reused
    for the output (no per-step 3n-int copy), so callers must treat the
    passed-in state as consumed — exactly the ``partial_fit`` contract,
    which replaces its state with the returned one.
    """
    n = state.d.shape[0]
    padded, n_chunks = pad_edges_to_chunks(edges, chunk)
    return _scan_chunks(state, padded.reshape(n_chunks, chunk, 2), v_max, n)


@functools.partial(
    jax.jit, static_argnames=("chunk",), donate_argnums=(0,)
)
def chunked_update_megabatch(
    state: ClusterState, edges: Array, v_max: Array, chunk: int = 1024
) -> ClusterState:
    """Fused megabatch chunked tier: ingest ``(K, B, 2)`` stacked batches in
    *one* dispatch.

    The K batches are flattened and scanned as one ``lax.scan`` over
    ``K * B / chunk`` Jacobi chunks — when ``B`` is a multiple of ``chunk``
    (guaranteed for pipeline-staged megabatches: the ``BatchPipeline`` rounds
    its batch size up to the chunk for chunk-aligned backends), the chunk
    grouping is identical to ``K`` sequential :func:`chunked_update` calls,
    so labels are bit-identical to the per-batch path while dispatch/transfer
    overhead drops ~K-fold.  All-PAD trailing batches (a ragged tail
    megabatch) are no-ops.  ``state`` is donated, as in
    :func:`chunked_update`.
    """
    n = state.d.shape[0]
    K, B = edges.shape[0], edges.shape[1]
    padded, n_chunks = pad_edges_to_chunks(edges.reshape(K * B, 2), chunk)
    return _scan_chunks(state, padded.reshape(n_chunks, chunk, 2), v_max, n)


@functools.partial(
    jax.jit, static_argnames=("v_max", "n", "chunk"), donate_argnums=(4, 5)
)
def cluster_stream_chunked(
    edges: Array,
    v_max: int,
    n: int,
    chunk: int = 1024,
    init_d: Array | None = None,
    init_v: Array | None = None,
) -> Tuple[Array, Array, Array]:
    """One-shot chunked streaming clustering.  Returns ``(c, d, v)`` size n.

    .. deprecated:: use ``repro.cluster.cluster(..., backend="chunked")``.

    ``init_d`` / ``init_v`` (size n) seed the degree/volume state — used by the
    distributed merge phase to carry supernode internal mass into the
    contracted stream.
    """
    state = ClusterState.init(n)
    if init_d is not None:
        state.d = init_d.astype(jnp.int32)
    if init_v is not None:
        state.v = init_v.astype(jnp.int32)
    s = chunked_update(state, edges, jnp.int32(v_max), chunk=chunk)
    return s.c, s.d, s.v
