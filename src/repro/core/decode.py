"""Pure-JAX reference decoder for compressed megabatch slabs (DESIGN.md §14).

The compressed ingest path (:meth:`BatchPipeline.compressed_megabatches`)
ships DVE3 payload bytes plus a descriptor table instead of decoded edges;
this module is the *specification* of what decoding that slab means:

* every Pallas decode kernel is pinned bit-for-bit against
  :func:`decode_megabatch` by the device-decode test suite and the CI
  interpret leg;
* in interpret mode the backends dispatch this implementation directly
  (tracing a byte-unpack loop through the Pallas emulator would be
  pointless — the reference *is* the same math on the same vector units);
* :func:`chunked_decode_update_megabatch` fuses decode + the Jacobi
  megabatch update under one jit so the chunked tier keeps its
  one-dispatch-per-megabatch contract with ``device_decode`` on.

Decoded output is defined to equal the ``(K * B, 2)`` PAD-carved slab the
host-decode staging path would have produced for the same rows — that
identity (not merely label equality) is what makes cursors and labels
interchangeable between ``device_decode`` on and off.

All arithmetic is int32: the DVE3 encoder only emits device-decodable
(``DESC_FIXED``) blocks when every zigzag value fits 31 bits, so the
shift/xor/cumsum chain below is exact; wider blocks arrive host-decoded as
``DESC_RAW`` int32 rows.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.chunked import chunked_update_megabatch
from repro.core.state import ClusterState
from repro.graph.pipeline import (
    D_BASE,
    D_KIND,
    D_NROWS,
    D_OFF_I,
    D_OFF_J,
    D_ROW,
    D_W_I,
    D_W_J,
    DESC_EMPTY,
    DESC_RAW,
    PAD,
)


def _zigzag32(z):
    """Inverse zigzag on int32 (exact: fixed lanes are capped below 2**31)."""
    return (z >> 1) ^ -(z & 1)


def _lane_view(pay, nbytes):
    """Reinterpret the (padded) payload as little-endian ``nbytes``-wide
    lanes.  Segment offsets are ``_SEGMENT_ALIGN``-aligned, so a width-w
    column always starts on a w-aligned boundary and one gather per lane
    replaces the per-byte combine."""
    if nbytes == 1:
        return pay
    return jax.lax.bitcast_convert_type(
        pay.reshape(-1, nbytes), jnp.uint16 if nbytes == 2 else jnp.uint32
    )


def _gather_w(view, off, nbytes, window):
    """Gather (D, window) int32 lanes of width ``nbytes`` from the matching
    :func:`_lane_view`; ``off`` is in bytes.  Out-of-range indices clamp
    (their lanes are masked out downstream)."""
    k = jnp.arange(window, dtype=jnp.int32)
    idx = (off[:, None] // nbytes) + k[None, :]
    v = jnp.take(view, idx, mode="clip")
    if nbytes == 4:
        return jax.lax.bitcast_convert_type(v, jnp.int32)
    return v.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("window", "out_rows"))
def decode_megabatch(payload, desc, window: int, out_rows: int):
    """Decode a compressed slab to its ``(out_rows, 2)`` int32 edge slab.

    ``payload`` is the ``(P,)`` uint8 staging buffer, ``desc`` the
    ``(D, DESC_COLS)`` int32 descriptor table (:mod:`repro.graph.pipeline`
    layout).  Every descriptor decodes as one ``window``-row lane batch —
    fixed lanes are gathered per candidate width and selected, so the whole
    table decodes in a handful of vector passes with no host loop.  Rows
    past a descriptor's ``n_rows`` and rows no descriptor covers come out
    PAD, reproducing the host-staged slab exactly.
    """
    kind = desc[:, D_KIND]
    dest = desc[:, D_ROW]
    nrows = desc[:, D_NROWS]
    off_i, off_j = desc[:, D_OFF_I], desc[:, D_OFF_J]
    w_i, w_j = desc[:, D_W_I], desc[:, D_W_J]
    base = desc[:, D_BASE]

    view2 = _lane_view(payload, 2)
    view4 = _lane_view(payload, 4)

    def fixed_col(off, w):
        v1 = _gather_w(payload, off, 1, window)
        v2 = _gather_w(view2, off, 2, window)
        v4 = _gather_w(view4, off, 4, window)
        return jnp.where(
            w[:, None] == 1, v1, jnp.where(w[:, None] == 2, v2, v4)
        )

    di = _zigzag32(fixed_col(off_i, w_i))
    fixed_i = base[:, None] + jnp.cumsum(di, axis=1, dtype=jnp.int32)
    fixed_j = fixed_i + _zigzag32(fixed_col(off_j, w_j))

    # DESC_RAW: (n, 2) little-endian int32 pairs at off_i — 8-byte stride
    k = jnp.arange(window, dtype=jnp.int32)
    raw_idx = (off_i[:, None] // 4) + 2 * k[None, :]
    raw_i = jax.lax.bitcast_convert_type(
        jnp.take(view4, raw_idx, mode="clip"), jnp.int32
    )
    raw_j = jax.lax.bitcast_convert_type(
        jnp.take(view4, raw_idx + 1, mode="clip"), jnp.int32
    )

    raw = (kind == DESC_RAW)[:, None]
    vals_i = jnp.where(raw, raw_i, fixed_i)
    vals_j = jnp.where(raw, raw_j, fixed_j)

    # output-stationary assembly: each output row looks up its covering
    # descriptor (live descriptors tile the row space in ascending order;
    # dead table rows sort past the end) and gathers its lane — no scatter
    r = jnp.arange(out_rows, dtype=jnp.int32)
    dest_eff = jnp.where(kind == DESC_EMPTY, out_rows, dest)
    d = jnp.searchsorted(dest_eff, r, side="right").astype(jnp.int32) - 1
    d = jnp.clip(d, 0, desc.shape[0] - 1)
    lane = r - dest_eff[d]
    ok = (lane >= 0) & (lane < nrows[d]) & (kind[d] != DESC_EMPTY)
    flat = jnp.clip(d * window + lane, 0, None)
    out_i = jnp.where(ok, jnp.take(vals_i.reshape(-1), flat, mode="clip"), PAD)
    out_j = jnp.where(ok, jnp.take(vals_j.reshape(-1), flat, mode="clip"), PAD)
    return jnp.stack([out_i, out_j], axis=-1)


@functools.partial(
    jax.jit,
    static_argnames=("v_max", "window", "out_rows", "chunk"),
    donate_argnums=(0,),
)
def chunked_decode_update_megabatch(
    state: ClusterState,
    payload,
    desc,
    v_max: int,
    window: int,
    out_rows: int,
    chunk: int,
) -> ClusterState:
    """Decode a compressed slab and run the fused Jacobi megabatch update —
    one jit, one dispatch, exactly the slab the host-decode path would have
    fed ``chunked_update_megabatch`` (so labels are bit-identical to
    ``device_decode=False`` on the chunked tier)."""
    edges = decode_megabatch(payload, desc, window, out_rows)
    return chunked_update_megabatch(
        state, edges.reshape(1, out_rows, 2), jnp.int32(v_max), chunk=chunk
    )
