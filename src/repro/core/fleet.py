"""Vmapped fleet tier: advance ``T`` independent tenant streams per dispatch.

The fleet engine (DESIGN.md §13) stacks ``T`` per-tenant Algorithm-1 states
into one :class:`repro.core.state.FleetState` pytree and ingests a
``(T, B, 2)`` staged slab — one fixed-shape batch per tenant, carved by
``repro.graph.tenants.TenantRouter`` — with **one** donated dispatch.

Why ``vmap`` preserves per-tenant bit-exactness: the update for tenant ``t``
reads and writes only tenant ``t``'s state slab and edge slab — there is no
cross-tenant data flow — and the per-tenant math is integer arithmetic plus
integer scatter/gather, which XLA batching does not reassociate.  So row
``t`` of the fleet result equals the corresponding single-stream update
applied to tenant ``t``'s slab alone, for any fleet composition.  The other
half of the bit-identity contract lives in the router: each tenant's slab
sequence must equal the batch sequence a standalone single-stream run would
see (full ``B``-row batches, plus one final short batch when the tenant's
stream ends).

Two portable paths share this module (the tenant-major Pallas kernel lives
in ``repro.kernels.edge_stream``):

* :func:`fleet_update_chunked` — vmapped Jacobi chunked tier; per-tenant
  results bit-identical to single-stream ``chunked_update`` with the same
  batch/chunk geometry.
* :func:`fleet_update_scan` — vmapped per-edge ``lax.scan``; per-tenant
  results bit-identical to ``dense_update`` / the sequential Pallas kernel.

All-PAD tenant rows (idle tenants in a ragged fleet step) are true no-ops in
both paths: every masked write lands in the sink slot (chunked) or is an
identity write (scan), so an idle tenant's state is unchanged bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.chunked import _scan_chunks
from repro.core.state import ClusterState, FleetState
from repro.core.streaming import scan_update
from repro.graph.pipeline import PAD, round_up

Array = jax.Array


def _cluster_view(state: FleetState) -> ClusterState:
    """The fleet pytree reinterpreted as a tenant-batched ClusterState —
    the in/out carrier for ``jax.vmap`` over the single-stream updates."""
    return ClusterState(
        d=state.d, c=state.c, v=state.v, edges_seen=state.edges_seen
    )


def _fleet_view(state: ClusterState) -> FleetState:
    return FleetState(
        d=state.d, c=state.c, v=state.v, edges_seen=state.edges_seen
    )


@functools.partial(jax.jit, static_argnames=("chunk",), donate_argnums=(0,))
def fleet_update_chunked(
    state: FleetState, edges: Array, v_max: Array, chunk: int = 1024
) -> FleetState:
    """Ingest one ``(T, B, 2)`` fleet slab with the vmapped chunked tier.

    Each tenant's ``(B, 2)`` slab is padded up to a multiple of ``chunk``
    and scanned with the same Jacobi ``_chunk_update`` the single-stream
    chunked tier uses; ``vmap`` batches the scan over the tenant axis so the
    whole fleet is one dispatch.  Chunk grouping restarts at every slab —
    exactly as single-stream ``chunked_update`` restarts it at every batch —
    so per-tenant labels are bit-identical to a standalone chunked run fed
    the same batch sequence.  ``state`` is donated.
    """
    n = state.d.shape[1]
    T, B = edges.shape[0], edges.shape[1]
    b_pad = round_up(max(B, 1), chunk)
    padded = jnp.full((T, b_pad, 2), PAD, jnp.int32).at[:, :B, :].set(
        edges.astype(jnp.int32)
    )
    chunks = padded.reshape(T, b_pad // chunk, chunk, 2)
    out = jax.vmap(
        functools.partial(_scan_chunks, v_max=jnp.int32(v_max), n=n),
        in_axes=(0, 0),
    )(_cluster_view(state), chunks)
    return _fleet_view(out)


@functools.partial(jax.jit, donate_argnums=(0,))
def fleet_update_scan(
    state: FleetState, edges: Array, v_max: Array
) -> FleetState:
    """Ingest one ``(T, B, 2)`` fleet slab with the vmapped per-edge scan.

    Strict stream order *within* each tenant (the paper's semantics) — each
    tenant's row is bit-exact with ``dense_update`` / the sequential Pallas
    kernel over its own stream, independent of how slabs were grouped into
    fleet steps.  ``state`` is donated.
    """
    out = jax.vmap(
        lambda s, e: scan_update(s, e, jnp.int32(v_max)), in_axes=(0, 0)
    )(_cluster_view(state), edges.astype(jnp.int32))
    return _fleet_view(out)
