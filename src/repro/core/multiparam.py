"""One-pass multi-``v_max`` sweep (paper §2.5) — state-threaded.

The degree dictionary ``d`` is independent of ``v_max``; only ``(c, v)`` are
duplicated per parameter value — exactly the paper's observation.  The sweep
runs all ``A`` parameter values in a single pass over the stream, then selects
a result using *edge-free* metrics (entropy / average density) computable from
``(c, v)`` alone.  Modularity is intentionally not offered as a selector: its
computation needs the whole graph (paper §2.5).

:func:`multiparam_update` is the resumable tier: it takes and returns a
:class:`repro.core.state.SweepState`, so the stream can arrive in arbitrary
batches (``repro.cluster.StreamClusterer.partial_fit``) — k batches produce
a sweep bit-identical to the one-shot scan, because the per-edge ``lax.scan``
threads exactly the same state across batch boundaries and PAD rows are
no-ops.  The one-shot :func:`cluster_stream_multiparam` remains as a thin
shim.
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import avg_density_from_state, entropy_from_state
from repro.core.state import SweepState, count_live_edges
from repro.graph.pipeline import PAD

Array = jax.Array


class SweepResult(NamedTuple):
    c: Array  # (A, n) community labels per v_max
    d: Array  # (n,)   shared degrees
    v: Array  # (A, n) community volumes per v_max
    v_max: Array  # (A,)


def _edge_update_multi(state, edge, *, n: int):
    d, c, v, vmaxes = state  # d: (n+1,), c/v: (A, n+1)
    i_raw, j_raw = edge[0], edge[1]
    live = (i_raw != PAD) & (j_raw != PAD) & (i_raw != j_raw)
    sink = jnp.int32(n)
    i = jnp.where(live, i_raw, sink)
    j = jnp.where(live, j_raw, sink)
    one = jnp.where(live, jnp.int32(1), jnp.int32(0))

    d = d.at[i].add(one).at[j].add(one)
    di, dj = d[i], d[j]

    def per_param(c_a, v_a, v_max):
        ci, cj = c_a[i], c_a[j]
        v_a = v_a.at[ci].add(one).at[cj].add(one)
        vci, vcj = v_a[ci], v_a[cj]
        ok = live & (vci <= v_max) & (vcj <= v_max)
        i_joins = ok & (vci <= vcj)
        j_joins = ok & (vci > vcj)
        move_i = jnp.where(i_joins, di, 0)
        move_j = jnp.where(j_joins, dj, 0)
        v_a = v_a.at[cj].add(move_i - move_j).at[ci].add(move_j - move_i)
        c_a = c_a.at[i].set(jnp.where(i_joins, cj, ci))
        c_a = c_a.at[j].set(jnp.where(j_joins, ci, c_a[j]))
        return c_a, v_a

    c, v = jax.vmap(per_param)(c, v, vmaxes)
    return (d, c, v, vmaxes), ()


@functools.partial(jax.jit, donate_argnums=(0,))
def multiparam_update(state: SweepState, edges: Array) -> SweepState:
    """State-threading §2.5 sweep tier: ingest ``edges`` into ``state``.

    Strictly sequential (one edge per ``lax.scan`` step, all ``A`` parameter
    values vectorized per step), so every sweep column is bit-exact with a
    single-parameter ``scan``/``dense`` run at that ``v_max``, and batched
    ingestion is bit-identical to the one-shot run regardless of batching.
    The slot-``n`` write sink for PAD/self-loop rows is appended/stripped
    here, as in the chunked tier.  ``state`` is donated — the ``(2A + 1) n``
    ints update in place on accelerator backends; callers must treat the
    passed-in state as consumed (the ``partial_fit`` contract).
    """
    n = state.d.shape[0]
    A = state.c.shape[0]
    edges = edges.astype(jnp.int32)
    sink_col = jnp.full((A, 1), n, jnp.int32)
    init = (
        jnp.concatenate([state.d.astype(jnp.int32), jnp.int32([0])]),
        jnp.concatenate([state.c.astype(jnp.int32), sink_col], axis=1),
        jnp.concatenate(
            [state.v.astype(jnp.int32), jnp.zeros((A, 1), jnp.int32)], axis=1
        ),
        state.v_maxes.astype(jnp.int32),
    )
    (d, c, v, _), _ = jax.lax.scan(
        functools.partial(_edge_update_multi, n=n), init, edges
    )
    return SweepState(
        d=d[:n],
        c=c[:, :n],
        v=v[:, :n],
        v_maxes=state.v_maxes,
        edges_seen=state.edges_seen + count_live_edges(edges, PAD),
    )


def cluster_stream_multiparam(edges: Array, v_maxes: Array, n: int) -> SweepResult:
    """One-shot Algorithm 1 for every value in ``v_maxes`` in one pass.

    .. deprecated:: use ``repro.cluster.cluster(..., backend="multiparam")``;
       this is a shim over the state-threading :func:`multiparam_update`.
    """
    s = multiparam_update(
        SweepState.init(int(n), np.asarray(v_maxes)), jnp.asarray(edges)
    )
    return SweepResult(c=s.c, d=s.d, v=s.v, v_max=jnp.asarray(v_maxes))


def select_result(result, criterion: str = "density") -> Dict:
    """Pick the best sweep entry using edge-free metrics (paper §2.5).

    ``result`` may be a :class:`SweepResult` or a
    :class:`~repro.core.state.SweepState` (same field layout for ``c``/``d``/
    ``v``).
    """
    c = np.asarray(result.c)
    v = np.asarray(result.v)
    w = float(np.asarray(result.d).sum())
    v_maxes = np.asarray(
        result.v_max if isinstance(result, SweepResult) else result.v_maxes
    )
    rows = []
    for a in range(c.shape[0]):
        rows.append(
            {
                "v_max": int(v_maxes[a]),
                "entropy": entropy_from_state(v[a], w),
                "density": avg_density_from_state(c[a], v[a]),
            }
        )
    if criterion == "density":
        best = int(np.argmax([r["density"] for r in rows]))
    elif criterion == "entropy":
        best = int(np.argmax([r["entropy"] for r in rows]))
    else:
        raise ValueError(f"unknown criterion {criterion!r}")
    return {"best_index": best, "best_v_max": rows[best]["v_max"], "rows": rows,
            "labels": c[best]}
