"""One-pass multi-``v_max`` sweep (paper §2.5).

The degree dictionary ``d`` is independent of ``v_max``; only ``(c, v)`` are
duplicated per parameter value — exactly the paper's observation.  The sweep
runs all ``A`` parameter values in a single pass over the stream, then selects
a result using *edge-free* metrics (entropy / average density) computable from
``(c, v)`` alone.  Modularity is intentionally not offered as a selector: its
computation needs the whole graph (paper §2.5).
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import avg_density_from_state, entropy_from_state
from repro.core.state import ClusterState, count_live_edges
from repro.core.streaming import PAD

Array = jax.Array


class SweepResult(NamedTuple):
    c: Array  # (A, n) community labels per v_max
    d: Array  # (n,)   shared degrees
    v: Array  # (A, n) community volumes per v_max
    v_max: Array  # (A,)


def _edge_update_multi(state, edge, *, n: int):
    d, c, v, vmaxes = state  # d: (n+1,), c/v: (A, n+1)
    i_raw, j_raw = edge[0], edge[1]
    live = (i_raw != PAD) & (j_raw != PAD) & (i_raw != j_raw)
    sink = jnp.int32(n)
    i = jnp.where(live, i_raw, sink)
    j = jnp.where(live, j_raw, sink)
    one = jnp.where(live, jnp.int32(1), jnp.int32(0))

    d = d.at[i].add(one).at[j].add(one)
    di, dj = d[i], d[j]

    def per_param(c_a, v_a, v_max):
        ci, cj = c_a[i], c_a[j]
        v_a = v_a.at[ci].add(one).at[cj].add(one)
        vci, vcj = v_a[ci], v_a[cj]
        ok = live & (vci <= v_max) & (vcj <= v_max)
        i_joins = ok & (vci <= vcj)
        j_joins = ok & (vci > vcj)
        move_i = jnp.where(i_joins, di, 0)
        move_j = jnp.where(j_joins, dj, 0)
        v_a = v_a.at[cj].add(move_i - move_j).at[ci].add(move_j - move_i)
        c_a = c_a.at[i].set(jnp.where(i_joins, cj, ci))
        c_a = c_a.at[j].set(jnp.where(j_joins, ci, c_a[j]))
        return c_a, v_a

    c, v = jax.vmap(per_param)(c, v, vmaxes)
    return (d, c, v, vmaxes), ()


@functools.partial(jax.jit, static_argnames=("n",))
def cluster_stream_multiparam(edges: Array, v_maxes: Array, n: int) -> SweepResult:
    """Run Algorithm 1 for every value in ``v_maxes`` in one pass."""
    A = v_maxes.shape[0]
    edges = edges.astype(jnp.int32)
    c0 = jnp.broadcast_to(
        jnp.concatenate([jnp.arange(n, dtype=jnp.int32), jnp.int32([n])]), (A, n + 1)
    )
    init = (
        jnp.zeros(n + 1, jnp.int32),
        c0,
        jnp.zeros((A, n + 1), jnp.int32),
        v_maxes.astype(jnp.int32),
    )
    (d, c, v, _), _ = jax.lax.scan(
        functools.partial(_edge_update_multi, n=n), init, edges
    )
    return SweepResult(c=c[:, :n], d=d[:n], v=v[:, :n], v_max=v_maxes)


def sweep_state(result: SweepResult, index: int, edges: Array) -> ClusterState:
    """The :class:`ClusterState` of one sweep entry (shared ``d``, per-``v_max``
    ``c``/``v``) — lets the unified API return sweep picks in the common state
    representation."""
    return ClusterState(
        d=result.d,
        c=result.c[index],
        v=result.v[index],
        edges_seen=count_live_edges(edges, PAD),
    )


def select_result(result: SweepResult, criterion: str = "density") -> Dict:
    """Pick the best sweep entry using edge-free metrics (paper §2.5)."""
    c = np.asarray(result.c)
    v = np.asarray(result.v)
    w = float(np.asarray(result.d).sum())
    rows = []
    for a in range(c.shape[0]):
        rows.append(
            {
                "v_max": int(np.asarray(result.v_max)[a]),
                "entropy": entropy_from_state(v[a], w),
                "density": avg_density_from_state(c[a], v[a]),
            }
        )
    if criterion == "density":
        best = int(np.argmax([r["density"] for r in rows]))
    elif criterion == "entropy":
        best = int(np.argmax([r["entropy"] for r in rows]))
    else:
        raise ValueError(f"unknown criterion {criterion!r}")
    return {"best_index": best, "best_v_max": rows[best]["v_max"], "rows": rows,
            "labels": c[best]}
