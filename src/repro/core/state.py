"""The streaming-clustering state pytrees.

:class:`ClusterState` is the paper's ``3n`` integers (DESIGN.md §3/§6):
degree ``d``, community label ``c``, community volume ``v`` (all size ``n``,
int32, dense node-id label space) plus an ``edges_seen`` counter of live
edges ingested so far.

Three wider siblings make *every* tier resumable and out-of-core rather
than just the single-parameter ones:

* :class:`SweepState` — the §2.5 multi-``v_max`` sweep: one shared ``d`` of
  size ``n`` plus ``(A, n)`` ``c``/``v`` (degrees are parameter-independent;
  only labels and volumes fork per ``v_max``).
* :class:`ShardedState` — the distributed tier: ``P`` per-shard
  ``ClusterState``s stacked on a leading shard axis, plus a batch cursor so
  arriving batches deal onto shards deterministically.
* :class:`FleetState` — the multi-tenant fleet engine (DESIGN.md §13):
  ``T`` *independent* per-tenant ``ClusterState``s stacked on a leading
  tenant axis, advanced together by one vmapped / tenant-major-kernel
  dispatch per fleet step (``repro.core.fleet``).

All three are registered JAX pytrees, so they flow through ``jit``/``scan``
and are serializable as-is by
:class:`repro.checkpoint.manager.CheckpointManager` — that is what makes
clustering suspendable/resumable across sessions for every backend
(:class:`repro.cluster.StreamClusterer`): a sweep or sharded checkpoint is
just a wider pytree riding the same manager.
"""

from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

Array = Union[jax.Array, np.ndarray]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClusterState:
    """Dense-layout Algorithm-1 state.

    ``c[i]`` is the id of the founding node of ``i``'s community (a pure
    relabeling of the paper's incrementing-``k`` scheme; see
    ``core/streaming.py``).  The dict-oracle backend stores its 1-based
    community ids in the same arrays (``c[i] = 0`` means "never seen",
    ``v[k - 1]`` is the volume of community ``k``) — structure and footprint
    are identical, only the label space differs.
    """

    d: Array  # (n,) int32 node degrees
    c: Array  # (n,) int32 community labels
    v: Array  # (n,) int32 community volumes (indexed by community id)
    edges_seen: Array  # () live (non-PAD, non-self) edges ingested.  int64 on
    #   the numpy tiers; int32 on device tiers (JAX's default without x64
    #   enabled), so the counter wraps past ~2.1e9 live edges there — above
    #   the paper's largest graph (Friendster, 1.8e9) but a known ceiling.

    @classmethod
    def init(cls, n: int, *, numpy: bool = False) -> "ClusterState":
        """Fresh dense-layout state for an ``n``-node stream."""
        if numpy:
            return cls(
                d=np.zeros(n, np.int32),
                c=np.arange(n, dtype=np.int32),
                v=np.zeros(n, np.int32),
                edges_seen=np.int64(0),
            )
        return cls(
            d=jnp.zeros(n, jnp.int32),
            c=jnp.arange(n, dtype=jnp.int32),
            v=jnp.zeros(n, jnp.int32),
            edges_seen=jnp.int32(0),
        )

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.d.shape[0])

    def to_numpy(self) -> "ClusterState":
        return ClusterState(
            d=np.asarray(self.d),
            c=np.asarray(self.c),
            v=np.asarray(self.v),
            edges_seen=np.int64(self.edges_seen),
        )

    def to_device(self) -> "ClusterState":
        return ClusterState(
            d=jnp.asarray(self.d, jnp.int32),
            c=jnp.asarray(self.c, jnp.int32),
            v=jnp.asarray(self.v, jnp.int32),
            edges_seen=jnp.asarray(self.edges_seen, jnp.int32),
        )

    def block_until_ready(self) -> "ClusterState":
        for leaf in (self.d, self.c, self.v):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        return self


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SweepState:
    """Multi-``v_max`` sweep state (paper §2.5) — the degree dictionary is
    independent of ``v_max``, so ``d`` is shared across all ``A`` parameter
    values while ``(c, v)`` fork per value.  Footprint: ``(2A + 1) n`` ints
    vs ``A`` independent runs' ``3An``.
    """

    d: Array  # (n,)   int32 shared node degrees
    c: Array  # (A, n) int32 community labels per v_max
    v: Array  # (A, n) int32 community volumes per v_max
    v_maxes: Array  # (A,) int32 the swept thresholds (carried in-state so a
    #   checkpoint is self-describing and a resumed run cannot silently
    #   continue under different parameters)
    edges_seen: Array  # () live edges ingested (see ClusterState.edges_seen)

    @classmethod
    def init(cls, n: int, v_maxes, *, numpy: bool = False) -> "SweepState":
        """Fresh sweep state for ``n`` nodes and the given ``v_maxes``."""
        v_maxes = np.asarray(v_maxes, np.int32)
        A = int(v_maxes.shape[0])
        if numpy:
            return cls(
                d=np.zeros(n, np.int32),
                c=np.broadcast_to(np.arange(n, dtype=np.int32), (A, n)).copy(),
                v=np.zeros((A, n), np.int32),
                v_maxes=v_maxes,
                edges_seen=np.int64(0),
            )
        return cls(
            d=jnp.zeros(n, jnp.int32),
            c=jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (A, n)),
            v=jnp.zeros((A, n), jnp.int32),
            v_maxes=jnp.asarray(v_maxes),
            edges_seen=jnp.int32(0),
        )

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.d.shape[0])

    @property
    def A(self) -> int:
        return int(self.v_maxes.shape[0])

    def entry(self, index: int) -> ClusterState:
        """One sweep column as a plain :class:`ClusterState` (shared ``d``,
        per-``v_max`` ``c``/``v``) — the common representation the unified
        API returns for the selected parameter value."""
        return ClusterState(
            d=self.d,
            c=self.c[index],
            v=self.v[index],
            edges_seen=self.edges_seen,
        )

    def to_numpy(self) -> "SweepState":
        return SweepState(
            d=np.asarray(self.d),
            c=np.asarray(self.c),
            v=np.asarray(self.v),
            v_maxes=np.asarray(self.v_maxes),
            edges_seen=np.int64(self.edges_seen),
        )

    def to_device(self) -> "SweepState":
        return SweepState(
            d=jnp.asarray(self.d, jnp.int32),
            c=jnp.asarray(self.c, jnp.int32),
            v=jnp.asarray(self.v, jnp.int32),
            v_maxes=jnp.asarray(self.v_maxes, jnp.int32),
            edges_seen=jnp.asarray(self.edges_seen, jnp.int32),
        )

    def block_until_ready(self) -> "SweepState":
        for leaf in (self.d, self.c, self.v):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        return self


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedState:
    """Distributed-tier state: ``P`` per-shard Algorithm-1 states stacked on
    a leading shard axis.

    Arriving batches are dealt onto shards by ``cursor`` (round-robin over
    batches): with one batch per shard the split is the classic contiguous
    window sharding; with more batches each shard ingests an interleaved,
    order-preserving subsequence of the stream — the paper's streaming
    argument applies within every shard either way, and the assignment is a
    pure function of the batch index, so runs are deterministic and
    checkpoint/resume safe (the cursor is a state leaf).
    """

    d: Array  # (P, n) int32 per-shard node degrees
    c: Array  # (P, n) int32 per-shard community labels (node-id space)
    v: Array  # (P, n) int32 per-shard community volumes
    cursor: Array  # () int32 batches ingested so far (next shard = cursor % P)
    edges_seen: Array  # () live edges ingested across all shards

    @classmethod
    def init(cls, n: int, n_shards: int, *, numpy: bool = False) -> "ShardedState":
        if numpy:
            return cls(
                d=np.zeros((n_shards, n), np.int32),
                c=np.broadcast_to(
                    np.arange(n, dtype=np.int32), (n_shards, n)
                ).copy(),
                v=np.zeros((n_shards, n), np.int32),
                cursor=np.int64(0),
                edges_seen=np.int64(0),
            )
        return cls(
            d=jnp.zeros((n_shards, n), jnp.int32),
            c=jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (n_shards, n)),
            v=jnp.zeros((n_shards, n), jnp.int32),
            cursor=jnp.int32(0),
            edges_seen=jnp.int32(0),
        )

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.d.shape[1])

    @property
    def n_shards(self) -> int:
        return int(self.d.shape[0])

    def to_numpy(self) -> "ShardedState":
        return ShardedState(
            d=np.asarray(self.d),
            c=np.asarray(self.c),
            v=np.asarray(self.v),
            cursor=np.int64(self.cursor),
            edges_seen=np.int64(self.edges_seen),
        )

    def to_device(self) -> "ShardedState":
        return ShardedState(
            d=jnp.asarray(self.d, jnp.int32),
            c=jnp.asarray(self.c, jnp.int32),
            v=jnp.asarray(self.v, jnp.int32),
            cursor=jnp.asarray(self.cursor, jnp.int32),
            edges_seen=jnp.asarray(self.edges_seen, jnp.int32),
        )

    def block_until_ready(self) -> "ShardedState":
        for leaf in (self.d, self.c, self.v):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        return self


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FleetState:
    """Fleet-tier state: ``T`` independent per-tenant Algorithm-1 states
    stacked on a leading tenant axis (DESIGN.md §13).

    Unlike :class:`ShardedState` (one logical graph dealt across shards),
    the tenants are *disjoint* streams over disjoint logical graphs — the
    stack exists purely so the whole fleet advances with **one** donated
    device dispatch per fleet step instead of ``T`` single-stream
    dispatches.  Row ``t`` is bit-identical to what a standalone
    single-stream run of tenant ``t`` would hold, which is what makes the
    fleet suspend/resume and the per-tenant bit-identity tests exact.

    ``edges_seen`` is per-tenant (a ``(T,)`` vector, not a scalar): each
    tenant's live-edge count matches its standalone run.
    """

    d: Array  # (T, n) int32 per-tenant node degrees
    c: Array  # (T, n) int32 per-tenant community labels (node-id space)
    v: Array  # (T, n) int32 per-tenant community volumes
    edges_seen: Array  # (T,) live edges ingested per tenant

    @classmethod
    def init(cls, n: int, tenants: int, *, numpy: bool = False) -> "FleetState":
        if numpy:
            return cls(
                d=np.zeros((tenants, n), np.int32),
                c=np.broadcast_to(
                    np.arange(n, dtype=np.int32), (tenants, n)
                ).copy(),
                v=np.zeros((tenants, n), np.int32),
                edges_seen=np.zeros(tenants, np.int64),
            )
        return cls(
            d=jnp.zeros((tenants, n), jnp.int32),
            c=jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (tenants, n)),
            v=jnp.zeros((tenants, n), jnp.int32),
            edges_seen=jnp.zeros(tenants, jnp.int32),
        )

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.d.shape[1])

    @property
    def tenants(self) -> int:
        return int(self.d.shape[0])

    def entry(self, tenant: int) -> ClusterState:
        """Tenant ``tenant``'s slab as a plain :class:`ClusterState` — the
        representation the single-stream API (finalize, refine, metrics)
        understands."""
        return ClusterState(
            d=self.d[tenant],
            c=self.c[tenant],
            v=self.v[tenant],
            edges_seen=self.edges_seen[tenant],
        )

    def to_numpy(self) -> "FleetState":
        return FleetState(
            d=np.asarray(self.d),
            c=np.asarray(self.c),
            v=np.asarray(self.v),
            edges_seen=np.asarray(self.edges_seen, np.int64),
        )

    def to_device(self) -> "FleetState":
        return FleetState(
            d=jnp.asarray(self.d, jnp.int32),
            c=jnp.asarray(self.c, jnp.int32),
            v=jnp.asarray(self.v, jnp.int32),
            edges_seen=jnp.asarray(self.edges_seen, jnp.int32),
        )

    def block_until_ready(self) -> "FleetState":
        for leaf in (self.d, self.c, self.v):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        return self


def count_live_edges(edges: Array, pad: int) -> Array:
    """Number of non-PAD, non-self edges in a (m, 2) batch (int32)."""
    e = jnp.asarray(edges)
    if e.shape[0] == 0:
        return jnp.int32(0)
    live = (e[:, 0] != pad) & (e[:, 1] != pad) & (e[:, 0] != e[:, 1])
    return jnp.sum(live, dtype=jnp.int32)
