"""The streaming-clustering state pytree — the paper's ``3n`` integers.

:class:`ClusterState` is the single state representation shared by every
clustering backend (DESIGN.md §3/§6): degree ``d``, community label ``c``,
community volume ``v`` (all size ``n``, int32, dense node-id label space)
plus an ``edges_seen`` counter of live edges ingested so far.

It is a registered JAX pytree, so it flows through ``jit``/``scan`` and is
serializable as-is by :class:`repro.checkpoint.manager.CheckpointManager` —
that is what makes clustering suspendable/resumable across sessions
(:class:`repro.cluster.StreamClusterer`).
"""

from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp
import numpy as np

Array = Union[jax.Array, np.ndarray]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClusterState:
    """Dense-layout Algorithm-1 state.

    ``c[i]`` is the id of the founding node of ``i``'s community (a pure
    relabeling of the paper's incrementing-``k`` scheme; see
    ``core/streaming.py``).  The dict-oracle backend stores its 1-based
    community ids in the same arrays (``c[i] = 0`` means "never seen",
    ``v[k - 1]`` is the volume of community ``k``) — structure and footprint
    are identical, only the label space differs.
    """

    d: Array  # (n,) int32 node degrees
    c: Array  # (n,) int32 community labels
    v: Array  # (n,) int32 community volumes (indexed by community id)
    edges_seen: Array  # () live (non-PAD, non-self) edges ingested.  int64 on
    #   the numpy tiers; int32 on device tiers (JAX's default without x64
    #   enabled), so the counter wraps past ~2.1e9 live edges there — above
    #   the paper's largest graph (Friendster, 1.8e9) but a known ceiling.

    @classmethod
    def init(cls, n: int, *, numpy: bool = False) -> "ClusterState":
        """Fresh dense-layout state for an ``n``-node stream."""
        if numpy:
            return cls(
                d=np.zeros(n, np.int32),
                c=np.arange(n, dtype=np.int32),
                v=np.zeros(n, np.int32),
                edges_seen=np.int64(0),
            )
        return cls(
            d=jnp.zeros(n, jnp.int32),
            c=jnp.arange(n, dtype=jnp.int32),
            v=jnp.zeros(n, jnp.int32),
            edges_seen=jnp.int32(0),
        )

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.d.shape[0])

    def to_numpy(self) -> "ClusterState":
        return ClusterState(
            d=np.asarray(self.d),
            c=np.asarray(self.c),
            v=np.asarray(self.v),
            edges_seen=np.int64(self.edges_seen),
        )

    def to_device(self) -> "ClusterState":
        return ClusterState(
            d=jnp.asarray(self.d, jnp.int32),
            c=jnp.asarray(self.c, jnp.int32),
            v=jnp.asarray(self.v, jnp.int32),
            edges_seen=jnp.asarray(self.edges_seen, jnp.int32),
        )

    def block_until_ready(self) -> "ClusterState":
        for leaf in (self.d, self.c, self.v):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        return self


def count_live_edges(edges: Array, pad: int) -> Array:
    """Number of non-PAD, non-self edges in a (m, 2) batch (int32)."""
    e = jnp.asarray(edges)
    if e.shape[0] == 0:
        return jnp.int32(0)
    live = (e[:, 0] != pad) & (e[:, 1] != pad) & (e[:, 0] != e[:, 1])
    return jnp.sum(live, dtype=jnp.int32)
