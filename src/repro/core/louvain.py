"""Louvain baseline (paper's main non-streaming comparator, [Blondel et al.]).

Full two-phase implementation on CSR adjacency: greedy local moves until no
gain, then graph coarsening; repeat.  Numpy implementation sized for the
benchmark graphs (≤ ~1e7 edges in-container).  Unlike the streaming algorithm
it stores the whole graph — the memory benchmark reports exactly that gap.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def _to_csr(edges: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Undirected weighted CSR from an edge multiset (multi-edges summed)."""
    e = np.asarray(edges)
    live = (e[:, 0] >= 0) & (e[:, 1] >= 0) & (e[:, 0] != e[:, 1])
    e = e[live]
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    wts = np.ones(len(src), dtype=np.float64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, dst.astype(np.int64), wts


def _one_level(
    indptr: np.ndarray,
    indices: np.ndarray,
    data: np.ndarray,
    w: float,
    rng: np.random.Generator,
    max_sweeps: int = 10,
) -> Tuple[np.ndarray, bool]:
    """Greedy modularity moves; returns (labels, improved)."""
    n = len(indptr) - 1
    deg = np.zeros(n)
    np.add.at(deg, np.repeat(np.arange(n), np.diff(indptr)), data)
    labels = np.arange(n, dtype=np.int64)
    sigma_tot = deg.copy()  # community total degree
    improved = False
    for _ in range(max_sweeps):
        moved = 0
        for u in rng.permutation(n):
            cu = labels[u]
            lo, hi = indptr[u], indptr[u + 1]
            nbr, wts = indices[lo:hi], data[lo:hi]
            if len(nbr) == 0:
                continue
            # Weight from u to each neighbouring community.
            comms = labels[nbr]
            uniq, inv = np.unique(comms, return_inverse=True)
            k_in = np.zeros(len(uniq))
            np.add.at(k_in, inv, wts)
            # Remove u from its community.
            sigma_tot[cu] -= deg[u]
            self_idx = np.searchsorted(uniq, cu)
            k_in_self = (
                k_in[self_idx]
                if self_idx < len(uniq) and uniq[self_idx] == cu
                else 0.0
            )
            # Gain of joining community c: k_in(c) - deg_u * sigma_tot(c) / w
            gains = k_in - deg[u] * sigma_tot[uniq] / w
            stay_gain = k_in_self - deg[u] * sigma_tot[cu] / w
            best = int(np.argmax(gains))
            if gains[best] > stay_gain + 1e-12:
                labels[u] = uniq[best]
                moved += 1
            sigma_tot[labels[u]] += deg[u]
        if moved == 0:
            break
        improved = True
    return labels, improved


def _coarsen(
    indptr, indices, data, labels
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Contract communities into supernodes; returns new CSR + relabel map."""
    uniq, new = np.unique(labels, return_inverse=True)
    k = len(uniq)
    src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    cs, cd = new[src], new[indices]
    key = cs * k + cd
    uk, pos = np.unique(key, return_inverse=True)
    wsum = np.zeros(len(uk))
    np.add.at(wsum, pos, data)
    ns, nd = uk // k, uk % k
    order = np.argsort(ns, kind="stable")
    ns, nd, wsum = ns[order], nd[order], wsum[order]
    nip = np.zeros(k + 1, dtype=np.int64)
    np.add.at(nip, ns + 1, 1)
    nip = np.cumsum(nip)
    return nip, nd, wsum, new


def louvain(edges: np.ndarray, n: int, seed: int = 0, max_levels: int = 10) -> np.ndarray:
    """Run Louvain; returns community labels (n,)."""
    rng = np.random.default_rng(seed)
    indptr, indices, data = _to_csr(edges, n)
    w = float(data.sum())
    if w == 0:
        return np.arange(n, dtype=np.int64)
    mapping = np.arange(n, dtype=np.int64)
    for _ in range(max_levels):
        labels, improved = _one_level(indptr, indices, data, w, rng)
        if not improved:
            break
        indptr, indices, data, new = _coarsen(indptr, indices, data, labels)
        mapping = new[labels[mapping]]
        if len(indptr) - 1 == len(np.unique(mapping)) and len(indptr) - 1 <= 1:
            break
    return mapping
