"""Louvain baseline (paper's main non-streaming comparator, [Blondel et al.]).

Full two-phase implementation on CSR adjacency: greedy local moves until no
gain, then graph coarsening; repeat.  Numpy implementation sized for the
benchmark graphs (≤ ~1e7 edges in-container).  Unlike the streaming algorithm
it stores the whole graph — the memory benchmark reports exactly that gap.

Edges may carry weights (``weights=None`` means unit weight) — a weighted
edge is exactly equivalent to that many duplicated unit edges, which is what
lets the refinement subsystem (``repro.cluster.refine``) run Louvain rounds
on a *contracted supergraph* whose edges are accumulated inter-community
weights instead of raw graph edges.  Self-loops are kept as internal weight
(they are the contraction of intra-community edges): a self-loop of weight w
contributes 2w to its node's strength and w to its community's internal
weight, the standard Louvain convention.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def _to_csr(
    edges: np.ndarray, n: int, weights: Optional[np.ndarray] = None
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Undirected weighted CSR from an edge multiset (multi-edges summed).

    ``weights``: optional per-edge weights (unit when ``None``).  Self-loops
    are dropped here (the plain-graph baselines never see them); the
    refinement engine keeps contracted self-weight out-of-band — see
    ``repro.core.refine.contract_graph``.
    """
    e = np.asarray(edges)
    w = (
        np.ones(e.shape[0], dtype=np.float64)
        if weights is None
        else np.asarray(weights, dtype=np.float64)
    )
    if w.shape[0] != e.shape[0]:
        raise ValueError(
            f"weights length {w.shape[0]} != edge count {e.shape[0]}"
        )
    live = (e[:, 0] >= 0) & (e[:, 1] >= 0) & (e[:, 0] != e[:, 1])
    e, w = e[live], w[live]
    src = np.concatenate([e[:, 0], e[:, 1]])
    dst = np.concatenate([e[:, 1], e[:, 0]])
    wts = np.concatenate([w, w])
    order = np.argsort(src, kind="stable")
    src, dst, wts = src[order], dst[order], wts[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, dst.astype(np.int64), wts


def _one_level(
    indptr: np.ndarray,
    indices: np.ndarray,
    data: np.ndarray,
    w: float,
    rng: np.random.Generator,
    max_sweeps: int = 10,
    self_weight: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, bool]:
    """Greedy modularity moves; returns (labels, improved).

    ``self_weight``: per-node internal weight (contracted self-loops) — it
    adds 2w to the node's strength (degree mass it carries into whichever
    community it joins) but never to a neighbour-community gain, since a
    self-loop stays internal wherever the node goes.
    """
    n = len(indptr) - 1
    deg = np.zeros(n)
    np.add.at(deg, np.repeat(np.arange(n), np.diff(indptr)), data)
    if self_weight is not None:
        deg += 2.0 * np.asarray(self_weight, dtype=np.float64)
    labels = np.arange(n, dtype=np.int64)
    sigma_tot = deg.copy()  # community total degree
    improved = False
    for _ in range(max_sweeps):
        moved = 0
        for u in rng.permutation(n):
            cu = labels[u]
            lo, hi = indptr[u], indptr[u + 1]
            nbr, wts = indices[lo:hi], data[lo:hi]
            if len(nbr) == 0:
                continue
            # Weight from u to each neighbouring community.
            comms = labels[nbr]
            uniq, inv = np.unique(comms, return_inverse=True)
            k_in = np.zeros(len(uniq))
            np.add.at(k_in, inv, wts)
            # Remove u from its community.
            sigma_tot[cu] -= deg[u]
            self_idx = np.searchsorted(uniq, cu)
            k_in_self = (
                k_in[self_idx]
                if self_idx < len(uniq) and uniq[self_idx] == cu
                else 0.0
            )
            # Gain of joining community c: k_in(c) - deg_u * sigma_tot(c) / w
            gains = k_in - deg[u] * sigma_tot[uniq] / w
            stay_gain = k_in_self - deg[u] * sigma_tot[cu] / w
            best = int(np.argmax(gains))
            if gains[best] > stay_gain + 1e-12:
                labels[u] = uniq[best]
                moved += 1
            sigma_tot[labels[u]] += deg[u]
        if moved == 0:
            break
        improved = True
    return labels, improved


def _coarsen(
    indptr, indices, data, labels
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Contract communities into supernodes; returns new CSR + relabel map."""
    uniq, new = np.unique(labels, return_inverse=True)
    k = len(uniq)
    src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    cs, cd = new[src], new[indices]
    key = cs * k + cd
    uk, pos = np.unique(key, return_inverse=True)
    wsum = np.zeros(len(uk))
    np.add.at(wsum, pos, data)
    ns, nd = uk // k, uk % k
    order = np.argsort(ns, kind="stable")
    ns, nd, wsum = ns[order], nd[order], wsum[order]
    nip = np.zeros(k + 1, dtype=np.int64)
    np.add.at(nip, ns + 1, 1)
    nip = np.cumsum(nip)
    return nip, nd, wsum, new


def louvain(
    edges: np.ndarray,
    n: int,
    seed: int = 0,
    max_levels: int = 10,
    weights: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Run Louvain; returns community labels (n,).

    ``weights``: optional per-edge weights — equivalent to duplicating each
    unit edge that many times (pinned by tests), which is how the refinement
    engine runs this on accumulated supergraph weights.
    """
    rng = np.random.default_rng(seed)
    indptr, indices, data = _to_csr(edges, n, weights)
    w = float(data.sum())
    if w == 0:
        return np.arange(n, dtype=np.int64)
    mapping = np.arange(n, dtype=np.int64)
    for _ in range(max_levels):
        labels, improved = _one_level(indptr, indices, data, w, rng)
        if not improved:
            break
        indptr, indices, data, new = _coarsen(indptr, indices, data, labels)
        mapping = new[labels[mapping]]
        if len(indptr) - 1 == len(np.unique(mapping)) and len(indptr) - 1 <= 1:
            break
    return mapping
