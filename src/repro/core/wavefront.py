"""Device-side wavefront apply: vectorised waves, sequential fallback.

Shared by the pure-JAX reference path (:func:`wavefront_update_megabatch`,
used in interpret-mode runs and as the oracle for the kernel) and the
Pallas wavefront kernel (``repro.kernels.edge_stream``), which imports
:func:`wave_conflict` / :func:`wave_apply` so both paths apply *exactly*
the same math (DESIGN.md §12).

Correctness argument, cell by cell.  The planner guarantees every wave is
a contiguous, node-disjoint run of the stream:

* ``d[i]``, ``c[i]`` are node-indexed — node-disjointness alone makes the
  wave's reads/writes of them conflict-free.
* ``v[c]`` and the join decisions read community volumes, and communities
  are dynamic — so the wave needs *community* disjointness too, decidable
  only at apply time against the live state.  :func:`wave_conflict` flags
  a wave when two live edges touch the same **unsaturated** community
  (``v[c] < v_max`` before the wave).  A *saturated* shared community is
  provably harmless: no edge touching it can pass the ``ok`` volume test
  in any order (every reader sees at least ``v_max + 1``), so it only ever
  receives commutative arrival ``+1``s and is never a join source/target —
  the final state is order-independent.  This is what keeps the fallback
  rate low in steady state, where most communities sit at the cap.

Flagged waves fall back to the sequential per-edge loop, so labels are
bit-identical to ``cluster_stream_dense`` for every stream and every plan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.state import ClusterState, count_live_edges
from repro.core.streaming import _edge_update
from repro.graph.pipeline import PAD


def wave_live(i_raw, j_raw):
    """Per-slot liveness mask (PAD rows and self-loops are no-ops)."""
    return (i_raw != PAD) & (j_raw != PAD) & (i_raw != j_raw)


def wave_conflict(c, v, i_raw, j_raw, v_max, n):
    """True iff the vectorised apply of this node-disjoint wave could
    diverge from the sequential order: some unsaturated community is
    touched by more than one live edge.

    Dead slots and saturated communities are keyed by unique sentinels
    ``>= n`` (labels live in ``[0, n)``), so a duplicate among the sorted
    keys is exactly a real collision.  An edge whose endpoints share one
    community contributes that community once — a single edge always
    commutes with itself.
    """
    W = i_raw.shape[0]
    live = wave_live(i_raw, j_raw)
    i = jnp.maximum(i_raw, 0)
    j = jnp.maximum(j_raw, 0)
    ci = c[i]
    cj = c[j]
    e = jnp.arange(W, dtype=jnp.int32)
    hot_i = live & (v[ci] < v_max)
    hot_j = live & (v[cj] < v_max) & (cj != ci)
    key_i = jnp.where(hot_i, ci, n + 2 * e)
    key_j = jnp.where(hot_j, cj, n + 2 * e + 1)
    keys = jnp.sort(jnp.concatenate([key_i, key_j]))
    return jnp.any(keys[1:] == keys[:-1])


def wave_apply(d, c, v, i_raw, j_raw, v_max):
    """Apply one wave as gathered vector loads / scattered stores.

    Bit-exact with the sequential loop exactly when
    :func:`wave_conflict` is False (node-disjoint wave, no shared
    unsaturated community): every gather then sees the same values the
    sequential order would, and the scatters hit disjoint cells — except
    the commutative ``+1`` arrivals on saturated shared communities, whose
    order never mattered.
    """
    n = d.shape[0]
    live = wave_live(i_raw, j_raw)
    i = jnp.maximum(i_raw, 0)
    j = jnp.maximum(j_raw, 0)
    one = jnp.where(live, jnp.int32(1), jnp.int32(0))

    d = d.at[i].add(one).at[j].add(one)
    ci = c[i]
    cj = c[j]
    # both arrivals land before any read, matching the sequential reload
    # (an edge with ci == cj sees +2, like the scalar path)
    v = v.at[ci].add(one).at[cj].add(one)
    vci = v[ci]
    vcj = v[cj]

    ok = live & (vci <= v_max) & (vcj <= v_max)
    i_joins = ok & (vci <= vcj)
    j_joins = ok & (vci > vcj)
    win = i_joins | j_joins

    mover = jnp.where(i_joins, i, j)
    target = jnp.where(i_joins, cj, ci)
    source = jnp.where(i_joins, ci, cj)
    dm = jnp.where(win, d[mover], 0)
    v = v.at[target].add(dm).at[source].add(-dm)
    # non-winning slots are routed out of bounds and dropped — clamping to
    # a real index would collide with a genuine write to that node
    c = c.at[jnp.where(win, mover, n)].set(target, mode="drop")
    return d, c, v


def _sequential_rows(dcv, rows, v_max):
    """The fallback: the scan tier's per-edge step over ``rows`` in order."""
    (d, c, v), _ = jax.lax.scan(
        functools.partial(_edge_update, v_max=v_max), dcv, rows
    )
    return d, c, v


@functools.partial(jax.jit, donate_argnums=(0,))
def wavefront_update_megabatch(
    state: ClusterState, waves, leftover, meta, v_max
) -> tuple:
    """Reference wavefront ingest over a :class:`~repro.graph.wavefront
    .WavePlan`'s arrays: vector-apply each wave, sequential fallback on
    community collision, then drain the uncovered suffix sequentially.

    Bit-exact with ``dense_update`` over the original stream for any plan
    produced by ``plan_waves`` (hypothesis-pinned in
    ``tests/test_wavefront.py``).  Only ``meta[0]`` waves are visited (a
    ``fori_loop``, not a full-buffer scan), so the planner's slack budget
    costs staging memory but never device compute.  Returns ``(new_state,
    stats)`` with ``stats = [live_waves, fallback_waves]`` int32.
    ``state`` is donated.
    """
    n = state.d.shape[0]
    v_max = jnp.int32(v_max)
    waves = waves.astype(jnp.int32)
    leftover = leftover.astype(jnp.int32)
    init = (
        state.d.astype(jnp.int32),
        state.c.astype(jnp.int32),
        state.v.astype(jnp.int32),
        jnp.zeros((2,), jnp.int32),
    )

    def step(t, carry):
        d, c, v, stats = carry
        wave = jax.lax.dynamic_index_in_dim(waves, t, keepdims=False)
        i_raw = wave[:, 0]
        j_raw = wave[:, 1]
        has_live = jnp.any(wave_live(i_raw, j_raw))
        conflict = wave_conflict(c, v, i_raw, j_raw, v_max, n)
        d, c, v = jax.lax.cond(
            conflict,
            lambda dcv: _sequential_rows(dcv, wave, v_max),
            lambda dcv: wave_apply(*dcv, i_raw, j_raw, v_max),
            (d, c, v),
        )
        stats = stats + jnp.stack(
            [has_live.astype(jnp.int32), (conflict & has_live).astype(jnp.int32)]
        )
        return d, c, v, stats

    nw = jnp.minimum(meta[0].astype(jnp.int32), waves.shape[0])
    d, c, v, stats = jax.lax.fori_loop(0, nw, step, init)

    # skip the O(M) sequential suffix scan entirely in the common case
    # where the plan covered every row (live rows always have i != PAD)
    has_left = jnp.any(leftover[:, 0] != PAD)
    d, c, v = jax.lax.cond(
        has_left,
        lambda dcv: _sequential_rows(dcv, leftover, v_max),
        lambda dcv: dcv,
        (d, c, v),
    )
    seen = count_live_edges(waves.reshape(-1, 2), PAD) + count_live_edges(
        leftover, PAD
    )
    return (
        ClusterState(d=d, c=c, v=v, edges_seen=state.edges_seen + seen),
        stats,
    )
