"""Asynchronous label propagation baseline (speed-class stand-in for SCD).

Simple and fast: each sweep, every node adopts the plurality label among its
neighbours (ties -> keep / smallest label).  Included so the quality table has
a second non-streaming baseline that *does* scale to the larger benchmark
graphs in-container.
"""

from __future__ import annotations

import numpy as np

from repro.core.louvain import _to_csr


def label_propagation(
    edges: np.ndarray, n: int, sweeps: int = 5, seed: int = 0
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    indptr, indices, _ = _to_csr(edges, n)
    labels = np.arange(n, dtype=np.int64)
    for _ in range(sweeps):
        changed = 0
        for u in rng.permutation(n):
            lo, hi = indptr[u], indptr[u + 1]
            if hi == lo:
                continue
            nbr_labels = labels[indices[lo:hi]]
            uniq, cnt = np.unique(nbr_labels, return_counts=True)
            best = uniq[np.argmax(cnt)]
            if best != labels[u]:
                labels[u] = best
                changed += 1
        if changed == 0:
            break
    return labels
