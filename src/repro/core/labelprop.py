"""Asynchronous label propagation baseline (speed-class stand-in for SCD).

Simple and fast: each sweep, every node adopts the plurality label among its
neighbours (ties -> keep / smallest label).  Included so the quality table has
a second non-streaming baseline that *does* scale to the larger benchmark
graphs in-container.

Two extensions feed the refinement subsystem (``repro.cluster.refine``):

* ``weights`` — plurality becomes a weighted vote; a weighted edge is
  exactly equivalent to that many duplicated unit edges (pinned by tests),
  so the same sweeps run on a contracted supergraph's accumulated weights.
* ``init_labels`` — start from an existing partition instead of singletons;
  the buffered-replay refinement stage re-plays the recent edge window
  through the projected labels this way, letting *individual nodes* move
  (a split-capable correction the contracted supergraph alone cannot make).
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.louvain import _to_csr


def label_propagation(
    edges: np.ndarray,
    n: int,
    sweeps: int = 5,
    seed: int = 0,
    weights: Optional[np.ndarray] = None,
    init_labels: Optional[np.ndarray] = None,
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    indptr, indices, data = _to_csr(edges, n, weights)
    if init_labels is None:
        labels = np.arange(n, dtype=np.int64)
    else:
        labels = np.asarray(init_labels, dtype=np.int64).copy()
        if labels.shape[0] != n:
            raise ValueError(
                f"init_labels has {labels.shape[0]} entries for n={n}"
            )
    for _ in range(sweeps):
        changed = 0
        for u in rng.permutation(n):
            lo, hi = indptr[u], indptr[u + 1]
            if hi == lo:
                continue
            nbr_labels = labels[indices[lo:hi]]
            uniq, inv = np.unique(nbr_labels, return_inverse=True)
            vote = np.zeros(len(uniq))
            np.add.at(vote, inv, data[lo:hi])
            best = uniq[np.argmax(vote)]
            if best != labels[u]:
                labels[u] = best
                changed += 1
        if changed == 0:
            break
    return labels
