"""Exact quantities from the paper's §3 theory (Lemmas 1–2, Theorem 1).

These are *identities* over a finite edge prefix and a partition, so the tests
assert them to machine precision on random instances — a strong check that the
implementation matches the paper's analysis.

Conventions follow the paper: ``w`` is the total weight of the FULL stream
(``2m``), ``S_t`` the first ``t`` edges, ``Vol_t``/``w_t(i)`` computed on
``S_t`` only, ``Q_t`` the unnormalised streaming modularity
``sum_C [2 Int_t(C) - Vol_t(C)^2 / w]``.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def degrees_t(edges_t: np.ndarray, n: int) -> np.ndarray:
    return np.bincount(np.asarray(edges_t).ravel(), minlength=n).astype(np.float64)


def streaming_q(edges_t: np.ndarray, labels: np.ndarray, w: float) -> float:
    """Q_t = sum_C [ 2 Int_t(C) - Vol_t(C)^2 / w ]."""
    e = np.asarray(edges_t)
    if e.size == 0:
        return 0.0
    li, lj = labels[e[:, 0]], labels[e[:, 1]]
    intra = float(np.count_nonzero(li == lj))
    deg = degrees_t(e, len(labels))
    vol = np.zeros(int(labels.max()) + 1)
    np.add.at(vol, labels, deg)
    return 2.0 * intra - float((vol**2).sum()) / w


def vol_t(edges_t: np.ndarray, labels: np.ndarray, comm: int) -> float:
    deg = degrees_t(edges_t, len(labels))
    return float(deg[labels == comm].sum())


def lemma1_increment(
    vol_ci: float, vol_cj: float, same_community: bool, w: float
) -> float:
    """Q_{t+1} - Q_t for arrival of (i, j) with the partition unchanged."""
    delta = 1.0 if same_community else 0.0
    return 2.0 * (delta - (vol_ci + vol_cj + 1.0 + delta) / w)


def l_term(
    edges_t: np.ndarray, labels: np.ndarray, node: int, comm: int, w: float
) -> float:
    """L_t(i, C) = deg_t(i -> C) - w_t(i) * Vol_t(C) / w (Lemma 2)."""
    e = np.asarray(edges_t)
    if e.size == 0:
        return 0.0
    deg = degrees_t(e, len(labels))
    w_i = deg[node]
    # Number of edges adjacent to `node` whose other endpoint lies in C.
    is_i = e[:, 0] == node
    is_j = e[:, 1] == node
    other_in_c = (labels[e[:, 1]] == comm) & is_i
    other_in_c2 = (labels[e[:, 0]] == comm) & is_j
    deg_to_c = float(np.count_nonzero(other_in_c) + np.count_nonzero(other_in_c2))
    return deg_to_c - w_i * vol_t(e, labels, comm) / w


def lemma2_delta(
    edges_t: np.ndarray, labels: np.ndarray, node: int, dst: int, w: float
) -> float:
    """ΔQ_t = 2 [ L_t(i, C(j)) - L_t(i, C(i)) - w_t(i)^2 / w ]."""
    src = int(labels[node])
    deg = degrees_t(edges_t, len(labels))
    w_i = deg[node]
    return 2.0 * (
        l_term(edges_t, labels, node, dst, w)
        - l_term(edges_t, labels, node, src, w)
        - (w_i**2) / w
    )


def delta_q_t1(
    edges_t: np.ndarray,
    labels: np.ndarray,
    i: int,
    j: int,
    w: float,
) -> float:
    """Closed form for ΔQ_{t+1} = Q_{t+1}^{(a)} - Q_{t+1}^{(c)} (Appendix C).

    Action (a): *i joins C(j)* on arrival of edge (i, j).
    """
    ci, cj = int(labels[i]), int(labels[j])
    deg = degrees_t(edges_t, len(labels))
    w_i = deg[i]
    vci = vol_t(edges_t, labels, ci)
    vcj = vol_t(edges_t, labels, cj)
    l_ci = _l_norm(edges_t, labels, i, ci, w, vci)
    l_cj = _l_norm(edges_t, labels, i, cj, w, vcj)
    return 2.0 * (
        1.0
        + (l_cj - 1.0 / w) * vcj
        - (l_ci - 1.0 / w) * vci
        - (w_i + 1.0) ** 2 / w
    )


def _l_norm(edges_t, labels, node, comm, w, vol) -> float:
    return l_term(edges_t, labels, node, comm, w) / vol if vol > 0 else 0.0


def theorem1_threshold(
    edges_t: np.ndarray, labels: np.ndarray, i: int, j: int, w: float
) -> float:
    """v_t(i, j) from Theorem 1.

    Two implicit assumptions of the paper's statement, FOUND BY PROPERTY
    TESTING (hypothesis, tests/test_theory.py) and handled here:

    1. The Appendix-C step ``u_t <= [l_t(i,C(i)) - l_t(i,C(j))] Vol_t(C(j))``
       replaces Vol_t(C(i)) by the larger Vol_t(C(j)) — valid only when the
       coefficient ``l_t(i,C(i)) - 1/w`` is NON-NEGATIVE.  A concrete
       counterexample with ``l_ci = l_cj < 0`` gives v_t = +inf per the
       paper's definition yet ΔQ_{t+1} = -0.74 < 0.
    2. Dividing by the denominator ``l_ci - l_cj`` assumes it is positive
       (consistent with the paper's τ₁ > τ₂ > 0 discussion).

    We therefore return the paper's ratio only on its (implicit) domain of
    validity — ``l_ci >= 1/w`` and ``l_ci > l_cj`` — and otherwise:

    * ``+inf`` when the bound degenerates but the sufficient inequality
      holds for every volume (denominator <= 0, RHS >= 0, AND l_ci >= 1/w);
    * ``-inf`` (no guarantee) when the proof's assumptions fail.

    The *practical* design conclusion of the paper (threshold volumes of
    joining communities) is unaffected: the regime it argues from
    (τ₁ > τ₂ > 0, small degrees) satisfies both assumptions.
    """
    ci, cj = int(labels[i]), int(labels[j])
    deg = degrees_t(edges_t, len(labels))
    w_i = deg[i]
    vci = vol_t(edges_t, labels, ci)
    vcj = vol_t(edges_t, labels, cj)
    l_ci = _l_norm(edges_t, labels, i, ci, w, vci)
    l_cj = _l_norm(edges_t, labels, i, cj, w, vcj)
    denom = l_ci - l_cj
    rhs = 1.0 - (w_i + 1.0) ** 2 / w
    if l_ci < 1.0 / w:  # assumption (1) violated: no guarantee
        return float("-inf")
    if denom <= 0.0:
        return float("inf") if rhs >= 0.0 else float("-inf")
    return rhs / denom


def brute_force_delta_q_t1(
    edges_t: np.ndarray, labels: np.ndarray, i: int, j: int, w: float
) -> Tuple[float, float]:
    """(Q_{t+1}^{(a)}, Q_{t+1}^{(c)}) computed from scratch — test oracle."""
    e_t1 = np.concatenate([edges_t, np.array([[i, j]])], axis=0)
    q_c = streaming_q(e_t1, labels, w)
    moved = labels.copy()
    moved[i] = labels[j]
    q_a = streaming_q(e_t1, moved, w)
    return q_a, q_c
