"""Clustering quality metrics: modularity, average F1 (Yang–Leskovec), NMI.

Also the *edge-free* selection metrics of paper §2.5 (entropy, average
density), computable from the streaming state ``(c, v)`` alone — i.e. without
the graph — which is what makes them usable for one-pass multi-``v_max``
selection.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Modularity (paper §3.1)
# ---------------------------------------------------------------------------

def modularity(edges: np.ndarray, labels: np.ndarray) -> float:
    """Newman modularity of a partition given the edge multiset.

    ``Q = (1/w) * (2*E_intra - sum_C Vol(C)^2 / w)`` with ``w = 2m``.
    Self-loop/PAD rows are ignored.
    """
    edges = np.asarray(edges)
    live = (edges[:, 0] >= 0) & (edges[:, 1] >= 0) & (edges[:, 0] != edges[:, 1])
    e = edges[live]
    m = e.shape[0]
    if m == 0:
        return 0.0
    w = 2.0 * m
    li, lj = labels[e[:, 0]], labels[e[:, 1]]
    intra = float(np.count_nonzero(li == lj))
    deg = np.bincount(e.ravel(), minlength=len(labels)).astype(np.float64)
    vol = np.zeros(int(labels.max()) + 1, dtype=np.float64)
    np.add.at(vol, labels, deg)
    return (2.0 * intra - float((vol**2).sum()) / w) / w


def weighted_modularity(
    edges: np.ndarray,
    labels: np.ndarray,
    weights: Optional[np.ndarray] = None,
) -> float:
    """Newman modularity of a partition of a *weighted* graph, self-loops
    included.

    The refinement subsystem scores contracted supergraphs with this: a
    self-loop is the contraction of a community's internal edges, so a
    self-loop of weight ``w`` counts ``2w`` toward its node's strength
    (``A_ii = 2w`` in the adjacency convention) and ``w`` toward intra
    weight.  Under that convention the modularity of a supergraph partition
    equals the modularity of the projected partition on the original graph
    (the classic Louvain invariant — pinned as a hypothesis property in
    ``tests/test_refine.py``).  With unit weights and no self-loops this
    agrees with :func:`modularity`.
    """
    edges = np.asarray(edges)
    w_e = (
        np.ones(edges.shape[0], dtype=np.float64)
        if weights is None
        else np.asarray(weights, dtype=np.float64)
    )
    live = (edges[:, 0] >= 0) & (edges[:, 1] >= 0)
    e, w_e = edges[live], w_e[live]
    W = 2.0 * float(w_e.sum())
    if W == 0:
        return 0.0
    li, lj = labels[e[:, 0]], labels[e[:, 1]]
    intra = float(w_e[li == lj].sum())
    # e.ravel() lists a self-loop's endpoint twice -> its 2w strength.
    deg = np.zeros(len(labels), dtype=np.float64)
    np.add.at(deg, e.ravel(), np.repeat(w_e, 2))
    vol = np.zeros(int(labels.max()) + 1, dtype=np.float64)
    np.add.at(vol, labels, deg)
    return (2.0 * intra - float((vol**2).sum()) / W) / W


def streaming_modularity_terms(
    edges: np.ndarray, labels: np.ndarray
) -> Tuple[float, float]:
    """(Int, Vol^2-sum) terms of the *unnormalised* streaming Q_t (paper §3.1)."""
    edges = np.asarray(edges)
    live = (edges[:, 0] >= 0) & (edges[:, 1] >= 0)
    e = edges[live]
    li, lj = labels[e[:, 0]], labels[e[:, 1]]
    intra = float(np.count_nonzero(li == lj))
    deg = np.bincount(e.ravel(), minlength=len(labels)).astype(np.float64)
    vol = np.zeros(int(labels.max()) + 1, dtype=np.float64)
    np.add.at(vol, labels, deg)
    return intra, float((vol**2).sum())


# ---------------------------------------------------------------------------
# Average F1 score (Yang & Leskovec / SCD convention)
# ---------------------------------------------------------------------------

def _contingency(a: np.ndarray, b: np.ndarray):
    """Sparse contingency counts between two labelings over the same nodes."""
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    key = a * (b.max() + 1) + b
    uk, cnt = np.unique(key, return_counts=True)
    return uk // (b.max() + 1), uk % (b.max() + 1), cnt


def avg_f1(pred: np.ndarray, truth: np.ndarray) -> float:
    """Average F1: mean of best-match F1 in both directions (paper §4.3)."""
    pa, pb, cnt = _contingency(pred, truth)
    sz_pred = np.bincount(np.asarray(pred, dtype=np.int64))
    sz_truth = np.bincount(np.asarray(truth, dtype=np.int64))
    inter = cnt.astype(np.float64)
    prec = inter / sz_pred[pa]
    rec = inter / sz_truth[pb]
    f1 = 2 * prec * rec / np.maximum(prec + rec, 1e-12)

    def best(keys, f1s, n_groups, live_sizes):
        bst = np.zeros(n_groups, dtype=np.float64)
        np.maximum.at(bst, keys, f1s)
        mask = live_sizes > 0
        return float(bst[mask].mean()) if mask.any() else 0.0

    f_pred = best(pa, f1, len(sz_pred), sz_pred)
    f_truth = best(pb, f1, len(sz_truth), sz_truth)
    return 0.5 * (f_pred + f_truth)


# ---------------------------------------------------------------------------
# Normalized Mutual Information (disjoint partitions)
# ---------------------------------------------------------------------------

def nmi(pred: np.ndarray, truth: np.ndarray) -> float:
    """NMI with sqrt normalisation over the joint node distribution."""
    n = len(pred)
    pa, pb, cnt = _contingency(pred, truth)
    pxy = cnt / n
    px = np.bincount(np.asarray(pred, dtype=np.int64)) / n
    py = np.bincount(np.asarray(truth, dtype=np.int64)) / n
    mi = float(np.sum(pxy * np.log(np.maximum(pxy / (px[pa] * py[pb]), 1e-300))))
    hx = -float(np.sum(px[px > 0] * np.log(px[px > 0])))
    hy = -float(np.sum(py[py > 0] * np.log(py[py > 0])))
    denom = np.sqrt(hx * hy)
    return mi / denom if denom > 0 else 0.0


# ---------------------------------------------------------------------------
# Edge-free selection metrics (paper §2.5) — computable from (c, v) alone
# ---------------------------------------------------------------------------

def entropy_from_state(v: np.ndarray, w: float) -> float:
    """H(v) = -sum_k (v_k/w) log(v_k/w) over non-empty communities."""
    vk = np.asarray(v, dtype=np.float64)
    vk = vk[vk > 0]
    p = vk / w
    return -float(np.sum(p * np.log(p)))


def avg_density_from_state(c: np.ndarray, v: np.ndarray) -> float:
    """D(c,v) = (1/|P|) sum_k v_k / (|C_k| (|C_k|-1)) over non-empty k."""
    c = np.asarray(c, dtype=np.int64)
    sizes = np.bincount(c, minlength=len(v))
    live = sizes > 0
    dens = np.zeros(len(v), dtype=np.float64)
    big = live & (sizes > 1)
    dens[big] = np.asarray(v)[big] / (sizes[big] * (sizes[big] - 1.0))
    k = int(np.count_nonzero(live))
    return float(dens[live].sum() / k) if k else 0.0


def community_stats(labels: np.ndarray) -> Dict[str, float]:
    sizes = np.bincount(np.asarray(labels, dtype=np.int64))
    sizes = sizes[sizes > 0]
    return {
        "n_communities": int(len(sizes)),
        "max_size": int(sizes.max()) if len(sizes) else 0,
        "mean_size": float(sizes.mean()) if len(sizes) else 0.0,
    }
