"""The paper's Algorithm 1 — one-pass edge-streaming graph clustering.

Three tiers (see DESIGN.md §3):

* :func:`cluster_stream_oracle` — bit-faithful dictionary implementation of
  Algorithm 1 (the paper-faithful baseline; pure Python/numpy).
* :func:`cluster_stream_dense` — dense-array variant where a node's initial
  community index is its own node id (behaviourally identical up to community
  relabeling; this is the layout every JAX/Pallas tier uses).
* :func:`cluster_stream_scan` — ``jax.lax.scan`` port, one edge per step,
  bit-exact with the dense oracle.

State is exactly the paper's ``3n`` integers per node: degree ``d``, community
``c``, community volume ``v`` (indexed by community id, which is a node id in
the dense layout).

Tie rule: Algorithm 1 line 11 — ``v[c_i] <= v[c_j]`` ⇒ *i joins the community
of j*.  (The paper's §2.3 prose states the opposite tie-break; we follow the
pseudocode, which is what the reference C++ implementation does.)
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Sentinel node id used to pad edge chunks to fixed shapes; padded edges are
# no-ops in every tier.
PAD = -1


# ---------------------------------------------------------------------------
# Tier 0a: faithful dictionary oracle (paper's Algorithm 1, line by line)
# ---------------------------------------------------------------------------

def cluster_stream_oracle(edges: np.ndarray, v_max: int) -> Dict[int, int]:
    """Algorithm 1, dictionaries with default value 0, community ids 1,2,...

    Args:
      edges: int array of shape (m, 2); rows are stream order.
      v_max: volume threshold parameter (``>= 1``).

    Returns:
      dict node id -> community id.
    """
    d: Dict[int, int] = {}
    v: Dict[int, int] = {}
    c: Dict[int, int] = {}
    k = 1
    for i, j in np.asarray(edges):
        i, j = int(i), int(j)
        if i == PAD or j == PAD or i == j:
            continue
        if c.get(i, 0) == 0:
            c[i] = k
            k += 1
        if c.get(j, 0) == 0:
            c[j] = k
            k += 1
        d[i] = d.get(i, 0) + 1
        d[j] = d.get(j, 0) + 1
        v[c[i]] = v.get(c[i], 0) + 1
        v[c[j]] = v.get(c[j], 0) + 1
        if v[c[i]] <= v_max and v[c[j]] <= v_max:
            if v[c[i]] <= v[c[j]]:  # i joins the community of j
                v[c[j]] += d[i]
                v[c[i]] -= d[i]
                c[i] = c[j]
            else:  # j joins the community of i
                v[c[i]] += d[j]
                v[c[j]] -= d[j]
                c[j] = c[i]
    return c


# ---------------------------------------------------------------------------
# Tier 0b: dense-array oracle (initial community of node i is i)
# ---------------------------------------------------------------------------

def cluster_stream_dense(
    edges: np.ndarray, v_max: int, n: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dense-layout Algorithm 1.  Returns ``(c, d, v)`` int64 arrays of size n.

    Community ids live in the node-id space (the founding node's id).  This is
    a pure relabeling of the paper's incrementing-``k`` scheme: only equality
    of community ids and the volumes ``v`` enter the decision rule, and both
    are preserved.  Verified against :func:`cluster_stream_oracle` in tests.
    """
    d = np.zeros(n, dtype=np.int64)
    c = np.arange(n, dtype=np.int64)
    v = np.zeros(n, dtype=np.int64)
    for i, j in np.asarray(edges):
        i, j = int(i), int(j)
        if i == PAD or j == PAD or i == j:
            continue
        d[i] += 1
        d[j] += 1
        ci, cj = c[i], c[j]
        v[ci] += 1
        v[cj] += 1
        if v[ci] <= v_max and v[cj] <= v_max:
            if v[ci] <= v[cj]:  # i joins the community of j
                v[cj] += d[i]
                v[ci] -= d[i]
                c[i] = cj
            else:  # j joins the community of i
                v[ci] += d[j]
                v[cj] -= d[j]
                c[j] = ci
    return c, d, v


# ---------------------------------------------------------------------------
# Tier 1: jax.lax.scan port (bit-exact with the dense oracle)
# ---------------------------------------------------------------------------

def _edge_update(state, edge, *, v_max):
    """One Algorithm-1 step on dense (d, c, v) int32 state."""
    d, c, v = state
    i, j = edge[0], edge[1]
    live = (i != PAD) & (j != PAD) & (i != j)
    # Clamp so gathers stay in bounds for padded edges (updates are masked).
    i = jnp.maximum(i, 0)
    j = jnp.maximum(j, 0)
    one = jnp.where(live, jnp.int32(1), jnp.int32(0))

    d = d.at[i].add(one).at[j].add(one)
    di, dj = d[i], d[j]
    ci, cj = c[i], c[j]
    # Chained .at updates have sequential semantics, so ci == cj gets +2.
    v = v.at[ci].add(one).at[cj].add(one)
    vci, vcj = v[ci], v[cj]

    ok = live & (vci <= v_max) & (vcj <= v_max)
    i_joins = ok & (vci <= vcj)
    j_joins = ok & (vci > vcj)

    move_i = jnp.where(i_joins, di, 0)
    move_j = jnp.where(j_joins, dj, 0)
    v = v.at[cj].add(move_i - move_j).at[ci].add(move_j - move_i)
    c = c.at[i].set(jnp.where(i_joins, cj, ci))
    c = c.at[j].set(jnp.where(j_joins, ci, c[j]))
    return (d, c, v), ()


@functools.partial(jax.jit, static_argnames=("v_max", "n"))
def cluster_stream_scan(edges: Array, v_max: int, n: int):
    """``lax.scan`` over the stream; state = 3n int32 (paper footprint).

    Returns ``(c, d, v)``.  Sequential by construction — bit-exact with
    :func:`cluster_stream_dense`; used as the on-device oracle and for small
    graphs.  Large graphs use the chunked tier (``core.chunked``).
    """
    edges = edges.astype(jnp.int32)
    init = (
        jnp.zeros(n, jnp.int32),
        jnp.arange(n, dtype=jnp.int32),
        jnp.zeros(n, jnp.int32),
    )
    (d, c, v), _ = jax.lax.scan(
        functools.partial(_edge_update, v_max=jnp.int32(v_max)), init, edges
    )
    return c, d, v


def canonical_labels(c: np.ndarray) -> np.ndarray:
    """Map community labels to 0..K-1 by first appearance (for comparisons)."""
    c = np.asarray(c)
    _, inv = np.unique(c, return_inverse=True)
    first = {}
    out = np.empty_like(inv)
    nxt = 0
    for idx, lab in enumerate(inv):
        if lab not in first:
            first[lab] = nxt
            nxt += 1
        out[idx] = first[lab]
    return out
