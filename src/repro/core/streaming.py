"""The paper's Algorithm 1 — one-pass edge-streaming graph clustering.

Three tiers (see DESIGN.md §3):

* :func:`oracle_update` — bit-faithful dictionary implementation of
  Algorithm 1 (the paper-faithful baseline; pure Python/numpy).
* :func:`dense_update` — dense-array variant where a node's initial
  community index is its own node id (behaviourally identical up to community
  relabeling; this is the layout every JAX/Pallas tier uses).
* :func:`scan_update` — ``jax.lax.scan`` port, one edge per step, bit-exact
  with the dense oracle.

Each tier takes and returns a :class:`repro.core.state.ClusterState` — the
paper's ``3n`` integers per node (degree ``d``, community ``c``, community
volume ``v``) plus an edges-seen counter — so a stream can be ingested in
arbitrary batches and suspended/resumed (``repro.cluster.StreamClusterer``).

The historical one-shot entry points (``cluster_stream_oracle``,
``cluster_stream_dense``, ``cluster_stream_scan``) are retained as thin
shims over the state-threading tiers.

.. deprecated::
   Call sites should use :func:`repro.cluster.cluster` /
   :class:`repro.cluster.StreamClusterer` with ``ClusterConfig(backend=...)``
   instead of these per-tier functions.

Tie rule: Algorithm 1 line 11 — ``v[c_i] <= v[c_j]`` ⇒ *i joins the community
of j*.  (The paper's §2.3 prose states the opposite tie-break; we follow the
pseudocode, which is what the reference C++ implementation does.)
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import ClusterState, count_live_edges
from repro.graph.pipeline import PAD

Array = jax.Array


# ---------------------------------------------------------------------------
# Tier 0a: faithful dictionary oracle (paper's Algorithm 1, line by line)
# ---------------------------------------------------------------------------

def _oracle_loop(
    d: Dict[int, int],
    v: Dict[int, int],
    c: Dict[int, int],
    k: int,
    edges: np.ndarray,
    v_max: int,
) -> Tuple[int, int]:
    """Algorithm 1 inner loop on the paper's dictionaries.

    Returns ``(next_k, live_edges_processed)``."""
    seen = 0
    for i, j in np.asarray(edges):
        i, j = int(i), int(j)
        if i == PAD or j == PAD or i == j:
            continue
        seen += 1
        if c.get(i, 0) == 0:
            c[i] = k
            k += 1
        if c.get(j, 0) == 0:
            c[j] = k
            k += 1
        d[i] = d.get(i, 0) + 1
        d[j] = d.get(j, 0) + 1
        v[c[i]] = v.get(c[i], 0) + 1
        v[c[j]] = v.get(c[j], 0) + 1
        if v[c[i]] <= v_max and v[c[j]] <= v_max:
            if v[c[i]] <= v[c[j]]:  # i joins the community of j
                v[c[j]] += d[i]
                v[c[i]] -= d[i]
                c[i] = c[j]
            else:  # j joins the community of i
                v[c[i]] += d[j]
                v[c[j]] -= d[j]
                c[j] = c[i]
    return k, seen


def oracle_update(
    state: ClusterState, edges: np.ndarray, v_max: int
) -> ClusterState:
    """State-threading dict oracle (paper label space, resumable).

    Layout (see :class:`ClusterState`): ``c[i] = 0`` means node ``i`` has
    never appeared; community ids are 1-based and ``v`` is stored shifted by
    one (``v[k - 1]`` is the volume of community ``k``).  Fresh state must be
    created with ``c`` zeroed — use ``oracle_init(n)``.
    """
    s = state.to_numpy()
    c = {i: int(lab) for i, lab in enumerate(s.c) if lab != 0}
    d = {i: int(deg) for i, deg in enumerate(s.d) if deg != 0}
    v = {kk: int(vol) for kk, vol in enumerate(np.asarray(s.v), start=1) if vol != 0}
    # Every node gets a fresh id exactly once, so the next id is one past the
    # number of ever-seen nodes (max(c) would wrongly reuse absorbed ids).
    k = int(np.count_nonzero(np.asarray(s.c))) + 1
    _, seen = _oracle_loop(d, v, c, k, edges, v_max)
    out = ClusterState.init(s.n, numpy=True)
    out.c[:] = 0
    for i, lab in c.items():
        out.c[i] = lab
    for i, deg in d.items():
        out.d[i] = deg
    for kk, vol in v.items():
        out.v[kk - 1] = vol
    out.edges_seen = s.edges_seen + seen
    return out


def oracle_init(n: int) -> ClusterState:
    """Fresh state in the dict-oracle label space (all nodes unassigned)."""
    s = ClusterState.init(n, numpy=True)
    s.c[:] = 0
    return s


def cluster_stream_oracle(edges: np.ndarray, v_max: int) -> Dict[int, int]:
    """One-shot Algorithm 1, dictionaries with default 0, community ids 1,2,...

    .. deprecated:: use ``repro.cluster.cluster(..., backend="oracle")``.

    Args:
      edges: int array of shape (m, 2); rows are stream order.
      v_max: volume threshold parameter (``>= 1``).

    Returns:
      dict node id -> community id.
    """
    d: Dict[int, int] = {}
    v: Dict[int, int] = {}
    c: Dict[int, int] = {}
    _oracle_loop(d, v, c, 1, edges, v_max)
    return c


# ---------------------------------------------------------------------------
# Tier 0b: dense-array oracle (initial community of node i is i)
# ---------------------------------------------------------------------------

def dense_update(
    state: ClusterState, edges: np.ndarray, v_max: int
) -> ClusterState:
    """State-threading dense-layout Algorithm 1 (numpy loop, resumable).

    Community ids live in the node-id space (the founding node's id).  This
    is a pure relabeling of the paper's incrementing-``k`` scheme: only
    equality of community ids and the volumes ``v`` enter the decision rule,
    and both are preserved.  Verified against :func:`oracle_update` in tests.
    """
    s = state.to_numpy()
    d, c, v = s.d.copy(), s.c.copy(), s.v.copy()
    seen = 0
    for i, j in np.asarray(edges):
        i, j = int(i), int(j)
        if i == PAD or j == PAD or i == j:
            continue
        seen += 1
        d[i] += 1
        d[j] += 1
        ci, cj = c[i], c[j]
        v[ci] += 1
        v[cj] += 1
        if v[ci] <= v_max and v[cj] <= v_max:
            if v[ci] <= v[cj]:  # i joins the community of j
                v[cj] += d[i]
                v[ci] -= d[i]
                c[i] = cj
            else:  # j joins the community of i
                v[ci] += d[j]
                v[cj] -= d[j]
                c[j] = ci
    return ClusterState(d=d, c=c, v=v, edges_seen=s.edges_seen + seen)


def cluster_stream_dense(
    edges: np.ndarray, v_max: int, n: int
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One-shot dense-layout Algorithm 1.  Returns ``(c, d, v)`` int64 arrays.

    .. deprecated:: use ``repro.cluster.cluster(..., backend="dense")``.
    """
    s = dense_update(ClusterState.init(n, numpy=True), edges, v_max)
    return (
        s.c.astype(np.int64),
        s.d.astype(np.int64),
        s.v.astype(np.int64),
    )


# ---------------------------------------------------------------------------
# Tier 1: jax.lax.scan port (bit-exact with the dense oracle)
# ---------------------------------------------------------------------------

def _edge_update(state, edge, *, v_max):
    """One Algorithm-1 step on dense (d, c, v) int32 state."""
    d, c, v = state
    i, j = edge[0], edge[1]
    live = (i != PAD) & (j != PAD) & (i != j)
    # Clamp so gathers stay in bounds for padded edges (updates are masked).
    i = jnp.maximum(i, 0)
    j = jnp.maximum(j, 0)
    one = jnp.where(live, jnp.int32(1), jnp.int32(0))

    d = d.at[i].add(one).at[j].add(one)
    di, dj = d[i], d[j]
    ci, cj = c[i], c[j]
    # Chained .at updates have sequential semantics, so ci == cj gets +2.
    v = v.at[ci].add(one).at[cj].add(one)
    vci, vcj = v[ci], v[cj]

    ok = live & (vci <= v_max) & (vcj <= v_max)
    i_joins = ok & (vci <= vcj)
    j_joins = ok & (vci > vcj)

    move_i = jnp.where(i_joins, di, 0)
    move_j = jnp.where(j_joins, dj, 0)
    v = v.at[cj].add(move_i - move_j).at[ci].add(move_j - move_i)
    c = c.at[i].set(jnp.where(i_joins, cj, ci))
    c = c.at[j].set(jnp.where(j_joins, ci, c[j]))
    return (d, c, v), ()


@jax.jit
def scan_update(state: ClusterState, edges: Array, v_max: Array) -> ClusterState:
    """State-threading ``lax.scan`` tier (one edge per step, resumable).

    Sequential by construction — bit-exact with :func:`dense_update`; used as
    the on-device oracle and for small graphs.  Large graphs use the chunked
    tier (``core.chunked``) or the Pallas kernel (``kernels.edge_stream``).
    """
    edges = edges.astype(jnp.int32)
    init = (
        state.d.astype(jnp.int32),
        state.c.astype(jnp.int32),
        state.v.astype(jnp.int32),
    )
    (d, c, v), _ = jax.lax.scan(
        functools.partial(_edge_update, v_max=jnp.int32(v_max)), init, edges
    )
    return ClusterState(
        d=d, c=c, v=v, edges_seen=state.edges_seen + count_live_edges(edges, PAD)
    )


@functools.partial(jax.jit, static_argnames=("v_max", "n"))
def cluster_stream_scan(edges: Array, v_max: int, n: int):
    """One-shot ``lax.scan`` tier; state = 3n int32 (paper footprint).

    .. deprecated:: use ``repro.cluster.cluster(..., backend="scan")``.

    Returns ``(c, d, v)``.
    """
    s = scan_update(ClusterState.init(n), edges, jnp.int32(v_max))
    return s.c, s.d, s.v


def canonical_labels(c: np.ndarray) -> np.ndarray:
    """Map community labels to 0..K-1 by first appearance (for comparisons).

    Fully vectorised: ``np.unique`` gives each label's first-occurrence index;
    ranking those indices by argsort yields the first-appearance order without
    any per-element Python work (this sits on every quality comparison, where
    the old dict loop was O(n) interpreter time).
    """
    c = np.asarray(c)
    _, first, inv = np.unique(c, return_index=True, return_inverse=True)
    order = np.argsort(first, kind="stable")
    rank = np.empty_like(order)
    rank[order] = np.arange(order.shape[0])
    return rank[inv]
