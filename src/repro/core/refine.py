"""Supergraph refinement engines — the algorithmic core of the multi-stage
refinement subsystem (``repro.cluster.refine``, DESIGN.md §11).

The paper's one-pass algorithm buys its ``3n``-int footprint with quality:
streamed labels are noisy (over-fragmented at small ``v_max``, over-merged at
large).  CluStRE (arXiv 2502.06879) shows the gap closes by refining a
*contracted* graph after the stream: communities become supernodes, the
supergraph is O(#clusters) and fits in memory even when the edge list never
does, and a few weighted Louvain / label-propagation rounds over it recover
near-offline modularity.  Everything here is pure numpy over the contracted
representation:

* :func:`contract_pairs` / :func:`contract_graph` — build the weighted
  supergraph from accumulated inter-community weights (the streaming sketch)
  or from an explicit edge list (exact; used by tests and the equivalence
  property).
* :func:`refine_partition` — weighted Louvain or label-propagation rounds on
  the supergraph, then community merge/split moves scored by the modularity
  terms (``repro.core.metrics``).
* :func:`project_labels` — push refined supergraph labels back through the
  contraction map onto nodes, staying in the node-id label space so the
  result is a valid :class:`~repro.core.state.ClusterState` labelling.

Invariant (pinned by a hypothesis property): the weighted modularity of the
projected labels on the original graph equals the weighted modularity of the
supergraph partition on the contracted graph — so supergraph moves optimise
exactly the objective that matters on the full graph.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.core.labelprop import label_propagation
from repro.core.louvain import _coarsen, _one_level, _to_csr


class Supergraph(NamedTuple):
    """A contracted weighted graph over the distinct values of a labelling.

    ``edges``/``weights`` hold the *inter*-community weights in compressed
    supernode ids (each unordered pair once, no self rows); ``self_weight``
    holds each supernode's internal (intra-community) weight; ``node_of``
    maps compressed supernode id back to the original label (a node id).
    """

    edges: np.ndarray  # (E, 2) int64 compressed supernode ids, a < b
    weights: np.ndarray  # (E,) float64 inter-supernode weight
    self_weight: np.ndarray  # (K,) float64 internal weight per supernode
    node_of: np.ndarray  # (K,) int64 original label of each supernode

    @property
    def k(self) -> int:
        return int(self.node_of.shape[0])


def contract_pairs(
    pair_a: np.ndarray,
    pair_b: np.ndarray,
    pair_w: np.ndarray,
    labels: np.ndarray,
) -> Supergraph:
    """Contract accumulated ``(a, b, w)`` label pairs through ``labels``.

    ``pair_a``/``pair_b`` are community labels *as observed mid-stream* — in
    the node-id label space, a label is its founding node's id, so the final
    home of community ``a``'s mass is ``labels[a]``, the founder's final
    community.  Remapping every entry through the final labelling folds
    stale observations into the supernodes that actually exist at the end
    (entries whose endpoints land in the same supernode become internal
    weight).  The supernode set is the full set of distinct final labels,
    including communities no sketch entry mentions (isolated supernodes
    refine as singletons).
    """
    labels = np.asarray(labels)
    uniq, inv = np.unique(labels, return_inverse=True)
    k = uniq.shape[0]
    # Compress final labels to [0, K); map each entry endpoint through the
    # founder's final community.
    rank = np.zeros(int(uniq[-1]) + 1 if k else 1, dtype=np.int64)
    rank[uniq] = np.arange(k)
    a = rank[labels[np.asarray(pair_a, dtype=np.int64)]]
    b = rank[labels[np.asarray(pair_b, dtype=np.int64)]]
    w = np.asarray(pair_w, dtype=np.float64)
    lo, hi = np.minimum(a, b), np.maximum(a, b)
    self_weight = np.zeros(k, dtype=np.float64)
    diag = lo == hi
    np.add.at(self_weight, lo[diag], w[diag])
    lo, hi, w = lo[~diag], hi[~diag], w[~diag]
    key = lo * k + hi
    uk, pos = np.unique(key, return_inverse=True)
    wsum = np.zeros(uk.shape[0], dtype=np.float64)
    np.add.at(wsum, pos, w)
    edges = np.stack([uk // k, uk % k], axis=1).astype(np.int64)
    return Supergraph(
        edges=edges,
        weights=wsum,
        self_weight=self_weight,
        node_of=uniq.astype(np.int64),
    )


def contract_graph(
    edges: np.ndarray,
    labels: np.ndarray,
    weights: Optional[np.ndarray] = None,
) -> Supergraph:
    """Exact contraction of an explicit edge list by a labelling.

    The ground-truth counterpart of the streaming sketch: every live edge
    ``(i, j)`` contributes its weight between supernodes ``labels[i]`` and
    ``labels[j]``.  Used by the equivalence property tests and anywhere the
    edges are actually in memory.
    """
    e = np.asarray(edges)
    w = (
        np.ones(e.shape[0], dtype=np.float64)
        if weights is None
        else np.asarray(weights, dtype=np.float64)
    )
    live = (e[:, 0] >= 0) & (e[:, 1] >= 0) & (e[:, 0] != e[:, 1])
    e, w = e[live], w[live]
    labels = np.asarray(labels)
    # contract_pairs remaps entries through labels[founder]; here endpoints
    # are nodes, so "founder" is the node itself and the identity labelling
    # of pair keys is exactly labels[i] — reuse the same path by passing the
    # node ids as pair keys.
    return contract_pairs(e[:, 0], e[:, 1], w, labels)


# ---------------------------------------------------------------------------
# Refinement rounds on the supergraph
# ---------------------------------------------------------------------------

def _sg_strength(sg: Supergraph) -> np.ndarray:
    """Supernode strengths: incident inter-weight + 2x internal weight."""
    deg = 2.0 * sg.self_weight.copy()
    np.add.at(deg, sg.edges[:, 0], sg.weights)
    np.add.at(deg, sg.edges[:, 1], sg.weights)
    return deg


def _community_terms(
    sg: Supergraph, sg_labels: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, float]:
    """(internal weight, volume) per community + total weight W.

    The modularity terms of the contracted graph: community ``c``
    contributes ``2*in_c/W - (vol_c/W)^2`` to Q (same convention as
    :func:`repro.core.metrics.weighted_modularity`).
    """
    k = int(sg_labels.max()) + 1 if sg_labels.size else 0
    strength = _sg_strength(sg)
    W = float(strength.sum())
    vol = np.zeros(k, dtype=np.float64)
    np.add.at(vol, sg_labels, strength)
    internal = np.zeros(k, dtype=np.float64)
    np.add.at(internal, sg_labels, sg.self_weight)
    la, lb = sg_labels[sg.edges[:, 0]], sg_labels[sg.edges[:, 1]]
    intra = la == lb
    np.add.at(internal, la[intra], sg.weights[intra])
    return internal, vol, W


def _merge_moves(sg: Supergraph, sg_labels: np.ndarray) -> np.ndarray:
    """Greedy community-pair merges with positive modularity gain.

    Louvain moves one supernode at a time and can stall where no single
    supernode moves but merging two whole communities pays:
    ``dQ(c1, c2) = 2*w_between/W - 2*vol1*vol2/W^2``.  Repeatedly applies
    the best positive merge until none remains (community count only
    shrinks, so this terminates).
    """
    labels = np.asarray(sg_labels, dtype=np.int64).copy()
    while True:
        _, vol, W = _community_terms(sg, labels)
        if W <= 0:
            return labels
        la, lb = labels[sg.edges[:, 0]], labels[sg.edges[:, 1]]
        inter = la != lb
        if not inter.any():
            return labels
        clo = np.minimum(la[inter], lb[inter])
        chi = np.maximum(la[inter], lb[inter])
        ncomm = vol.shape[0]
        key = clo * ncomm + chi
        uk, pos = np.unique(key, return_inverse=True)
        between = np.zeros(uk.shape[0], dtype=np.float64)
        np.add.at(between, pos, sg.weights[inter])
        c1, c2 = uk // ncomm, uk % ncomm
        gain = 2.0 * between / W - 2.0 * vol[c1] * vol[c2] / (W * W)
        best = int(np.argmax(gain))
        if gain[best] <= 1e-12:
            return labels
        labels[labels == c2[best]] = c1[best]


def _split_moves(sg: Supergraph, sg_labels: np.ndarray) -> np.ndarray:
    """Dissolve refined communities whose members score higher apart.

    A community's modularity contribution is ``2*in_c/W - (vol_c/W)^2``;
    dissolved back into its constituent supernodes (the streamed clusters —
    the finest partition the contraction can express) the members contribute
    ``sum_m 2*self_m/W - (vol_m/W)^2``.  Where the dissolved sum is higher,
    the merge was a bad one — undo it.  This is the split half of the
    merge/split pair: it cannot split a *streamed* cluster (only the
    buffered replay can), but it reverses over-merging at zero edge I/O.
    """
    labels = np.asarray(sg_labels, dtype=np.int64).copy()
    internal, vol, W = _community_terms(sg, labels)
    if W <= 0:
        return labels
    strength = _sg_strength(sg)
    k = vol.shape[0]
    as_one = 2.0 * internal / W - (vol / W) ** 2
    solo = 2.0 * sg.self_weight / W - (strength / W) ** 2
    solo_sum = np.zeros(k, dtype=np.float64)
    np.add.at(solo_sum, labels, solo)
    members = np.bincount(labels, minlength=k)
    dissolve = (members > 1) & (solo_sum > as_one + 1e-12)
    if dissolve.any():
        hit = dissolve[labels]
        # each dissolved member becomes its own community, keyed off the
        # supernode id shifted past the existing community id range
        labels[hit] = k + np.flatnonzero(hit)
    return labels


def refine_partition(
    sg: Supergraph,
    engine: str = "louvain",
    rounds: int = 10,
    seed: int = 0,
) -> np.ndarray:
    """Refinement rounds on a supergraph; returns (K,) supernode labels.

    ``engine="louvain"``: multi-level weighted Louvain with supernode
    self-weights carried through coarsening, then merge/split moves.
    ``engine="labelprop"``: weighted plurality sweeps (self-weight is inert
    — a self-loop votes for the label the node already has), then the same
    merge/split pass.  Labels are compressed supernode indices; singleton
    supernodes untouched by any move keep their own index.
    """
    k = sg.k
    if k == 0:
        return np.zeros(0, dtype=np.int64)
    if engine == "labelprop":
        sg_labels = label_propagation(
            sg.edges, k, sweeps=rounds, seed=seed, weights=sg.weights
        )
    elif engine == "louvain":
        sg_labels = _louvain_with_self(sg, max_levels=rounds, seed=seed)
    else:
        raise ValueError(
            f"unknown refine engine {engine!r}; expected 'louvain' or "
            "'labelprop'"
        )
    # canonical compressed ids so merge/split bincounts stay O(K)
    _, sg_labels = np.unique(sg_labels, return_inverse=True)
    sg_labels = _merge_moves(sg, sg_labels)
    _, sg_labels = np.unique(sg_labels, return_inverse=True)
    sg_labels = _split_moves(sg, sg_labels)
    _, sg_labels = np.unique(sg_labels, return_inverse=True)
    return sg_labels.astype(np.int64)


def _louvain_with_self(sg: Supergraph, max_levels: int, seed: int) -> np.ndarray:
    """Multi-level Louvain on a supergraph with per-node self-weights.

    ``core.louvain`` drops self-loops at CSR build time (raw graphs have
    none), so internal weight rides separately: it joins each node's
    strength in ``_one_level`` and folds into the coarse level's
    self-weights after each contraction.
    """
    rng = np.random.default_rng(seed)
    indptr, indices, data = _to_csr(sg.edges, sg.k, sg.weights)
    self_w = sg.self_weight.astype(np.float64).copy()
    W = float(data.sum()) + 2.0 * float(self_w.sum())
    if W == 0:
        return np.arange(sg.k, dtype=np.int64)
    mapping = np.arange(sg.k, dtype=np.int64)
    for _ in range(max_levels):
        labels, improved = _one_level(
            indptr, indices, data, W, rng, self_weight=self_w
        )
        if not improved:
            break
        # coarse self-weights: members' self-weights + internal CSR weight
        # (each internal edge appears in both directions -> diag/2)
        uniq, new = np.unique(labels, return_inverse=True)
        coarse_self = np.zeros(uniq.shape[0], dtype=np.float64)
        np.add.at(coarse_self, new, self_w)
        src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
        internal = new[src] == new[indices]
        np.add.at(coarse_self, new[src[internal]], data[internal] / 2.0)
        indptr, indices, data, new2 = _coarsen(indptr, indices, data, labels)
        # _coarsen keeps contracted internal edges as diagonal entries;
        # they are already in coarse_self, so drop them from the CSR
        indptr, indices, data = _drop_diagonal(indptr, indices, data)
        self_w = coarse_self
        mapping = new2[labels[mapping]]
        if len(indptr) - 1 <= 1:
            break
    return mapping


def _drop_diagonal(indptr, indices, data):
    src = np.repeat(np.arange(len(indptr) - 1), np.diff(indptr))
    keep = src != indices
    nip = np.zeros(len(indptr), dtype=np.int64)
    np.add.at(nip, src[keep] + 1, 1)
    return np.cumsum(nip), indices[keep], data[keep]


def project_labels(
    node_labels: np.ndarray, sg: Supergraph, sg_labels: np.ndarray
) -> np.ndarray:
    """Push refined supergraph labels back onto nodes.

    Each refined community is named by its first member's original label (a
    node id), so projected labels remain valid in the node-id label space —
    the representation every dense-space :class:`ClusterState` uses.
    """
    node_labels = np.asarray(node_labels)
    k = sg.k
    # representative original label per refined community: first supernode
    n_comm = int(sg_labels.max()) + 1 if k else 0
    rep = np.zeros(n_comm, dtype=np.int64)
    first = np.full(n_comm, k, dtype=np.int64)
    np.minimum.at(first, sg_labels, np.arange(k))
    rep = sg.node_of[first]
    # node -> supernode (compressed) -> refined community -> representative
    rank = np.zeros(int(sg.node_of[-1]) + 1 if k else 1, dtype=np.int64)
    rank[sg.node_of] = np.arange(k)
    return rep[sg_labels[rank[node_labels]]].astype(np.int32)
