from repro.kernels.edge_decide.ops import edge_decide  # noqa: F401
