"""Pure-jnp oracle for the edge_decide kernel."""

from __future__ import annotations

import jax.numpy as jnp


def edge_decide_ref(vci, vcj, di, dj, live, v_max: int):
    live = live != 0
    ok = live & (vci <= v_max) & (vcj <= v_max)
    i_joins = ok & (vci <= vcj)
    j_joins = ok & (vci > vcj)
    action = jnp.where(i_joins, 1, jnp.where(j_joins, 2, 0)).astype(jnp.int32)
    amount = jnp.where(i_joins, di, jnp.where(j_joins, dj, 0)).astype(jnp.int32)
    return action, amount
