"""Pallas TPU kernel: vectorised per-edge join decision (Algorithm 1 line 10+).

The decision stage of the chunked (Jacobi) tier: given the gathered
post-arrival community volumes and degrees for a block of edges, emit the
action code and the volume delta, 8×128-lane vectorised on the VPU.  The
gather/scatter halves stay in XLA (they are data-movement, not compute); this
kernel is the arithmetic hot loop.

action: 0 = no-op, 1 = i joins C(j), 2 = j joins C(i).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def edge_decide_kernel(
    vci_ref, vcj_ref, di_ref, dj_ref, live_ref, action_ref, amount_ref,
    *, v_max: int,
):
    vci = vci_ref[...]
    vcj = vcj_ref[...]
    live = live_ref[...] != 0
    ok = live & (vci <= v_max) & (vcj <= v_max)
    i_joins = ok & (vci <= vcj)
    j_joins = ok & (vci > vcj)
    action = jnp.where(i_joins, 1, jnp.where(j_joins, 2, 0)).astype(jnp.int32)
    amount = jnp.where(
        i_joins, di_ref[...], jnp.where(j_joins, dj_ref[...], 0)
    ).astype(jnp.int32)
    action_ref[...] = action
    amount_ref[...] = amount


def build_call(rows: int, block_rows: int, v_max: int, interpret: bool):
    kernel = functools.partial(edge_decide_kernel, v_max=v_max)
    spec = pl.BlockSpec((block_rows, 128), lambda r: (r, 0))
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[spec] * 5,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 128), jnp.int32),
            jax.ShapeDtypeStruct((rows, 128), jnp.int32),
        ],
        interpret=interpret,
    )
