"""Jitted wrapper for the edge_decide kernel: 1-D edge vectors are retiled to
(rows, 128) lanes, padded as no-ops, and cropped back."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.edge_decide.kernel import build_call

_LANES = 128


def _retile(x, rows):
    flat = jnp.zeros(rows * _LANES, x.dtype)
    flat = jax.lax.dynamic_update_slice(flat, x, (0,))
    return flat.reshape(rows, _LANES)


@functools.partial(
    jax.jit, static_argnames=("v_max", "block_rows", "interpret")
)
def edge_decide(
    vci: jax.Array,
    vcj: jax.Array,
    di: jax.Array,
    dj: jax.Array,
    live: jax.Array,
    v_max: int,
    block_rows: int = 8,
    interpret: bool = True,
):
    """Decision stage over a batch of edges.  All inputs (B,) int32.

    Returns (action, amount), each (B,) int32.
    """
    b = vci.shape[0]
    rows = -(-b // (_LANES * block_rows)) * block_rows
    args = [
        _retile(x.astype(jnp.int32), rows)
        for x in (vci, vcj, di, dj, live.astype(jnp.int32))
    ]
    call = build_call(rows, block_rows, v_max, interpret)
    action, amount = call(*args)
    return action.reshape(-1)[:b], amount.reshape(-1)[:b]
