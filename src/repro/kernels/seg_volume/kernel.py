"""Pallas TPU kernel: community-volume histogram as one-hot MXU matmul.

``bincount`` is a scatter on GPUs/CPUs; the TPU-native formulation is
``ones(1, B) @ one_hot(labels, K)`` — a (1, B) x (B, K) matmul that runs on
the MXU at full tile utilisation.  Used by the Jacobi tier and by metric
computation to histogram weighted community volumes.

Grid: (K_blocks, B_blocks); the output block (1, bk) for a given k-block is
revisited across all B-blocks (TPU grids iterate the minor axis sequentially)
and accumulated in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def seg_volume_kernel(labels_ref, weights_ref, out_ref, *, block_k: int):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    k0 = pl.program_id(0) * block_k
    labels = labels_ref[...]  # (1, bb) int32
    weights = weights_ref[...]  # (1, bb) float32
    cols = jax.lax.broadcasted_iota(jnp.int32, (labels.shape[1], block_k), 1)
    onehot = (labels.reshape(-1, 1) == cols + k0).astype(jnp.float32)
    # (1, bb) @ (bb, bk) on the MXU.
    out_ref[...] += jnp.dot(
        weights, onehot, preferred_element_type=jnp.float32
    )


def build_call(
    b: int, k: int, block_b: int, block_k: int, interpret: bool
):
    kernel = functools.partial(seg_volume_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=(k // block_k, b // block_b),
        in_specs=[
            pl.BlockSpec((1, block_b), lambda kk, bb: (0, bb)),
            pl.BlockSpec((1, block_b), lambda kk, bb: (0, bb)),
        ],
        out_specs=pl.BlockSpec((1, block_k), lambda kk, bb: (0, kk)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.float32),
        interpret=interpret,
    )
