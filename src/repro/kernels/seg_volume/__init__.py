from repro.kernels.seg_volume.ops import seg_volume  # noqa: F401
