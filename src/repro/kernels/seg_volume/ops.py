"""Jitted wrapper for the seg_volume kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.seg_volume.kernel import build_call


@functools.partial(
    jax.jit, static_argnames=("k", "block_b", "block_k", "interpret")
)
def seg_volume(
    labels: jax.Array,
    weights: jax.Array,
    k: int,
    block_b: int = 512,
    block_k: int = 256,
    interpret: bool = True,
):
    """Weighted histogram of ``labels`` (B,) into ``k`` bins via MXU matmul.

    Out-of-range labels (e.g. PAD sinks) must be pre-masked to weight 0 and
    label 0 by the caller.
    """
    b = labels.shape[0]
    bp = -(-b // block_b) * block_b
    kp = -(-k // block_k) * block_k
    lab = jnp.zeros((1, bp), jnp.int32).at[0, :b].set(labels.astype(jnp.int32))
    wts = jnp.zeros((1, bp), jnp.float32).at[0, :b].set(
        weights.astype(jnp.float32)
    )
    call = build_call(bp, kp, block_b, block_k, interpret)
    out = call(lab, wts)
    return out[0, :k]
