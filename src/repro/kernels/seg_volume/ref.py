"""Pure-jnp oracle for seg_volume: weighted bincount."""

from __future__ import annotations

import jax.numpy as jnp


def seg_volume_ref(labels, weights, k: int):
    return jnp.zeros(k, jnp.float32).at[labels].add(weights.astype(jnp.float32))
