"""Pure-jnp oracle for the edge_stream kernel.

``cluster_stream_scan`` (one edge per ``lax.scan`` step) is itself verified
bit-exact against the paper's dictionary Algorithm 1 in
``tests/test_streaming_core.py``; the kernel must match it bit-for-bit.
"""

from __future__ import annotations

import jax

from repro.core.streaming import cluster_stream_scan


def edge_stream_ref(edges: jax.Array, v_max: int, n: int):
    """Returns (c, d, v) — same contract as the kernel wrapper."""
    c, d, v = cluster_stream_scan(edges, v_max, n)
    return c, d, v
