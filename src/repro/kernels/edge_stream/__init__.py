from repro.kernels.edge_stream.ops import edge_stream_cluster  # noqa: F401
