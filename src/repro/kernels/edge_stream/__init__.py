from repro.kernels.edge_stream.ops import (  # noqa: F401
    edge_stream_cluster,
    pallas_fleet_update,
)
