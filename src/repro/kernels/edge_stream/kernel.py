"""Pallas TPU kernel: Algorithm 1 with the full (d, c, v) state in VMEM.

TPU adaptation of the paper's CPU pointer-chasing loop (DESIGN.md §3):
the state is exactly ``3n`` int32 — for n ≤ ~1.3M nodes that is ≤ 16 MB and
fits VMEM, so every per-edge load/store hits VMEM (~ns latency) instead of
HBM.  The edge stream is the *grid*: chunk ``t`` is DMA'd HBM→VMEM by the
Pallas pipeline while chunk ``t-1`` is being processed; the (d, c, v) output
blocks have a constant index map, so they stay resident in VMEM across all
grid steps and are written back to HBM once at the end.

Semantics are bit-exact with ``core.streaming.cluster_stream_dense`` — the
sequential `fori_loop` inside the kernel preserves the paper's strict stream
order (unlike the Jacobi tier).

Layout note for real hardware: the 1-D state arrays would be lane-padded to
(⌈n/128⌉, 128) tiles; scalar load/store then addresses (idx // 128, idx % 128).
We keep the logical 1-D layout here (validated in interpret mode) and treat
the retile as a mechanical lowering detail.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graph.pipeline import PAD


def edge_stream_kernel(
    edges_ref, d0_ref, c0_ref, v0_ref, d_ref, c_ref, v_ref, *, v_max: int, n: int
):
    """Process one edge chunk; (d, c, v) persist in VMEM across grid steps.

    ``(d0, c0, v0)`` seed the state at grid step 0 — a fresh run passes
    zeros/iota, a resumed run (``repro.cluster.StreamClusterer``) passes the
    carried :class:`ClusterState` arrays.
    """
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        d_ref[...] = d0_ref[...]
        c_ref[...] = c0_ref[...]
        v_ref[...] = v0_ref[...]

    chunk = edges_ref.shape[0]

    def body(e, carry):
        i_raw = edges_ref[e, 0]
        j_raw = edges_ref[e, 1]
        live = (i_raw != PAD) & (j_raw != PAD) & (i_raw != j_raw)
        i = jnp.maximum(i_raw, 0)
        j = jnp.maximum(j_raw, 0)

        @pl.when(live)
        def _update():
            di = d_ref[i] + 1
            d_ref[i] = di
            dj = d_ref[j] + 1
            d_ref[j] = dj

            ci = c_ref[i]
            cj = c_ref[j]
            # Sequential +1 per endpoint community; reload so ci == cj sees +2.
            v_ref[ci] = v_ref[ci] + 1
            v_ref[cj] = v_ref[cj] + 1
            vci = v_ref[ci]
            vcj = v_ref[cj]

            ok = (vci <= v_max) & (vcj <= v_max)
            i_joins = ok & (vci <= vcj)
            j_joins = ok & (vci > vcj)

            @pl.when(i_joins)
            def _move_i():  # i joins the community of j
                v_ref[cj] = v_ref[cj] + di
                v_ref[ci] = v_ref[ci] - di
                c_ref[i] = cj

            @pl.when(j_joins)
            def _move_j():  # j joins the community of i
                v_ref[ci] = v_ref[ci] + dj
                v_ref[cj] = v_ref[cj] - dj
                c_ref[j] = ci

        return carry

    jax.lax.fori_loop(0, chunk, body, None)


def build_call(n: int, chunk: int, n_chunks: int, v_max: int, interpret: bool):
    kernel = functools.partial(edge_stream_kernel, v_max=v_max, n=n)
    state_spec = pl.BlockSpec((n,), lambda t: (0,))
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((chunk, 2), lambda t: (t, 0)),
            state_spec,
            state_spec,
            state_spec,
        ],
        out_specs=[state_spec, state_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),  # d
            jax.ShapeDtypeStruct((n,), jnp.int32),  # c
            jax.ShapeDtypeStruct((n,), jnp.int32),  # v
        ],
        interpret=interpret,
    )
