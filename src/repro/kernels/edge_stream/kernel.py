"""Pallas TPU kernel: Algorithm 1 with the full (d, c, v) state in VMEM.

TPU adaptation of the paper's CPU pointer-chasing loop (DESIGN.md §3):
the state is exactly ``3n`` int32 — for n ≤ ~1.3M nodes that is ≤ 16 MB and
fits VMEM, so every per-edge load/store hits VMEM (~ns latency) instead of
HBM.  Two entry points share the same per-edge update:

* **Grid-pipelined** (:func:`build_call`): the edge stream is the *grid* —
  chunk ``t`` is DMA'd HBM→VMEM by the Pallas pipeline while chunk ``t-1``
  is being processed; the (d, c, v) output blocks have a constant index
  map, so they stay resident in VMEM across all grid steps and are written
  back to HBM once at the end.  One ``pallas_call`` per ingest batch.
* **Megabatch, explicit double-buffered DMA** (:func:`build_megabatch_call`):
  the whole ``(n_chunks, chunk, 2)`` megabatch stays in HBM
  (``memory_space=ANY``) and the kernel drives its own edge DMA — two VMEM
  chunk slots with manual ``make_async_copy``s, chunk ``t+1`` streaming in
  while chunk ``t``'s sequential ``fori_loop`` runs, the state resident in
  VMEM across the *entire* megabatch.  One ``pallas_call`` per ``K`` staged
  pipeline batches (DESIGN.md §10 device pipelining).

Semantics are bit-exact with ``core.streaming.cluster_stream_dense`` — the
sequential `fori_loop` inside the kernel preserves the paper's strict stream
order (unlike the Jacobi tier), whichever entry point dispatches it.

Layout note for real hardware: the 1-D state arrays would be lane-padded to
(⌈n/128⌉, 128) tiles; scalar load/store then addresses (idx // 128, idx % 128).
We keep the logical 1-D layout here (validated in interpret mode) and treat
the retile as a mechanical lowering detail.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.wavefront import wave_apply, wave_conflict, wave_live
from repro.graph.pipeline import (
    D_BASE,
    D_KIND,
    D_NROWS,
    D_OFF_I,
    D_OFF_J,
    D_ROW,
    D_W_I,
    D_W_J,
    DESC_COLS,
    DESC_EMPTY,
    DESC_RAW,
    PAD,
)


def _apply_edge(i_raw, j_raw, d_ref, c_ref, v_ref, *, v_max: int):
    """One Algorithm-1 step against the VMEM-resident (d, c, v) refs —
    shared by the grid-pipelined and manual-DMA kernels."""
    live = (i_raw != PAD) & (j_raw != PAD) & (i_raw != j_raw)
    i = jnp.maximum(i_raw, 0)
    j = jnp.maximum(j_raw, 0)

    @pl.when(live)
    def _update():
        di = d_ref[i] + 1
        d_ref[i] = di
        dj = d_ref[j] + 1
        d_ref[j] = dj

        ci = c_ref[i]
        cj = c_ref[j]
        # Sequential +1 per endpoint community; reload so ci == cj sees +2.
        v_ref[ci] = v_ref[ci] + 1
        v_ref[cj] = v_ref[cj] + 1
        vci = v_ref[ci]
        vcj = v_ref[cj]

        ok = (vci <= v_max) & (vcj <= v_max)
        i_joins = ok & (vci <= vcj)
        j_joins = ok & (vci > vcj)

        @pl.when(i_joins)
        def _move_i():  # i joins the community of j
            v_ref[cj] = v_ref[cj] + di
            v_ref[ci] = v_ref[ci] - di
            c_ref[i] = cj

        @pl.when(j_joins)
        def _move_j():  # j joins the community of i
            v_ref[ci] = v_ref[ci] + dj
            v_ref[cj] = v_ref[cj] - dj
            c_ref[j] = ci


def edge_stream_kernel(
    edges_ref, d0_ref, c0_ref, v0_ref, d_ref, c_ref, v_ref, *, v_max: int, n: int
):
    """Process one edge chunk; (d, c, v) persist in VMEM across grid steps.

    ``(d0, c0, v0)`` seed the state at grid step 0 — a fresh run passes
    zeros/iota, a resumed run (``repro.cluster.StreamClusterer``) passes the
    carried :class:`ClusterState` arrays.
    """
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        d_ref[...] = d0_ref[...]
        c_ref[...] = c0_ref[...]
        v_ref[...] = v0_ref[...]

    chunk = edges_ref.shape[0]

    def body(e, carry):
        _apply_edge(
            edges_ref[e, 0], edges_ref[e, 1], d_ref, c_ref, v_ref, v_max=v_max
        )
        return carry

    jax.lax.fori_loop(0, chunk, body, None)


def build_call(n: int, chunk: int, n_chunks: int, v_max: int, interpret: bool):
    kernel = functools.partial(edge_stream_kernel, v_max=v_max, n=n)
    state_spec = pl.BlockSpec((n,), lambda t: (0,))
    return pl.pallas_call(
        kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((chunk, 2), lambda t: (t, 0)),
            state_spec,
            state_spec,
            state_spec,
        ],
        out_specs=[state_spec, state_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),  # d
            jax.ShapeDtypeStruct((n,), jnp.int32),  # c
            jax.ShapeDtypeStruct((n,), jnp.int32),  # v
        ],
        interpret=interpret,
    )


N_EDGE_SLOTS = 2  # double buffering: one slot streams in, one is consumed


def edge_stream_megabatch_kernel(
    edges_hbm_ref,
    d0_ref,
    c0_ref,
    v0_ref,
    d_ref,
    c_ref,
    v_ref,
    *,
    v_max: int,
    n: int,
    chunk: int,
    n_chunks: int,
):
    """Whole-megabatch kernel with explicit double-buffered edge DMA.

    ``edges_hbm_ref`` is the full ``(n_chunks, chunk, 2)`` megabatch, left
    in HBM (``memory_space=ANY``).  The kernel owns the edge movement: two
    ``(chunk, 2)`` VMEM slots, chunk ``t+1``'s async copy started *before*
    chunk ``t``'s sequential edge loop runs, so the DMA engine streams edges
    while the scalar loop updates the VMEM-resident (d, c, v).  One kernel
    launch ingests the entire megabatch — the state never round-trips to
    HBM between the K staged batches.
    """
    d_ref[...] = d0_ref[...]
    c_ref[...] = c0_ref[...]
    v_ref[...] = v0_ref[...]

    def scoped(slots_ref, sems_ref):
        def edge_dma(t):
            slot = jax.lax.rem(t, N_EDGE_SLOTS)
            return pltpu.make_async_copy(
                edges_hbm_ref.at[t], slots_ref.at[slot], sems_ref.at[slot]
            )

        # Warm-up: chunk 0 starts streaming before the loop.
        edge_dma(jnp.int32(0)).start()

        def chunk_body(t, carry):
            # Kick off chunk t+1 while chunk t is (still) in flight /
            # being consumed — the double buffer's other slot is free.
            @pl.when(t + 1 < n_chunks)
            def _prefetch_next():
                edge_dma(t + 1).start()

            edge_dma(t).wait()
            slot = jax.lax.rem(t, N_EDGE_SLOTS)

            def body(e, c):
                _apply_edge(
                    slots_ref[slot, e, 0],
                    slots_ref[slot, e, 1],
                    d_ref,
                    c_ref,
                    v_ref,
                    v_max=v_max,
                )
                return c

            jax.lax.fori_loop(0, chunk, body, None)
            return carry

        jax.lax.fori_loop(0, n_chunks, chunk_body, None)

    pl.run_scoped(
        scoped,
        pltpu.VMEM((N_EDGE_SLOTS, chunk, 2), jnp.int32),
        pltpu.SemaphoreType.DMA((N_EDGE_SLOTS,)),
    )


def edge_stream_wavefront_kernel(
    waves_hbm_ref,
    left_hbm_ref,
    meta_ref,
    d0_ref,
    c0_ref,
    v0_ref,
    d_ref,
    c_ref,
    v_ref,
    stats_ref,
    *,
    v_max: int,
    n: int,
    width: int,
    n_waves: int,
    chunk: int,
    n_left_chunks: int,
):
    """Wave-vectorised megabatch kernel (DESIGN.md §12).

    ``waves_hbm_ref`` holds the planner's ``(n_waves, width, 2)`` layout in
    HBM; waves are double-buffer DMA'd into VMEM like the sequential
    megabatch kernel's chunks.  Each wave is applied as gathered vector
    loads / scattered stores against the VMEM-resident (d, c, v) via the
    shared :func:`repro.core.wavefront.wave_apply` — after a runtime
    community-disjointness check (:func:`wave_conflict`) against the live
    state; colliding waves fall back to the sequential per-edge
    ``fori_loop``, so labels stay bit-identical to
    :func:`edge_stream_megabatch_kernel` for any plan.  The uncovered
    stream suffix (``meta_ref[1]`` rows, chunked in ``left_hbm_ref``) is
    drained sequentially at the end.  ``meta_ref[0]`` bounds the wave loop
    so trailing all-PAD waves cost nothing.  ``stats_ref`` returns
    ``[live_waves, fallback_waves]``.
    """
    d_ref[...] = d0_ref[...]
    c_ref[...] = c0_ref[...]
    v_ref[...] = v0_ref[...]
    stats_ref[...] = jnp.zeros((2,), jnp.int32)
    nw = jnp.minimum(meta_ref[0], n_waves)
    left_rows = meta_ref[1]

    def waves_scoped(slots_ref, sems_ref):
        def wave_dma(t):
            slot = jax.lax.rem(t, N_EDGE_SLOTS)
            return pltpu.make_async_copy(
                waves_hbm_ref.at[t], slots_ref.at[slot], sems_ref.at[slot]
            )

        @pl.when(nw > 0)
        def _warmup():
            wave_dma(jnp.int32(0)).start()

        def wave_body(t, carry):
            @pl.when(t + 1 < nw)
            def _prefetch_next():
                wave_dma(t + 1).start()

            wave_dma(t).wait()
            slot = jax.lax.rem(t, N_EDGE_SLOTS)
            wave = pl.load(
                slots_ref, (pl.dslice(slot, 1), slice(None), slice(None))
            )[0]
            i_raw = wave[:, 0]
            j_raw = wave[:, 1]
            c_now = c_ref[...]
            v_now = v_ref[...]
            has_live = jnp.any(wave_live(i_raw, j_raw))
            conflict = wave_conflict(c_now, v_now, i_raw, j_raw, v_max, n)

            @pl.when(jnp.logical_not(conflict))
            def _vector():
                d2, c2, v2 = wave_apply(
                    d_ref[...], c_now, v_now, i_raw, j_raw, v_max
                )
                d_ref[...] = d2
                c_ref[...] = c2
                v_ref[...] = v2

            @pl.when(conflict)
            def _sequential():
                def body(e, cy):
                    _apply_edge(
                        wave[e, 0], wave[e, 1], d_ref, c_ref, v_ref,
                        v_max=v_max,
                    )
                    return cy

                jax.lax.fori_loop(0, width, body, None)

            stats_ref[0] = stats_ref[0] + has_live.astype(jnp.int32)
            stats_ref[1] = stats_ref[1] + (conflict & has_live).astype(
                jnp.int32
            )
            return carry

        jax.lax.fori_loop(0, nw, wave_body, None)

    pl.run_scoped(
        waves_scoped,
        pltpu.VMEM((N_EDGE_SLOTS, width, 2), jnp.int32),
        pltpu.SemaphoreType.DMA((N_EDGE_SLOTS,)),
    )

    # leftover suffix: strictly sequential, single-buffered (rare path —
    # non-empty only when the planner's wave budget ran out)
    n_live_chunks = jnp.minimum(
        (left_rows + chunk - 1) // chunk, n_left_chunks
    )

    def left_scoped(slot_ref, sem_ref):
        def chunk_body(t, carry):
            cp = pltpu.make_async_copy(left_hbm_ref.at[t], slot_ref, sem_ref)
            cp.start()
            cp.wait()

            def body(e, cy):
                _apply_edge(
                    slot_ref[e, 0], slot_ref[e, 1], d_ref, c_ref, v_ref,
                    v_max=v_max,
                )
                return cy

            jax.lax.fori_loop(0, chunk, body, None)
            return carry

        jax.lax.fori_loop(0, n_live_chunks, chunk_body, None)

    pl.run_scoped(
        left_scoped,
        pltpu.VMEM((chunk, 2), jnp.int32),
        pltpu.SemaphoreType.DMA(()),
    )


def edge_stream_fleet_kernel(
    edges_ref, d0_ref, c0_ref, v0_ref, d_ref, c_ref, v_ref, *, v_max: int,
    batch: int,
):
    """Tenant-major fleet kernel: grid step ``t`` ingests tenant ``t``.

    The fleet's ``(T, B, 2)`` staged slab and ``(T, n)`` state arrays live
    in HBM; the Pallas pipeline DMAs tenant ``t``'s ``(1, B, 2)`` edge slab
    and ``(1, n)`` d/c/v slabs into VMEM per grid step (tenant ``t+1``'s
    tiles stream in while tenant ``t``'s sequential edge loop runs — same
    double buffering the grid-pipelined single-stream kernel gets for
    chunks).  Per-tenant semantics are the strict-stream-order
    :func:`_apply_edge` loop, so row ``t`` is bit-exact with a standalone
    sequential run of tenant ``t`` — tenants never share state, so the
    grid order is irrelevant.  All-PAD slabs (idle tenants) are no-ops.
    """
    d_ref[...] = d0_ref[...]
    c_ref[...] = c0_ref[...]
    v_ref[...] = v0_ref[...]
    # Squeeze the leading tenant-block axis so the shared per-edge update
    # sees plain (n,) refs.
    dr, cr, vr = d_ref.at[0], c_ref.at[0], v_ref.at[0]

    def body(e, carry):
        _apply_edge(
            edges_ref[0, e, 0], edges_ref[0, e, 1], dr, cr, vr, v_max=v_max
        )
        return carry

    jax.lax.fori_loop(0, batch, body, None)


def build_fleet_call(
    n: int, tenants: int, batch: int, v_max: int, interpret: bool
):
    """One fused dispatch over a ``(T, B, 2)`` fleet slab: the tenant axis
    is the grid, per-tenant state tiles are DMA'd HBM→VMEM→HBM by the
    Pallas pipeline (``3n`` ints per tenant — only one tenant's slabs are
    VMEM-resident at a time, so fleet size is bounded by HBM, not VMEM)."""
    kernel = functools.partial(
        edge_stream_fleet_kernel, v_max=v_max, batch=batch
    )
    state_spec = pl.BlockSpec((1, n), lambda t: (t, 0))
    return pl.pallas_call(
        kernel,
        grid=(tenants,),
        in_specs=[
            pl.BlockSpec((1, batch, 2), lambda t: (t, 0, 0)),
            state_spec,
            state_spec,
            state_spec,
        ],
        out_specs=[state_spec, state_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((tenants, n), jnp.int32),  # d
            jax.ShapeDtypeStruct((tenants, n), jnp.int32),  # c
            jax.ShapeDtypeStruct((tenants, n), jnp.int32),  # v
        ],
        interpret=interpret,
    )


def _decode_span(window: int) -> int:
    """Bytes DMA'd per descriptor: the widest segment is a DESC_RAW window
    (8 bytes/row) or a u4+u4 fixed pair (4 + 4 bytes/row plus one alignment
    gap) — both bounded by ``8 * window + 8``.  The staging producer leaves
    this much tail slack in the payload slab, so a fixed-size span read at
    any live descriptor offset is always in bounds."""
    return 8 * window + 8


def _decode_window(desc_row, bytes_i32, *, window: int):
    """Decode one descriptor's span into ``(window, 2)`` int32 edge rows.

    ``desc_row`` is the (DESC_COLS,) descriptor; ``bytes_i32`` the span's
    bytes as int32 values, with the descriptor's first data byte
    (``off_i``) at position 0.  All candidate widths are unpacked with
    reshape-and-combine lane math and selected by the descriptor's width
    fields — no per-byte scalar loop.  Rows at/after ``n_rows`` (and the
    whole window for DESC_EMPTY) come out PAD, so a consumer can treat
    every window as exactly ``window`` stream rows.  Shared by the
    standalone decode kernel and the fused decode→update kernel, and
    pinned bit-for-bit against ``repro.core.decode.decode_megabatch``.
    """
    kind = desc_row[D_KIND]
    nrows = desc_row[D_NROWS]
    w_i = desc_row[D_W_I]
    w_j = desc_row[D_W_J]
    rel_j = desc_row[D_OFF_J] - desc_row[D_OFF_I]
    base = desc_row[D_BASE]

    def fixed_col(rel, w):
        v1 = jax.lax.dynamic_slice(bytes_i32, (rel,), (window,))
        p2 = jax.lax.dynamic_slice(bytes_i32, (rel,), (2 * window,)).reshape(
            window, 2
        )
        v2 = p2[:, 0] | (p2[:, 1] << 8)
        p4 = jax.lax.dynamic_slice(bytes_i32, (rel,), (4 * window,)).reshape(
            window, 4
        )
        v4 = p4[:, 0] | (p4[:, 1] << 8) | (p4[:, 2] << 16) | (p4[:, 3] << 24)
        return jnp.where(w == 1, v1, jnp.where(w == 2, v2, v4))

    def unzig(z):
        return (z >> 1) ^ -(z & 1)

    di = unzig(fixed_col(jnp.int32(0), w_i))
    fixed_i = base + jnp.cumsum(di, dtype=jnp.int32)
    fixed_j = fixed_i + unzig(fixed_col(rel_j, w_j))

    # DESC_RAW: little-endian int32 (i, j) pairs — 8 bytes per row
    p8 = bytes_i32[: 8 * window].reshape(window, 8)
    raw_i = p8[:, 0] | (p8[:, 1] << 8) | (p8[:, 2] << 16) | (p8[:, 3] << 24)
    raw_j = p8[:, 4] | (p8[:, 5] << 8) | (p8[:, 6] << 16) | (p8[:, 7] << 24)

    is_raw = kind == DESC_RAW
    vals_i = jnp.where(is_raw, raw_i, fixed_i)
    vals_j = jnp.where(is_raw, raw_j, fixed_j)
    rows = jnp.stack([vals_i, vals_j], axis=1)
    rowid = jax.lax.broadcasted_iota(jnp.int32, (window, 2), 0)
    live = (rowid < nrows) & (kind != DESC_EMPTY)
    return jnp.where(live, rows, PAD)


def decode_megabatch_kernel(
    desc_ref,
    payload_hbm_ref,
    out_hbm_ref,
    *,
    window: int,
    d_max: int,
    n_out_windows: int,
):
    """Standalone compressed-slab decode: payload bytes in, edge slab out.

    The payload stays in HBM (``memory_space=ANY``); descriptor spans are
    double-buffer DMA'd into two VMEM byte slots — descriptor ``t+1``'s
    bytes stream in while ``t``'s lanes are unpacked — and each decoded
    ``(window, 2)`` window is DMA'd back to the HBM output slab at its
    destination row.  Windows are written in ascending ``dest_row`` order
    and a window's PAD tail may be overwritten by the next segment's real
    rows, which is exactly how the host-staged slab composes; a PAD
    pre-pass covers rows no descriptor reaches (the ragged stream tail).
    """
    span = _decode_span(window)

    def scoped(slots_ref, sems_ref, row_ref, out_sem):
        # PAD pre-pass: the slab must read PAD wherever no live descriptor
        # lands (trailing all-PAD batches of a ragged tail megabatch)
        row_ref[...] = jnp.full((window, 2), PAD, jnp.int32)

        def pad_body(t, carry):
            cp = pltpu.make_async_copy(
                row_ref,
                out_hbm_ref.at[pl.ds(t * window, window), :],
                out_sem,
            )
            cp.start()
            cp.wait()
            return carry

        jax.lax.fori_loop(0, n_out_windows, pad_body, None)

        def bytes_dma(t):
            slot = jax.lax.rem(t, N_EDGE_SLOTS)
            off = desc_ref[t, D_OFF_I]
            return pltpu.make_async_copy(
                payload_hbm_ref.at[pl.ds(off, span)],
                slots_ref.at[slot],
                sems_ref.at[slot],
            )

        bytes_dma(jnp.int32(0)).start()

        def body(t, carry):
            @pl.when(t + 1 < d_max)
            def _prefetch_next():
                bytes_dma(t + 1).start()

            bytes_dma(t).wait()
            slot = jax.lax.rem(t, N_EDGE_SLOTS)
            desc_row = pl.load(
                desc_ref, (pl.dslice(t, 1), slice(None))
            )[0]
            rows = _decode_window(
                desc_row, slots_ref[slot].astype(jnp.int32), window=window
            )

            @pl.when(desc_row[D_KIND] != DESC_EMPTY)
            def _write():
                row_ref[...] = rows
                cp = pltpu.make_async_copy(
                    row_ref,
                    out_hbm_ref.at[pl.ds(desc_row[D_ROW], window), :],
                    out_sem,
                )
                cp.start()
                cp.wait()

            return carry

        jax.lax.fori_loop(0, d_max, body, None)

    pl.run_scoped(
        scoped,
        pltpu.VMEM((N_EDGE_SLOTS, _decode_span(window)), jnp.uint8),
        pltpu.SemaphoreType.DMA((N_EDGE_SLOTS,)),
        pltpu.VMEM((window, 2), jnp.int32),
        pltpu.SemaphoreType.DMA(()),
    )


def edge_stream_decode_update_kernel(
    desc_ref,
    payload_hbm_ref,
    d0_ref,
    c0_ref,
    v0_ref,
    d_ref,
    c_ref,
    v_ref,
    stats_ref,
    *,
    v_max: int,
    window: int,
    d_max: int,
):
    """Fused decode→update: compressed bytes in, clustered state out.

    One launch per compressed megabatch: descriptor spans double-buffer
    DMA from the HBM payload slab (descriptor ``t+1``'s bytes in flight
    while ``t`` is decoded and applied — PR 5's DMA structure with byte
    spans in place of decoded chunks), lanes unpack in VMEM via
    :func:`_decode_window`, and the decoded window immediately runs the
    strict-order sequential :func:`_apply_edge` loop against the
    VMEM-resident (d, c, v).  Descriptors tile the stream in order and PAD
    rows are no-ops, so labels are bit-exact with host decode + the plain
    megabatch kernel over the same rows.  The decoded edges never touch
    HBM.  ``stats_ref[0]`` returns the live-edge count (the host can't
    cheaply know it without decoding).
    """
    d_ref[...] = d0_ref[...]
    c_ref[...] = c0_ref[...]
    v_ref[...] = v0_ref[...]
    stats_ref[...] = jnp.zeros((1,), jnp.int32)
    span = _decode_span(window)

    def scoped(slots_ref, sems_ref):
        def bytes_dma(t):
            slot = jax.lax.rem(t, N_EDGE_SLOTS)
            off = desc_ref[t, D_OFF_I]
            return pltpu.make_async_copy(
                payload_hbm_ref.at[pl.ds(off, span)],
                slots_ref.at[slot],
                sems_ref.at[slot],
            )

        bytes_dma(jnp.int32(0)).start()

        def body(t, carry):
            @pl.when(t + 1 < d_max)
            def _prefetch_next():
                bytes_dma(t + 1).start()

            bytes_dma(t).wait()
            slot = jax.lax.rem(t, N_EDGE_SLOTS)
            desc_row = pl.load(
                desc_ref, (pl.dslice(t, 1), slice(None))
            )[0]
            rows = _decode_window(
                desc_row, slots_ref[slot].astype(jnp.int32), window=window
            )
            live = (
                (rows[:, 0] != PAD)
                & (rows[:, 1] != PAD)
                & (rows[:, 0] != rows[:, 1])
            )
            stats_ref[0] = stats_ref[0] + jnp.sum(live.astype(jnp.int32))

            @pl.when(desc_row[D_KIND] != DESC_EMPTY)
            def _apply():
                def edge_body(e, cy):
                    _apply_edge(
                        rows[e, 0], rows[e, 1], d_ref, c_ref, v_ref,
                        v_max=v_max,
                    )
                    return cy

                jax.lax.fori_loop(0, window, edge_body, None)

            return carry

        jax.lax.fori_loop(0, d_max, body, None)

    pl.run_scoped(
        scoped,
        pltpu.VMEM((N_EDGE_SLOTS, _decode_span(window)), jnp.uint8),
        pltpu.SemaphoreType.DMA((N_EDGE_SLOTS,)),
    )


def build_decode_call(
    window: int, d_max: int, n_out_windows: int, interpret: bool
):
    """One dispatch decoding a compressed slab to a
    ``(n_out_windows * window, 2)`` edge slab in HBM (callers trim to the
    megabatch's ``K * B`` rows)."""
    kernel = functools.partial(
        decode_megabatch_kernel,
        window=window,
        d_max=d_max,
        n_out_windows=n_out_windows,
    )
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec((d_max, DESC_COLS), lambda: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        out_shape=jax.ShapeDtypeStruct((n_out_windows * window, 2), jnp.int32),
        interpret=interpret,
    )


def build_decode_update_call(
    n: int, window: int, d_max: int, v_max: int, interpret: bool
):
    """One fused dispatch over a compressed megabatch: payload bytes stay in
    HBM and are span-DMA'd by the kernel; the 3n-int state is seeded into
    VMEM once and written back once, plus a ``(1,)`` live-edge count."""
    kernel = functools.partial(
        edge_stream_decode_update_kernel,
        v_max=v_max,
        window=window,
        d_max=d_max,
    )
    state_spec = pl.BlockSpec((n,), lambda: (0,))
    stats_spec = pl.BlockSpec((1,), lambda: (0,))
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec((d_max, DESC_COLS), lambda: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            state_spec,
            state_spec,
            state_spec,
        ],
        out_specs=[state_spec, state_spec, state_spec, stats_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),  # d
            jax.ShapeDtypeStruct((n,), jnp.int32),  # c
            jax.ShapeDtypeStruct((n,), jnp.int32),  # v
            jax.ShapeDtypeStruct((1,), jnp.int32),  # stats: live edges
        ],
        interpret=interpret,
    )


def build_wavefront_call(
    n: int,
    width: int,
    n_waves: int,
    chunk: int,
    n_left_chunks: int,
    v_max: int,
    interpret: bool,
):
    """One fused dispatch over a planned megabatch: waves and the leftover
    suffix stay in HBM and are DMA'd by the kernel; the 3n-int state is
    seeded into VMEM once and written back once, plus a ``(2,)`` stats
    output ``[live_waves, fallback_waves]``."""
    kernel = functools.partial(
        edge_stream_wavefront_kernel,
        v_max=v_max,
        n=n,
        width=width,
        n_waves=n_waves,
        chunk=chunk,
        n_left_chunks=n_left_chunks,
    )
    state_spec = pl.BlockSpec((n,), lambda: (0,))
    stats_spec = pl.BlockSpec((2,), lambda: (0,))
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            stats_spec,
            state_spec,
            state_spec,
            state_spec,
        ],
        out_specs=[state_spec, state_spec, state_spec, stats_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),  # d
            jax.ShapeDtypeStruct((n,), jnp.int32),  # c
            jax.ShapeDtypeStruct((n,), jnp.int32),  # v
            jax.ShapeDtypeStruct((2,), jnp.int32),  # stats
        ],
        interpret=interpret,
    )


def build_megabatch_call(
    n: int, chunk: int, n_chunks: int, v_max: int, interpret: bool
):
    """One fused dispatch over a ``(n_chunks, chunk, 2)`` megabatch: edges
    stay in HBM and are double-buffer DMA'd by the kernel itself; the 3n-int
    state is seeded into VMEM once and written back once."""
    kernel = functools.partial(
        edge_stream_megabatch_kernel,
        v_max=v_max,
        n=n,
        chunk=chunk,
        n_chunks=n_chunks,
    )
    state_spec = pl.BlockSpec((n,), lambda: (0,))
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            state_spec,
            state_spec,
            state_spec,
        ],
        out_specs=[state_spec, state_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),  # d
            jax.ShapeDtypeStruct((n,), jnp.int32),  # c
            jax.ShapeDtypeStruct((n,), jnp.int32),  # v
        ],
        interpret=interpret,
    )
