"""Jitted wrappers for the edge_stream Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.decode import decode_megabatch
from repro.core.state import ClusterState, FleetState, count_live_edges
from repro.graph.pipeline import PAD, pad_edges_to_chunks
from repro.kernels.edge_stream.kernel import (
    build_call,
    build_decode_call,
    build_decode_update_call,
    build_fleet_call,
    build_megabatch_call,
    build_wavefront_call,
)


@functools.partial(
    jax.jit,
    static_argnames=("v_max", "chunk", "interpret"),
    donate_argnums=(0,),
)
def pallas_update(
    state: ClusterState,
    edges: jax.Array,
    v_max: int,
    chunk: int = 2048,
    interpret: bool = True,
) -> ClusterState:
    """State-threading in-VMEM Pallas tier: ingest ``edges`` into ``state``.

    Bit-exact with ``core.streaming.dense_update`` (strict stream order) —
    the kernel seeds its VMEM-resident (d, c, v) from ``state`` at grid step
    0, so arbitrary batch boundaries produce identical results.  ``state``
    is donated (treat the passed-in state as consumed — the ``partial_fit``
    contract).
    """
    n = state.d.shape[0]
    padded, n_chunks = pad_edges_to_chunks(edges, chunk)
    call = build_call(n, chunk, n_chunks, int(v_max), interpret)
    d, c, v = call(
        padded,
        state.d.astype(jnp.int32),
        state.c.astype(jnp.int32),
        state.v.astype(jnp.int32),
    )
    return ClusterState(
        d=d, c=c, v=v, edges_seen=state.edges_seen + count_live_edges(edges, PAD)
    )


@functools.partial(
    jax.jit,
    static_argnames=("v_max", "chunk", "interpret"),
    donate_argnums=(0,),
)
def pallas_update_megabatch(
    state: ClusterState,
    edges: jax.Array,
    v_max: int,
    chunk: int = 2048,
    interpret: bool = True,
) -> ClusterState:
    """Fused megabatch Pallas tier: ingest ``(K, B, 2)`` stacked batches in
    one kernel launch with explicit double-buffered edge DMA.

    The megabatch is flattened to ``K * B / chunk`` DMA chunks; the kernel
    keeps the 3n-int state in VMEM across all of them and streams chunk
    ``t+1`` from HBM while chunk ``t``'s sequential edge loop runs
    (``kernel.edge_stream_megabatch_kernel``).  Strict stream order is
    preserved, so labels are bit-exact with per-batch :func:`pallas_update`
    — and with ``dense_update`` — for *any* ``K``/``B``; trailing all-PAD
    batches (a ragged tail megabatch) are no-ops.  ``state`` is donated.
    """
    n = state.d.shape[0]
    K, B = edges.shape[0], edges.shape[1]
    padded, n_chunks = pad_edges_to_chunks(edges.reshape(K * B, 2), chunk)
    call = build_megabatch_call(n, chunk, n_chunks, int(v_max), interpret)
    d, c, v = call(
        padded.reshape(n_chunks, chunk, 2),
        state.d.astype(jnp.int32),
        state.c.astype(jnp.int32),
        state.v.astype(jnp.int32),
    )
    return ClusterState(
        d=d, c=c, v=v, edges_seen=state.edges_seen + count_live_edges(edges.reshape(-1, 2), PAD)
    )


@functools.partial(
    jax.jit, static_argnames=("window", "out_rows", "interpret")
)
def pallas_decode_megabatch(
    payload: jax.Array,
    desc: jax.Array,
    window: int,
    out_rows: int,
    interpret: bool = True,
) -> jax.Array:
    """Standalone device decode of a compressed megabatch slab.

    Returns the ``(out_rows, 2)`` int32 edge slab — bit-identical to the
    host-decode staging path and to ``repro.core.decode.decode_megabatch``
    (the pure-JAX reference the kernel is pinned against).  In interpret
    mode the reference *is* the implementation: tracing the byte-unpack
    lanes through the Pallas emulator adds nothing on CPU, while on
    hardware the kernel double-buffers descriptor spans from HBM
    (``kernel.decode_megabatch_kernel``).
    """
    if interpret:
        return decode_megabatch(payload, desc, window, out_rows)
    d_max = desc.shape[0]
    n_out_windows = -(-(out_rows + window) // window)
    call = build_decode_call(window, d_max, n_out_windows, False)
    out = call(desc.astype(jnp.int32), payload)
    return out[:out_rows]


@functools.partial(
    jax.jit,
    static_argnames=("v_max", "window", "out_rows", "chunk", "interpret"),
    donate_argnums=(0,),
)
def pallas_decode_update_megabatch(
    state: ClusterState,
    payload: jax.Array,
    desc: jax.Array,
    v_max: int,
    window: int,
    out_rows: int,
    chunk: int = 2048,
    interpret: bool = True,
) -> ClusterState:
    """Fused decode→update over one compressed megabatch — one dispatch.

    On hardware this is ``kernel.edge_stream_decode_update_kernel``: the
    payload slab stays in HBM, descriptor ``t+1``'s byte span streams in
    while ``t``'s decoded window runs the strict-order per-edge loop, and
    the decoded edges never round-trip through HBM.  In interpret mode the
    same dispatch composes the pure-JAX reference decode with the plain
    double-buffered megabatch kernel under this jit — identical math,
    still one dispatch per megabatch.  Labels are bit-exact with host
    decode + :func:`pallas_update_megabatch` either way.  ``state`` is
    donated.
    """
    n = state.d.shape[0]
    if interpret:
        edges = decode_megabatch(payload, desc, window, out_rows)
        padded, n_chunks = pad_edges_to_chunks(edges, chunk)
        call = build_megabatch_call(n, chunk, n_chunks, int(v_max), True)
        d, c, v = call(
            padded.reshape(n_chunks, chunk, 2),
            state.d.astype(jnp.int32),
            state.c.astype(jnp.int32),
            state.v.astype(jnp.int32),
        )
        seen = count_live_edges(edges, PAD)
    else:
        d_max = desc.shape[0]
        call = build_decode_update_call(n, window, d_max, int(v_max), False)
        d, c, v, stats = call(
            desc.astype(jnp.int32),
            payload,
            state.d.astype(jnp.int32),
            state.c.astype(jnp.int32),
            state.v.astype(jnp.int32),
        )
        seen = stats[0]
    return ClusterState(d=d, c=c, v=v, edges_seen=state.edges_seen + seen)


@functools.partial(
    jax.jit,
    static_argnames=("v_max", "chunk", "interpret"),
    donate_argnums=(0,),
)
def pallas_wavefront_update(
    state: ClusterState,
    waves: jax.Array,
    leftover: jax.Array,
    meta: jax.Array,
    v_max: int,
    chunk: int = 2048,
    interpret: bool = True,
):
    """Wavefront Pallas tier: ingest a planned megabatch (see
    ``repro.graph.wavefront.plan_waves``) in one kernel launch.

    ``waves`` is the planner's ``(n_waves, width, 2)`` layout, ``leftover``
    the ``(M, 2)`` uncovered suffix, ``meta`` the ``[n_waves_used,
    leftover_rows]`` loop bounds.  Labels are bit-identical to
    :func:`pallas_update_megabatch` over the original stream for any valid
    plan — vectorised waves with a runtime community-collision fallback
    (DESIGN.md §12).  Returns ``(state, stats)`` with ``stats =
    [live_waves, fallback_waves]``.  ``state`` is donated.
    """
    n = state.d.shape[0]
    n_waves, width = waves.shape[0], waves.shape[1]
    padded, n_left_chunks = pad_edges_to_chunks(leftover, chunk)
    call = build_wavefront_call(
        n, width, n_waves, chunk, n_left_chunks, int(v_max), interpret
    )
    d, c, v, stats = call(
        waves.astype(jnp.int32),
        padded.reshape(n_left_chunks, chunk, 2),
        meta.astype(jnp.int32),
        state.d.astype(jnp.int32),
        state.c.astype(jnp.int32),
        state.v.astype(jnp.int32),
    )
    # waves + leftover hold exactly the live rows of the original megabatch
    seen = count_live_edges(waves.reshape(-1, 2), PAD) + count_live_edges(
        leftover, PAD
    )
    return (
        ClusterState(d=d, c=c, v=v, edges_seen=state.edges_seen + seen),
        stats,
    )


@functools.partial(
    jax.jit,
    static_argnames=("v_max", "interpret"),
    donate_argnums=(0,),
)
def pallas_fleet_update(
    state: FleetState,
    edges: jax.Array,
    v_max: int,
    interpret: bool = True,
) -> FleetState:
    """Tenant-major fleet Pallas tier: ingest a ``(T, B, 2)`` staged slab
    into a ``(T, n)`` :class:`FleetState` in one kernel launch.

    The tenant axis is the Pallas grid — per-tenant d/c/v tiles are
    pipelined HBM→VMEM→HBM while each tenant's slab runs the sequential
    per-edge loop (``kernel.edge_stream_fleet_kernel``), so every tenant
    row is bit-exact with ``core.streaming.dense_update`` over its own
    stream regardless of how the router grouped slabs into fleet steps.
    ``state`` is donated (the ``partial_fit_fleet`` contract).
    """
    tenants, n = state.d.shape[0], state.d.shape[1]
    B = edges.shape[1]
    e = edges.astype(jnp.int32)
    call = build_fleet_call(n, tenants, B, int(v_max), interpret)
    d, c, v = call(
        e,
        state.d.astype(jnp.int32),
        state.c.astype(jnp.int32),
        state.v.astype(jnp.int32),
    )
    live = (e[:, :, 0] != PAD) & (e[:, :, 1] != PAD) & (
        e[:, :, 0] != e[:, :, 1]
    )
    return FleetState(
        d=d,
        c=c,
        v=v,
        edges_seen=state.edges_seen + jnp.sum(live, axis=1, dtype=jnp.int32),
    )


@functools.partial(
    jax.jit, static_argnames=("v_max", "n", "chunk", "interpret")
)
def edge_stream_cluster(
    edges: jax.Array,
    v_max: int,
    n: int,
    chunk: int = 2048,
    interpret: bool = True,
):
    """One-shot clustering with the in-VMEM Pallas kernel.

    .. deprecated:: use ``repro.cluster.cluster(..., backend="pallas")``.

    Args:
      edges: (m, 2) int32 stream (PAD rows are no-ops).
      v_max: paper's volume threshold.
      n: number of nodes (state = 3n int32 must fit VMEM; n ≤ ~1.3M).
      chunk: edges per grid step (HBM→VMEM DMA granularity).
      interpret: True on CPU (validation); False on real TPUs.

    Returns:
      (c, d, v) int32 arrays of size n — bit-exact with Algorithm 1.
    """
    s = pallas_update(
        ClusterState.init(n), edges, int(v_max), chunk=chunk,
        interpret=interpret,
    )
    return s.c, s.d, s.v
