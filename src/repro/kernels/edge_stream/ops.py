"""Jitted wrapper for the edge_stream Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.streaming import PAD
from repro.kernels.edge_stream.kernel import build_call


@functools.partial(
    jax.jit, static_argnames=("v_max", "n", "chunk", "interpret")
)
def edge_stream_cluster(
    edges: jax.Array,
    v_max: int,
    n: int,
    chunk: int = 2048,
    interpret: bool = True,
):
    """Cluster an edge stream with the in-VMEM Pallas kernel.

    Args:
      edges: (m, 2) int32 stream (PAD rows are no-ops).
      v_max: paper's volume threshold.
      n: number of nodes (state = 3n int32 must fit VMEM; n ≤ ~1.3M).
      chunk: edges per grid step (HBM→VMEM DMA granularity).
      interpret: True on CPU (validation); False on real TPUs.

    Returns:
      (c, d, v) int32 arrays of size n — bit-exact with Algorithm 1.
    """
    m = edges.shape[0]
    n_chunks = max(1, -(-m // chunk))
    padded = jnp.full((n_chunks * chunk, 2), PAD, dtype=jnp.int32)
    padded = jax.lax.dynamic_update_slice(padded, edges.astype(jnp.int32), (0, 0))
    call = build_call(n, chunk, n_chunks, v_max, interpret)
    d, c, v = call(padded)
    return c, d, v
