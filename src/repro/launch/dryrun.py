import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell with ShapeDtypeStruct inputs (no allocation), dump memory/cost/HLO
analysis to results/dryrun/<arch>__<shape>__<mesh>.json.

The XLA_FLAGS line above MUST precede every other import (jax locks the
device count on first init) — do not move it.

    # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
        --shape train_4k --mesh single
    # full sweep (each cell in a fresh subprocess, resumable)
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ALL_SHAPES, ShapeConfig
from repro.configs.registry import REGISTRY, get_config
from repro.dist.sharding import (
    _fit_spec,
    batch_sharding,
    cache_shardings,
    param_shardings,
    replicated,
    sharding_context,
)
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import (
    count_params_analytic,
    init_params,
    make_cache,
)
from repro.optim.adamw import AdamW
from repro.train.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import init_train_state, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")

# Hardware constants (TPU v5e target).
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link
HBM_BYTES = 16e9  # per chip

# Per-arch memory/throughput knobs used by the BASELINE dry-run (chosen so
# state fits the per-chip HBM budget; see DESIGN.md §4 and EXPERIMENTS.md).
KNOBS = {
    "llama3-405b": dict(
        microbatch=16, remat_group=9, m_dtype="int8", v_dtype="int8",
        accum_dtype="bfloat16", kv_dtype="float8_e4m3fn",
    ),
    "llama-3.2-vision-90b": dict(
        microbatch=8, remat_group=5, m_dtype="int8", v_dtype="int8",
        accum_dtype="bfloat16", kv_dtype="float8_e4m3fn",
    ),
    "deepseek-v2-236b": dict(
        microbatch=8, m_dtype="int8", v_dtype="int8", accum_dtype="bfloat16",
    ),
    "phi3.5-moe-42b-a6.6b": dict(microbatch=4, m_dtype="int8", v_dtype="int8"),
}


def knobs_for(arch: str) -> dict:
    base = dict(
        microbatch=None, remat_group=0, m_dtype="float32", v_dtype="float32",
        accum_dtype="float32", kv_dtype=None,
    )
    base.update(KNOBS.get(arch, {}))
    return base


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    out = {}
    if shape.kind == "train":
        out = {"tokens": tok, "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    elif shape.kind == "prefill":
        out = {"tokens": tok}
    elif shape.kind == "decode":
        out = {
            "token": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    if shape.kind in ("train", "prefill"):
        if cfg.encoder_layers:
            out["enc"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frames, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        elif cfg.n_image_tokens:
            out["enc"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
            )
    return out


def _quant_aware_shardings(pshard, state_shape, mesh):
    """Shardings for optimizer moments (handles int8-quantised dicts)."""

    def is_q(x):
        return isinstance(x, dict) and "q" in x and "scale" in x

    def leaf(ms, ps):
        if is_q(ms):
            return {
                "q": NamedSharding(mesh, _fit_spec(mesh, ps.spec, ms["q"].shape)),
                "scale": NamedSharding(
                    mesh, _fit_spec(mesh, ps.spec, ms["scale"].shape)
                ),
            }
        return NamedSharding(mesh, _fit_spec(mesh, ps.spec, ms.shape))

    return jax.tree.map(leaf, state_shape, pshard, is_leaf=is_q)


# ---------------------------------------------------------------------------
# Cell builders: (jitted fn, abstract args, in_shardings)
# ---------------------------------------------------------------------------

def build_cell(cfg, shape: ShapeConfig, mesh, knobs):
    if knobs["kv_dtype"]:
        cfg = cfg.replace(kv_dtype=knobs["kv_dtype"])
    specs = input_specs(cfg, shape)
    pshape = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    pshard = param_shardings(pshape, mesh)

    if shape.kind == "train":
        opt = AdamW(m_dtype=knobs["m_dtype"], v_dtype=knobs["v_dtype"])
        lr_fn = lambda step: jnp.float32(1e-4)
        step_fn = make_train_step(
            cfg, opt, lr_fn,
            microbatch=knobs["microbatch"],
            accum_dtype=knobs["accum_dtype"],
            remat_group=knobs["remat_group"],
            ce_chunk=512,
        )
        state_shape = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), cfg, opt)
        )
        state_shard = {
            "params": pshard,
            "opt": {
                "m": _quant_aware_shardings(pshard, state_shape["opt"]["m"], mesh),
                "v": _quant_aware_shardings(pshard, state_shape["opt"]["v"], mesh),
                "count": replicated(mesh),
            },
            "step": replicated(mesh),
        }
        batch_shard = {
            k: batch_sharding(mesh, v.shape) for k, v in specs.items()
        }
        fn = jax.jit(step_fn, in_shardings=(state_shard, batch_shard),
                     donate_argnums=0)
        return fn, (state_shape, specs), (state_shard, batch_shard)

    if shape.kind == "prefill":
        pf = make_prefill_step(cfg, cache_size=shape.seq_len)
        args = [pshape, specs["tokens"]]
        shards = [pshard, batch_sharding(mesh, specs["tokens"].shape)]
        if "enc" in specs:
            args.append(specs["enc"])
            shards.append(batch_sharding(mesh, specs["enc"].shape))
            fn = jax.jit(pf, in_shardings=tuple(shards))
        else:
            fn = jax.jit(lambda p, t: pf(p, t), in_shardings=tuple(shards))
        return fn, tuple(args), tuple(shards)

    # decode
    dec = make_decode_step(cfg)
    cache_shape = jax.eval_shape(
        lambda: make_cache(cfg, shape.global_batch, shape.seq_len)
    )
    cshard = cache_shardings(cache_shape, mesh)
    shards = (
        pshard,
        cshard,
        batch_sharding(mesh, specs["token"].shape),
        replicated(mesh),
    )
    fn = jax.jit(dec, in_shardings=shards, donate_argnums=1)
    return fn, (pshape, cache_shape, specs["token"], specs["pos"]), shards


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def _spec_div(mesh, spec, shape) -> int:
    div = 1
    for dim, axes in zip(shape, spec):
        if axes is None:
            continue
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        div *= int(np.prod([mesh.shape[a] for a in names]))
    return div


def tree_bytes_per_device(shape_tree, shard_tree, mesh) -> int:
    """Exact per-device bytes of a sharded pytree (leaf sizes / shard factor)."""
    leaves = jax.tree.leaves(shape_tree)
    shards = jax.tree.leaves(
        shard_tree, is_leaf=lambda x: isinstance(x, NamedSharding)
    )
    total = 0
    for leaf, sh in zip(leaves, shards):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        b = n * leaf.dtype.itemsize
        total += b // _spec_div(mesh, sh.spec, leaf.shape)
    return total


def analytic_transient_bytes(cfg, shape: ShapeConfig, knobs, mesh) -> int:
    """Back-of-envelope transient HBM: remat residual stack + CE chunk +
    flash working set.  The measured temp_size on the CPU backend is inflated
    by bf16->f32 canonicalisation (CPU has no native bf16) and is reported
    separately; this is the TPU-expected figure."""
    n_dev = int(np.prod(list(mesh.shape.values())))
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.shape]))
    tp = mesh.shape.get("model", 1)
    B_loc = max(shape.global_batch // dp, 1)
    if shape.kind == "train":
        mb = knobs["microbatch"]
        k = (shape.global_batch // mb) if mb else 1
        B_eff = max(B_loc // k, 1)
        _, n_cycles, _ = cfg.layer_stack
        g = knobs["remat_group"]
        n_saved = (n_cycles // g + g) if (g and g > 1) else n_cycles
        hidden = B_eff * shape.seq_len * cfg.d_model * 2
        residuals = n_saved * hidden
        ce = B_eff * 512 * (cfg.vocab_size // tp) * 4
        accum = 0
        if mb:
            nbytes = {"float32": 4, "bfloat16": 2}[knobs["accum_dtype"]]
            from repro.models.transformer import count_params_analytic as cpa
            accum = cpa(cfg) * nbytes // n_dev
        return residuals + ce + accum + (1 << 30)
    if shape.kind == "prefill":
        hidden = B_loc * shape.seq_len * cfg.d_model * 2
        return 4 * hidden + (1 << 30)
    return 1 << 30  # decode: O(B·S·heads_loc) scores + slack


def model_flops(cfg, shape: ShapeConfig) -> float:
    n_active = count_params_analytic(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: per emitted token


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str) -> dict:
    cfg = get_config(arch)
    shape = next(s for s in ALL_SHAPES if s.name == shape_name)
    if shape.name == "long_500k" and not cfg.supports_long_context:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "skipped",
                  "reason": "full attention (see DESIGN.md §5)"}
        os.makedirs(out_dir, exist_ok=True)
        fname = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
        with open(fname, "w") as f:
            json.dump(result, f, indent=1)
        return result
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    knobs = knobs_for(arch)
    t0 = time.time()
    with mesh, sharding_context(mesh):
        fn, args, shards = build_cell(cfg, shape, mesh, knobs)
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = analyze(compiled.as_text())

    if knobs["kv_dtype"]:
        cfg = cfg.replace(kv_dtype=knobs["kv_dtype"])
    state_bytes = tree_bytes_per_device(args, shards, mesh)
    transient = analytic_transient_bytes(cfg, shape, knobs, mesh)
    bytes_per_dev = state_bytes + transient
    measured_bytes = (
        mem.argument_size_in_bytes
        + mem.temp_size_in_bytes
        + mem.output_size_in_bytes
        - mem.alias_size_in_bytes
    )
    mf = model_flops(cfg, shape)
    compute_s = hlo["flops"] / PEAK_FLOPS
    memory_s = hlo["traffic_bytes"] / HBM_BW
    coll_s = hlo["collective_bytes_total"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "status": "ok",
        "n_devices": n_dev,
        "knobs": knobs,
        "lower_s": round(t1 - t0, 1),
        "compile_s": round(t2 - t1, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "state_bytes_per_device": state_bytes,
            "analytic_transient_bytes": transient,
            "bytes_per_device": bytes_per_dev,
            "measured_bytes_per_device_cpu_backend": measured_bytes,
            "fits_16GB": bool(bytes_per_dev <= HBM_BYTES),
        },
        "xla_cost_analysis": {
            k: ca.get(k) for k in ("flops", "bytes accessed") if k in ca
        },
        "hlo": {
            "flops_per_device": hlo["flops"],
            "traffic_bytes_per_device": hlo["traffic_bytes"],
            "collective_bytes_per_device": hlo["collective_bytes_total"],
            "collective_breakdown": hlo["collective_bytes"],
            "collective_counts": hlo["collective_counts"],
        },
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops_total": mf,
            "model_flops_per_device": mf / n_dev,
            "useful_flops_ratio": (mf / n_dev) / max(hlo["flops"], 1.0),
            "roofline_s": max(terms.values()),
            "roofline_fraction": (mf / n_dev / PEAK_FLOPS)
            / max(max(terms.values()), 1e-30),
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
    with open(fname, "w") as f:
        json.dump(result, f, indent=1)
    return result


def run_all(out_dir: str, meshes=("single", "multi"), archs=None, timeout=3600):
    """Sweep every cell in fresh subprocesses (resumable)."""
    archs = archs or list(REGISTRY)
    cells = []
    for arch in archs:
        for shape in ALL_SHAPES:
            for mesh_kind in meshes:
                cells.append((arch, shape.name, mesh_kind))
    results = []
    for arch, shape, mesh_kind in cells:
        fname = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")
        if os.path.exists(fname):
            print(f"[dryrun] cached   {arch} {shape} {mesh_kind}")
            continue
        print(f"[dryrun] running  {arch} {shape} {mesh_kind} ...", flush=True)
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", mesh_kind, "--out", out_dir],
            capture_output=True, text=True, timeout=timeout,
            env={**os.environ, "PYTHONPATH": "src"},
        )
        ok = proc.returncode == 0 and os.path.exists(fname)
        print(f"[dryrun] {'done  ' if ok else 'FAILED'}  {arch} {shape} "
              f"{mesh_kind} ({time.time()-t0:.0f}s)")
        if not ok:
            tail = (proc.stderr or "")[-2000:]
            print(tail)
            with open(fname + ".err", "w") as f:
                f.write(proc.stdout + "\n" + proc.stderr)
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", default="")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args(argv)
    if args.all:
        run_all(args.out, archs=args.archs.split(",") if args.archs else None)
        return
    res = run_cell(args.arch, args.shape, args.mesh, args.out)
    print(json.dumps(
        {k: res[k] for k in ("arch", "shape", "mesh", "status") if k in res}
    ))
    if res["status"] == "ok":
        print(f"  compile: {res['compile_s']}s  "
              f"bytes/dev: {res['memory']['bytes_per_device']/1e9:.2f} GB  "
              f"fits16GB: {res['memory']['fits_16GB']}")
        r = res["roofline"]
        print(f"  compute {r['compute_s']*1e3:.2f} ms | memory "
              f"{r['memory_s']*1e3:.2f} ms | collective "
              f"{r['collective_s']*1e3:.2f} ms -> dominant {r['dominant']}")
        print(f"  useful-flops ratio {r['useful_flops_ratio']:.3f}  "
              f"roofline fraction {r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
