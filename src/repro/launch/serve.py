"""Serving driver: batched prefill + greedy decode with KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_smoke_config
from repro.models.transformer import init_params
from repro.train.serve_step import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = args.batch, args.prompt_len
    cache_size = S + args.gen
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    enc = None
    if cfg.encoder_layers:
        enc = jax.random.normal(key, (B, cfg.n_frames, cfg.d_model)).astype(cfg.dtype)
    elif cfg.n_image_tokens:
        enc = jax.random.normal(key, (B, cfg.n_image_tokens, cfg.d_model)).astype(cfg.dtype)

    pf = jax.jit(make_prefill_step(cfg, cache_size))
    dec = jax.jit(make_decode_step(cfg), donate_argnums=1)

    t0 = time.perf_counter()
    tok, _, cache = pf(params, prompt, enc)
    tok.block_until_ready()
    t1 = time.perf_counter()
    toks = [tok]
    for i in range(args.gen - 1):
        tok, _, cache = dec(params, cache, tok, jnp.int32(S + i))
        toks.append(tok)
    tok.block_until_ready()
    t2 = time.perf_counter()

    out = jnp.stack(toks, axis=1)
    print(f"[serve] arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"[serve] prefill {t1 - t0:.3f}s; decode {(t2 - t1):.3f}s "
          f"({B * (args.gen - 1) / max(t2 - t1, 1e-9):.1f} tok/s)")
    print("[serve] sample tokens:", out[0, :8].tolist())
    return out


if __name__ == "__main__":
    main()
