"""Training driver: data pipeline -> train_step loop with checkpointing,
preemption drain, straggler monitoring, and elastic resume.

CPU-runnable end to end with ``--smoke`` (reduced configs); the production
mesh path is exercised by launch/dryrun.py.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config, get_smoke_config
from repro.data.pipeline import make_pipeline
from repro.dist.fault_tolerance import HeartbeatMonitor, PreemptionHandler
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_schedule
from repro.train.train_step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--m-dtype", default="float32")
    ap.add_argument("--v-dtype", default="float32")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt = AdamW(m_dtype=args.m_dtype, v_dtype=args.v_dtype)
    lr_fn = cosine_schedule(args.lr, warmup_steps=10, total_steps=args.steps)
    step_fn = jax.jit(
        make_train_step(
            cfg, opt, lr_fn,
            microbatch=args.microbatch or None,
            grad_compress=args.grad_compress,
            ce_chunk=min(1024, args.seq),
        ),
        donate_argnums=0,
    )

    state = init_train_state(
        jax.random.PRNGKey(0), cfg, opt, grad_compress=args.grad_compress
    )
    pipe = make_pipeline(cfg, args.batch, args.seq)
    manager = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    start = 0
    if args.resume and manager and manager.latest_step() is not None:
        full = {"state": state, "data": pipe.state_dict()}
        restored = manager.restore(full)
        state = restored["state"]
        pipe.load_state_dict(restored["data"])
        start = int(restored["state"]["step"])
        print(f"[train] resumed from step {start}")

    preempt = PreemptionHandler()
    preempt.install()
    monitor = HeartbeatMonitor()
    losses = []
    for step in range(start, args.steps):
        monitor.step_start()
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if monitor.step_end(step):
            print(f"[train] straggler at step {step}: {monitor.stragglers[-1]}")
        if step % args.log_every == 0:
            print(
                f"[train] step {step:5d} loss {loss:8.4f} "
                f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.3f}"
            )
        should_ckpt = manager and (
            (step + 1) % args.ckpt_every == 0 or preempt.preempted
        )
        if should_ckpt:
            manager.save(step + 1, {"state": state, "data": pipe.state_dict()})
            print(f"[train] checkpointed step {step + 1}")
        if preempt.preempted:
            print("[train] preemption drain complete; exiting")
            break
    print(
        json.dumps(
            {
                "first_loss": losses[0] if losses else None,
                "last_loss": losses[-1] if losses else None,
                "median_step_s": monitor.median,
            }
        )
    )
    return losses


if __name__ == "__main__":
    main()
