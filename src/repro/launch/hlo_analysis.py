"""Static analysis of post-SPMD compiled HLO text.

XLA's ``compiled.cost_analysis()`` visits each computation ONCE — ``while``
bodies (our layer scans, microbatch loops, flash-attention KV loops) are not
multiplied by their trip counts (verified empirically).  This module
re-derives roofline inputs from ``compiled.as_text()``:

* parses computations + the call graph (while bodies/conditions, fusions,
  calls, conditionals);
* reads while trip counts from ``backend_config known_trip_count`` (with a
  condition-literal fallback);
* walks the graph from ENTRY accumulating an execution multiplier per
  computation;
* tallies per-device dot FLOPs (2 × numel(out) × contracted size — operand
  shapes resolved through the computation's name→shape table), collective
  bytes (result bytes of all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute), and approximate HBM traffic (operand +
  result bytes of top-level ops — the "every op round-trips HBM" static
  roofline convention; fusions count once at their call site).

Post-SPMD HLO shapes are PER-DEVICE shapes, so all tallies are per device.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    args: str  # inside the op's parens
    attrs: str  # after the op's parens


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)
    is_entry: bool = False


def _split_rhs(rhs: str) -> Optional[Tuple[str, str, str, str]]:
    """'(shape) op(args), attrs' or 'shape op(args), attrs' ->
    (shape, op, args, attrs)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape, rest = rhs[: i + 1], rhs[i + 1 :].strip()
                    break
        else:
            return None
    else:
        parts = rhs.split(None, 1)
        if len(parts) != 2:
            return None
        shape, rest = parts
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    op = m.group(1)
    depth = 0
    start = m.end() - 1
    for i in range(start, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                return shape, op, rest[start + 1 : i], rest[i + 1 :]
    return shape, op, rest[start + 1 :], ""


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if stripped.endswith("{") and "->" in stripped and "=" not in stripped.split("(")[0]:
            name = stripped.split()[1] if stripped.startswith("ENTRY") else stripped.split()[0]
            name = name.lstrip("%")
            cur = Computation(name, is_entry=stripped.startswith("ENTRY"))
            comps[name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None or "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        lhs = lhs.replace("ROOT", "").strip().lstrip("%")
        if not re.fullmatch(r"[\w.\-]+", lhs):
            continue
        parsed = _split_rhs(rhs)
        if not parsed:
            continue
        shape, op, args, attrs = parsed
        cur.instrs.append(Instr(lhs, shape, op, args, attrs))
        cur.shapes[lhs] = shape
    return comps


def _trip_count(ins: Instr, comps: Dict[str, Computation]) -> int:
    m = re.search(r'known_trip_count[^}]*?"n":"(\d+)"', ins.attrs)
    if m:
        return max(int(m.group(1)), 1)
    # Fallback: literal in the condition computation's compare.
    cond = _named_attr(ins, "condition")
    if cond and cond in comps:
        consts = []
        for ci in comps[cond].instrs:
            mm = re.search(r"constant\((-?\d+)\)", ci.op + "(" + ci.args + ")")
            if mm:
                consts.append(int(mm.group(1)))
        if consts:
            return max(max(consts), 1)
    return 1


def _named_attr(ins: Instr, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", ins.attrs)
    return m.group(1) if m else None


_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SKIP_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "call", "conditional", "after-all", "iota", "partition-id",
    "replica-id", "copy-start", "copy-done",
}


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    out_dims = _shape_dims(ins.shape)
    numel_out = math.prod(out_dims) if out_dims else 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    operands = re.findall(r"%([\w.\-]+)", ins.args)
    contract = 1
    if m and operands and operands[0] in shapes:
        lhs_dims = _shape_dims(shapes[operands[0]])
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * numel_out * contract


def analyze(text: str) -> Dict[str, object]:
    comps = parse_hlo(text)
    entries = [c for c in comps.values() if c.is_entry]
    if not entries:
        raise ValueError("no ENTRY computation found")

    mult: Dict[str, float] = defaultdict(float)
    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                body = _named_attr(ins, "calls")
                if body:
                    fusion_bodies.add(body)

    def visit(comp: Computation, m: float, depth=0):
        if depth > 64:
            return
        mult[comp.name] += m
        for ins in comp.instrs:
            if ins.op == "while":
                trips = _trip_count(ins, comps)
                body = _named_attr(ins, "body")
                cond = _named_attr(ins, "condition")
                if body in comps:
                    visit(comps[body], m * trips, depth + 1)
                if cond in comps:
                    mult[cond] += m * (trips + 1)
            elif ins.op in ("call", "custom-call", "async-start"):
                to = _named_attr(ins, "to_apply")
                if to in comps:
                    visit(comps[to], m, depth + 1)
            elif ins.op == "conditional":
                for key in ("true_computation", "false_computation"):
                    t = _named_attr(ins, key)
                    if t in comps:
                        visit(comps[t], m, depth + 1)
                mm = re.search(r"branch_computations=\{([^}]*)\}", ins.attrs)
                if mm:
                    for name in mm.group(1).replace("%", "").split(","):
                        name = name.strip()
                        if name in comps:
                            visit(comps[name], m, depth + 1)

    for entry in entries:
        visit(entry, 1.0)

    flops = 0.0
    traffic = 0.0
    coll_bytes: Dict[str, float] = defaultdict(float)
    coll_counts: Dict[str, float] = defaultdict(float)

    _VIEWS = ("bitcast", "reshape", "copy", "convert", "transpose",
              "broadcast", "slice")

    def _sliced_params(body: Optional[Computation]) -> Dict[int, float]:
        """Fusion params consumed through a dynamic-slice/gather inside the
        body (possibly via bitcast/reshape view chains): traffic is the
        slice, not the whole (loop-invariant) operand."""
        out: Dict[int, float] = {}
        if body is None:
            return out
        track: Dict[str, int] = {}  # name -> param idx it derives from
        for ins in body.instrs:
            if ins.op == "parameter":
                m = re.match(r"(\d+)", ins.args)
                if m:
                    track[ins.name] = int(m.group(1))
            elif ins.op in _VIEWS:
                ops = re.findall(r"%([\w.\-]+)", ins.args)
                if len(ops) == 1 and ops[0] in track:
                    track[ins.name] = track[ops[0]]
            elif ins.op in ("dynamic-slice", "gather"):
                for opn in re.findall(r"%([\w.\-]+)", ins.args):
                    if opn in track:
                        idx = track[opn]
                        b = _shape_bytes(ins.shape)
                        out[idx] = min(out.get(idx, b), b)
                        # the slice result is small; further views stay small
                        track[ins.name] = idx
        return out

    def op_traffic(ins: Instr, shapes: Dict[str, str],
                   root_op: Optional[str] = None,
                   body: Optional[Computation] = None) -> float:
        """HBM traffic model.  Slice-type ops touch only the slice, not the
        whole (aliased/loop-invariant) buffer: a dynamic-update-slice into a
        stacked remat residual writes one slice in place; a fusion gathering
        one KV block from the stacked KV array reads one block."""
        kind = root_op or ins.op
        operands = [o for o in re.findall(r"%([\w.\-]+)", ins.args)
                    if o in shapes]
        sliced = _sliced_params(body)
        operand_bytes = [
            sliced.get(i, _shape_bytes(shapes[opn]))
            for i, opn in enumerate(operands)
        ]
        if kind in ("dynamic-slice", "gather"):
            return 2.0 * _shape_bytes(ins.shape)
        if kind in ("dynamic-update-slice", "scatter"):
            small = sum(operand_bytes) - (max(operand_bytes) if operand_bytes else 0)
            return 3.0 * small
        return _shape_bytes(ins.shape) + sum(operand_bytes)

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0 or comp.name in fusion_bodies:
            continue
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                flops += m * _dot_flops(ins, comp.shapes)
                traffic += m * op_traffic(ins, comp.shapes)
                continue
            is_coll = any(
                ins.op == c or ins.op.startswith(c + "-") for c in _COLLECTIVES
            )
            if is_coll:
                if ins.op.endswith("-start"):
                    continue  # counted at the -done
                kind = next(c for c in _COLLECTIVES if ins.op.startswith(c))
                b = _shape_bytes(ins.shape)
                coll_bytes[kind] += m * b
                coll_counts[kind] += m
                traffic += m * b
                continue
            if ins.op == "fusion":
                body = _named_attr(ins, "calls")
                root_op = None
                bcomp = comps.get(body)
                if bcomp and bcomp.instrs:
                    root_op = bcomp.instrs[-1].op
                traffic += m * op_traffic(ins, comp.shapes, root_op, bcomp)
                if body in comps:
                    for sub in comps[body].instrs:
                        if sub.op in ("dot", "convolution"):
                            flops += m * _dot_flops(sub, comps[body].shapes)
                continue
            if ins.op in ("dynamic-slice", "dynamic-update-slice", "gather",
                          "scatter"):
                traffic += m * op_traffic(ins, comp.shapes)
                continue
            if ins.op not in _SKIP_TRAFFIC:
                traffic += m * _shape_bytes(ins.shape)

    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collective_bytes": dict(coll_bytes),
        "collective_bytes_total": float(sum(coll_bytes.values())),
        "collective_counts": dict(coll_counts),
        "n_computations": len(comps),
    }
