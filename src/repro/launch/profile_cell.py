import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run cell profiler: rank top HBM-traffic / collective / FLOP
contributors (with loop multipliers) for one (arch, shape, mesh) cell.

    PYTHONPATH=src python -m repro.launch.profile_cell --arch deepseek-v2-236b \
        --shape train_4k --top 15
"""

import argparse
import re
from collections import defaultdict

import jax

from repro.configs.base import ALL_SHAPES
from repro.configs.registry import get_config
from repro.dist.sharding import sharding_context
from repro.launch import hlo_analysis as H
from repro.launch.dryrun import build_cell, knobs_for
from repro.launch.mesh import make_production_mesh


def contributors(text: str):
    comps = H.parse_hlo(text)
    mult = defaultdict(float)
    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                b = H._named_attr(ins, "calls")
                if b:
                    fusion_bodies.add(b)

    def visit(comp, m, depth=0):
        if depth > 64:
            return
        mult[comp.name] += m
        for ins in comp.instrs:
            if ins.op == "while":
                trips = H._trip_count(ins, comps)
                b = H._named_attr(ins, "body")
                c = H._named_attr(ins, "condition")
                if b in comps:
                    visit(comps[b], m * trips, depth + 1)
                if c in comps:
                    mult[c] += m * (trips + 1)
            elif ins.op in ("call", "custom-call", "async-start"):
                t = H._named_attr(ins, "to_apply")
                if t in comps:
                    visit(comps[t], m, depth + 1)

    for e in [c for c in comps.values() if c.is_entry]:
        visit(e, 1.0)

    rows = []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0 or comp.name in fusion_bodies:
            continue
        for ins in comp.instrs:
            meta = re.search(r'op_name="([^"]*)"', ins.attrs)
            tag = meta.group(1)[-70:] if meta else ins.name[-40:]
            is_coll = any(ins.op.startswith(c) for c in H._COLLECTIVES)
            if ins.op in ("dot", "convolution"):
                t = H._shape_bytes(ins.shape)
                f = H._dot_flops(ins, comp.shapes)
                rows.append((m * t, m * f, 0.0, m, ins.op, ins.shape[:60], tag))
            elif ins.op == "fusion":
                b = H._named_attr(ins, "calls")
                root = comps[b].instrs[-1].op if b in comps and comps[b].instrs else None
                # approximate: output + operands (slice-aware)
                ob = [H._shape_bytes(comp.shapes[o])
                      for o in re.findall(r"%([\w.\-]+)", ins.args)
                      if o in comp.shapes]
                if root in ("dynamic-slice", "gather"):
                    t = 2 * H._shape_bytes(ins.shape)
                elif root in ("dynamic-update-slice", "scatter"):
                    small = sum(ob) - (max(ob) if ob else 0)
                    t = 3 * small
                else:
                    t = H._shape_bytes(ins.shape) + sum(ob)
                f = 0.0
                if b in comps:
                    f = sum(H._dot_flops(s, comps[b].shapes)
                            for s in comps[b].instrs
                            if s.op in ("dot", "convolution"))
                rows.append((m * t, m * f, 0.0, m, f"fusion:{root}",
                             ins.shape[:60], tag))
            elif is_coll and not ins.op.endswith("-start"):
                t = H._shape_bytes(ins.shape)
                rows.append((m * t, 0.0, m * t, m, ins.op, ins.shape[:60], tag))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--sort", choices=("traffic", "flops", "coll"),
                    default="traffic")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = next(s for s in ALL_SHAPES if s.name == args.shape)
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    knobs = knobs_for(args.arch)
    with mesh, sharding_context(mesh):
        fn, cell_args, _ = build_cell(cfg, shape, mesh, knobs)
        compiled = fn.lower(*cell_args).compile()
    rows = contributors(compiled.as_text())
    key = {"traffic": 0, "flops": 1, "coll": 2}[args.sort]
    rows.sort(key=lambda r: -r[key])
    print(f"{'traffic':>10s} {'flops':>10s} {'coll':>10s} {'mult':>8s} "
          f"{'op':24s} shape / origin")
    for t, f, c, m, op, sh, tag in rows[: args.top]:
        print(f"{t/1e9:9.1f}G {f/1e9:9.1f}G {c/1e9:9.1f}G {m:8.0f} {op:24s} "
              f"{sh}")
        print(f"{'':42s}{tag}")


if __name__ == "__main__":
    main()
