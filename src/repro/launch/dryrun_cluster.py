import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the PAPER'S OWN workload on the production mesh: distributed
streaming graph clustering (local chunked pass per device + contracted
global merge), lowered and compiled for 256/512 chips.

This is the third §Perf hillclimb cell — "most representative of the paper's
technique".  Lever: the chunk size B of the Jacobi tier trades scatter count
(per-edge work) against conflict-window size; larger chunks also amortise the
per-chunk fixed cost of the scan.

    PYTHONPATH=src python -m repro.launch.dryrun_cluster --nodes 1048576 \
        --edges-per-shard 131072 --chunk 4096
"""

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.chunked import cluster_stream_chunked
from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_production_mesh

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def build(n_nodes: int, edges_per_shard: int, chunk: int, mesh,
          mode: str = "shardmap"):
    n_shards = int(np.prod(list(mesh.shape.values())))
    axes = tuple(mesh.axis_names)

    if mode == "gspmd":
        # Baseline: vmap + GSPMD auto-partitioning.  The (n+1,)-sized state
        # vector is NOT divisible by the mesh, so the partitioner replicates
        # the scan carry — every per-chunk scatter update becomes an
        # all-reduce (measured: collective-dominant, 8.2 s at chunk=1024).
        def local_phase(shards):  # (P, L, 2) int32
            def one(shard):
                return cluster_stream_chunked(shard, 1 << 16, n_nodes, chunk)

            return jax.vmap(one)(shards)

    else:
        # Optimised: explicit per-device execution.  Each device owns its
        # stream shard and its full 3n-int state copy (the paper's memory
        # model, one copy per worker) — zero collectives by construction.
        def local_phase(shards):
            def per_device(shard):  # (1, L, 2)
                c, d, v = cluster_stream_chunked(
                    shard[0], 1 << 16, n_nodes, chunk
                )
                return c[None], d[None], v[None]

            return jax.shard_map(
                per_device,
                mesh=mesh,
                in_specs=P(axes, None, None),
                out_specs=(P(axes, None),) * 3,
                check_vma=False,
            )(shards)

    spec = NamedSharding(mesh, P(axes, None, None))
    shards = jax.ShapeDtypeStruct(
        (n_shards, edges_per_shard, 2), jnp.int32, sharding=spec
    )
    fn = jax.jit(local_phase, in_shardings=spec)
    return fn, shards


def run(n_nodes, edges_per_shard, chunk, multi_pod=False, out=None,
        mode="shardmap"):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    with mesh:
        fn, shards = build(n_nodes, edges_per_shard, chunk, mesh, mode)
        compiled = fn.lower(shards).compile()
    mem = compiled.memory_analysis()
    hlo = analyze(compiled.as_text())
    m_edges = n_dev * edges_per_shard
    terms = {
        "compute_s": hlo["flops"] / PEAK_FLOPS,
        "memory_s": hlo["traffic_bytes"] / HBM_BW,
        "collective_s": hlo["collective_bytes_total"] / ICI_BW,
    }
    res = {
        "workload": "graph-streamcluster(local-phase)",
        "mode": mode,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "n_nodes": n_nodes,
        "edges_total": m_edges,
        "chunk": chunk,
        "bytes_per_device": mem.argument_size_in_bytes
        + mem.temp_size_in_bytes + mem.output_size_in_bytes,
        "hlo": {k: hlo[k] for k in
                ("flops", "traffic_bytes", "collective_bytes_total")},
        "roofline": {**terms, "dominant": max(terms, key=terms.get)},
        # useful work proxy: bytes that MUST move per edge: 2 endpoint ids +
        # ~6 state words touched = ~32 B/edge
        "useful_bytes_per_device": 32.0 * edges_per_shard,
        "useful_traffic_ratio": 32.0 * edges_per_shard
        / max(hlo["traffic_bytes"], 1.0),
        "edges_per_s_per_device_roofline": edges_per_shard
        / max(max(terms.values()), 1e-30),
    }
    if out:
        os.makedirs(out, exist_ok=True)
        tag = f"graphcluster__{mode}__chunk{chunk}__{res['mesh']}.json"
        with open(os.path.join(out, tag), "w") as f:
            json.dump(res, f, indent=1)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=1 << 20)
    ap.add_argument("--edges-per-shard", type=int, default=1 << 17)
    ap.add_argument("--chunk", type=int, default=4096)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", choices=("shardmap", "gspmd"), default="shardmap")
    ap.add_argument("--out", default="results/dryrun_cluster")
    args = ap.parse_args()
    res = run(args.nodes, args.edges_per_shard, args.chunk, args.multi_pod,
              args.out, args.mode)
    r = res["roofline"]
    print(f"graph-cluster mode={args.mode} chunk={args.chunk} mesh={res['mesh']} "
          f"GB/dev={res['bytes_per_device']/1e9:.2f}")
    print(f"  compute {r['compute_s']*1e3:.3f} ms | memory "
          f"{r['memory_s']*1e3:.3f} ms | collective "
          f"{r['collective_s']*1e3:.3f} ms -> {r['dominant']}")
    print(f"  roofline edge rate: "
          f"{res['edges_per_s_per_device_roofline']:,.0f} edges/s/device "
          f"({res['edges_per_s_per_device_roofline']*res['n_devices']:,.0f} total)")


if __name__ == "__main__":
    main()
