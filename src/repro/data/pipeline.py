"""Deterministic synthetic data pipelines (offline container — no corpora).

``TokenPipeline`` emits sequences with learnable structure (per-sequence
affine token chains + noise) so end-to-end training drivers show a real
decreasing loss.  The pipeline is host-sharded (each host generates its own
disjoint slice) and checkpointable: its state is a single step counter, so
restore-and-replay is exact.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass
class TokenPipeline:
    vocab_size: int
    batch: int  # per-host batch
    seq_len: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    noise: float = 0.05
    step: int = 0

    def state_dict(self) -> Dict:
        return {"step": self.step}

    def load_state_dict(self, s: Dict):
        self.step = int(s["step"])

    def __next__(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.step) * 31 + self.host_id
        )
        self.step += 1
        B, S, V = self.batch, self.seq_len, self.vocab_size
        start = rng.integers(0, V, size=(B, 1))
        stride = rng.integers(1, min(V - 1, 97), size=(B, 1))
        seq = (start + stride * np.arange(S + 1)[None, :]) % V
        flip = rng.random((B, S + 1)) < self.noise
        seq = np.where(flip, rng.integers(0, V, size=(B, S + 1)), seq)
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
        }

    def __iter__(self):
        return self


@dataclass
class EncDecPipeline:
    """Adds stub modality inputs (frames / image patches) to token batches."""

    inner: TokenPipeline
    enc_len: int
    d_model: int
    dtype: str = "bfloat16"

    def state_dict(self):
        return self.inner.state_dict()

    def load_state_dict(self, s):
        self.inner.load_state_dict(s)

    def __next__(self):
        batch = next(self.inner)
        rng = np.random.default_rng(self.inner.step * 7 + 5)
        batch["enc"] = rng.standard_normal(
            (self.inner.batch, self.enc_len, self.d_model), dtype=np.float32
        ).astype(self.dtype)
        return batch

    def __iter__(self):
        return self


def make_pipeline(cfg, batch: int, seq_len: int, seed: int = 0,
                  host_id: int = 0, n_hosts: int = 1):
    inner = TokenPipeline(
        vocab_size=cfg.vocab_size, batch=batch, seq_len=seq_len, seed=seed,
        host_id=host_id, n_hosts=n_hosts,
    )
    if cfg.encoder_layers:
        return EncDecPipeline(inner, cfg.n_frames, cfg.d_model, cfg.dtype)
    if cfg.n_image_tokens:
        return EncDecPipeline(inner, cfg.n_image_tokens, cfg.d_model, cfg.dtype)
    return inner
