"""Serving steps: prefill + KV-cache greedy decode."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, make_cache, prefill

Array = jax.Array


def make_prefill_step(cfg: ModelConfig, cache_size: int):
    def prefill_step(params, tokens, enc_inputs=None):
        logits, cache = prefill(
            params, cfg, tokens, cache_size=cache_size, enc_inputs=enc_inputs
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_decode(params, cache, token, pos):
        """token: (B,) int32; pos: scalar int32 write position."""
        logits, cache = decode_step(params, cfg, cache, token[:, None], pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, cache

    return serve_decode


def greedy_generate(
    params,
    cfg: ModelConfig,
    prompt: Array,
    n_steps: int,
    cache_size: Optional[int] = None,
    enc_inputs=None,
):
    """Prefill + greedy decode loop (lax.fori over decode steps)."""
    B, S = prompt.shape
    cache_size = cache_size or (S + n_steps)
    pf = jax.jit(make_prefill_step(cfg, cache_size))
    dec = jax.jit(make_decode_step(cfg))

    next_tok, _, cache = pf(params, prompt, enc_inputs)
    out = [next_tok]
    for i in range(n_steps - 1):
        next_tok, _, cache = dec(params, cache, next_tok, jnp.int32(S + i))
        out.append(next_tok)
    return jnp.stack(out, axis=1)
