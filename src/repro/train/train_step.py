"""Training step: chunked cross-entropy, microbatch accumulation, remat,
optional int8 gradient compression with error feedback.

The loss head is computed in sequence chunks so the (B, S, V) logits tensor
is never materialised (decisive for 262k-vocab gemma3 at 4k×256: full logits
would be 2 TB in f32).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.compress import compress_grads, init_error_feedback
from repro.models.transformer import forward, init_params, unembed
from repro.optim.adamw import AdamW

Array = jax.Array


def chunked_xent(params, cfg: ModelConfig, hidden: Array, labels: Array,
                 chunk: int = 1024):
    """Mean CE + mean log-Z^2 (z-loss term), streaming over sequence chunks."""
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    hc = hidden.reshape(B, nc, chunk, D).swapaxes(0, 1)  # (nc, B, c, D)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    def body(carry, xs):
        ce_sum, z_sum = carry
        h, l = xs
        logits = unembed(params, cfg, h)  # (B, c, V) float32
        logz = jax.nn.logsumexp(logits, axis=-1)
        # Gold logit via masked reduction, NOT take_along_axis: the vocab axis
        # is "model"-sharded and a gather would force a full logits all-gather
        # (measured: +13 GB/device temp on qwen train_4k).  A where+sum keeps
        # the reduction local + one small psum.
        ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(
            jnp.where(ids == l[..., None], logits, 0.0), axis=-1
        )
        ce_sum += jnp.sum(logz - gold)
        z_sum += jnp.sum(jnp.square(logz))
        return (ce_sum, z_sum), None

    (ce, zz), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hc, lc)
    )
    n = B * S
    return ce / n, zz / n


def make_loss_fn(cfg: ModelConfig, *, remat: bool = True, ce_chunk: int = 1024,
                 aux_coef: float = 0.01, z_coef: float = 1e-4,
                 remat_group: int = 0):
    def loss_fn(params, batch):
        hidden, aux = forward(
            params, cfg, batch["tokens"], enc_inputs=batch.get("enc"),
            remat=remat, remat_group=remat_group,
        )
        ce, zz = chunked_xent(params, cfg, hidden, batch["labels"], ce_chunk)
        loss = ce + z_coef * zz + aux_coef * aux
        return loss, {"ce": ce, "z": zz, "aux": aux}

    return loss_fn


def init_train_state(key, cfg: ModelConfig, opt: AdamW,
                     grad_compress: bool = False) -> Dict[str, Any]:
    params = init_params(key, cfg)
    state = {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if grad_compress:
        state["ef"] = init_error_feedback(params)
    return state


def make_train_step(
    cfg: ModelConfig,
    opt: AdamW,
    lr_fn: Callable,
    *,
    remat: bool = True,
    ce_chunk: int = 1024,
    microbatch: Optional[int] = None,
    grad_compress: bool = False,
    aux_coef: float = 0.01,
    z_coef: float = 1e-4,
    accum_dtype: str = "float32",
    remat_group: int = 0,
):
    """Returns train_step(state, batch) -> (state, metrics)."""
    loss_fn = make_loss_fn(
        cfg, remat=remat, ce_chunk=ce_chunk, aux_coef=aux_coef, z_coef=z_coef,
        remat_group=remat_group,
    )
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if not microbatch:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        # Gradient accumulation over microbatches (f32 accumulators).
        B = batch["tokens"].shape[0]
        assert B % microbatch == 0
        k = B // microbatch

        def slice_mb(x, i):
            return jax.lax.dynamic_slice_in_dim(x, i * microbatch, microbatch, 0)

        def body(carry, i):
            acc, loss_acc = carry
            mb = {k_: slice_mb(v, i) for k_, v in batch.items()}
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + (g / k).astype(a.dtype), acc, grads
            )
            return (acc, loss_acc + loss / k), None

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.dtype(accum_dtype)), params
        )
        (grads, loss), _ = jax.lax.scan(
            body, (zero, jnp.float32(0.0)), jnp.arange(k)
        )
        return loss, {"ce": loss, "z": 0.0, "aux": 0.0}, grads

    def train_step(state, batch):
        loss, metrics, grads = compute_grads(state["params"], batch)
        new_state = dict(state)
        if grad_compress:
            grads, new_state["ef"] = compress_grads(grads, state["ef"])
        lr = lr_fn(state["step"])
        params, opt_state, om = opt.update(
            grads, state["opt"], state["params"], lr
        )
        new_state.update(
            params=params, opt=opt_state, step=state["step"] + 1
        )
        metrics = dict(metrics, loss=loss, lr=lr, **om)
        return new_state, metrics

    return train_step
